"""Layer-wise hybrid mapping strategy (paper Sec. 3.5, Fig. 6, Table 4).

For each layer l and mapping m in {IS, WS} we profile:
  d_l(m) — accuracy degradation (percentage points vs. the noise-free model)
           when ONLY layer l runs through the noisy analog path under m,
  e_l(m) — that layer's EDP under m (from the analytical energy model).

The per-layer choice minimizes the balanced metric

    M_l(m) = (d_l(m)/d_ref)^alpha_l * (e_l(m)/e_ref)^(1-alpha_l)
    d_ref = min_m d_l(m),  e_ref = min_m e_l(m)
    alpha_l = alpha_min + gamma * log(1 + d_ref/d_tol)

with the paper's hyperparameters alpha_min=0.01, gamma=0.1, d_tol=1.0 —
layers whose best-case degradation exceeds ~1% get their accuracy term
up-weighted logarithmically.

This module is model-agnostic: the CNN experiment (benchmarks/table4_hybrid)
supplies accuracy callbacks; the LM fleet uses the EDP side only (its
accuracy profiling is the same code path on logits agreement).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

from repro.core import energy as E
from repro.core.constants import ComputeMode, Mapping, OPEConfig

ALPHA_MIN = 0.01
GAMMA = 0.1
D_TOL = 1.0         # percentage points
_D_FLOOR = 1e-3     # numerical floor so ratios stay finite at zero degradation


@dataclasses.dataclass
class LayerProfile:
    """Measured IS/WS behaviour of one layer."""

    name: str
    d_is: float     # accuracy degradation [pp] with layer on IS analog path
    d_ws: float     # ... with layer on WS analog path
    e_is: float     # EDP [J*s] under IS
    e_ws: float     # EDP [J*s] under WS

    def d(self, m: Mapping) -> float:
        return self.d_is if m is Mapping.IS else self.d_ws

    def e(self, m: Mapping) -> float:
        return self.e_is if m is Mapping.IS else self.e_ws


def alpha_of(d_ref: float) -> float:
    """Layer-adaptive accuracy weight alpha_l."""
    return min(1.0, ALPHA_MIN + GAMMA * math.log(1.0 + max(d_ref, 0.0) / D_TOL))


def balanced_metric(p: LayerProfile, m: Mapping) -> float:
    d_ref = max(min(p.d_is, p.d_ws), _D_FLOOR)
    e_ref = max(min(p.e_is, p.e_ws), 1e-30)
    a = alpha_of(d_ref)
    d = max(p.d(m), _D_FLOOR)
    return (d / d_ref) ** a * (p.e(m) / e_ref) ** (1.0 - a)


def choose_mapping(p: LayerProfile) -> Mapping:
    """arg-min of the balanced metric for one layer."""
    m_is = balanced_metric(p, Mapping.IS)
    m_ws = balanced_metric(p, Mapping.WS)
    return Mapping.IS if m_is < m_ws else Mapping.WS


def hybrid_plan(profiles: Sequence[LayerProfile]) -> dict[str, Mapping]:
    """The paper's layer-wise hybrid mapping plan (pure balanced-metric
    argmin).  Single-layer degradations under-estimate full-plan cost when
    noise compounds across layers — `repro.robust.sensitivity` provides
    the Monte-Carlo-verified search (`searched_hybrid_plan`) that
    guarantees the chosen plan matches-or-beats pure WS on a chip
    ensemble."""
    return {p.name: choose_mapping(p) for p in profiles}


def degradation_fn_from_matrix(deg) -> Callable[[str, Mapping], float]:
    """Adapt a `{layer: {mapping.value: pp}}` degradation matrix (the
    output of `repro.robust.sensitivity.degradation_matrix`) to the
    `degradation_fn(name, mapping)` callback the profilers take."""
    return lambda name, m: deg[name][m.value]


def profile_layers(layers: Sequence[E.LayerShape],
                   ope: OPEConfig,
                   degradation_fn: Callable[[str, Mapping], float],
                   mode: ComputeMode = ComputeMode.MIXED,
                   osa: E.OSAEnergyConfig = E.OSA_OPTIMAL,
                   batch: int = 1) -> list[LayerProfile]:
    """Build LayerProfiles: EDP from the analytical model, accuracy from a
    user callback `degradation_fn(layer_name, mapping) -> pp degradation`.

    The callback is where behavioural simulation happens (inject noise into
    exactly one layer, eval, diff against clean accuracy) — see
    benchmarks/table4_hybrid.py for the CNN instantiation.
    """
    out = []
    for layer in layers:
        e_is = E.layer_energy(layer, ope, Mapping.IS, mode, osa, batch=batch).edp
        e_ws = E.layer_energy(layer, ope, Mapping.WS, mode, osa, batch=batch).edp
        out.append(LayerProfile(
            name=layer.name,
            d_is=degradation_fn(layer.name, Mapping.IS),
            d_ws=degradation_fn(layer.name, Mapping.WS),
            e_is=e_is, e_ws=e_ws,
        ))
    return out


def profile_layers_fast(layers: Sequence[E.LayerShape],
                        ope: OPEConfig,
                        degradation_fn: Callable[[str, Mapping], float]
                        | None = None,
                        mode: ComputeMode = ComputeMode.MIXED,
                        osa: E.OSAEnergyConfig = E.OSA_OPTIMAL,
                        batch: int = 1) -> list[LayerProfile]:
    """Vectorized LayerProfile builder for model-zoo-scale networks.

    Both mappings' per-layer EDPs come from `core.energy_vec` in two vmapped
    calls instead of 2*L scalar evaluations.  Without a degradation
    callback (zoo workloads have no behavioural twin) degradations are 0,
    alpha collapses to alpha_min, and the hybrid plan reduces to the
    per-layer EDP argmin — the paper's search with the accuracy term muted.
    """
    import numpy as np
    from jax.experimental import enable_x64

    from repro.core import energy_vec as EV

    cand = EV.stack_candidates([ope])
    stacked = EV.stack_layers(layers)
    edps = {}
    with enable_x64():
        for mp in (Mapping.IS, Mapping.WS):
            spec = EV.EnergySpec.make(mapping=mp, mode=mode, osa=osa,
                                      batch=batch)
            en, lat = EV.grid_energy(cand, stacked, spec)
            edps[mp] = np.asarray(en[0] * lat[0])
    d_fn = degradation_fn if degradation_fn is not None \
        else (lambda name, m: 0.0)
    return [LayerProfile(
        name=layer.name,
        d_is=d_fn(layer.name, Mapping.IS),
        d_ws=d_fn(layer.name, Mapping.WS),
        e_is=float(edps[Mapping.IS][i]), e_ws=float(edps[Mapping.WS][i]))
        for i, layer in enumerate(layers)]


def plan_edp(layers: Sequence[E.LayerShape], plan: dict[str, Mapping],
             ope: OPEConfig, mode: ComputeMode = ComputeMode.MIXED,
             osa: E.OSAEnergyConfig = E.OSA_OPTIMAL,
             batch: int = 1) -> float:
    """Network EDP under a given per-layer mapping plan.

    The trace-based counterpart is `rosa.EnergyLedger.edp`, which prices the
    matmuls an Engine actually routed; on the same layers/plan the two agree
    by construction (tests/test_engine.py asserts it).
    """
    return E.network_energy(layers, ope, plan, mode, osa, batch=batch).edp


def execution_plan(profiles: Sequence[LayerProfile], default_cfg,
                   layers: Sequence[str] | None = None):
    """Lift profiled layers straight into an executable `rosa.ExecutionPlan`:
    per-layer balanced-metric argmin, overriding `default_cfg`'s mapping."""
    # local import: repro.rosa initializes through repro.core, so a
    # module-level import here would be circular
    from repro.rosa import ExecutionPlan
    return ExecutionPlan.from_mapping_plan(
        default_cfg, hybrid_plan(profiles),
        layers if layers is not None else [p.name for p in profiles])
