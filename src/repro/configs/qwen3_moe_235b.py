"""qwen3-moe-235b-a22b [hf:Qwen/Qwen3-235B-A22B family].

94L d_model=4096 64H (GQA kv=4) MoE 128 experts top-8, expert d_ff=1536,
vocab=151936, qk_norm.  ROSA GEMM mapping applies to QKV/O and all expert
FFNs; the router stays electronic (DESIGN.md §Arch-applicability).
"""

from repro.models.moe import MoEConfig
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    vocab=151936,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    qk_norm=True,
    rope_theta=1e6,
    moe=MoEConfig(n_experts=128, top_k=8, d_model=4096, d_ff=1536,
                  capacity_factor=1.25),
    moe_ep=True,
)

SMOKE = ModelConfig(
    name="qwen3-moe-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    vocab=256,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    qk_norm=True,
    moe=MoEConfig(n_experts=8, top_k=2, d_model=64, d_ff=32,
                  capacity_factor=2.0),
    moe_ep=False,
)
