"""Execution backends for the ROSA optical matmul + the `RosaConfig` knob.

This module is the single home of the paper's MAC semantics (previously
`core/onn_linear.py`).  A *backend* is the contraction primitive that turns
noise-placed operands into outputs:

    dense   exact einsum contraction — the ideal-OSA closed form (Eq. 2),
            also used for non-optical layers routed by `rosa.Engine`.
    ref     pure-jnp OSA pipeline (signed-digit planes + slot gains, Eq. 1)
            — the oracle, honours OSAConfig non-idealities.
    pallas  the Pallas TPU kernel in kernels/osa_matmul (bit-plane
            decomposition + per-plane MXU matmuls), interpret-mode on CPU.

Backends are registered by name (`register_backend`) and selected by
`RosaConfig.backend`; the default "auto" resolves per platform (pallas on
TPU, ref elsewhere).  This replaces the old `use_kernel: bool` toggle.

Forward semantics (mixed digital-analog mode, Sec. 2-3.1):

  WS mapping: weights are programmed onto TO-tuned analog MRRs through the
    noisy voltage chain (mrr.realize_weights); activations take the exact
    digital EO path (8-bit signed-digit streams) and accumulate via OSA.
  IS mapping: the roles swap — activations are realized on the noisy analog
    MRRs, weights travel the exact digital path.
  ANALOG mode (DEAP baseline): both operands pass the noisy analog chain.

Backward semantics: straight-through — gradients flow as if the matmul were
exact, which makes every model in the zoo noise-aware-trainable (QAT) with
zero graph surgery.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import mrr, osa, quant
from repro.core.constants import ComputeMode, Mapping


@dataclasses.dataclass(frozen=True)
class RosaConfig:
    """Per-layer execution config for the optical backend."""

    mapping: Mapping = Mapping.WS
    mode: ComputeMode = ComputeMode.MIXED
    quant_bits: int = 8
    pam_bits: int = 1
    noise: mrr.NoiseModel = mrr.IDEAL
    osa_cfg: osa.OSAConfig = osa.IDEAL_OSA
    mrr_params: mrr.MRRParams = mrr.DEFAULT_PARAMS
    backend: str = "auto"   # registered backend name, or "auto" (platform)
    act_per_vector: bool = False  # quantize each activation ROW at its own
    #   full-scale.  Default False preserves historic QAT numerics; serving
    #   (repro.serve) turns it on so a request's logits cannot depend on
    #   which other requests share its decode batch (per-tensor scales
    #   couple rows through one absmax — the differential suite caught it)

    @property
    def qcfg(self) -> quant.QuantConfig:
        """Quantization config derived from `quant_bits`."""
        return quant.QuantConfig(bits=self.quant_bits)


DEFAULT = RosaConfig()


# ---------------------------------------------------------------------------
# Backend registry
# ---------------------------------------------------------------------------
# Two backend classes share the registry:
#   * contraction backends (the default) take noise-placed operands:
#     (x_eff (M,K), w_eff (K,N), cfg: RosaConfig | None) -> (M,N);
#   * RAW backends (`raw=True`) replace the whole conditioning+contraction
#     pipeline: (x, w, cfg, *, key, var, gate, mgate) -> (M,N).  The fused
#     megakernel is raw — quantize/realize/OSA/dequant happen inside one
#     pallas_call, so _forward must hand it the UNconditioned operands.
Backend = Callable[..., jax.Array]

_BACKENDS: dict[str, Backend] = {}
_RAW_BACKENDS: set[str] = set()


def register_backend(name: str, raw: bool = False):
    """Decorator: register a backend under `name` (`raw=True` for backends
    that fuse operand conditioning into the contraction)."""
    def deco(fn: Backend) -> Backend:
        """Register `fn` under `name` and return it unchanged."""
        _BACKENDS[name] = fn
        if raw:
            _RAW_BACKENDS.add(name)
        return fn
    return deco


def backend_names() -> list[str]:
    """Registered contraction-backend names."""
    return sorted(_BACKENDS)


def is_raw_backend(name: str) -> bool:
    """Whether `name` registered as a raw (fully-fused) backend."""
    return name in _RAW_BACKENDS


def resolve_backend(name: str) -> tuple[str, Backend]:
    """Resolve a backend name ("auto" -> platform pick) to (name, fn).

    On TPU "auto" picks the fused megakernel (ONE pallas_call for the
    whole analog pipeline — ROADMAP's single biggest raw-speed lever);
    elsewhere the pure-jnp composed reference.  The ideal-QAT shortcut in
    `_forward` still short-circuits before any backend runs.
    """
    if name == "auto":
        name = "fused" if jax.default_backend() == "tpu" else "ref"
    try:
        return name, _BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; registered: {backend_names()}"
        ) from None


@register_backend("dense")
def _dense_backend(x: jax.Array, w: jax.Array, cfg=None) -> jax.Array:
    return x @ w


@register_backend("ref")
def _ref_backend(x: jax.Array, w: jax.Array, cfg: RosaConfig) -> jax.Array:
    return osa.osa_matmul_ref(x, w, cfg.osa_cfg, cfg.qcfg,
                              per_vector=cfg.act_per_vector)


@register_backend("pallas")
def _pallas_backend(x: jax.Array, w: jax.Array, cfg: RosaConfig) -> jax.Array:
    # deferred import: pulls in jax.experimental.pallas only when routed here
    from repro.kernels.osa_matmul import ops as osa_ops
    return osa_ops.osa_matmul(x, w, quant_bits=cfg.quant_bits,
                              pam_bits=cfg.pam_bits,
                              per_vector=cfg.act_per_vector)


@register_backend("fused", raw=True)
def _fused_backend(x: jax.Array, w: jax.Array, cfg: RosaConfig, *,
                   key=None, var=None, gate=None, mgate=None) -> jax.Array:
    # deferred import: pulls in jax.experimental.pallas only when routed here
    from repro.kernels.rosa_fused import ops as fused_ops
    # decomposition radix follows osa_cfg (what the composed ref chain
    # uses), NOT RosaConfig.pam_bits (which only the per-op pallas backend
    # reads) — the fused path must price and compute like the chain it fuses
    return fused_ops.rosa_fused_matmul(
        x, w, key, var, gate, mgate, mapping=cfg.mapping, mode=cfg.mode,
        quant_bits=cfg.quant_bits, pam_bits=cfg.osa_cfg.pam_bits,
        act_per_vector=cfg.act_per_vector, noise=cfg.noise,
        osa_cfg=cfg.osa_cfg, p=cfg.mrr_params)


# ---------------------------------------------------------------------------
# Operand conditioning (noise placement)
# ---------------------------------------------------------------------------
def _noisy_realize(t: jax.Array, cfg: RosaConfig, key: jax.Array | None,
                   var: mrr.StaticVariation | None = None,
                   per_vector: bool = False):
    """Quantize a tensor to cfg.quant_bits and realize it on analog MRRs.

    Values are normalized to the MRR weight range [q_min, q_max],
    programmed through the physical chain with DAC/thermal noise and the
    chip's static variation, and de-normalized.  This is where WS puts
    weights and IS puts activations.

    Weights are programmed once and share one per-tensor full-scale;
    activations (`per_vector=True`) are driven vector-at-a-time, each
    (M, K) row at its own DAC full-scale — batch outliers must not
    compress every other sample's analog resolution.
    """
    scale = quant.absmax_scale(t, per_vector)
    q = quant.fake_quant(t / scale, cfg.qcfg)          # 8-bit grid in [-1,1]
    w = mrr.realize_weights(q, key, cfg.mrr_params, cfg.noise, var)
    return w * scale


def _digital_path(t: jax.Array, cfg: RosaConfig,
                  per_vector: bool = False):
    """Exact digital EO encoding: quantization is the only error source.
    `per_vector` applies to the streamed (activation) operand only —
    weights always share one programmed full-scale.
    """
    return quant.fake_quant(t, cfg.qcfg, per_vector=per_vector)


# orientation-aware variation broadcast now lives in core (the fused kernel
# wrapper needs the identical convention); keep the historic private name.
_expand_lanes = mrr.expand_lanes


def realization_rms_error(t: jax.Array, cfg: RosaConfig,
                          var: mrr.StaticVariation | None = None,
                          per_vector: bool = False) -> jax.Array:
    """RMS programming error of realizing `t` on this chip (scalar, no key).

    The deviation between the ideal quantized operand and its *noiseless*
    analog realization under the chip's static variation, in normalized
    weight units.  Per-shot noise is deliberately excluded — it is i.i.d.
    across chips, so only the static part discriminates between them.  This
    is the control-variate surrogate feature of
    `repro.robust.ensemble.estimate_ensemble`: it costs one
    `realize_weights` sweep per (chip, layer) instead of a forward pass
    over the evaluation set, and is vmappable over a chip ensemble.
    """
    scale = quant.absmax_scale(t, per_vector)
    q = quant.fake_quant(t / scale, cfg.qcfg)
    w = mrr.realize_weights(q, None, cfg.mrr_params, mrr.IDEAL,
                            _expand_lanes(var, t))
    return jnp.sqrt(jnp.mean((w - q) ** 2))


def _analog_operand(t: jax.Array, cfg: RosaConfig, key: jax.Array | None,
                    var: mrr.StaticVariation | None,
                    gate: jax.Array | None, per_vector: bool = False):
    """Condition the analog-side operand: noisy realization under per-shot
    noise + static variation, optionally convex-blended against the exact
    digital path by a traced `gate` in [0, 1] (the vectorized
    perturb-one-layer selector of `repro.robust.sensitivity`).
    """
    clean = _digital_path(t, cfg, per_vector and cfg.act_per_vector)
    if cfg.noise.is_ideal and var is None and gate is None:
        return clean
    noisy = _noisy_realize(t, cfg, key, var, per_vector)
    if gate is None:
        return noisy
    return clean + gate * (noisy - clean)


def condition_weight(w: jax.Array, cfg: RosaConfig | None,
                     key: jax.Array | None,
                     var: mrr.StaticVariation | None = None,
                     gate: jax.Array | None = None):
    """Weight conditioning outside the matmul fast path (per-channel
    contractions like depthwise conv): analog realization + gate blend.
    Identity when the layer is dense or fully ideal (matching the historic
    dwconv behaviour: no fake-quant on the ideal path).
    """
    if cfg is None or (cfg.noise.is_ideal and var is None and gate is None):
        return w
    noisy = _noisy_realize(w, cfg, key, _expand_lanes(var, w))
    if gate is None:
        return noisy
    return w + gate * (noisy - w)


def _forward(x: jax.Array, w: jax.Array, cfg: RosaConfig,
             key: jax.Array | None,
             var: mrr.StaticVariation | None = None,
             gate: jax.Array | None = None,
             mgate: jax.Array | None = None) -> jax.Array:
    if cfg.mode is ComputeMode.MIXED:
        if cfg.noise.is_ideal and cfg.osa_cfg.is_ideal \
                and cfg.backend in ("auto", "dense") \
                and var is None and gate is None and mgate is None:
            # exactness-preserving shortcut: ideal OSA over signed-digit
            # planes == fake-quant matmul (tests/test_osa.py asserts this),
            # so QAT training skips the 7-plane decomposition entirely.
            # Guarded on the UNRESOLVED name: "auto" must stay fast for QAT
            # even when it would resolve to pallas on TPU, while an EXPLICIT
            # "ref"/"pallas" request always runs its registered pipeline.
            # ("dense" is algebraically the shortcut itself.)
            return _digital_path(x, cfg, cfg.act_per_vector) \
                @ _digital_path(w, cfg)
        bname, contract = resolve_backend(cfg.backend)
        if bname in _RAW_BACKENDS:
            # fully-fused pipeline: conditioning happens inside the kernel
            return contract(x, w, cfg, key=key, var=var, gate=gate,
                            mgate=mgate)
        if mgate is not None:
            # mapping superposition: realize BOTH orientations and blend the
            # OPERANDS by the traced selector (exact for mgate in {0, 1}) —
            # a whole {layer: IS|WS} plan becomes a float vector, so plan
            # candidates are a vmap axis (repro.robust.sensitivity's
            # MC-verified hybrid search).  One contraction either way.
            k_w, k_x = (jax.random.split(key) if key is not None
                        else (None, None))
            w_ws = _analog_operand(w, cfg, k_w, _expand_lanes(var, w), gate)
            x_is = _analog_operand(x, cfg, k_x, var, gate, per_vector=True)
            w_eff = (1.0 - mgate) * w_ws + mgate * _digital_path(w, cfg)
            x_eff = (1.0 - mgate) * _digital_path(x, cfg,
                                                  cfg.act_per_vector) \
                + mgate * x_is
        elif cfg.mapping in (Mapping.WS, Mapping.GEMM):
            w_eff = _analog_operand(w, cfg, key, _expand_lanes(var, w), gate)
            x_eff = _digital_path(x, cfg, cfg.act_per_vector)
        else:  # IS: inputs on the analog rings, weights exact digital
            w_eff = _digital_path(w, cfg)
            x_eff = _analog_operand(x, cfg, key, var, gate, per_vector=True)
        return contract(x_eff, w_eff, cfg)
    elif cfg.mode is ComputeMode.ANALOG:
        bname, contract = resolve_backend(cfg.backend)
        if bname in _RAW_BACKENDS:
            # single-shot analog readout, fused end to end (mgate is
            # ignored in ANALOG mode, matching the composed branch below)
            return contract(x, w, cfg, key=key, var=var, gate=gate,
                            mgate=None)
        if key is not None:
            k_w, k_x = jax.random.split(key)
        else:
            k_w = k_x = None
        w_eff = _analog_operand(w, cfg, k_w, _expand_lanes(var, w), gate)
        x_eff = _analog_operand(x, cfg, k_x, var, gate)
        return x_eff @ w_eff                      # single-shot analog readout
    elif cfg.mode is ComputeMode.DIGITAL:
        return _digital_path(x, cfg) @ _digital_path(w, cfg)
    raise ValueError(cfg.mode)


# ---------------------------------------------------------------------------
# The drop-in matmul with straight-through gradients
# ---------------------------------------------------------------------------
@partial(jax.custom_vjp, nondiff_argnums=(2,))
def rosa_matmul(x: jax.Array, w: jax.Array, cfg: RosaConfig = DEFAULT,
                key: jax.Array | None = None,
                var: mrr.StaticVariation | None = None,
                gate: jax.Array | None = None,
                mgate: jax.Array | None = None) -> jax.Array:
    """Optical matmul  y = x @ w  through the configured ROSA pipeline.

    x: (..., K) activations; w: (K, N) weights; returns (..., N).
    `var` pins one chip's static device variation on the analog operand;
    `gate` (traced scalar in [0, 1]) blends the analog path against the
    exact digital one; `mgate` (traced, {0=WS, 1=IS}) superposes the two
    mapping orientations.  Straight-through gradients w.r.t. both x and w
    (noise, variation and gates are treated as non-differentiable).
    """
    lead = x.shape[:-1]
    y = _forward(x.reshape(-1, x.shape[-1]), w, cfg, key, var, gate, mgate)
    return y.reshape(*lead, w.shape[-1])


def _fwd(x, w, cfg, key, var, gate, mgate):
    return rosa_matmul(x, w, cfg, key, var, gate, mgate), (x, w)


def _bwd(cfg, res, g):
    x, w = res
    g2 = g.reshape(-1, g.shape[-1])
    x2 = x.reshape(-1, x.shape[-1])
    dx = (g2 @ w.T).reshape(x.shape)
    dw = x2.T @ g2
    return dx, dw, None, None, None, None


rosa_matmul.defvjp(_fwd, _bwd)


def make_backend(cfg: RosaConfig):
    """Callable matmul closure (legacy helper, kept for compatibility)."""
    def matmul(x, w, key=None):
        """Closure: `x @ w` through `rosa_matmul` with this config."""
        return rosa_matmul(x, w, cfg, key)
    return matmul
