"""Architecture registry: one module per assigned architecture.

Each module defines CONFIG (the exact published dims) and SMOKE (a reduced
same-family config that runs a forward/train step on CPU in seconds).

    from repro.configs import get_config, get_smoke, ARCHS
"""

from __future__ import annotations

import importlib

ARCHS = [
    "qwen3_moe_235b",
    "deepseek_v2_236b",
    "qwen3_32b",
    "deepseek_67b",
    "mistral_large_123b",
    "gemma3_12b",
    "mamba2_1p3b",
    "seamless_m4t_medium",
    "phi3_vision_4p2b",
    "zamba2_1p2b",
]

# assignment ids -> module names
ARCH_IDS = {
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "qwen3-32b": "qwen3_32b",
    "deepseek-67b": "deepseek_67b",
    "mistral-large-123b": "mistral_large_123b",
    "gemma3-12b": "gemma3_12b",
    "mamba2-1.3b": "mamba2_1p3b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "phi-3-vision-4.2b": "phi3_vision_4p2b",
    "zamba2-1.2b": "zamba2_1p2b",
}


def _module(name: str):
    name = ARCH_IDS.get(name, name).replace("-", "_").replace(".", "p")
    return importlib.import_module(f"repro.configs.{name}")


def get_config(name: str):
    return _module(name).CONFIG


def get_smoke(name: str):
    return _module(name).SMOKE


def get_workload_zoo(**kw):
    """GEMM-lowered DSE workloads: paper CNNs + every registry arch.

    Lazy import — `model_zoo` pulls in the model stacks (jax-heavy), which
    plain config lookups should not pay for."""
    from repro.configs.model_zoo import zoo_workloads
    return zoo_workloads(**kw)
