"""repro.obs: tracer, metrics registry, energy bridge, CLI, integrations."""

import io
import itertools
import json
import threading

import numpy as np
import pytest

from repro import obs
from repro.obs import cli as obs_cli


def _fake_clock():
    t = itertools.count()
    return lambda: next(t) * 1e-3       # 1 ms per call


# ---------------------------------------------------------------------------
# Tracer core
# ---------------------------------------------------------------------------
def test_chrome_trace_valid_json_and_nesting_on_raise(tmp_path):
    tr = obs.Tracer()
    with obs.tracing(tr):
        with obs.span("outer", cat="stage"):
            with obs.span("inner"):
                pass
            with pytest.raises(ValueError):
                with obs.span("boom"):
                    raise ValueError("body failed")
    path = tmp_path / "t.json"
    tr.save(path)
    doc = json.loads(path.read_text())            # valid JSON end to end
    evs = doc["traceEvents"]
    by_name = {e["name"]: e for e in evs if e.get("ph") == "X"}
    assert set(by_name) == {"outer", "inner", "boom"}
    # the raising span is bounded and annotated
    assert by_name["boom"]["dur"] >= 0
    assert by_name["boom"]["args"]["error"] == "ValueError"
    # nesting by time containment: both children inside outer's window
    o = by_name["outer"]
    for child in ("inner", "boom"):
        c = by_name[child]
        assert o["ts"] <= c["ts"]
        assert c["ts"] + c["dur"] <= o["ts"] + o["dur"] + 1e-6
    assert doc["displayTimeUnit"] == "ms"


def test_disabled_path_adds_zero_events():
    tr = obs.Tracer()
    n0 = len(tr)
    assert not obs.enabled()
    with obs.span("nope", cat="x"):
        obs.instant("nothing")
        obs.counter("c", 1)
        obs.async_begin("r", 1)
        obs.async_end("r", 1)
    assert len(tr) == n0 == 0
    # the shared null span is reused, not rebuilt per call
    assert obs.span("a") is obs.span("b")


def test_tracing_none_disables_under_outer_tracer():
    outer = obs.Tracer()
    with obs.tracing(outer):
        with obs.span("kept"):
            pass
        with obs.tracing(None):
            assert not obs.enabled()
            with obs.span("dropped"):
                pass
        with obs.span("kept2"):
            pass
    names = {e["name"] for e in outer.events}
    assert "kept" in names and "kept2" in names
    assert "dropped" not in names


def test_traced_decorator_and_exception():
    tr = obs.Tracer()

    @obs.traced(cat="fn")
    def work(x):
        if x < 0:
            raise RuntimeError("neg")
        return x + 1

    with obs.tracing(tr):
        assert work(1) == 2
        with pytest.raises(RuntimeError):
            work(-1)
    spans = [e for e in tr.events if e.get("ph") == "X"]
    assert len(spans) == 2
    assert all(s["name"].endswith("work") for s in spans)
    assert spans[1]["args"]["error"] == "RuntimeError"


def test_tracer_thread_safety():
    tr = obs.Tracer()

    def worker(i):
        with obs.tracing(tr):           # ContextVar: per-thread install
            for j in range(50):
                with obs.span(f"w{i}"):
                    obs.counter("c", j)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    evs = tr.events
    assert sum(1 for e in evs if e.get("ph") == "X") == 200
    assert sum(1 for e in evs if e.get("ph") == "C") == 200
    # one thread_name metadata record per distinct tid (the OS may reuse
    # idents for non-overlapping threads, so <= 4 but never duplicated)
    metas = [e for e in evs if e.get("ph") == "M"]
    tids = {e["tid"] for e in evs if e.get("ph") == "X"}
    assert len(metas) == len(tids) <= 4
    json.dumps(tr.to_chrome())          # still serializable


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------
def test_registry_thread_safety():
    reg = obs.MetricsRegistry()

    def worker():
        for _ in range(500):
            reg.counter("hits").inc()
            reg.gauge("depth").add(1)
            reg.histogram("lat").observe(0.01)

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert reg.counter("hits").value == 4000
    assert reg.gauge("depth").value == 4000
    assert reg.histogram("lat").count == 4000


def test_registry_exports():
    reg = obs.MetricsRegistry()
    reg.counter("rosa.plancache_hits", help="plan IO").inc(3)
    reg.gauge("serve.queue_depth").set(2)
    h = reg.histogram("tick_s", bounds=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    # bench-schema rows: ungated runtime observations
    rows = {m.name: m for m in reg.to_metrics(prefix="p_")}
    assert rows["p_rosa.plancache_hits"].value == 3
    assert not rows["p_rosa.plancache_hits"].gate
    assert rows["p_tick_s_count"].value == 3
    text = reg.to_prometheus()
    assert "# TYPE rosa_plancache_hits counter" in text
    assert "rosa_plancache_hits 3" in text
    assert 'tick_s_bucket{le="+Inf"} 3' in text
    assert "tick_s_count 3" in text
    # histogram stats
    assert h.min == 0.05 and h.max == 5.0
    assert h.percentile(50) == 1.0      # upper edge of the median bucket
    # type mismatch on an existing name is an error, not silent
    with pytest.raises(TypeError):
        reg.gauge("rosa.plancache_hits")


def test_histogram_bounded_memory():
    h = obs.Histogram("h", bounds=(1.0, 2.0))
    for i in range(10_000):
        h.observe(i % 7)
    assert len(h.snapshot()["buckets"]) == 3    # 2 bounds + overflow
    assert h.count == 10_000


# ---------------------------------------------------------------------------
# CLI golden
# ---------------------------------------------------------------------------
def test_cli_summary_golden(tmp_path):
    tr = obs.Tracer(clock=_fake_clock())
    tr._pid = 1          # pin pid for byte-stable output paths
    with tr.span("compile", cat="stage"):
        with tr.span("search"):
            pass
    tr.async_begin("request", 7, cat="request", prompt_len=3)
    tr.async_instant("first_token", 7, cat="request")
    tr.async_end("request", 7, cat="request", tokens=5)
    tr.counter("energy.decode", {"J": 0.25}, cat="energy")
    path = tmp_path / "golden.json"
    tr.save(path)

    buf = io.StringIO()
    obs_cli.summarize(str(path), top=5, out=buf)
    assert buf.getvalue() == (
        "trace: 7 events (2 spans)\n"
        "\n"
        "top 2 spans by self-time (ms):\n"
        "        self      total  count  name\n"
        "       2.000      3.000      1  compile\n"
        "       1.000      1.000      1  search\n"
        "\n"
        "requests:\n"
        "        id    ttft_ms     e2e_ms  args\n"
        "         7      1.000      2.000  tokens=5\n"
        "\n"
        "counters (final values):\n"
        "  energy.decode: J=0.25\n"
    )


def test_cli_main_runs(tmp_path, capsys):
    tr = obs.Tracer()
    with tr.span("a"):
        pass
    p = tmp_path / "t.json"
    tr.save(p)
    assert obs_cli.main(["summarize", str(p)]) == 0
    out = capsys.readouterr().out
    assert "top 1 spans" in out and "  a" in out


# ---------------------------------------------------------------------------
# Energy bridge
# ---------------------------------------------------------------------------
def test_energy_track_cumulative_counters():
    import jax
    import jax.numpy as jnp

    from repro import rosa

    ledger = rosa.EnergyLedger()
    engine = rosa.Engine.from_config(
        rosa.RosaConfig(), layers=["l0"], key=jax.random.PRNGKey(0),
        ledger=ledger)
    with ledger.scope("decode"):
        jax.eval_shape(
            lambda x: engine.matmul(x, jnp.zeros((8, 4)), name="l0"),
            jnp.zeros((2, 8)))
    tr = obs.Tracer()
    with obs.tracing(tr):
        et = obs.EnergyTrack(ledger)
        et.tick("decode")
        et.tick("decode", n=2)
        et.tick("prefill")              # never traced: no event, no crash
    evs = [e for e in tr.events if e.get("ph") == "C"]
    assert [e["name"] for e in evs] == ["energy.decode", "energy.decode"]
    j1, j3 = evs[0]["args"]["J"], evs[1]["args"]["J"]
    assert j1 > 0 and np.isclose(j3, 3 * j1)    # cumulative, linear in n
    assert np.isclose(et.total_j(), j3)
    # disabled -> no accumulation, no emission
    et2 = obs.EnergyTrack(ledger)
    et2.tick("decode")
    assert et2.total_j() == 0.0


# ---------------------------------------------------------------------------
# Ledger seq satellite
# ---------------------------------------------------------------------------
def test_ledger_seq_monotonic_and_exported():
    import jax
    import jax.numpy as jnp

    from repro import rosa
    from repro.core.constants import ROSA_OPTIMAL

    ledger = rosa.EnergyLedger()
    engine = rosa.Engine.from_config(
        rosa.RosaConfig(), layers=["a", "b"], key=jax.random.PRNGKey(0),
        ledger=ledger)

    def fwd(x):
        y = engine.matmul(x, jnp.zeros((8, 8)), name="a")
        return engine.matmul(y, jnp.zeros((8, 4)), name="b")

    jax.eval_shape(fwd, jnp.zeros((2, 8)))
    seqs = [ev.seq for ev in ledger.events]
    assert len(seqs) == 2
    assert seqs[1] > seqs[0] >= 0       # stamped, strictly increasing
    export = ledger.export(ROSA_OPTIMAL)
    assert [e["seq"] for e in export["events"]] == seqs
    # dedup ignores seq: re-tracing the same layer keeps one event
    jax.eval_shape(fwd, jnp.zeros((2, 8)))
    assert len(ledger.unique_events()) == 2


# ---------------------------------------------------------------------------
# rosa.compile + scheduler integrations
# ---------------------------------------------------------------------------
def test_compile_spans_and_plancache_counters(tmp_path):
    import jax
    import jax.numpy as jnp

    from repro import rosa
    from repro.models.cnn import LITE_MODELS, LITE_SKIPS, cnn_apply, cnn_def
    from repro.models.module import abstract_params
    from repro.training.cnn_train import QAT_CFG

    specs = LITE_MODELS["alexnet"]
    engine = rosa.Engine.from_config(QAT_CFG)

    def apply_fn(eng, params, x):
        return cnn_apply(params, specs, x, eng,
                         residual_from=LITE_SKIPS.get("alexnet"))

    skel = abstract_params(cnn_def(specs), dtype=jnp.float32)
    x = jax.ShapeDtypeStruct((4, 32, 32, 3), jnp.float32)
    tune = rosa.AutotuneConfig(batch=4)

    reg = obs.MetricsRegistry()
    tr = obs.Tracer()
    with obs.swap_registry(reg), obs.tracing(tr):
        cold = rosa.compile(apply_fn, engine, (skel, x), autotune=tune,
                            cache=tmp_path)
        warm = rosa.compile(apply_fn, engine, (skel, x), autotune=tune,
                            cache=tmp_path)
    assert cold.searched and warm.cache_hit
    names = [e["name"] for e in tr.events if e.get("ph") == "X"]
    # cold: capture -> search -> store -> freeze; warm: capture -> load
    assert names.count("rosa.compile") == 2
    assert names.count("rosa.capture_trace") == 2
    assert names.count("rosa.plan_search") == 1
    assert names.count("plancache.store") == 1
    assert names.count("plancache.load") == 2
    assert names.count("rosa.freeze") == 2
    assert reg.counter("rosa.plancache_misses").value == 1
    assert reg.counter("rosa.plancache_hits").value == 1


def test_scheduler_trace_and_wall_metrics():
    from repro.configs import get_smoke
    from repro.serve import (Scheduler, ServeConfig, poisson_requests,
                             report_metrics)

    cfg = get_smoke("qwen3-32b")
    scfg = ServeConfig(n_slots=2, max_len=32, prefill_chunk=8, seed=0)
    sched = Scheduler(cfg, scfg, init_seed=0)
    reqs = poisson_requests(4, 1.0, vocab=cfg.vocab, prompt_len=(4, 8),
                            gen_len=(2, 6), seed=0)

    reg = obs.MetricsRegistry()
    tr = obs.Tracer()
    with obs.swap_registry(reg), obs.tracing(tr):
        rep = sched.run(reqs)

    # spans from the tick loop
    span_names = {e["name"] for e in tr.events if e.get("ph") == "X"}
    assert {"serve.tick", "serve.prefill_chunk",
            "serve.decode_step"} <= span_names
    # request lifecycle: one b/e pair per request + instants
    begins = [e for e in tr.events if e.get("ph") == "b"]
    ends = [e for e in tr.events if e.get("ph") == "e"]
    assert len(begins) == len(ends) == len(reqs)
    firsts = [e for e in tr.events
              if e.get("ph") == "n" and e["name"] == "first_token"]
    assert len(firsts) == len(reqs)
    # counter tracks sampled every tick
    track_names = {e["name"] for e in tr.events if e.get("ph") == "C"}
    assert {"serve.queue_depth", "serve.slots_active"} <= track_names
    assert reg.counter("serve.requests_completed").value == len(reqs)

    # wall-clock stamps: ordered per request, surfaced as metrics
    for c in rep.completions.values():
        assert (c.enqueue_wall <= c.first_token_wall <= c.done_wall)
        assert c.ttft_s >= 0 and c.latency_s >= c.ttft_s
    names = {m.name: m for m in report_metrics(rep)}
    assert names["ttft_p50_ms"].value >= 0
    assert names["latency_p99_ms"].value > 0
    assert not names["ttft_p50_ms"].gate        # wall clock never gates
    assert not names["latency_p99_ms"].gate
    # tick percentiles unchanged by instrumentation
    assert names["latency_p50_ticks"].gate


def test_scheduler_untraced_report_identical():
    """Tracing must not change scheduling, tokens, or gated metrics."""
    from repro.configs import get_smoke
    from repro.serve import (Scheduler, ServeConfig, poisson_requests,
                             report_metrics)

    cfg = get_smoke("qwen3-32b")
    scfg = ServeConfig(n_slots=2, max_len=32, prefill_chunk=8, seed=0)
    sched = Scheduler(cfg, scfg, init_seed=0)
    reqs = poisson_requests(4, 1.0, vocab=cfg.vocab, prompt_len=(4, 8),
                            gen_len=(2, 6), seed=0)
    with obs.tracing(None):
        rep_off = sched.run(reqs)
    with obs.tracing(obs.Tracer()):
        rep_on = sched.run(reqs)
    for rid in rep_off.completions:
        assert rep_off.completions[rid].tokens \
            == rep_on.completions[rid].tokens
    gated_off = {m.name: m.value for m in report_metrics(rep_off) if m.gate}
    gated_on = {m.name: m.value for m in report_metrics(rep_on) if m.gate}
    assert gated_off == gated_on


# ---------------------------------------------------------------------------
# jax.monitoring hooks
# ---------------------------------------------------------------------------
def test_jax_hooks_count_retraces():
    import jax
    import jax.numpy as jnp

    assert obs.install_jax_hooks()
    assert obs.install_jax_hooks()      # idempotent
    reg = obs.MetricsRegistry()
    with obs.swap_registry(reg):
        @jax.jit
        def f(x):
            return x * 2

        f(jnp.ones(3)).block_until_ready()
    assert reg.counter("xla.retraces").value >= 1
    assert reg.histogram("xla.trace_s").count >= 1
