"""JSON (de)serialization for the plan/program layer.

Everything `rosa.compile` persists — `RosaConfig`, `ExecutionPlan`,
`ProgramTrace`, autotune settings — round-trips through plain JSON dicts so
searched plans can live in the content-addressed on-disk plan cache and be
inspected / diffed offline.  Serialization is canonical (sorted keys, no
whitespace variance) because the cache key is a hash of these documents.
"""

from __future__ import annotations

import dataclasses
import enum
import hashlib
import json
from typing import Any

from repro.core import energy as E
from repro.core import mrr, osa
from repro.core.constants import ComputeMode, Mapping, OPEConfig
from repro.rosa.backends import RosaConfig


def to_jsonable(obj: Any) -> Any:
    """Recursively lower dataclasses/enums/tuples to JSON-native values."""
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    if isinstance(obj, enum.Enum):
        return obj.value
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return {f.name: to_jsonable(getattr(obj, f.name))
                for f in dataclasses.fields(obj)}
    if isinstance(obj, (list, tuple)):
        return [to_jsonable(v) for v in obj]
    if isinstance(obj, dict):
        return {str(k): to_jsonable(v) for k, v in obj.items()}
    raise TypeError(f"cannot serialize {type(obj).__name__} to JSON")


def canonical_json(doc: Any) -> str:
    """Deterministic JSON text (sorted keys, minimal separators)."""
    return json.dumps(to_jsonable(doc), sort_keys=True,
                      separators=(",", ":"))


def content_hash(*docs: Any) -> str:
    """SHA-256 over the canonical JSON of `docs` — the cache-key primitive."""
    h = hashlib.sha256()
    for doc in docs:
        h.update(canonical_json(doc).encode("utf-8"))
        h.update(b"\x00")
    return h.hexdigest()


# ---------------------------------------------------------------------------
# RosaConfig
# ---------------------------------------------------------------------------
def config_to_json(cfg: RosaConfig | None) -> dict | None:
    """RosaConfig -> JSON-able dict (None passes through)."""
    return None if cfg is None else to_jsonable(cfg)


def config_from_json(doc: dict | None) -> RosaConfig | None:
    """Inverse of `config_to_json`."""
    if doc is None:
        return None
    return RosaConfig(
        mapping=Mapping(doc["mapping"]),
        mode=ComputeMode(doc["mode"]),
        quant_bits=int(doc["quant_bits"]),
        pam_bits=int(doc["pam_bits"]),
        noise=mrr.NoiseModel(**doc["noise"]),
        osa_cfg=osa.OSAConfig(**doc["osa_cfg"]),
        mrr_params=mrr.MRRParams(**doc["mrr_params"]),
        backend=doc["backend"],
        act_per_vector=bool(doc["act_per_vector"]),
    )


# ---------------------------------------------------------------------------
# Energy-model configs (autotune settings)
# ---------------------------------------------------------------------------
def ope_from_json(doc: dict) -> OPEConfig:
    """OPEConfig from its JSON dict."""
    return OPEConfig(rows=int(doc["rows"]), cols=int(doc["cols"]),
                     tiles=int(doc["tiles"]))


def osa_energy_from_json(doc: dict) -> E.OSAEnergyConfig:
    """OSAEnergyConfig from its JSON dict."""
    return E.OSAEnergyConfig(enabled=bool(doc["enabled"]),
                             ode_len=int(doc["ode_len"]))
