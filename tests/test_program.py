"""rosa.Program compile-once API: trace capture, JSON round-trips, the
content-addressed on-disk plan cache, autotune determinism, bit-exactness
against the eager Engine.matmul path (CNN + transformer families), and the
ContextVar ambient-engine semantics (thread isolation, deprecation)."""

import dataclasses
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import rosa
from repro.core import mapping as M
from repro.core import mrr, osa
from repro.core.constants import Mapping, ROSA_OPTIMAL

NOISY = rosa.RosaConfig(noise=mrr.PAPER_NOISE)
TUNE = rosa.AutotuneConfig(batch=4)


def _net(eng, x, w1, w2):
    h = eng.matmul(x, w1, name="a")
    return eng.matmul(h, w2, name="b")


def _args(key):
    k1, k2, k3 = jax.random.split(key, 3)
    return (jax.random.normal(k1, (4, 16)),
            jax.random.normal(k2, (16, 8)),
            jax.random.normal(k3, (8, 4)))


# ---------------------------------------------------------------------------
# Trace capture
# ---------------------------------------------------------------------------
def test_capture_trace_names_shapes_counts(key):
    eng = rosa.Engine.from_config(NOISY)
    w = jnp.ones((16, 16))

    def f(eng_, x):
        h = eng_.matmul(x, w, name="a")
        h = eng_.matmul(h, w, name="a")     # same layer routed twice
        return eng_.matmul(h, w, name="b")

    trace = rosa.capture_trace(f, eng, (jnp.ones((4, 16)),))
    assert trace.names == ("a", "b")
    by_name = {e.name: e for e in trace.entries}
    assert (by_name["a"].m, by_name["a"].k, by_name["a"].n) == (4, 16, 16)
    assert by_name["a"].count == 2
    assert by_name["b"].count == 1


def test_capture_trace_skips_dense_layers(key):
    eng = rosa.Engine.from_layer_cfgs({"opt": NOISY},
                                      layers=("opt", "plain"))
    w = jnp.ones((8, 8))

    def f(eng_, x):
        return eng_.matmul(eng_.matmul(x, w, name="opt"), w, name="plain")

    trace = rosa.capture_trace(f, eng, (jnp.ones((2, 8)),))
    assert trace.names == ("opt",)      # dense layers are not plan candidates


# ---------------------------------------------------------------------------
# JSON round-trips
# ---------------------------------------------------------------------------
def test_execution_plan_json_roundtrip():
    weird = dataclasses.replace(
        NOISY, mapping=Mapping.IS, quant_bits=6, backend="ref",
        act_per_vector=True,
        osa_cfg=osa.OSAConfig(splitter_imbalance=0.01))
    plan = rosa.ExecutionPlan.build(
        NOISY, {"a": weird, "b": None}, layers=("a", "b", "c"))
    doc = plan.to_json()
    back = rosa.ExecutionPlan.from_json(doc)
    assert back == plan
    assert back.resolve("a").osa_cfg.splitter_imbalance == 0.01
    assert back.resolve("b") is None
    # JSON-native all the way down (what the disk cache persists)
    import json
    assert rosa.ExecutionPlan.from_json(
        json.loads(json.dumps(doc))) == plan


def test_program_trace_json_roundtrip():
    trace = rosa.ProgramTrace((rosa.TraceEntry("a", 4, 16, 8, 2),
                               rosa.TraceEntry("b", 4, 8, 4, 1)))
    back = rosa.ProgramTrace.from_json(trace.to_json())
    assert back == trace
    assert back.fingerprint == trace.fingerprint
    assert back.layer_shapes()[0].k == 16


# ---------------------------------------------------------------------------
# Plan cache: cold searches, warm hits, key sensitivity
# ---------------------------------------------------------------------------
def test_plan_cache_cold_then_warm(key, tmp_path):
    eng = rosa.Engine.from_config(NOISY)
    args = _args(key)
    cold = rosa.compile(_net, eng, args, autotune=TUNE, cache=tmp_path)
    assert cold.searched and not cold.cache_hit
    assert (tmp_path / f"{cold.cache_key}.json").exists()
    warm = rosa.compile(_net, eng, args, autotune=TUNE, cache=tmp_path)
    assert warm.cache_hit and not warm.searched   # search skipped entirely
    assert warm.cache_key == cold.cache_key
    assert warm.plan == cold.plan


def test_plan_cache_key_tracks_inputs(key, tmp_path):
    eng = rosa.Engine.from_config(NOISY)
    args = _args(key)
    base = rosa.compile(_net, eng, args, autotune=TUNE, cache=tmp_path)
    # a different RosaConfig must miss the cache and re-search
    eng6 = rosa.Engine.from_config(dataclasses.replace(NOISY, quant_bits=6))
    other = rosa.compile(_net, eng6, args, autotune=TUNE, cache=tmp_path)
    assert other.cache_key != base.cache_key
    assert other.searched and not other.cache_hit
    # different search settings miss too
    tuned = rosa.compile(_net, eng, args, cache=tmp_path,
                         autotune=rosa.AutotuneConfig(batch=64))
    assert tuned.cache_key != base.cache_key
    # different traced workload (new shapes) misses as well
    wide = (jnp.ones((4, 32)), jnp.ones((32, 8)), jnp.ones((8, 4)))
    other_tr = rosa.compile(_net, eng, wide, autotune=TUNE, cache=tmp_path)
    assert other_tr.cache_key != base.cache_key


def test_autotune_matches_manual_search(key):
    eng = rosa.Engine.from_config(NOISY)
    prog = rosa.compile(_net, eng, _args(key), autotune=TUNE, cache=False)
    profs = M.profile_layers_fast(prog.trace.layer_shapes(), TUNE.ope,
                                  batch=TUNE.batch)
    assert prog.plan.mapping_plan() == M.hybrid_plan(profs)
    assert prog.plan.default == NOISY           # base config preserved


def test_autotune_accuracy_guard(key):
    """A degradation matrix + guard_pp vetoes EDP-favoured mappings that
    cost accuracy (repro.robust-style accuracy-aware search)."""
    eng = rosa.Engine.from_config(NOISY)
    free = rosa.compile(_net, eng, _args(key), autotune=TUNE, cache=False)
    deg = {n: {Mapping.IS.value: 50.0, Mapping.WS.value: 0.0}
           for n in free.trace.names}
    guarded = rosa.compile(
        _net, eng, _args(key), cache=False, degradation=deg,
        autotune=dataclasses.replace(TUNE, guard_pp=0.5))
    assert all(m is Mapping.WS
               for m in guarded.plan.mapping_plan().values())


def test_autotune_requires_base_config(key):
    with pytest.raises(ValueError, match="autotune"):
        rosa.compile(_net, rosa.Engine.dense(), _args(key),
                     autotune=TUNE, cache=False)


# ---------------------------------------------------------------------------
# Program execution: bit-exact vs the eager Engine.matmul path
# ---------------------------------------------------------------------------
def test_program_matches_eager_toy(key):
    eng = rosa.Engine.from_config(NOISY, key=jax.random.PRNGKey(0))
    args = _args(key)
    prog = rosa.compile(_net, eng, args, autotune=TUNE, cache=False)
    eager = _net(eng.with_plan(prog.plan), *args)
    np.testing.assert_array_equal(np.asarray(prog(*args)),
                                  np.asarray(eager))
    # explicit key threading == eager engine with that base key
    k2 = jax.random.PRNGKey(9)
    np.testing.assert_array_equal(
        np.asarray(prog(*args, key=k2)),
        np.asarray(_net(eng.with_plan(prog.plan).with_key(k2), *args)))
    assert float(jnp.max(jnp.abs(prog(*args, key=k2) - prog(*args)))) > 0


def test_program_variation_threading(key):
    eng = rosa.Engine.from_config(NOISY, key=jax.random.PRNGKey(0))
    args = _args(key)
    prog = rosa.compile(_net, eng, args, autotune=None, cache=False)
    var = {"a": mrr.StaticVariation(jnp.asarray(0.05), jnp.asarray(0.0),
                                    jnp.asarray(0.0))}
    y = prog(*args, variation=var)
    eager = _net(eng.with_variation(var), *args)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(eager))
    assert float(jnp.max(jnp.abs(y - prog(*args)))) > 0


def test_program_matches_eager_cnn(key):
    """Acceptance pin: Program output bit-exact with the eager
    Engine.matmul path for a CNN family."""
    from repro.models.cnn import LITE_MODELS, LITE_SKIPS, cnn_apply, cnn_def
    from repro.models.module import init_params
    from repro.training.cnn_train import cnn_program

    model = "alexnet"
    specs = LITE_MODELS[model]
    params = init_params(cnn_def(specs), jax.random.PRNGKey(1))
    eng = rosa.Engine.from_config(NOISY, layers=[s.name for s in specs],
                                  key=jax.random.PRNGKey(0))
    prog = cnn_program(model, eng)
    x = jax.random.normal(key, (4, 32, 32, 3))
    eager = cnn_apply(params, specs, x, eng,
                      residual_from=LITE_SKIPS.get(model))
    np.testing.assert_array_equal(np.asarray(prog(params, x)),
                                  np.asarray(eager))


def test_program_matches_eager_transformer(key):
    """Acceptance pin: Program output bit-exact with the eager
    ambient-engine path for a transformer family (rosa_mlp prefill)."""
    import dataclasses as dc

    from repro.configs import get_smoke
    from repro.models.model import build_model

    cfg = dc.replace(get_smoke("qwen3-32b"), rosa_mlp=True)
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0), jnp.float32)
    eng = rosa.Engine.from_config(NOISY, key=jax.random.PRNGKey(3))
    batch = {"tokens": jax.random.randint(key, (2, 8), 0, cfg.vocab,
                                          dtype=jnp.int32)}
    prog = rosa.compile(lambda e, p, b: bundle.prefill(p, b), eng,
                        (params, batch), autotune=None, cache=False)
    logits, _ = prog(params, batch)
    with rosa.engine_context(eng):
        logits_eager, _ = bundle.prefill(params, batch)
    np.testing.assert_array_equal(np.asarray(logits),
                                  np.asarray(logits_eager))


def test_program_ledger_prices_tuned_plan(key):
    ledger = rosa.EnergyLedger()
    eng = rosa.Engine.from_config(NOISY, ledger=ledger)
    prog = rosa.compile(_net, eng, _args(key), autotune=TUNE, cache=False)
    assert prog.ledger is not None
    traced_plan = prog.ledger.mapping_plan()
    assert traced_plan == prog.plan.mapping_plan()
    assert prog.ledger.edp(ROSA_OPTIMAL) == pytest.approx(
        M.plan_edp(prog.trace.layer_shapes(), traced_plan, ROSA_OPTIMAL,
                   batch=1), rel=1e-12)


def test_compile_leaves_populated_ledger_untouched(key):
    """Compiling against an engine whose ledger already carries (scoped)
    runtime events must not append untagged compile-time duplicates —
    tag=None pricing would double-count them (the serving ledger case)."""
    ledger = rosa.EnergyLedger()
    eng = rosa.Engine.from_config(NOISY, key=jax.random.PRNGKey(0),
                                  ledger=ledger)
    args = _args(key)
    with ledger.scope("decode"):
        eng.matmul(args[0], args[1], name="a")
    before = list(ledger.events)
    rosa.compile(_net, eng, args, autotune=TUNE, cache=False)
    assert ledger.events == before


def test_program_bind_installs_engine(key):
    eng = rosa.Engine.from_config(NOISY, key=jax.random.PRNGKey(0))
    args = _args(key)
    prog = rosa.compile(_net, eng, args, autotune=None, cache=False)

    def ambient_fn(x, w):
        return rosa.ambient_engine().matmul(x, w, name="a")

    bound = prog.bind(ambient_fn)
    np.testing.assert_array_equal(
        np.asarray(bound(args[0], args[1])),
        np.asarray(eng.matmul(args[0], args[1], name="a")))


def test_dense_program_is_plain_matmul(key):
    args = _args(key)
    prog = rosa.compile(_net, rosa.Engine.dense(), args, cache=False)
    assert len(prog.trace) == 0
    np.testing.assert_allclose(
        np.asarray(prog(*args)), np.asarray(args[0] @ args[1] @ args[2]),
        rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# Ambient engine: ContextVar semantics
# ---------------------------------------------------------------------------
def test_engine_context_thread_isolation():
    e1 = rosa.Engine.from_config(NOISY)
    e2 = rosa.Engine.from_config(rosa.DEFAULT)
    barrier = threading.Barrier(2)
    seen = {}

    def worker(name, engine):
        with rosa.engine_context(engine):
            barrier.wait(timeout=10)       # both contexts active at once
            seen[name] = rosa.ambient_engine()

    threads = [threading.Thread(target=worker, args=("t1", e1)),
               threading.Thread(target=worker, args=("t2", e2))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert seen["t1"] is e1
    assert seen["t2"] is e2
    assert rosa.ambient_engine() is None   # nothing leaked to the main thread


def test_engine_context_nests_and_restores():
    e1 = rosa.Engine.from_config(NOISY)
    e2 = rosa.Engine.dense()
    assert rosa.ambient_engine() is None
    with rosa.engine_context(e1):
        assert rosa.ambient_engine() is e1
        with rosa.engine_context(e2):
            assert rosa.ambient_engine() is e2
        assert rosa.ambient_engine() is e1
    assert rosa.ambient_engine() is None


def test_deprecated_wrappers_warn_and_delegate():
    eng = rosa.Engine.from_config(NOISY)
    with pytest.warns(DeprecationWarning, match="use_engine"):
        ctx = rosa.use_engine(eng)
    with ctx:
        with pytest.warns(DeprecationWarning, match="current_engine"):
            assert rosa.current_engine() is eng
    assert rosa.ambient_engine() is None


# ---------------------------------------------------------------------------
# Donation canaries: declared donations survive into compiled HLO
# ---------------------------------------------------------------------------
def test_program_donation_canary(key):
    """Pin: a Program compiled with donate_argnums aliases the donated
    buffer in its optimized HLO (checked against the real alias map, not
    the declaration)."""
    from repro.analysis import program_target, run_checks
    from repro.analysis.hlo import (entry_parameter_shapes,
                                    parse_input_output_aliases)

    eng = rosa.Engine.from_config(NOISY)

    def f(e, x, w, state):
        return state + e.matmul(x, w, name="a")

    sds = jax.ShapeDtypeStruct
    ex = (sds((4, 16), jnp.float32), sds((16, 16), jnp.float32),
          sds((4, 16), jnp.float32))
    prog = rosa.compile(f, eng, ex, donate_argnums=(2,), cache=False)

    t = program_target(prog, ex, name="canary:program")
    assert list(run_checks([t], checks=["donation"])) == []

    txt = prog._call.lower(sds((2,), jnp.uint32), None,
                           *ex).compile().as_text()
    aliases = parse_input_output_aliases(txt)
    params = entry_parameter_shapes(txt)
    aliased = [params.get(p, "").split("{")[0] for p, _ in aliases]
    assert "f32[4,16]" in aliased, (aliases, params)


def test_program_verify_catches_dropped_donation(key):
    """Negative control: donating an arg the program never touches must
    surface as DON001 through verify="error"."""
    from repro import analysis as A

    eng = rosa.Engine.from_config(NOISY)

    def f(e, x, w, scratch):
        return e.matmul(x, w, name="a")

    sds = jax.ShapeDtypeStruct
    ex = (sds((4, 16), jnp.float32), sds((16, 16), jnp.float32),
          sds((4, 16), jnp.float32))
    with pytest.raises(A.VerificationError) as ei:
        rosa.compile(f, eng, ex, donate_argnums=(2,), cache=False,
                     verify="error")
    assert any(fd.code == "DON001" for fd in ei.value.report.findings)


# ---------------------------------------------------------------------------
# accuracy-aware default + cached degradation matrices
# ---------------------------------------------------------------------------
def _counting_source(calls):
    """A DegradationSource whose measure() logs which layers it was asked
    to score (IS mildly worse so the guard keeps WS deterministically)."""
    def measure(names):
        calls.append(tuple(names))
        return {n: {Mapping.IS.value: 2.0, Mapping.WS.value: 0.0}
                for n in names}
    return rosa.DegradationSource(measure=measure, spec={"kind": "test",
                                                         "v": 1})


def test_autotune_accuracy_aware_default():
    assert rosa.AutotuneConfig().accuracy_aware is True
    assert rosa.EDP_ONLY.accuracy_aware is False
    # old cached/serialized configs (no key) stay accuracy-aware
    doc = rosa.AutotuneConfig().to_json()
    doc.pop("accuracy_aware", None)
    assert rosa.AutotuneConfig.from_json(doc).accuracy_aware is True


def test_compile_measures_once_then_warm_skips_mc(key, tmp_path):
    """Tentpole acceptance: a warm accuracy-aware compile takes its
    degradation matrix from the PlanCache and never re-runs MC."""
    eng = rosa.Engine.from_config(NOISY)
    args = _args(key)
    calls = []
    src = _counting_source(calls)
    cold = rosa.compile(_net, eng, args, autotune=TUNE, cache=tmp_path,
                        degradation=src)
    assert cold.searched and calls == [("a", "b")]
    warm = rosa.compile(_net, eng, args, autotune=TUNE, cache=tmp_path,
                        degradation=src)
    assert warm.cache_hit and not warm.searched
    assert calls == [("a", "b")]                  # MC stage skipped entirely
    assert warm.plan == cold.plan
    # plan evicted but matrix kept: re-search, still no re-measure
    (tmp_path / f"{cold.cache_key}.json").unlink()
    rewarm = rosa.compile(_net, eng, args, autotune=TUNE, cache=tmp_path,
                          degradation=src)
    assert rewarm.searched and calls == [("a", "b")]
    assert rewarm.plan == cold.plan


def test_matrix_cache_measures_only_missing_layers(key, tmp_path):
    """Incremental re-score: rows already in the cache are reused and only
    absent layers are measured."""
    eng = rosa.Engine.from_config(NOISY)
    args = _args(key)
    calls = []
    src = _counting_source(calls)
    cache = rosa.PlanCache(tmp_path)
    mkey = cache.matrix_key(NOISY, src.spec)
    cache.store_matrix(mkey, {"a": {Mapping.IS.value: 2.0,
                                    Mapping.WS.value: 0.0}})
    prog = rosa.compile(_net, eng, args, autotune=TUNE, cache=tmp_path,
                        degradation=src)
    assert calls == [("b",)]                       # only the missing row
    assert prog.searched
    # the merged matrix is persisted: a fresh compile measures nothing
    rosa.compile(_net, eng, args, cache=tmp_path, degradation=src,
                 autotune=dataclasses.replace(TUNE, batch=8))
    assert calls == [("b",)]


def test_matrix_cache_invalidation(key, tmp_path):
    """A changed variation spec or base RosaConfig must re-measure."""
    eng = rosa.Engine.from_config(NOISY)
    args = _args(key)
    calls = []
    src = _counting_source(calls)
    rosa.compile(_net, eng, args, autotune=TUNE, cache=tmp_path,
                 degradation=src)
    assert len(calls) == 1
    # same config, different spec -> different matrix key -> re-measure
    src2 = rosa.DegradationSource(measure=src.measure,
                                  spec={"kind": "test", "v": 2})
    rosa.compile(_net, eng, args, autotune=TUNE, cache=tmp_path,
                 degradation=src2)
    assert len(calls) == 2
    # same spec, different RosaConfig -> re-measure too
    eng6 = rosa.Engine.from_config(dataclasses.replace(NOISY, quant_bits=6))
    rosa.compile(_net, eng6, args, autotune=TUNE, cache=tmp_path,
                 degradation=src)
    assert len(calls) == 3


def test_edp_only_ignores_degradation_source(key, tmp_path):
    calls = []
    src = _counting_source(calls)
    eng = rosa.Engine.from_config(NOISY)
    prog = rosa.compile(_net, eng, _args(key), cache=tmp_path,
                        degradation=src,
                        autotune=dataclasses.replace(
                            rosa.EDP_ONLY, batch=TUNE.batch))
    assert calls == []                             # MC never invoked
    assert prog.searched
    # and the EDP-only plan matches the historic accuracy-blind search
    profs = M.profile_layers_fast(prog.trace.layer_shapes(), TUNE.ope,
                                  batch=TUNE.batch)
    assert prog.plan.mapping_plan() == M.hybrid_plan(profs)


def test_matrix_cache_roundtrip_and_corruption(tmp_path):
    cache = rosa.PlanCache(tmp_path)
    mkey = cache.matrix_key(NOISY, {"kind": "test"})
    layers = {"a": {Mapping.IS.value: 1.5, Mapping.WS.value: 0.25}}
    cache.store_matrix(mkey, layers)
    assert cache.load_matrix(mkey) == layers
    assert cache.load_matrix("no-such-key") is None
    (tmp_path / f"{mkey}.deg.json").write_text("{corrupt")
    assert cache.load_matrix(mkey) is None         # never raises
