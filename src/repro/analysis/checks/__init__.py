"""Check modules: importing this package populates the registry."""

from repro.analysis.checks import (donation, pallas, prng,  # noqa: F401
                                   purity, recompile)
