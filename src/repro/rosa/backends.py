"""Execution backends for the ROSA optical matmul + the `RosaConfig` knob.

This module is the single home of the paper's MAC semantics (previously
`core/onn_linear.py`).  A *backend* is the contraction primitive that turns
noise-placed operands into outputs:

    dense   exact einsum contraction — the ideal-OSA closed form (Eq. 2),
            also used for non-optical layers routed by `rosa.Engine`.
    ref     pure-jnp OSA pipeline (signed-digit planes + slot gains, Eq. 1)
            — the oracle, honours OSAConfig non-idealities.
    pallas  the Pallas TPU kernel in kernels/osa_matmul (bit-plane
            decomposition + per-plane MXU matmuls), interpret-mode on CPU.

Backends are registered by name (`register_backend`) and selected by
`RosaConfig.backend`; the default "auto" resolves per platform (pallas on
TPU, ref elsewhere).  This replaces the old `use_kernel: bool` toggle.

Forward semantics (mixed digital-analog mode, Sec. 2-3.1):

  WS mapping: weights are programmed onto TO-tuned analog MRRs through the
    noisy voltage chain (mrr.realize_weights); activations take the exact
    digital EO path (8-bit signed-digit streams) and accumulate via OSA.
  IS mapping: the roles swap — activations are realized on the noisy analog
    MRRs, weights travel the exact digital path.
  ANALOG mode (DEAP baseline): both operands pass the noisy analog chain.

Backward semantics: straight-through — gradients flow as if the matmul were
exact, which makes every model in the zoo noise-aware-trainable (QAT) with
zero graph surgery.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import mrr, osa, quant
from repro.core.constants import ComputeMode, Mapping


@dataclasses.dataclass(frozen=True)
class RosaConfig:
    """Per-layer execution config for the optical backend."""

    mapping: Mapping = Mapping.WS
    mode: ComputeMode = ComputeMode.MIXED
    quant_bits: int = 8
    pam_bits: int = 1
    noise: mrr.NoiseModel = mrr.IDEAL
    osa_cfg: osa.OSAConfig = osa.IDEAL_OSA
    mrr_params: mrr.MRRParams = mrr.DEFAULT_PARAMS
    backend: str = "auto"   # registered backend name, or "auto" (platform)

    @property
    def qcfg(self) -> quant.QuantConfig:
        return quant.QuantConfig(bits=self.quant_bits)


DEFAULT = RosaConfig()


# ---------------------------------------------------------------------------
# Backend registry
# ---------------------------------------------------------------------------
# A backend contracts noise-placed operands: (x_eff (M,K), w_eff (K,N),
# cfg: RosaConfig | None) -> (M,N).  cfg is None on the Engine's non-optical
# (plain dense) layers.
Backend = Callable[[jax.Array, jax.Array, "RosaConfig | None"], jax.Array]

_BACKENDS: dict[str, Backend] = {}


def register_backend(name: str):
    """Decorator: register a contraction backend under `name`."""
    def deco(fn: Backend) -> Backend:
        _BACKENDS[name] = fn
        return fn
    return deco


def backend_names() -> list[str]:
    return sorted(_BACKENDS)


def resolve_backend(name: str) -> tuple[str, Backend]:
    """Resolve a backend name ("auto" -> platform pick) to (name, fn)."""
    if name == "auto":
        name = "pallas" if jax.default_backend() == "tpu" else "ref"
    try:
        return name, _BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; registered: {backend_names()}"
        ) from None


@register_backend("dense")
def _dense_backend(x: jax.Array, w: jax.Array, cfg=None) -> jax.Array:
    return x @ w


@register_backend("ref")
def _ref_backend(x: jax.Array, w: jax.Array, cfg: RosaConfig) -> jax.Array:
    return osa.osa_matmul_ref(x, w, cfg.osa_cfg, cfg.qcfg)


@register_backend("pallas")
def _pallas_backend(x: jax.Array, w: jax.Array, cfg: RosaConfig) -> jax.Array:
    # deferred import: pulls in jax.experimental.pallas only when routed here
    from repro.kernels.osa_matmul import ops as osa_ops
    return osa_ops.osa_matmul(x, w, quant_bits=cfg.quant_bits,
                              pam_bits=cfg.pam_bits)


# ---------------------------------------------------------------------------
# Operand conditioning (noise placement)
# ---------------------------------------------------------------------------
def _noisy_realize(t: jax.Array, cfg: RosaConfig, key: jax.Array | None):
    """Quantize a tensor to cfg.quant_bits and realize it on analog MRRs.

    Values are normalized per-tensor to the MRR weight range [q_min, q_max],
    programmed through the physical chain with DAC/thermal noise, and
    de-normalized.  This is where WS puts weights and IS puts activations.
    """
    scale = jnp.maximum(jnp.max(jnp.abs(t)), 1e-8)
    q = quant.fake_quant(t / scale, cfg.qcfg)          # 8-bit grid in [-1,1]
    w = mrr.realize_weights(q, key, cfg.mrr_params, cfg.noise)
    return w * scale


def _digital_path(t: jax.Array, cfg: RosaConfig):
    """Exact digital EO encoding: quantization is the only error source."""
    return quant.fake_quant(t, cfg.qcfg)


def _forward(x: jax.Array, w: jax.Array, cfg: RosaConfig,
             key: jax.Array | None) -> jax.Array:
    if cfg.mode is ComputeMode.MIXED:
        if cfg.noise.is_ideal and cfg.osa_cfg.is_ideal \
                and cfg.backend in ("auto", "dense"):
            # exactness-preserving shortcut: ideal OSA over signed-digit
            # planes == fake-quant matmul (tests/test_osa.py asserts this),
            # so QAT training skips the 7-plane decomposition entirely.
            # Guarded on the UNRESOLVED name: "auto" must stay fast for QAT
            # even when it would resolve to pallas on TPU, while an EXPLICIT
            # "ref"/"pallas" request always runs its registered pipeline.
            # ("dense" is algebraically the shortcut itself.)
            return _digital_path(x, cfg) @ _digital_path(w, cfg)
        bname, contract = resolve_backend(cfg.backend)
        if cfg.mapping in (Mapping.WS, Mapping.GEMM):
            w_eff = _noisy_realize(w, cfg, key) if not cfg.noise.is_ideal \
                else _digital_path(w, cfg)
            x_eff = _digital_path(x, cfg)
        else:  # IS: inputs on the analog rings, weights exact digital
            w_eff = _digital_path(w, cfg)
            x_eff = _noisy_realize(x, cfg, key) if not cfg.noise.is_ideal \
                else _digital_path(x, cfg)
        return contract(x_eff, w_eff, cfg)
    elif cfg.mode is ComputeMode.ANALOG:
        if key is not None:
            k_w, k_x = jax.random.split(key)
        else:
            k_w = k_x = None
        w_eff = _noisy_realize(w, cfg, k_w) if not cfg.noise.is_ideal \
            else _digital_path(w, cfg)
        x_eff = _noisy_realize(x, cfg, k_x) if not cfg.noise.is_ideal \
            else _digital_path(x, cfg)
        return x_eff @ w_eff                      # single-shot analog readout
    elif cfg.mode is ComputeMode.DIGITAL:
        return _digital_path(x, cfg) @ _digital_path(w, cfg)
    raise ValueError(cfg.mode)


# ---------------------------------------------------------------------------
# The drop-in matmul with straight-through gradients
# ---------------------------------------------------------------------------
@partial(jax.custom_vjp, nondiff_argnums=(2,))
def rosa_matmul(x: jax.Array, w: jax.Array, cfg: RosaConfig = DEFAULT,
                key: jax.Array | None = None) -> jax.Array:
    """Optical matmul  y = x @ w  through the configured ROSA pipeline.

    x: (..., K) activations; w: (K, N) weights; returns (..., N).
    Straight-through gradients w.r.t. both x and w.
    """
    lead = x.shape[:-1]
    y = _forward(x.reshape(-1, x.shape[-1]), w, cfg, key)
    return y.reshape(*lead, w.shape[-1])


def _fwd(x, w, cfg, key):
    return rosa_matmul(x, w, cfg, key), (x, w)


def _bwd(cfg, res, g):
    x, w = res
    g2 = g.reshape(-1, g.shape[-1])
    x2 = x.reshape(-1, x.shape[-1])
    dx = (g2 @ w.T).reshape(x.shape)
    dw = x2.T @ g2
    return dx, dw, None


rosa_matmul.defvjp(_fwd, _bwd)


def make_backend(cfg: RosaConfig):
    """Callable matmul closure (legacy helper, kept for compatibility)."""
    def matmul(x, w, key=None):
        return rosa_matmul(x, w, cfg, key)
    return matmul
