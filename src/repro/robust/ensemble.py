"""Vectorized Monte-Carlo chip-ensemble evaluation ("N-chip wafer").

One jitted call evaluates a model forward over N static-variation
instances at once: the ensemble pytree (leading chip axis) is `jax.vmap`ed
through the `rosa.Engine`, per-shot noise keys split per chip, and the
per-chip accuracy / logit-agreement / yield statistics come back in a
single XLA program.  Inside the chip vmap the evaluation set is streamed
in micro-batches (`lax.map`) so 64+ chips stay memory-bounded on CPU.

    ens  = variation.sample_ensemble(key, 64, variation.cnn_lane_dims("alexnet"))
    res  = ensemble.evaluate_cnn_ensemble(params, "alexnet", engine, ens, key)
    res.mean_acc, res.yield_frac(max_drop_pp=2.0)
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mrr
from repro.robust import variation as V

# apply_fn(params, x, engine) -> logits; the engine arrives pre-loaded with
# this chip's variation and per-shot key.
ApplyFn = Callable[..., jax.Array]


@dataclasses.dataclass
class EnsembleResult:
    """Per-chip statistics of one ensemble evaluation."""

    accs: np.ndarray           # (n_chips,) accuracy [%] (vs labels, or vs
    #                            clean predictions when labels are absent)
    agreement: np.ndarray      # (n_chips,) argmax agreement with clean [0,1]
    clean_acc: float           # noise-free reference accuracy [%]

    @property
    def n_chips(self) -> int:
        return len(self.accs)

    @property
    def mean_acc(self) -> float:
        return float(self.accs.mean())

    @property
    def std_acc(self) -> float:
        return float(self.accs.std())

    @property
    def min_acc(self) -> float:
        return float(self.accs.min())

    @property
    def mean_drop_pp(self) -> float:
        return self.clean_acc - self.mean_acc

    def yield_frac(self, max_drop_pp: float = 2.0) -> float:
        """Fraction of chips within `max_drop_pp` of the clean model —
        the wafer-yield figure of merit (higher is better)."""
        return float((self.accs >= self.clean_acc - max_drop_pp).mean())

    def yield_curve(self, drops_pp: Sequence[float]) -> list[tuple[float, float]]:
        return [(float(d), self.yield_frac(d)) for d in drops_pp]

    def summary(self) -> dict:
        return {"n_chips": self.n_chips, "clean_acc": self.clean_acc,
                "mean_acc": self.mean_acc, "std_acc": self.std_acc,
                "min_acc": self.min_acc,
                "mean_agreement": float(self.agreement.mean()),
                "yield_2pp": self.yield_frac(2.0)}


def clean_reference(engine):
    """The noise-free twin of an engine: same plan with per-shot noise
    muted, no pinned chip, no gates (blend or mapping), no key."""
    plan = engine.plan.map_configs(
        lambda c: dataclasses.replace(c, noise=mrr.IDEAL))
    return engine.with_plan(plan).with_variation(None).with_gates(None) \
        .with_mapping_gates(None).with_key(None)


def chunk_eval_set(x: jax.Array, size: int) -> jax.Array:
    """(N, ...) -> (N//size, size, ...) micro-batches for `lax.map`
    streaming.  A remainder that does not fill a chunk is dropped — loudly,
    because every downstream accuracy/yield statistic would silently run
    on fewer samples than the caller asked for."""
    size = min(size, x.shape[0])
    n = (x.shape[0] // size) * size
    if n < x.shape[0]:
        import warnings
        warnings.warn(
            f"evaluation set truncated {x.shape[0]} -> {n} samples "
            f"(not a multiple of eval_batch={size}); statistics cover the "
            f"truncated set", stacklevel=2)
    return x[:n].reshape(n // size, size, *x.shape[1:])


def chunked_argmax_preds(apply_fn: ApplyFn, params, xb: jax.Array, engine
                         ) -> jax.Array:
    """Stream the (n_chunks, chunk, ...) batches through the engine and
    return flat argmax predictions — the shared inner evaluator of
    ensemble/sensitivity/plan-search (trace it inside jit/vmap)."""
    return jax.lax.map(
        lambda xc: jnp.argmax(apply_fn(params, xc, engine), -1),
        xb).reshape(-1)


def make_ensemble_eval(apply_fn: ApplyFn, engine, *, eval_batch: int = 128):
    """Build the ONE jitted evaluator: (params, x, y, ensemble, keys) ->
    (accs, agreement, clean_acc).

    The chip axis is a `jax.vmap`; the evaluation set streams through
    `lax.map` micro-batches of `eval_batch` inside it.  Reuse the returned
    callable across calls (drift loops, sigma sweeps) — retracing only
    happens on new shapes.
    """
    clean_engine = clean_reference(engine)

    @jax.jit
    def run(params, x, y, ens, keys):
        xb = chunk_eval_set(x, eval_batch)
        clean_pred = chunked_argmax_preds(apply_fn, params, xb, clean_engine)

        def one_chip(var, k):
            return chunked_argmax_preds(
                apply_fn, params, xb, engine.with_variation(var).with_key(k))

        preds = jax.vmap(one_chip)(ens, keys)          # (n_chips, n_eval)
        ref = clean_pred if y is None else y[:preds.shape[1]]
        accs = 100.0 * jnp.mean(preds == ref[None, :], axis=1)
        agreement = jnp.mean(preds == clean_pred[None, :], axis=1)
        clean_acc = 100.0 * jnp.mean(clean_pred == ref)
        return accs, agreement, clean_acc

    return run


def evaluate_ensemble(apply_fn: ApplyFn, params, x, y, engine,
                      ensemble: V.Chip, key: jax.Array, *,
                      eval_batch: int = 128) -> EnsembleResult:
    """One-shot convenience around `make_ensemble_eval` (builds, runs,
    wraps).  `y=None` scores argmax agreement against the clean model
    (label-free workloads: LM logit agreement)."""
    n = V.ensemble_size(ensemble)
    keys = jax.random.split(key, n)
    run = make_ensemble_eval(apply_fn, engine, eval_batch=eval_batch)
    accs, agreement, clean_acc = run(params, x, y, ensemble, keys)
    return EnsembleResult(accs=np.asarray(accs),
                          agreement=np.asarray(agreement),
                          clean_acc=float(clean_acc))


# ---------------------------------------------------------------------------
# CNN front-end (the paper's behavioural experiments)
# ---------------------------------------------------------------------------
def cnn_apply_fn(model: str) -> ApplyFn:
    from repro.models.cnn import LITE_MODELS, LITE_SKIPS, cnn_apply
    specs, skips = LITE_MODELS[model], LITE_SKIPS.get(model)
    return lambda params, x, engine: cnn_apply(params, specs, x, engine,
                                               residual_from=skips)


def cnn_eval_set(n_eval: int = 512, seed: int = 0):
    from repro.data.synth_cifar import train_test_split
    (_, _), (xte, yte) = train_test_split(seed=seed)
    return jnp.asarray(xte[:n_eval]), jnp.asarray(yte[:n_eval])


def evaluate_cnn_ensemble(params, model: str, engine, ensemble: V.Chip,
                          key: jax.Array, *, n_eval: int = 512,
                          eval_batch: int = 128,
                          seed: int = 0) -> EnsembleResult:
    """Ensemble statistics of a lite CNN on the synth-CIFAR test set."""
    x, y = cnn_eval_set(n_eval, seed)
    return evaluate_ensemble(cnn_apply_fn(model), params, x, y, engine,
                             ensemble, key, eval_batch=eval_batch)
