"""Vectorized (JAX) counterpart of the analytical energy model.

`core.energy.layer_energy` is plain-Python-float by design — it prices one
(layer, OPE config) pair in microseconds and stays trace-free.  The DSE,
however, evaluates a full candidate-grid x workload cross-product, and the
model zoo pushes that product into the hundreds of thousands of cells.
This module ports the *same arithmetic* to `jax.numpy` so the whole grid
evaluates as one vmapped, jitted call:

    cand   = stack_candidates(opes)        # (P,) int arrays: rows/cols/tiles
    layers = stack_layers(shapes)          # (L,) int arrays: g/m/k/n/n_total
    energy, latency = grid_energy(cand, layers, spec)      # (P, L) float64

Compute mode, dataflow mapping, OSA sizing and bit widths are *static*
(they select formulas, not values) and ride in an `EnergySpec`; rows, cols,
tiles and the GEMM dims are traced array data.  Everything runs in float64
(via `jax.experimental.enable_x64`) so the vectorized path matches the
scalar reference to ~1e-15 relative — the DSE parity test pins 1e-6.

Scalar-model invariants preserved here (see energy.layer_energy):
  * ceil-divisions are exact integer ceil-divs, not float ceils;
  * event counts (tiles, programming words, streamed values, ADC firings)
    are integers until the final multiply by per-event Joule constants;
  * static power integrates over the same `rounds * (t_prog + t_stream)`
    latency.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import constants as C
from repro.core.constants import ComputeMode, Mapping, OPEConfig
from repro.core.energy import (LayerShape, ODL_STATIC_W, OSAEnergyConfig,
                               PSUM_BITS)


@dataclasses.dataclass(frozen=True)
class EnergySpec:
    """Static (formula-selecting) knobs of one grid evaluation."""

    mapping: Mapping = Mapping.WS
    mode: ComputeMode = ComputeMode.MIXED
    osa_enabled: bool = False
    ode_len: int = 0
    n_bits_in: int = C.N_BITS_INPUT
    n_bits_w: int = C.N_BITS_WEIGHT
    n_bits_out: int = C.N_BITS_OUTPUT
    pam_bits: int = 1
    batch: int = 1

    @classmethod
    def make(cls, mapping: Mapping = Mapping.WS,
             mode: ComputeMode = ComputeMode.MIXED,
             osa: OSAEnergyConfig | None = None,
             batch: int = 1, **kw) -> "EnergySpec":
        osa = osa if osa is not None else OSAEnergyConfig(enabled=False)
        return cls(mapping=mapping, mode=mode, osa_enabled=osa.enabled,
                   ode_len=osa.ode_len, batch=batch, **kw)

    @property
    def osa(self) -> OSAEnergyConfig:
        return OSAEnergyConfig(enabled=self.osa_enabled, ode_len=self.ode_len)

    @property
    def n_slots(self) -> int:
        return max(1, math.ceil((self.n_bits_in - 1) / self.pam_bits))


def stack_candidates(opes: Sequence[OPEConfig]) -> dict[str, np.ndarray]:
    """(P,) int64 arrays of the candidate grid."""
    return {
        "rows": np.array([o.rows for o in opes], dtype=np.int64),
        "cols": np.array([o.cols for o in opes], dtype=np.int64),
        "tiles": np.array([o.tiles for o in opes], dtype=np.int64),
    }


def stack_layers(shapes: Sequence[LayerShape]) -> dict[str, np.ndarray]:
    """(L,) int64 arrays of GEMM-lowered layers (per-group dims pre-split)."""
    cols = {"g": [], "m": [], "k_pg": [], "n_pg": [], "n_total": []}
    for s in shapes:
        g, m, k_pg, n_pg = s.sub_gemm()
        cols["g"].append(g)
        cols["m"].append(m)
        cols["k_pg"].append(k_pg)
        cols["n_pg"].append(n_pg)
        cols["n_total"].append(s.n)
    return {k: np.array(v, dtype=np.int64) for k, v in cols.items()}


def _ceil_div(a, b):
    return -(-a // b)


def _layer_energy_one(cand: dict, layer: dict, spec: EnergySpec):
    """(energy [J], latency [s]) of ONE layer on ONE OPE config.

    Scalar-in/scalar-out port of `energy.layer_energy`; `cand` and `layer`
    hold 0-d integer arrays so the caller can vmap over either side.
    """
    rows, cols, tiles = cand["rows"], cand["cols"], cand["tiles"]
    g, m0, k_pg, n_pg = layer["g"], layer["m"], layer["k_pg"], layer["n_pg"]
    n_total = layer["n_total"]
    m = m0 * spec.batch

    n_slots = spec.n_slots
    mode, osa = spec.mode, spec.osa

    # ---- tile grid of the stationary operand -----------------------------
    if spec.mapping in (Mapping.WS, Mapping.GEMM):
        tiles_r = _ceil_div(n_total, rows)
        tiles_c = _ceil_div(k_pg, cols)
        n_tiles = tiles_r * tiles_c
        stream_len = m
    elif spec.mapping is Mapping.IS:
        tiles_r = _ceil_div(m, rows)
        tiles_c = _ceil_div(k_pg, cols)
        n_tiles = g * tiles_r * tiles_c
        stream_len = n_pg
    else:
        raise ValueError(spec.mapping)
    rounds = _ceil_div(n_tiles, tiles)

    # ---- per-mode timing and event structure -----------------------------
    f64 = lambda x: jnp.asarray(x, jnp.float64)  # noqa: E731 — local alias
    if mode is ComputeMode.MIXED:
        t_program = C.T_TO_TUNING_S
        slots_per_value = n_slots
        t_stream = f64(stream_len) * slots_per_value * C.T_SLOT_S
        conv_per_out = osa.conversions_per_output(n_slots)
    elif mode is ComputeMode.ANALOG:
        t_program = C.T_TO_TUNING_S
        slots_per_value = 1
        t_stream = f64(stream_len) * C.T_TO_TUNING_S
        conv_per_out = 1
    elif mode is ComputeMode.DIGITAL:
        t_program = C.T_EO_TUNING_S
        slots_per_value = spec.n_bits_in * spec.n_bits_w
        t_stream = f64(stream_len) * slots_per_value * C.T_SLOT_S
        conv_per_out = slots_per_value
    else:
        raise ValueError(mode)

    latency = f64(rounds) * (t_program + t_stream)

    # ---- dynamic energy --------------------------------------------------
    prog_events = f64(n_tiles * rows * cols)
    eo_mod = f64(0.0)
    if mode is ComputeMode.DIGITAL:
        dac_prog = f64(0.0)
        eo_mod = prog_events * spec.n_bits_w * C.MRR_EO_DYNAMIC_J_PER_BIT
    else:
        dac_prog = prog_events * spec.n_bits_w * C.DAC_J_PER_BIT

    stream_values = f64(n_tiles) * f64(stream_len) * f64(cols)
    if mode is ComputeMode.ANALOG:
        dac_prog = dac_prog + stream_values * spec.n_bits_in * C.DAC_J_PER_BIT
    else:
        eo_mod = eo_mod + (stream_values * slots_per_value
                           * C.MRR_EO_DYNAMIC_J_PER_BIT)

    useful_outputs = f64(m) * f64(n_total)
    out_events = useful_outputs * f64(tiles_c) * conv_per_out
    pd_tia = out_events * C.PD_TIA_J_PER_BIT
    adc = out_events * C.adc_energy_per_conversion(spec.n_bits_out)

    sram_dyn = out_events * 2 * PSUM_BITS * C.SRAM_J_PER_BIT
    sram_words = (prog_events * spec.n_bits_w
                  + stream_values * spec.n_bits_in
                  + useful_outputs * spec.n_bits_out)
    sram_dyn = sram_dyn + sram_words * C.SRAM_J_PER_BIT

    dram = (f64(m) * f64(k_pg * g) * spec.n_bits_in
            + f64(k_pg * n_pg * g) * spec.n_bits_w
            + useful_outputs * spec.n_bits_out) * C.DRAM_J_PER_BIT

    dynamic = eo_mod + dac_prog + pd_tia + adc + sram_dyn + dram

    # ---- static energy = power * runtime ---------------------------------
    p_laser = f64(tiles * cols) * C.LASER_STATIC_W
    p_mrr = (f64(tiles * rows * cols) * C.MRR_TO_STATIC_W
             if mode is not ComputeMode.DIGITAL else f64(0.0))
    p_odl = (f64(tiles * rows) * osa.stages_per_row(n_slots) * ODL_STATIC_W
             if mode is ComputeMode.MIXED else f64(0.0))
    buf_bits = (f64(tiles * rows * cols) * spec.n_bits_w
                + f64(tiles * cols) * f64(stream_len) * spec.n_bits_in
                + f64(tiles * rows) * PSUM_BITS)
    p_leak = buf_bits * C.SRAM_LEAK_W_PER_BIT

    energy = dynamic + (p_laser + p_mrr + p_odl + p_leak) * latency
    return energy, latency


def grid_energy(cand: dict, layers: dict, spec: EnergySpec):
    """(P, L) energy and latency: every candidate x every layer, one vmap."""
    per_layer = jax.vmap(_layer_energy_one, in_axes=(None, 0, None))
    per_cand = jax.vmap(per_layer, in_axes=(0, None, None))
    return per_cand(
        {k: jnp.asarray(v, jnp.int64) for k, v in cand.items()},
        {k: jnp.asarray(v, jnp.int64) for k, v in layers.items()},
        spec,
    )


# vmap over a dataclass argument needs it registered as a (static) pytree —
# EnergySpec carries no arrays, so it is all aux_data.
jax.tree_util.register_pytree_node(
    EnergySpec,
    lambda s: ((), s),
    lambda aux, _: aux,
)
