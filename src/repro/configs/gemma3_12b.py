"""gemma3-12b [hf:google/gemma-3-12b family].

Dense 48L d_model=3840 16H (GQA kv=8) head_dim=256 d_ff=15360
vocab=262144; 5:1 local:global attention (window 1024, every 6th layer
global with rope_theta=1e6, locals 1e4); tied embeddings.

long_500k RUNS for this arch: 40 of 48 layers cap their decode cache at the
1024-token window; only the 8 global layers hold the full 500k KV.
"""

from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    vocab=262144,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    qk_norm=True,
    rope_theta=1e6,
    rope_theta_local=1e4,
    window=1024,
    window_pattern=6,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="gemma3-smoke",
    family="dense",
    n_layers=6,
    d_model=64,
    vocab=256,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    qk_norm=True,
    window=8,
    window_pattern=3,
    tie_embeddings=True,
)
