"""Quantization / signed-digit plane invariants (hypothesis-driven, with a
fixed-sample parametrized fallback when hypothesis is not installed)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis as hp
    import hypothesis.strategies as st
except ModuleNotFoundError:
    hp = st = None

from repro.core import quant as Q


def _check_plane_roundtrip(bits: int, seed: int) -> None:
    cfg = Q.QuantConfig(bits=bits)
    q = jax.random.randint(jax.random.PRNGKey(seed), (32,),
                           -cfg.qmax, cfg.qmax + 1).astype(jnp.float32)
    planes = Q.decompose_planes(q, cfg)
    assert planes.shape == (cfg.n_planes, 32)
    assert set(np.unique(np.asarray(planes))) <= {-1.0, 0.0, 1.0}
    np.testing.assert_array_equal(np.asarray(Q.compose_planes(planes, cfg)),
                                  np.asarray(q))


def _check_pam_roundtrip(bits: int, pam_bits: int, seed: int) -> None:
    cfg = Q.QuantConfig(bits=bits)
    q = jax.random.randint(jax.random.PRNGKey(seed), (16,),
                           -cfg.qmax, cfg.qmax + 1).astype(jnp.float32)
    digits = Q.decompose_pam(q, pam_bits, cfg)
    assert digits.shape[0] == -(-cfg.n_planes // pam_bits)
    np.testing.assert_array_equal(
        np.asarray(Q.compose_pam(digits, pam_bits, cfg)), np.asarray(q))


def _check_quantize_bounds(seed: int) -> None:
    x = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * 10
    q, scale = Q.quantize(x)
    assert float(jnp.max(jnp.abs(q))) <= 127
    err = jnp.max(jnp.abs(Q.dequantize(q, scale) - x))
    assert float(err) <= float(scale) / 127 * 0.5 + 1e-6


if hp is not None:
    @hp.given(st.integers(2, 8), st.integers(0, 2 ** 31 - 1))
    @hp.settings(max_examples=40, deadline=None)
    def test_plane_roundtrip_exact(bits, seed):
        _check_plane_roundtrip(bits, seed)

    @hp.given(st.integers(2, 8), st.sampled_from([1, 2, 3, 4]),
              st.integers(0, 2 ** 31 - 1))
    @hp.settings(max_examples=40, deadline=None)
    def test_pam_roundtrip_exact(bits, pam_bits, seed):
        _check_pam_roundtrip(bits, pam_bits, seed)

    @hp.given(st.integers(0, 2 ** 31 - 1))
    @hp.settings(max_examples=20, deadline=None)
    def test_quantize_bounds_and_scale(seed):
        _check_quantize_bounds(seed)
else:
    @pytest.mark.parametrize("bits", range(2, 9))
    @pytest.mark.parametrize("seed", [0, 7, 12345])
    def test_plane_roundtrip_exact(bits, seed):
        _check_plane_roundtrip(bits, seed)

    @pytest.mark.parametrize("bits", [2, 3, 5, 8])
    @pytest.mark.parametrize("pam_bits", [1, 2, 3, 4])
    @pytest.mark.parametrize("seed", [0, 99])
    def test_pam_roundtrip_exact(bits, pam_bits, seed):
        _check_pam_roundtrip(bits, pam_bits, seed)

    @pytest.mark.parametrize("seed", [0, 1, 2, 41, 1337])
    def test_quantize_bounds_and_scale(seed):
        _check_quantize_bounds(seed)


def test_fake_quant_idempotent(key):
    x = jax.random.normal(key, (128,))
    x1 = Q.fake_quant(x)
    x2 = Q.fake_quant(x1)
    np.testing.assert_allclose(np.asarray(x1), np.asarray(x2), atol=1e-6)


def test_fake_quant_straight_through_grad(key):
    x = jax.random.normal(key, (16,))
    g = jax.grad(lambda v: jnp.sum(Q.fake_quant(v)))(x)
    np.testing.assert_allclose(np.asarray(g), 1.0, atol=1e-6)
