"""Pallas kernels vs pure-jnp oracles (interpret mode, shape/dtype sweeps).

The property sections fuzz the osa_matmul / mrr_transfer kernels against
their ref.py oracles over randomized shapes, dtypes and edge tiles
(hypothesis when installed, fixed-sample parametrization otherwise — the
same guard pattern as tests/test_mrr.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:                      # degrade gracefully: property tests fall back to
    import hypothesis as hp            # fixed-sample parametrization when
    import hypothesis.strategies as st  # hypothesis is not installed
except ModuleNotFoundError:
    hp = st = None

from repro.core import mrr, quant
from repro.kernels.mrr_transfer import ops as mt_ops
from repro.kernels.mrr_transfer import ref as mt_ref
from repro.kernels.osa_matmul import ops as osa_ops
from repro.kernels.osa_matmul.ref import osa_matmul_ref
from repro.kernels.ssd_scan import ops as ssd_ops
from repro.kernels.ssd_scan import ref as ssd_ref


# ---------------------------------------------------------------------------
# osa_matmul
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("m,k,n", [(8, 16, 8), (32, 48, 24), (17, 33, 5),
                                   (128, 128, 128)])
@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.analog_guard
def test_osa_kernel_matches_ref(m, k, n, bits, key):
    k1, k2 = jax.random.split(key)
    cfg = quant.QuantConfig(bits=bits)
    q = jnp.round(jax.random.uniform(k1, (m, k), minval=-cfg.qmax,
                                     maxval=cfg.qmax))
    w = jax.random.normal(k2, (k, n))
    y = osa_ops.osa_matmul_int(q, w, quant.plane_weights(cfg),
                               n_planes=cfg.n_planes, bm=8, bn=8, bk=8)
    y_ref = osa_matmul_ref(q, w, quant_bits=bits)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("fused", [True, False])
def test_osa_kernel_fused_vs_per_plane(fused, key):
    k1, k2 = jax.random.split(key)
    q = jnp.round(jax.random.uniform(k1, (16, 24), minval=-127, maxval=127))
    w = jax.random.normal(k2, (24, 8))
    y = osa_ops.osa_matmul_int(q, w, quant.plane_weights(), n_planes=7,
                               fused=fused, bm=8, bn=8, bk=8)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(osa_matmul_ref(q, w)),
                               rtol=1e-4, atol=1e-3)


def test_osa_kernel_nonideal_gains(key):
    """Calibrated (non power-of-two) slot gains flow through the kernel."""
    k1, k2, k3 = jax.random.split(key, 3)
    q = jnp.round(jax.random.uniform(k1, (8, 16), minval=-127, maxval=127))
    w = jax.random.normal(k2, (16, 4))
    gains = quant.plane_weights() * (1 + 0.01 * jax.random.normal(k3, (7,)))
    y = osa_ops.osa_matmul_int(q, w, gains, n_planes=7, bm=8, bn=8, bk=8)
    y_ref = osa_matmul_ref(q, w, gains=gains)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.analog_guard
def test_osa_float_entrypoint(key):
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (9, 21))
    w = jax.random.normal(k2, (21, 6))
    y = osa_ops.osa_matmul(x, w, bm=8, bn=8, bk=8)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(quant.fake_quant(x) @ w),
                               rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# osa_matmul / mrr_transfer property fuzzing vs ref.py
# ---------------------------------------------------------------------------
def _check_osa_parity(m: int, k: int, n: int, bits: int, seed: int,
                      wdtype=jnp.float32) -> None:
    """Kernel == oracle for arbitrary (possibly non-tile-aligned) shapes."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    cfg = quant.QuantConfig(bits=bits)
    q = jnp.round(jax.random.uniform(k1, (m, k), minval=-cfg.qmax,
                                     maxval=cfg.qmax))
    w = jax.random.normal(k2, (k, n)).astype(wdtype)
    y = osa_ops.osa_matmul_int(q, w, quant.plane_weights(cfg),
                               n_planes=cfg.n_planes, bm=8, bn=8, bk=8)
    y_ref = osa_matmul_ref(q, w, quant_bits=bits)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=2e-3)


def _check_mrr_ideal_parity(rows: int, cols: int, seed: int,
                            lo: float, hi: float) -> None:
    """sigma=0: kernel == oracle exactly (up to interpolation tolerance)
    for arbitrary shapes, including non-lane-aligned ones."""
    w = jax.random.uniform(jax.random.PRNGKey(seed), (rows, cols),
                           minval=lo, maxval=hi)
    out_k = mt_ops.mrr_transfer(w, jax.random.PRNGKey(seed + 1),
                                sigma_dac=0.0, sigma_th=0.0)
    z = jnp.zeros_like(w)
    out_r = mt_ref.mrr_transfer_ref(w, z, z, sigma_dac=0.0, sigma_th=0.0)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=5e-4)


def _check_mrr_noisy_parity(n: int, seed: int, sigma_dac: float,
                            sigma_th: float) -> None:
    """Noisy parity: replicate ops.mrr_transfer's internal noise layout
    (flatten -> pad to (rows, 128) -> split key -> two normals) so the
    kernel and the oracle consume IDENTICAL draws."""
    key = jax.random.PRNGKey(seed)
    w = jax.random.uniform(jax.random.fold_in(key, 1), (n,),
                           minval=-1.0, maxval=1.0)
    out_k = mt_ops.mrr_transfer(w, key, sigma_dac=sigma_dac,
                                sigma_th=sigma_th)
    rows = -(-n // 128)
    rows_pad = -(-rows // 8) * 8
    flat = jnp.pad(w, (0, rows_pad * 128 - n)).reshape(rows_pad, 128)
    k1, k2 = jax.random.split(key)
    e_dac = jax.random.normal(k1, flat.shape, flat.dtype)
    e_th = jax.random.normal(k2, flat.shape, flat.dtype)
    out_r = mt_ref.mrr_transfer_ref(flat, e_dac, e_th,
                                    sigma_dac=sigma_dac, sigma_th=sigma_th)
    np.testing.assert_allclose(np.asarray(out_k),
                               np.asarray(out_r.reshape(-1)[:n]),
                               atol=5e-4)


if hp is not None:
    @hp.given(st.integers(1, 40), st.integers(1, 64), st.integers(1, 24),
              st.sampled_from([4, 6, 8]), st.integers(0, 2 ** 16))
    @hp.settings(max_examples=12, deadline=None)
    def test_osa_parity_property(m, k, n, bits, seed):
        _check_osa_parity(m, k, n, bits, seed)

    @hp.given(st.integers(1, 40), st.integers(1, 64),
              st.integers(0, 2 ** 16))
    @hp.settings(max_examples=8, deadline=None)
    def test_osa_parity_bf16_property(m, k, seed):
        _check_osa_parity(m, k, 8, 8, seed, wdtype=jnp.bfloat16)

    @hp.given(st.integers(1, 40), st.integers(1, 40),
              st.integers(0, 2 ** 16),
              st.floats(-1.0, 0.0), st.floats(0.0, 1.0))
    @hp.settings(max_examples=10, deadline=None)
    def test_mrr_ideal_parity_property(rows, cols, seed, lo, hi):
        _check_mrr_ideal_parity(rows, cols, seed, lo, max(hi, lo + 1e-3))

    @hp.given(st.integers(1, 700), st.integers(0, 2 ** 16),
              st.floats(0.0, 0.05), st.floats(0.0, 0.1))
    @hp.settings(max_examples=10, deadline=None)
    def test_mrr_noisy_parity_property(n, seed, sigma_dac, sigma_th):
        _check_mrr_noisy_parity(n, seed, sigma_dac, sigma_th)
else:
    @pytest.mark.parametrize("m,k,n,bits,seed", [
        (1, 1, 1, 8, 0), (7, 9, 3, 4, 1), (8, 8, 8, 6, 2),
        (9, 17, 8, 8, 3), (33, 64, 24, 8, 4), (40, 5, 1, 4, 5),
        (16, 48, 9, 6, 6), (25, 31, 17, 8, 7)])
    def test_osa_parity_property(m, k, n, bits, seed):
        _check_osa_parity(m, k, n, bits, seed)

    @pytest.mark.parametrize("m,k,seed", [(5, 12, 0), (17, 33, 1),
                                          (40, 64, 2)])
    def test_osa_parity_bf16_property(m, k, seed):
        _check_osa_parity(m, k, 8, 8, seed, wdtype=jnp.bfloat16)

    @pytest.mark.parametrize("rows,cols,seed,lo,hi", [
        (1, 1, 0, -1.0, 1.0), (3, 7, 1, -0.5, 0.5), (16, 8, 2, -1.0, 0.0),
        (33, 7, 3, 0.0, 1.0), (40, 40, 4, -0.9, 0.9)])
    def test_mrr_ideal_parity_property(rows, cols, seed, lo, hi):
        _check_mrr_ideal_parity(rows, cols, seed, lo, hi)

    @pytest.mark.parametrize("n,seed,sd,sth", [
        (1, 0, 0.02, 0.04), (127, 1, 0.0, 0.1), (128, 2, 0.05, 0.0),
        (129, 3, 0.02, 0.04), (700, 4, 0.01, 0.02)])
    def test_mrr_noisy_parity_property(n, seed, sd, sth):
        _check_mrr_noisy_parity(n, seed, sd, sth)


# ---------------------------------------------------------------------------
# ssd_scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("l,chunk", [(16, 8), (24, 8), (17, 8)])
@pytest.mark.parametrize("h,g,p,s", [(4, 2, 8, 4), (2, 1, 16, 8)])
def test_ssd_kernel_matches_sequential(l, chunk, h, g, p, s, key):
    ks = jax.random.split(key, 4)
    b = 2
    x = jax.random.normal(ks[0], (b, l, h, p))
    loga = -jnp.abs(jax.random.normal(ks[1], (b, l, h))) * 0.2
    bb = jax.random.normal(ks[2], (b, l, g, s))
    cc = jax.random.normal(ks[3], (b, l, g, s))
    y, sf = ssd_ops.ssd_scan(x, loga, bb, cc, chunk=chunk)
    rep = h // g
    for bi in range(b):
        for hi in range(h):
            gi = hi // rep
            y_r, s_r = ssd_ref.ssd_scan_ref(
                x[bi, :, hi], jnp.exp(loga[bi, :, hi]), bb[bi, :, gi],
                cc[bi, :, gi])
            np.testing.assert_allclose(np.asarray(y[bi, :, hi]),
                                       np.asarray(y_r), rtol=2e-3, atol=2e-3)
            np.testing.assert_allclose(np.asarray(sf[bi, hi]),
                                       np.asarray(s_r), rtol=2e-3, atol=2e-3)


def test_ssd_chunked_ref_matches_sequential(key):
    ks = jax.random.split(key, 4)
    l, p, s = 32, 8, 4
    x = jax.random.normal(ks[0], (l, p))
    a = jnp.exp(-jnp.abs(jax.random.normal(ks[1], (l,))) * 0.3)
    bb = jax.random.normal(ks[2], (l, s))
    cc = jax.random.normal(ks[3], (l, s))
    y1, s1 = ssd_ref.ssd_scan_ref(x, a, bb, cc)
    y2, s2 = ssd_ref.ssd_scan_chunked_ref(x, a, bb, cc, chunk=8)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4,
                               atol=1e-4)


# ---------------------------------------------------------------------------
# mrr_transfer
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(16, 8), (64, 32), (33, 7)])
def test_mrr_transfer_ideal_matches_ref(shape, key):
    w = jax.random.uniform(key, shape, minval=-1, maxval=1)
    out_k = mt_ops.mrr_transfer(w, key, sigma_dac=0.0, sigma_th=0.0)
    z = jnp.zeros_like(w)
    out_r = mt_ref.mrr_transfer_ref(w, z, z, sigma_dac=0.0, sigma_th=0.0)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=5e-4)


def test_mrr_transfer_noise_statistics(key):
    """Kernel noise std matches the behavioural model's Monte-Carlo std."""
    w = jnp.zeros((4096,))
    out = mt_ops.mrr_transfer(w.reshape(64, 64), key)
    std_kernel = float(jnp.std(out))
    std_model = float(mrr.weight_noise_std(jnp.zeros(()), key, 256))
    assert std_kernel == pytest.approx(std_model, rel=0.35)
