"""Jitted public wrapper for the MRR transfer kernel.

Accepts arbitrary-shape weight tensors; flattens to 2-D, pads to block
alignment, draws the noise operands from a PRNG key, dispatches to the
Pallas kernel (interpret mode off-TPU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import mrr
from repro.kernels import on_tpu
from repro.kernels.mrr_transfer.mrr_transfer import mrr_transfer_pallas

_LANE = 128


def preflight(n_elements: int, *, block_rows: int = 8) -> dict:
    """Static tileability/VMEM report for realizing `n_elements` weights.

    Mirrors `mrr_transfer`'s layout: flatten, pad to a (rows, 128) sheet
    with rows a `block_rows` multiple, stream (block_rows, 128) blocks of
    the target plus two noise operands through the VPU (all three
    double-buffered, elementwise chain — no scratch)."""
    issues: list[str] = []
    if n_elements <= 0 or block_rows <= 0:
        issues.append(f"non-positive size n_elements={n_elements} "
                      f"block_rows={block_rows}")
        return {"kernel": "mrr_transfer", "grid": (0,), "vmem_bytes": 0,
                "pad_waste": 0.0, "issues": issues}
    if block_rows % 8:
        issues.append(f"block_rows={block_rows} not a multiple of 8 "
                      "(f32 sublane tile)")
    rows = -(-n_elements // _LANE)
    rows_pad = -(-rows // block_rows) * block_rows
    block = block_rows * _LANE
    vmem = 4 * 2 * block * 4     # 3 in + 1 out blocks, double-buffered
    return {"kernel": "mrr_transfer", "grid": (rows_pad // block_rows,),
            "vmem_bytes": vmem,
            "pad_waste": (rows_pad * _LANE) / n_elements - 1.0,
            "issues": issues}


@functools.partial(jax.jit, static_argnames=("sigma_dac", "sigma_th", "p",
                                             "block_rows"))
def mrr_transfer(w_target: jax.Array, key: jax.Array,
                 sigma_dac: float = 0.02, sigma_th: float = 0.04,
                 p: mrr.MRRParams = mrr.DEFAULT_PARAMS,
                 block_rows: int = 8) -> jax.Array:
    """Noisy MRR realization of target weights, any shape, any size.

    `block_rows` must match `preflight`'s default (pinned by tests): the
    noise-draw padding below depends on it, so changing the launch tiling
    changes which Gaussian each padded element receives."""
    shape = w_target.shape
    flat = w_target.reshape(-1)
    n = flat.shape[0]
    per_row = _LANE
    rows = -(-n // per_row)
    rows_pad = -(-rows // block_rows) * block_rows
    pad = rows_pad * per_row - n
    flat = jnp.pad(flat, (0, pad)).reshape(rows_pad, per_row)
    k1, k2 = jax.random.split(key)
    e_dac = jax.random.normal(k1, flat.shape, flat.dtype)
    e_th = jax.random.normal(k2, flat.shape, flat.dtype)
    y = mrr_transfer_pallas(flat, e_dac, e_th, sigma_dac=sigma_dac,
                            sigma_th=sigma_th, p=p, block_rows=block_rows,
                            interpret=not on_tpu())
    return y.reshape(-1)[:n].reshape(shape)
