"""Jitted public wrapper around the OSA matmul kernel.

Handles: quantization-scale plumbing, padding to MXU-aligned block multiples,
CPU fallback (interpret mode), and default ideal slot gains.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import quant as Q
from repro.kernels import on_tpu
from repro.kernels.osa_matmul.osa_matmul import osa_matmul_pallas


def _pad_to(x: jax.Array, mult: int, axis: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("quant_bits", "pam_bits", "fused",
                                             "per_vector", "bm", "bn", "bk"))
def osa_matmul(x: jax.Array, w: jax.Array, gains: jax.Array | None = None,
               *, quant_bits: int = 8, pam_bits: int = 1, fused: bool = True,
               per_vector: bool = False,
               bm: int = 128, bn: int = 128, bk: int = 128) -> jax.Array:
    """Float activations -> quantize -> OSA kernel -> dequantized output.

    x: (M, K) float; w: (K, N) float; returns (M, N) f32.
    pam_bits > 1 shrinks the slot count (PAM-2^k digits, paper Sec. 3.1).
    per_vector quantizes each activation row at its own full-scale
    (RosaConfig.act_per_vector — serving's batch-decoupling invariant);
    the (M, 1) scale broadcasts through the final dequant.
    """
    cfg = Q.QuantConfig(bits=quant_bits)
    q, scale = Q.quantize(x, cfg, per_vector=per_vector)
    n_planes = -(-cfg.n_planes // pam_bits)
    if gains is None:
        gains = (Q.plane_weights(cfg) if pam_bits == 1
                 else Q.pam_plane_weights(pam_bits, cfg))
    y = osa_matmul_int(q, w, gains, n_planes=n_planes, fused=fused,
                       bm=bm, bn=bn, bk=bk)
    return y * (scale / cfg.qmax)


def preflight(m: int, k: int, n: int, *, bm: int = 128, bn: int = 128,
              bk: int = 128, quant_bits: int = 8, pam_bits: int = 1) -> dict:
    """Static tileability/VMEM report for an (m, k, n) GEMM — no launch.

    Mirrors exactly what `osa_matmul` would do with the shape: pad every
    dimension up to its block multiple, run a (m/bm, n/bn) grid with a
    k-step inner loop, and hold x/w blocks plus an f32 accumulator scratch
    in VMEM (in/out blocks double-buffered by the pipeline).  The slot
    count is derived from (quant_bits, pam_bits) exactly as `osa_matmul`
    derives it, so the sweep prices what actually launches.  `issues`
    lists hard contract violations (block shapes the MXU tiling cannot
    accept); padding itself is legal but wasteful — `pad_waste` is the
    fraction of extra MACs the padding buys."""
    n_planes = -(-Q.QuantConfig(bits=quant_bits).n_planes // pam_bits)
    issues: list[str] = []
    if min(m, k, n) <= 0 or min(bm, bn, bk) <= 0:
        issues.append(f"non-positive dimension in m,k,n={m},{k},{n} "
                      f"bm,bn,bk={bm},{bn},{bk}")
        return {"kernel": "osa_matmul", "grid": (0, 0, 0), "vmem_bytes": 0,
                "pad_waste": 0.0, "issues": issues}
    # f32 min tile is (8, 128): sublane dims % 8, lane dims % 128
    if bm % 8:
        issues.append(f"bm={bm} not a multiple of 8 (f32 sublane tile)")
    if bk % 128:
        issues.append(f"bk={bk} not a multiple of 128 (x-block lane dim)")
    if bn % 128:
        issues.append(f"bn={bn} not a multiple of 128 (w-block lane dim)")
    mp = -(-m // bm) * bm
    kp = -(-k // bk) * bk
    np_ = -(-n // bn) * bn
    grid = (mp // bm, np_ // bn, kp // bk)
    vmem = 4 * (2 * (bm * bk + bk * bn)      # double-buffered in blocks
                + 2 * bm * bn                # double-buffered out block
                + bm * bn                    # accumulator scratch
                + n_planes)                  # plane gains
    pad_waste = (mp * kp * np_) / (m * k * n) - 1.0
    return {"kernel": "osa_matmul", "grid": grid, "vmem_bytes": vmem,
            "pad_waste": pad_waste, "issues": issues}


def osa_matmul_int(q: jax.Array, w: jax.Array, gains: jax.Array,
                   *, n_planes: int, fused: bool = True,
                   bm: int = 128, bn: int = 128, bk: int = 128) -> jax.Array:
    """Integer-activation entry point (the kernel's native contract)."""
    m, k = q.shape
    _, n = w.shape
    qp = _pad_to(_pad_to(q.astype(jnp.float32), bm, 0), bk, 1)
    wp = _pad_to(_pad_to(w.astype(jnp.float32), bk, 0), bn, 1)
    y = osa_matmul_pallas(qp, wp, gains.astype(jnp.float32),
                          n_planes=n_planes, fused=fused, bm=bm, bn=bn, bk=bk,
                          interpret=not on_tpu())
    return y[:m, :n]
