"""Pallas kernels vs pure-jnp oracles (interpret mode, shape/dtype sweeps)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mrr, quant
from repro.kernels.mrr_transfer import ops as mt_ops
from repro.kernels.mrr_transfer import ref as mt_ref
from repro.kernels.osa_matmul import ops as osa_ops
from repro.kernels.osa_matmul.ref import osa_matmul_ref
from repro.kernels.ssd_scan import ops as ssd_ops
from repro.kernels.ssd_scan import ref as ssd_ref


# ---------------------------------------------------------------------------
# osa_matmul
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("m,k,n", [(8, 16, 8), (32, 48, 24), (17, 33, 5),
                                   (128, 128, 128)])
@pytest.mark.parametrize("bits", [4, 8])
def test_osa_kernel_matches_ref(m, k, n, bits, key):
    k1, k2 = jax.random.split(key)
    cfg = quant.QuantConfig(bits=bits)
    q = jnp.round(jax.random.uniform(k1, (m, k), minval=-cfg.qmax,
                                     maxval=cfg.qmax))
    w = jax.random.normal(k2, (k, n))
    y = osa_ops.osa_matmul_int(q, w, quant.plane_weights(cfg),
                               n_planes=cfg.n_planes, bm=8, bn=8, bk=8)
    y_ref = osa_matmul_ref(q, w, quant_bits=bits)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("fused", [True, False])
def test_osa_kernel_fused_vs_per_plane(fused, key):
    k1, k2 = jax.random.split(key)
    q = jnp.round(jax.random.uniform(k1, (16, 24), minval=-127, maxval=127))
    w = jax.random.normal(k2, (24, 8))
    y = osa_ops.osa_matmul_int(q, w, quant.plane_weights(), n_planes=7,
                               fused=fused, bm=8, bn=8, bk=8)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(osa_matmul_ref(q, w)),
                               rtol=1e-4, atol=1e-3)


def test_osa_kernel_nonideal_gains(key):
    """Calibrated (non power-of-two) slot gains flow through the kernel."""
    k1, k2, k3 = jax.random.split(key, 3)
    q = jnp.round(jax.random.uniform(k1, (8, 16), minval=-127, maxval=127))
    w = jax.random.normal(k2, (16, 4))
    gains = quant.plane_weights() * (1 + 0.01 * jax.random.normal(k3, (7,)))
    y = osa_ops.osa_matmul_int(q, w, gains, n_planes=7, bm=8, bn=8, bk=8)
    y_ref = osa_matmul_ref(q, w, gains=gains)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-3)


def test_osa_float_entrypoint(key):
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (9, 21))
    w = jax.random.normal(k2, (21, 6))
    y = osa_ops.osa_matmul(x, w, bm=8, bn=8, bk=8)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(quant.fake_quant(x) @ w),
                               rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# ssd_scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("l,chunk", [(16, 8), (24, 8), (17, 8)])
@pytest.mark.parametrize("h,g,p,s", [(4, 2, 8, 4), (2, 1, 16, 8)])
def test_ssd_kernel_matches_sequential(l, chunk, h, g, p, s, key):
    ks = jax.random.split(key, 4)
    b = 2
    x = jax.random.normal(ks[0], (b, l, h, p))
    loga = -jnp.abs(jax.random.normal(ks[1], (b, l, h))) * 0.2
    bb = jax.random.normal(ks[2], (b, l, g, s))
    cc = jax.random.normal(ks[3], (b, l, g, s))
    y, sf = ssd_ops.ssd_scan(x, loga, bb, cc, chunk=chunk)
    rep = h // g
    for bi in range(b):
        for hi in range(h):
            gi = hi // rep
            y_r, s_r = ssd_ref.ssd_scan_ref(
                x[bi, :, hi], jnp.exp(loga[bi, :, hi]), bb[bi, :, gi],
                cc[bi, :, gi])
            np.testing.assert_allclose(np.asarray(y[bi, :, hi]),
                                       np.asarray(y_r), rtol=2e-3, atol=2e-3)
            np.testing.assert_allclose(np.asarray(sf[bi, hi]),
                                       np.asarray(s_r), rtol=2e-3, atol=2e-3)


def test_ssd_chunked_ref_matches_sequential(key):
    ks = jax.random.split(key, 4)
    l, p, s = 32, 8, 4
    x = jax.random.normal(ks[0], (l, p))
    a = jnp.exp(-jnp.abs(jax.random.normal(ks[1], (l,))) * 0.3)
    bb = jax.random.normal(ks[2], (l, s))
    cc = jax.random.normal(ks[3], (l, s))
    y1, s1 = ssd_ref.ssd_scan_ref(x, a, bb, cc)
    y2, s2 = ssd_ref.ssd_scan_chunked_ref(x, a, bb, cc, chunk=8)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4,
                               atol=1e-4)


# ---------------------------------------------------------------------------
# mrr_transfer
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(16, 8), (64, 32), (33, 7)])
def test_mrr_transfer_ideal_matches_ref(shape, key):
    w = jax.random.uniform(key, shape, minval=-1, maxval=1)
    out_k = mt_ops.mrr_transfer(w, key, sigma_dac=0.0, sigma_th=0.0)
    z = jnp.zeros_like(w)
    out_r = mt_ref.mrr_transfer_ref(w, z, z, sigma_dac=0.0, sigma_th=0.0)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=5e-4)


def test_mrr_transfer_noise_statistics(key):
    """Kernel noise std matches the behavioural model's Monte-Carlo std."""
    w = jnp.zeros((4096,))
    out = mt_ops.mrr_transfer(w.reshape(64, 64), key)
    std_kernel = float(jnp.std(out))
    std_model = float(mrr.weight_noise_std(jnp.zeros(()), key, 256))
    assert std_kernel == pytest.approx(std_model, rel=0.35)
