"""Serving CLI — a thin driver over the `repro.serve` subsystem.

Three policies:

  continuous  (default) slot-based continuous batching: Poisson request
              stream, chunked prefill interleaved with decode, in-step
              slot eviction/refill on a donated paged KV cache
  oneshot     static batching baseline (the old one-shot script semantics:
              form a full batch, decode until its last request finishes)
  batch       the minimal fixed-batch demo loop (one prompt shape, one
              batch, N tokens) through `steps.make_sampling_decode_step` —
              a single jitted step with traced temperature + carried key

Examples:

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-32b --smoke \
      --requests 24 --rate 1.0 --n-slots 4 --temperature 0.7
  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-12b --smoke \
      --policy batch --batch 4 --prompt-len 32 --gen 16
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-32b --smoke \
      --rosa --variation-seed 7 --devices 2
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke


def _run_batch(args) -> None:
    """Fixed-batch demo path (the historic serve.py, minus its bugs)."""
    from repro.launch.steps import make_sampling_decode_step
    from repro.models.model import build_model, pad_cache

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    bundle = build_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = bundle.init(key)
    print(f"arch={cfg.name} params={bundle.n_params:,}")

    b, s = args.batch, args.prompt_len
    prompt = jax.random.randint(key, (b, s), 0, cfg.vocab, dtype=jnp.int32)
    batch = {"tokens": prompt}
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jnp.zeros((b, 16, cfg.d_model), jnp.bfloat16)
    if cfg.frontend == "audio":
        batch["src_embeds"] = jax.random.normal(
            key, (b, s, cfg.d_model), jnp.float32).astype(jnp.bfloat16)

    t0 = time.time()
    logits, cache = jax.jit(bundle.prefill)(params, batch)
    cache = pad_cache(cfg, cache, args.gen + 1)
    print(f"prefill {b}x{s}: {time.time() - t0:.2f}s")

    step = make_sampling_decode_step(bundle)
    tok = jnp.argmax(logits, -1)
    out = [tok]
    t0 = time.time()
    for _ in range(args.gen - 1):
        tok, cache, key = step(params, tok, cache, args.temperature, key)
        out.append(tok)
    dt = time.time() - t0
    toks = jnp.stack(out, 1)
    print(f"decoded {args.gen} tokens x {b} seqs in {dt:.2f}s "
          f"({b * args.gen / max(dt, 1e-9):.1f} tok/s)")
    print("sample token ids:", toks[0, :12].tolist())


def _run_stream(args) -> None:
    """Continuous-batching / one-shot serving over a synthetic stream."""
    import contextlib

    from repro.serve import (Scheduler, ServeConfig, poisson_requests,
                             report_metrics)

    tracer = None
    ctx = contextlib.nullcontext()
    if args.trace:
        from repro import obs
        obs.install_jax_hooks()
        tracer = obs.Tracer()
        # installed around construction too, so compile/autotune/plan-cache
        # spans land in the same trace as the serving ticks
        ctx = obs.tracing(tracer)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    scfg = ServeConfig(n_slots=args.n_slots, max_len=args.max_len,
                       prefill_chunk=args.prefill_chunk,
                       temperature=args.temperature, seed=args.seed,
                       rosa=args.rosa, variation_seed=args.variation_seed)
    mesh = None
    if args.devices > 1:
        mesh = jax.make_mesh((args.devices,), ("data",))
    with ctx:
        sched = Scheduler(cfg, scfg, init_seed=args.seed, mesh=mesh)
        print(f"arch={cfg.name} params={sched.bundle.n_params:,} "
              f"slots={scfg.n_slots} max_len={scfg.max_len} "
              f"chunk={scfg.prefill_chunk} policy={args.policy}"
              + (f" mesh={args.devices}x data" if mesh else "")
              + (" rosa" if args.rosa else ""))

        reqs = poisson_requests(
            args.requests, args.rate, vocab=cfg.vocab,
            prompt_len=tuple(args.prompt_range),
            gen_len=tuple(args.gen_range), seed=args.seed)
        rep = sched.run(reqs, policy=args.policy)

    if tracer is not None:
        tracer.save(args.trace)
        print(f"trace: {len(tracer)} events -> {args.trace} "
              f"(load in https://ui.perfetto.dev, or summarize with "
              f"`python -m repro.obs summarize {args.trace}`)")

    for m in report_metrics(rep):
        v = f"{m.value:.4g}" if isinstance(m.value, float) else m.value
        print(f"  {m.name:24s} {v} {m.unit}")
    if args.rosa and sched.engine is not None \
            and sched.engine.ledger is not None:
        from repro.core.constants import ROSA_OPTIMAL
        e = sched.engine.ledger.per_token(ROSA_OPTIMAL, batch=scfg.n_slots)
        print(f"  {'energy_per_token':24s} {e:.4g} J (traced ledger)")
    done = sorted(rep.completions.values(), key=lambda c: c.rid)[:3]
    for c in done:
        print(f"  rid={c.rid} prompt={c.prompt_len} "
              f"tokens={c.tokens[:8]}{'...' if len(c.tokens) > 8 else ''}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--arch", default="gemma3-12b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--policy", default="continuous",
                    choices=["continuous", "oneshot", "batch"])
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    # stream policies
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--rate", type=float, default=1.0,
                    help="Poisson arrivals per tick (<=0: all at tick 0)")
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=56)
    ap.add_argument("--prefill-chunk", type=int, default=8)
    ap.add_argument("--prompt-range", type=int, nargs=2, default=(4, 8))
    ap.add_argument("--gen-range", type=int, nargs=2, default=(2, 40))
    ap.add_argument("--devices", type=int, default=1,
                    help="shard slots over this many devices (shard_map)")
    ap.add_argument("--rosa", action="store_true",
                    help="serve through the optical engine (hybrid plan "
                         "searched on the decode trace + energy ledger)")
    ap.add_argument("--variation-seed", type=int, default=None,
                    help="pin one sampled fabricated chip (repro.robust)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Perfetto-loadable Chrome trace of the "
                         "run (compile + scheduler + request lifecycle + "
                         "energy counters) to PATH")
    # batch policy
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    if args.policy == "batch":
        _run_batch(args)
    else:
        _run_stream(args)


if __name__ == "__main__":
    main()
