"""Event-count energy / latency / EDP model of the MRR-ONN (paper Sec. 3.4).

The paper extends Timeloop/CiMLoop with photonic primitives; this module is
the same idea in analytical closed form: for a layer's GEMM (M,K,N) mapped
onto a (T x R x C) OPE fleet under a given compute mode (Table 1), dataflow
mapping (Fig. 4) and OSA configuration, we count *every* energy event —

    weight-programming DACs, EO input modulation bits, photodetections,
    ADC conversions, partial-sum SRAM read-modify-writes, DRAM traffic —

and every latency contributor (thermo-optic settles, bit-slot streaming),
then integrate static power (lasers, TO holds, ODL stages, SRAM leakage)
over the layer runtime.  EDP = energy * latency.

Conventions:
  * conv layers are im2col'd to GEMM: M = output pixels, K = C_in*kh*kw,
    N = C_out; grouped/depthwise convs become `groups` independent
    sub-GEMMs of (M, K/g, N/g).
  * mixed mode (ROSA): weights analog on TO-tuned MRRs, inputs bit-serial
    signed digits on EO modulators, `n_slots = N_i - 1` slots per value.
  * without OSA the photocurrent is digitized once per bit slot; with OSA
    slots accumulate optically and the ADC fires once per `ode_len` slots
    (optimal ODE sizing: ode_len = n_slots -> exactly one conversion per
    output per K-tile).

All arithmetic is plain Python floats — this model is swept thousands of
times by the DSE and must stay trace-free.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable

from repro.core import constants as C
from repro.core.constants import ComputeMode, Mapping, OPEConfig

PSUM_BITS = 24          # electronic partial-sum accumulator width
ODL_STATIC_W = 0.2e-3   # per ODL shift stage: SCISSOR thermal hold + phase
#                         calibration [17, 18] — passive spiral + trim heater,
#                         well below a full MRR resonance hold (1.58 mW).


@dataclasses.dataclass(frozen=True)
class LayerShape:
    """One GEMM-lowered layer."""

    name: str
    m: int                 # streamed/output spatial dim (tokens or pixels)
    k: int                 # reduction dim
    n: int                 # output channels
    groups: int = 1
    kind: str = "conv"     # conv | dwconv | fc | gemm (bookkeeping only)

    @property
    def macs(self) -> int:
        return self.m * (self.k // self.groups) * (self.n // self.groups) \
            * self.groups

    def sub_gemm(self) -> tuple[int, int, int, int]:
        """(g, M, K, N) of the per-group sub-GEMM."""
        return (self.groups, self.m,
                max(1, self.k // self.groups), max(1, self.n // self.groups))


@dataclasses.dataclass(frozen=True)
class OSAEnergyConfig:
    """OSA presence and optical-delay-element sizing."""

    enabled: bool = True
    ode_len: int = 0       # max slots the ODL chain can align; 0 -> all slots
    #                        (paper's 'optimized ODE sizing'); Fig. 8's plain
    #                        OSA bar corresponds to a shorter default chain.

    def conversions_per_output(self, n_slots: int) -> int:
        if not self.enabled:
            return n_slots
        ode = self.ode_len if self.ode_len > 0 else n_slots
        return math.ceil(n_slots / ode)

    def stages_per_row(self, n_slots: int) -> int:
        if not self.enabled:
            return 0
        ode = self.ode_len if self.ode_len > 0 else n_slots
        return min(ode, n_slots) - 1


NO_OSA = OSAEnergyConfig(enabled=False)
OSA_DEFAULT = OSAEnergyConfig(enabled=True, ode_len=4)   # un-optimized chain
OSA_OPTIMAL = OSAEnergyConfig(enabled=True, ode_len=0)   # sized to n_slots


@dataclasses.dataclass
class EnergyBreakdown:
    """Per-component energies [J], latency [s], and the EDP [J*s]."""

    name: str = ""
    laser: float = 0.0
    mrr_static: float = 0.0
    odl_static: float = 0.0
    sram_leak: float = 0.0
    eo_mod: float = 0.0
    dac_prog: float = 0.0
    pd_tia: float = 0.0
    adc: float = 0.0
    sram_dyn: float = 0.0
    dram: float = 0.0
    latency: float = 0.0
    events: dict = dataclasses.field(default_factory=dict)

    @property
    def static(self) -> float:
        return self.laser + self.mrr_static + self.odl_static + self.sram_leak

    @property
    def dynamic(self) -> float:
        return (self.eo_mod + self.dac_prog + self.pd_tia + self.adc
                + self.sram_dyn + self.dram)

    @property
    def energy(self) -> float:
        return self.static + self.dynamic

    @property
    def edp(self) -> float:
        return self.energy * self.latency

    def __add__(self, o: "EnergyBreakdown") -> "EnergyBreakdown":
        out = EnergyBreakdown(name=self.name or o.name)
        for f in ("laser", "mrr_static", "odl_static", "sram_leak", "eo_mod",
                  "dac_prog", "pd_tia", "adc", "sram_dyn", "dram", "latency"):
            setattr(out, f, getattr(self, f) + getattr(o, f))
        out.events = {k: self.events.get(k, 0) + o.events.get(k, 0)
                      for k in set(self.events) | set(o.events)}
        return out

    def as_dict(self) -> dict:
        d = {f: getattr(self, f) for f in
             ("laser", "mrr_static", "odl_static", "sram_leak", "eo_mod",
              "dac_prog", "pd_tia", "adc", "sram_dyn", "dram")}
        d.update(energy=self.energy, latency=self.latency, edp=self.edp)
        return d


def _tiles(stationary_rows: int, stationary_cols: int, ope: OPEConfig):
    """Tile grid of the stationary operand over one R x C array."""
    return (math.ceil(stationary_rows / ope.rows),
            math.ceil(stationary_cols / ope.cols))


def layer_energy(shape: LayerShape,
                 ope: OPEConfig,
                 mapping: Mapping = Mapping.WS,
                 mode: ComputeMode = ComputeMode.MIXED,
                 osa: OSAEnergyConfig = OSA_OPTIMAL,
                 n_bits_in: int = C.N_BITS_INPUT,
                 n_bits_w: int = C.N_BITS_WEIGHT,
                 n_bits_out: int = C.N_BITS_OUTPUT,
                 pam_bits: int = 1,
                 batch: int = 1) -> EnergyBreakdown:
    """Energy/latency/EDP of one layer inference (see module docstring)."""
    g, m, k_pg, n_pg = shape.sub_gemm()           # per-group K, N
    m = m * batch
    n_total = shape.n
    bd = EnergyBreakdown(name=shape.name)

    n_slots = max(1, math.ceil((n_bits_in - 1) / pam_bits))

    # ---- tile grid of the stationary operand -----------------------------
    # Grouped/depthwise convs are GROUP-PACKED (the co-optimized mapper of
    # Sec. 4 packs different groups on different rows): all n_total output
    # channels tile over the rows, while WDM reduction parallelism is
    # bounded by the PER-GROUP reduction depth k/g.
    if mapping in (Mapping.WS, Mapping.GEMM):
        tiles_r, tiles_c = _tiles(n_total, k_pg, ope)   # weights stationary
        n_tiles = tiles_r * tiles_c
        stream_len = m                            # input vectors per tile
    elif mapping is Mapping.IS:
        tiles_r, tiles_c = _tiles(m, k_pg, ope)   # inputs stationary
        n_tiles = g * tiles_r * tiles_c
        stream_len = n_pg                         # weight vectors per tile
    else:
        raise ValueError(mapping)
    rounds = math.ceil(n_tiles / ope.tiles)

    # ---- per-mode timing and event structure -----------------------------
    if mode is ComputeMode.MIXED:
        t_program = C.T_TO_TUNING_S               # stationary operand is TO
        slots_per_value = n_slots
        t_stream = stream_len * slots_per_value * C.T_SLOT_S
        conv_per_out = osa.conversions_per_output(n_slots)
    elif mode is ComputeMode.ANALOG:
        # DEAP-CNNs: both operands analog + TO-tuned; every streamed vector
        # is itself a thermo-optic reprogramming (Table 1: update time t_TO).
        t_program = C.T_TO_TUNING_S
        slots_per_value = 1
        t_stream = stream_len * C.T_TO_TUNING_S
        conv_per_out = 1                          # single-shot analog readout
    elif mode is ComputeMode.DIGITAL:
        # HolyLight: 1-bit EO operands; N_i*N_w slot passes per value pair.
        t_program = C.T_EO_TUNING_S
        slots_per_value = n_bits_in * n_bits_w
        t_stream = stream_len * slots_per_value * C.T_SLOT_S
        conv_per_out = slots_per_value            # digitize every slot
    else:
        raise ValueError(mode)

    bd.latency = rounds * (t_program + t_stream)

    # ---- dynamic energy ---------------------------------------------------
    # stationary-operand programming: full array per tile (parked rings are
    # still driven to their off state), one DAC word per MRR.
    prog_events = n_tiles * ope.rows * ope.cols
    if mode is ComputeMode.DIGITAL:
        bd.dac_prog = 0.0
        bd.eo_mod = prog_events * n_bits_w * C.MRR_EO_DYNAMIC_J_PER_BIT
    else:
        bd.dac_prog = prog_events * n_bits_w * C.DAC_J_PER_BIT

    # streamed-operand encoding
    stream_values = n_tiles * stream_len * ope.cols
    if mode is ComputeMode.ANALOG:
        # analog amplitude needs a DAC sample per streamed value
        bd.dac_prog += stream_values * n_bits_in * C.DAC_J_PER_BIT
    else:
        bd.eo_mod += stream_values * slots_per_value * C.MRR_EO_DYNAMIC_J_PER_BIT

    # detection + digitization: per useful output, per K-tile, per conversion
    # (unused rows of a partially-filled tile are power-gated: no ADC fires)
    useful_outputs = m * n_total
    out_events = useful_outputs * tiles_c * conv_per_out
    bd.pd_tia = out_events * C.PD_TIA_J_PER_BIT
    bd.adc = out_events * C.adc_energy_per_conversion(n_bits_out)

    # partial-sum SRAM read-modify-write per digitized sample
    bd.sram_dyn = out_events * 2 * PSUM_BITS * C.SRAM_J_PER_BIT
    # tile staging traffic: stationary words in, streamed words in, outputs out
    sram_words = (prog_events * n_bits_w
                  + stream_values * n_bits_in
                  + useful_outputs * n_bits_out)
    bd.sram_dyn += sram_words * C.SRAM_J_PER_BIT

    # DRAM: each tensor moves once (per-group sub-tensors summed over groups)
    bd.dram = (m * k_pg * g * n_bits_in + k_pg * n_pg * g * n_bits_w
               + m * n_total * n_bits_out) * C.DRAM_J_PER_BIT

    # ---- static energy = power * runtime ----------------------------------
    p_laser = ope.tiles * ope.cols * C.LASER_STATIC_W
    p_mrr = ope.tiles * ope.rows * ope.cols * C.MRR_TO_STATIC_W \
        if mode is not ComputeMode.DIGITAL else 0.0
    p_odl = ope.tiles * ope.rows * osa.stages_per_row(n_slots) * ODL_STATIC_W \
        if mode is ComputeMode.MIXED else 0.0
    buf_bits = (ope.tiles * ope.rows * ope.cols * n_bits_w      # weight buffer
                + ope.tiles * ope.cols * stream_len * n_bits_in  # stream buffer
                + ope.tiles * ope.rows * PSUM_BITS)              # psum regs
    p_leak = buf_bits * C.SRAM_LEAK_W_PER_BIT

    bd.laser = p_laser * bd.latency
    bd.mrr_static = p_mrr * bd.latency
    bd.odl_static = p_odl * bd.latency
    bd.sram_leak = p_leak * bd.latency

    bd.events = dict(n_tiles=n_tiles, rounds=rounds, prog_events=prog_events,
                     stream_values=stream_values, out_events=out_events,
                     adc_conversions=out_events, macs=shape.macs * batch)
    return bd


def network_energy(layers: Iterable[LayerShape],
                   ope: OPEConfig,
                   mappings: dict[str, Mapping] | Mapping = Mapping.WS,
                   mode: ComputeMode = ComputeMode.MIXED,
                   osa: OSAEnergyConfig = OSA_OPTIMAL,
                   batch: int = 1,
                   **kw) -> EnergyBreakdown:
    """Whole-network energy: layers execute sequentially on the chip."""
    total = EnergyBreakdown(name="network")
    for layer in layers:
        mp = mappings if isinstance(mappings, Mapping) \
            else mappings.get(layer.name, Mapping.WS)
        total = total + layer_energy(layer, ope, mp, mode, osa,
                                     batch=batch, **kw)
    return total


# --------------------------------------------------------------------------
# Table 1 analytical throughput (OPS) formulas
# --------------------------------------------------------------------------
def ops_analog(ope: OPEConfig, n_i: int = 8, n_w: int = 8) -> float:
    return ope.tiles * ope.rows * ope.cols * n_i * n_w / C.T_TO_TUNING_S


def ops_digital(ope: OPEConfig) -> float:
    return ope.tiles * ope.rows * ope.cols / C.T_EO_TUNING_S


def ops_mixed(ope: OPEConfig, n_w: int = 8) -> float:
    return ope.tiles * ope.rows * ope.cols * n_w / C.T_EO_TUNING_S
