"""Mixture-of-experts block: top-k router, shared + routed experts.

Two execution paths with identical semantics (tested for equality):

  * `moe_ref`  — dense one-hot combine over all experts.  O(E) compute; only
    for unit tests / tiny smoke configs.
  * `moe_ep`   — production path, runs inside `shard_map`.  Experts are
    sharded over the `model` mesh axis (expert parallelism); tokens are
    data-sharded and replicated across `model`, so each device packs the
    tokens routed to ITS local experts into a (E_local, capacity, d) buffer
    (sort-free scatter pack), runs the batched expert GEMMs, and psums the
    combined output over `model`.  Expert weights are additionally
    FSDP-sharded on d_model and gathered *explicitly* inside the shard —
    the all-gather is the ZeRO-3 weight gather, and its transpose is the
    reduce-scatter of expert grads.

This dispatch is sort/scatter-based (no GShard one-hot dispatch einsum), so
compiled HLO FLOPs stay within ~capacity_factor of the true active-expert
FLOPs — which is what makes the MoE roofline rows meaningful.

Capacity: per-expert slots C = ceil(T_local * top_k / E * capacity_factor);
overflow tokens are dropped (GShard-style), underflow slots are zero-padded.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.module import ParamDef


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_model: int
    d_ff: int                    # per-expert hidden
    n_shared: int = 0            # always-on shared experts (deepseek-v2)
    capacity_factor: float = 1.25
    router_scale: bool = True    # normalize top-k weights to sum to 1


def moe_def(cfg: MoEConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    p = {
        # router: FSDP storage on d, replicated into the shard_map (small);
        # expert mlp dims deliberately NOT mapped to "model" (experts are).
        "router": ParamDef((d, e), ("embed", None), scale=0.02),
        "wi": ParamDef((e, d, 2, f), ("experts", "embed", None, None)),
        "wo": ParamDef((e, f, d), ("experts", None, "embed")),
    }
    if cfg.n_shared:
        p["shared_wi"] = ParamDef((d, 2, cfg.n_shared * f),
                                  ("embed", None, "mlp"))
        p["shared_wo"] = ParamDef((cfg.n_shared * f, d), ("mlp", "embed"))
    return p


def _route(p: dict, cfg: MoEConfig, x2: jax.Array):
    """x2: (T, d) -> top-k (weights (T,k), ids (T,k))."""
    logits = jnp.einsum("td,de->te", x2.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, cfg.top_k)
    if cfg.router_scale:
        w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    return w.astype(x2.dtype), ids


def _shared(p: dict, cfg: MoEConfig, x: jax.Array) -> jax.Array:
    gu = jnp.einsum("td,dcf->tcf", x, p["shared_wi"])
    h = jax.nn.silu(gu[:, 0]) * gu[:, 1]
    return jnp.einsum("tf,fd->td", h, p["shared_wo"])


def _expert_ffn(wi: jax.Array, wo: jax.Array, buf: jax.Array) -> jax.Array:
    """buf: (E, C, d); wi: (E, d, 2, f); wo: (E, f, d) -> (E, C, d)."""
    gu = jnp.einsum("ecd,edxf->ecxf", buf, wi)
    h = jax.nn.silu(gu[:, :, 0]) * gu[:, :, 1]
    return jnp.einsum("ecf,efd->ecd", h, wo)


# ---------------------------------------------------------------------------
# Reference path (dense combine) — oracle + tiny configs
# ---------------------------------------------------------------------------
def moe_ref(p: dict, cfg: MoEConfig, x: jax.Array) -> jax.Array:
    """x: (B, S, d). Dense per-expert evaluation weighted by router gates."""
    b, s, d = x.shape
    x2 = x.reshape(-1, d)
    w, ids = _route(p, cfg, x2)                       # (T, k)
    gates = jnp.zeros((x2.shape[0], cfg.n_experts), x.dtype)
    gates = jax.vmap(lambda g, i, v: g.at[i].add(v))(gates, ids, w)
    # (E, T, d) all-expert eval — reference only
    gu = jnp.einsum("td,edxf->etxf", x2, p["wi"])
    h = jax.nn.silu(gu[:, :, 0]) * gu[:, :, 1]
    y_all = jnp.einsum("etf,efd->etd", h, p["wo"])
    y = jnp.einsum("te,etd->td", gates, y_all)
    if cfg.n_shared:
        y = y + _shared(p, cfg, x2)
    return y.reshape(b, s, d)


# ---------------------------------------------------------------------------
# Capacity pack/unpack (runs per device shard; pure jnp, no collectives)
# ---------------------------------------------------------------------------
def _pack_local(x2, w, ids, e_first, e_local, capacity):
    """Scatter local-expert tokens into (e_local, capacity, d).

    Returns (buf, slot, valid, w_flat, tok_flat) where slot/valid/w/tok are
    the flattened (T*k,) assignment records used to unpack.
    """
    t, d = x2.shape
    k = ids.shape[1]
    e_flat = ids.reshape(-1) - e_first                # (T*k,) local expert idx
    w_flat = w.reshape(-1)
    tok_flat = jnp.repeat(jnp.arange(t), k)
    is_local = (e_flat >= 0) & (e_flat < e_local)
    key = jnp.where(is_local, e_flat, e_local)        # invalid -> bucket E
    order = jnp.argsort(key, stable=True)
    e_sorted = key[order]
    # position within each expert's contiguous run
    counts = jnp.bincount(e_sorted, length=e_local + 1)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(t * k) - starts[e_sorted]
    valid = (e_sorted < e_local) & (pos < capacity)
    slot = jnp.where(valid, e_sorted * capacity + pos, e_local * capacity)
    # scatter token IDS (int32) into slots, then ONE gather of exactly
    # (E_local*C, d) rows — the naive gather-then-scatter materializes a
    # top_k-times duplicated (T*k, d) tensor (measured 8.6 GB/layer on the
    # 235B cell, the single largest memory-term contributor; §Perf C2)
    tok_slot = jnp.full((e_local * capacity + 1,), t, jnp.int32)
    tok_slot = tok_slot.at[slot].set(tok_flat[order].astype(jnp.int32))
    x2_pad = jnp.concatenate([x2, jnp.zeros((1, d), x2.dtype)], axis=0)
    buf = x2_pad[tok_slot[:-1]].reshape(e_local, capacity, d)
    return buf, slot, valid, w_flat[order], tok_flat[order]


def _unpack_local(y_buf, slot, valid, w_sorted, tok_sorted, t):
    """Weighted scatter-add of expert outputs back to token order."""
    e_local, capacity, d = y_buf.shape
    flat = jnp.concatenate([y_buf.reshape(-1, d),
                            jnp.zeros((1, d), y_buf.dtype)], axis=0)
    picked = flat[jnp.where(valid, slot, e_local * capacity)]
    contrib = picked * (w_sorted * valid)[:, None]
    return jnp.zeros((t, d), y_buf.dtype).at[tok_sorted].add(contrib)


def capacity_of(t_local: int, cfg: MoEConfig) -> int:
    c = int(-(-t_local * cfg.top_k * cfg.capacity_factor // cfg.n_experts))
    return max(1, c)


# ---------------------------------------------------------------------------
# Expert-parallel path (inside shard_map)
# ---------------------------------------------------------------------------
def moe_ep_local(p_local: dict, cfg: MoEConfig, x_local: jax.Array, *,
                 model_axis: str = "model",
                 fsdp_axes=("pod", "data"),
                 capacity: int | None = None,
                 a2a: bool = False) -> jax.Array:
    """Per-shard MoE body.  Call inside shard_map.

    Two dispatch modes:
      a2a=False — tokens are data-sharded and REPLICATED over `model_axis`;
        each shard packs the tokens routed to its local experts and the
        outputs psum over the model axis (zero all-to-all, replicated
        activations; the default under the TP train layout).
      a2a=True  — tokens are sharded over `model_axis` too (ZeRO-3 layout,
        §Perf C4): each shard routes its own tokens against ALL experts,
        packs per-destination buffers, and two all-to-alls move tokens to
        expert owners and results back.  No psum; wire per layer is
        2 x buffer instead of a full activation all-reduce.

    p_local: expert weights sharded: wi/wo expert dim over `model_axis` and
      d_model dim over `fsdp_axes` (gathered here); router replicated.
    """
    b, s, d = x_local.shape
    x2 = x_local.reshape(-1, d)
    t_local = b * s
    cap = capacity or capacity_of(t_local, cfg)
    e_local = p_local["wi"].shape[0]
    n_shards = cfg.n_experts // e_local
    ax_idx = jax.lax.axis_index(model_axis)

    w, ids = _route(p_local, cfg, x2)
    wi, wo = p_local["wi"], p_local["wo"]
    if fsdp_axes:
        wi = jax.lax.all_gather(wi, fsdp_axes, axis=1, tiled=True)
        wo = jax.lax.all_gather(wo, fsdp_axes, axis=2, tiled=True)

    if a2a:
        # pack against the GLOBAL expert space, then exchange
        buf, slot, valid, w_srt, tok_srt = _pack_local(
            x2, w, ids, 0, cfg.n_experts, cap)      # (E, cap, d)
        buf = buf.reshape(n_shards, e_local, cap, d)
        recv = jax.lax.all_to_all(buf, model_axis, split_axis=0,
                                  concat_axis=0, tiled=True)
        h = _expert_ffn(wi, wo,
                        recv.transpose(1, 0, 2, 3).reshape(
                            e_local, n_shards * cap, d))
        back = h.reshape(e_local, n_shards, cap, d).transpose(1, 0, 2, 3)
        back = jax.lax.all_to_all(back, model_axis, split_axis=0,
                                  concat_axis=0, tiled=True)
        y = _unpack_local(back.reshape(cfg.n_experts, cap, d),
                          slot, valid, w_srt, tok_srt, t_local)
    else:
        buf, slot, valid, w_srt, tok_srt = _pack_local(
            x2, w, ids, ax_idx * e_local, e_local, cap)
        y_buf = _expert_ffn(wi, wo, buf)
        y = _unpack_local(y_buf, slot, valid, w_srt, tok_srt, t_local)
        y = jax.lax.psum(y, model_axis)
    if cfg.n_shared:
        # shared experts: d_ff tensor-parallel over `model` (f dim arrives
        # pre-sharded by the shard_map in_specs), d_model FSDP-gathered here.
        swi, swo = p_local["shared_wi"], p_local["shared_wo"]
        if fsdp_axes:
            swi = jax.lax.all_gather(swi, fsdp_axes, axis=0, tiled=True)
            swo = jax.lax.all_gather(swo, fsdp_axes, axis=1, tiled=True)
        if a2a:
            # tokens differ across model shards: a TP psum would mix them —
            # gather the (small) shared-expert weights and compute locally
            swi = jax.lax.all_gather(swi, model_axis, axis=2, tiled=True)
            swo = jax.lax.all_gather(swo, model_axis, axis=0, tiled=True)
            y = y + _shared({"shared_wi": swi, "shared_wo": swo}, cfg, x2)
        else:
            y = y + _shared_tp(swi, swo, x2, model_axis)
    return y.reshape(b, s, d)


def _shared_tp(swi, swo, x2, model_axis):
    """Shared experts with d_ff tensor-parallel over the model axis."""
    gu = jnp.einsum("td,dcf->tcf", x2, swi)
    h = jax.nn.silu(gu[:, 0]) * gu[:, 1]
    return jax.lax.psum(jnp.einsum("tf,fd->td", h, swo), model_axis)
