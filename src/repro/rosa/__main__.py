"""Offline plan-cache maintenance: `python -m repro.rosa stats|gc`.

The serving stack bounds its cache online (`PlanCache(max_entries=...)`
GCs after every store); this CLI is the operator's view of a store on
disk — how big it has grown, what is hot, and a manual prune for roots
that were written unbounded.

    python -m repro.rosa stats [--root PATH]
    python -m repro.rosa gc --max-entries N [--root PATH]
"""

from __future__ import annotations

import argparse
import json

from repro.rosa.program import PlanCache


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.rosa",
                                 description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)
    p_stats = sub.add_parser("stats", help="summarize a plan-cache store")
    p_gc = sub.add_parser("gc", help="evict LRU entries beyond a bound")
    p_gc.add_argument("--max-entries", type=int, required=True,
                      help="keep at most N entries (plans + matrices)")
    for p in (p_stats, p_gc):
        p.add_argument("--root", default=None,
                       help="cache root (default: the repo-standard dir)")
    args = ap.parse_args(argv)

    cache = PlanCache(args.root)
    if args.cmd == "gc":
        evicted = cache.gc(args.max_entries)
        print(json.dumps({"evicted": evicted, **cache.stats()}, indent=1))
    else:
        print(json.dumps(cache.stats(), indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
