"""Jitted public wrapper around the OSA matmul kernel.

Handles: quantization-scale plumbing, padding to MXU-aligned block multiples,
CPU fallback (interpret mode), and default ideal slot gains.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import quant as Q
from repro.kernels.osa_matmul.osa_matmul import osa_matmul_pallas


def _pad_to(x: jax.Array, mult: int, axis: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("quant_bits", "pam_bits", "fused",
                                             "per_vector", "bm", "bn", "bk"))
def osa_matmul(x: jax.Array, w: jax.Array, gains: jax.Array | None = None,
               *, quant_bits: int = 8, pam_bits: int = 1, fused: bool = True,
               per_vector: bool = False,
               bm: int = 128, bn: int = 128, bk: int = 128) -> jax.Array:
    """Float activations -> quantize -> OSA kernel -> dequantized output.

    x: (M, K) float; w: (K, N) float; returns (M, N) f32.
    pam_bits > 1 shrinks the slot count (PAM-2^k digits, paper Sec. 3.1).
    per_vector quantizes each activation row at its own full-scale
    (RosaConfig.act_per_vector — serving's batch-decoupling invariant);
    the (M, 1) scale broadcasts through the final dequant.
    """
    cfg = Q.QuantConfig(bits=quant_bits)
    q, scale = Q.quantize(x, cfg, per_vector=per_vector)
    n_planes = -(-cfg.n_planes // pam_bits)
    if gains is None:
        gains = (Q.plane_weights(cfg) if pam_bits == 1
                 else Q.pam_plane_weights(pam_bits, cfg))
    y = osa_matmul_int(q, w, gains, n_planes=n_planes, fused=fused,
                       bm=bm, bn=bn, bk=bk)
    return y * (scale / cfg.qmax)


def osa_matmul_int(q: jax.Array, w: jax.Array, gains: jax.Array,
                   *, n_planes: int, fused: bool = True,
                   bm: int = 128, bn: int = 128, bk: int = 128) -> jax.Array:
    """Integer-activation entry point (the kernel's native contract)."""
    m, k = q.shape
    _, n = w.shape
    qp = _pad_to(_pad_to(q.astype(jnp.float32), bm, 0), bk, 1)
    wp = _pad_to(_pad_to(w.astype(jnp.float32), bk, 0), bn, 1)
    y = osa_matmul_pallas(qp, wp, gains.astype(jnp.float32),
                          n_planes=n_planes, fused=fused, bm=bm, bn=bn, bk=bk,
                          interpret=not _on_tpu())
    return y[:m, :n]
