"""Donation verification: declared donate_argnums vs compiled reality.

`donate_argnums` is a *permission*, not a guarantee: XLA only aliases a
donated buffer into an output of identical shape/dtype, and jit silently
drops donations on unused arguments — a refactor that stops returning the
updated cache keeps the declaration, loses the alias, and doubles decode's
HBM footprint with zero warning.  The compiled module header's
`input_output_alias` map is the ground truth, so the check compares the
donated argument's array leaves (as a shape multiset) against the aliased
entry parameters.

Findings:

  DON001 ERROR    a declared donated buffer produced no input_output_alias
  DON002 WARNING  a hot-path jit (serving step) declares no donation at
                  all while taking multi-buffer state arguments
"""

from __future__ import annotations

from collections import Counter

from repro.analysis.findings import Finding, Severity
from repro.analysis.hlo import (entry_parameter_shapes,
                                parse_input_output_aliases)
from repro.analysis.registry import register
from repro.analysis.target import AnalysisTarget


def _norm(shape_text: str) -> str:
    """Strip layout annotations: 'f32[4,8]{1,0}' -> 'f32[4,8]'."""
    return shape_text.split("{")[0].strip()


@register("donation")
def check_donation(target: AnalysisTarget) -> list[Finding]:
    if target.fn is None:
        return []
    if not target.donate_argnums:
        if target.hot_path:
            return [Finding(
                check="donation", code="DON002",
                severity=Severity.WARNING, subject=target.name,
                location="donate_argnums=()",
                message=("hot-path jit declares no donation: per-step "
                         "state buffers are copied every tick — donate "
                         "the state argument"))]
        return []

    declared = Counter(_norm(s) for s in target.donated_leaf_shapes())
    if not declared:
        return []

    hlo = target.compiled_text()
    params = entry_parameter_shapes(hlo)
    aliased = Counter(
        _norm(params.get(p, "?"))
        for p, _tuple_idx in parse_input_output_aliases(hlo))

    findings: list[Finding] = []
    missing = declared - aliased
    for shape, count in sorted(missing.items()):
        findings.append(Finding(
            check="donation", code="DON001", severity=Severity.ERROR,
            subject=target.name, location=f"donated {shape}",
            message=(f"{count} donated buffer(s) of shape {shape} "
                     "produced NO input_output_alias in the compiled "
                     "module: the donation was dropped (buffer unused, "
                     "or no same-shaped output) and the step pays a full "
                     "copy — fix the dataflow or remove the donation")))
    return findings
