"""PRNG discipline: key provenance through the jaxpr.

The paper's robustness numbers assume every noise draw (DAC quantization,
thermal crosstalk, per-layer variation) is statistically independent; one
reused key silently correlates the Monte-Carlo ensemble.  Whether a key is
reused is decidable from the jaxpr: jax's functional PRNG funnels every
distribution through `random_bits`, and keys move through a small closed
set of primitives (`random_wrap`/`random_unwrap` are representation casts,
`random_split`/`random_fold_in` derive fresh streams).

The walker assigns every key value a provenance id:

  * `random_wrap` / `random_unwrap` / `broadcast_in_dim` / `reshape` /
    `convert_element_type` preserve identity (same bits, same stream);
  * `random_split` / `random_fold_in` derive a child id — MEMOIZED on
    (parent, primitive, literal operands, static params), so folding the
    same constants twice yields the SAME id: two layers folding an equal
    (name-CRC, step) pair are correctly seen as one correlated stream;
  * slicing a split's stack derives per-half ids (memoized on indices);
  * `random_bits` CONSUMES its key id.

Findings:

  PRNG001 ERROR    one key id consumed by >= 2 independent draws
  PRNG002 WARNING  a constant-baked key (captured PRNGKey(0) array)
                   feeding draws — every run realizes identical noise
  PRNG003 WARNING  `random_seed` of a compile-time constant inside traced
                   code (a PRNGKey(const) baked into the computation)
  PRNG004 ERROR    a loop-invariant key consumed inside a scan/while body
                   with no iteration-dependent fold — every iteration
                   draws the SAME noise
"""

from __future__ import annotations

import dataclasses
import itertools

import numpy as np

from repro.analysis.findings import Finding, Severity
from repro.analysis.jaxprs import ClosedJaxpr, Literal, sub_jaxprs
from repro.analysis.registry import register
from repro.analysis.target import AnalysisTarget

# identity-preserving ops: the output is the same key material
_IDENTITY = {"random_wrap", "random_unwrap", "broadcast_in_dim", "reshape",
             "convert_element_type", "copy"}
# derivation ops: output is a fresh stream derived from the input key
_DERIVE = {"random_split", "random_fold_in", "threefry2x32"}
# stack-indexing ops: picking one key out of a split's stack
_INDEX = {"slice", "dynamic_slice", "squeeze", "gather"}
_CONSUME = {"random_bits"}


def _is_key_aval(aval) -> bool:
    """Key-typed (new-style) or a raw uint32[..., 2] counter pair."""
    try:
        import jax
        if jax.dtypes.issubdtype(aval.dtype, jax.dtypes.prng_key):
            return True
    except (TypeError, AttributeError):
        pass
    return (getattr(aval, "dtype", None) == np.uint32
            and tuple(getattr(aval, "shape", ()))[-1:] == (2,))


@dataclasses.dataclass(frozen=True)
class _KeyInfo:
    kid: int
    origin: str
    constant: bool = False       # traces back to a captured constant array
    loop_const: bool = False     # loop-invariant inside the current body


class _Walker:
    def __init__(self, subject: str):
        self.subject = subject
        self.fresh = itertools.count()
        self.memo: dict[tuple, int] = {}
        self.consumed: dict[int, list[str]] = {}
        self.infos: dict[int, _KeyInfo] = {}
        self.findings: list[Finding] = []

    # -- helpers -------------------------------------------------------------
    def new_info(self, origin: str, constant=False, loop_const=False
                 ) -> _KeyInfo:
        info = _KeyInfo(next(self.fresh), origin, constant, loop_const)
        self.infos[info.kid] = info
        return info

    def derived(self, parent: _KeyInfo, eqn, loc: str,
                literal_ops: tuple, loop_const: bool) -> _KeyInfo:
        static = tuple(sorted(
            (k, str(v)) for k, v in eqn.params.items()
            if isinstance(v, (int, float, str, bool, tuple))))
        key = (parent.kid, eqn.primitive.name, literal_ops, static)
        kid = self.memo.get(key)
        if kid is None:
            info = self.new_info(f"{parent.origin}->{loc}",
                                 constant=parent.constant,
                                 loop_const=loop_const)
            self.memo[key] = info.kid
            return info
        return dataclasses.replace(self.infos[kid], loop_const=loop_const)

    def consume(self, info: _KeyInfo, loc: str, in_loop: bool):
        self.consumed.setdefault(info.kid, []).append(loc)
        if info.constant:
            self.findings.append(Finding(
                check="prng", code="PRNG002", severity=Severity.WARNING,
                subject=self.subject, location=info.origin,
                message=("constant-baked PRNG key consumed at "
                         f"{loc}: every run realizes identical noise — "
                         "thread an explicit key instead")))
        if in_loop and info.loop_const:
            self.findings.append(Finding(
                check="prng", code="PRNG004", severity=Severity.ERROR,
                subject=self.subject, location=loc,
                message=("loop-invariant key consumed inside a loop body "
                         "with no iteration-dependent fold_in: every "
                         "iteration draws the SAME noise "
                         f"(key origin: {info.origin})")))

    # -- the walk ------------------------------------------------------------
    def walk(self, closed: ClosedJaxpr, env: dict, path: str,
             varying: set | None, depth: int = 0):
        """env: Var -> _KeyInfo; varying: loop-varying Vars of the current
        loop body (None outside loops)."""
        if depth > 64:
            return
        in_loop = varying is not None
        for cv, const in zip(closed.jaxpr.constvars, closed.consts):
            if cv not in env and _is_key_aval(cv.aval):
                env[cv] = self.new_info(
                    f"{path or 'jaxpr'}:captured-const"
                    f"{tuple(np.shape(const))}", constant=True,
                    loop_const=in_loop)

        def info_of(atom):
            return None if isinstance(atom, Literal) else env.get(atom)

        def is_varying(atom):
            return (varying is not None and not isinstance(atom, Literal)
                    and atom in varying)

        for eqn in closed.jaxpr.eqns:
            prim = eqn.primitive.name
            loc = f"{path}/{prim}".lstrip("/")
            name = eqn.params.get("name")
            if isinstance(name, str) and name:
                loc = f"{loc}:{name}"

            if varying is not None and any(is_varying(a)
                                           for a in eqn.invars):
                varying.update(eqn.outvars)

            if prim == "random_seed":
                op = eqn.invars[0]
                const_seed = isinstance(op, Literal) or (
                    op in closed.jaxpr.constvars)
                info = self.new_info(f"{loc}:seed", constant=const_seed,
                                     loop_const=in_loop
                                     and not is_varying(op))
                env[eqn.outvars[0]] = info
                if const_seed:
                    self.findings.append(Finding(
                        check="prng", code="PRNG003",
                        severity=Severity.WARNING, subject=self.subject,
                        location=loc,
                        message=("PRNG key seeded from a compile-time "
                                 "constant inside traced code — every run "
                                 "draws the same stream")))
                continue

            if prim in _IDENTITY:
                src = info_of(eqn.invars[0]) if eqn.invars else None
                if src is not None:
                    for ov in eqn.outvars:
                        env[ov] = src
                continue

            if prim in _DERIVE or prim in _INDEX:
                src = next((i for a in eqn.invars
                            if (i := info_of(a)) is not None), None)
                if src is not None:
                    other = tuple(
                        repr(a.val) if isinstance(a, Literal) else None
                        for a in eqn.invars if info_of(a) is None)
                    # the derived stream stays loop-invariant only if the
                    # key was AND nothing folded in varies per iteration
                    lc = src.loop_const and not any(
                        is_varying(a) for a in eqn.invars)
                    d = self.derived(src, eqn, loc, other, lc)
                    for ov in eqn.outvars:
                        env[ov] = d
                continue

            if prim in _CONSUME:
                src = next((i for a in eqn.invars
                            if (i := info_of(a)) is not None), None)
                if src is not None:
                    self.consume(src, loc, in_loop)
                continue

            # -- recursion into nested jaxprs -------------------------------
            subs = list(sub_jaxprs(eqn))
            if not subs:
                continue
            if prim == "while":
                self._walk_while(eqn, env, loc, varying, depth)
                continue
            loop = prim == "scan"
            nconsts = eqn.params.get("num_consts", 0) if loop else 0
            for _pname, sub in subs:
                inner = sub.jaxpr.invars
                outer = list(eqn.invars)
                # positional when lengths agree, else align tails (covers
                # the custom_*_call wrappers that prepend const args)
                if len(outer) > len(inner):
                    outer = outer[len(outer) - len(inner):]
                sub_env = dict(env)
                sub_varying = varying
                if loop:
                    sub_varying = set(inner[nconsts:])
                elif varying is not None:
                    sub_varying = set()
                for pos, (iv, ov) in enumerate(zip(inner, outer)):
                    if sub_varying is not None and is_varying(ov):
                        sub_varying.add(iv)
                    src = info_of(ov)
                    if src is not None:
                        if loop and pos < nconsts:
                            src = dataclasses.replace(src, loop_const=True)
                        sub_env[iv] = src
                self.walk(sub, sub_env, f"{loc}", sub_varying, depth + 1)
                # map results back (pjit/cond: positional; scan: carries+ys)
                inner_out = sub.jaxpr.outvars
                if len(inner_out) == len(eqn.outvars):
                    for iv, ov in zip(inner_out, eqn.outvars):
                        src = None if isinstance(iv, Literal) \
                            else sub_env.get(iv)
                        if src is not None:
                            env[ov] = src

    def _walk_while(self, eqn, env, loc, varying, depth):
        cn = eqn.params.get("cond_nconsts", 0)
        bn = eqn.params.get("body_nconsts", 0)
        body = eqn.params.get("body_jaxpr")
        cond = eqn.params.get("cond_jaxpr")
        carry = list(eqn.invars[cn + bn:])

        def seed_env(consts, sub):
            sub_env = dict(env)
            inner = sub.jaxpr.invars
            sub_varying = set(inner[len(consts):])
            for pos, (iv, ov) in enumerate(zip(inner, consts + carry)):
                src = None if isinstance(ov, Literal) else env.get(ov)
                if src is not None:
                    if pos < len(consts):
                        src = dataclasses.replace(src, loop_const=True)
                    sub_env[iv] = src
            return sub_env, sub_varying

        if body is not None:
            sub_env, sub_varying = seed_env(
                list(eqn.invars[cn:cn + bn]), body)
            self.walk(body, sub_env, f"{loc}/body", sub_varying, depth + 1)
        if cond is not None:
            sub_env, sub_varying = seed_env(list(eqn.invars[:cn]), cond)
            self.walk(cond, sub_env, f"{loc}/cond", sub_varying, depth + 1)


@register("prng")
def check_prng(target: AnalysisTarget) -> list[Finding]:
    if target.fn is None:
        return []
    closed = target.try_jaxpr()
    if closed is None:
        return []
    walker = _Walker(target.name)
    env: dict = {}
    for iv in closed.jaxpr.invars:
        if _is_key_aval(iv.aval):
            env[iv] = walker.new_info(f"arg:{iv.aval.str_short()}")
    walker.walk(closed, env, "", None)

    findings = list(walker.findings)
    for kid, locs in walker.consumed.items():
        if len(locs) >= 2:
            info = walker.infos[kid]
            shown = ", ".join(locs[:4]) + ("..." if len(locs) > 4 else "")
            findings.append(Finding(
                check="prng", code="PRNG001", severity=Severity.ERROR,
                subject=target.name, location=info.origin,
                message=(f"PRNG key consumed by {len(locs)} independent "
                         f"draws ({shown}): the draws are perfectly "
                         "correlated — split or fold_in a fresh key per "
                         "draw")))
    # dedupe (a PRNG002/004 can fire once per consumption of one stream)
    seen: set[str] = set()
    out = []
    for f in findings:
        if f.fingerprint not in seen:
            seen.add(f.fingerprint)
            out.append(f)
    return out
