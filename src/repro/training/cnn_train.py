"""QAT training + noisy evaluation for the reduced CNN families.

Matches the paper's Sec. 4 protocol: train with uniform 8-bit quantization
of inputs/weights (straight-through), then evaluate under DAC + thermal
noise with a chosen per-layer IS/WS mapping.  All on synth-CIFAR
(DESIGN.md §8 — CIFAR-10 itself is not available offline).

Execution routes through the compile-once `rosa.Program` API: a model +
engine pair is compiled once (`cnn_program` -> `rosa.compile`), training
differentiates through the program's frozen engine, evaluation calls the
program with an explicit base key (per-layer PRNG keys are folded inside),
and noisy evaluation compiles a derived program with per-layer overrides
(`ExecutionPlan.build`).

Variation-aware QAT: pass a chip `ensemble` (repro.robust.variation) and
each training step pins chip ``step % n_chips`` on the engine — the model
learns weights that survive the whole sampled wafer, not just the nominal
device (the ensemble-axis analogue of the paper's noise-aware training).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro import rosa
from repro.core import mrr
from repro.core.constants import ComputeMode, Mapping
from repro.data.synth_cifar import train_test_split
from repro.models.cnn import LITE_MODELS, LITE_SKIPS, cnn_apply, cnn_def
from repro.models.module import abstract_params, init_params

QAT_CFG = rosa.RosaConfig(mode=ComputeMode.MIXED, noise=mrr.IDEAL)


def qat_engine(model: str, key: jax.Array | None = None) -> rosa.Engine:
    """Uniform 8-bit QAT engine for one lite model (all layers QAT_CFG)."""
    names = [s.name for s in LITE_MODELS[model]]
    return rosa.Engine.from_config(QAT_CFG, layers=names, key=key)


def cnn_program(model: str, engine: rosa.Engine | None = None, *,
                example_batch: int = 8) -> rosa.Program:
    """Compile one lite CNN against `engine` into a `rosa.Program`.

    No plan autotune: the engine's plan (uniform QAT, per-layer override,
    hybrid, ...) is frozen as-is; the compile still captures the named-GEMM
    `ProgramTrace` and re-prices it onto the engine's ledger when one is
    attached.  The program is shape-polymorphic over the batch dim (jit
    retraces per input shape); `example_batch` only sizes the trace."""
    specs = LITE_MODELS[model]
    skips = LITE_SKIPS.get(model)
    engine = engine if engine is not None else rosa.Engine.dense()

    def apply_fn(eng, params, x):
        return cnn_apply(params, specs, x, eng, residual_from=skips)

    skel = abstract_params(cnn_def(specs), jnp.float32)
    x = jax.ShapeDtypeStruct((example_batch, 32, 32, 3), jnp.float32)
    return rosa.compile(apply_fn, engine, (skel, x), autotune=None)


def _loss(params, specs, skips, x, y, engine, key=None):
    logits = cnn_apply(params, specs, x, engine, key, residual_from=skips)
    labels = jax.nn.one_hot(y, logits.shape[-1])
    return -jnp.mean(jnp.sum(labels * jax.nn.log_softmax(logits), -1))


def train_cnn(model: str = "alexnet", steps: int = 400, batch: int = 64,
              lr: float = 3e-3, seed: int = 0, qat: bool = True,
              n_train: int = 4096, verbose: bool = False,
              ensemble=None):
    """Returns (params, clean_test_accuracy).

    With a chip `ensemble` ({layer: mrr.StaticVariation}, leading chip
    axis — see repro.robust.variation.sample_ensemble), step i trains
    through chip ``i % n_chips``: variation-aware QAT over the sampled
    wafer.  The returned accuracy stays the *clean* (variation-free) one.
    """
    specs = LITE_MODELS[model]
    skips = LITE_SKIPS.get(model)
    (xtr, ytr), (xte, yte) = train_test_split(n_train=n_train, seed=seed)
    key = jax.random.PRNGKey(seed)
    params = init_params(cnn_def(specs), key)
    # compile once; the training step differentiates through the program's
    # frozen engine (same plan, straight-through grads), evaluation calls
    # the program itself
    program = cnn_program(model, qat_engine(model) if qat
                          else rosa.Engine.dense())
    engine = program.engine
    n_chips = 0
    if ensemble is not None:
        from repro.robust.variation import ensemble_size
        n_chips = ensemble_size(ensemble)

    # Adam
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)

    @jax.jit
    def step(params, m, v, i, x, y, ens):
        eng = engine
        if ens is not None:
            chip = jax.tree.map(lambda a: a[jnp.mod(i, n_chips)], ens)
            eng = engine.with_variation(chip)
        loss, g = jax.value_and_grad(_loss)(params, specs, skips, x, y,
                                            eng)
        m = jax.tree.map(lambda a, b: 0.9 * a + 0.1 * b, m, g)
        v = jax.tree.map(lambda a, b: 0.99 * a + 0.01 * b * b, v, g)
        t = i + 1
        params = jax.tree.map(
            lambda p, mm, vv: p - lr * (mm / (1 - 0.9 ** t))
            / (jnp.sqrt(vv / (1 - 0.99 ** t)) + 1e-8), params, m, v)
        return params, m, v, loss

    rng = np.random.default_rng(seed)
    for i in range(steps):
        idx = rng.integers(0, len(xtr), batch)
        params, m, v, loss = step(params, m, v, jnp.asarray(i),
                                  jnp.asarray(xtr[idx]), jnp.asarray(ytr[idx]),
                                  ensemble)
        if verbose and i % 100 == 0:
            print(f"  step {i} loss {float(loss):.3f}")

    acc = evaluate_cnn(params, model, program=program)
    return params, acc


@functools.lru_cache(maxsize=4)
def _test_set(seed: int = 0):
    (_, _), (xte, yte) = train_test_split(seed=seed)
    return jnp.asarray(xte), jnp.asarray(yte)


def evaluate_cnn(params, model: str, engine: rosa.Engine | None = None,
                 key: jax.Array | None = None, n_mc: int = 1,
                 seed: int = 0, program: rosa.Program | None = None) -> float:
    """Test accuracy (%); with a noisy engine/program and n_mc>1,
    MC-average over base keys (per-layer keys are folded by the engine).
    Pass a pre-compiled `program` to skip the per-call `rosa.compile`."""
    xte, yte = _test_set(seed)
    if program is None:
        program = cnn_program(model, engine)

    def acc_of(k):
        logits = program(params, xte, key=k)
        return jnp.mean(jnp.argmax(logits, -1) == yte)

    if key is None and n_mc == 1:
        return float(acc_of(None)) * 100.0
    keys = jax.random.split(key if key is not None
                            else jax.random.PRNGKey(7), n_mc)
    return float(jnp.mean(jnp.stack([acc_of(k) for k in keys]))) * 100.0


def layer_noise_profile(params, model: str, *,
                        noise: mrr.NoiseModel = mrr.PAPER_NOISE,
                        n_mc: int = 3, seed: int = 0) -> dict:
    """d_l(m): accuracy drop (pp) when ONLY layer l is noisy-analog under
    mapping m, all other layers exact 8-bit (paper Fig. 6 protocol)."""
    specs = LITE_MODELS[model]
    names = [s.name for s in specs]
    base = qat_engine(model)
    clean = evaluate_cnn(params, model, program=cnn_program(model, base))
    out: dict[str, dict[str, float]] = {}
    key = jax.random.PRNGKey(seed + 100)
    for s in specs:
        out[s.name] = {}
        for mp in (Mapping.IS, Mapping.WS):
            noisy = dataclasses.replace(QAT_CFG, mapping=mp, noise=noise)
            prog = cnn_program(model, base.with_plan(rosa.ExecutionPlan.build(
                QAT_CFG, {s.name: noisy}, layers=names)))
            acc = evaluate_cnn(params, model, program=prog, key=key,
                               n_mc=n_mc)
            out[s.name][mp.value] = max(clean - acc, 0.0)
    return {"clean": clean, "layers": out}
