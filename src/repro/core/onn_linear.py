"""Compatibility shim — the optical MAC now lives in `repro.rosa`.

`rosa_matmul` (the paper's MAC engine as a drop-in JAX matmul with
straight-through gradients) and `RosaConfig` moved to
`repro.rosa.backends`, where the contraction backend (dense einsum /
pure-jnp OSA reference / Pallas kernel) is a registry entry selected by
`RosaConfig.backend` instead of the old `use_kernel` boolean.  Per-layer
routing, PRNG key folding, and trace-based energy accounting live on
`repro.rosa.Engine`.

This module re-exports the names so existing `repro.core.onn_linear`
imports keep working; new code should import from `repro.rosa`.
"""

from __future__ import annotations

__all__ = ["DEFAULT", "RosaConfig", "make_backend", "rosa_matmul"]


def __getattr__(name: str):
    # PEP 562 lazy re-export: repro.core.__init__ imports this module while
    # repro.rosa may still be mid-initialization (rosa.backends itself
    # imports repro.core submodules), so the indirection must not resolve
    # at import time.
    if name in __all__:
        from repro.rosa import backends
        return getattr(backends, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
