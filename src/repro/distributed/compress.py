"""Gradient compression: bf16 on the wire with f32 error feedback.

The DP gradient all-reduce moves every gradient bf16 instead of f32 —
halving the dominant cross-pod collective — while an f32 residual buffer
accumulates the rounding error and re-injects it next step (error feedback
keeps the *long-run* update unbiased; see Seide et al. 1-bit SGD lineage).

Mechanically: the model's loss is differentiated normally; `compress` is
applied to the gradient INSIDE the jitted train step *before* XLA's
all-reduce (the cast makes XLA reduce in bf16), and `state` rides in the
train state pytree, sharded like the params.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress(grads, err_state):
    """-> (bf16 grads for the reduce, new f32 error state)."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        g16 = g32.astype(jnp.bfloat16)
        return g16, g32 - g16.astype(jnp.float32)
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(treedef, [o[0] for o in out]),
            jax.tree.unflatten(treedef, [o[1] for o in out]))


def decompress(grads16):
    return jax.tree.map(lambda g: g.astype(jnp.float32), grads16)
