"""Layer-wise hybrid mapping + OPE array DSE (paper Sec. 3.5)."""

import math

import pytest

from repro.configs.paper_cnns import CNN_WORKLOADS, WORKLOADS
from repro.core import dse, mapping
from repro.core.constants import (COMPACT_4X4, Mapping, MAX_TOTAL_MRRS,
                                  MAX_WDM_CHANNELS)


def test_alpha_layer_adaptive():
    """alpha grows log-like with degradation past d_tol (paper Eq.)."""
    assert mapping.alpha_of(0.0) == pytest.approx(0.01)
    assert mapping.alpha_of(1.0) == pytest.approx(0.01 + 0.1 * math.log(2))
    assert mapping.alpha_of(10.0) > mapping.alpha_of(1.0)


def test_choose_mapping_prefers_accuracy_when_sensitive():
    """Big WS degradation + slightly cheaper WS -> IS must win."""
    p = mapping.LayerProfile("l", d_is=0.5, d_ws=20.0, e_is=1.1, e_ws=1.0)
    assert mapping.choose_mapping(p) is Mapping.IS


def test_choose_mapping_prefers_edp_when_insensitive():
    """Negligible degradation both ways -> cheaper mapping wins."""
    p = mapping.LayerProfile("l", d_is=0.01, d_ws=0.01, e_is=2.0, e_ws=1.0)
    assert mapping.choose_mapping(p) is Mapping.WS


def test_hybrid_plan_is_per_layer_argmin():
    # layer a: noise-critical (both mappings degrade >1% so alpha_l rises;
    # WS 10x worse) -> IS wins despite 10% higher EDP.  layer b: WS is both
    # more accurate and cheaper -> WS.
    profs = [
        mapping.LayerProfile("a", d_is=5.0, d_ws=50.0, e_is=1.1, e_ws=1.0),
        mapping.LayerProfile("b", d_is=4.0, d_ws=0.1, e_is=1.3, e_ws=1.0),
    ]
    plan = mapping.hybrid_plan(profs)
    assert plan["a"] is Mapping.IS
    assert plan["b"] is Mapping.WS


def test_dse_candidates_respect_constraints():
    for ope in dse.default_candidates(include_baselines=False):
        assert ope.cols <= MAX_WDM_CHANNELS
        assert ope.total_mrrs <= MAX_TOTAL_MRRS


def test_dse_winner_beats_deap_and_compact():
    """Fig. 7: the best config has lower aggregated relative EDP than both
    the DEAP-CNNs high-channel setting and the 4x4 compact baseline."""
    wls = [dse.Workload(n, ls) for n, ls in WORKLOADS.items()]
    pts = dse.sweep(wls)
    best = pts[0]
    by_label = {p.label: p for p in pts}
    deap = by_label["R=113,C=9,T=1"]
    compact = [p for p in pts if p.ope == COMPACT_4X4][0]
    assert best.metric < deap.metric
    assert best.metric < compact.metric
    assert best.geomean < 1.0            # beats the 4x4 reference itself


def test_dse_moderate_arrays_win():
    """Paper: (8,8)-scale arrays rank near the top; extremes lose."""
    wls = [dse.Workload(n, ls) for n, ls in CNN_WORKLOADS.items()]
    pts = dse.sweep(wls)
    ranks = {p.label: i for i, p in enumerate(pts)}
    assert ranks["R=8,C=8,T=16"] < ranks["R=1,C=1,T=1024"]
    assert ranks["R=8,C=8,T=16"] < ranks["R=113,C=9,T=1"]
