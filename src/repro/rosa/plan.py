"""`ExecutionPlan` — frozen, hashable per-layer config resolution.

A plan is the single object that says, for every named matmul in a network,
which `RosaConfig` executes it: a `default` config (None = plain dense
einsum, i.e. the layer never touches the optical path) plus per-layer
`overrides` (the paper's layer-wise hybrid IS/WS mapping is exactly such an
override set).  Optionally the plan carries the known `layers` tuple, in
which case override names are validated at build time and lookups of
undeclared names fail loudly instead of silently falling back.

The plan is registered as a *static* pytree (no array leaves), so it can be
closed over or passed through `jax.jit` boundaries as a hashable constant.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Mapping as TMapping

import jax

from repro.core.constants import Mapping
from repro.rosa.backends import RosaConfig


@jax.tree_util.register_static
@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """Resolves layer name -> RosaConfig (None = exact dense einsum)."""

    default: RosaConfig | None = None
    overrides: tuple[tuple[str, RosaConfig | None], ...] = ()
    layers: tuple[str, ...] | None = None   # declared layer set (optional)

    # -- constructors -------------------------------------------------------
    @classmethod
    def build(cls, default: RosaConfig | None = None,
              overrides: TMapping[str, RosaConfig | None] | None = None,
              layers: Iterable[str] | None = None) -> "ExecutionPlan":
        """Validating constructor: override names must be declared layers."""
        layers_t = tuple(layers) if layers is not None else None
        ov = dict(overrides or {})
        if layers_t is not None:
            unknown = sorted(set(ov) - set(layers_t))
            if unknown:
                raise ValueError(
                    f"plan overrides name unknown layers {unknown}; "
                    f"declared layers: {sorted(layers_t)}")
        return cls(default, tuple(sorted(ov.items())), layers_t)

    @classmethod
    def from_mapping_plan(cls, default: RosaConfig,
                          plan: TMapping[str, Mapping],
                          layers: Iterable[str] | None = None
                          ) -> "ExecutionPlan":
        """Lift a `{layer: Mapping}` hybrid plan (core.mapping.hybrid_plan)
        into per-layer configs: the default config with the mapping field
        swapped per layer.
        """
        ov = {name: dataclasses.replace(default, mapping=m)
              for name, m in plan.items()}
        return cls.build(default, ov, layers)

    # -- resolution ---------------------------------------------------------
    def resolve(self, name: str) -> RosaConfig | None:
        """Config for a named layer; raises KeyError on undeclared names
        when the plan carries a declared layer set.
        """
        for n, cfg in self.overrides:
            if n == name:
                return cfg
        if self.layers is not None and name not in self.layers:
            raise KeyError(
                f"layer {name!r} not in declared plan layers "
                f"{sorted(self.layers)}")
        return self.default

    def map_configs(self, fn) -> "ExecutionPlan":
        """Derived plan with `fn(cfg)` applied to every non-None config
        (default and overrides) — e.g. flip the noise model or compute mode
        across a whole plan without rebuilding it layer by layer.
        """
        return ExecutionPlan(
            fn(self.default) if self.default is not None else None,
            tuple((n, fn(c) if c is not None else None)
                  for n, c in self.overrides),
            self.layers)

    @property
    def is_dense(self) -> bool:
        """True when no layer can reach the optical path."""
        return self.default is None and all(c is None
                                            for _, c in self.overrides)

    # -- JSON round-trip -----------------------------------------------------
    def to_json(self) -> dict:
        """JSON-native view; `ExecutionPlan.from_json` inverts it exactly.
        This is what the on-disk plan cache persists.
        """
        from repro.rosa.serialize import config_to_json
        return {
            "default": config_to_json(self.default),
            "overrides": [[n, config_to_json(c)] for n, c in self.overrides],
            "layers": list(self.layers) if self.layers is not None else None,
        }

    @classmethod
    def from_json(cls, doc: dict) -> "ExecutionPlan":
        """Inverse of `to_json`."""
        from repro.rosa.serialize import config_from_json
        return cls(
            config_from_json(doc["default"]),
            tuple((n, config_from_json(c)) for n, c in doc["overrides"]),
            tuple(doc["layers"]) if doc["layers"] is not None else None,
        )

    def mapping_plan(self) -> dict[str, Mapping]:
        """Project back to a `{layer: Mapping}` dict (optical layers only)."""
        return {n: c.mapping for n, c in self.overrides if c is not None}
