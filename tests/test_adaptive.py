"""repro.serve.adaptive — closed-loop drift-adaptive serving.

Pinned here:

  * `DriftModel.offsets_at` (the jit-compatible accessor) agrees with the
    materialized `offsets` grid for all three schedule kinds;
  * the re-trim math: residuals shrink monotonically with re-trim
    frequency, and the controller's trim-as-ddt-shift is BIT-exact with
    `drift.residual_offsets` / `drift.simulate`'s realized weights;
  * detector semantics (alpha-beta tracking, CUSUM fire + hysteresis);
  * the bounded LRU `rosa.PlanCache` (gc, touch-on-load, stats, CLI);
  * the scheduler `TickHook` seam; and
  * the end-to-end A/B scenario: a forced mid-stream Program swap with
    zero dropped requests, a bit-exact pre-action epoch, and zero ticks
    of swap downtime.
"""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import rosa
from repro.core import mrr
from repro.core.constants import Mapping
from repro.robust import drift as D
from repro.robust import variation as V
from repro.serve.adaptive import (ControllerState, DetectorConfig,
                                  DriftDetector, ScenarioConfig,
                                  run_scenario)
from repro.serve.adaptive.probes import _ROW_FLOOR

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ---------------------------------------------------------------------------
# DriftModel.offsets_at parity (the controller's per-tick accessor)
# ---------------------------------------------------------------------------
def test_offsets_at_matches_offsets_grid():
    t = np.linspace(0.0, 3600.0, 49)
    key = jax.random.PRNGKey(3)
    for kind in ("sine", "linear", "walk"):
        dm = D.DriftModel(kind=kind, amp_k=0.4, period_s=3600.0)
        grid = dm.offsets(t, key)
        at = np.asarray(dm.offsets_at(t, key=key, t_grid=t))
        np.testing.assert_allclose(at, grid, atol=2e-6)
        # scalar query, under jit (the serving tick loop's usage)
        f = jax.jit(lambda s, d=dm: d.offsets_at(s, key=key, t_grid=t))
        np.testing.assert_allclose(float(f(t[17])), grid[17], atol=2e-6)


def test_offsets_at_walk_needs_key_and_grid():
    dm = D.DriftModel(kind="walk")
    with pytest.raises(ValueError):
        dm.offsets_at(10.0)                       # no key
    with pytest.raises(ValueError):
        dm.offsets_at(10.0, key=jax.random.PRNGKey(0))   # no grid
    with pytest.raises(ValueError):
        D.DriftModel(kind="nope").offsets_at(10.0)


def test_offsets_at_walk_interpolates_between_grid_points():
    t = np.array([0.0, 100.0, 200.0])
    dm = D.DriftModel(kind="walk", amp_k=0.5)
    key = jax.random.PRNGKey(9)
    grid = dm.offsets(t, key)
    mid = float(dm.offsets_at(50.0, key=key, t_grid=t))
    np.testing.assert_allclose(mid, 0.5 * (grid[0] + grid[1]), atol=2e-6)


# ---------------------------------------------------------------------------
# Re-trim math (the controller's actuator model)
# ---------------------------------------------------------------------------
def test_retrim_residual_shrinks_with_frequency():
    """More frequent re-trim => smaller residual.  Deterministic paths
    (sine / linear) shrink pathwise in RMS; the random walk shrinks in
    seed-averaged RMS (a single walk can be unlucky at coarse spacing)."""
    t = np.linspace(0.0, 3600.0, 241)
    ladder = (None, 1800.0, 900.0, 450.0, 225.0)

    def rms_curve(offs):
        return [float(np.sqrt(np.mean(
            D.residual_offsets(offs, t, ev) ** 2))) for ev in ladder]

    for kind in ("sine", "linear"):
        dm = D.DriftModel(kind=kind, amp_k=0.5, period_s=3600.0)
        rms = rms_curve(dm.offsets(t))
        assert all(a >= b - 1e-12 for a, b in zip(rms, rms[1:])), \
            (kind, rms)
        assert rms[-1] < 0.25 * rms[0]

    dm = D.DriftModel(kind="walk", amp_k=0.5, period_s=3600.0)
    acc = np.zeros(len(ladder))
    for s in range(16):
        offs = dm.offsets(t, jax.random.PRNGKey(s))
        acc += [np.mean(D.residual_offsets(offs, t, ev) ** 2)
                for ev in ladder]
    rms = np.sqrt(acc / 16)
    assert all(a >= b - 1e-12 for a, b in zip(rms, rms[1:])), rms


def test_trim_is_offset_subtraction_on_the_plant():
    """The controller models a re-trim at estimate d_hat as shrinking the
    injected offset to (d - d_hat).  Physically the trim re-programs the
    voltages (`trim_voltages(w, d_hat)`) while the FULL offset d stays on
    the rings — the two must realize the same weights (away from heater
    saturation)."""
    w = jnp.linspace(-0.7, 0.5, 25)
    d, d_hat = jnp.float32(0.35), jnp.float32(0.3)
    physical = mrr.weight_of_voltage(
        D.trim_voltages(w, d_hat),
        var=mrr.StaticVariation(jnp.zeros(()), d, jnp.zeros(())))
    modeled = mrr.weight_of_voltage(
        jnp.clip(mrr.voltage_of_weight(w), mrr.DEFAULT_PARAMS.v_min,
                 mrr.DEFAULT_PARAMS.v_max),
        var=mrr.StaticVariation(jnp.zeros(()), d - d_hat, jnp.zeros(())))
    np.testing.assert_allclose(np.asarray(physical), np.asarray(modeled),
                               atol=1e-5)


def test_controller_residual_bitexact_with_simulate():
    """One drift step through the controller's plant model — trim at the
    last trim instant, `shift_thermal(chip, d(t) - d(trim))` — realizes
    the SAME weights, bit for bit, as `drift.simulate`'s
    `residual_offsets` + `shift_thermal` path."""
    t = np.linspace(0.0, 1800.0, 7)
    dm = D.DriftModel(kind="sine", amp_k=0.5, period_s=3600.0)
    offs = dm.offsets(t)
    i, retrim_every = 5, 600.0
    # simulate's residual at step i
    resid_sim = D.residual_offsets(offs, t, retrim_every)[i]
    # controller's residual: true offset minus the trim applied at the
    # last trim instant <= t[i]
    t_trim = (t[i] // retrim_every) * retrim_every
    trim_k = dm.offsets(np.array([t_trim]))[0]
    resid_ctl = offs[i] - trim_k
    assert resid_sim == resid_ctl    # exact: same float subtraction

    chip = V.sample_chip(jax.random.PRNGKey(4), {"a": 6})
    w = jax.random.normal(jax.random.PRNGKey(5), (6, 8)) * 0.4
    shifted = V.shift_thermal(chip, jnp.float32(resid_ctl))["a"]
    reference = V.shift_thermal(chip, jnp.float32(resid_sim))["a"]
    np.testing.assert_array_equal(np.asarray(shifted.ddt),
                                  np.asarray(reference.ddt))
    w_col = w[:, 0]                  # variation is per k-row
    np.testing.assert_array_equal(
        np.asarray(mrr.realize_weights(w_col, var=shifted)),
        np.asarray(mrr.realize_weights(w_col, var=reference)))

    # and through the engine: with_variation on the shifted chip routes
    # the identical realized weights into the matmul
    eng = rosa.Engine.from_config(rosa.RosaConfig(), layers=["a"])
    x = jax.random.normal(jax.random.PRNGKey(6), (3, 6))
    out_ctl = eng.with_variation({"a": shifted}).matmul(x, w, name="a")
    out_sim = eng.with_variation({"a": reference}).matmul(x, w, name="a")
    np.testing.assert_array_equal(np.asarray(out_ctl), np.asarray(out_sim))


# ---------------------------------------------------------------------------
# Detector
# ---------------------------------------------------------------------------
def test_detector_tracks_ramp_with_prediction():
    det = DriftDetector(DetectorConfig(), ref_agreement=1.0)
    slope = 0.05
    for i in range(20):
        det.observe_temp(slope * i)        # noiseless ramp
    # alpha-beta has zero steady-state lag on a ramp; predict() leads by
    # one observation
    assert abs(det.predict() - slope * 20) < 5e-3
    assert abs(det.temp_rate_k - slope) < 5e-3


def test_detector_cusum_fire_and_hysteresis():
    cfg = DetectorConfig(cusum_k=0.02, cusum_h=0.04, rearm=2)
    det = DriftDetector(cfg, ref_agreement=1.0)
    assert not det.update(0.99)            # inside slack: never accumulates
    assert det.cusum == 0.0
    assert not det.update(0.95)            # 0.03 accumulated, below h
    assert det.update(0.95)                # 0.06 > h: fired
    assert det.update(1.0)                 # decaying toward the threshold
    assert det.update(1.0)                 # first clean in-band probe
    assert not det.update(1.0)             # second in-band: re-armed
    assert det.cusum == 0.0 and not det.fired

    det.update(0.9)
    det.update(0.9)
    assert det.fired
    det.reset()                            # corrective action re-arms
    assert not det.fired and det.cusum == 0.0


# ---------------------------------------------------------------------------
# PlanCache: bounded LRU store + CLI
# ---------------------------------------------------------------------------
def _fill(cache, names):
    for n in names:
        cache.store_matrix(n, {"layer": {"weight_stationary": 1.0}})


def test_plancache_gc_bound_and_lru(tmp_path):
    cache = rosa.PlanCache(tmp_path, max_entries=3)
    _fill(cache, [f"k{i}" for i in range(6)])    # gc runs after each store
    assert cache.stats()["entries"] == 3
    # oldest evicted, newest kept
    kept = {p.name for p in tmp_path.iterdir()}
    assert kept == {"k3.deg.json", "k4.deg.json", "k5.deg.json"}

    # a load touches the entry: it becomes MRU and survives the next gc
    os.utime(tmp_path / "k4.deg.json", (1.0, 1.0))
    os.utime(tmp_path / "k5.deg.json", (2.0, 2.0))
    assert cache.load_matrix("k3") is not None   # k3 -> MRU
    assert cache.gc(1) == 2
    assert {p.name for p in tmp_path.iterdir()} == {"k3.deg.json"}


def test_plancache_stats_and_validation(tmp_path):
    with pytest.raises(ValueError):
        rosa.PlanCache(tmp_path, max_entries=0)
    cache = rosa.PlanCache(tmp_path)             # unbounded
    assert cache.gc() == 0                       # no-op without a bound
    with pytest.raises(ValueError):
        cache.gc(0)
    _fill(cache, ["a", "b"])
    st = cache.stats()
    assert st["entries"] == 2 and st["matrices"] == 2 and st["plans"] == 0
    assert st["bytes"] > 0 and st["max_entries"] is None
    assert st["root"] == str(tmp_path)
    json.dumps(st)                               # CLI-serializable


def test_plancache_cli_stats_and_gc(tmp_path):
    cache = rosa.PlanCache(tmp_path)
    _fill(cache, [f"k{i}" for i in range(4)])
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-m", "repro.rosa", "stats", "--root",
         str(tmp_path)], capture_output=True, text=True, env=env,
        check=True)
    st = json.loads(out.stdout)
    assert st["entries"] == 4
    out = subprocess.run(
        [sys.executable, "-m", "repro.rosa", "gc", "--max-entries", "2",
         "--root", str(tmp_path)], capture_output=True, text=True, env=env,
        check=True)
    doc = json.loads(out.stdout)
    assert doc["evicted"] == 2 and doc["entries"] == 2


# ---------------------------------------------------------------------------
# Scheduler TickHook seam
# ---------------------------------------------------------------------------
def test_tick_hook_called_every_tick():
    from repro.configs import get_smoke
    from repro.serve import Request, Scheduler, ServeConfig, TickHook

    cfg = get_smoke("qwen3-32b")
    sched = Scheduler(cfg, ServeConfig(n_slots=2, max_len=24,
                                       prefill_chunk=4))
    reqs = [Request(0, np.arange(1, 5), 4, arrival=0),
            Request(1, np.arange(2, 8), 3, arrival=1)]

    class Counting(TickHook):
        calls: list = []

        def on_tick_end(self, sched, tick, state, idle_slots):
            self.calls.append((tick, idle_slots))

    hook = Counting()
    rep = sched.run(reqs, hook=hook)
    assert len(rep.completions) == 2
    ticks = [t for t, _ in hook.calls]
    assert ticks == sorted(set(ticks))           # once per executed tick
    assert all(0 <= idle <= 2 for _, idle in hook.calls)
    assert hook.step_args(0) == ()               # default: no extra args

    # the hooked run is a pure observer: streams match the hook-free run
    rep2 = sched.run(reqs)
    for rid in (0, 1):
        assert rep.completions[rid].tokens == rep2.completions[rid].tokens


# ---------------------------------------------------------------------------
# End-to-end scenario: the A/B with a forced mid-stream swap
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def scen():
    cfg = ScenarioConfig(n_requests=6, n_probes=8, period_ticks=64.0,
                         warmup_ticks=4, force_replan_at=10)
    res, reqs = run_scenario(cfg)
    return res, reqs


def test_scenario_zero_drops_and_swap_continuity(scen):
    res, reqs = scen
    ctl = res.controller
    assert res.dropped_requests(reqs) == 0
    assert ctl.replans == 1                      # the forced swap happened
    assert all(s["downtime_ticks"] == 0 for s in ctl.swaps)
    # the swap rebound the scheduler onto a fresh program
    assert ctl.swaps[0]["plan"]                  # searched mapping plan
    assert res.summary()["swap_wall_ms"] > 0


def test_scenario_epoch_bitexact_and_recovery(scen):
    res, _ = scen
    n_epoch, exact = res.epoch_bitexact()
    assert exact                                 # vacuous only if n == 0
    assert res.ref_agreement == 1.0              # golden self-agreement
    assert res.controller.mean_agreement > res.monitor.mean_agreement
    assert 0.0 < res.recovery <= 1.0
    assert res.first_action_tick >= res.cfg.warmup_ticks


def test_scenario_controller_acted(scen):
    res, _ = scen
    ctl = res.controller
    assert ctl.retrims >= 1 and ctl.trim_updates >= ctl.retrims
    assert ctl.tracking                          # servo engaged and sticky
    assert ctl.state in tuple(ControllerState)
    # telemetry rows carry the full signal set
    row = ctl.series[-1]
    assert {"tick", "resid_k", "agreement", "trim_k",
            "energy_per_token_j"} <= set(row)
    assert row["energy_per_token_j"] > 0


def test_probes_deterministic_and_monotone(scen):
    """Probe agreement is a pure function of the residual: one fixed
    noise key, one pinned chip — repeat calls agree exactly, and the
    score decays away from zero residual."""
    res, _ = scen
    probes, params = res.controller.probes, res.sched.params
    a = probes.agreement(params, 0.25)
    assert a == probes.agreement(params, 0.25)
    assert 0.0 <= a <= 1.0
    assert probes.agreement(params, 0.0) >= probes.agreement(params, 0.6)


def test_degradation_rows_format(scen):
    """REPLAN measurement: `{layer: {mapping: pp}}` rows in exactly the
    format `rosa.compile(degradation=...)` consumes, floored so a
    measured-zero row can't look infinitely safe to the plan search."""
    res, _ = scen
    rows = res.controller.probes.degradation_rows(res.sched.params, 0.2)
    assert set(rows) == set(res.controller.probes.names)
    for row in rows.values():
        assert set(row) == {Mapping.WS.value, Mapping.IS.value}
        assert all(v >= _ROW_FLOOR for v in row.values())
    json.dumps(rows)                             # PlanCache-serializable
