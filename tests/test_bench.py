"""Benchmark harness: BENCH_<n>.json schema, regression gate, DSE v2 parity."""

import copy

import pytest

from repro.bench import compare as BC
from repro.bench import schema as BS
from repro.configs.model_zoo import layers_from_config, zoo_workloads
from repro.configs.paper_cnns import WORKLOADS
from repro.core import dse


def _report(**kw):
    base = dict(
        bench_seq=0, mode="quick", created_utc="2026-07-30T00:00:00Z",
        env={"python": "3.10", "jax": "0.4.37"},
        results=[BS.BenchResult(
            name="fig7", status="ok", wall_s=1.0,
            metrics=[
                BS.Metric("best_config", "R=8,C=8,T=16", gate=True),
                BS.Metric("reduction_vs_deap", 0.34, unit="frac", gate=True,
                          rel_tol=0.05, direction="higher_is_better"),
                BS.Metric("edp", 2.0e-5, unit="J*s", gate=True,
                          rel_tol=0.05, direction="lower_is_better"),
                BS.Metric("wall_s", 1.23),          # ungated
            ])])
    base.update(kw)
    return BS.BenchReport(**base)


# ---------------------------------------------------------------------------
# Schema
# ---------------------------------------------------------------------------
def test_schema_roundtrip(tmp_path):
    rep = _report()
    path = BS.save(rep, tmp_path / "BENCH_0.json")
    back = BS.load(path)
    assert back == rep


def test_schema_validate_rejects_bad_docs():
    good = _report().to_dict()
    for mutate in (
        lambda d: d.update(schema_version=99),
        lambda d: d.update(mode="fastest"),
        lambda d: d.update(bench_seq=-1),
        lambda d: d["results"][0].update(status="exploded"),
        lambda d: d["results"][0].update(status="failed", error=""),
        lambda d: d["results"][0]["metrics"][0].update(direction="sideways"),
        lambda d: d["results"].append(copy.deepcopy(d["results"][0])),
        lambda d: d["results"][0].update(metrics={"name": "x"}),
        lambda d: d["results"][0].update(metrics=["not-an-object"]),
    ):
        doc = copy.deepcopy(good)
        mutate(doc)
        with pytest.raises(BS.SchemaError):
            BS.validate(doc)


def test_schema_omitted_rel_tol_means_exact():
    """A hand-edited metric without rel_tol must not inherit a tolerance."""
    doc = _report().to_dict()
    del doc["results"][0]["metrics"][1]["rel_tol"]
    rep = BS.from_dict(doc)
    assert rep.results[0].metric("reduction_vs_deap").rel_tol == 0.0


def test_next_bench_path_sequencing(tmp_path):
    assert BS.next_bench_path(tmp_path).name == "BENCH_2.json"
    (tmp_path / "BENCH_4.json").write_text("{}")
    assert BS.next_bench_path(tmp_path).name == "BENCH_5.json"
    assert BS.next_bench_path(tmp_path, seq=7).name == "BENCH_7.json"


# ---------------------------------------------------------------------------
# Compare gate
# ---------------------------------------------------------------------------
def test_compare_identical_passes():
    res = BC.compare(_report(), _report())
    assert res.ok and not res.regressions


def test_compare_within_tolerance_passes():
    cur = _report()
    cur.results[0].metric("reduction_vs_deap").value = 0.335  # -1.5% < 5%
    cur.results[0].metric("edp").value = 2.05e-5              # +2.5% < 5%
    assert BC.compare(_report(), cur).ok


def test_compare_regression_fails_per_direction():
    # lower_is_better metric grows past tol -> regression
    cur = _report()
    cur.results[0].metric("edp").value = 2.2e-5               # +10%
    res = BC.compare(_report(), cur)
    assert not res.ok
    assert [v.key for v in res.regressions] == ["fig7.edp"]
    # ...but an *improvement* of the same size is fine
    cur.results[0].metric("edp").value = 1.8e-5
    assert BC.compare(_report(), cur).ok
    # higher_is_better metric shrinking past tol -> regression
    cur = _report()
    cur.results[0].metric("reduction_vs_deap").value = 0.30   # -12%
    assert not BC.compare(_report(), cur).ok


def test_compare_string_and_missing_metrics():
    cur = _report()
    cur.results[0].metric("best_config").value = "R=4,C=4,T=64"
    res = BC.compare(_report(), cur)
    assert [v.key for v in res.regressions] == ["fig7.best_config"]

    cur = _report()
    cur.results[0].metrics = [m for m in cur.results[0].metrics
                              if m.name != "edp"]
    res = BC.compare(_report(), cur)
    assert not res.ok and res.regressions[0].note.startswith("gated metric")


def test_compare_failed_bench_fails_gate():
    cur = _report()
    cur.results.append(BS.BenchResult(name="table4", status="failed",
                                      wall_s=0.1, error="boom"))
    res = BC.compare(_report(), cur)
    assert not res.ok and res.failed_benches == ["table4"]


def test_compare_mode_mismatch_fails_loudly():
    """quick vs full runs gate different scopes -> explicit failure, not a
    pile of spurious metric regressions."""
    cur = _report(mode="full")
    res = BC.compare(_report(), cur)
    assert not res.ok and "quick" in res.mode_mismatch
    assert not res.verdicts          # no misleading per-metric verdicts
    assert "MODE MISMATCH" in BC.format_result(res)


def test_compare_tol_scale():
    cur = _report()
    cur.results[0].metric("edp").value = 2.2e-5               # +10% > 5%
    assert not BC.compare(_report(), cur).ok
    assert BC.compare(_report(), cur, tol_scale=3.0).ok       # 15% tol


# ---------------------------------------------------------------------------
# Runner failure propagation
# ---------------------------------------------------------------------------
def test_runner_records_failures_and_continues(monkeypatch, capsys):
    """A bench that raises is recorded as failed; the others still run and
    the runner exits non-zero at the END (the old aggregator aborted)."""
    from benchmarks import run as R

    calls = []

    def ok_bench(quick):
        calls.append("ok")
        return [BS.Metric("x", 1.0)]

    def bad_bench(quick):
        calls.append("bad")
        raise RuntimeError("boom")

    def skip_bench(quick):
        raise R.SkipBench("no inputs")

    monkeypatch.setattr(R, "BENCHES", {"bad": bad_bench, "ok": ok_bench,
                                       "skip": skip_bench})
    results = R.run_benches(["bad", "ok", "skip"], quick=True)
    assert calls == ["bad", "ok"]          # ok still ran after the failure
    by = {r.name: r for r in results}
    assert by["bad"].status == "failed" and "boom" in by["bad"].error
    assert by["ok"].status == "ok"
    assert by["skip"].status == "skipped" and "no inputs" in by["skip"].error
    rc = R.main(["--quick"])
    assert rc == 1                         # registry still patched -> fails


# ---------------------------------------------------------------------------
# DSE v2: vmapped engine vs scalar reference
# ---------------------------------------------------------------------------
def test_dse_vmap_matches_scalar_reference():
    """ISSUE 2 acceptance: <=1e-6 relative on the full default grid."""
    wls = [dse.Workload(n, ls) for n, ls in WORKLOADS.items()]
    pts_v = dse.sweep(wls, engine="vmap", batch=8)
    pts_s = dse.sweep(wls, engine="scalar", batch=8)
    assert [p.label for p in pts_v] == [p.label for p in pts_s]
    by_label = {p.label: p for p in pts_s}
    for pv in pts_v:
        ps = by_label[pv.label]
        for attr in ("metric", "geomean", "worst"):
            a, b = getattr(pv, attr), getattr(ps, attr)
            assert abs(a - b) <= 1e-6 * abs(b), (pv.label, attr)
        for name in pv.rel_edp:
            a, b = pv.rel_edp[name], ps.rel_edp[name]
            assert abs(a - b) <= 1e-6 * abs(b), (pv.label, name)


def test_dse_unknown_engine_rejected():
    wls = [dse.Workload("alexnet", WORKLOADS["alexnet"])]
    with pytest.raises(ValueError):
        dse.sweep(wls, engine="quantum")


# ---------------------------------------------------------------------------
# Model zoo
# ---------------------------------------------------------------------------
def test_zoo_covers_all_registry_archs():
    from repro.configs import ARCHS, get_config
    for name in ARCHS:
        layers = layers_from_config(get_config(name), seq_len=128)
        assert layers, name
        assert all(l.m > 0 and l.k > 0 and l.n > 0 for l in layers), name
        assert layers[-1].name == "lm_head"
        assert len({l.name for l in layers}) == len(layers), \
            f"{name}: duplicate layer names"


def test_zoo_sweep_single_jitted_call():
    """Grid x zoo cross-product evaluates through the vmapped engine."""
    wls = zoo_workloads(seq_len=128, include_paper=False,
                        archs=["qwen3-32b", "mamba2-1.3b"])
    pts = dse.evaluate_grid(wls, dse.default_candidates(), batch=2)
    assert len(pts) == len(dse.default_candidates())
    for p in pts:
        assert set(p.rel_edp) == {"qwen3-32b", "mamba2-1.3b"}
        assert p.metric > 0
