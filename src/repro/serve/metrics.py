"""Serving metrics: throughput/latency summaries + per-token energy.

Everything gated in CI is DETERMINISTIC by construction:

  * throughput is counted in step units (decode steps + prefill chunks),
    and token counts are budget-driven — neither depends on sampled token
    VALUES, so the numbers survive jax/platform changes;
  * latency percentiles are in scheduler ticks;
  * energy comes from pricing the decode-step trace (`EnergyLedger` under
    a "decode" scope) with the paper's analytical model — pure shape math.

Wall-clock tokens/sec are recorded alongside, ungated.

`build_serving_engine` is the Engine-aware serving story: trace the decode
step once to discover its GEMMs, search the layer-wise hybrid IS/WS plan on
those shapes (paper Sec. 3.5, EDP term), optionally pin ONE fabricated chip
(`repro.robust` static variation) — and serve every token through that
frozen (plan, chip) pair.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.bench.schema import Metric
from repro.core.constants import ROSA_OPTIMAL, Mapping
from repro.serve.config import ServeConfig
from repro.serve.scheduler import Scheduler, ServeReport


def _abstract_decode_batch(cfg, scfg: ServeConfig):
    from repro.models import transformer as T
    s = scfg.n_slots
    cache = jax.eval_shape(lambda: T.init_cache(cfg, s, scfg.max_len))
    return {"token": jax.ShapeDtypeStruct((s,), jnp.int32),
            "pos": jax.ShapeDtypeStruct((s,), jnp.int32),
            "cache": cache}


def _abstract_chunk_batch(cfg, scfg: ServeConfig):
    from repro.models import transformer as T
    cache = jax.eval_shape(lambda: T.init_cache(cfg, 1, scfg.max_len))
    return {"tokens": jax.ShapeDtypeStruct((1, scfg.prefill_chunk),
                                           jnp.int32),
            "n_valid": jax.ShapeDtypeStruct((1,), jnp.int32),
            "cache": cache}


def trace_serving_shapes(bundle, scfg: ServeConfig, engine):
    """Trace decode step (+ one prefill chunk when the family supports it)
    under `engine`'s ledger with "decode"/"prefill" attribution scopes."""
    from repro import rosa
    ledger = engine.ledger
    params = bundle.abstract(jnp.float32)
    with rosa.engine_context(engine):
        with ledger.scope("decode"):
            jax.eval_shape(bundle.decode_step, params,
                           _abstract_decode_batch(bundle.cfg, scfg))
        if bundle.cfg.family not in ("ssm", "hybrid"):
            with ledger.scope("prefill"):
                jax.eval_shape(bundle.chunk_step, params,
                               _abstract_chunk_batch(bundle.cfg, scfg))
    return ledger


def build_serving_program(bundle, scfg: ServeConfig, cache=None):
    """Compile the decode step into a `rosa.Program`: ONE abstract trace
    discovers the decode GEMMs, the layer-wise hybrid IS/WS plan is
    autotuned on that whole workload (EDP term of paper Sec. 3.5), and the
    searched plan lands in the on-disk plan cache — a warm serving start
    with the same model/slots/backend skips the search entirely.  The
    program then carries the pinned chip (scfg.variation_seed) and a fresh
    `EnergyLedger`, and the scheduler builds every jitted step from it."""
    from repro import rosa

    # act_per_vector: a request's numerics must not depend on which other
    # requests share its decode batch (per-tensor activation scales couple
    # rows; tests/test_serve.py::test_rosa_differential pins this)
    base = rosa.RosaConfig(backend=scfg.rosa_backend, act_per_vector=True)
    probe = rosa.Engine.from_config(base)
    params = bundle.abstract(jnp.float32)
    batch = _abstract_decode_batch(bundle.cfg, scfg)
    # the traced GEMMs already carry the slot batch in m — batch=1 here,
    # or the concurrency would be priced twice
    program = rosa.compile(
        lambda eng, p, b: bundle.decode_step(p, b), probe, (params, batch),
        autotune=rosa.AutotuneConfig(ope=ROSA_OPTIMAL, batch=1),
        cache=cache)
    if scfg.variation_seed is not None:
        from repro.robust import variation as V
        chip = V.sample_chip(
            jax.random.PRNGKey(scfg.variation_seed),
            dims={e.name: e.k for e in program.trace.entries})
        program = program.with_variation(chip)
    return program


def build_serving_engine(bundle, scfg: ServeConfig, with_ledger: bool = True,
                         cache=None):
    """Engine for serving: `build_serving_program`'s autotuned engine
    (hybrid plan from the decode trace, optional pinned chip), plus a
    fresh `EnergyLedger` when requested."""
    from repro import rosa

    engine = build_serving_program(bundle, scfg, cache=cache).engine
    if with_ledger:
        engine = engine.with_ledger(rosa.EnergyLedger())
    return engine


def energy_metrics(model_cfg, scfg: ServeConfig) -> list[Metric]:
    """Per-token / per-chunk energy of the optical serving path, plus the
    hybrid-vs-WS decode EDP ratio the plan search bought."""
    from repro.core import mapping as M
    from repro.models.model import build_model
    from repro.serve.config import serving_model_config

    bundle = build_model(serving_model_config(model_cfg, rosa=True))
    engine = build_serving_engine(bundle, scfg)
    ledger = trace_serving_shapes(bundle, scfg, engine)
    shapes = ledger.layer_shapes(tag="decode")
    plan = {s.name: engine.config(s.name).mapping for s in shapes}
    # batch=1: the decode-step trace already encodes n_slots in each m
    e_hybrid = M.plan_edp(shapes, plan, ROSA_OPTIMAL, batch=1)
    e_ws = M.plan_edp(shapes, {s.name: Mapping.WS for s in shapes},
                      ROSA_OPTIMAL, batch=1)
    out = [
        Metric("energy_per_token_j",
               ledger.per_token(ROSA_OPTIMAL, batch=scfg.n_slots,
                                tag="decode"),
               unit="J", gate=True, rel_tol=1e-3,
               direction="lower_is_better"),
        Metric("decode_edp_hybrid_vs_ws", e_hybrid / e_ws, unit="ratio",
               gate=True, rel_tol=1e-3, direction="lower_is_better"),
        Metric("decode_is_layers",
               sum(1 for m in plan.values() if m is Mapping.IS),
               gate=True, rel_tol=0.0),
    ]
    prefill = ledger.breakdown(ROSA_OPTIMAL, batch=1, tag="prefill")
    if prefill.energy > 0:
        out.append(Metric("energy_per_prefill_chunk_j", prefill.energy,
                          unit="J", gate=True, rel_tol=1e-3,
                          direction="lower_is_better"))
    return out


def report_metrics(rep: ServeReport, prefix: str = "",
                   gate: bool = True) -> list[Metric]:
    """Throughput/latency metrics of one scheduler run.  Step-unit and
    tick metrics gate; wall-clock ones never do."""
    p = prefix
    return [
        Metric(f"{p}total_tokens", rep.total_tokens, gate=gate,
               rel_tol=0.0),
        Metric(f"{p}tokens_per_unit", rep.tokens_per_unit, unit="tok/step",
               gate=gate, rel_tol=1e-6, direction="higher_is_better"),
        Metric(f"{p}occupancy", rep.occupancy, unit="frac", gate=gate,
               rel_tol=1e-6, direction="higher_is_better"),
        Metric(f"{p}latency_p50_ticks", rep.percentile(50), unit="ticks",
               gate=gate, rel_tol=1e-6, direction="lower_is_better"),
        Metric(f"{p}latency_p99_ticks", rep.percentile(99), unit="ticks",
               gate=gate, rel_tol=1e-6, direction="lower_is_better"),
        Metric(f"{p}ttft_p50_ticks", rep.percentile(50, "ttft"),
               unit="ticks", gate=gate, rel_tol=1e-6,
               direction="lower_is_better"),
        Metric(f"{p}tokens_per_s", rep.tokens_per_s, unit="tok/s"),
        Metric(f"{p}wall_s", rep.wall_s, unit="s"),
        Metric(f"{p}ttft_p50_ms", rep.wall_percentile_ms(50, "ttft"),
               unit="ms"),
        Metric(f"{p}latency_p99_ms", rep.wall_percentile_ms(99), unit="ms"),
    ]


def smoke_report(arch: str = "qwen3-32b", n_requests: int = 24,
                 rate: float = 1.0, scfg: ServeConfig | None = None,
                 seed: int = 0) -> list[Metric]:
    """The `serve_smoke` bench: a Poisson stream served continuous vs
    one-shot on the smoke arch; gates continuous throughput, the >= 1.5x
    continuous/one-shot ratio, latency percentiles and per-token energy.

    The workload is deliberately RAGGED (generation budgets 2..40): that is
    the regime continuous batching exists for — a static batch decodes
    max(budget) steps while its short requests idle, continuous refills
    their slots the next tick."""
    from repro.configs import get_smoke

    from repro.serve.loadgen import poisson_requests

    cfg = get_smoke(arch)
    scfg = scfg or ServeConfig(n_slots=4, max_len=56, prefill_chunk=8,
                               seed=seed)
    sched = Scheduler(cfg, scfg, init_seed=seed)
    reqs = poisson_requests(n_requests, rate, vocab=cfg.vocab,
                            prompt_len=(4, 8), gen_len=(2, 40), seed=seed)
    ones = sched.run(reqs, policy="oneshot")     # first run eats compile
    cont = sched.run(reqs, policy="continuous")

    out = report_metrics(cont, prefix="cont_")
    out += [m for m in report_metrics(ones, prefix="oneshot_", gate=False)
            if m.name in ("oneshot_tokens_per_unit", "oneshot_occupancy",
                          "oneshot_tokens_per_s")]
    out.append(Metric(
        "throughput_ratio_vs_oneshot",
        cont.tokens_per_unit / max(ones.tokens_per_unit, 1e-12),
        unit="x", gate=True, rel_tol=1e-6, direction="higher_is_better"))

    # energy of the same serving shapes through the optical engine
    out += energy_metrics(cfg, scfg)
    return out
