"""Noise-aware behavioral model of MRR weight realization (paper Sec. 3.3).

Implements the full physical chain of Eqs. (3)-(8):

    V --(Eq.3)--> dT --(Eq.3)--> d_lambda --(Eq.4)--> T_drop(lambda_ref)
      --(Eq.5)--> T_diff --(Eq.7)--> w

together with its closed-form inverse (used to *program* a target weight),
and the two noise injection points of Eq. (8):

    V' = V + eps_DAC,          eps_DAC ~ N(0, sigma_DAC^2)
    dT' = dT(V') + eps_th,     eps_th  ~ N(0, sigma_th^2)

On top of the per-shot draws, a chip carries *per-device static* variation
(`StaticVariation`): driver/DAC offset dv [V], thermal-crosstalk bias
ddt [K], and fab mismatch of the resonance dlam [nm].  These are drawn ONCE
per fabricated chip (see `repro.robust.variation`) and enter the same chain
deterministically:

    V'' = V' + dv,   dT'' = dT(V'') + eps_th + ddt,
    lam = lambda_0 + dlam + delta_lambda(dT'')

Everything is pure jnp and differentiable; `realize_weights` is the
user-facing op: target weights -> programming voltages -> noisy realized
weights.  A straight-through variant for noise-aware training lives in
`rosa.backends`.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import constants as C


@dataclasses.dataclass(frozen=True)
class MRRParams:
    """Device parameters; defaults are paper Table 2."""

    lambda_0: float = C.LAMBDA_0_NM
    lambda_ref: float = C.LAMBDA_REF_NM
    n_eff: float = C.N_EFF
    gamma: float = C.GAMMA_HWHM_NM
    r_heater: float = C.R_HEATER_OHM
    r_thermal: float = C.R_THERMAL_K_PER_MW
    beta: float = C.BETA_TO_PER_K
    kappa: float = C.HEATER_COUPLING
    v_min: float = C.V_MIN
    v_max: float = C.V_MAX
    q_min: float = -1.0
    q_max: float = 1.0

    @property
    def q_rng(self) -> float:
        return self.q_max - self.q_min


DEFAULT_PARAMS = MRRParams()


@dataclasses.dataclass(frozen=True)
class NoiseModel:
    """Gaussian perturbations of Eq. (8)."""

    sigma_dac: float = C.SIGMA_DAC_DEFAULT   # volts on V
    sigma_th: float = C.SIGMA_TH_DEFAULT     # kelvin on dT

    @property
    def is_ideal(self) -> bool:
        return self.sigma_dac == 0.0 and self.sigma_th == 0.0


IDEAL = NoiseModel(sigma_dac=0.0, sigma_th=0.0)
PAPER_NOISE = NoiseModel()


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class StaticVariation:
    """Per-device (per-chip) static perturbation of the physical chain.

    Leaves are arrays broadcastable against the realized tensor: scalars
    (whole-layer bias), per-reduction-lane vectors of shape (K,) (one entry
    per physical ring lane — the array tile is reused across output
    channels, so lane mismatch correlates along N), or full elementwise
    fields.  Sampled once per chip by `repro.robust.variation`.
    """

    dv: jax.Array      # static driver/DAC voltage offset [V]
    ddt: jax.Array     # static thermal-crosstalk temperature bias [K]
    dlam: jax.Array    # fab mismatch of the resonance wavelength [nm]

    @classmethod
    def zero(cls) -> "StaticVariation":
        z = jnp.zeros(())
        return cls(dv=z, ddt=z, dlam=z)

    def scale(self, s) -> "StaticVariation":
        return StaticVariation(self.dv * s, self.ddt * s, self.dlam * s)

    def shift_ddt(self, offset) -> "StaticVariation":
        """Add a (scalar) thermal offset — the drift injection point."""
        return dataclasses.replace(self, ddt=self.ddt + offset)


def expand_lanes(var: "StaticVariation | None", t):
    """Adapt a chip's per-lane variation to an operand's orientation.

    Convention: 1-D variation fields are per-reduction-lane (length K — one
    entry per physical ring lane).  Against a (K, N) weight they gain a
    trailing axis so lane k perturbs every output channel it is reused for;
    against (M, K) activations they broadcast as-is.  Scalars and
    full-shape fields pass through.
    """
    if var is None:
        return None

    def fix(a):
        a = jnp.asarray(a)
        if a.ndim == 1 and t.ndim == 2 and a.shape[0] == t.shape[0]:
            return a[:, None]
        return a

    return StaticVariation(fix(var.dv), fix(var.ddt), fix(var.dlam))


# --------------------------------------------------------------------------
# Forward chain  V -> w
# --------------------------------------------------------------------------
def delta_t(v, p: MRRParams = DEFAULT_PARAMS):
    """Eq. (3) left: heater temperature rise [K] for drive voltage V.

    V^2/R_h is electrical power in W; x1e3 converts to mW to match R_th's
    K/mW unit; kappa is the calibrated heater coupling (constants.py).
    """
    p_heater_mw = p.kappa * (v * v / p.r_heater) * 1e3
    return p_heater_mw * p.r_thermal


def delta_lambda(dt, p: MRRParams = DEFAULT_PARAMS):
    """Eq. (3) right: resonance shift [nm] for temperature rise dT [K]."""
    bdt = p.beta * dt
    return p.lambda_0 * bdt / (p.n_eff + bdt)


def t_drop(lam, p: MRRParams = DEFAULT_PARAMS):
    """Eq. (4): Lorentzian drop-port transmission probed at lambda_ref."""
    det = lam - p.lambda_ref
    g2 = p.gamma * p.gamma
    return g2 / (det * det + g2)


def t_diff(lam, p: MRRParams = DEFAULT_PARAMS):
    """Eq. (5): differential drop-through transmission in [-1, 1]."""
    return 2.0 * t_drop(lam, p) - 1.0


def _t_diff_of_v(v, p: MRRParams):
    return t_diff(p.lambda_0 + delta_lambda(delta_t(v, p), p), p)


def transmission_endpoints(p: MRRParams = DEFAULT_PARAMS):
    """Eq. (6): T_hi = T_diff(V_min), T_lo = T_diff(V_max).

    V_min leaves the ring closest to lambda_ref (highest drop transmission);
    V_max detunes it furthest.
    """
    return _t_diff_of_v(jnp.asarray(p.v_min), p), _t_diff_of_v(jnp.asarray(p.v_max), p)


def transmission_endpoints_py(p: MRRParams = DEFAULT_PARAMS) -> tuple[float, float]:
    """Pure-Python Eq. (6) endpoints (trace-free, for static kernel params)."""
    import math

    def td(v: float) -> float:
        p_mw = p.kappa * (v * v / p.r_heater) * 1e3
        dt = p_mw * p.r_thermal
        bdt = p.beta * dt
        lam = p.lambda_0 + p.lambda_0 * bdt / (p.n_eff + bdt)
        det = lam - p.lambda_ref
        g2 = p.gamma * p.gamma
        return 2.0 * g2 / (det * det + g2) - 1.0

    del math
    return td(p.v_min), td(p.v_max)


def weight_of_voltage(v, p: MRRParams = DEFAULT_PARAMS, noise: NoiseModel = IDEAL,
                      key: jax.Array | None = None,
                      var: StaticVariation | None = None):
    """Full chain Eqs. (3)-(8): drive voltage(s) -> realized weight(s).

    With a non-ideal `noise` model, `key` must be provided; two independent
    Gaussian draws perturb V (DAC) and dT (thermal crosstalk).  `var` adds
    a chip's static perturbation (driver offset, thermal bias, fab
    mismatch) on top of the per-shot draws.
    """
    v = jnp.asarray(v)
    if not noise.is_ideal:
        if key is None:
            raise ValueError("noisy realization requires a PRNG key")
        k_dac, k_th = jax.random.split(key)
        v = v + noise.sigma_dac * jax.random.normal(k_dac, v.shape, v.dtype)
        eps_th = noise.sigma_th * jax.random.normal(k_th, v.shape, v.dtype)
    else:
        eps_th = 0.0
    if var is not None:
        v = v + var.dv
    dt = delta_t(v, p) + eps_th
    dl = 0.0
    if var is not None:
        dt = dt + var.ddt
        dl = var.dlam
    # accumulate the small detuning terms BEFORE adding the ~1538 nm
    # resonance constant: float32 rounding of lambda_0 + dlam alone would
    # already move the Lorentzian by ~1e-4 nm
    lam = p.lambda_0 + (delta_lambda(dt, p) + dl)
    td = t_diff(lam, p)
    t_hi, t_lo = transmission_endpoints(p)
    return p.q_min + p.q_rng * (td - t_lo) / (t_hi - t_lo)   # Eq. (7)


# --------------------------------------------------------------------------
# Inverse chain  w -> V  (programming)
# --------------------------------------------------------------------------
def voltage_of_weight(w, p: MRRParams = DEFAULT_PARAMS, dt_trim=0.0):
    """Closed-form inverse of the forward chain (for ideal programming).

    Each stage is monotone over the operating branch (lambda detuning grows
    away from lambda_ref as V rises), so the inverse is unique:

      w -> T_diff -> T_drop -> |lam - lam_ref| -> d_lambda -> dT -> V.

    Weights are clipped to the physically realizable range [q_min, q_max];
    this is the quantizer's clamp, matching the paper's full-range mapping.

    `dt_trim` is the re-calibration hook of the drift controller
    (`repro.robust.drift`): a *known* static temperature bias [K] measured
    at trim time is subtracted from the required heater rise, so the
    programmed voltage compensates it exactly at the trim instant.
    """
    w = jnp.asarray(w)
    t_hi, t_lo = transmission_endpoints(p)
    wq = jnp.clip(w, p.q_min, p.q_max)
    td = t_lo + (wq - p.q_min) / p.q_rng * (t_hi - t_lo)          # invert Eq. (7)
    tdrop = 0.5 * (td + 1.0)                                       # invert Eq. (5)
    # invert Eq. (4): detuning magnitude; the ring sits red of lambda_ref and
    # moves further red with voltage, so lam = lambda_ref + det, det > 0.
    det = p.gamma * jnp.sqrt(jnp.maximum(1.0 / tdrop - 1.0, 0.0))
    lam = p.lambda_ref + det
    dl = lam - p.lambda_0                                          # shift from rest
    u = dl / p.lambda_0
    dt = p.n_eff * u / (p.beta * (1.0 - u))                        # invert Eq. (3) right
    dt = jnp.maximum(dt - dt_trim, 0.0)     # heater supplies what drift doesn't
    p_heater_mw = dt / p.r_thermal
    v2 = p_heater_mw / (p.kappa * 1e3) * p.r_heater                # invert Eq. (3) left
    return jnp.sqrt(jnp.maximum(v2, 0.0))


@partial(jax.jit, static_argnames=("p", "noise"))
def realize_weights(w_target, key: jax.Array | None = None,
                    p: MRRParams = DEFAULT_PARAMS,
                    noise: NoiseModel = IDEAL,
                    var: StaticVariation | None = None):
    """Program target weights onto MRRs and read back the noisy realization.

    This is THE core primitive of the paper's robustness analysis: the
    composition `weight_of_voltage(voltage_of_weight(w))` is the identity in
    the ideal case and a stochastically perturbed identity under per-shot
    DAC/thermal noise and/or a chip's static `var`.  Values outside
    [q_min, q_max] saturate (physical clipping).
    """
    v = voltage_of_weight(w_target, p)
    v = jnp.clip(v, p.v_min, p.v_max)
    return weight_of_voltage(v, p, noise, key, var)


@partial(jax.jit, static_argnames=("n_samples", "p", "noise"))
def _weight_noise_std(w_target, key, n_samples, p, noise):
    keys = jax.random.split(key, n_samples)
    samples = jax.vmap(lambda k: realize_weights(w_target, k, p, noise))(keys)
    return samples.std(axis=0)


def weight_noise_std(w_target, key: jax.Array, n_samples: int = 256,
                     p: MRRParams = DEFAULT_PARAMS,
                     noise: NoiseModel = PAPER_NOISE):
    """Monte-Carlo std of the realized weight around its target.

    Used by the mapping profiler to quantify how V->w gain (slope of the
    transfer curve) shapes noise: weights programmed on the steep part of the
    Lorentzian amplify voltage noise more than those near the tails.

    The sampler is jitted once with `n_samples` static — per-layer profiler
    loops reuse the compiled vmap instead of retracing it on every call.
    """
    if not isinstance(n_samples, int) or n_samples < 1:
        raise ValueError(f"n_samples must be a positive Python int (static "
                         f"under jit), got {n_samples!r}")
    return _weight_noise_std(w_target, key, n_samples, p, noise)


def transfer_curve(n: int = 256, p: MRRParams = DEFAULT_PARAMS):
    """(V, w) samples of the ideal transfer curve — Fig. 5(c) reproduction."""
    v = jnp.linspace(p.v_min, p.v_max, n)
    return v, weight_of_voltage(v, p)
