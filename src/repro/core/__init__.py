"""ROSA core: the paper's contribution as composable JAX modules.

  constants   device constants (Tables 2-3), modes, OPE configs
  mrr         noise-aware voltage->weight chain (Eqs. 3-8) + inverse
  quant       8-bit quantization, signed-digit / PAM plane decomposition
  osa         optical shift-and-add semantics (Eqs. 1-2) + non-idealities
  onn_linear  compat shim: rosa_matmul/RosaConfig now live in repro.rosa
  energy      event-count energy/latency/EDP model (Sec. 3.4)
  mapping     layer-wise hybrid IS/WS mapping (Sec. 3.5)
  dse         OPE array design-space exploration (Fig. 7)
"""

from repro.core import constants, dse, energy, mapping, mrr, onn_linear, osa, quant  # noqa: F401
