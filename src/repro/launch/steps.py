"""Step-function factories shared by the train driver and the dry-run."""

from __future__ import annotations

import jax

from repro.distributed import compress as C
from repro.models.model import ModelBundle
from repro.optim import AdamWConfig, adamw_init, adamw_update


def make_train_step(bundle: ModelBundle, opt_cfg: AdamWConfig,
                    grad_compress: bool = False):
    """-> train_step(params, opt_state, batch) -> (params, opt, metrics).

    With grad_compress=True the gradient is cast to bf16 (with f32 error
    feedback carried in opt_state["err"]) BEFORE the data-parallel
    all-reduce — XLA then reduces half the bytes over the pod/data axes.
    """

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: bundle.train_loss(p, batch))(params)
        if grad_compress:
            g16, err = C.compress(grads, opt_state["err"])
            grads = C.decompress(g16)
            opt_state = dict(opt_state, err=err)
        new_params, new_inner, metrics = adamw_update(
            params, grads, opt_state["adam"], opt_cfg)
        metrics["loss"] = loss
        return new_params, dict(opt_state, adam=new_inner), metrics

    return train_step


def init_opt_state(params, grad_compress: bool = False) -> dict:
    st = {"adam": adamw_init(params)}
    if grad_compress:
        st["err"] = C.init_error_state(params)
    return st


def opt_state_shardings(param_sh, grad_compress: bool = False):
    """Moments/err shard like their params; the step counter replicates."""
    mesh = jax.tree.leaves(param_sh)[0].mesh
    from jax.sharding import NamedSharding, PartitionSpec
    rep = NamedSharding(mesh, PartitionSpec())
    st = {"adam": {"mu": param_sh, "nu": param_sh, "step": rep}}
    if grad_compress:
        st["err"] = param_sh
    return st
