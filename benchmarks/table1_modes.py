"""Table 1 reproduction: analog vs digital vs mixed computing modes.

Throughput / update-time / OPS formulas evaluated on the paper's array
sizes, plus the energy model's view of one representative conv layer under
each mode (robustness column comes from the behavioural sims — see
table4_hybrid).
"""

from __future__ import annotations

from repro.core import constants as C
from repro.core import energy as E
from repro.core.constants import ComputeMode, OPEConfig

LAYER = E.LayerShape("conv3", m=64, k=1728, n=384)


def run(verbose: bool = True) -> dict:
    ope = OPEConfig(rows=8, cols=8, tiles=16)
    rows = {}
    for mode, name in [(ComputeMode.ANALOG, "analog (DEAP-CNNs)"),
                       (ComputeMode.DIGITAL, "digital (HolyLight)"),
                       (ComputeMode.MIXED, "mixed (ROSA)")]:
        ops = {ComputeMode.ANALOG: E.ops_analog,
               ComputeMode.DIGITAL: E.ops_digital,
               ComputeMode.MIXED: E.ops_mixed}[mode](ope)
        bd = E.layer_energy(LAYER, ope, mode=mode)
        rows[mode.value] = dict(name=name, ops=ops, latency=bd.latency,
                                energy=bd.energy, edp=bd.edp,
                                oadc_energy=bd.adc + bd.pd_tia)
    if verbose:
        print(f"{'mode':22s} {'OPS':>12s} {'latency[s]':>12s} "
              f"{'energy[J]':>12s} {'EDP[J*s]':>12s} {'OADC[J]':>10s}")
        for r in rows.values():
            print(f"{r['name']:22s} {r['ops']:12.3e} {r['latency']:12.3e} "
                  f"{r['energy']:12.3e} {r['edp']:12.3e} "
                  f"{r['oadc_energy']:10.3e}")
        mx, an = rows["mixed"], rows["analog"]
        print(f"\nmixed vs analog: {an['latency'] / mx['latency']:.0f}x "
              f"faster, OPS x{mx['ops'] / an['ops']:.1f}")
    return rows


if __name__ == "__main__":
    run()
