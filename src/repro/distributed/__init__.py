"""Distribution layer: logical sharding rules, gradient compression,
collective helpers, elastic/straggler policy hooks."""
