"""deepseek-67b [arXiv:2401.02954]. Llama-arch dense 95L d_model=8192
64H (GQA kv=8) d_ff=22016 vocab=102400."""

from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b",
    family="dense",
    n_layers=95,
    d_model=8192,
    vocab=102400,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    rope_theta=1e4,
)

SMOKE = ModelConfig(
    name="deepseek-67b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    vocab=256,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
)
