"""Vectorized perturb-one-layer sensitivity profiling (paper Fig. 6).

The serial protocol (`training.cnn_train.layer_noise_profile`) re-jits one
forward per (layer, mapping, MC draw): O(2·L·n_mc) compilations and
evaluations.  Here "which single layer runs the noisy analog path" becomes
a *traced* one-hot gate vector blended inside `rosa.backends`, so ONE
jitted call per mapping evaluates the whole (chips x layers) grid:

    accs[c, l] = accuracy with ONLY layer l analog-noisy on chip c

Degradations are Monte-Carlo averages over the chip ensemble (static
variation + per-shot noise), and feed `mapping.LayerProfile.d_is/d_ws`
directly — the accuracy-aware hybrid search needs no per-model callback
plumbing anymore.  Models without labels (LM stacks in the zoo) profile on
clean-logit agreement instead, through the same code path.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import rosa
from repro.core import energy as E
from repro.core import mapping as M
from repro.core import mrr
from repro.core.constants import Mapping, OPEConfig
from repro.robust import variation as V
from repro.robust.ensemble import (ApplyFn, chunk_eval_set,
                                   chunked_argmax_preds, clean_reference,
                                   cnn_apply_fn, cnn_eval_set)

_D_CLIP = 0.0   # degradations are reported as max(clean - acc, 0), like
#                 the serial profiler


def degradation_matrix(apply_fn: ApplyFn, params, x, y,
                       layer_names: Sequence[str],
                       base_cfg: rosa.RosaConfig,
                       ensemble: V.Chip, key: jax.Array, *,
                       noise: mrr.NoiseModel = mrr.PAPER_NOISE,
                       mappings: Sequence[Mapping] = (Mapping.IS, Mapping.WS),
                       eval_batch: int = 128) -> dict[str, dict[str, float]]:
    """{layer: {mapping.value: degradation_pp}} over the chip ensemble.

    One jitted vmap-over-(chips x layers) call per mapping.  `y=None`
    scores clean-logit agreement (label-free profiling).
    """
    names = list(layer_names)
    n_layers = len(names)
    n_chips = V.ensemble_size(ensemble)
    keys = jax.random.split(key, n_chips)
    eye = jnp.eye(n_layers)

    out: dict[str, dict[str, float]] = {n: {} for n in names}
    for mp in mappings:
        cfg = dataclasses.replace(base_cfg, mapping=mp, noise=noise)
        engine = rosa.Engine(rosa.ExecutionPlan.build(cfg, None, names))
        clean_cfg = dataclasses.replace(base_cfg, mapping=mp,
                                        noise=mrr.IDEAL)
        clean_engine = rosa.Engine(
            rosa.ExecutionPlan.build(clean_cfg, None, names))

        @jax.jit
        def run(params, x, y, ens, keys, engine=engine,
                clean_engine=clean_engine):
            xb = chunk_eval_set(x, eval_batch)
            clean_pred = chunked_argmax_preds(apply_fn, params, xb,
                                              clean_engine)
            ref = clean_pred if y is None else y[:clean_pred.shape[0]]
            clean_acc = 100.0 * jnp.mean(clean_pred == ref)

            def one_chip(var, k):
                def one_layer(onehot):
                    gates = {n: onehot[i] for i, n in enumerate(names)}
                    e = engine.with_variation(var).with_gates(gates) \
                        .with_key(k)
                    return chunked_argmax_preds(apply_fn, params, xb, e)
                preds = jax.vmap(one_layer)(eye)       # (L, n_eval)
                return 100.0 * jnp.mean(preds == ref[None, :], axis=1)

            accs = jax.vmap(one_chip)(ens, keys)       # (n_chips, L)
            return clean_acc, accs

        clean_acc, accs = run(params, x, y, ensemble, keys)
        mean_accs = np.asarray(accs).mean(axis=0)      # MC over chips
        for i, n in enumerate(names):
            out[n][mp.value] = max(float(clean_acc) - float(mean_accs[i]),
                                   _D_CLIP)
    return out


def plan_search(apply_fn: ApplyFn, params, x, y,
                layer_names: Sequence[str],
                base_cfg: rosa.RosaConfig,
                ensemble: V.Chip, key: jax.Array,
                candidates: np.ndarray, *,
                noise: mrr.NoiseModel = mrr.PAPER_NOISE,
                eval_batch: int = 64) -> np.ndarray:
    """MC-evaluate a whole batch of hybrid-plan candidates in ONE jitted
    call.

    `candidates` is a (P, L) binary matrix (row p, column l: layer l runs
    IS when 1, WS when 0).  Each layer's WS/IS orientation is superposed
    behind a traced mapping gate (`rosa_matmul`'s `mgate`), so the plan
    axis vmaps like any other batch axis — P plans x n_chips ensemble
    forwards per call, identical PRNG draws across plans.  Returns the
    (P,) ensemble-mean accuracies [%]; `y=None` scores clean-logit
    agreement (label-free zoo workloads).
    """
    names = list(layer_names)
    n_chips = V.ensemble_size(ensemble)
    keys = jax.random.split(key, n_chips)
    cand = jnp.asarray(candidates, dtype=jnp.float32)
    cfg = dataclasses.replace(base_cfg, mapping=Mapping.WS, noise=noise)
    engine = rosa.Engine(rosa.ExecutionPlan.build(cfg, None, names))
    clean_engine = clean_reference(engine)

    @jax.jit
    def run(params, x, y, ens, keys, cand):
        xb = chunk_eval_set(x, eval_batch)
        ref = y[:xb.shape[0] * xb.shape[1]] if y is not None \
            else chunked_argmax_preds(apply_fn, params, xb, clean_engine)

        def one_plan(sel):
            mgates = {n: sel[i] for i, n in enumerate(names)}

            def one_chip(var, k):
                e = engine.with_variation(var).with_key(k) \
                    .with_mapping_gates(mgates)
                preds = chunked_argmax_preds(apply_fn, params, xb, e)
                return 100.0 * jnp.mean(preds == ref)

            return jnp.mean(jax.vmap(one_chip)(ens, keys))

        return jax.vmap(one_plan)(cand)

    return np.asarray(run(params, x, y, ensemble, keys, cand))


def searched_hybrid_plan(profiles: Sequence[M.LayerProfile],
                         apply_fn: ApplyFn, params, x, y,
                         base_cfg: rosa.RosaConfig,
                         ensemble: V.Chip, key: jax.Array, *,
                         noise: mrr.NoiseModel = mrr.PAPER_NOISE,
                         max_extra_pp: float = 0.5,
                         max_candidates: int = 6,
                         eval_batch: int = 64
                         ) -> tuple[dict[str, Mapping], dict]:
    """Accuracy-verified hybrid search: profile-guided candidate ordering,
    MC-verified in one vectorized call.

    Single-layer degradations under-estimate full-plan cost (noise
    compounds across layers), so instead of trusting the profile the
    search MC-evaluates nested IS-prefix plans — always including the pure
    WS row — over the chip ensemble and keeps the most IS-aggressive plan
    that attains the best measured accuracy.  By construction the result
    matches or beats pure WS under the search keys (Table-4 direction).
    """
    names = [p.name for p in profiles]
    by_name = {p.name: p for p in profiles}
    # IS-flip attractiveness: robustness gain first, then EDP leverage
    eligible = [p.name for p in profiles
                if p.d_is <= p.d_ws + max_extra_pp]
    order = sorted(eligible,
                   key=lambda n: (by_name[n].d_is - by_name[n].d_ws)
                   + 0.5 * np.log(max(by_name[n].e_is, 1e-30)
                                  / max(by_name[n].e_ws, 1e-30)))
    order = order[:max_candidates]
    cand = np.zeros((len(order) + 1, len(names)), dtype=np.float32)
    for k, layer in enumerate(order):
        cand[k + 1:, names.index(layer)] = 1.0

    accs = plan_search(apply_fn, params, x, y, names, base_cfg, ensemble,
                       key, cand, noise=noise, eval_batch=eval_batch)
    best = accs.max()
    # most IS-aggressive among the exact-best rows (EDP tie-break)
    p_star = int(max(np.flatnonzero(accs >= best)))
    plan = {layer: Mapping.IS for layer in order[:p_star]}
    info = {"order": order, "accs": accs.tolist(),
            "ws_acc": float(accs[0]), "chosen_acc": float(accs[p_star]),
            "n_is": p_star}
    return plan, info


def accuracy_guarded_plan(profiles: Sequence[M.LayerProfile],
                          max_extra_pp: float = 0.5
                          ) -> dict[str, Mapping]:
    """Accuracy-aware hybrid plan: the balanced-metric argmin
    (`mapping.choose_mapping`), vetoed whenever its degradation exceeds the
    layer's best mapping by more than `max_extra_pp` — then the more robust
    mapping wins.  Under Monte-Carlo degradations with strong static
    variation the raw paper metric can trade tens of pp for EDP (its alpha
    term grows only logarithmically); the guard keeps the Table-4 direction
    (hybrid accuracy >= WS) while still harvesting EDP wherever it is
    accuracy-free."""
    plan: dict[str, Mapping] = {}
    for p in profiles:
        m = M.choose_mapping(p)
        if p.d(m) > min(p.d_is, p.d_ws) + max_extra_pp:
            m = Mapping.IS if p.d_is < p.d_ws else Mapping.WS
        plan[p.name] = m
    return plan


def profile_layers_mc(layers: Sequence[E.LayerShape], ope: OPEConfig,
                      degradation: dict[str, dict[str, float]], *,
                      batch: int = 1, **kwargs) -> list[M.LayerProfile]:
    """Join a Monte-Carlo degradation matrix with the vectorized EDP model
    into `mapping.LayerProfile`s — drop-in input for `hybrid_plan`."""
    return M.profile_layers_fast(
        layers, ope,
        degradation_fn=M.degradation_fn_from_matrix(degradation),
        batch=batch, **kwargs)


# ---------------------------------------------------------------------------
# CNN front-end
# ---------------------------------------------------------------------------
def cnn_degradation_matrix(params, model: str, *,
                           n_chips: int = 16,
                           key: jax.Array | None = None,
                           noise: mrr.NoiseModel = mrr.PAPER_NOISE,
                           var_model: V.VariationModel = V.PAPER_VARIATION,
                           ensemble: V.Chip | None = None,
                           n_eval: int = 256,
                           eval_batch: int = 128
                           ) -> dict[str, dict[str, float]]:
    """Degradation matrix of a lite CNN over a freshly sampled (or given)
    chip ensemble."""
    from repro.models.cnn import LITE_MODELS
    from repro.training.cnn_train import QAT_CFG

    key = key if key is not None else jax.random.PRNGKey(42)
    k_ens, k_mc = jax.random.split(key)
    names = [s.name for s in LITE_MODELS[model]]
    if ensemble is None:
        ensemble = V.sample_ensemble(k_ens, n_chips,
                                     V.cnn_lane_dims(model), var_model)
    x, y = cnn_eval_set(n_eval)
    return degradation_matrix(cnn_apply_fn(model), params, x, y, names,
                              QAT_CFG, ensemble, k_mc, noise=noise,
                              eval_batch=eval_batch)


def searched_cnn_hybrid_plan(profiles: Sequence[M.LayerProfile], params,
                             model: str, ensemble: V.Chip,
                             key: jax.Array, *,
                             noise: mrr.NoiseModel = mrr.PAPER_NOISE,
                             n_eval: int = 256, eval_batch: int = 64,
                             **kwargs) -> tuple[dict[str, Mapping], dict]:
    """`searched_hybrid_plan` on a lite CNN's synth-CIFAR evaluation set."""
    from repro.training.cnn_train import QAT_CFG

    x, y = cnn_eval_set(n_eval)
    return searched_hybrid_plan(profiles, cnn_apply_fn(model), params, x, y,
                                QAT_CFG, ensemble, key, noise=noise,
                                eval_batch=eval_batch, **kwargs)


def cnn_profiles_mc(params, model: str, ope: OPEConfig, *,
                    batch: int = 128,
                    **kwargs) -> list[M.LayerProfile]:
    """End to end: MC degradation matrix + full-size EDP rows -> profiles
    for the layers that exist in both the lite model and the paper table."""
    from repro.configs.paper_cnns import CNN_WORKLOADS

    deg = cnn_degradation_matrix(params, model, **kwargs)
    rows = [l for l in CNN_WORKLOADS[model] if l.name in deg]
    return profile_layers_mc(rows, ope, deg, batch=batch)
