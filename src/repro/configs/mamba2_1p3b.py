"""mamba2-1.3b [arXiv:2405.21060]. Attention-free SSD: 48L d_model=2048
(d_inner=4096, 64 heads x P=64, d_state=128, conv 4), vocab=50280, tied.

long_500k RUNS: O(1) decode state, no KV cache."""

from repro.models.ssm import SSMConfig
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    vocab=50280,
    ssm=SSMConfig(d_model=2048, d_state=128, head_dim=64, expand=2,
                  n_groups=1, d_conv=4, chunk=128),
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2-smoke",
    family="ssm",
    n_layers=2,
    d_model=64,
    vocab=256,
    ssm=SSMConfig(d_model=64, d_state=16, head_dim=16, expand=2,
                  n_groups=1, d_conv=4, chunk=8),
    tie_embeddings=True,
)
