"""Hierarchical span tracer with Chrome-trace JSON export.

One `Tracer` collects timestamped events — duration spans, instants,
counter samples, and async request-lifecycle markers — and serializes them
as a Chrome trace (the ``traceEvents`` JSON format) loadable in Perfetto
(https://ui.perfetto.dev) or ``chrome://tracing``.

Design constraints, in order:

1. **Zero-cost when disabled.**  Instrumented code calls the module-level
   helpers (`span`, `instant`, `counter`, ...), which consult a
   `contextvars.ContextVar` — exactly the ambient-engine pattern of
   `rosa.engine_context` — and collapse to a shared no-op when no tracer
   is installed.  The `obs_overhead` bench gates the residual overhead.
2. **Thread/task safety.**  Installation is context-local (`tracing`),
   event emission is lock-guarded, and span nesting needs no explicit
   stack: complete ("X") events nest by time containment per (pid, tid),
   which Perfetto renders — and `repro.obs.cli` re-derives — directly.
3. **Exception safety.**  A span is emitted from a ``finally`` block with
   its real duration even when the body raises; the raising span is
   annotated with the exception type so failed stages are visible on the
   timeline.

Usage::

    tracer = Tracer()
    with tracing(tracer):
        with span("rosa.compile", cat="compile"):
            ...
    tracer.save("out.trace.json")        # load in Perfetto
"""

from __future__ import annotations

import contextlib
import contextvars
import functools
import json
import os
import threading
import time
from typing import Any, Callable

_TRACER_VAR: contextvars.ContextVar["Tracer | None"] = \
    contextvars.ContextVar("repro_obs_tracer", default=None)


def current_tracer() -> "Tracer | None":
    """The innermost tracer installed by `tracing`, or None when disabled."""
    return _TRACER_VAR.get()


def enabled() -> bool:
    """Whether a tracer is currently installed (cheap per-tick guard)."""
    return _TRACER_VAR.get() is not None


@contextlib.contextmanager
def tracing(tracer: "Tracer | None"):
    """Install `tracer` as the ambient tracer for the dynamic extent.

    Context-local (thread- and task-safe), nestable; ``tracing(None)``
    explicitly DISABLES tracing inside the block — the `obs_overhead`
    bench uses that to measure the no-op path under an outer tracer.
    """
    token = _TRACER_VAR.set(tracer)
    try:
        yield tracer
    finally:
        _TRACER_VAR.reset(token)


class Tracer:
    """An append-only event collector with a perf_counter timebase.

    ``clock`` is injectable (tests pass a deterministic fake); timestamps
    are microseconds relative to the tracer's construction epoch, which is
    what the Chrome trace format expects.
    """

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self._clock = clock
        self._epoch = clock()
        self._lock = threading.Lock()
        # spans are stored as raw tuples and materialized to Chrome dicts
        # only at export — emission is the hot path, export is not
        self._events: "list[dict | tuple]" = []
        self._pid = os.getpid()
        self._thread_names: dict[int, str] = {}
        self.wall_epoch = time.time()

    # -- timebase ------------------------------------------------------------
    def now_us(self) -> float:
        """Microseconds since the tracer epoch (the event timebase)."""
        return (self._clock() - self._epoch) * 1e6

    # -- low-level emission --------------------------------------------------
    def _emit(self, ev: dict) -> None:
        tid = ev.setdefault("tid", threading.get_ident())
        ev.setdefault("pid", self._pid)
        with self._lock:
            if tid not in self._thread_names:
                self._thread_names[tid] = threading.current_thread().name
            self._events.append(ev)

    def _append(self, tup: tuple) -> None:
        """Append one raw (un-materialized) event tuple — the hot path.

        Tuple layouts, discriminated by the leading Chrome phase char:

        * ``("X", name, cat, t0, t1, args, err, tid)`` — span; t0/t1 are
          RAW clock readings, converted to µs-since-epoch at export
        * ``("C", name, cat, traw, values, tid)`` — counter sample
        * ``("i", name, cat, traw, args, tid)`` — instant
        * ``("b"|"n"|"e", name, cat, traw, id, args, tid)`` — async
        """
        tid = tup[-1]
        with self._lock:
            if tid not in self._thread_names:
                self._thread_names[tid] = threading.current_thread().name
            self._events.append(tup)

    def _materialize(self, ev: tuple) -> dict:
        epoch, pid = self._epoch, self._pid
        ph = ev[0]
        if ph == "X":
            _, name, cat, t0, t1, args, err, tid = ev
            if err is not None:
                args = {**args, "error": err}
            d = {"name": name, "cat": cat, "ph": "X",
                 "ts": (t0 - epoch) * 1e6, "dur": (t1 - t0) * 1e6,
                 "tid": tid, "pid": pid}
        elif ph == "C":
            _, name, cat, traw, args, tid = ev
            return {"name": name, "cat": cat, "ph": "C",
                    "ts": (traw - epoch) * 1e6, "args": args,
                    "tid": tid, "pid": pid}
        elif ph == "i":
            _, name, cat, traw, args, tid = ev
            d = {"name": name, "cat": cat, "ph": "i",
                 "ts": (traw - epoch) * 1e6, "s": "t",
                 "tid": tid, "pid": pid}
        else:                                   # async: b / n / e
            _, name, cat, traw, sid, args, tid = ev
            d = {"name": name, "cat": cat, "ph": ph, "id": sid,
                 "ts": (traw - epoch) * 1e6, "tid": tid, "pid": pid}
        if args:
            d["args"] = args
        return d

    @property
    def events(self) -> list[dict]:
        """Snapshot of the recorded events as Chrome dicts (a copy —
        safe to mutate).  Thread-name "M" metadata events lead."""
        with self._lock:
            raw = list(self._events)
            names = dict(self._thread_names)
        out: list[dict] = [
            {"name": "thread_name", "ph": "M", "pid": self._pid, "tid": tid,
             "args": {"name": nm}} for tid, nm in names.items()]
        for ev in raw:
            out.append(self._materialize(ev) if type(ev) is tuple else ev)
        return out

    def __len__(self) -> int:
        with self._lock:
            return len(self._events) + len(self._thread_names)

    # -- spans ---------------------------------------------------------------
    def span(self, name: str, cat: str = "", **args: Any) -> "_SpanCtx":
        """Record a complete ("X") event around the block.

        Emitted from ``__exit__`` so a raising body still produces a
        correctly-bounded span, annotated with the exception type.
        """
        return _SpanCtx(self, name, cat or "span", args)

    def instant(self, name: str, cat: str = "", **args: Any) -> None:
        """Record a thread-scoped instant ("i") event."""
        self._append(("i", name, cat or "instant", self._clock(), args,
                      threading.get_ident()))

    # -- counters ------------------------------------------------------------
    def counter(self, name: str, value: "float | int | dict",
                cat: str = "counter") -> None:
        """Record a counter ("C") sample — one Perfetto track per `name`.

        `value` may be a scalar (series ``value``) or a dict of series.
        """
        args = dict(value) if isinstance(value, dict) else {"value": value}
        self._append(("C", name, cat, self._clock(), args,
                      threading.get_ident()))

    # -- async (request-lifecycle) events ------------------------------------
    def async_begin(self, name: str, id: "int | str", cat: str = "async",
                    **args: Any) -> None:
        """Open an async track item (Perfetto pairs by (cat, id, name))."""
        self._async("b", name, id, cat, args)

    def async_instant(self, name: str, id: "int | str", cat: str = "async",
                      **args: Any) -> None:
        """Mark an instant on an open async track item."""
        self._async("n", name, id, cat, args)

    def async_end(self, name: str, id: "int | str", cat: str = "async",
                  **args: Any) -> None:
        """Close an async track item opened by `async_begin`."""
        self._async("e", name, id, cat, args)

    def _async(self, ph: str, name: str, id, cat: str, args: dict) -> None:
        self._append((ph, name, cat, self._clock(), str(id), args,
                      threading.get_ident()))

    # -- export --------------------------------------------------------------
    def to_chrome(self) -> dict:
        """The Chrome trace document (``{"traceEvents": [...]}``)."""
        return {"traceEvents": self.events,
                "displayTimeUnit": "ms",
                "otherData": {"wall_epoch_s": self.wall_epoch}}

    def save(self, path) -> None:
        """Serialize `to_chrome()` as JSON at `path`."""
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f, separators=(",", ":"))
            f.write("\n")


class _SpanCtx:
    """A hand-rolled span context manager.

    This is the hot path of the tracer (one instance per span, several per
    scheduler tick), so it avoids ``contextlib.contextmanager``'s generator
    machinery — that alone is ~3x the cost of the whole emission.
    """

    __slots__ = ("_tr", "_name", "_cat", "_args", "_t0")

    def __init__(self, tr: Tracer, name: str, cat: str, args: dict):
        self._tr = tr
        self._name = name
        self._cat = cat
        self._args = args

    def __enter__(self) -> Tracer:
        self._t0 = self._tr._clock()        # raw clock; converted at export
        return self._tr

    def __exit__(self, etype, exc, tb) -> bool:
        tr = self._tr
        tr._append(("X", self._name, self._cat, self._t0, tr._clock(),
                    self._args, None if etype is None else etype.__name__,
                    threading.get_ident()))
        return False


# ---------------------------------------------------------------------------
# Module-level helpers — the zero-cost-when-disabled instrumentation API
# ---------------------------------------------------------------------------
_NULL_SPAN = contextlib.nullcontext()


def span(name: str, cat: str = "", **args: Any):
    """`Tracer.span` on the ambient tracer, or a shared no-op context."""
    tr = _TRACER_VAR.get()
    return _NULL_SPAN if tr is None else _SpanCtx(tr, name, cat or "span", args)


def instant(name: str, cat: str = "", **args: Any) -> None:
    """`Tracer.instant` on the ambient tracer; no-op when disabled."""
    tr = _TRACER_VAR.get()
    if tr is not None:
        tr.instant(name, cat, **args)


def counter(name: str, value: "float | int | dict",
            cat: str = "counter") -> None:
    """`Tracer.counter` on the ambient tracer; no-op when disabled."""
    tr = _TRACER_VAR.get()
    if tr is not None:
        tr.counter(name, value, cat)


def async_begin(name: str, id: "int | str", cat: str = "async",
                **args: Any) -> None:
    """`Tracer.async_begin` on the ambient tracer; no-op when disabled."""
    tr = _TRACER_VAR.get()
    if tr is not None:
        tr.async_begin(name, id, cat, **args)


def async_instant(name: str, id: "int | str", cat: str = "async",
                  **args: Any) -> None:
    """`Tracer.async_instant` on the ambient tracer; no-op when disabled."""
    tr = _TRACER_VAR.get()
    if tr is not None:
        tr.async_instant(name, id, cat, **args)


def async_end(name: str, id: "int | str", cat: str = "async",
              **args: Any) -> None:
    """`Tracer.async_end` on the ambient tracer; no-op when disabled."""
    tr = _TRACER_VAR.get()
    if tr is not None:
        tr.async_end(name, id, cat, **args)


def traced(name: str | None = None, cat: str = ""):
    """Decorator form of `span` (span name defaults to the qualname)."""
    def deco(fn):
        label = name or fn.__qualname__

        @functools.wraps(fn)
        def wrapped(*a, **kw):
            with span(label, cat):
                return fn(*a, **kw)

        return wrapped
    return deco
