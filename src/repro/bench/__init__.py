"""Machine-readable benchmark harness.

`benchmarks/run.py` executes the paper table/figure benches and serializes
one `BenchReport` per run as ``BENCH_<n>.json`` at the repo root; this
package owns the schema (`repro.bench.schema`) and the regression gate
(`repro.bench.compare`, also a CLI: ``python -m repro.bench.compare``).

    from repro import bench
    report = bench.load("BENCH_2.json")
    verdict = bench.compare_reports(baseline, report)
    sys.exit(0 if verdict.ok else 1)
"""

from repro.bench.schema import (SCHEMA_VERSION, BenchReport, BenchResult,
                                Metric, load, next_bench_path, save, validate)

# The submodule is named `compare` and so is its main function.  Its names
# are re-exported lazily (PEP 562) so `repro.bench.compare` keeps resolving
# to the module and `python -m repro.bench.compare` doesn't warn about the
# package pre-importing its own CLI module.
_COMPARE_EXPORTS = {"CompareResult": "CompareResult",
                    "MetricVerdict": "MetricVerdict",
                    "compare_reports": "compare"}


def __getattr__(name: str):
    if name in _COMPARE_EXPORTS:
        from repro.bench import compare as _compare
        return getattr(_compare, _COMPARE_EXPORTS[name])
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "SCHEMA_VERSION",
    "BenchReport",
    "BenchResult",
    "CompareResult",
    "Metric",
    "MetricVerdict",
    "compare_reports",
    "load",
    "next_bench_path",
    "save",
    "validate",
]
