from repro.checkpoint.checkpoint import (CheckpointManager, latest_step,  # noqa
                                         read_meta, restore, save)
