"""Regression gate: baseline vs. current ``BENCH_<n>.json``.

Per gated metric the verdict is:

  * ``direction="lower_is_better"``  — regression when current exceeds
    baseline by more than `rel_tol` relative;
  * ``direction="higher_is_better"`` — regression when current falls short
    of baseline by more than `rel_tol` relative;
  * ``direction="both"``             — regression when |current-baseline|
    drifts past `rel_tol` relative (deterministic reproduction metrics);
  * string values                    — regression on any mismatch (e.g. the
    DSE winner's config label).

A gated metric present in the baseline but missing from the current report
is a regression (a silently dropped bench must not pass CI), as is any
current bench with ``status: failed``.  Tolerances come from the *baseline*
metric (the committed file is the contract); `--rel-tol` scales them all.

CLI (non-zero exit on regression):

    PYTHONPATH=src python -m repro.bench.compare benchmarks/baseline.json \\
        BENCH_2.json
"""

from __future__ import annotations

import argparse
import dataclasses
import sys

from repro.bench.schema import BenchReport, Metric, load


@dataclasses.dataclass
class MetricVerdict:
    bench: str
    metric: str
    baseline: float | int | str
    current: float | int | str | None
    rel_delta: float | None         # None for strings / missing
    rel_tol: float
    direction: str
    ok: bool
    note: str = ""

    @property
    def key(self) -> str:
        return f"{self.bench}.{self.metric}"


@dataclasses.dataclass
class CompareResult:
    verdicts: list[MetricVerdict]
    failed_benches: list[str]       # current benches with status=failed
    mode_mismatch: str = ""         # set when baseline/current modes differ

    @property
    def regressions(self) -> list[MetricVerdict]:
        return [v for v in self.verdicts if not v.ok]

    @property
    def ok(self) -> bool:
        return (not self.regressions and not self.failed_benches
                and not self.mode_mismatch)


def _judge(base: Metric, cur: Metric | None, bench: str,
           tol_scale: float) -> MetricVerdict:
    tol = base.rel_tol * tol_scale
    kw = dict(bench=bench, metric=base.name, baseline=base.value,
              rel_tol=tol, direction=base.direction)
    if cur is None:
        return MetricVerdict(current=None, rel_delta=None, ok=False,
                             note="gated metric missing from current", **kw)
    if isinstance(base.value, str) or isinstance(cur.value, str):
        ok = base.value == cur.value
        return MetricVerdict(current=cur.value, rel_delta=None, ok=ok,
                             note="" if ok else "value mismatch", **kw)
    denom = abs(base.value) if base.value else 1.0
    delta = (cur.value - base.value) / denom
    if base.direction == "lower_is_better":
        ok = delta <= tol
    elif base.direction == "higher_is_better":
        ok = delta >= -tol
    else:
        ok = abs(delta) <= tol
    return MetricVerdict(current=cur.value, rel_delta=delta, ok=ok,
                         note="" if ok else "outside tolerance", **kw)


def compare(baseline: BenchReport, current: BenchReport,
            tol_scale: float = 1.0) -> CompareResult:
    """Judge every gated baseline metric against the current report."""
    if baseline.mode != current.mode:
        # quick and full runs gate different bench scopes (e.g. table4's
        # n_models); comparing across modes produces spurious regressions,
        # so fail loudly instead of confusingly.
        return CompareResult(
            verdicts=[], failed_benches=[],
            mode_mismatch=f"baseline is a {baseline.mode!r} run but current "
                          f"is {current.mode!r} — regenerate the baseline "
                          f"in the same mode")
    verdicts = []
    for (bench, _), base_m in baseline.gated_metrics().items():
        cur_r = current.result(bench)
        cur_m = cur_r.metric(base_m.name) if cur_r is not None else None
        verdicts.append(_judge(base_m, cur_m, bench, tol_scale))
    failed = [r.name for r in current.results if r.status == "failed"]
    return CompareResult(verdicts=verdicts, failed_benches=failed)


def format_result(res: CompareResult) -> str:
    if res.mode_mismatch:
        return f"MODE MISMATCH: {res.mode_mismatch} -> FAIL"
    lines = [f"{'metric':44s} {'baseline':>12s} {'current':>12s} "
             f"{'delta':>8s} {'tol':>6s}  verdict"]
    for v in res.verdicts:
        if isinstance(v.baseline, str) or v.current is None:
            base_s, cur_s, d_s = str(v.baseline)[:12], str(v.current)[:12], "-"
        else:
            base_s = f"{v.baseline:12.5g}"
            cur_s = f"{v.current:12.5g}"
            d_s = f"{v.rel_delta * 100:+.2f}%"
        mark = "ok" if v.ok else f"REGRESSION ({v.note})"
        lines.append(f"{v.key:44s} {base_s:>12s} {cur_s:>12s} "
                     f"{d_s:>8s} {v.rel_tol * 100:5.1f}%  {mark}")
    for b in res.failed_benches:
        lines.append(f"{b:44s} {'-':>12s} {'-':>12s} {'-':>8s} {'':>6s}  "
                     f"FAILED in current run")
    lines.append(f"\n{len(res.verdicts)} gated metrics, "
                 f"{len(res.regressions)} regressions, "
                 f"{len(res.failed_benches)} failed benches -> "
                 + ("PASS" if res.ok else "FAIL"))
    return "\n".join(lines)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="Compare two BENCH_<n>.json reports; exit 1 on "
                    "regression.")
    ap.add_argument("baseline", help="committed baseline report")
    ap.add_argument("current", help="freshly produced report")
    ap.add_argument("--rel-tol", type=float, default=1.0, metavar="SCALE",
                    help="scale every metric's tolerance (default 1.0)")
    args = ap.parse_args(argv)

    res = compare(load(args.baseline), load(args.current),
                  tol_scale=args.rel_tol)
    print(format_result(res))
    return 0 if res.ok else 1


if __name__ == "__main__":
    sys.exit(main())
