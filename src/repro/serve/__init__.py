"""repro.serve — continuous-batching serving over the optical Engine.

The subsystem promotes `launch/serve.py` from a one-shot script to a
scheduler-driven serving stack:

  `ServeConfig`      slots / cache capacity / prefill chunking / sampling /
                     optical-engine knobs (frozen, jit-closure safe)
  `Scheduler`        slot-based continuous batching: per-tick prefill
                     chunks, in-step slot eviction + refill on a DONATED
                     paged KV cache, deterministic tick accounting; also
                     runs the static-batching "oneshot" baseline policy
  `run_sequential`   the per-request oracle the differential test suite
                     (tests/test_serve.py) pins the scheduler against —
                     greedy streams must match BIT-exactly
  `poisson_requests` reproducible synthetic load (Poisson arrivals)
  `smoke_report`     the gated `serve_smoke` bench: throughput (step
                     units), latency percentiles (ticks), continuous vs
                     one-shot ratio, per-token energy from the ledger

Sampling keys fold (request id, token index) from one base seed, so a
request's stream is invariant to scheduling — the property that makes
serving testable at all.

`repro.serve.adaptive` closes the drift loop on top of this stack: a
`TickHook` injects per-tick thermal residuals into the decode step, and a
probe/detector/controller pipeline re-trims or re-plans the serving
`rosa.Program` mid-traffic without dropping requests (see
docs/adaptive-serving.md).
"""

from repro.serve.config import ServeConfig, serving_model_config
from repro.serve.decode import (DecodeState, PrefillTask, init_state,
                                make_admit, make_admit_step, make_chunk_fn,
                                make_evict, make_serve_step, null_admit,
                                sample_token)
from repro.serve.loadgen import poisson_requests
from repro.serve.metrics import (build_serving_engine, energy_metrics,
                                 report_metrics, smoke_report)
from repro.serve.scheduler import (Completion, EmptyStat, Request,
                                   Scheduler, ServeReport, TickHook,
                                   run_sequential)

__all__ = [
    "Completion", "DecodeState", "EmptyStat", "PrefillTask", "Request",
    "Scheduler", "ServeConfig", "ServeReport", "TickHook",
    "build_serving_engine", "energy_metrics", "init_state", "make_admit",
    "make_admit_step", "make_chunk_fn", "make_evict", "make_serve_step",
    "null_admit", "poisson_requests", "report_metrics", "run_sequential",
    "sample_token", "serving_model_config", "smoke_report",
]
