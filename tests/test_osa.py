"""Optical shift-and-add semantics (paper Eqs. 1-2, Sec. 3.1)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import osa, quant
from repro.rosa import RosaConfig, rosa_matmul
from repro.core import mrr
from repro.core.constants import ComputeMode, Mapping


@pytest.mark.analog_guard
def test_eq2_equivalence_ideal(key):
    """Ideal OSA == fake-quant matmul (Eq. 1 == Eq. 2)."""
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (12, 33))
    w = jax.random.normal(k2, (33, 9))
    y_osa = osa.osa_matmul_ref(x, w)
    y_ref = quant.fake_quant(x) @ w
    np.testing.assert_allclose(np.asarray(y_osa), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.analog_guard
def test_pam_equivalence(key):
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (8, 16))
    w = jax.random.normal(k2, (16, 4))
    y1 = osa.osa_matmul_ref(x, w, osa.OSAConfig(pam_bits=1))
    y2 = osa.osa_matmul_ref(x, w, osa.OSAConfig(pam_bits=2))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)


def test_splitter_imbalance_breaks_exactness(key):
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (8, 16))
    w = jax.random.normal(k2, (16, 4))
    y_ideal = osa.osa_matmul_ref(x, w)
    y_bad = osa.osa_matmul_ref(x, w, osa.OSAConfig(splitter_imbalance=0.02))
    assert float(jnp.max(jnp.abs(y_ideal - y_bad))) > 1e-3


def test_odl_loss_attenuates(key):
    cfg = osa.OSAConfig(odl_loss_db_per_stage=0.5)
    g = osa.slot_gains(cfg)
    g0 = osa.slot_gains(osa.IDEAL_OSA)
    # loss hits low-significance slots (more stages) hardest
    ratio = np.asarray(g / g0)
    assert ratio[-1] == pytest.approx(1.0)
    assert np.all(np.diff(ratio) > 0)


def test_slot_counts():
    assert osa.required_slot_count(quant.Q8, 1) == 7
    assert osa.required_slot_count(quant.Q8, 2) == 4
    assert osa.required_slot_count(quant.Q8, 3) == 3


@pytest.mark.analog_guard
def test_rosa_matmul_shortcut_equals_plane_path(key):
    """The ideal-mixed fast path must equal the explicit OSA pipeline."""
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (6, 20))
    w = jax.random.normal(k2, (20, 5))
    cfg_fast = RosaConfig()                       # ideal => shortcut
    y_fast = rosa_matmul(x, w, cfg_fast)
    y_plane = osa.osa_matmul_ref(quant.fake_quant(x), quant.fake_quant(w))
    np.testing.assert_allclose(np.asarray(y_fast), np.asarray(y_plane),
                               rtol=1e-4, atol=1e-4)


def test_rosa_ws_noise_on_weights_only(key):
    """WS: repeated calls with the same key give identical results (weights
    drawn once deterministically); IS noise differs with activations."""
    k1, k2, kn = jax.random.split(key, 3)
    x = jax.random.normal(k1, (6, 20))
    w = jax.random.normal(k2, (20, 5))
    ws = RosaConfig(mapping=Mapping.WS, noise=mrr.PAPER_NOISE)
    y1 = rosa_matmul(x, w, ws, kn)
    y2 = rosa_matmul(x, w, ws, kn)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2))
    y_clean = rosa_matmul(x, w, RosaConfig())
    assert float(jnp.max(jnp.abs(y1 - y_clean))) > 1e-5


def test_rosa_straight_through_grads(key):
    k1, k2, kn = jax.random.split(key, 3)
    x = jax.random.normal(k1, (4, 8))
    w = jax.random.normal(k2, (8, 3))
    cfg = RosaConfig(noise=mrr.PAPER_NOISE)
    gx, gw = jax.grad(
        lambda x_, w_: jnp.sum(rosa_matmul(x_, w_, cfg, kn)),
        argnums=(0, 1))(x, w)
    # straight-through: grads equal those of the exact matmul
    np.testing.assert_allclose(np.asarray(gx),
                               np.asarray(jnp.ones((4, 3)) @ w.T), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(gw),
                               np.asarray(x.T @ jnp.ones((4, 3))), rtol=1e-5)


def test_analog_mode_noisier_than_mixed(key):
    """DEAP-style analog mode perturbs both operands -> larger error."""
    k1, k2, kn = jax.random.split(key, 3)
    x = jax.random.normal(k1, (32, 64))
    w = jax.random.normal(k2, (64, 16))
    y_exact = x @ w
    errs = {}
    for mode in (ComputeMode.MIXED, ComputeMode.ANALOG):
        cfg = RosaConfig(mode=mode, noise=mrr.PAPER_NOISE)
        ys = jnp.stack([rosa_matmul(x, w, cfg, k)
                        for k in jax.random.split(kn, 8)])
        errs[mode] = float(jnp.mean(jnp.abs(ys - y_exact)))
    assert errs[ComputeMode.ANALOG] > errs[ComputeMode.MIXED]
