"""Jaxpr walking helpers shared by the jaxpr-level checks.

jax moved the core IR types between releases (`jax.core` -> portions of
`jax.extend.core`); everything version-sensitive is funneled through here
so the check modules stay import-stable across the CI jax matrix.
"""

from __future__ import annotations

from typing import Iterator

try:                                    # jax >= 0.6 home
    from jax.extend.core import ClosedJaxpr, Jaxpr, Literal, Var
except ImportError:                     # pragma: no cover - older jax
    from jax.core import ClosedJaxpr, Jaxpr, Literal, Var

__all__ = ["ClosedJaxpr", "Jaxpr", "Literal", "Var", "sub_jaxprs",
           "iter_eqns", "eqn_location"]

# primitives whose sub-jaxpr executes once per loop iteration
LOOP_PRIMITIVES = ("scan", "while")


def _as_closed(j) -> ClosedJaxpr:
    return j if isinstance(j, ClosedJaxpr) else ClosedJaxpr(j, ())


def sub_jaxprs(eqn) -> Iterator[tuple[str, ClosedJaxpr]]:
    """Every jaxpr nested in `eqn.params`, as (param_name, ClosedJaxpr).

    Covers pjit ("jaxpr"), scan ("jaxpr"), while ("cond_jaxpr" /
    "body_jaxpr"), cond ("branches"), remat ("jaxpr", a raw Jaxpr) and the
    custom_[jv]p call wrappers — anything a later jax adds that stores a
    jaxpr-typed param is picked up structurally, not by name."""
    for name, val in eqn.params.items():
        if isinstance(val, (ClosedJaxpr, Jaxpr)):
            yield name, _as_closed(val)
        elif isinstance(val, (tuple, list)):
            for i, item in enumerate(val):
                if isinstance(item, (ClosedJaxpr, Jaxpr)):
                    yield f"{name}[{i}]", _as_closed(item)


def _eqn_label(eqn) -> str:
    name = eqn.params.get("name")
    prim = eqn.primitive.name
    return f"{prim}:{name}" if isinstance(name, str) and name else prim


def iter_eqns(closed: ClosedJaxpr, path: str = "", loop_depth: int = 0,
              _depth: int = 0) -> Iterator[tuple]:
    """Depth-first (eqn, path, loop_depth) over a jaxpr and every nested
    sub-jaxpr.  `loop_depth` counts enclosing scan/while bodies — the
    "runs many times per call" context the purity check cares about."""
    if _depth > 64:
        return
    for eqn in closed.jaxpr.eqns:
        yield eqn, path, loop_depth
        inc = 1 if eqn.primitive.name in LOOP_PRIMITIVES else 0
        for _pname, sub in sub_jaxprs(eqn):
            # a while COND runs per iteration too; only skip loop credit
            # for cond branches (each runs at most once per visit)
            sub_inc = 0 if eqn.primitive.name == "cond" else inc
            yield from iter_eqns(
                sub, f"{path}/{_eqn_label(eqn)}", loop_depth + sub_inc,
                _depth + 1)


def eqn_location(eqn, path: str) -> str:
    return f"{path}/{_eqn_label(eqn)}".lstrip("/")
