"""`EnergyLedger` — trace-based energy accounting for the optical path.

Every matmul routed through `rosa.Engine` records a `MatmulEvent` (layer
name, GEMM shape, mapping, compute mode) at trace time.  The ledger then
prices the *recorded* trace with the analytical event-count model
(core.energy.layer_energy), so EDP numbers are derived from the same call
sequence that produced the numerics — they cannot drift from a separately
maintained `LayerShape` list.

Recording happens while JAX traces the network (shapes are static), so the
canonical usage is one un-cached forward pass:

    ledger = EnergyLedger()
    engine = Engine.from_hybrid_plan(cfg, plan).with_ledger(ledger)
    jax.eval_shape(forward, params, x)        # or a direct call
    print(ledger.edp(ROSA_OPTIMAL))

A jit cache *hit* re-runs no Python and records nothing; trace once (or use
`jax.eval_shape`, which is free) when you want the ledger populated.
"""

from __future__ import annotations

import contextlib
import dataclasses
import itertools

from repro.core import energy as E
from repro.core.constants import ComputeMode, Mapping, OPEConfig
from repro.rosa.backends import RosaConfig


# process-wide record-order stamp; lets ledger events be aligned against
# obs trace spans even when several ledgers interleave in one run
_SEQ = itertools.count()


@dataclasses.dataclass(frozen=True)
class MatmulEvent:
    """One routed optical matmul, as seen at trace time."""

    name: str
    m: int
    k: int
    n: int
    mapping: Mapping
    mode: ComputeMode
    backend: str
    tag: str = ""          # attribution scope (e.g. "prefill" / "decode")
    seq: int = -1          # monotonic stamp assigned by EnergyLedger.record

    def layer_shape(self) -> E.LayerShape:
        """This event as an energy-model LayerShape."""
        return E.LayerShape(self.name, m=self.m, k=self.k, n=self.n,
                            kind="gemm")


class EnergyLedger:
    """Accumulates MatmulEvents and prices them with core.energy.

    `scope(tag)` attributes every matmul recorded inside it to `tag` —
    serving traces its prefill and decode steps under distinct scopes, so
    per-request energy (prompt energy + tokens x decode-step energy) can be
    re-aggregated from one ledger without re-tracing.
    """

    def __init__(self):
        self.events: list[MatmulEvent] = []
        self._tag = ""

    @contextlib.contextmanager
    def scope(self, tag: str):
        """Attribute events recorded inside to `tag` (trace-time, nestable)."""
        prev, self._tag = self._tag, tag
        try:
            yield self
        finally:
            self._tag = prev

    def record(self, name: str, m: int, k: int, n: int,
               cfg: RosaConfig) -> None:
        """Append one matmul event to the trace."""
        self.events.append(MatmulEvent(
            name=name, m=m, k=k, n=n,
            mapping=cfg.mapping, mode=cfg.mode, backend=cfg.backend,
            tag=self._tag, seq=next(_SEQ)))

    def clear(self) -> None:
        """Drop every recorded event."""
        self.events.clear()

    def __len__(self) -> int:
        return len(self.events)

    # -- views --------------------------------------------------------------
    def unique_events(self, tag: str | None = None) -> list[MatmulEvent]:
        """The 'network' view used for EDP: one event per distinct
        (name, GEMM shape, mapping, mode, tag), order preserved.  Re-traces
        and MC loops of the same layer dedupe to one event; the same name
        traced at a DIFFERENT shape (e.g. a prefill trace then a decode
        trace) is a distinct workload and keeps its own event rather than
        being silently discarded — clear() between traces if you want only
        the latest.  `tag` filters to one attribution scope.
        """
        seen: dict[tuple, MatmulEvent] = {}
        for ev in self.events:
            if tag is not None and ev.tag != tag:
                continue
            seen[(ev.name, ev.m, ev.k, ev.n, ev.mapping, ev.mode,
                  ev.tag)] = ev
        return list(seen.values())

    def layer_shapes(self, tag: str | None = None) -> list[E.LayerShape]:
        """LayerShapes of the deduplicated events."""
        return [ev.layer_shape() for ev in self.unique_events(tag)]

    def mapping_plan(self, tag: str | None = None) -> dict[str, Mapping]:
        """`{layer: Mapping}` of the deduplicated events."""
        return {ev.name: ev.mapping for ev in self.unique_events(tag)}

    # -- pricing ------------------------------------------------------------
    def breakdown(self, ope: OPEConfig,
                  osa: E.OSAEnergyConfig = E.OSA_OPTIMAL,
                  batch: int = 1, dedupe: bool = True,
                  tag: str | None = None) -> E.EnergyBreakdown:
        """Price the trace on an OPE fleet.  With dedupe (default) each named
        layer counts once — the sequential-network semantics of
        core.energy.network_energy; without it every recorded call counts.
        `tag` restricts pricing to one attribution scope.
        """
        if dedupe:
            events = self.unique_events(tag)
        else:
            events = [ev for ev in self.events
                      if tag is None or ev.tag == tag]
        total = E.EnergyBreakdown(name="trace")
        for ev in events:
            total = total + E.layer_energy(ev.layer_shape(), ope,
                                           ev.mapping, ev.mode, osa,
                                           batch=batch)
        return total

    def per_token(self, ope: OPEConfig,
                  osa: E.OSAEnergyConfig = E.OSA_OPTIMAL,
                  batch: int = 1, tag: str | None = "decode") -> float:
        """Energy [J] attributed to ONE generated token of ONE sequence.

        Prices the (deduped) events under `tag` — canonically the serving
        decode-step trace, which computes one token for each of `batch`
        concurrent slots — and splits the step energy evenly across the
        slots.  The traced events ALREADY carry the slot concurrency in
        their m dimension, so the trace is priced as-is (batch=1 —
        passing `batch` into layer_energy again would double-count it)
        and only the division spreads it over the slots.  This is the
        number `serve_smoke` exports as energy_per_token_j.
        """
        bd = self.breakdown(ope, osa, batch=1, tag=tag)
        return bd.energy / max(batch, 1)

    def edp(self, ope: OPEConfig, osa: E.OSAEnergyConfig = E.OSA_OPTIMAL,
            batch: int = 1, dedupe: bool = True) -> float:
        """Energy-delay product [J*s] of the recorded trace; equals
        core.mapping.plan_edp on the same layers/plan by construction.
        """
        return self.breakdown(ope, osa, batch=batch, dedupe=dedupe).edp

    def export(self, ope: OPEConfig,
               osa: E.OSAEnergyConfig = E.OSA_OPTIMAL,
               batch: int = 1) -> dict:
        """JSON-serializable view of the priced trace for BENCH reports.

        One object per unique routed matmul plus the network totals — what
        `benchmarks/run.py` embeds so offline tooling can re-aggregate EDP
        without replaying the trace.
        """
        bd = self.breakdown(ope, osa, batch=batch)
        return {
            "ope": {"rows": ope.rows, "cols": ope.cols, "tiles": ope.tiles},
            "batch": batch,
            "events": [
                {"name": ev.name, "m": ev.m, "k": ev.k, "n": ev.n,
                 "mapping": ev.mapping.value, "mode": ev.mode.value,
                 "backend": ev.backend, "tag": ev.tag, "seq": ev.seq}
                for ev in self.unique_events()
            ],
            "totals": bd.as_dict(),
        }
