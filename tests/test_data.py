"""Deterministic data pipelines (fault-tolerance property)."""

import numpy as np

from repro.data import TokenPipeline, synth_cifar


def test_token_pipeline_deterministic():
    p = TokenPipeline(vocab=101, seq_len=16, global_batch=8, seed=3)
    b1, b2 = p.batch(5), p.batch(5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = p.batch(6)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))


def test_token_pipeline_labels_are_shifted_stream():
    p = TokenPipeline(vocab=50, seq_len=12, global_batch=4)
    b = p.batch(0)
    assert b["tokens"].shape == (4, 12)
    assert b["labels"].shape == (4, 12)
    # labels[t] is the next token of the same underlying stream
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


def test_shard_batch_partitions_global_batch():
    p = TokenPipeline(vocab=50, seq_len=8, global_batch=8)
    full = p.batch(2)
    parts = [p.shard_batch(2, s, 4) for s in range(4)]
    rebuilt = np.concatenate([np.asarray(x["tokens"]) for x in parts])
    np.testing.assert_array_equal(rebuilt, np.asarray(full["tokens"]))


def test_synth_cifar_deterministic_and_balanced():
    x1, y1 = synth_cifar(256, seed=1)
    x2, y2 = synth_cifar(256, seed=1)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)
    assert x1.shape == (256, 32, 32, 3)
    assert x1.min() >= -1 and x1.max() <= 1
    counts = np.bincount(y1, minlength=10)
    assert counts.min() > 5       # roughly balanced


def test_synth_cifar_classes_distinguishable():
    """Class-conditional means differ (there is signal to learn)."""
    x, y = synth_cifar(512, seed=0, noise=0.0)
    m0 = x[y == 0].mean(axis=0)
    m5 = x[y == 5].mean(axis=0)
    assert np.abs(m0 - m5).mean() > 0.01
