"""Per-architecture smoke tests (deliverable f): reduced configs of all 10
assigned families run a forward/train step on CPU, asserting shapes and
finiteness; decode agrees with prefill."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke
from repro.models.model import (ASSIGNED_SHAPES, SMOKE_SHAPES, applicable,
                                build_model, pad_cache)
from repro.models.moe import MoEConfig, moe_def, moe_ep_local, moe_ref
from repro.models.module import init_params


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_finite(arch, key):
    cfg = get_smoke(arch)
    bundle = build_model(cfg)
    params = bundle.init(key)
    batch, _ = bundle.input_specs(SMOKE_SHAPES["train_4k"], concrete=True,
                                  key=key)
    loss, grads = jax.value_and_grad(
        lambda p: bundle.train_loss(p, batch))(params)
    assert jnp.isfinite(loss)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in jax.tree.leaves(grads))


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes(arch, key):
    cfg = get_smoke(arch)
    bundle = build_model(cfg)
    params = bundle.init(key)
    batch, _ = bundle.input_specs(SMOKE_SHAPES["train_4k"], concrete=True,
                                  key=key)
    x = bundle.forward(params, batch)
    assert x.shape[0] == batch["tokens"].shape[0]
    assert x.shape[-1] == cfg.d_model
    assert bool(jnp.all(jnp.isfinite(x.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_prefill(arch, key):
    cfg = dataclasses.replace(get_smoke(arch), cache_dtype=jnp.float32)
    bundle = build_model(cfg)
    params = bundle.init(key)
    batch, _ = bundle.input_specs(SMOKE_SHAPES["prefill_32k"], concrete=True,
                                  key=key)
    logits_p, cache = bundle.prefill(params, batch)
    cache = pad_cache(cfg, cache, 4)
    nxt = jnp.argmax(logits_p, -1)
    logits_d, _ = bundle.decode_step(
        params, {"token": nxt, "pos": cache["pos"], "cache": cache})
    batch2 = dict(batch)
    batch2["tokens"] = jnp.concatenate([batch["tokens"], nxt[:, None]], 1)
    logits_ref, _ = bundle.prefill(params, batch2)
    np.testing.assert_allclose(np.asarray(logits_d), np.asarray(logits_ref),
                               rtol=0.05, atol=0.05)


def test_full_configs_match_assignment():
    """The full configs carry the exact published dimensions."""
    spec = {
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 151936),
        "deepseek-v2-236b": (60, 5120, 128, 128, 102400),
        "qwen3-32b": (64, 5120, 64, 8, 151936),
        "deepseek-67b": (95, 8192, 64, 8, 102400),
        "mistral-large-123b": (88, 12288, 96, 8, 32768),
        "gemma3-12b": (48, 3840, 16, 8, 262144),
        "mamba2-1.3b": (48, 2048, 0, 0, 50280),
        "seamless-m4t-medium": (12, 1024, 16, 16, 256206),
        "phi-3-vision-4.2b": (32, 3072, 32, 32, 32064),
        "zamba2-1.2b": (38, 2048, 32, 32, 32000),
    }
    for name, (nl, dm, h, kv, v) in spec.items():
        cfg = get_config(name)
        assert cfg.n_layers == nl and cfg.d_model == dm and cfg.vocab == v
        if h:
            assert cfg.n_heads == h and cfg.n_kv_heads == kv
    assert get_config("qwen3-moe-235b-a22b").moe.n_experts == 128
    assert get_config("qwen3-moe-235b-a22b").moe.top_k == 8
    assert get_config("deepseek-v2-236b").moe.n_experts == 160
    assert get_config("deepseek-v2-236b").moe.top_k == 6
    assert get_config("deepseek-v2-236b").mla.kv_lora == 512
    assert get_config("mamba2-1.3b").ssm.d_state == 128
    assert get_config("zamba2-1.2b").ssm.d_state == 64


def test_long_500k_applicability():
    """Skip/run rules for the long-context shape per DESIGN.md."""
    runs = {a: applicable(get_config(a), ASSIGNED_SHAPES["long_500k"])[0]
            for a in ARCHS}
    assert runs["gemma3_12b"] and runs["mamba2_1p3b"] and runs["zamba2_1p2b"]
    assert sum(runs.values()) == 3


def test_moe_ep_equals_ref(key):
    """shard_map EP path == dense reference on a 1x1 mesh (no dropping)."""
    cfg = MoEConfig(n_experts=4, top_k=2, d_model=16, d_ff=8,
                    capacity_factor=8.0, n_shared=1)
    params = init_params(moe_def(cfg), key)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, 16))
    y_ref = moe_ref(params, cfg, x)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import shard_map_compat
    y_ep = shard_map_compat(
        lambda p, xl: moe_ep_local(p, cfg, x_local=xl, fsdp_axes=()),
        mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(), params), P()),
        out_specs=P())(params, x)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_ep),
                               rtol=2e-3, atol=2e-3)


def test_gemma_window_pattern():
    from repro.models.transformer import layer_meta
    cfg = get_config("gemma3-12b")
    meta = layer_meta(cfg)
    w = np.asarray(meta["window"])
    assert (w == 0).sum() == 8            # 8 global layers
    assert (w == 1024).sum() == 40        # 40 local layers
    assert w[5] == 0 and w[0] == 1024     # every 6th is global
