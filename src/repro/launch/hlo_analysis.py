"""Optimized-HLO analyzer — thin façade over `repro.analysis.hlo`.

The parser grew a second consumer (the static verifier's donation check
reads the same module text for `input_output_alias`), so the machinery
moved to `repro.analysis.hlo`; this module keeps the historical import
path for the dry-run pipeline (`launch/dryrun.py`) and external callers.
See `repro.analysis.hlo` for the full methodology notes (why
`compiled.cost_analysis()` undercounts scanned loops, byte-accounting
conventions, collective wire math).
"""

from __future__ import annotations

from repro.analysis.hlo import (  # noqa: F401
    COLLECTIVES, DTYPE_BYTES, Comp, HLOReport, UnknownDtypeError,
    _DTYPE_BYTES, _multiplicities, _operand_names, _shape_dims,
    _shape_list_bytes, _split, _sym_bytes, analyze,
    entry_parameter_shapes, parse_input_output_aliases, top_bytes,
)

__all__ = ["COLLECTIVES", "DTYPE_BYTES", "HLOReport", "UnknownDtypeError",
           "analyze", "top_bytes", "parse_input_output_aliases",
           "entry_parameter_shapes"]
