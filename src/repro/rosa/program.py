"""`rosa.Program` — compile-once programs with autotuned, disk-cached plans.

The paper's wins come from co-optimizing the array config and the per-layer
IS/WS dataflow against a *whole workload*, so plan decisions belong at
program granularity, not per-matmul.  `rosa.compile` is the one entry
point:

    program = rosa.compile(apply_fn, engine, (params, x))
    y = program(params, x, key=key)

Compilation is three deterministic steps:

  1. **Trace** — `apply_fn` is abstractly evaluated once (`jax.eval_shape`,
     no FLOPs) with a trace-capturing engine installed; every named matmul
     the engine routes is recorded into a `ProgramTrace` (layer name, GEMM
     shape, call count).
  2. **Autotune** — with an `AutotuneConfig`, the layer-wise hybrid IS/WS
     plan is searched over the traced workload: EDP-only through
     `core.mapping.profile_layers_fast`, or accuracy-aware when a
     Monte-Carlo `degradation` matrix (`repro.robust.sensitivity`) is
     supplied.  The searched plan is persisted in a content-addressed
     on-disk `PlanCache` keyed by hash(trace, RosaConfig, search settings),
     so a warm compile loads the plan and skips the search entirely.
  3. **Freeze** — the resolved `ExecutionPlan` is installed on the engine,
     the trace is re-priced onto the engine's `EnergyLedger` (when one is
     attached), and the returned `Program` is a jitted executable with
     explicit `key=` / `variation=` threading and optional donation — no
     global engine stack is involved.

`Program.plan` / `Program.lower()` expose the resolved plan for inspection
and JSON round-trip; `Program.bind(fn)` jit-compiles auxiliary step
functions (a serving scheduler's decode/prefill steps) under the same
frozen engine.
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
import json
import os
import pathlib
import tempfile
from typing import Any, Callable, Sequence

import jax

from repro.core import energy as E
from repro.core import mapping as M
from repro.core.constants import ComputeMode, OPEConfig, ROSA_OPTIMAL
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs
from repro.rosa.engine import Engine, engine_context
from repro.rosa.ledger import EnergyLedger
from repro.rosa.plan import ExecutionPlan
from repro.rosa.serialize import (canonical_json, config_to_json,
                                  content_hash, ope_from_json,
                                  osa_energy_from_json, to_jsonable)

# apply_fn(engine, *args) -> outputs.  The engine is handed in explicitly
# AND installed as the ambient context around the call, so both explicit-
# engine models (cnn_apply) and ambient-engine models (the transformer
# stacks) compile through the same entry point.
ApplyFn = Callable[..., Any]


# ---------------------------------------------------------------------------
# ProgramTrace — the captured named-matmul workload
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TraceEntry:
    """One distinct routed GEMM: layer name, shape, trace-time call count."""

    name: str
    m: int
    k: int
    n: int
    count: int = 1

    def layer_shape(self) -> E.LayerShape:
        """This entry as an energy-model LayerShape."""
        return E.LayerShape(self.name, m=self.m, k=self.k, n=self.n,
                            kind="gemm")


@dataclasses.dataclass(frozen=True)
class ProgramTrace:
    """The full named-matmul trace of one abstract program evaluation."""

    entries: tuple[TraceEntry, ...] = ()

    @property
    def names(self) -> tuple[str, ...]:
        """Layer names in trace order."""
        return tuple(e.name for e in self.entries)

    def layer_shapes(self) -> list[E.LayerShape]:
        """LayerShapes of every traced entry."""
        return [e.layer_shape() for e in self.entries]

    def __len__(self) -> int:
        return len(self.entries)

    @property
    def fingerprint(self) -> str:
        """Content hash of the trace (one input to the plan-cache key)."""
        return content_hash(self.to_json())

    # -- JSON round-trip -----------------------------------------------------
    def to_json(self) -> dict:
        """JSON-able dict of the trace."""
        return {"entries": [to_jsonable(e) for e in self.entries]}

    @classmethod
    def from_json(cls, doc: dict) -> "ProgramTrace":
        """Inverse of `to_json`."""
        return cls(tuple(TraceEntry(name=e["name"], m=int(e["m"]),
                                    k=int(e["k"]), n=int(e["n"]),
                                    count=int(e["count"]))
                         for e in doc["entries"]))

    @classmethod
    def from_ledger(cls, ledger: EnergyLedger) -> "ProgramTrace":
        """Collapse the raw (non-deduped) event list into counted entries,
        first-seen order preserved.
        """
        counts: dict[tuple, int] = {}
        for ev in ledger.events:
            k = (ev.name, ev.m, ev.k, ev.n)
            counts[k] = counts.get(k, 0) + 1
        return cls(tuple(TraceEntry(name, m, k, n, c)
                         for (name, m, k, n), c in counts.items()))


def capture_trace(apply_fn: ApplyFn, engine: Engine,
                  example_args: Sequence[Any]) -> ProgramTrace:
    """Abstractly trace `apply_fn` once and capture its routed matmuls.

    The capture engine is `engine` with a private recording ledger swapped
    in, installed both as the explicit first argument and as the ambient
    context; `jax.eval_shape` runs no math, so capture cost is one Python
    trace.  Only matmuls the engine actually routes optically (resolved
    config not None) appear — plain dense layers are not plan candidates.
    """
    recorder = EnergyLedger()
    probe = engine.with_ledger(recorder)
    if probe.key is None:
        # shapes are key-independent, but the noisy realization path
        # refuses to trace without one — any key does for an abstract pass
        probe = probe.with_key(jax.random.PRNGKey(0))
    with obs.span("rosa.capture_trace", cat="compile"):
        with engine_context(probe):
            jax.eval_shape(functools.partial(apply_fn, probe),
                           *example_args)
    return ProgramTrace.from_ledger(recorder)


# ---------------------------------------------------------------------------
# Autotune settings
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class AutotuneConfig:
    """Workload-aware hybrid-mapping search settings.

    EDP profiling runs on the traced GEMMs through the vectorized energy
    model (`mapping.profile_layers_fast`).  Without a degradation matrix
    the accuracy term is muted and the plan is the per-layer EDP argmin;
    with one (see `repro.robust.sensitivity.degradation_matrix`) the
    balanced metric runs accuracy-aware, and `guard_pp` additionally vetoes
    any per-layer choice that costs more than `guard_pp` percentage points
    over that layer's most robust mapping
    (`sensitivity.accuracy_guarded_plan`).

    ``accuracy_aware`` (the default) lets a supplied degradation matrix or
    `DegradationSource` steer the search; ``accuracy_aware=False`` (the
    `EDP_ONLY` preset) mutes the accuracy term even when one is supplied —
    the search is then the pure per-layer EDP argmin and degradation inputs
    do not enter the cache key.
    """

    ope: OPEConfig = ROSA_OPTIMAL
    batch: int = 1
    mode: ComputeMode = ComputeMode.MIXED
    osa: E.OSAEnergyConfig = E.OSA_OPTIMAL
    guard_pp: float | None = None
    accuracy_aware: bool = True

    def to_json(self) -> dict:
        """Lower to a JSON-native dict (cache-key input)."""
        return to_jsonable(self)

    @classmethod
    def from_json(cls, doc: dict) -> "AutotuneConfig":
        """Invert `to_json` (tolerates pre-schema-2 docs without the flag)."""
        return cls(ope=ope_from_json(doc["ope"]), batch=int(doc["batch"]),
                   mode=ComputeMode(doc["mode"]),
                   osa=osa_energy_from_json(doc["osa"]),
                   guard_pp=doc["guard_pp"],
                   accuracy_aware=bool(doc.get("accuracy_aware", True)))


EDP_ONLY = AutotuneConfig(accuracy_aware=False)


@dataclasses.dataclass(frozen=True)
class DegradationSource:
    """A measure-on-miss provider of Monte-Carlo degradation matrices.

    ``measure(layer_names)`` returns ``{layer: {mapping: pp}}`` for exactly
    the requested layers (the expensive MC stage); ``spec`` is a JSON-able
    identity of everything those numbers depend on — ensemble size/seed,
    noise and variation models, eval-set size, trained-params digest.
    `compile` content-addresses cached matrices in the `PlanCache` by
    (spec, base RosaConfig) and invokes ``measure`` only for layers the
    cache does not already hold, so warm compiles skip the MC stage
    entirely and trace growth re-scores only the new layers.  See
    `repro.robust.sensitivity.cnn_degradation_source` for the canonical
    constructor.
    """

    measure: Callable[[Sequence[str]], dict]
    spec: Any


# ---------------------------------------------------------------------------
# Content-addressed on-disk plan cache
# ---------------------------------------------------------------------------
_CACHE_ENV = "ROSA_PLAN_CACHE"
# Part of every cache key AND checked on load: bump it whenever the plan
# SEARCH itself changes meaning (profile_layers_fast semantics, the energy
# model, the balanced metric, this file's search wiring) so stale plans
# searched by older code can never be silently reused.
# 2: AutotuneConfig gained accuracy_aware; degradation matrices joined the
#    cache (ISSUE 7 — shared-forward measurement changed their PRNG draws).
_CACHE_SCHEMA = 2


def default_cache_dir() -> pathlib.Path:
    """Cache root: `$ROSA_PLAN_CACHE` or `~/.cache/rosa-repro/plans`."""
    return pathlib.Path(os.environ.get(
        _CACHE_ENV, "~/.cache/rosa-repro/plans")).expanduser()


class PlanCache:
    """Content-addressed plan store: one JSON file per cache key.

    Keys are sha256 hashes over the canonical JSON of (trace, base
    RosaConfig, autotune settings, degradation matrix), so any change to
    the workload or the search inputs misses the cache and re-searches;
    identical inputs hit and load the identical plan.  Writes are
    atomic-rename so concurrent compiles never observe torn files.

    `max_entries` bounds the store: after every write the oldest-mtime
    entries beyond the bound are unlinked (plan and degradation files
    count alike).  Loads touch their entry's mtime, so eviction is LRU,
    not FIFO — months-long adaptive serving keeps its hot plans while the
    cache stays bounded.  `python -m repro.rosa stats|gc` inspects and
    prunes a store offline.
    """

    def __init__(self, root: str | os.PathLike | None = None,
                 max_entries: int | None = None):
        if max_entries is not None and max_entries < 1:
            raise ValueError("max_entries must be >= 1")
        self.root = pathlib.Path(root) if root is not None \
            else default_cache_dir()
        self.max_entries = max_entries

    def _path(self, key: str) -> pathlib.Path:
        return self.root / f"{key}.json"

    @staticmethod
    def key(trace: ProgramTrace, base_cfg, autotune: AutotuneConfig,
            degradation: dict | None = None) -> str:
        """Content key of a (trace, config, autotune, degradation) plan."""
        return content_hash({
            "schema": _CACHE_SCHEMA,
            "trace": trace.to_json(),
            "config": config_to_json(base_cfg),
            "autotune": autotune.to_json(),
            "degradation": degradation or {},
        })

    def load(self, key: str) -> ExecutionPlan | None:
        """The cached plan under `key`, or None on miss/corruption."""
        path = self._path(key)
        with obs.span("plancache.load", cat="cache", key=key[:12]):
            try:
                doc = json.loads(path.read_text())
                if doc.get("schema") != _CACHE_SCHEMA \
                        or doc.get("key") != key:
                    plan = None
                else:
                    plan = ExecutionPlan.from_json(doc["plan"])
            except (OSError, json.JSONDecodeError, KeyError, TypeError,
                    ValueError):
                # any unreadable/stale/torn entry is a miss, never a crash
                # — the cold path re-searches and overwrites it
                plan = None
        if plan is not None:
            self._touch(path)
        reg = obs_metrics.registry()
        reg.counter("rosa.plancache_hits" if plan is not None
                    else "rosa.plancache_misses").inc()
        return plan

    def store(self, key: str, plan: ExecutionPlan,
              trace: ProgramTrace) -> pathlib.Path:
        """Atomically persist a searched plan under its content key."""
        doc = {"schema": _CACHE_SCHEMA, "key": key, "plan": plan.to_json(),
               "trace_fingerprint": trace.fingerprint}
        with obs.span("plancache.store", cat="cache", key=key[:12]):
            path = self._write(self._path(key), doc)
        self.gc()
        return path

    @staticmethod
    def _touch(path: pathlib.Path) -> None:
        """Bump an entry's mtime on a hit: mtime IS the LRU clock."""
        with contextlib.suppress(OSError):
            os.utime(path)

    def _entries(self) -> list[pathlib.Path]:
        """Every persisted entry (plans AND degradation stores), LRU
        first: eviction order for `gc`, listing order for `stats`."""
        try:
            files = [p for p in self.root.iterdir()
                     if p.suffix == ".json" and p.is_file()]
        except OSError:
            return []
        def mtime(p: pathlib.Path) -> float:
            try:
                return p.stat().st_mtime
            except OSError:       # racing eviction/cleanup: sort last
                return float("inf")
        return sorted(files, key=lambda p: (mtime(p), p.name))

    def gc(self, max_entries: int | None = None) -> int:
        """Evict least-recently-used entries beyond the bound; returns the
        eviction count.  `max_entries=None` uses the instance bound (and
        is a no-op when the instance is unbounded)."""
        bound = self.max_entries if max_entries is None else max_entries
        if bound is None:
            return 0
        if bound < 1:
            raise ValueError("max_entries must be >= 1")
        entries = self._entries()
        evicted = 0
        for path in entries[:max(len(entries) - bound, 0)]:
            with contextlib.suppress(OSError):
                path.unlink()
                evicted += 1
        if evicted:
            obs_metrics.registry().counter(
                "rosa.plancache_evictions").inc(evicted)
        return evicted

    def stats(self) -> dict:
        """JSON-able store summary (the `python -m repro.rosa stats` view)."""
        entries = self._entries()
        plans = [p for p in entries if not p.name.endswith(".deg.json")]
        sizes = []
        for p in entries:
            with contextlib.suppress(OSError):
                sizes.append(p.stat().st_size)
        return {"root": str(self.root),
                "entries": len(entries),
                "plans": len(plans),
                "matrices": len(entries) - len(plans),
                "bytes": sum(sizes),
                "max_entries": self.max_entries,
                "lru": [p.name for p in entries[:3]],
                "mru": [p.name for p in entries[-3:]]}

    def _write(self, path: pathlib.Path, doc: dict) -> pathlib.Path:
        self.root.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(doc, f, indent=1, sort_keys=True)
            os.replace(tmp, path)
        except BaseException:
            with contextlib.suppress(FileNotFoundError):
                os.unlink(tmp)
            raise
        return path

    # -- degradation matrices -------------------------------------------------
    # One `<key>.deg.json` per (base RosaConfig, measurement spec): a
    # per-layer accumulator, NOT a single frozen blob.  Entries are keyed
    # by layer name inside, so a grown trace re-measures only its new
    # layers (`DegradationSource`) and every earlier row is reused —
    # the effective key of each row is (layer, RosaConfig, spec).
    @staticmethod
    def matrix_key(base_cfg, spec) -> str:
        """Content key of a degradation-matrix store file."""
        return content_hash({"schema": _CACHE_SCHEMA, "kind": "degradation",
                             "config": config_to_json(base_cfg),
                             "spec": to_jsonable(spec)})

    def _matrix_path(self, key: str) -> pathlib.Path:
        return self.root / f"{key}.deg.json"

    def load_matrix(self, key: str) -> dict | None:
        """The cached `{layer: {mapping: pp}}` rows, or None on any miss."""
        path = self._matrix_path(key)
        try:
            doc = json.loads(path.read_text())
            if doc.get("schema") != _CACHE_SCHEMA or doc.get("key") != key:
                return None
            layers = doc["layers"]
            rows = {str(n): {str(m): float(v) for m, v in row.items()}
                    for n, row in layers.items()}
        except (OSError, json.JSONDecodeError, KeyError, TypeError,
                ValueError, AttributeError):
            return None
        self._touch(path)
        return rows

    def store_matrix(self, key: str, layers: dict) -> pathlib.Path:
        """Atomically persist (or extend) a degradation-matrix store."""
        doc = {"schema": _CACHE_SCHEMA, "key": key, "layers": layers}
        path = self._write(self._matrix_path(key), doc)
        self.gc()
        return path


def _resolve_cache(cache) -> PlanCache | None:
    if cache is False:
        return None
    if cache is None or cache is True:
        return PlanCache()
    if isinstance(cache, PlanCache):
        return cache
    return PlanCache(cache)


def _measured_matrix(src: DegradationSource, trace: ProgramTrace,
                     base_cfg, store: PlanCache | None) -> dict:
    """Degradation rows for the traced layers: cache first, measure the rest.

    Loads whatever rows the PlanCache already holds under
    `PlanCache.matrix_key(base_cfg, src.spec)`, measures ONLY the missing
    layers (the incremental path — a warm cache measures nothing), marks
    layers the source cannot score with an empty row so they are never
    re-attempted, and persists the extended store.
    """
    mkey = PlanCache.matrix_key(base_cfg, src.spec)
    with obs.span("degstore.load", cat="cache", key=mkey[:12]):
        have = (store.load_matrix(mkey) if store is not None else None) \
            or {}
    missing = [n for n in trace.names if n not in have]
    reg = obs_metrics.registry()
    reg.counter("rosa.degstore_layer_hits").inc(
        len(trace.names) - len(missing))
    reg.counter("rosa.degstore_layer_misses").inc(len(missing))
    if missing:
        with obs.span("rosa.degradation_measure", cat="compile",
                      layers=len(missing)):
            have = {**have, **src.measure(missing)}
        for n in missing:
            have.setdefault(n, {})
        if store is not None:
            with obs.span("degstore.store", cat="cache", key=mkey[:12]):
                store.store_matrix(mkey, have)
    return {n: have[n] for n in trace.names if have.get(n)}


# ---------------------------------------------------------------------------
# Program — the frozen executable handle
# ---------------------------------------------------------------------------
class Program:
    """A compiled optical program: frozen engine + jitted apply.

    Call it like the traced function minus the engine argument —
    ``program(*args, key=..., variation=...)`` — with an optional base PRNG
    key (per-layer keys fold inside the engine) and an optional pinned-chip
    `variation` pytree, both threaded explicitly through the jit boundary.
    `donate_argnums` indices refer to ``apply_fn``'s positional args (the
    engine excluded).
    """

    def __init__(self, apply_fn: ApplyFn, engine: Engine,
                 trace: ProgramTrace, *,
                 donate_argnums: Sequence[int] = (),
                 searched: bool = False, cache_hit: bool = False,
                 cache_key: str | None = None):
        self.apply_fn = apply_fn
        self.engine = engine
        self.trace = trace
        self.searched = searched
        self.cache_hit = cache_hit
        self.cache_key = cache_key
        self._donate = tuple(donate_argnums)

        def run(key, variation, *args):
            """Jitted entry: rebind key/variation, then run the forward."""
            eng = engine
            if key is not None:
                eng = eng.with_key(key)
            if variation is not None:
                eng = eng.with_variation(variation)
            with engine_context(eng):
                return apply_fn(eng, *args)

        # key/variation prepend two positions in front of apply_fn's args
        self._call = jax.jit(
            run, donate_argnums=tuple(i + 2 for i in self._donate))

    def __call__(self, *args, key: jax.Array | None = None,
                 variation=None):
        return self._call(key, variation, *args)

    # -- inspection ----------------------------------------------------------
    @property
    def plan(self) -> ExecutionPlan:
        """The resolved per-layer execution plan this program runs."""
        return self.engine.plan

    @property
    def ledger(self) -> EnergyLedger | None:
        """The frozen engine's ledger (None when unattached)."""
        return self.engine.ledger

    def lower(self) -> dict:
        """JSON-serializable artifact: the captured trace, the resolved
        plan, and the cache provenance — `ExecutionPlan.from_json` /
        `ProgramTrace.from_json` invert the nested documents.
        """
        return {
            "trace": self.trace.to_json(),
            "plan": self.plan.to_json(),
            "cache_key": self.cache_key,
            "searched": self.searched,
            "cache_hit": self.cache_hit,
        }

    def lower_json(self) -> str:
        """Canonical-JSON string of `lower()`."""
        return canonical_json(self.lower())

    # -- derivation ----------------------------------------------------------
    def with_engine(self, engine: Engine) -> "Program":
        """Same trace/provenance, different frozen engine (e.g. a pinned
        chip or an attached ledger added after autotuning).
        """
        return Program(self.apply_fn, engine, self.trace,
                       donate_argnums=self._donate, searched=self.searched,
                       cache_hit=self.cache_hit, cache_key=self.cache_key)

    def with_variation(self, variation) -> "Program":
        """Program with one sampled chip pinned on its engine."""
        return self.with_engine(self.engine.with_variation(variation))

    def with_ledger(self, ledger: EnergyLedger | None) -> "Program":
        """Program with `ledger` attached to its engine."""
        return self.with_engine(self.engine.with_ledger(ledger))

    def bind(self, fn: Callable, *, donate_argnums=(),
             static_argnums=()) -> Callable:
        """Jit-compile an auxiliary function under this program's engine.

        The engine is installed as the ambient context while `fn` traces,
        so model code that resolves `rosa.ambient_engine()` sees the
        program's frozen (plan, chip, ledger) — this is how the serving
        scheduler builds its decode/prefill/admit steps from one Program
        without any global engine stack.
        """
        engine = self.engine

        def wrapped(*args, **kwargs):
            """Run `fn` with this program's engine ambient."""
            with engine_context(engine):
                return fn(*args, **kwargs)

        return jax.jit(wrapped, donate_argnums=donate_argnums,
                       static_argnums=static_argnums)


# ---------------------------------------------------------------------------
# compile — trace once, autotune, freeze
# ---------------------------------------------------------------------------
@obs.traced("rosa.compile", cat="compile")
def compile(apply_fn: ApplyFn, engine: Engine,
            example_args: Sequence[Any] = (), *,
            autotune: AutotuneConfig | None = None,
            degradation: "dict | DegradationSource | None" = None,
            cache: "PlanCache | str | os.PathLike | None | bool" = None,
            donate_argnums: Sequence[int] = (),
            verify: str = "off") -> Program:
    """Compile `apply_fn` against `engine` into a frozen `Program`.

    `example_args` are arrays or `jax.ShapeDtypeStruct`s matching
    ``apply_fn(engine, *example_args)``; they are only evaluated
    abstractly.  With ``autotune`` the traced workload drives a layer-wise
    hybrid IS/WS plan search seeded from ``engine.plan.default`` (existing
    overrides are replaced by the searched plan); without it the engine's
    plan is taken as-is and compilation is trace + freeze.  ``degradation``
    makes the search accuracy-aware (the default — mute it with
    ``AutotuneConfig(accuracy_aware=False)`` / the `EDP_ONLY` preset):
    either a ready `{layer: {mapping: pp}}` Monte-Carlo matrix
    (`repro.robust.sensitivity`) or a `DegradationSource`, whose measured
    rows are themselves cached in the `PlanCache` per (layer, RosaConfig,
    measurement spec) — a warm compile loads them instead of re-running
    the MC stage, and a grown trace measures only its new layers.

    Searched plans persist in the content-addressed `PlanCache` (``cache``:
    default directory when None, a directory path, a `PlanCache`, or
    ``False`` to disable) — a warm compile with identical trace + config +
    settings loads the plan from disk and skips the search.

    ``verify`` runs the `repro.analysis` static checks (PRNG discipline,
    donation aliasing, recompile hazards, hot-loop purity) over the
    compiled program: ``"error"`` raises `analysis.VerificationError` on
    ERROR-severity findings, ``"warn"`` emits a warning per finding,
    ``"off"`` (default) skips the pass.  Verification re-traces the
    program with an abstract key and — when donations are declared —
    pays one real XLA compile to read the alias map.
    """
    if verify not in ("off", "warn", "error"):
        raise ValueError(
            f"verify must be 'off'|'warn'|'error', got {verify!r}")
    example_args = tuple(example_args)
    trace = capture_trace(apply_fn, engine, example_args)

    searched = False
    cache_hit = False
    cache_key = None
    if autotune is not None:
        base_cfg = engine.plan.default
        if base_cfg is None:
            raise ValueError(
                "autotune needs engine.plan.default (the base RosaConfig "
                "the search specializes per layer); got a dense default — "
                "pass autotune=None to freeze the plan as-is")
        store = _resolve_cache(cache)
        src = degradation if isinstance(degradation, DegradationSource) \
            else None
        deg = degradation if isinstance(degradation, dict) else None
        if not autotune.accuracy_aware:
            # EDP_ONLY: the accuracy term is muted and degradation inputs
            # are excluded from the cache key (they cannot affect the plan)
            src = deg = None
        key_deg = deg if deg is not None else \
            ({"source": to_jsonable(src.spec)} if src is not None else None)
        cache_key = PlanCache.key(trace, base_cfg, autotune, key_deg)
        plan = store.load(cache_key) if store is not None else None
        if plan is not None:
            # warm compile: the plan (and with it, any MC measurement the
            # search consumed) is loaded whole — the MC stage never runs
            cache_hit = True
        elif len(trace) == 0:
            plan = engine.plan     # nothing routed optically: nothing to tune
        else:
            if src is not None:
                deg = _measured_matrix(src, trace, base_cfg, store)
            d_fn = None
            if deg is not None:
                # default-0 lookup: layers the source could not score run
                # EDP-only instead of crashing the whole search
                matrix = deg
                d_fn = lambda name, m: float(     # noqa: E731
                    matrix.get(name, {}).get(m.value, 0.0))
            with obs.span("rosa.plan_search", cat="compile",
                          layers=len(trace)):
                profiles = M.profile_layers_fast(
                    trace.layer_shapes(), autotune.ope, d_fn,
                    mode=autotune.mode, osa=autotune.osa,
                    batch=autotune.batch)
                if autotune.guard_pp is not None and deg is not None:
                    from repro.robust.sensitivity import \
                        accuracy_guarded_plan
                    mapping_plan = accuracy_guarded_plan(
                        profiles, max_extra_pp=autotune.guard_pp)
                else:
                    mapping_plan = M.hybrid_plan(profiles)
            # open layer set: non-GEMM contractions (depthwise convs) and
            # names outside the trace still resolve to the base config
            plan = ExecutionPlan.from_mapping_plan(base_cfg, mapping_plan)
            searched = True
            if store is not None:
                store.store(cache_key, plan, trace)
        engine = engine.with_plan(plan)

    # Final abstract pass under the frozen plan: validates every traced
    # layer resolves against the tuned plan, and re-prices the trace onto
    # the engine's ledger — but only onto a FRESH (empty) ledger, so a
    # live ledger already carrying scoped runtime events (a serving
    # engine) is never polluted with untagged compile-time duplicates.
    # Skipped entirely when the plan is unchanged and there is nothing to
    # price: capture_trace already resolved every layer under it.
    if autotune is not None or engine.ledger is not None:
        final = engine
        if final.ledger is not None and len(final.ledger.events):
            final = final.with_ledger(None)
        if final.key is None:
            final = final.with_key(jax.random.PRNGKey(0))  # same ledger obj
        with obs.span("rosa.freeze", cat="compile"):
            with engine_context(final):
                jax.eval_shape(functools.partial(apply_fn, final),
                               *example_args)

    program = Program(apply_fn, engine, trace,
                      donate_argnums=donate_argnums, searched=searched,
                      cache_hit=cache_hit, cache_key=cache_key)

    if verify != "off":
        # lazy import: rosa must stay importable without the analysis
        # package, and analysis imports rosa types for its CLI targets
        from repro import analysis as A
        report = A.verify_program(program, example_args)
        if verify == "error" and report.errors:
            raise A.VerificationError(report)
        if report.findings:
            import warnings
            for f in report.findings:
                warnings.warn(f"rosa.compile verification: {f}",
                              stacklevel=2)
    return program
