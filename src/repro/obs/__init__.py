"""repro.obs — spans, metrics, and trace export for the whole pipeline.

The observability layer the serving/robustness roadmap items build on:

* `trace` — hierarchical span tracer with Chrome-trace JSON export
  (Perfetto-loadable); ambient installation via `tracing`, zero-cost
  module-level helpers (`span`, `instant`, `counter`, async events);
* `metrics` — thread-safe registry of counters/gauges/bounded histograms
  with bench-schema and Prometheus exports, plus `jax.monitoring` hooks
  for XLA retrace / compile-cache counters;
* `energy` — `EnergyTrack`, bridging `rosa.EnergyLedger` step pricing
  onto the trace timeline as cumulative counter tracks;
* `cli` — ``python -m repro.obs summarize`` trace summarizer.
"""

from repro.obs.energy import EnergyTrack
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    install_jax_hooks,
    registry,
    swap_registry,
)
from repro.obs.trace import (
    Tracer,
    async_begin,
    async_end,
    async_instant,
    counter,
    current_tracer,
    enabled,
    instant,
    span,
    traced,
    tracing,
)

__all__ = [
    "Counter",
    "EnergyTrack",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "Tracer",
    "async_begin",
    "async_end",
    "async_instant",
    "counter",
    "current_tracer",
    "enabled",
    "install_jax_hooks",
    "instant",
    "registry",
    "span",
    "swap_registry",
    "traced",
    "tracing",
]
