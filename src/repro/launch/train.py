"""End-to-end LM training driver (deliverable b's main example).

Runs real optimization steps on whatever devices exist (CPU in this
container, TPU pod in production — same code path):

  * builds the model from an arch config (full or --smoke reduced),
  * shards params/opt-state/batch via the logical rules if >1 device,
  * deterministic TokenPipeline (step -> batch; elastic restart-safe),
  * AdamW + cosine schedule + grad clip (+ optional bf16 compression),
  * atomic keep-K checkpointing with resume (--resume),
  * straggler/fault policy: the data pipeline is stateless so any step can
    be re-issued; SIGTERM-safe checkpoint on exit.

Example (CPU, ~100M-param model, a few hundred steps):
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-32b --smoke \
      --d-model 512 --n-layers 8 --steps 300 --batch 8 --seq 256
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax

from repro.checkpoint import CheckpointManager, restore
from repro.configs import get_config, get_smoke
from repro.data import TokenPipeline
from repro.distributed.sharding import (TRAIN_RULES, param_shardings,
                                        tree_shardings, use_sharding)
from repro.launch.mesh import make_test_mesh
from repro.launch.steps import (init_opt_state, make_train_step,
                                opt_state_shardings)
from repro.models.model import ShapeSpec, build_model, make_inputs
from repro.optim import AdamWConfig, cosine_schedule


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-32b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config for this arch")
    ap.add_argument("--d-model", type=int, default=0)
    ap.add_argument("--n-layers", type=int, default=0)
    ap.add_argument("--d-ff", type=int, default=0)
    ap.add_argument("--vocab", type=int, default=0)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="results/ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--data-axis", type=int, default=0,
                    help="data-parallel ways (0 = all devices)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0,
                    help="base PRNG seed for init and data")
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    if args.d_model:
        cfg = dataclasses.replace(cfg, d_model=args.d_model)
    if args.n_layers:
        cfg = dataclasses.replace(cfg, n_layers=args.n_layers)
    if args.d_ff:
        cfg = dataclasses.replace(cfg, d_ff=args.d_ff)
    if args.vocab:
        cfg = dataclasses.replace(cfg, vocab=args.vocab)
    bundle = build_model(cfg)
    print(f"arch={cfg.name} params={bundle.n_params:,}")

    n_dev = len(jax.devices())
    dp = args.data_axis or n_dev
    mesh = make_test_mesh(data=dp, model=n_dev // dp) if n_dev > 1 else None

    key = jax.random.PRNGKey(args.seed)
    opt_cfg = AdamWConfig(lr=cosine_schedule(args.lr, args.warmup,
                                             args.steps))
    step_fn = make_train_step(bundle, opt_cfg,
                              grad_compress=args.compress_grads)

    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=args.seq,
                         global_batch=args.batch, seed=args.seed)
    shape = ShapeSpec("cli", "train", args.seq, args.batch)

    ckpt = CheckpointManager(args.ckpt_dir, every=args.ckpt_every, keep=3)
    start = 0

    if mesh is not None:
        rules = TRAIN_RULES
        with use_sharding(mesh, rules):
            p_sh = param_shardings(bundle.skeleton, mesh, rules)
            params = jax.jit(bundle.init, out_shardings=p_sh)(key)
            o_sh = opt_state_shardings(p_sh, args.compress_grads)
            opt = jax.jit(
                lambda p: init_opt_state(p, args.compress_grads),
                out_shardings=o_sh)(params)
            _, batch_axes = make_inputs(cfg, shape)
            b_sh = tree_shardings(
                jax.eval_shape(lambda: pipe.batch(0)), batch_axes, mesh,
                rules)
            jit_step = jax.jit(step_fn, in_shardings=(p_sh, o_sh, b_sh),
                               donate_argnums=(0, 1))
    else:
        params = bundle.init(key)
        opt = init_opt_state(params, args.compress_grads)
        jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

    if args.resume and ckpt.latest() is not None:
        start = ckpt.latest()
        state = restore(args.ckpt_dir, start,
                        {"params": params, "opt": opt})
        params, opt = state["params"], state["opt"]
        print(f"resumed from step {start}")

    ctx = use_sharding(mesh, TRAIN_RULES) if mesh is not None else None
    if ctx:
        ctx.__enter__()
    t0 = time.time()
    try:
        for step in range(start, args.steps):
            batch = pipe.batch(step)
            params, opt, metrics = jit_step(params, opt, batch)
            if step % args.log_every == 0 or step == args.steps - 1:
                loss = float(metrics["loss"])
                gn = float(metrics["grad_norm"])
                dt = time.time() - t0
                print(f"step {step:5d}  loss {loss:7.4f}  |g| {gn:8.3f}  "
                      f"{dt:6.1f}s", flush=True)
            ckpt.maybe_save(step + 1, {"params": params, "opt": opt},
                            meta={"arch": cfg.name})
    finally:
        if ctx:
            ctx.__exit__(None, None, None)
    print(f"done: {args.steps - start} steps in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
