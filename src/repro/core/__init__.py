"""ROSA core: the paper's contribution as composable JAX modules.

  constants   device constants (Tables 2-3), modes, OPE configs
  mrr         noise-aware voltage->weight chain (Eqs. 3-8) + inverse
  quant       8-bit quantization, signed-digit / PAM plane decomposition
  osa         optical shift-and-add semantics (Eqs. 1-2) + non-idealities
  energy      event-count energy/latency/EDP model (Sec. 3.4)
  mapping     layer-wise hybrid IS/WS mapping (Sec. 3.5)
  dse         OPE array design-space exploration (Fig. 7)

The optical MAC itself (rosa_matmul/RosaConfig) and all per-layer routing
live in `repro.rosa` — compile models with `rosa.compile`.
"""

from repro.core import constants, dse, energy, mapping, mrr, osa, quant  # noqa: F401
