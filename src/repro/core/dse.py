"""OPE array-size design-space exploration (paper Sec. 3.5, Fig. 7).

Sweeps (R, C) under the physical constraints C <= MAX_WDM_CHANNELS and
T*R*C <= MAX_TOTAL_MRRS (T auto-filled to the budget), evaluates the EDP of
every workload network, and aggregates with

    G     = (prod_n EDP_n)^(1/N)            # balanced geometric mean
    W_max = max_n EDP_n                      # worst case
    M     = (1-lambda) * G + lambda * W_max  # robust efficiency metric

EDPs are expressed *relative to a reference config per workload* before
aggregation (the paper reports "relative EDP" vs. the compact 4x4 array) so
no single heavy network dominates the geomean.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from repro.core import energy as E
from repro.core.constants import (COMPACT_4X4, DEAP_HIGH_CHANNEL, ComputeMode,
                                  Mapping, MAX_TOTAL_MRRS, MAX_WDM_CHANNELS,
                                  OPEConfig)


@dataclasses.dataclass
class Workload:
    name: str
    layers: list[E.LayerShape]


@dataclasses.dataclass
class DSEPoint:
    ope: OPEConfig
    edp_per_workload: dict[str, float]
    rel_edp: dict[str, float]
    geomean: float
    worst: float
    metric: float

    @property
    def label(self) -> str:
        return f"R={self.ope.rows},C={self.ope.cols},T={self.ope.tiles}"


def default_candidates(include_baselines: bool = True) -> list[OPEConfig]:
    """The sweep grid: all power-of-two-ish (R, C) within constraints."""
    rs = [1, 2, 4, 8, 16, 32, 64, 128]
    cs = [1, 2, 4, 8]
    cands = []
    for r in rs:
        for c in cs:
            if r * c <= MAX_TOTAL_MRRS and c <= MAX_WDM_CHANNELS:
                cands.append(OPEConfig(rows=r, cols=c))
    if include_baselines:
        cands.append(DEAP_HIGH_CHANNEL)      # violates C<=8; kept for comparison
    return cands


def evaluate(ope: OPEConfig,
             workloads: Sequence[Workload],
             reference: OPEConfig = COMPACT_4X4,
             lam: float = 0.3,
             mapping: Mapping = Mapping.WS,
             mode: ComputeMode = ComputeMode.MIXED,
             osa: E.OSAEnergyConfig = E.NO_OSA,
             batch: int = 1) -> DSEPoint:
    """EDP of every workload on `ope`, relative to `reference`, aggregated."""
    edp, rel = {}, {}
    for wl in workloads:
        e = E.network_energy(wl.layers, ope, mapping, mode, osa, batch=batch).edp
        e_ref = E.network_energy(wl.layers, reference, mapping, mode, osa,
                                 batch=batch).edp
        edp[wl.name] = e
        rel[wl.name] = e / e_ref
    g = math.exp(sum(math.log(v) for v in rel.values()) / len(rel))
    w = max(rel.values())
    return DSEPoint(ope=ope, edp_per_workload=edp, rel_edp=rel,
                    geomean=g, worst=w, metric=(1 - lam) * g + lam * w)


def sweep(workloads: Sequence[Workload],
          candidates: Sequence[OPEConfig] | None = None,
          lam: float = 0.3,
          **kw) -> list[DSEPoint]:
    """Full DSE; returns points sorted by the robust metric M (best first)."""
    candidates = candidates or default_candidates()
    pts = [evaluate(ope, workloads, lam=lam, **kw) for ope in candidates]
    pts.sort(key=lambda p: p.metric)
    return pts


def best(workloads: Sequence[Workload], **kw) -> DSEPoint:
    return sweep(workloads, **kw)[0]
