"""Jitted public wrapper for the SSD scan kernel.

Shapes in model-land are (B, L, H, P) with per-head state (B, H, S, P); this
wrapper folds (B, H) -> BH, pads L to the chunk multiple with identity steps
(log a = 0, b = c = 0 contribute nothing and leave the state untouched), and
falls back to interpret mode off-TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import on_tpu
from repro.kernels.ssd_scan.ssd_scan import ssd_scan_pallas


def preflight(bsz: int, l: int, h: int, p: int, s_dim: int, *,
              chunk: int = 128) -> dict:
    """Static tileability/VMEM report for an SSD scan — no launch.

    Mirrors `ssd_scan`'s layout: (B, H) folds to BH rows, L pads to the
    chunk multiple with identity steps, and each grid step holds one
    chunk of x/loga/b/c plus the running (S, P) state scratch in VMEM."""
    issues: list[str] = []
    soft: list[str] = []
    if min(bsz, l, h, p, s_dim, chunk) <= 0:
        issues.append(f"non-positive dimension in B,L,H,P,S,chunk="
                      f"{bsz},{l},{h},{p},{s_dim},{chunk}")
        return {"kernel": "ssd_scan", "grid": (0, 0), "vmem_bytes": 0,
                "pad_waste": 0.0, "issues": issues, "soft_issues": soft}
    # P/S are lane dims the compiler CAN pad to 128 — legal, but any
    # shortfall idles lanes on every matmul, so they are soft issues.
    if p % 128:
        soft.append(f"P={p} not a multiple of 128 (lane dim of x/y): "
                    "lanes idle on every chunk matmul")
    if s_dim % 128:
        soft.append(f"S={s_dim} not a multiple of 128 (lane dim of b/c): "
                    "lanes idle on every chunk matmul")
    if chunk % 8:
        issues.append(f"chunk={chunk} not a multiple of 8 (sublane tile)")
    lp = -(-l // chunk) * chunk
    vmem = 4 * (2 * (chunk * p + chunk + 2 * chunk * s_dim)  # in blocks
                + 2 * chunk * p                              # out block
                + s_dim * p)                                 # state scratch
    return {"kernel": "ssd_scan", "grid": (bsz * h, lp // chunk),
            "vmem_bytes": vmem, "pad_waste": lp / l - 1.0,
            "issues": issues, "soft_issues": soft}


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x: jax.Array, loga: jax.Array, b: jax.Array, c: jax.Array,
             chunk: int = 128):
    """x: (B, L, H, P); loga: (B, L, H); b, c: (B, L, G, S) with G head
    groups (G divides H, heads within a group share B/C — Mamba-2's GVA).

    Returns (y: (B, L, H, P), state: (B, H, S, P)).
    """
    bsz, l, h, p = x.shape
    g = b.shape[2]
    s_dim = b.shape[-1]
    rep = h // g

    # broadcast groups to heads, fold (B, H) -> BH
    bh = bsz * h
    xf = x.transpose(0, 2, 1, 3).reshape(bh, l, p)
    lf = loga.transpose(0, 2, 1).reshape(bh, l)
    bf = jnp.repeat(b, rep, axis=2).transpose(0, 2, 1, 3).reshape(bh, l, s_dim)
    cf = jnp.repeat(c, rep, axis=2).transpose(0, 2, 1, 3).reshape(bh, l, s_dim)

    pad = (-l) % chunk
    if pad:
        xf = jnp.pad(xf, ((0, 0), (0, pad), (0, 0)))
        lf = jnp.pad(lf, ((0, 0), (0, pad)))          # log a = 0 -> a = 1
        bf = jnp.pad(bf, ((0, 0), (0, pad), (0, 0)))  # b = 0 -> no state write
        cf = jnp.pad(cf, ((0, 0), (0, pad), (0, 0)))

    y, sf = ssd_scan_pallas(xf, lf, bf, cf, chunk=chunk,
                            interpret=not on_tpu())
    y = y[:, :l].reshape(bsz, h, l, p).transpose(0, 2, 1, 3)
    sf = sf.reshape(bsz, h, s_dim, p)
    return y, sf
