"""MRR voltage->weight physics (paper Sec. 3.3, Table 2, Fig. 5)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:                      # degrade gracefully: property tests fall back to
    import hypothesis as hp            # fixed-sample parametrization when
    import hypothesis.strategies as st  # hypothesis is not installed
except ModuleNotFoundError:
    hp = st = None

from repro.core import constants as C
from repro.core import mrr


def test_eta_lambda_p_matches_eq9():
    # Eq. (9): 0.238 nm/mW from Table 2 constants
    assert abs(C.ETA_LAMBDA_P_NM_PER_MW - 0.238) < 2e-3


def test_to_hold_power_matches_table3():
    # 0.5 * gamma / eta = 1.58 mW (paper Sec. 3.4)
    p = 0.5 * C.GAMMA_HWHM_NM / C.ETA_LAMBDA_P_NM_PER_MW
    assert abs(p - 1.58) < 0.02


def test_fig5b_max_shift_calibration():
    """1V -> 3V sweep must give exactly the paper's 0.740 nm shift."""
    p = mrr.DEFAULT_PARAMS
    d1 = mrr.delta_lambda(mrr.delta_t(jnp.asarray(1.0)))
    d3 = mrr.delta_lambda(mrr.delta_t(jnp.asarray(3.0)))
    assert abs(float(d3 - d1) - 0.740) < 1e-3


@pytest.mark.analog_guard
def test_transfer_curve_monotone_decreasing():
    v, w = mrr.transfer_curve(128)
    assert np.all(np.diff(np.asarray(w)) < 0)   # more V -> more detuned -> lower w


@pytest.mark.analog_guard
def test_roundtrip_identity_ideal():
    w = jnp.linspace(-1.0, 1.0, 41)
    w2 = mrr.realize_weights(w)
    np.testing.assert_allclose(np.asarray(w2), np.asarray(w), atol=2e-4)


def test_out_of_range_targets_saturate():
    w = jnp.asarray([-2.0, 2.0])
    w2 = mrr.realize_weights(w)
    np.testing.assert_allclose(np.asarray(w2), [-1.0, 1.0], atol=2e-3)


def _check_inverse(wt: float) -> None:
    v = mrr.voltage_of_weight(jnp.asarray(wt))
    w = mrr.weight_of_voltage(v)
    assert abs(float(w) - wt) < 1e-3


if hp is not None:
    @hp.given(st.floats(-0.999, 0.999))
    @hp.settings(max_examples=30, deadline=None)
    def test_inverse_is_exact_inverse(wt):
        _check_inverse(wt)
else:
    @pytest.mark.parametrize(
        "wt", [-0.999, -0.73, -0.25, 0.0, 0.31, 0.5, 0.85, 0.999])
    def test_inverse_is_exact_inverse(wt):
        _check_inverse(wt)


# ---------------------------------------------------------------------------
# Property-style invariants of the voltage<->weight physical chain
# (hypothesis-driven with the fixed-sample fallback, like the inverse test)
# ---------------------------------------------------------------------------
def _check_roundtrip_ideal(wt: float) -> None:
    """realize_weights under IDEAL noise is the identity on [q_min, q_max]."""
    w2 = mrr.realize_weights(jnp.asarray(wt))
    assert abs(float(w2) - wt) < 5e-4


def _check_voltage_monotone(w_lo: float, w_hi: float) -> None:
    """voltage_of_weight is strictly decreasing: larger weights sit closer
    to lambda_ref, i.e. need LESS detuning, i.e. less drive voltage."""
    v_lo = float(mrr.voltage_of_weight(jnp.asarray(w_lo)))
    v_hi = float(mrr.voltage_of_weight(jnp.asarray(w_hi)))
    assert v_lo > v_hi


def _check_saturation(wt: float) -> None:
    """Targets beyond [q_min, q_max] clip to the range edge (physical
    saturation of the transmission map)."""
    p = mrr.DEFAULT_PARAMS
    w2 = float(mrr.realize_weights(jnp.asarray(wt)))
    edge = p.q_max if wt > p.q_max else p.q_min
    assert abs(w2 - edge) < 2e-3


if hp is not None:
    @hp.given(st.floats(-1.0, 1.0))
    @hp.settings(max_examples=30, deadline=None)
    def test_roundtrip_identity_property(wt):
        _check_roundtrip_ideal(wt)

    @hp.given(st.floats(-0.999, 0.995), st.floats(1e-3, 0.5))
    @hp.settings(max_examples=30, deadline=None)
    def test_voltage_of_weight_monotone_property(w_lo, gap):
        _check_voltage_monotone(w_lo, min(w_lo + gap, 0.999))

    @hp.given(st.one_of(st.floats(1.0001, 50.0), st.floats(-50.0, -1.0001)))
    @hp.settings(max_examples=30, deadline=None)
    def test_saturation_clipping_property(wt):
        _check_saturation(wt)
else:
    @pytest.mark.parametrize(
        "wt", [-1.0, -0.87, -0.31, 0.0, 0.22, 0.64, 0.93, 1.0])
    def test_roundtrip_identity_property(wt):
        _check_roundtrip_ideal(wt)

    @pytest.mark.parametrize("w_lo,w_hi", [(-0.999, -0.5), (-0.5, 0.0),
                                           (-0.1, 0.1), (0.0, 0.7),
                                           (0.7, 0.999)])
    def test_voltage_of_weight_monotone_property(w_lo, w_hi):
        _check_voltage_monotone(w_lo, w_hi)

    @pytest.mark.parametrize("wt", [1.001, 1.5, 7.0, -1.001, -2.0, -40.0])
    def test_saturation_clipping_property(wt):
        _check_saturation(wt)


def test_weight_noise_std_jitted_once(key):
    """The MC sampler reuses one compiled vmap across profiler-style calls
    and rejects non-static sample counts."""
    s1 = mrr.weight_noise_std(jnp.zeros(()), key, 128)
    s2 = mrr.weight_noise_std(jnp.zeros(()), key, 128)
    assert float(s1) == float(s2)
    before = mrr._weight_noise_std._cache_size()
    for _ in range(4):
        mrr.weight_noise_std(jnp.full((), 0.3), key, 128)
    assert mrr._weight_noise_std._cache_size() == before + 1  # one new shape
    with pytest.raises(ValueError):
        mrr.weight_noise_std(jnp.zeros(()), key, jnp.asarray(16))
    with pytest.raises(ValueError):
        mrr.weight_noise_std(jnp.zeros(()), key, 0)


def test_noise_statistics(key):
    """Realized-weight std under paper noise is small but nonzero and
    grows with sigma."""
    w = jnp.zeros((256,))
    s1 = mrr.weight_noise_std(jnp.zeros(()), key, 256)
    s2 = mrr.weight_noise_std(
        jnp.zeros(()), key, 256,
        noise=mrr.NoiseModel(sigma_dac=0.04, sigma_th=0.08))
    assert 1e-4 < float(s1) < 0.2
    assert float(s2) > float(s1)


def test_noisy_realization_unbiased(key):
    w = jnp.full((4096,), 0.3)
    out = mrr.realize_weights(w, key, noise=mrr.PAPER_NOISE)
    assert abs(float(jnp.mean(out)) - 0.3) < 0.01
