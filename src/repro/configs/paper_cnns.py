"""GEMM-lowered layer tables for the paper's workloads (Sec. 4, Fig. 7).

Convolutions are im2col'd: M = output pixels, K = C_in*kh*kw (per group),
N = C_out.  These tables drive the analytical energy/EDP model — Fig. 7's
array-size DSE aggregates over exactly this workload mix (AlexNet ->
MobileNet V3 + GPT-2 Medium + ViT), and Table 4 / Figs. 8-10 use the CNN
subsets.  CIFAR-10-scale spatial dims (32x32 inputs), matching the paper's
accuracy experiments; GPT-2M/ViT use seq_len=1024/197 tokens.

The REDUCED (trainable-on-CPU) behavioural variants live in
models/cnn.py::LITE_MODELS; layer names match one-to-one so the layer-wise
noise profiles measured on the lite nets can be joined against these
full-size EDP rows (DESIGN.md §8 records this calibration compromise).
"""

from __future__ import annotations

from repro.core.energy import LayerShape


def _conv(name, hw, cin, cout, k=3, stride=1, groups=1):
    m = (hw // stride) ** 2
    return LayerShape(name, m=m, k=cin * k * k, n=cout, groups=groups,
                      kind="dwconv" if groups == cin else "conv")


def _fc(name, cin, cout):
    return LayerShape(name, m=1, k=cin, n=cout, kind="fc")


def _gemm(name, m, k, n):
    return LayerShape(name, m=m, k=k, n=n, kind="gemm")


ALEXNET = [
    _conv("conv1", 32, 3, 64),
    _conv("conv2", 16, 64, 192),
    _conv("conv3", 8, 192, 384),
    _conv("conv4", 8, 384, 256),
    _conv("conv5", 8, 256, 256),
    _fc("fc1", 256 * 4 * 4, 4096),
    _fc("fc2", 4096, 4096),
    _fc("fc3", 4096, 10),
]

VGG16 = (
    [_conv("conv1_1", 32, 3, 64), _conv("conv1_2", 32, 64, 64),
     _conv("conv2_1", 16, 64, 128), _conv("conv2_2", 16, 128, 128),
     _conv("conv3_1", 8, 128, 256), _conv("conv3_2", 8, 256, 256),
     _conv("conv3_3", 8, 256, 256),
     _conv("conv4_1", 4, 256, 512), _conv("conv4_2", 4, 512, 512),
     _conv("conv4_3", 4, 512, 512),
     _conv("conv5_1", 2, 512, 512), _conv("conv5_2", 2, 512, 512),
     _conv("conv5_3", 2, 512, 512)]
    + [_fc("fc1", 512, 512), _fc("fc2", 512, 512), _fc("fc3", 512, 10)]
)

RESNET18 = (
    [_conv("conv1", 32, 3, 64)]
    + [_conv(f"l1_b{b}_c{c}", 32, 64, 64)
       for b in (1, 2) for c in (1, 2)]
    + [_conv("l2_b1_c1", 16, 64, 128), _conv("l2_b1_c2", 16, 128, 128),
       _conv("l2_b2_c1", 16, 128, 128), _conv("l2_b2_c2", 16, 128, 128)]
    + [_conv("l3_b1_c1", 8, 128, 256), _conv("l3_b1_c2", 8, 256, 256),
       _conv("l3_b2_c1", 8, 256, 256), _conv("l3_b2_c2", 8, 256, 256)]
    + [_conv("l4_b1_c1", 4, 256, 512), _conv("l4_b1_c2", 4, 512, 512),
       _conv("l4_b2_c1", 4, 512, 512), _conv("l4_b2_c2", 4, 512, 512)]
    + [_fc("fc", 512, 10)]
)

# MobileNetV3-small-style: pointwise expand / depthwise / pointwise project.
# Small kernels + depthwise = the poor-utilization workload of Sec. 3.5.
def _mb_block(tag, hw, cin, cexp, cout, k=3):
    return [
        LayerShape(f"{tag}_exp", m=hw * hw, k=cin, n=cexp, kind="conv"),
        # depthwise: cexp independent (M, k*k, 1) sub-GEMMs
        LayerShape(f"{tag}_dw", m=hw * hw, k=cexp * k * k, n=cexp,
                   groups=cexp, kind="dwconv"),
        LayerShape(f"{tag}_prj", m=hw * hw, k=cexp, n=cout, kind="conv"),
    ]


MOBILENET_V3 = (
    [_conv("conv_stem", 32, 3, 16)]
    + _mb_block("mb1", 16, 16, 16, 16)
    + _mb_block("mb2", 16, 16, 72, 24)
    + _mb_block("mb3", 8, 24, 88, 24)
    + _mb_block("mb4", 8, 24, 96, 40, k=5)
    + _mb_block("mb5", 4, 40, 240, 40, k=5)
    + _mb_block("mb6", 4, 40, 120, 48, k=5)
    + _mb_block("mb7", 4, 48, 288, 96, k=5)
    + [_fc("head", 96, 576), _fc("fc", 576, 10)]
)

# GPT-2 Medium: 24L, d=1024; per-layer projection GEMMs at seq 1024.
_GPT2M_LAYER = lambda i: [
    _gemm(f"h{i}_qkv", 1024, 1024, 3072),
    _gemm(f"h{i}_proj", 1024, 1024, 1024),
    _gemm(f"h{i}_fc", 1024, 1024, 4096),
    _gemm(f"h{i}_out", 1024, 4096, 1024),
]
GPT2_MEDIUM = [l for i in range(24) for l in _GPT2M_LAYER(i)]

# ViT-Base/16 at 224px: 197 tokens, d=768, 12 layers.
_VIT_LAYER = lambda i: [
    _gemm(f"b{i}_qkv", 197, 768, 2304),
    _gemm(f"b{i}_proj", 197, 768, 768),
    _gemm(f"b{i}_fc", 197, 768, 3072),
    _gemm(f"b{i}_out", 197, 3072, 768),
]
VIT_BASE = [_gemm("patch_embed", 196, 768, 768)] \
    + [l for i in range(12) for l in _VIT_LAYER(i)]

WORKLOADS = {
    "alexnet": ALEXNET,
    "vgg16": VGG16,
    "resnet18": RESNET18,
    "mobilenet_v3": MOBILENET_V3,
    "gpt2_medium": GPT2_MEDIUM,
    "vit_base": VIT_BASE,
}

CNN_WORKLOADS = {k: WORKLOADS[k]
                 for k in ("alexnet", "vgg16", "resnet18", "mobilenet_v3")}
