"""Pure-jnp oracle for the MRR voltage->weight transfer kernel.

Exactly core.mrr.realize_weights, but taking the two Gaussian noise draws as
explicit operands so the kernel and oracle consume identical randomness.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import mrr


def mrr_transfer_ref(w_target: jnp.ndarray,
                     eps_dac: jnp.ndarray,
                     eps_th: jnp.ndarray,
                     sigma_dac: float = 0.02,
                     sigma_th: float = 0.04,
                     p: mrr.MRRParams = mrr.DEFAULT_PARAMS) -> jnp.ndarray:
    """w_target -> programming voltage -> perturbed chain -> realized w.

    eps_dac/eps_th: standard-normal draws, same shape as w_target.
    """
    v = mrr.voltage_of_weight(w_target, p)
    v = jnp.clip(v, p.v_min, p.v_max)
    v = v + sigma_dac * eps_dac
    dt = mrr.delta_t(v, p) + sigma_th * eps_th
    lam = p.lambda_0 + mrr.delta_lambda(dt, p)
    td = mrr.t_diff(lam, p)
    t_hi, t_lo = mrr.transmission_endpoints(p)
    return p.q_min + p.q_rng * (td - t_lo) / (t_hi - t_lo)
