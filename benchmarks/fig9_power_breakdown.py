"""Fig. 9 reproduction: component-wise power with / without OSA.

Average power = component energy / runtime for four CNN workloads on the
(8,8) array.  The paper's observation to reproduce: OSA cuts OAC (PD+TIA)
and ADC power, and also the partial-sum SRAM + main-memory traffic.
"""

from __future__ import annotations

from repro.configs.paper_cnns import CNN_WORKLOADS
from repro.core import energy as E
from repro.core.constants import ROSA_OPTIMAL

COMPONENTS = ("laser", "mrr_static", "odl_static", "sram_leak", "eo_mod",
              "dac_prog", "pd_tia", "adc", "sram_dyn", "dram")


def run(verbose: bool = True) -> dict:
    out = {}
    for name, layers in CNN_WORKLOADS.items():
        rows = {}
        for tag, osa in (("no_osa", E.NO_OSA), ("osa", E.OSA_OPTIMAL)):
            bd = E.network_energy(layers, ROSA_OPTIMAL, osa=osa,
                                  batch=128)
            rows[tag] = {c: getattr(bd, c) / bd.latency
                         for c in COMPONENTS}
            rows[tag]["total"] = bd.energy / bd.latency
        out[name] = rows
    if verbose:
        for name, rows in out.items():
            print(f"\n{name}  (avg power [W])")
            print(f"  {'component':12s} {'no OSA':>11s} {'with OSA':>11s}")
            for c in COMPONENTS + ("total",):
                a, b = rows["no_osa"][c], rows["osa"][c]
                mark = " <-" if b < a * 0.7 and a > 1e-6 else ""
                print(f"  {c:12s} {a:11.4e} {b:11.4e}{mark}")
    return out


if __name__ == "__main__":
    run()
