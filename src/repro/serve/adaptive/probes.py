"""Golden-token drift probes: cheap accuracy telemetry for the controller.

A probe is a tiny fixed batch of deterministic prompts run through the
serving model's prefill forward under the CURRENT thermal residual, scored
as argmax agreement against the GOLDEN reference: this chip, zero drift,
the fixed probe noise key — i.e. the fleet's behavior at calibration time.
(The clean no-chip reference would charge the probe for static fabrication
variation the controller cannot act on; against golden, agreement is
exactly 1.0 at zero residual and decays only with drift.)  The evaluator
is `robust.ensemble.make_plan_eval` verbatim — the same one-hot-gate
shared program that backs the sensitivity degradation matrix — so one
compile serves every probe use:

  * plain health probe        sel = current plan, g = all-ones
  * per-layer localization    g one-hot (which layer is melting?)
  * replan measurement        (sel, g one-hot) grid -> degradation rows
                              in the exact `{layer: {mapping: pp}}` format
                              `rosa.compile(degradation=...)` consumes

The residual offset, the mapping selector and the analog gates are all
TRACED arguments, so the controller probes every few ticks without ever
retracing.  Probes run with the ledger detached: telemetry forwards must
not pollute the serving energy accounting.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.constants import Mapping
from repro.robust import variation as V
from repro.robust.ensemble import (chunk_eval_set, chunked_argmax_preds,
                                   make_plan_eval)
from repro.rosa.engine import engine_context

# same floor the sensitivity matrix applies: a measured-zero row must not
# make a mapping look infinitely safe to the accuracy-aware plan search
_ROW_FLOOR = 1e-3


@dataclasses.dataclass(frozen=True)
class ProbeConfig:
    """Probe batch shape + determinism knobs (frozen, hashable)."""

    n_probes: int = 8      # prompts per probe batch
    prompt_len: int = 6    # tokens per prompt
    seed: int = 2024       # prompt content + per-probe noise keys


def plan_selector(engine, names) -> jnp.ndarray:
    """The current plan as a mapping-gate vector (1 = IS, else WS-side)."""
    mp = engine.plan.mapping_plan()
    return jnp.asarray([1.0 if mp.get(n) is Mapping.IS else 0.0
                        for n in names], jnp.float32)


class ProbeSet:
    """One compiled probe evaluator bound to a serving program's engine.

    Construction traces NOTHING; the first `agreement` call compiles the
    shared gated evaluator, and every later call (any residual, any
    selector, any gate vector) re-dispatches it.
    """

    def __init__(self, bundle, program, cfg: ProbeConfig = ProbeConfig()):
        if not program.engine.variation:
            raise ValueError(
                "drift probes need a pinned chip: build the serving "
                "program with scfg.variation_seed set")
        self.cfg = cfg
        self.names = list(program.trace.names)
        self.chip = dict(program.engine.variation)
        self.tokens = jax.random.randint(
            jax.random.PRNGKey(cfg.seed),
            (cfg.n_probes, cfg.prompt_len), 1, bundle.cfg.vocab, jnp.int32)

        def probe_apply(params, xc, eng):
            with engine_context(eng):
                logits, _ = bundle.prefill(params, {"tokens": xc})
            return logits                              # (B, V) last-token

        base_engine = program.engine.with_ledger(None)
        self._run = make_plan_eval(
            probe_apply, base_engine, self.names,
            eval_batch=cfg.n_probes, gated=True)
        self.sel = plan_selector(program.engine, self.names)
        self._ones = jnp.ones(len(self.names), jnp.float32)
        # ONE fixed probe noise key: probe scores are deterministic
        # functions of the residual alone (no per-tick per-shot jitter —
        # the detector sees drift, not dice)
        self._keys = jax.random.split(jax.random.PRNGKey(cfg.seed + 1), 1)
        names = self.names

        def preds_fn(params, var, key, sel, g):
            eng = base_engine.with_variation(var).with_key(key) \
                .with_mapping_gates({n: sel[i]
                                     for i, n in enumerate(names)}) \
                .with_gates({n: g[i] for i, n in enumerate(names)})
            return chunked_argmax_preds(
                probe_apply, params,
                chunk_eval_set(self.tokens, cfg.n_probes), eng)

        self._preds = jax.jit(preds_fn)
        self._golden = None      # resolved on first scoring (needs params)

    def golden(self, params) -> jnp.ndarray:
        """Next-token argmax of THIS chip at zero residual under the fixed
        probe key — the calibration-time behavior every probe is scored
        against.  Computed once; survives replans (the yardstick must not
        move when the plan does)."""
        if self._golden is None:
            self._golden = self._preds(params, self.chip, self._keys[0],
                                       self.sel, self._ones)
        return self._golden

    def rebind(self, program) -> None:
        """Point the probe scoring at a re-planned program.

        The evaluator itself is NOT rebuilt — mapping choice is a traced
        `sel` vector, so only the selector changes (the trace, chip and
        prompt shapes are identical by construction)."""
        self.sel = plan_selector(program.engine, self.names)

    def agreement(self, params, resid_k: float, tick: int = 0, *,
                  sel=None, g=None) -> float:
        """Golden-token agreement in [0, 1] under thermal residual
        `resid_k` [K]: fraction of probe prompts whose next-token argmax
        matches the zero-drift golden reference (== 1.0 at resid 0)."""
        golden = self.golden(params)
        shifted = V.shift_thermal(self.chip, jnp.float32(resid_k))
        ens1 = jax.tree.map(lambda leaf: jnp.asarray(leaf)[None], shifted)
        accs, _, _ = self._run(params, self.tokens, golden, ens1,
                               self._keys,
                               self.sel if sel is None else sel,
                               self._ones if g is None else g)
        return float(np.asarray(accs)[0]) / 100.0

    def degradation_rows(self, params, resid_k: float,
                         tick: int = 0) -> dict:
        """Measure `{layer: {mapping.value: drop_pp}}` at the current
        residual — the REPLAN input.  Every (mapping x layer) cell is one
        re-dispatch of the shared evaluator with a one-hot `g` (only that
        layer analog) and a constant `sel` (its orientation)."""
        eye = np.eye(len(self.names), dtype=np.float32)
        rows: dict[str, dict[str, float]] = {n: {} for n in self.names}
        for mp in (Mapping.WS, Mapping.IS):
            sel = jnp.full(len(self.names),
                           1.0 if mp is Mapping.IS else 0.0, jnp.float32)
            for i, name in enumerate(self.names):
                agree = self.agreement(params, resid_k, tick,
                                       sel=sel, g=jnp.asarray(eye[i]))
                rows[name][mp.value] = max(100.0 * (1.0 - agree),
                                           _ROW_FLOOR)
        return rows
