from repro.training.cnn_train import evaluate_cnn, train_cnn  # noqa
