"""Pallas TPU kernel: Mamba-2 SSD chunked scan.

TPU-native realization of the state-space duality algorithm: the sequence is
split into chunks of Q steps; each chunk is three MXU matmuls

    att  = (C @ B^T) . tril(decay)         (Q, Q)
    Y    = att @ X + exp(lcum) * (C @ S)   (Q, P)
    S'   = exp(ltot) * S + (B * w)^T @ X   (S, P)

with the running state S carried across the chunk grid dimension in a VMEM
scratch accumulator — the classic sequential-innermost-grid-dim pattern.
The (batch*heads) grid dimension is parallel; the chunk dimension is
"arbitrary" (sequential) so the scratch state persists step to step and is
re-zeroed whenever a new (batch, head) row begins.

Chunk length Q and head dim P default to 128 to keep every matmul
MXU-shaped; d_state S is the lane dim of the B/C blocks (Mamba-2 uses
64-256, already aligned).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels import tpu_compiler_params


def _kernel(x_ref, loga_ref, b_ref, c_ref, y_ref, sf_ref, state_ref,
            *, n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0]                                  # (Q, P)
    loga = loga_ref[0]                            # (Q,)
    b = b_ref[0]                                  # (Q, S)
    c = c_ref[0]                                  # (Q, S)
    q = x.shape[0]

    lcum = jnp.cumsum(loga)
    ltot = lcum[-1]
    # intra-chunk: masked decay kernel (rows i, cols j), j <= i
    dmat = jnp.exp(lcum[:, None] - lcum[None, :])
    row = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    att = jnp.dot(c, b.T, preferred_element_type=jnp.float32)
    att = att * jnp.where(col <= row, dmat, 0.0)
    y = jnp.dot(att, x, preferred_element_type=jnp.float32)
    # inter-chunk: contribution of the carried state
    s = state_ref[...]                            # (S, P)
    y = y + jnp.exp(lcum)[:, None] * jnp.dot(c, s,
                                             preferred_element_type=jnp.float32)
    # carry the state forward
    w = jnp.exp(ltot - lcum)
    s_new = jnp.exp(ltot) * s + jnp.dot((b * w[:, None]).T, x,
                                        preferred_element_type=jnp.float32)
    state_ref[...] = s_new
    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _flush():
        sf_ref[0] = s_new.astype(sf_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(x: jax.Array, loga: jax.Array, b: jax.Array,
                    c: jax.Array, *, chunk: int = 128,
                    interpret: bool = False):
    """Batched SSD scan.

    x: (BH, L, P); loga: (BH, L) = log decay; b, c: (BH, L, S).
    Returns (y: (BH, L, P), s_final: (BH, S, P)).  L % chunk == 0.
    """
    bh, l, p = x.shape
    s_dim = b.shape[-1]
    assert l % chunk == 0, (l, chunk)
    n_chunks = l // chunk

    kernel = functools.partial(_kernel, n_chunks=n_chunks)
    grid = (bh, n_chunks)
    y, sf = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk), lambda i, j: (i, j)),
            pl.BlockSpec((1, chunk, s_dim), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, s_dim), lambda i, j: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, s_dim, p), lambda i, j: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, l, p), jnp.float32),
            jax.ShapeDtypeStruct((bh, s_dim, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((s_dim, p), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, loga, b, c)
    return y, sf
