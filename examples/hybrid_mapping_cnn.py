"""The paper's hybrid-mapping pipeline on one CNN, end to end:

QAT-train AlexNet-lite on synth-CIFAR -> profile per-layer IS/WS noise
sensitivity (Fig. 6) -> join with the full-size EDP table -> balanced-
metric plan (Sec. 3.5) -> evaluate accuracy + EDP vs WS/IS/analog.

Run:  PYTHONPATH=src python examples/hybrid_mapping_cnn.py [--steps 250]
"""

import argparse

from benchmarks.table4_hybrid import run_model


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="alexnet")
    ap.add_argument("--steps", type=int, default=250)
    args = ap.parse_args()
    res = run_model(args.model, steps=args.steps, n_mc=2)
    plan = res["plan"]
    print("\nper-layer plan:")
    for name, mp in plan.items():
        print(f"  {name:10s} -> {mp}")
