"""``python -m repro.obs`` — summarize a Chrome trace file.

Reads a trace produced by `repro.obs.trace.Tracer.save` (or any Chrome
``traceEvents`` JSON) and prints three tables:

* **top spans by self-time** — "X" events aggregated by name, with the
  time spent in nested child spans subtracted, so the hot stage is
  visible without opening Perfetto;
* **per-request latency** — async "b"/"e" pairs (the scheduler's request
  lifecycle), with TTFT from the ``first_token`` "n" instant;
* **counter tails** — the final value of every counter track.

Output is deterministic for a given trace (sorted, fixed formatting), so
the golden test pins it exactly.
"""

from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict


def load_events(path: str) -> list[dict]:
    """The traceEvents list of `path` (accepts a bare JSON array too)."""
    with open(path) as f:
        doc = json.load(f)
    events = doc if isinstance(doc, list) else doc.get("traceEvents", [])
    if not isinstance(events, list):
        raise ValueError(f"{path}: no traceEvents array")
    return events


def span_self_times(events: list[dict]) -> dict[str, dict]:
    """Aggregate "X" events by name: {name: {count, total_us, self_us}}.

    Self-time subtracts the duration of children, where parenthood is time
    containment within one (pid, tid) — the same rule Perfetto applies.
    """
    by_track: dict[tuple, list[dict]] = defaultdict(list)
    for ev in events:
        if ev.get("ph") == "X":
            by_track[(ev.get("pid"), ev.get("tid"))].append(ev)

    agg: dict[str, dict] = defaultdict(
        lambda: {"count": 0, "total_us": 0.0, "self_us": 0.0})
    for track in by_track.values():
        # sort by start, longest first at equal start so parents precede
        track.sort(key=lambda e: (e["ts"], -e.get("dur", 0.0)))
        stack: list[dict] = []     # open ancestors, each with _child_us
        for ev in track:
            ts, dur = ev["ts"], ev.get("dur", 0.0)
            while stack and ts >= stack[-1]["ts"] + stack[-1].get("dur", 0.0):
                stack.pop()
            if stack:
                stack[-1]["_child_us"] = \
                    stack[-1].get("_child_us", 0.0) + dur
            ev["_child_us"] = 0.0
            stack.append(ev)
        for ev in track:
            a = agg[ev["name"]]
            a["count"] += 1
            a["total_us"] += ev.get("dur", 0.0)
            a["self_us"] += ev.get("dur", 0.0) - ev.pop("_child_us", 0.0)
    return dict(agg)


def request_table(events: list[dict]) -> list[dict]:
    """Per-request rows from async lifecycle events, sorted by begin time."""
    reqs: dict[tuple, dict] = {}
    for ev in events:
        ph = ev.get("ph")
        if ph not in ("b", "n", "e"):
            continue
        key = (ev.get("cat", ""), ev.get("id"))
        row = reqs.setdefault(key, {"id": ev.get("id"), "args": {}})
        if ph == "b":
            row["begin_us"] = ev["ts"]
            row["name"] = ev.get("name", "")
        elif ph == "e":
            row["end_us"] = ev["ts"]
            row["args"].update(ev.get("args", {}))
        elif ev.get("name") == "first_token":
            row["first_token_us"] = ev["ts"]
    rows = []
    for row in reqs.values():
        if "begin_us" not in row or "end_us" not in row:
            continue
        row["e2e_ms"] = (row["end_us"] - row["begin_us"]) / 1e3
        if "first_token_us" in row:
            row["ttft_ms"] = (row["first_token_us"] - row["begin_us"]) / 1e3
        rows.append(row)
    rows.sort(key=lambda r: (r["begin_us"], str(r["id"])))
    return rows


def counter_tails(events: list[dict]) -> dict[str, dict]:
    """Last sample of each counter track: {name: {series: value}}."""
    tails: dict[str, dict] = {}
    for ev in events:
        if ev.get("ph") == "C":
            tails[ev["name"]] = dict(ev.get("args", {}))
    return dict(sorted(tails.items()))


def _fmt_us(us: float) -> str:
    return f"{us / 1e3:10.3f}"


def summarize(path: str, top: int = 15, out=None) -> None:
    """Print the three summary tables for the trace at `path`."""
    out = out or sys.stdout
    events = load_events(path)
    n_x = sum(1 for e in events if e.get("ph") == "X")
    print(f"trace: {len(events)} events ({n_x} spans)", file=out)

    spans = span_self_times(events)
    if spans:
        print(f"\ntop {min(top, len(spans))} spans by self-time (ms):",
              file=out)
        print(f"  {'self':>10} {'total':>10} {'count':>6}  name", file=out)
        ranked = sorted(spans.items(),
                        key=lambda kv: (-kv[1]["self_us"], kv[0]))
        for name, a in ranked[:top]:
            print(f"  {_fmt_us(a['self_us'])} {_fmt_us(a['total_us'])} "
                  f"{a['count']:6d}  {name}", file=out)

    reqs = request_table(events)
    if reqs:
        print("\nrequests:", file=out)
        print(f"  {'id':>8} {'ttft_ms':>10} {'e2e_ms':>10}  args", file=out)
        for r in reqs:
            ttft = f"{r['ttft_ms']:10.3f}" if "ttft_ms" in r else " " * 10
            args = " ".join(f"{k}={v}" for k, v in sorted(r["args"].items()))
            print(f"  {str(r['id']):>8} {ttft} {r['e2e_ms']:10.3f}  {args}",
                  file=out)

    tails = counter_tails(events)
    if tails:
        print("\ncounters (final values):", file=out)
        for name, series in tails.items():
            vals = " ".join(f"{k}={v:g}" if isinstance(v, (int, float))
                            else f"{k}={v}"
                            for k, v in sorted(series.items()))
            print(f"  {name}: {vals}", file=out)


def main(argv: list[str] | None = None) -> int:
    """CLI entry point (``python -m repro.obs summarize trace.json``)."""
    ap = argparse.ArgumentParser(
        prog="repro.obs", description="Chrome-trace summarizer")
    sub = ap.add_subparsers(dest="cmd", required=True)
    s = sub.add_parser("summarize", help="summarize a trace file")
    s.add_argument("trace", help="path to a Chrome trace JSON")
    s.add_argument("--top", type=int, default=15,
                   help="spans to list (default 15)")
    args = ap.parse_args(argv)
    summarize(args.trace, top=args.top)
    return 0
