"""Sharded, atomic, elastic checkpointing.

Layout (one directory per step):

    <root>/step_000123.tmp-<nonce>/   — written first
        arrays.npz                    — flat {path: ndarray}
        manifest.json                 — treedef + shapes + dtypes + meta
    <root>/step_000123/               — atomic rename on completion

Properties the training loop relies on:
  * ATOMIC    — a crash mid-write never leaves a readable-but-corrupt step;
                restore only sees fully-renamed directories.
  * ELASTIC   — arrays are stored UNSHARDED (gathered through host memory);
                restore re-shards onto whatever mesh/device-count the new
                job brings up.  Saving under one topology and restoring
                under another is a tested path (tests/test_checkpoint.py).
  * KEEP-K    — older steps garbage-collected after each successful save.

For multi-TB models a production deployment would write per-shard files
(one per data-parallel host) instead of the gathered npz; the manifest
format already records per-array shapes so that change is local to
_write/_read.  On this single-process container the gathered form is exact.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def save(root: str, step: int, tree: Any, meta: dict | None = None) -> str:
    """Atomic save; returns the final directory."""
    os.makedirs(root, exist_ok=True)
    final = os.path.join(root, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(prefix=f"step_{step:08d}.tmp-", dir=root)
    try:
        flat = _flatten(tree)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = {
            "step": step,
            "keys": sorted(flat.keys()),
            "shapes": {k: list(v.shape) for k, v in flat.items()},
            "dtypes": {k: str(v.dtype) for k, v in flat.items()},
            "meta": meta or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)          # atomicity boundary
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def latest_step(root: str) -> int | None:
    if not os.path.isdir(root):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(root)
             if d.startswith("step_") and ".tmp-" not in d
             and os.path.exists(os.path.join(root, d, "manifest.json"))]
    return max(steps) if steps else None


def restore(root: str, step: int, like: Any, shardings: Any = None) -> Any:
    """Restore into the structure of `like`; optionally re-shard.

    `like` may be real arrays or ShapeDtypeStructs; `shardings` (a matching
    pytree of NamedSharding) re-places every array — this is the elastic
    path: the stored arrays are topology-free.
    """
    d = os.path.join(root, f"step_{step:08d}")
    with np.load(os.path.join(d, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path, leaf in leaves_like:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"ckpt {arr.shape} vs model {leaf.shape}")
        out.append(arr.astype(leaf.dtype))
    tree = jax.tree.unflatten(treedef, out)
    if shardings is not None:
        tree = jax.tree.map(jax.device_put, tree, shardings)
    return tree


def read_meta(root: str, step: int) -> dict:
    d = os.path.join(root, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        return json.load(f)


class CheckpointManager:
    """save-every-N + keep-K policy around save/restore."""

    def __init__(self, root: str, every: int = 100, keep: int = 3):
        self.root, self.every, self.keep = root, every, keep

    def maybe_save(self, step: int, tree: Any,
                   meta: dict | None = None) -> str | None:
        if step % self.every:
            return None
        path = save(self.root, step, tree, meta)
        self._gc()
        return path

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.root)
            if d.startswith("step_") and ".tmp-" not in d)
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.root, f"step_{s:08d}"),
                          ignore_errors=True)

    def latest(self) -> int | None:
        return latest_step(self.root)
