"""Hot-loop purity: no host round-trips where the step rate lives.

A `debug.print` / host callback inside a scanned layer stack or a serving
decode step forces a device->host sync per iteration; on a real accelerator
that serializes the pipeline the continuous-batching scheduler exists to
keep full.  All of these appear in the jaxpr as callback primitives, so
the check is a walk counting loop depth.

Findings:

  PUR001 ERROR    callback primitive inside a scan/while body
  PUR002 WARNING  callback primitive anywhere in a hot-path jit
                  (serving step) — even outside loops it syncs per tick
"""

from __future__ import annotations

from repro.analysis.findings import Finding, Severity
from repro.analysis.jaxprs import eqn_location, iter_eqns
from repro.analysis.registry import register
from repro.analysis.target import AnalysisTarget

_CALLBACKS = {"debug_callback", "pure_callback", "io_callback", "callback",
              "host_callback", "outside_call", "debug_print"}


@register("purity")
def check_purity(target: AnalysisTarget) -> list[Finding]:
    if target.fn is None:
        return []
    closed = target.try_jaxpr()
    if closed is None:
        return []
    findings: list[Finding] = []
    for eqn, path, loop_depth in iter_eqns(closed):
        if eqn.primitive.name not in _CALLBACKS:
            continue
        loc = eqn_location(eqn, path)
        if loop_depth > 0:
            findings.append(Finding(
                check="purity", code="PUR001", severity=Severity.ERROR,
                subject=target.name, location=loc,
                message=(f"host callback `{eqn.primitive.name}` inside a "
                         f"loop body (depth {loop_depth}): one device->"
                         "host sync PER ITERATION — hoist it out or guard "
                         "it behind a debug build")))
        elif target.hot_path:
            findings.append(Finding(
                check="purity", code="PUR002", severity=Severity.WARNING,
                subject=target.name, location=loc,
                message=(f"host callback `{eqn.primitive.name}` in a "
                         "hot-path step: syncs the device every tick")))
    return findings
