"""Vectorized Monte-Carlo chip-ensemble evaluation ("N-chip wafer").

One jitted call evaluates a model forward over N static-variation
instances at once: the ensemble pytree (leading chip axis) is `jax.vmap`ed
through the `rosa.Engine`, per-shot noise keys split per chip, and the
per-chip accuracy / logit-agreement / yield statistics come back in a
single XLA program.  Inside the chip vmap the evaluation set is streamed
in micro-batches (`lax.map`) so 64+ chips stay memory-bounded on CPU.

    ens  = variation.sample_ensemble(key, 64, variation.cnn_lane_dims("alexnet"))
    res  = ensemble.evaluate_cnn_ensemble(params, "alexnet", engine, ens, key)
    res.mean_acc, res.yield_frac(max_drop_pp=2.0)
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mrr
from repro.robust import variation as V

# apply_fn(params, x, engine) -> logits; the engine arrives pre-loaded with
# this chip's variation and per-shot key.
ApplyFn = Callable[..., jax.Array]


@dataclasses.dataclass(frozen=True)
class EstimatorConfig:
    """Variance-reduced ensemble estimator settings (hashable, jsonable).

    ``n_probe`` chips get real eval-set forward passes; the remaining
    chips' accuracies are predicted by a control-variate regression on a
    cheap weight-realization surrogate (`surrogate_features`).  ``0``
    probes means brute force: every chip is evaluated.  ``antithetic``
    records whether the ensemble was drawn with mirrored chip pairs
    (`variation.sample_ensemble(antithetic=True)`) — the probe prefix then
    covers whole pairs, which centres the regression fit.
    """

    n_probe: int = 4
    antithetic: bool = True
    control_variate: bool = True


FULL_MC = EstimatorConfig(n_probe=0, antithetic=False, control_variate=False)


@dataclasses.dataclass
class EnsembleResult:
    """Per-chip statistics of one ensemble evaluation."""

    accs: np.ndarray           # (n_chips,) accuracy [%] (vs labels, or vs
    #                            clean predictions when labels are absent)
    agreement: np.ndarray      # (n_chips,) argmax agreement with clean [0,1]
    clean_acc: float           # noise-free reference accuracy [%]
    n_probe: int = 0           # chips with measured (not predicted) accs;
    #                            0 = all measured (brute-force MC)
    method: str = "mc"         # "mc" | "control-variate"

    @property
    def n_chips(self) -> int:
        """Number of chips in the ensemble."""
        return len(self.accs)

    @property
    def mean_acc(self) -> float:
        """Ensemble-mean accuracy [%]."""
        return float(self.accs.mean())

    @property
    def std_acc(self) -> float:
        """Across-chip accuracy standard deviation [pp]."""
        return float(self.accs.std())

    @property
    def min_acc(self) -> float:
        """Worst-chip accuracy [%]."""
        return float(self.accs.min())

    @property
    def mean_drop_pp(self) -> float:
        """Clean-minus-ensemble-mean accuracy drop [pp]."""
        return self.clean_acc - self.mean_acc

    def yield_frac(self, max_drop_pp: float = 2.0) -> float:
        """Fraction of chips within `max_drop_pp` of the clean model —
        the wafer-yield figure of merit (higher is better).
        """
        return float((self.accs >= self.clean_acc - max_drop_pp).mean())

    def yield_curve(self, drops_pp: Sequence[float]) -> list[tuple[float, float]]:
        """(drop_pp, yield) pairs over a grid of drop thresholds."""
        return [(float(d), self.yield_frac(d)) for d in drops_pp]

    def summary(self) -> dict:
        """One-level dict of the headline statistics (JSON-ready)."""
        out = {"n_chips": self.n_chips, "clean_acc": self.clean_acc,
               "mean_acc": self.mean_acc, "std_acc": self.std_acc,
               "min_acc": self.min_acc,
               "mean_agreement": float(self.agreement.mean()),
               "yield_2pp": self.yield_frac(2.0), "method": self.method}
        if self.n_probe:
            out["n_probe"] = self.n_probe
        return out


def clean_reference(engine):
    """The noise-free twin of an engine: same plan with per-shot noise
    muted, no pinned chip, no gates (blend or mapping), no key.
    """
    plan = engine.plan.map_configs(
        lambda c: dataclasses.replace(c, noise=mrr.IDEAL))
    return engine.with_plan(plan).with_variation(None).with_gates(None) \
        .with_mapping_gates(None).with_key(None)


def chunk_eval_set(x: jax.Array, size: int) -> jax.Array:
    """(N, ...) -> (N//size, size, ...) micro-batches for `lax.map`
    streaming.  A remainder that does not fill a chunk is dropped — loudly,
    because every downstream accuracy/yield statistic would silently run
    on fewer samples than the caller asked for.
    """
    size = min(size, x.shape[0])
    n = (x.shape[0] // size) * size
    if n < x.shape[0]:
        import warnings
        warnings.warn(
            f"evaluation set truncated {x.shape[0]} -> {n} samples "
            f"(not a multiple of eval_batch={size}); statistics cover the "
            f"truncated set", stacklevel=2)
    return x[:n].reshape(n // size, size, *x.shape[1:])


def chunked_argmax_preds(apply_fn: ApplyFn, params, xb: jax.Array, engine
                         ) -> jax.Array:
    """Stream the (n_chunks, chunk, ...) batches through the engine and
    return flat argmax predictions — the shared inner evaluator of
    ensemble/sensitivity/plan-search (trace it inside jit/vmap).
    """
    return jax.lax.map(
        lambda xc: jnp.argmax(apply_fn(params, xc, engine), -1),
        xb).reshape(-1)


def make_ensemble_eval(apply_fn: ApplyFn, engine, *, eval_batch: int = 128):
    """Build the ONE jitted evaluator: (params, x, y, ensemble, keys) ->
    (accs, agreement, clean_acc).

    The chip axis is a `jax.vmap`; the evaluation set streams through
    `lax.map` micro-batches of `eval_batch` inside it.  Reuse the returned
    callable across calls (drift loops, sigma sweeps) — retracing only
    happens on new shapes.
    """
    clean_engine = clean_reference(engine)

    @jax.jit
    def run(params, x, y, ens, keys):
        """Jitted ensemble evaluation body."""
        xb = chunk_eval_set(x, eval_batch)
        clean_pred = chunked_argmax_preds(apply_fn, params, xb, clean_engine)

        def one_chip(var, k):
            """Evaluate one chip of the vmapped ensemble."""
            return chunked_argmax_preds(
                apply_fn, params, xb, engine.with_variation(var).with_key(k))

        preds = jax.vmap(one_chip)(ens, keys)          # (n_chips, n_eval)
        ref = clean_pred if y is None else y[:preds.shape[1]]
        accs = 100.0 * jnp.mean(preds == ref[None, :], axis=1)
        agreement = jnp.mean(preds == clean_pred[None, :], axis=1)
        clean_acc = 100.0 * jnp.mean(clean_pred == ref)
        return accs, agreement, clean_acc

    return run


def evaluate_ensemble(apply_fn: ApplyFn, params, x, y, engine,
                      ensemble: V.Chip, key: jax.Array, *,
                      eval_batch: int = 128) -> EnsembleResult:
    """One-shot convenience around `make_ensemble_eval` (builds, runs,
    wraps).  `y=None` scores argmax agreement against the clean model
    (label-free workloads: LM logit agreement).
    """
    n = V.ensemble_size(ensemble)
    keys = jax.random.split(key, n)
    run = make_ensemble_eval(apply_fn, engine, eval_batch=eval_batch)
    accs, agreement, clean_acc = run(params, x, y, ensemble, keys)
    return EnsembleResult(accs=np.asarray(accs),
                          agreement=np.asarray(agreement),
                          clean_acc=float(clean_acc))


# ---------------------------------------------------------------------------
# Variance-reduced estimation: antithetic pairs + control-variate surrogate
# ---------------------------------------------------------------------------
def layer_weights(params, names) -> dict:
    """Extract per-layer weight arrays `{name: (K, N) array}` from params.

    Accepts both the CNN convention (``params[name]["w"]``) and bare-array
    layers (``params[name]`` is the weight itself, the toy-MLP test
    convention).  Layers without a recognizable weight are skipped — they
    simply contribute no surrogate feature.
    """
    out = {}
    for n in names:
        p = params.get(n) if hasattr(params, "get") else None
        if isinstance(p, dict):
            p = p.get("w")
        if p is not None and getattr(p, "ndim", 0) >= 1:
            out[n] = p
    return out


def surrogate_features(weights: dict, ensemble: V.Chip, engine) -> np.ndarray:
    """Per-chip surrogate `s_c`: summed weight-realization RMS errors.

    For every chip `c` and layer `l`, `rosa.backends.realization_rms_error`
    measures how far the chip's static variation pulls the programmed
    weights off their quantized targets — no eval-set forwards, one
    `realize_weights` sweep per (chip, layer), vmapped over the ensemble.
    The per-layer errors are summed into a single (n_chips,) feature: chips
    that distort their weights more degrade more, and the relation is
    close enough to linear for a 2-parameter regression fit on a handful
    of probe chips (`estimate_ensemble`).
    """
    from repro.rosa.backends import realization_rms_error

    names = [n for n in weights if n in ensemble
             and engine.plan.resolve(n) is not None]

    @jax.jit
    def run(ws, ens):
        """Jitted ensemble evaluation body."""
        def one_chip(var):
            """Evaluate one chip of the vmapped ensemble."""
            errs = [realization_rms_error(ws[n], engine.plan.resolve(n),
                                          var[n]) for n in names]
            return jnp.stack(errs).sum()

        return jax.vmap(one_chip)({n: ens[n] for n in names})

    if not names:
        return np.zeros(V.ensemble_size(ensemble))
    return np.asarray(run({n: weights[n] for n in names},
                          {n: ensemble[n] for n in names}))


def control_variate_accs(probe_accs: np.ndarray, features: np.ndarray,
                         n_probe: int) -> np.ndarray:
    """Predict all-chip accuracies from `n_probe` measured ones.

    Ordinary least squares of the probe accuracies on the surrogate
    feature, ``acc ~ b - a * s`` with the slope clipped to ``a >= 0`` (more
    weight distortion can only hurt).  Probe chips keep their measured
    values; the rest get the regression prediction, clipped to [0, 100].
    Because OLS residuals average to zero over the fit set, the mean of
    the combined vector IS the regression control-variate estimator of the
    ensemble mean.  Fitting the coefficient on the same probes introduces
    an O(1/n_probe) bias — small against the variance it removes (see
    docs/robustness.md for the math and measured tolerances).
    """
    s, f = features[:n_probe], probe_accs
    var_s = float(np.var(s))
    if var_s > 1e-12:
        a = max(0.0, -float(np.cov(s, f, bias=True)[0, 1]) / var_s)
    else:
        a = 0.0
    b = float(np.mean(f)) + a * float(np.mean(s))
    pred = np.clip(b - a * features, 0.0, 100.0)
    pred[:n_probe] = probe_accs
    return pred


def estimate_ensemble(apply_fn: ApplyFn, params, x, y, engine,
                      ensemble: V.Chip, key: jax.Array, *,
                      estimator: EstimatorConfig = EstimatorConfig(),
                      weights: dict | None = None,
                      eval_batch: int = 128) -> EnsembleResult:
    """Variance-reduced twin of `evaluate_ensemble`.

    Runs real eval-set forwards for the first ``estimator.n_probe`` chips
    only and predicts the remaining chips' accuracies through the
    control-variate surrogate (`surrogate_features`), so ~4 evaluated
    chips estimate a 16-chip wafer's mean accuracy and yield.  Draw the
    ensemble with ``antithetic=True`` so the probe prefix covers mirrored
    pairs.  ``n_probe=0`` (or ``control_variate=False``, or n_probe >=
    n_chips) falls back to the exact brute-force path bit-for-bit.
    """
    n = V.ensemble_size(ensemble)
    n_probe = estimator.n_probe
    if not estimator.control_variate or n_probe <= 0 or n_probe >= n:
        return evaluate_ensemble(apply_fn, params, x, y, engine, ensemble,
                                 key, eval_batch=eval_batch)
    keys = jax.random.split(key, n)[:n_probe]
    run = make_ensemble_eval(apply_fn, engine, eval_batch=eval_batch)
    p_accs, p_agree, clean_acc = run(params, x, y,
                                     V.chip_slice(ensemble, n_probe), keys)
    p_accs = np.asarray(p_accs)
    if weights is None:
        weights = layer_weights(params, list(ensemble))
    feats = surrogate_features(weights, ensemble, engine)
    accs = control_variate_accs(p_accs, feats, n_probe)
    return EnsembleResult(accs=accs, agreement=np.asarray(p_agree),
                          clean_acc=float(clean_acc), n_probe=n_probe,
                          method="control-variate")


def make_plan_eval(apply_fn: ApplyFn, engine, names, *,
                   eval_batch: int = 128, gated: bool = False):
    """One jitted evaluator shared by every hybrid-plan candidate.

    Like `make_ensemble_eval` but the per-layer IS/WS choice arrives as a
    traced ``sel`` vector of mapping gates (1 = IS, 0 = WS), so evaluating
    a hybrid plan and its pure-WS baseline reuses ONE compiled program —
    the plan axis never retraces.  Returns ``(params, x, y, ens, keys,
    sel) -> (accs, agreement, clean_acc)``.

    ``gated=True`` adds a trailing per-layer analog-gate vector ``g``
    (``(params, x, y, ens, keys, sel, g) -> ...``): layer ``i`` runs the
    analog path blended by ``g[i]`` in [0, 1] against the exact digital
    one.  With ``g`` one-hot this is the perturb-one-layer degradation
    cell, with ``g`` all-ones it is a full hybrid-plan (or pure-WS)
    evaluation — so a single compiled program can serve ensemble probes,
    the whole degradation matrix, the plan search, and the final plan
    evaluations, as long as chip count and eval-set shape stay fixed
    (`repro.robust.cli.run_smoke`).
    """
    clean_engine = clean_reference(engine)

    @jax.jit
    def run(params, x, y, ens, keys, sel, g=None):
        """Jitted ensemble evaluation body."""
        xb = chunk_eval_set(x, eval_batch)
        clean_pred = chunked_argmax_preds(apply_fn, params, xb, clean_engine)
        mgates = {n: sel[i] for i, n in enumerate(names)}
        gates = None if g is None else {n: g[i]
                                        for i, n in enumerate(names)}

        def one_chip(var, k):
            """Evaluate one chip of the vmapped ensemble."""
            e = engine.with_variation(var).with_key(k) \
                .with_mapping_gates(mgates).with_gates(gates)
            return chunked_argmax_preds(apply_fn, params, xb, e)

        preds = jax.vmap(one_chip)(ens, keys)
        ref = clean_pred if y is None else y[:preds.shape[1]]
        accs = 100.0 * jnp.mean(preds == ref[None, :], axis=1)
        agreement = jnp.mean(preds == clean_pred[None, :], axis=1)
        clean_acc = 100.0 * jnp.mean(clean_pred == ref)
        return accs, agreement, clean_acc

    if gated:
        return run
    return lambda params, x, y, ens, keys, sel: \
        run(params, x, y, ens, keys, sel)


# ---------------------------------------------------------------------------
# CNN front-end (the paper's behavioural experiments)
# ---------------------------------------------------------------------------
def cnn_apply_fn(model: str) -> ApplyFn:
    """The apply-fn closure of a lite-CNN zoo model."""
    from repro.models.cnn import LITE_MODELS, LITE_SKIPS, cnn_apply
    specs, skips = LITE_MODELS[model], LITE_SKIPS.get(model)
    return lambda params, x, engine: cnn_apply(params, specs, x, engine,
                                               residual_from=skips)


def cnn_eval_set(n_eval: int = 512, seed: int = 0):
    """First `n_eval` synth-CIFAR test images and labels."""
    from repro.data.synth_cifar import train_test_split
    (_, _), (xte, yte) = train_test_split(seed=seed)
    return jnp.asarray(xte[:n_eval]), jnp.asarray(yte[:n_eval])


def evaluate_cnn_ensemble(params, model: str, engine, ensemble: V.Chip,
                          key: jax.Array, *, n_eval: int = 512,
                          eval_batch: int = 128, seed: int = 0,
                          estimator: EstimatorConfig | None = None
                          ) -> EnsembleResult:
    """Ensemble statistics of a lite CNN on the synth-CIFAR test set.

    ``estimator=None`` runs the exact brute-force MC; an `EstimatorConfig`
    routes through the probe + control-variate path (`estimate_ensemble`).
    """
    x, y = cnn_eval_set(n_eval, seed)
    if estimator is None:
        return evaluate_ensemble(cnn_apply_fn(model), params, x, y, engine,
                                 ensemble, key, eval_batch=eval_batch)
    return estimate_ensemble(cnn_apply_fn(model), params, x, y, engine,
                             ensemble, key, estimator=estimator,
                             eval_batch=eval_batch)
