"""Metrics registry: counters, gauges, bounded-memory histograms.

A `MetricsRegistry` is a thread-safe, get-or-create table of named
instruments.  Every instrument keeps O(1) state (a histogram holds fixed
bucket counts + count/sum/min/max, never samples), so a registry can run
under a serving scheduler for months without growing.

Two export surfaces:

* `to_metrics()` — bench-schema `repro.bench.schema.Metric` rows, so any
  counter can ride inside a ``BENCH_<n>.json`` entry;
* `to_prometheus()` — the Prometheus text exposition format, for scraping.

A process-global default registry (`registry()`) carries the first-class
series the instrumented subsystems maintain:

    rosa.plancache_hits / rosa.plancache_misses     PlanCache plan IO
    rosa.degstore_layer_hits / _misses              degradation-matrix rows
    serve.queue_depth / serve.slots_active          scheduler gauges
    serve.evictions / serve.requests_completed      scheduler counters
    xla.retraces / xla.backend_compiles             jax.monitoring hooks
    xla.cache_hits / xla.cache_misses               persistent compile cache

`install_jax_hooks` registers `jax.monitoring` listeners ONCE per process;
the listeners resolve `registry()` at fire time (so tests can swap the
registry) and additionally drop compile spans onto the ambient trace.
"""

from __future__ import annotations

import contextlib
import math
import re
import threading

from repro.obs import trace as _trace

# log-spaced seconds buckets: ~30 us .. ~5 min, x4 per step — wide enough
# for both a single jitted tick and a cold XLA compile
DEFAULT_BOUNDS = tuple(2.0 ** e for e in range(-15, 9, 2))


class Counter:
    """Monotonic counter (float increments allowed)."""

    __slots__ = ("name", "help", "_lock", "_value")

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self._lock = threading.Lock()
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        """Add `n` (must be >= 0) to the counter."""
        if n < 0:
            raise ValueError(f"counter {self.name}: negative increment {n}")
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        """Current total."""
        with self._lock:
            return self._value


class Gauge:
    """Last-written value (set/add semantics)."""

    __slots__ = ("name", "help", "_lock", "_value")

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, v: float) -> None:
        """Overwrite the gauge."""
        with self._lock:
            self._value = float(v)

    def add(self, n: float) -> None:
        """Adjust the gauge by `n` (may be negative)."""
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        """Current value."""
        with self._lock:
            return self._value


class Histogram:
    """Bounded-memory histogram: fixed bucket bounds, no stored samples.

    ``bounds`` are the upper edges of the finite buckets (sorted); one
    overflow bucket catches everything above the last edge.  Memory is
    O(len(bounds)) forever, whatever the observation rate.
    """

    __slots__ = ("name", "help", "bounds", "_lock", "_counts", "count",
                 "total", "min", "max")

    def __init__(self, name: str, help: str = "",
                 bounds: tuple = DEFAULT_BOUNDS):
        self.name, self.help = name, help
        self.bounds = tuple(sorted(float(b) for b in bounds))
        if not self.bounds:
            raise ValueError("histogram needs at least one bucket bound")
        self._lock = threading.Lock()
        self._counts = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf

    def _bucket(self, v: float) -> int:
        lo, hi = 0, len(self.bounds)
        while lo < hi:                       # first bound >= v
            mid = (lo + hi) // 2
            if v <= self.bounds[mid]:
                hi = mid
            else:
                lo = mid + 1
        return lo

    def observe(self, v: float) -> None:
        """Record one sample."""
        v = float(v)
        i = self._bucket(v)
        with self._lock:
            self._counts[i] += 1
            self.count += 1
            self.total += v
            self.min = min(self.min, v)
            self.max = max(self.max, v)

    @property
    def mean(self) -> float:
        """Mean of the observed samples (0 when empty)."""
        with self._lock:
            return self.total / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """Approximate q-th percentile (upper bucket edge; 0 when empty)."""
        with self._lock:
            counts, n = list(self._counts), self.count
        if not n:
            return 0.0
        target = max(1, math.ceil(n * q / 100.0))
        seen = 0
        for i, c in enumerate(counts):
            seen += c
            if seen >= target:
                return self.bounds[i] if i < len(self.bounds) else self.max
        return self.max                                   # pragma: no cover

    def snapshot(self) -> dict:
        """Summary dict (count/sum/min/max/mean + cumulative buckets)."""
        with self._lock:
            counts = list(self._counts)
            out = {"count": self.count, "sum": self.total,
                   "min": self.min if self.count else 0.0,
                   "max": self.max if self.count else 0.0}
        out["mean"] = out["sum"] / out["count"] if out["count"] else 0.0
        cum, acc = [], 0
        for c in counts:
            acc += c
            cum.append(acc)
        out["buckets"] = list(zip([*self.bounds, math.inf], cum))
        return out


class MetricsRegistry:
    """Thread-safe get-or-create table of named instruments."""

    def __init__(self):
        self._lock = threading.Lock()
        self._items: dict[str, Counter | Gauge | Histogram] = {}

    def _get(self, name: str, cls, **kw):
        with self._lock:
            item = self._items.get(name)
            if item is None:
                item = self._items[name] = cls(name, **kw)
        if not isinstance(item, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{type(item).__name__}, not {cls.__name__}")
        return item

    def counter(self, name: str, help: str = "") -> Counter:
        """Get-or-create a `Counter`."""
        return self._get(name, Counter, help=help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        """Get-or-create a `Gauge`."""
        return self._get(name, Gauge, help=help)

    def histogram(self, name: str, help: str = "",
                  bounds: tuple = DEFAULT_BOUNDS) -> Histogram:
        """Get-or-create a `Histogram`."""
        return self._get(name, Histogram, help=help, bounds=bounds)

    def items(self) -> dict:
        """Snapshot {name: instrument} (insertion order preserved)."""
        with self._lock:
            return dict(self._items)

    def snapshot(self) -> dict:
        """{name: value | histogram summary} for cheap diffing."""
        out = {}
        for name, item in self.items().items():
            out[name] = item.snapshot() if isinstance(item, Histogram) \
                else item.value
        return out

    # -- exports -------------------------------------------------------------
    def to_metrics(self, prefix: str = "") -> list:
        """Bench-schema `Metric` rows (never gated — runtime observations)."""
        from repro.bench.schema import Metric
        rows = []
        for name, item in self.items().items():
            if isinstance(item, Histogram):
                rows.append(Metric(f"{prefix}{name}_count", item.count))
                rows.append(Metric(f"{prefix}{name}_mean", item.mean))
            else:
                rows.append(Metric(f"{prefix}{name}", item.value))
        return rows

    def to_prometheus(self) -> str:
        """The Prometheus text exposition format of every instrument."""
        lines = []
        for name, item in self.items().items():
            pname = _prom_name(name)
            if item.help:
                lines.append(f"# HELP {pname} {item.help}")
            if isinstance(item, Counter):
                lines.append(f"# TYPE {pname} counter")
                lines.append(f"{pname} {_prom_val(item.value)}")
            elif isinstance(item, Gauge):
                lines.append(f"# TYPE {pname} gauge")
                lines.append(f"{pname} {_prom_val(item.value)}")
            else:
                snap = item.snapshot()
                lines.append(f"# TYPE {pname} histogram")
                for edge, cum in snap["buckets"]:
                    le = "+Inf" if math.isinf(edge) else _prom_val(edge)
                    lines.append(f'{pname}_bucket{{le="{le}"}} {cum}')
                lines.append(f"{pname}_sum {_prom_val(snap['sum'])}")
                lines.append(f"{pname}_count {snap['count']}")
        return "\n".join(lines) + "\n"


def _prom_name(name: str) -> str:
    return re.sub(r"[^a-zA-Z0-9_:]", "_", name)


def _prom_val(v: float) -> str:
    return repr(int(v)) if float(v).is_integer() and abs(v) < 1e15 \
        else repr(float(v))


# ---------------------------------------------------------------------------
# The process-global default registry
# ---------------------------------------------------------------------------
_REGISTRY = MetricsRegistry()


def registry() -> MetricsRegistry:
    """The process-global registry the instrumented subsystems write to."""
    return _REGISTRY


@contextlib.contextmanager
def swap_registry(reg: MetricsRegistry):
    """Temporarily replace the global registry (hermetic tests)."""
    global _REGISTRY
    prev, _REGISTRY = _REGISTRY, reg
    try:
        yield reg
    finally:
        _REGISTRY = prev


# ---------------------------------------------------------------------------
# jax.monitoring bridge: XLA retrace / compile / cache counters
# ---------------------------------------------------------------------------
_JAX_HOOKS_LOCK = threading.Lock()
_JAX_HOOKS_INSTALLED = False

_DURATION_SERIES = {
    "/jax/core/compile/jaxpr_trace_duration":
        ("xla.retraces", "xla.trace_s", "xla.trace"),
    "/jax/core/compile/backend_compile_duration":
        ("xla.backend_compiles", "xla.backend_compile_s",
         "xla.backend_compile"),
}
_EVENT_SERIES = {
    "/jax/compilation_cache/cache_hits": "xla.cache_hits",
    "/jax/compilation_cache/cache_misses": "xla.cache_misses",
}


def _on_duration(event: str, duration: float, **kw) -> None:
    series = _DURATION_SERIES.get(event)
    if series is None:
        return
    cnt, hist, span_name = series
    reg = registry()
    reg.counter(cnt).inc()
    reg.histogram(hist).observe(duration)
    tr = _trace.current_tracer()
    if tr is not None:
        # the duration arrives after the fact: back-date the span start
        tr._emit({"name": span_name, "cat": "xla", "ph": "X",
                  "ts": tr.now_us() - duration * 1e6,
                  "dur": duration * 1e6})


def _on_event(event: str, **kw) -> None:
    series = _EVENT_SERIES.get(event)
    if series is None:
        return
    registry().counter(series).inc()
    tr = _trace.current_tracer()
    if tr is not None:
        tr.instant(series, cat="xla")


def install_jax_hooks() -> bool:
    """Register the `jax.monitoring` listeners (idempotent).

    Returns True when the hooks are active after the call.  Listener
    registration is append-only in jax, so this runs once per process; the
    listeners dispatch through `registry()` and the ambient tracer at fire
    time.  Best effort: a jax without the monitoring API leaves the
    counters at zero rather than failing the caller.
    """
    global _JAX_HOOKS_INSTALLED
    with _JAX_HOOKS_LOCK:
        if _JAX_HOOKS_INSTALLED:
            return True
        try:
            from jax import monitoring
            monitoring.register_event_duration_secs_listener(_on_duration)
            monitoring.register_event_listener(_on_event)
        except Exception:
            return False
        _JAX_HOOKS_INSTALLED = True
        return True
