"""Closed-loop drift-adaptive serving: detect → re-trim → re-plan.

The serving fleet's chip drifts thermally mid-traffic; this package keeps
it accurate without dropping a request.  Golden-token probes piggyback on
idle decode slots (`probes`), EWMA/CUSUM statistics decide when drift is
real (`detector`), and a HEALTHY→DEGRADED→RETRIM→REPLAN state machine
first re-trims the ring voltages at the estimated temperature, then — if
accuracy stays below guard — re-selects the hybrid plan and swaps the
serving `rosa.Program` double-buffered between ticks (`controller`).
`scenario` is the A/B harness and `python -m repro.serve.adaptive` the
CLI; `docs/adaptive-serving.md` walks through the whole loop.
"""

from repro.serve.adaptive.controller import (AdaptiveController,
                                             ControllerConfig,
                                             ControllerState, DriftMonitor,
                                             make_drift_step)
from repro.serve.adaptive.detector import DetectorConfig, DriftDetector
from repro.serve.adaptive.probes import ProbeConfig, ProbeSet, plan_selector
from repro.serve.adaptive.scenario import (DriftEnv, ScenarioConfig,
                                           ScenarioResult,
                                           drift_serve_metrics,
                                           run_scenario)

__all__ = [
    "AdaptiveController", "ControllerConfig", "ControllerState",
    "DetectorConfig", "DriftDetector", "DriftEnv", "DriftMonitor",
    "ProbeConfig", "ProbeSet", "ScenarioConfig", "ScenarioResult",
    "drift_serve_metrics", "make_drift_step", "plan_selector",
    "run_scenario",
]
