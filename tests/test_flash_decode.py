"""flash_decode (sharded-KV decode attention) vs the gather-free oracle.

Needs >1 device to exercise the shard_map, so it runs a subprocess with 4
forced host devices and a (1, 4) mesh: the KV sequence shards over "model"
(kv_heads=2 is indivisible by 4, mirroring the gemma long_500k cell).
"""

import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.distributed.sharding import SERVE_RULES, use_sharding, resolve_spec
from repro.models import layers as L

mesh = jax.make_mesh((1, 4), ("data", "model"))
cfg = L.AttnConfig(d_model=32, n_heads=4, n_kv_heads=2, head_dim=8)
key = jax.random.PRNGKey(0)
B, S = 2, 64
q = jax.random.normal(key, (B, 1, 4, 8))
kc = jax.random.normal(jax.random.PRNGKey(1), (B, S, 2, 8))
vc = jax.random.normal(jax.random.PRNGKey(2), (B, S, 2, 8))
pos = jnp.full((B,), 40, jnp.int32)

# oracle: plain masked attention over the full cache
k_pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
bias = L._mask_bias(pos[:, None], k_pos, True, 0, k_len_valid=(pos + 1)[:, None])
o_ref = L.attention_core(q, L._repeat_kv(kc, 4), L._repeat_kv(vc, 4), bias)

with use_sharding(mesh, SERVE_RULES):
    spec = resolve_spec(kc.shape, ("cache_batch", "cache_seq", "kv_heads",
                                   "head_dim"), SERVE_RULES, mesh)
    assert spec[1] is not None, f"seq not sharded: {spec}"
    kc_s = jax.device_put(kc, NamedSharding(mesh, spec))
    vc_s = jax.device_put(vc, NamedSharding(mesh, spec))
    def f(q, kc, vc, pos):
        return L.flash_decode(q, kc, vc, pos, 0, 4)
    o = jax.jit(f)(q, kc_s, vc_s, pos)

err = float(jnp.max(jnp.abs(o - o_ref)))
print("flash_decode max err:", err)
assert err < 2e-5, err

# windowed variant (sliding-window layers)
bias_w = L._mask_bias(pos[:, None], k_pos, True, 8, k_len_valid=(pos + 1)[:, None])
o_ref_w = L.attention_core(q, L._repeat_kv(kc, 4), L._repeat_kv(vc, 4), bias_w)
with use_sharding(mesh, SERVE_RULES):
    o_w = jax.jit(lambda q, k, v, p: L.flash_decode(q, k, v, p, 8, 4))(
        q, kc_s, vc_s, pos)
err_w = float(jnp.max(jnp.abs(o_w - o_ref_w)))
print("flash_decode windowed max err:", err_w)
assert err_w < 2e-5, err_w
print("OK")
"""


def test_flash_decode_matches_oracle():
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, (r.stdout[-1000:], r.stderr[-2000:])
    assert "OK" in r.stdout
