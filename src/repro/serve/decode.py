"""Jitted serving steps: continuous-batch decode + chunked prefill.

The decode state (slot cache + per-slot bookkeeping) lives on device and is
DONATED through every step — XLA updates the paged KV cache in place, so a
tick costs one token of compute, not one cache copy.  Admission (slot
eviction + refill) happens INSIDE the same jitted step: the admit payload
carries a prefilled batch-1 cache, and a traced `valid` flag turns the
whole write into an O(row) no-op, so the step never recompiles between
"plain decode" and "decode + refill" ticks.

Sampling is scheduling-invariant: the key for a request's i-th token folds
(request id, i) from the base key, so continuous batching, one-shot
batching and the per-request sequential oracle draw IDENTICAL samples —
which is what lets tests/test_serve.py assert exact (not just
distributional) equality under seeded sampling.

Prefill streams through `transformer.chunk_step` in `prefill_chunk`-token
chunks against a request-private cache; ssm/hybrid families (whose scan
state cannot be positionally chunked) fall back to whole-prompt prefill +
`pad_cache`.
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.model import (ModelBundle, cache_axes, evict_slot,
                                pad_cache, write_slot)
from repro.serve.config import ServeConfig


class DecodeState(NamedTuple):
    """Donated per-step serving state.  All vectors are (n_slots,)."""

    cache: Any              # model decode cache, batch = n_slots (pos inside)
    tok: jax.Array          # last sampled token per slot
    rid: jax.Array          # request id per slot (0 when never assigned)
    tidx: jax.Array         # tokens generated so far per slot
    budget: jax.Array       # generation budget per slot
    active: jax.Array       # bool: slot currently serving a request
    key: jax.Array          # base sampling key (constant across steps)


def init_state(cfg: T.ModelConfig, scfg: ServeConfig) -> DecodeState:
    s = scfg.n_slots
    return DecodeState(
        cache=T.init_cache(cfg, s, scfg.max_len),
        tok=jnp.zeros((s,), jnp.int32),
        rid=jnp.zeros((s,), jnp.int32),
        tidx=jnp.zeros((s,), jnp.int32),
        budget=jnp.zeros((s,), jnp.int32),
        active=jnp.zeros((s,), bool),
        key=jax.random.PRNGKey(scfg.seed))


def null_admit(cfg: T.ModelConfig, scfg: ServeConfig) -> dict:
    """An admission payload that admits nothing (valid=False)."""
    return {"valid": jnp.zeros((), bool),
            "slot": jnp.zeros((), jnp.int32),
            "cache": T.init_cache(cfg, 1, scfg.max_len),
            "token": jnp.zeros((1,), jnp.int32),
            "rid": jnp.zeros((1,), jnp.int32),
            "budget": jnp.zeros((1,), jnp.int32)}


def make_admit(req_cache, slot: int, rid: int, token, budget: int) -> dict:
    """Admission payload: request `rid` (first generated token `token`,
    prefilled `req_cache`) takes slot `slot` with `budget` tokens to go."""
    return {"valid": jnp.ones((), bool),
            "slot": jnp.asarray(slot, jnp.int32),
            "cache": req_cache,
            "token": jnp.reshape(jnp.asarray(token, jnp.int32), (1,)),
            "rid": jnp.full((1,), rid, jnp.int32),
            "budget": jnp.full((1,), budget, jnp.int32)}


# ---------------------------------------------------------------------------
# Sampling (shared single-row path => bit-identical across schedulers)
# ---------------------------------------------------------------------------
def sample_token(base_key: jax.Array, rid, tidx, logits: jax.Array,
                 temperature) -> jax.Array:
    """Token for request `rid`'s `tidx`-th generation from logits (V,).

    temperature is a TRACED scalar: one compiled step serves greedy and
    sampled decoding alike (greedy = temperature 0, selected with a traced
    `where`, not a Python branch)."""
    greedy = jnp.argmax(logits, -1).astype(jnp.int32)
    k = jax.random.fold_in(jax.random.fold_in(base_key, rid), tidx)
    t = jnp.maximum(jnp.asarray(temperature, jnp.float32), 1e-6)
    sampled = jax.random.categorical(
        k, logits.astype(jnp.float32) / t, -1).astype(jnp.int32)
    return jnp.where(jnp.asarray(temperature, jnp.float32) > 0.0,
                     sampled, greedy)


_sample_rows = jax.vmap(sample_token, in_axes=(None, 0, 0, 0, None))


# ---------------------------------------------------------------------------
# The serving step
# ---------------------------------------------------------------------------
def _row_write(vec: jax.Array, new: jax.Array, slot, valid) -> jax.Array:
    cur = jax.lax.dynamic_index_in_dim(vec, slot, 0, keepdims=True)
    row = jnp.where(valid, new.astype(vec.dtype), cur)
    return jax.lax.dynamic_update_index_in_dim(vec, row, slot, axis=0)


def _apply_admission(cfg: T.ModelConfig, state: DecodeState, admit: dict,
                     slot_offset) -> DecodeState:
    """Evict + refill one slot, O(row), a no-op when `valid` is False or
    the slot lives on another shard (slot_offset localizes the index)."""
    slot = admit["slot"] - slot_offset
    n_local = state.tok.shape[0]
    valid = admit["valid"] & (slot >= 0) & (slot < n_local)
    slot = jnp.clip(slot, 0, n_local - 1)
    return DecodeState(
        cache=write_slot(cfg, state.cache, admit["cache"], slot, valid),
        tok=_row_write(state.tok, admit["token"], slot, valid),
        rid=_row_write(state.rid, admit["rid"], slot, valid),
        # the prefill already produced generation token #1 (admit["token"])
        tidx=_row_write(state.tidx, jnp.ones((1,), jnp.int32), slot, valid),
        budget=_row_write(state.budget, admit["budget"], slot, valid),
        active=_row_write(state.active, jnp.ones((1,), bool), slot, valid),
        key=state.key)


def _step_body(bundle: ModelBundle, scfg: ServeConfig, params,
               state: DecodeState, admit: dict, temperature,
               slot_offset) -> tuple[DecodeState, dict]:
    state = _apply_admission(bundle.cfg, state, admit, slot_offset)
    cache, tok, rid = state.cache, state.tok, state.rid
    tidx, budget, active = state.tidx, state.budget, state.active

    # -- one decode token for every slot (inactive rows compute masked
    #    garbage; their cache rows never influence active rows) ------------
    logits, cache = bundle.decode_step(
        params, {"token": tok, "pos": cache["pos"], "cache": cache})
    tok_next = _sample_rows(state.key, rid, tidx, logits, temperature)

    tidx_next = jnp.where(active, tidx + 1, tidx)
    done = active & (tidx_next >= budget)
    new_state = DecodeState(cache=cache, tok=tok_next, rid=rid,
                            tidx=tidx_next, budget=budget,
                            active=active & ~done, key=state.key)
    out = {"token": tok_next, "emitted": active, "done": done,
           "pos": cache["pos"]}
    if scfg.collect_logits:
        out["logits"] = logits
    return new_state, out


def _jitter(program):
    """The jit entry for the serving steps: `jax.jit` when no optical
    program is attached, else `program.bind` — which installs the
    program's frozen engine (tuned plan, pinned chip, ledger) as the
    ambient context while the step traces, so the scheduler builds every
    step from ONE `rosa.Program` instead of a global engine stack."""
    return jax.jit if program is None else program.bind


def make_admit_step(bundle: ModelBundle, scfg: ServeConfig, program=None):
    """-> admit(state, admit_payload) -> state (jitted, state donated).

    Admission WITHOUT a decode step — the static-batching baseline forms
    its batch with this, then decodes; the continuous policy never needs
    it (its admissions ride inside `make_serve_step`)."""

    def admit(state: DecodeState, payload: dict) -> DecodeState:
        return _apply_admission(bundle.cfg, state, payload,
                                jnp.zeros((), jnp.int32))

    return _jitter(program)(admit, donate_argnums=(0,))


def make_serve_step(bundle: ModelBundle, scfg: ServeConfig, mesh=None,
                    program=None):
    """-> step(params, state, admit, temperature) -> (state, out), jitted
    with the state donated.  With `mesh` (carrying a "data" axis that
    divides n_slots) the step runs under a slot-sharded shard_map: each
    device owns n_slots/d slots, params are replicated, and the admit
    payload is broadcast — every shard turns it into a local write (or a
    no-op if the slot lives elsewhere)."""
    if mesh is None:
        body = functools.partial(_step_body, bundle, scfg)

        def step(params, state, admit, temperature):
            return body(params, state, admit, temperature,
                        jnp.zeros((), jnp.int32))

        return _jitter(program)(step, donate_argnums=(1,))

    from repro.distributed.sharding import shard_map_compat, slot_dim_specs
    from jax.sharding import PartitionSpec as P

    d = int(np.prod(list(mesh.shape.values())))
    if scfg.n_slots % d:
        raise ValueError(f"n_slots={scfg.n_slots} not divisible by "
                         f"mesh size {d}")
    axes = tuple(mesh.shape)             # shard slots over ALL mesh axes
    n_local = scfg.n_slots // d

    cache_specs = slot_dim_specs(cache_axes(bundle.cfg),
                                 T.init_cache(bundle.cfg, scfg.n_slots,
                                              scfg.max_len), axes)
    vec = P(axes if len(axes) > 1 else axes[0])
    state_specs = DecodeState(cache=cache_specs, tok=vec, rid=vec,
                              tidx=vec, budget=vec, active=vec, key=P())
    admit_specs = jax.tree.map(lambda _: P(),
                               null_admit(bundle.cfg, scfg))
    out_specs = {"token": vec, "emitted": vec, "done": vec, "pos": vec}
    if scfg.collect_logits:
        out_specs["logits"] = vec

    def local(params, state, admit, temperature):
        idx = jnp.zeros((), jnp.int32)
        for a in axes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        return _step_body(bundle, scfg, params, state, admit, temperature,
                          idx * n_local)

    sharded = shard_map_compat(
        local, mesh=mesh,
        in_specs=(P(), state_specs, admit_specs, P()),
        out_specs=(state_specs, out_specs))
    return _jitter(program)(sharded, donate_argnums=(1,))


def make_evict(bundle: ModelBundle, scfg: ServeConfig, program=None):
    """-> evict(state, slot) -> state with that slot's cache zeroed (jitted,
    donated).  Admission overwrites slots anyway; eviction guarantees a
    completed request's KV rows don't outlive it (scfg.evict_on_done)."""

    def evict(state: DecodeState, slot):
        return state._replace(
            cache=evict_slot(bundle.cfg, state.cache, slot),
            active=_row_write(state.active, jnp.zeros((1,), bool), slot,
                              True))

    return _jitter(program)(evict, donate_argnums=(0,))


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------
class PrefillTask:
    """One request's prefill, advanced one chunk per scheduler tick.

    Attention-cache families stream `prefill_chunk`-token chunks through
    `chunk_step` against a request-private max_len cache (so a long prompt
    never blocks the decode batch for more than one chunk).  ssm/hybrid
    prefill whole (one tick, compiled per prompt length).

    After `advance()` returns True: `.cache` is the admit-ready batch-1
    cache (pos = prompt length) and `.logits` the last-token logits (V,).
    """

    def __init__(self, bundle: ModelBundle, scfg: ServeConfig, prompt,
                 chunk_fn=None, whole_fn=None):
        self.bundle, self.scfg = bundle, scfg
        self.prompt = np.asarray(prompt, np.int32).reshape(-1)
        if len(self.prompt) == 0:
            raise ValueError("empty prompt")
        if len(self.prompt) >= scfg.max_len:
            raise ValueError(f"prompt length {len(self.prompt)} >= "
                             f"max_len {scfg.max_len}: no decode room")
        self.chunked = bundle.cfg.family not in ("ssm", "hybrid")
        self._chunk_fn = chunk_fn if chunk_fn is not None \
            else make_chunk_fn(bundle)
        self._whole_fn = whole_fn if whole_fn is not None \
            else jax.jit(bundle.prefill)
        self._off = 0
        self.cache = (T.init_cache(bundle.cfg, 1, scfg.max_len)
                      if self.chunked else None)
        self.logits = None
        self.done = False

    @property
    def n_chunks(self) -> int:
        if not self.chunked:
            return 1
        c = self.scfg.prefill_chunk
        return -(-len(self.prompt) // c)

    def advance(self, params) -> bool:
        """Run one chunk (or the whole prompt for ssm/hybrid); True when
        the prefill is complete."""
        if self.done:
            return True
        if not self.chunked:
            logits, cache = self._whole_fn(
                params, {"tokens": jnp.asarray(self.prompt)[None]})
            self.cache = pad_cache(self.bundle.cfg, cache,
                                   self.scfg.max_len - len(self.prompt))
            self.logits = logits[0]
            self.done = True
            return True
        c = self.scfg.prefill_chunk
        lo = self._off
        chunk = self.prompt[lo:lo + c]
        n_valid = len(chunk)
        if n_valid < c:                       # pad the tail chunk
            chunk = np.pad(chunk, (0, c - n_valid))
        logits, self.cache = self._chunk_fn(
            params, jnp.asarray(chunk)[None],
            jnp.full((1,), n_valid, jnp.int32), self.cache)
        self._off += n_valid
        if self._off >= len(self.prompt):
            self.logits = logits[0]
            self.done = True
        return self.done


def make_chunk_fn(bundle: ModelBundle, program=None):
    """The shared jitted chunk step; ONLY the request cache is donated
    (tokens/n_valid are rebuilt per chunk and too small to matter)."""
    return _jitter(program)(
        lambda params, tokens, n_valid, cache: bundle.chunk_step(
            params, {"tokens": tokens, "n_valid": n_valid, "cache": cache}),
        donate_argnums=(3,))
