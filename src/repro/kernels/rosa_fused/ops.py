"""Jitted public wrapper around the fused ROSA megakernel.

Handles everything the tiled kernel cannot do locally, in the order the
composed `rosa.backends` chain fixes:

  * quantization full-scales — global (or per-row) absmax reductions,
    computed here and streamed in as the (M, 3) scale operand.  The
    requantization scale of the conditioned activations is obtained from a
    cheap elementwise pre-pass over x (standard dynamic-quantization
    practice); the O(T*M*K*N) contraction and both (K, N)/(M, K)
    realizations stay fused in the kernel.
  * PRNG discipline — the per-layer key splits exactly as `_forward`
    does (mgate/ANALOG: (k_w, k_x); static WS: whole key to the weight
    side; static IS: to the activation side), and each side's Gaussians
    are drawn with `realize_weights`'s internal (DAC, thermal) split, so
    the kernel sees bit-identical noise to the composed path.
  * static variation — `StaticVariation` fields broadcast per orientation
    (core.mrr.expand_lanes) and fold with the noise draws into the three
    additive chain offsets the kernel consumes.
  * padding to MXU-aligned block multiples + the unpadded-K bookkeeping
    the kernel needs to mask analog-realized pad lanes.

Static specialization (`realize_x`/`realize_w`) mirrors
`_analog_operand`'s ideal shortcut: a side with ideal noise, no variation
and no gate skips the chain entirely, so the ideal fused path matches the
composed one with zero realization round-trip error.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import mrr, osa
from repro.core import quant as Q
from repro.core.constants import ComputeMode, Mapping
from repro.kernels import on_tpu
from repro.kernels.rosa_fused import ref
from repro.kernels.rosa_fused.rosa_fused import rosa_fused_pallas
from repro.obs import trace as obs


def _pad_to(x: jax.Array, mult: int, axis: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _offsets(t: jax.Array, key: jax.Array | None, noise: mrr.NoiseModel,
             var: mrr.StaticVariation | None):
    """Fold per-shot draws + static variation into the three additive
    offsets of the realization chain, broadcast to the operand's shape.

    Draw discipline matches mrr.weight_of_voltage exactly: the side key
    splits into (DAC, thermal) and each perturbation is sigma * N(0, 1).
    """
    if noise.is_ideal:
        e_dac = e_th = jnp.zeros((), t.dtype)
    else:
        if key is None:
            raise ValueError("noisy realization requires a PRNG key")
        k_dac, k_th = jax.random.split(key)
        e_dac = noise.sigma_dac * jax.random.normal(k_dac, t.shape, t.dtype)
        e_th = noise.sigma_th * jax.random.normal(k_th, t.shape, t.dtype)
    z = jnp.zeros((), t.dtype)
    dv, ddt, dlam = ((var.dv, var.ddt, var.dlam) if var is not None
                     else (z, z, z))
    return tuple(jnp.broadcast_to(jnp.asarray(o, t.dtype), t.shape)
                 for o in (e_dac + dv, e_th + ddt, dlam))


@functools.partial(jax.jit, static_argnames=(
    "mapping", "mode", "quant_bits", "pam_bits", "act_per_vector", "noise",
    "osa_cfg", "p", "bm", "bn", "bk"))
def rosa_fused_matmul(x: jax.Array, w: jax.Array,
                      key: jax.Array | None = None,
                      var: mrr.StaticVariation | None = None,
                      gate: jax.Array | None = None,
                      mgate: jax.Array | None = None, *,
                      mapping: Mapping = Mapping.WS,
                      mode: ComputeMode = ComputeMode.MIXED,
                      quant_bits: int = 8, pam_bits: int = 1,
                      act_per_vector: bool = False,
                      noise: mrr.NoiseModel = mrr.IDEAL,
                      osa_cfg: osa.OSAConfig = osa.IDEAL_OSA,
                      p: mrr.MRRParams = mrr.DEFAULT_PARAMS,
                      bm: int = 128, bn: int = 128,
                      bk: int = 128) -> jax.Array:
    """y = x @ w through the fused analog pipeline; x: (M, K), w: (K, N).

    Semantics are those of the composed `rosa.backends._forward` with the
    "ref" contraction backend (the parity tests pin this); `gate`, `mgate`
    and `var` leaves enter as kernel OPERANDS, so gated evaluators sweep
    them without retracing.  Two contract caveats: (a) the kernel assumes
    the quantizer's 1e-8 absmax floor never binds (operands whose global
    absmax is below 1e-8 are a degenerate all-zero edge case); (b) the
    in-kernel realization chain reorders float ops vs the composed path,
    so a conditioned activation landing within float noise of a
    requantization rounding boundary may flip one 8-bit code — each flip
    moves that row's outputs by at most one requant LSB (the parity tests
    assert this bound; see tests/test_kernels.py::assert_quantized_parity).
    """
    if mode is ComputeMode.DIGITAL:
        raise ValueError("DIGITAL layers take the exact digital path; the "
                         "fused kernel serves MIXED and ANALOG modes")
    x = x.astype(jnp.float32)
    w = w.astype(jnp.float32)
    m, k = x.shape
    _, n = w.shape
    qcfg = Q.QuantConfig(bits=quant_bits)
    analog = mode is ComputeMode.ANALOG
    if analog:
        mgate = None                 # _forward's ANALOG branch ignores it
    use_mgate = mgate is not None
    use_gate = gate is not None

    # -- which sides realize (static; mirrors _analog_operand's shortcut) --
    can_realize = not (noise.is_ideal and var is None and gate is None)
    w_active = use_mgate or analog or mapping in (Mapping.WS, Mapping.GEMM)
    x_active = use_mgate or analog or not w_active
    realize_w = w_active and can_realize
    realize_x = x_active and can_realize

    # -- key split (must match _forward bit-for-bit) --
    if use_mgate or analog:
        k_w, k_x = (jax.random.split(key) if key is not None
                    else (None, None))
    elif w_active:
        k_w, k_x = key, None
    else:
        k_w, k_x = None, key

    # -- scales --
    sw = Q.absmax_scale(w)
    if analog:
        sxd = sxa = s2 = Q.absmax_scale(x)
    else:
        sxd = Q.absmax_scale(x, act_per_vector)
        sxa = Q.absmax_scale(x, True)
        # requant scale of the CONDITIONED activations: a global reduction
        # the tiled kernel cannot see — recompute the composed operand
        # elementwise (ref.condition_x consumes the same k_x, so its noise
        # draws are the kernel's) and take its absmax
        x_eff_pre = ref.condition_x(
            x, k_x, x_active=realize_x, use_mgate=use_mgate, mgate=mgate,
            gate=gate, var=var, qcfg=qcfg, p=p,
            noise=noise if realize_x else mrr.IDEAL,
            act_per_vector=act_per_vector)
        s2 = Q.absmax_scale(x_eff_pre, act_per_vector)

    # -- noise/variation offsets per realized orientation --
    x_off = (_offsets(x, k_x, noise, var) if realize_x else None)
    w_off = (_offsets(w, k_w, noise, mrr.expand_lanes(var, w))
             if realize_w else None)

    # -- OSA slot gains (jitter needs a key the composed ref path never
    # threads either — slot_jitter_sigma != 0 raises, same as _ref_backend)
    if analog:
        n_planes = 1
        gains = jnp.ones((1,), jnp.float32)
    else:
        n_planes = -(-qcfg.n_planes // pam_bits)
        gains = osa.slot_gains(
            dataclasses.replace(osa_cfg, n_slots=n_planes,
                                pam_bits=pam_bits), None, jnp.float32)

    # -- pad + launch --
    xp = _pad_to(_pad_to(x, bm, 0), bk, 1)
    wp = _pad_to(_pad_to(w, bk, 0), bn, 1)
    mp = xp.shape[0]

    def col(s):
        return jnp.broadcast_to(jnp.asarray(s, jnp.float32), (m, 1)) \
            if jnp.ndim(s) == 0 else jnp.asarray(s, jnp.float32)

    sx = jnp.concatenate([col(sxd), col(sxa), col(s2)], axis=1)
    sx = jnp.pad(sx, ((0, mp - m), (0, 0)), constant_values=1.0)
    z = jnp.float32(0.0)
    gg = jnp.stack([jnp.asarray(gate, jnp.float32) if use_gate else z,
                    jnp.asarray(mgate, jnp.float32) if use_mgate else z,
                    jnp.asarray(sw, jnp.float32)])
    if x_off is not None:
        x_off = tuple(_pad_to(_pad_to(o, bm, 0), bk, 1) for o in x_off)
    if w_off is not None:
        w_off = tuple(_pad_to(_pad_to(o, bk, 0), bn, 1) for o in w_off)

    if obs.enabled():
        # trace-time only (the Engine.matmul pattern): one instant per
        # traced fused launch, so compile timelines show ONE kernel where
        # the composed path showed four device ops
        obs.instant("kernels.rosa_fused", "compile", m=m, k=k, n=n,
                    mapping=mapping.name, mode=mode.name,
                    realize_x=realize_x, realize_w=realize_w,
                    gated=use_gate, mapping_gated=use_mgate)

    y = rosa_fused_pallas(
        xp, wp, gains, sx, gg, x_off, w_off, analog=analog,
        n_planes=n_planes, radix_bits=pam_bits, qmax=qcfg.qmax,
        realize_x=realize_x, realize_w=realize_w, use_gate=use_gate,
        use_mgate=use_mgate, k_real=k, p=p, bm=bm, bn=bn, bk=bk,
        interpret=not on_tpu())
    return y[:m, :n]


def preflight(m: int, k: int, n: int, *, bm: int = 128, bn: int = 128,
              bk: int = 128, quant_bits: int = 8, pam_bits: int = 1,
              realize_x: bool = True, realize_w: bool = True) -> dict:
    """Static tileability/VMEM report for a fused (m, k, n) GEMM — no launch.

    Mirrors `rosa_fused_matmul`'s layout: pad every dimension to its block
    multiple, run an (m/bm, n/bn) grid with a k-step inner loop, and hold
    the x/w blocks, the per-row scale and gate operands, three offset
    streams per realized side, and the f32 accumulator scratch in VMEM
    (in/out blocks double-buffered by the pipeline).  Defaults price the
    worst-case launch (both orientations realized — the mapping-gate
    superposition the analysis sweep must budget for)."""
    n_planes = -(-Q.QuantConfig(bits=quant_bits).n_planes // pam_bits)
    issues: list[str] = []
    if min(m, k, n) <= 0 or min(bm, bn, bk) <= 0:
        issues.append(f"non-positive dimension in m,k,n={m},{k},{n} "
                      f"bm,bn,bk={bm},{bn},{bk}")
        return {"kernel": "rosa_fused", "grid": (0, 0, 0), "vmem_bytes": 0,
                "pad_waste": 0.0, "issues": issues}
    # f32 min tile is (8, 128): sublane dims % 8, lane dims % 128
    if bm % 8:
        issues.append(f"bm={bm} not a multiple of 8 (f32 sublane tile)")
    if bk % 128:
        issues.append(f"bk={bk} not a multiple of 128 (x-block lane dim)")
    if bn % 128:
        issues.append(f"bn={bn} not a multiple of 128 (w-block lane dim)")
    mp = -(-m // bm) * bm
    kp = -(-k // bk) * bk
    np_ = -(-n // bn) * bn
    grid = (mp // bm, np_ // bn, kp // bk)
    x_streams = 1 + 3 * realize_x            # x + its offset operands
    w_streams = 1 + 3 * realize_w            # w + its offset operands
    vmem = 4 * (2 * (x_streams * bm * bk + w_streams * bk * bn)
                + 2 * (3 * bm + 3)           # scale + gate operands (dbuf)
                + 2 * bm * bn                # double-buffered out block
                + bm * bn                    # accumulator scratch
                + n_planes)                  # slot gains
    pad_waste = (mp * kp * np_) / (m * k * n) - 1.0
    return {"kernel": "rosa_fused", "grid": grid, "vmem_bytes": vmem,
            "pad_waste": pad_waste, "issues": issues}
