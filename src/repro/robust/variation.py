"""Per-device static variation: the chip-ensemble half of the noise model.

The paper's Eq. (8) noise model draws a fresh DAC/thermal perturbation on
every shot (`mrr.NoiseModel`).  Fabricated chips additionally differ from
each other *statically*: driver/DAC offsets, thermal-crosstalk bias from
neighbouring heaters, and fab mismatch of each ring's resonance wavelength
(cf. the MRR-crossbar variation studies, arXiv:2106.04351 /
arXiv:2111.06705).  This module samples those static fields ONCE per chip
as a pytree keyed by layer name — an "N-chip wafer" is the same pytree with
a leading ensemble axis, ready for `jax.vmap` (`repro.robust.ensemble`).

Convention: variation fields are sampled per *reduction lane* (shape (K,)
— one entry per physical ring lane; the OPE tile is reused across output
channels, so lane mismatch correlates along N).  `rosa.backends` adapts
the orientation per operand, so the SAME chip sample serves both IS and WS
mappings — exactly what the sensitivity profiler needs.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Mapping as TMapping, Sequence

import jax
import jax.numpy as jnp

from repro.core import mrr
from repro.core.constants import SIGMA_DAC_DEFAULT, SIGMA_TH_DEFAULT


@dataclasses.dataclass(frozen=True)
class VariationModel:
    """Standard deviations of the per-chip static fields (hashable spec)."""

    sigma_v_static: float = 0.5 * SIGMA_DAC_DEFAULT    # [V] driver offset
    sigma_dt_static: float = SIGMA_TH_DEFAULT          # [K] thermal bias
    sigma_lambda_fab: float = 0.01                     # [nm] fab mismatch
    #   (post-trim residual mismatch; raw fab spread is ~10x larger but
    #   chips ship after a one-time per-ring trim)

    @property
    def is_zero(self) -> bool:
        """Whether every variation sigma is exactly zero."""
        return (self.sigma_v_static == 0.0 and self.sigma_dt_static == 0.0
                and self.sigma_lambda_fab == 0.0)

    def scaled(self, s: float) -> "VariationModel":
        """Model with every sigma multiplied by `s`."""
        return VariationModel(self.sigma_v_static * s,
                              self.sigma_dt_static * s,
                              self.sigma_lambda_fab * s)


NO_VARIATION = VariationModel(0.0, 0.0, 0.0)
PAPER_VARIATION = VariationModel()

# A chip: {layer_name: StaticVariation}; an ensemble is the same pytree
# with a leading n_chips axis on every leaf.
Chip = dict[str, mrr.StaticVariation]


def _layer_fold(key: jax.Array, name: str) -> jax.Array:
    """Name-stable per-layer subkey (same CRC folding as rosa.layer_key)."""
    return jax.random.fold_in(key,
                              zlib.crc32(name.encode("utf-8")) & 0x7FFFFFFF)


def sample_layer(key: jax.Array, model: VariationModel,
                 lanes: int | Sequence[int]) -> mrr.StaticVariation:
    """One layer's static fields: (K,) lane vectors (or a full shape)."""
    shape = (lanes,) if isinstance(lanes, int) else tuple(lanes)
    k_v, k_t, k_l = jax.random.split(key, 3)
    return mrr.StaticVariation(
        dv=model.sigma_v_static * jax.random.normal(k_v, shape),
        ddt=model.sigma_dt_static * jax.random.normal(k_t, shape),
        dlam=model.sigma_lambda_fab * jax.random.normal(k_l, shape))


def sample_chip(key: jax.Array, dims: TMapping[str, int | Sequence[int]],
                model: VariationModel = PAPER_VARIATION) -> Chip:
    """Draw ONE fabricated chip: independent static fields per layer.

    `dims` maps layer name -> lane count K (or a full field shape).  Layer
    subkeys are folded from the name, so adding/removing layers never
    perturbs the draw of the others.
    """
    return {name: sample_layer(_layer_fold(key, name), model, lanes)
            for name, lanes in dims.items()}


def sample_ensemble(key: jax.Array, n_chips: int,
                    dims: TMapping[str, int | Sequence[int]],
                    model: VariationModel = PAPER_VARIATION, *,
                    antithetic: bool = False) -> Chip:
    """An "N-chip wafer": `sample_chip` vmapped over `n_chips` keys.

    Every leaf gains a leading ensemble axis.  With ``antithetic=True``
    (requires even `n_chips`) only ``n_chips // 2`` chips are drawn and
    chip ``2i + 1`` is the sign-mirror of chip ``2i`` (every static field
    negated).  The static fields are zero-mean Gaussians, so the mirrored
    chip follows the SAME marginal distribution — the ensemble stays an
    unbiased sample — but each pair's accuracy errors anticorrelate, which
    cuts the Monte-Carlo variance of ensemble means (the antithetic-variate
    half of `repro.robust.ensemble.estimate_ensemble`).
    """
    if not antithetic:
        keys = jax.random.split(key, n_chips)
        return jax.vmap(lambda k: sample_chip(k, dims, model))(keys)
    if n_chips % 2:
        raise ValueError(f"antithetic sampling pairs chips: n_chips must "
                         f"be even, got {n_chips}")
    half = sample_ensemble(key, n_chips // 2, dims, model)
    return jax.tree.map(
        lambda a: jnp.stack([a, -a], axis=1).reshape(n_chips, *a.shape[1:]),
        half)


def chip_at(ensemble: Chip, i) -> Chip:
    """Select chip `i` (Python int or traced index) out of an ensemble."""
    return jax.tree.map(lambda a: a[i], ensemble)


def chip_slice(ensemble: Chip, n: int) -> Chip:
    """The first `n` chips of an ensemble (the estimator's probe set)."""
    return jax.tree.map(lambda a: a[:n], ensemble)


def ensemble_size(ensemble: Chip) -> int:
    """Number of chips in an ensemble pytree (leading axis)."""
    return jax.tree.leaves(ensemble)[0].shape[0]


def scale_ensemble(ensemble: Chip, s) -> Chip:
    """Scale every static field (sigma-sweep knob)."""
    return jax.tree.map(lambda a: a * s, ensemble)


def shift_thermal(ensemble: Chip, offset) -> Chip:
    """Add a global thermal offset [K] to every layer's ddt field — the
    injection point for drift schedules (`repro.robust.drift`).
    """
    return {name: v.shift_ddt(offset) for name, v in ensemble.items()}


def cnn_lane_dims(model: str) -> dict[str, int]:
    """Reduction-lane count per layer of a lite CNN (weight K dimension)."""
    from repro.models.cnn import LITE_MODELS
    dims: dict[str, int] = {}
    for s in LITE_MODELS[model]:
        if s.kind == "fc":
            dims[s.name] = s.c_in
        elif s.kind == "dwconv":
            dims[s.name] = s.c_in       # per-channel rings
        else:
            dims[s.name] = s.c_in * s.k * s.k
    return dims
