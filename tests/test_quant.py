"""Quantization / signed-digit plane invariants (hypothesis-driven)."""

import hypothesis as hp
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant as Q


@hp.given(st.integers(2, 8), st.integers(0, 2 ** 31 - 1))
@hp.settings(max_examples=40, deadline=None)
def test_plane_roundtrip_exact(bits, seed):
    cfg = Q.QuantConfig(bits=bits)
    q = jax.random.randint(jax.random.PRNGKey(seed), (32,),
                           -cfg.qmax, cfg.qmax + 1).astype(jnp.float32)
    planes = Q.decompose_planes(q, cfg)
    assert planes.shape == (cfg.n_planes, 32)
    assert set(np.unique(np.asarray(planes))) <= {-1.0, 0.0, 1.0}
    np.testing.assert_array_equal(np.asarray(Q.compose_planes(planes, cfg)),
                                  np.asarray(q))


@hp.given(st.integers(2, 8), st.sampled_from([1, 2, 3, 4]),
          st.integers(0, 2 ** 31 - 1))
@hp.settings(max_examples=40, deadline=None)
def test_pam_roundtrip_exact(bits, pam_bits, seed):
    cfg = Q.QuantConfig(bits=bits)
    q = jax.random.randint(jax.random.PRNGKey(seed), (16,),
                           -cfg.qmax, cfg.qmax + 1).astype(jnp.float32)
    digits = Q.decompose_pam(q, pam_bits, cfg)
    assert digits.shape[0] == -(-cfg.n_planes // pam_bits)
    np.testing.assert_array_equal(
        np.asarray(Q.compose_pam(digits, pam_bits, cfg)), np.asarray(q))


@hp.given(st.integers(0, 2 ** 31 - 1))
@hp.settings(max_examples=20, deadline=None)
def test_quantize_bounds_and_scale(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * 10
    q, scale = Q.quantize(x)
    assert float(jnp.max(jnp.abs(q))) <= 127
    err = jnp.max(jnp.abs(Q.dequantize(q, scale) - x))
    assert float(err) <= float(scale) / 127 * 0.5 + 1e-6


def test_fake_quant_idempotent(key):
    x = jax.random.normal(key, (128,))
    x1 = Q.fake_quant(x)
    x2 = Q.fake_quant(x1)
    np.testing.assert_allclose(np.asarray(x1), np.asarray(x2), atol=1e-6)


def test_fake_quant_straight_through_grad(key):
    x = jax.random.normal(key, (16,))
    g = jax.grad(lambda v: jnp.sum(Q.fake_quant(v)))(x)
    np.testing.assert_allclose(np.asarray(g), 1.0, atol=1e-6)
