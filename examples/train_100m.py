"""End-to-end driver: train a ~100M-param qwen3-family model for a few
hundred steps on the deterministic token pipeline, with checkpointing.

This wraps launch/train.py with a 100M-parameter configuration; on the
container's single CPU core a few hundred steps take tens of minutes —
pass --steps to shorten.  Loss drops well below the ln(vocab) floor within
the first ~100 steps (the pipeline is a learnable noisy-bigram stream).

Run:  PYTHONPATH=src python examples/train_100m.py [--steps 300]
"""

import argparse
import subprocess
import sys

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()
    # qwen3 smoke family scaled to ~120M params: 8L x 768 x 3072, vocab 32k
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", "qwen3-32b", "--smoke",
           "--d-model", "768", "--n-layers", "8",
           "--d-ff", "3072", "--vocab", "32000",
           "--steps", str(args.steps), "--batch", str(args.batch),
           "--seq", str(args.seq), "--lr", "1e-3",
           "--ckpt-dir", "results/ckpt_100m", "--ckpt-every", "100"]
    raise SystemExit(subprocess.call(cmd))
