"""The closed loop: drift step, HEALTHY→DEGRADED→RETRIM→REPLAN controller.

`make_drift_step` is the plant model: the scheduler's decode step with the
thermal residual as ONE extra traced scalar — per-tick drift re-dispatches
the same executable (the chip's `StaticVariation` is a pytree, so the
shifted leaves flow straight through the engine).

`AdaptiveController` is a `serve.TickHook`.  Per tick it feeds the
residual into the decode step (`step_args`) and, between ticks
(`on_tick_end`), folds a temperature-sensor reading into the detector,
probes on idle slots, and acts:

  HEALTHY   probes agree with the golden reference; no action
  DEGRADED  CUSUM fired: apply `trim_voltages` at the predicted
            temperature (an actuator write — the programmed voltages
            absorb the estimated offset, leaving only tracking error as
            residual) and ENGAGE the thermal servo: from here on the trim
            follows the alpha-beta prediction every tick (within a
            deadband), because a drift that fired once keeps moving and a
            probe-cadence trim goes stale between windows
  RETRIM    servo engaged, validating: back to HEALTHY once probes
            re-enter the slack band (servo stays engaged — hysteresis is
            for the state machine, not the actuator); REPLAN if agreement
            stays below the guard floor even with a fresh trim
  REPLAN    re-measure the degradation matrix at the live residual, store
            it in the `PlanCache`, re-run the accuracy-aware plan search,
            and swap the serving `Program` double-buffered: the new decode
            step is compiled and warmed BEFORE the pointer swap, which
            happens between ticks — in-flight KV slots carry over
            untouched and no request is ever dropped or perturbed.

`DriftMonitor` is the uncontrolled arm of the A/B: same drift injection,
same probe cadence, no actions — the bench baseline.
"""

from __future__ import annotations

import dataclasses
import enum
import time

import jax
import jax.numpy as jnp

from repro.core.constants import ROSA_OPTIMAL
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs
from repro.robust import variation as V
from repro.rosa.engine import engine_context
from repro.serve.adaptive.probes import ProbeConfig, ProbeSet
from repro.serve.adaptive.detector import DetectorConfig, DriftDetector
from repro.serve.decode import (_step_body, make_admit_step, make_chunk_fn,
                                make_evict)
from repro.serve.scheduler import TickHook, _ledger_scope


def make_drift_step(bundle, scfg, program):
    """The serving decode step with a traced thermal residual [K].

    Signature: `step(params, state, admit, temperature, resid_k)` — drop-in
    for `Scheduler.step` when a `TickHook.step_args` supplies the trailing
    scalar.  The engine context is installed inside the traced body (same
    trick as `Program.bind`), so the shifted chip is re-derived from the
    traced residual and nothing retraces tick-to-tick."""
    engine = program.engine
    chip = dict(engine.variation or {})

    def step(params, state, admit, temperature, resid_k):
        eng = engine
        if chip:
            eng = engine.with_variation(V.shift_thermal(chip, resid_k))
        with engine_context(eng):
            return _step_body(bundle, scfg, params, state, admit,
                              temperature, jnp.zeros((), jnp.int32))

    return jax.jit(step, donate_argnums=(1,))


class ControllerState(enum.IntEnum):
    """Gauge-friendly controller states (`serve.adaptive.state`)."""

    HEALTHY = 0
    DEGRADED = 1
    RETRIM = 2
    REPLAN = 3


@dataclasses.dataclass(frozen=True)
class ControllerConfig:
    """Closed-loop policy knobs."""

    probe_every: int = 4        # ticks between probe attempts
    starve_factor: int = 4      # probe anyway after this many skipped
    #                             windows with no idle slot (never go blind)
    warmup_ticks: int = 4       # no probes before this tick: lets the
    #                             temperature filter settle and keeps an
    #                             epoch of bit-exact pre-action traffic
    guard_agreement: float = 0.60   # post-retrim floor: below this a
    #                                 FRESH trim did not save us -> REPLAN
    trim_slack_k: float = 0.08      # REPLAN only once the applied trim
    #                                 already matches the temperature
    #                                 estimate this closely — a stale trim
    #                                 means re-trim, not re-plan
    trim_deadband_k: float = 0.005   # servo writes the trim only when the
    #                                 prediction moved this far (skip
    #                                 actuator churn inside sensor noise)
    allow_replan: bool = True
    force_replan_at: int | None = None   # deterministic swap trigger
    #                                      (bench pins swap metrics on it)


class DriftMonitor(TickHook):
    """Uncontrolled arm: inject drift, probe, record — never act.

    Owns everything the A/B must share with the controller: the drift
    step installation, the probe cadence and the telemetry series, so the
    two arms differ ONLY in the corrective actions."""

    def __init__(self, sched, env, probes: ProbeSet | None = None,
                 cfg: ControllerConfig = ControllerConfig()):
        if sched.program is None:
            raise ValueError("adaptive serving needs scfg.rosa=True "
                             "(the scheduler must carry a rosa.Program)")
        self.env = env
        self.cfg = cfg
        self.probes = probes if probes is not None \
            else ProbeSet(sched.bundle, sched.program)
        # idempotent install: the A/B harness runs two hooks over ONE
        # scheduler, and both arms must share the same compiled step
        if getattr(sched, "_drift_program", None) is not sched.program:
            sched.step = make_drift_step(sched.bundle, sched.scfg,
                                         sched.program)
            sched._drift_program = sched.program
        self.trim_k = 0.0
        self.first_action_tick = 10 ** 9    # no action yet
        # drift-free reference: the health bar every probe is scored
        # against (also compiles the shared evaluator, before traffic)
        self.ref_agreement = self.probes.agreement(sched.params, 0.0)
        self.series: list[dict] = []        # one row per executed probe
        self.tick_wall_s: list[float] = []
        self.retrims = 0
        self.replans = 0
        self.swaps: list[dict] = []
        self._last_probe = -10 ** 9
        self._last_wall: float | None = None

    # -- TickHook protocol --------------------------------------------------
    def step_args(self, tick: int) -> tuple:
        """The plant: physical residual = true drift minus applied trim."""
        return (jnp.float32(self.env.residual(tick, self.trim_k)),)

    def on_tick_end(self, sched, tick, state, idle_slots) -> None:
        now = time.perf_counter()
        if self._last_wall is not None:
            self.tick_wall_s.append(now - self._last_wall)
        self._last_wall = now
        if self._probe_due(tick, idle_slots):
            self._last_probe = tick
            resid = self.env.residual(tick, self.trim_k)
            with obs.span("adaptive.probe", "adaptive", tick=tick):
                agree = self.probes.agreement(sched.params, resid,
                                              tick=tick)
            self.series.append({"tick": tick, "resid_k": resid,
                                "agreement": agree,
                                "trim_k": self.trim_k,
                                "energy_per_token_j": _energy(sched)})
            self._after_probe(sched, tick, state, agree)

    # -- shared helpers -----------------------------------------------------
    def _probe_due(self, tick: int, idle_slots: int) -> bool:
        """Piggyback rule: probe on cadence when a decode slot idles;
        starvation override keeps a saturated fleet from going blind."""
        if tick < self.cfg.warmup_ticks:
            return False
        since = tick - self._last_probe
        if since < self.cfg.probe_every:
            return False
        return idle_slots > 0 \
            or since >= self.cfg.probe_every * self.cfg.starve_factor

    def _after_probe(self, sched, tick, state, agreement: float) -> None:
        """Monitor: record only."""

    @property
    def mean_agreement(self) -> float:
        if not self.series:
            return float("nan")
        return sum(r["agreement"] for r in self.series) / len(self.series)


class AdaptiveController(DriftMonitor):
    """The acting arm: detector + state machine + program swap."""

    def __init__(self, sched, env, probes: ProbeSet | None = None,
                 cfg: ControllerConfig = ControllerConfig(),
                 det_cfg: DetectorConfig = DetectorConfig(),
                 plan_cache=None):
        super().__init__(sched, env, probes, cfg)
        self.detector = DriftDetector(det_cfg, self.ref_agreement)
        self.state = ControllerState.HEALTHY
        self.tracking = False     # thermal servo engaged (sticky)
        self.trim_updates = 0     # actuator writes, incl. servo follow-ups
        self.plan_cache = plan_cache
        reg = obs_metrics.registry()
        self._g_state = reg.gauge("serve.adaptive.state")
        self._g_drift = reg.gauge("serve.adaptive.drift_est_k")
        self._c_retrim = reg.counter("serve.adaptive.retrims")
        self._c_replan = reg.counter("serve.adaptive.replans")
        self._g_state.set(int(self.state))

    def on_tick_end(self, sched, tick, state, idle_slots) -> None:
        # sensor readings are cheap: fold one in EVERY tick so the
        # tracking estimate is fresh whenever a probe decides to act on it
        self._g_drift.set(self.detector.observe_temp(self.env.sense(tick)))
        # probe FIRST (scores the trim that actually served this tick),
        # THEN let the servo re-aim the trim at the next tick's predicted
        # temperature — writing first would skew every probe by one tick
        # of drift slope
        super().on_tick_end(sched, tick, state, idle_slots)
        if self.tracking:
            target = self.detector.predict()
            if abs(target - self.trim_k) > self.cfg.trim_deadband_k:
                self._write_trim(target, tick)
        if self.cfg.force_replan_at is not None \
                and tick == self.cfg.force_replan_at and not self.replans:
            self._replan(sched, tick, state)

    def _after_probe(self, sched, tick, state, agreement: float) -> None:
        det = self.detector
        fired = det.update(agreement)
        in_band = (det.ref - agreement) <= det.cfg.cusum_k
        if self.state in (ControllerState.HEALTHY, ControllerState.REPLAN):
            if fired:
                self._transition(ControllerState.DEGRADED, tick)
                self._retrim(tick)
            elif self.state is ControllerState.REPLAN and in_band:
                self._transition(ControllerState.HEALTHY, tick)
        elif self.state is ControllerState.RETRIM:
            trim_fresh = abs(det.predict() - self.trim_k) \
                <= self.cfg.trim_slack_k
            if in_band:
                det.reset()
                self._transition(ControllerState.HEALTHY, tick)
            elif agreement < self.cfg.guard_agreement and trim_fresh \
                    and self.cfg.allow_replan:
                # trimmed at the best available estimate and STILL below
                # guard: thermal compensation is out of ammunition
                self._replan(sched, tick, state)
            # else: the servo is already following the prediction every
            # tick — nothing for the state machine to add

    # -- actions ------------------------------------------------------------
    def _transition(self, to: ControllerState, tick: int) -> None:
        self.state = to
        self._g_state.set(int(to))
        obs.instant(f"adaptive.{to.name.lower()}", cat="adaptive",
                    tick=tick)

    def _write_trim(self, target_k: float, tick: int) -> None:
        """One actuator write: program trim voltages for `target_k`.  By
        the trim identity (`voltage_of_weight(dt_trim=d)` under offset d
        == untrimmed under offset 0; pinned in tests/test_adaptive.py)
        this is exactly `trim_k = target` on the injected residual."""
        self.trim_k = float(target_k)
        self.first_action_tick = min(self.first_action_tick, tick)
        self.trim_updates += 1

    def _retrim(self, tick: int) -> None:
        """Corrective action: trim at the predicted temperature and keep
        the servo engaged — drift that fired once keeps moving, and a
        probe-cadence trim would go stale between windows."""
        self._write_trim(self.detector.predict(), tick)
        self.tracking = True
        self.retrims += 1
        self._c_retrim.inc()
        self.detector.reset()
        self._transition(ControllerState.RETRIM, tick)

    def _replan(self, sched, tick, state) -> None:
        """Measure → search → compile → warm → swap, all between ticks."""
        from repro import rosa

        t0 = time.perf_counter()
        self.first_action_tick = min(self.first_action_tick, tick)
        self._transition(ControllerState.REPLAN, tick)
        resid = self.env.residual(tick, self.trim_k)
        with obs.span("adaptive.replan", "adaptive", tick=tick):
            rows = self.probes.degradation_rows(sched.params, resid,
                                                tick=tick)
            base_cfg = sched.program.engine.plan.default
            store = rosa.PlanCache() if self.plan_cache is None \
                else self.plan_cache
            spec = {"kind": "serve-adaptive",
                    "model": sched.bundle.cfg.name,
                    "n_probes": self.probes.cfg.n_probes,
                    "prompt_len": self.probes.cfg.prompt_len,
                    "seed": self.probes.cfg.seed,
                    "resid_mk": round(resid * 1e3)}
            store.store_matrix(rosa.PlanCache.matrix_key(base_cfg, spec),
                               rows)
            from repro.serve.metrics import _abstract_decode_batch
            bundle, scfg = sched.bundle, sched.scfg
            new_prog = rosa.compile(
                lambda eng, p, b: bundle.decode_step(p, b),
                rosa.Engine.from_config(base_cfg),
                (bundle.abstract(jnp.float32),
                 _abstract_decode_batch(bundle.cfg, scfg)),
                autotune=rosa.AutotuneConfig(ope=ROSA_OPTIMAL, batch=1),
                degradation=rows, cache=store)
            new_prog = new_prog.with_variation(self.probes.chip) \
                .with_ledger(rosa.EnergyLedger())
            # double buffer: build + warm EVERY step against the live
            # state's shapes BEFORE any pointer moves, so the swapped-in
            # program never compiles (or drops a tick) on the serving path
            new_step = make_drift_step(bundle, scfg, new_prog)
            dummy = jax.tree.map(jnp.zeros_like, state)
            with _ledger_scope(new_prog.engine, "decode"):
                warm_out = new_step(sched.params, dummy, sched.null,
                                    jnp.float32(scfg.temperature),
                                    jnp.float32(resid))
            jax.block_until_ready(warm_out[0].tok)
            new_admit = make_admit_step(bundle, scfg, program=new_prog)
            new_chunk = make_chunk_fn(bundle, program=new_prog)
            new_whole = new_prog.bind(bundle.prefill)
            new_evict = make_evict(bundle, scfg, program=new_prog) \
                if scfg.evict_on_done else None
            # the swap: host-side pointer writes between ticks — in-flight
            # slots (DecodeState) carry over untouched
            sched.program, sched.engine = new_prog, new_prog.engine
            sched.step = new_step
            sched.admit_step = new_admit
            sched.chunk_fn = new_chunk
            sched.whole_fn = new_whole
            sched.evict = new_evict
            sched._drift_program = new_prog
            self.probes.rebind(new_prog)
        self.replans += 1
        self._c_replan.inc()
        self.detector.reset()
        self.swaps.append({"tick": tick, "wall_s": time.perf_counter() - t0,
                           "downtime_ticks": 0,
                           "plan": {n: m.value for n, m in
                                    new_prog.engine.plan.mapping_plan()
                                    .items()}})


def _energy(sched) -> float:
    """Energy per generated token [J] of the CURRENT program's decode
    trace (0.0 until the first decode step traced)."""
    ledger = sched.engine.ledger if sched.engine is not None else None
    if ledger is None:
        return 0.0
    try:
        return float(ledger.per_token(ROSA_OPTIMAL,
                                      batch=sched.scfg.n_slots))
    except (ValueError, ZeroDivisionError):
        return 0.0
