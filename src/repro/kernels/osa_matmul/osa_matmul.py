"""Pallas TPU kernel: OSA bit-serial signed-digit matmul.

TPU adaptation of the paper's optical shift-and-add MAC (DESIGN.md sec. 2):
the optical pipeline's per-bit-slot partial products and splitter/ODL
recombination become, on TPU,

  1. signed digit-plane extraction of int8 activations **inside VMEM**
     (the EO modulator's time slots),
  2. per-plane contributions weighted by the slot-gain ladder (the optical
     shift — ideal gains are exact powers of two),
  3. a single f32 VMEM accumulator written back once per (M, N) tile (the
     photodetector's one-conversion-per-output, i.e. OSA's whole point).

Two execution modes, both bit-exact against ref.py under ideal gains:

  * fused (default): because the MXU computes in full precision, the slot
    recombination sum_t g_t * plane_t can be folded BEFORE the matmul —
    one MXU pass instead of T.  This is the TPU-native insight: OSA's
    optical recombination has zero marginal cost on the MXU, so we hoist
    it.  (On the photonic chip the planes are physical time slots; on TPU
    they are algebra.)
  * per_plane: faithful emulation — one MXU matmul per digit plane,
    accumulated with its slot gain.  Needed when slot gains are per-plane
    *nonlinear* (e.g. studying detector saturation per slot) and as the
    paper-faithful reference timing model.

The HBM<->VMEM contract is what the paper's conversion-energy argument maps
to: activations are read from HBM once per (m, k) block, planes never
materialize in HBM, and the output tile is written once.

Block sizes default to MXU-aligned (128, 128, 128)-multiples; f32
accumulation in VMEM scratch across the K grid dimension.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels import tpu_compiler_params


def _plane(qf, t):
    """Signed digit plane t of integer-valued float tensor qf (VMEM-local)."""
    sign = jnp.sign(qf)
    mag = jnp.abs(qf).astype(jnp.int32)
    bit = (mag >> t) & 1
    return sign * bit.astype(qf.dtype)


def _kernel(q_ref, w_ref, g_ref, o_ref, acc_ref, *, n_planes: int,
            fused: bool, k_steps: int):
    """Grid = (M/bm, N/bn, K/bk); K innermost (sequential accumulation)."""
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qf = q_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    g = g_ref[...]                                   # (n_planes,) f32

    if fused:
        # Hoist the slot recombination: x_eff = sum_t g_t * plane_t(q).
        # With ideal gains (g_t = 2^t) x_eff == q and the extraction is
        # algebraically removable; with calibrated/non-ideal gains it is a
        # cheap VPU elementwise pass feeding one MXU matmul.
        x_eff = jnp.zeros_like(qf)
        for t in range(n_planes):
            x_eff = x_eff + g[t] * _plane(qf, t)
        acc_ref[...] += jnp.dot(x_eff, w, preferred_element_type=jnp.float32)
    else:
        # Faithful per-slot emulation: T MXU passes, one per digit plane.
        for t in range(n_planes):
            acc_ref[...] += g[t] * jnp.dot(_plane(qf, t), w,
                                           preferred_element_type=jnp.float32)

    @pl.when(k_idx == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("n_planes", "fused", "bm", "bn",
                                             "bk", "interpret"))
def osa_matmul_pallas(q: jax.Array, w: jax.Array, gains: jax.Array,
                      *, n_planes: int = 7, fused: bool = True,
                      bm: int = 128, bn: int = 128, bk: int = 128,
                      interpret: bool = False) -> jax.Array:
    """y = OSA(q) @ w with slot gains; q: (M, K) int values, w: (K, N).

    M, K, N must be multiples of (bm, bk, bn) — ops.py pads.
    """
    m, k = q.shape
    k2, n = w.shape
    assert k == k2, (q.shape, w.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    k_steps = k // bk

    grid = (m // bm, n // bn, k_steps)
    kernel = functools.partial(_kernel, n_planes=n_planes, fused=fused,
                               k_steps=k_steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((n_planes,), lambda i, j, kk: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, w, gains)
