"""Shared optimized-HLO text parser: FLOPs, HBM traffic, aliasing.

Grown out of `launch/hlo_analysis.py` (which now re-exports from here) so
the dry-run cost model and the static verifier read HLO through ONE parser.

Why this exists: `compiled.cost_analysis()` visits a while-loop body ONCE,
so for scan-over-layers models it reports ~1/L of the real FLOPs (verified
empirically — see EXPERIMENTS.md §Dry-run).  This module parses the
optimized per-device HLO text instead:

  1. split into computations; build a per-computation SYMBOL TABLE
     (operands are printed without shapes in scheduled HLO, so shapes are
     resolved from each value's defining line / the computation header);
  2. walk the call graph from ENTRY; while bodies multiply by the trip
     count XLA records in backend_config known_trip_count (fallback:
     largest s32 constant in the loop condition);
  3. accumulate per device:
       flops       — 2 * out_elems * K for every dot (K = contracting dims
                     of the lhs, batch dims excluded by construction);
       bytes       — operands + outputs of every top-level op except pure
                     bookkeeping (tuple/gte/parameter/bitcast/while/call —
                     fusion bodies are skipped for bytes: internals never
                     touch HBM; their dots still count flops);
       collectives — per kind, both conventions:
           operand_bytes: sum of operand sizes (assignment's definition)
           wire_bytes   : link traffic per device (all-gather: out-in;
                          reduce-scatter: in-out; all-reduce: 2*in;
                          permute / all-to-all: in).

Shapes in a GSPMD-partitioned module are per-device => per-device numbers.

The verifier additionally reads the module header's `input_output_alias`
map (`parse_input_output_aliases`) — the ground truth for whether a
`donate_argnums` declaration actually bought an in-place buffer.
"""

from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict

# Sub-byte dtypes carry fractional sizes; byte totals accumulate as floats
# and round up once at the end (an s4[7] really occupies 4 bytes packed).
_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1,
    "s4": 0.5, "u4": 0.5,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "f8e4m3fnuz": 1, "f8e5m2fnuz": 1, "f8e8m0fnu": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    # zero-size sentinels: control-flow tokens occupy no HBM
    "token": 0, "opaque": 0,
}
DTYPE_BYTES = _DTYPE_BYTES          # public alias

# Words that LOOK like an HLO element type.  _SHAPE_RE deliberately
# over-matches (any identifier followed by [dims] — e.g. "devices=[2,2]"
# inside a sharding annotation); only dtype-like matches participate in
# byte accounting, and a dtype-like word MISSING from _DTYPE_BYTES is a
# hard error instead of a silent undercount.
_DTYPE_LIKE = re.compile(
    r"^(?:pred|token|opaque|bf16|tf32|[sufc]\d+|f8e\d[a-z0-9]*"
    r"|f6e\d[a-z0-9]*|f4e\d[a-z0-9]*)$")

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]{1,11})\[([0-9,]*)\]")
_OPNAME_RE = re.compile(r"[\s)]([a-z][a-z0-9\-]*)\(")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')
_CONST_INT = re.compile(r"\bs32\[\]\s+constant\((\d+)\)")
_HDR_PARAM = re.compile(r"([\w\.\-]+):\s*((?:\([^)]*\))|(?:[a-z][a-z0-9]*\[[0-9,]*\](?:\{[0-9,]*\})?))")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_SKIP_BYTES_OPS = {"parameter", "tuple", "get-tuple-element", "constant",
                   "bitcast", "after-all", "while", "conditional", "call",
                   "iota", "partition-id", "replica-id"}


class UnknownDtypeError(ValueError):
    """An HLO shape used an element type missing from DTYPE_BYTES.

    Raised instead of silently skipping the tensor: an unaccounted dtype
    used to shave its bytes off every downstream roofline/traffic number
    with no signal at all.  Fix: add the dtype (and its size) to
    `repro.analysis.hlo.DTYPE_BYTES`."""


def _shape_list_bytes(text: str) -> int:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            if _DTYPE_LIKE.match(dt):
                raise UnknownDtypeError(
                    f"unknown HLO element type {dt!r} in {text[:80]!r}: "
                    f"add it to repro.analysis.hlo.DTYPE_BYTES so byte "
                    f"accounting stays exact")
            continue                 # not a shape (e.g. "devices=[2,2]")
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return int(math.ceil(total))


def _shape_dims(text: str) -> list[int] | None:
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


# ---------------------------------------------------------------------------
# Donation ground truth: the module header's alias map
# ---------------------------------------------------------------------------
_ALIAS_ENTRY = re.compile(r"\{([0-9,\s]*)\}\s*:\s*\((\d+)\s*,\s*\{([0-9,\s]*)\}")


def parse_input_output_aliases(hlo_text: str) -> list[tuple[int, str]]:
    """[(parameter_index, param_tuple_index), ...] from the module header's
    `input_output_alias={ {out}: (param, {idx}, kind), ... }` map — empty
    when XLA established no aliases (every donation was dropped)."""
    start = hlo_text.find("input_output_alias={")
    if start < 0:
        return []
    # brace-matched extraction: the value nests {out_index} tuples
    i = start + len("input_output_alias=")
    depth = 0
    for j in range(i, min(len(hlo_text), i + 100_000)):
        if hlo_text[j] == "{":
            depth += 1
        elif hlo_text[j] == "}":
            depth -= 1
            if depth == 0:
                body = hlo_text[i + 1:j]
                return [(int(p), t.replace(" ", ""))
                        for _, p, t in _ALIAS_ENTRY.findall(body)]
    return []


def entry_parameter_shapes(hlo_text: str) -> dict[int, str]:
    """{parameter_index: shape_text} of the ENTRY computation."""
    comps = _split(hlo_text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        return {}
    out: dict[int, str] = {}
    for ln in entry.lines:
        m = re.search(r"=\s*((?:\([^=]*?\))|(?:[a-z][a-z0-9]*\[[0-9,]*\]"
                      r"(?:\{[0-9,]*\})?))\s+parameter\((\d+)\)", ln)
        if m:
            out[int(m.group(2))] = m.group(1)
    return out


@dataclasses.dataclass
class Comp:
    name: str
    is_entry: bool = False
    lines: list = dataclasses.field(default_factory=list)
    symbols: dict = dataclasses.field(default_factory=dict)  # name -> shape str
    max_const: int = 0


def _split(hlo: str) -> dict[str, Comp]:
    comps: dict[str, Comp] = {}
    cur: Comp | None = None
    for line in hlo.splitlines():
        ls = line.rstrip()
        st = ls.strip()
        if st.endswith("{") and "->" in st and ("(" in st):
            is_entry = st.startswith("ENTRY")
            name_m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(", st)
            if name_m:
                cur = Comp(name_m.group(1), is_entry)
                comps[cur.name] = cur
                # header params: "name: shape"
                for pn, psh in _HDR_PARAM.findall(st):
                    cur.symbols[pn] = psh
                continue
        if st == "}" or st.startswith("}"):
            cur = None
            continue
        if cur is not None and st:
            cur.lines.append(st)
            if "=" in st:
                lhs, rhs = st.split("=", 1)
                vname = lhs.strip().lstrip("%").strip()
                # defining shape = first shape (or tuple) on the rhs
                mtup = re.match(r"\s*(\([^=]*?\))\s+[a-z]", rhs)
                if mtup:
                    cur.symbols[vname] = mtup.group(1)
                else:
                    msh = _SHAPE_RE.search(rhs)
                    if msh:
                        cur.symbols[vname] = msh.group(0)
            for m in _CONST_INT.finditer(st):
                cur.max_const = max(cur.max_const, int(m.group(1)))
    return comps


def _operand_names(rhs: str, op: str) -> list[str]:
    m = re.search(re.escape(op) + r"\(([^)]*)\)", rhs)
    if not m:
        return []
    # Operands may print bare ("%a, %b") or with inline shapes
    # ("f32[64,128]{1,0} %a, ..." — older jax); shape dims contain commas,
    # so extract the %names directly instead of comma-splitting.
    return re.findall(r"%([\w\.\-]+)", m.group(1))


def _sym_bytes(comp: Comp, names: list[str]) -> int:
    return sum(_shape_list_bytes(comp.symbols.get(n, "")) for n in names)


@dataclasses.dataclass
class HLOReport:
    flops: float
    bytes: float
    coll_operand: dict[str, float]
    coll_wire: dict[str, float]
    loop_counts: dict[str, int]
    dot_count: int = 0

    @property
    def collective_operand_total(self) -> float:
        return sum(self.coll_operand.values())

    @property
    def collective_wire_total(self) -> float:
        return sum(self.coll_wire.values())

    def as_dict(self) -> dict:
        return {"flops": self.flops, "bytes": self.bytes,
                "dot_count": self.dot_count,
                "coll_operand": dict(self.coll_operand),
                "coll_wire": dict(self.coll_wire),
                "coll_operand_total": self.collective_operand_total,
                "coll_wire_total": self.collective_wire_total,
                "loops": self.loop_counts}


def top_bytes(hlo_text: str, n: int = 20) -> list[tuple[float, str, str]]:
    """Largest HBM-traffic ops (bytes*multiplicity, op, line) — the profile
    view the §Perf hillclimb reads instead of a wall-clock trace."""
    comps = _split(hlo_text)
    rep_mult, fusion_bodies = _multiplicities(comps)
    tops = []
    for name, m in rep_mult.items():
        if name in fusion_bodies:
            continue
        c = comps[name]
        for ln in c.lines:
            if "=" not in ln:
                continue
            rhs = ln.split("=", 1)[1]
            opm = _OPNAME_RE.search(" " + rhs)
            op = opm.group(1) if opm else ""
            if not op or op in _SKIP_BYTES_OPS or op.endswith("-done"):
                continue
            out_b = _shape_list_bytes(rhs.split(op + "(")[0])
            in_b = _sym_bytes(c, _operand_names(rhs, op))
            tops.append(((out_b + in_b) * m, op, ln[:140]))
    tops.sort(key=lambda t: -t[0])
    return tops[:n]


def _multiplicities(comps) -> tuple[dict, set]:
    fusion_bodies: set[str] = set()
    for c in comps.values():
        for ln in c.lines:
            if " fusion(" in ln:
                m = re.search(r"calls=%?([\w\.\-]+)", ln)
                if m:
                    fusion_bodies.add(m.group(1))
    entry = next((c.name for c in comps.values() if c.is_entry), None)
    mult: dict[str, float] = defaultdict(float)

    def walk(name, m, depth=0):
        if name not in comps or depth > 64 or m <= 0:
            return
        mult[name] += m
        for ln in comps[name].lines:
            rhs = ln.split("=", 1)[-1]
            if "while(" in rhs:
                tm = _TRIP_RE.search(rhs)
                mc = re.search(r"condition=%?([\w\.\-]+)", rhs)
                trips = int(tm.group(1)) if tm else (
                    max(comps[mc.group(1)].max_const, 1)
                    if mc and mc.group(1) in comps else 1)
                mb = re.search(r"body=%?([\w\.\-]+)", rhs)
                if mb:
                    walk(mb.group(1), m * trips, depth + 1)
                if mc:
                    walk(mc.group(1), m * (trips + 1), depth + 1)
                continue
            for attr in ("calls", "to_apply"):
                for cm in re.finditer(attr + r"=%?([\w\.\-]+)", rhs):
                    walk(cm.group(1), m, depth + 1)
            bm = re.search(r"branch_computations=\{([^}]*)\}", rhs)
            if bm:
                for b in bm.group(1).split(","):
                    walk(b.strip().lstrip("%"), m, depth + 1)
    walk(entry, 1.0)
    return mult, fusion_bodies


def analyze(hlo_text: str) -> HLOReport:
    comps = _split(hlo_text)
    entry = next((c.name for c in comps.values() if c.is_entry), None)
    if entry is None and comps:
        entry = list(comps)[-1]

    # which computations are fusion bodies (bytes don't count there)
    fusion_bodies: set[str] = set()
    for c in comps.values():
        for ln in c.lines:
            if " fusion(" in ln or "fusion(" in ln.split("=", 1)[-1][:40]:
                m = re.search(r"calls=%?([\w\.\-]+)", ln)
                if m:
                    fusion_bodies.add(m.group(1))

    mult: dict[str, float] = defaultdict(float)
    loop_counts: dict[str, int] = {}

    def walk(name: str, m: float, depth: int = 0):
        if name not in comps or depth > 64 or m <= 0:
            return
        mult[name] += m
        c = comps[name]
        for ln in c.lines:
            rhs = ln.split("=", 1)[-1]
            if "while(" in rhs:
                trips = 1
                tm = _TRIP_RE.search(rhs)
                mc = re.search(r"condition=%?([\w\.\-]+)", rhs)
                if tm:
                    trips = int(tm.group(1))
                elif mc and mc.group(1) in comps:
                    trips = max(comps[mc.group(1)].max_const, 1)
                mb = re.search(r"body=%?([\w\.\-]+)", rhs)
                if mb:
                    loop_counts[mb.group(1)] = trips
                    walk(mb.group(1), m * trips, depth + 1)
                if mc:
                    walk(mc.group(1), m * (trips + 1), depth + 1)
                continue
            for attr in ("calls", "to_apply"):
                for cm in re.finditer(attr + r"=%?([\w\.\-]+)", rhs):
                    walk(cm.group(1), m, depth + 1)
            bm = re.search(r"branch_computations=\{([^}]*)\}", rhs)
            if bm:
                for b in bm.group(1).split(","):
                    walk(b.strip().lstrip("%"), m, depth + 1)
            cm2 = re.search(r"called_computations=\{([^}]*)\}", rhs)
            if cm2:
                for b in cm2.group(1).split(","):
                    if b.strip():
                        walk(b.strip().lstrip("%"), m, depth + 1)

    walk(entry, 1.0)

    flops = bytes_ = 0.0
    dot_count = 0
    coll_o: dict[str, float] = defaultdict(float)
    coll_w: dict[str, float] = defaultdict(float)

    for name, m in mult.items():
        c = comps[name]
        in_fusion = name in fusion_bodies
        # XLA:CPU legalizes bf16 arithmetic as convert->f32 op->convert;
        # on TPU those ops are native-bf16.  Track which f32 values are
        # just widened bf16 so their bytes can be counted at bf16 width
        # ("TPU-adjusted" memory accounting, EXPERIMENTS.md §Roofline).
        widened: set[str] = set()      # f32 values converted from/to bf16
        for ln in c.lines:
            if "=" not in ln or " convert(" not in ln:
                continue
            lhs, rhs = ln.split("=", 1)
            out_name = lhs.strip().lstrip("%")
            out_sh = _SHAPE_RE.search(rhs)
            ops_ = _operand_names(rhs, "convert")
            if not out_sh or not ops_:
                continue
            src_sh = c.symbols.get(ops_[0], "")
            if out_sh.group(1) == "f32" and src_sh.startswith("bf16"):
                widened.add(out_name)          # f32 copy of a bf16 value
            if out_sh.group(1) == "bf16" and src_sh.startswith("f32"):
                widened.add(ops_[0])           # f32 value narrowed away

        def _tensor_bytes(name_or_shape: str, is_name: bool) -> float:
            sh = c.symbols.get(name_or_shape, "") if is_name \
                else name_or_shape
            b = _shape_list_bytes(sh)
            if is_name and name_or_shape in widened:
                b *= 0.5
            return b

        for ln in c.lines:
            if "=" not in ln:
                continue
            rhs = ln.split("=", 1)[1]
            opm = _OPNAME_RE.search(" " + rhs)
            op = opm.group(1) if opm else ""
            if not op:
                continue

            if op == "dot":
                out_dims = _shape_dims(rhs) or []
                out_elems = math.prod(out_dims) if out_dims else 1
                ops = _operand_names(rhs, "dot")
                k = 1
                if ops:
                    lhs_dims = _shape_dims(c.symbols.get(ops[0], "")) or []
                    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rhs)
                    if mc and lhs_dims:
                        for idx in mc.group(1).split(","):
                            if idx and int(idx) < len(lhs_dims):
                                k *= lhs_dims[int(idx)]
                flops += 2.0 * out_elems * k * m
                dot_count += 1

            base = op.replace("-start", "")
            if base in COLLECTIVES and not op.endswith("-done"):
                lhs_name = ln.split("=", 1)[0].strip().lstrip("%")
                out_b = _tensor_bytes(lhs_name, True) \
                    if lhs_name in c.symbols \
                    else _shape_list_bytes(rhs.split(base + "(")[0])
                in_b = sum(_tensor_bytes(n, True)
                           for n in _operand_names(rhs, op))
                if in_b == 0:
                    in_b = out_b   # conservative fallback
                coll_o[base] += in_b * m
                if base == "all-gather":
                    wire = max(out_b - in_b, 0)
                elif base == "reduce-scatter":
                    wire = max(in_b - out_b, 0)
                elif base == "all-reduce":
                    wire = 2.0 * in_b
                else:
                    wire = in_b
                coll_w[base] += wire * m

            if not in_fusion and op not in _SKIP_BYTES_OPS \
                    and op != "convert" and not op.endswith("-done"):
                lhs_name = ln.split("=", 1)[0].strip().lstrip("%")
                out_b = _tensor_bytes(lhs_name, True) \
                    if lhs_name in c.symbols \
                    else _shape_list_bytes(rhs.split(op + "(")[0])
                in_b = sum(_tensor_bytes(n, True)
                           for n in _operand_names(rhs, op))
                total = out_b + in_b
                if "dynamic-update-slice" in ln:
                    # in-place slice update: the big buffer is aliased
                    # (donated scan carry / KV cache) — real traffic is the
                    # update slice, not buffer read + write
                    big = max([out_b, *(_tensor_bytes(n, True)
                                        for n in _operand_names(rhs, op))])
                    total = max(total - 2 * big, 0.0)
                bytes_ += total * m

    return HLOReport(flops, bytes_, dict(coll_o), dict(coll_w), loop_counts,
                     dot_count)
