"""Logical-axis sharding rules (MaxText-style) with divisibility fallback.

Model code annotates tensors with LOGICAL axis names ("batch", "embed",
"heads", ...).  A rules table maps each name to a tuple of mesh axes; this
module resolves names -> PartitionSpec per concrete shape with two safety
rules applied left-to-right over the tensor's dims:

  1. divisibility — a mesh-axis group is only used if the dim size is an
     exact multiple of the group's device count (GSPMD could pad, but
     padded shards waste roofline and break shard_map); progressively
     shorter SUFFIXES of the group are tried (("pod","data") -> ("data",)),
     so e.g. a batch of 8 on a 2x16 (pod,data) sub-mesh falls back cleanly;
  2. no-reuse — a mesh axis claimed by an earlier dim of the same tensor is
     skipped for later dims (a KV cache can shard batch OR sequence over
     "data", never both).

Rules differ between training (FSDP on the weights' embed dim) and serving
(2-D weight sharding, cache sharded over batch/sequence).  The active
(mesh, rules) pair is installed with `use_sharding(...)`; model code calls
`shard_act` which becomes a no-op outside any context — so unit tests on
one CPU device run the identical model code.
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.module import ParamDef


def shard_map_compat(f, *, mesh, in_specs, out_specs):
    """jax.shard_map across the API rename: newer jax exposes it at the top
    level with `check_vma`; older releases have
    jax.experimental.shard_map.shard_map with `check_rep`."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)

# ---------------------------------------------------------------------------
# Rules tables
# ---------------------------------------------------------------------------
TRAIN_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),        # DP over pods x data
    "embed": ("pod", "data"),        # FSDP / ZeRO-3 on weight d_model dims
    "heads": ("model",),             # TP
    "kv_heads": ("model",),
    "mlp": ("model",),
    "experts": ("model",),           # EP
    "vocab": ("model",),
    "cache_batch": ("pod", "data"),
    "cache_seq": ("pod", "data"),
    "act_seq": (),                   # train: sequence unsharded
}

# ZeRO-3 layout (EXPERIMENTS.md §Perf A6): batch data-parallel over the
# WHOLE mesh; weights stay 2-D sharded and are all-gathered layer-by-layer
# inside the scan.  Trades the per-layer TP activation psums (4 x (B,S,D)
# per layer) for bf16 weight gathers — and cuts per-device activation
# residency by the model-axis factor, which is what lets the 123B train
# cell fit HBM at all.
ZERO3_TRAIN_RULES: dict[str, tuple[str, ...]] = dict(
    TRAIN_RULES, batch=("pod", "data", "model"))

SERVE_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "embed": ("pod", "data"),        # 2-D weight sharding for serving
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "experts": ("model",),
    "vocab": ("model",),
    "cache_batch": ("pod", "data"),
    # KV sequence takes whatever axes the batch/kv_heads left unused —
    # batch=1 long-context cells shard 512-way over the whole mesh, while
    # decode_32k cells use "model" for whatever kv_heads couldn't cover.
    "cache_seq": ("pod", "data", "model"),
    "memory_seq": ("pod", "data", "model"),
    "act_seq": ("data",),            # prefill sequence parallelism
}

# Dims are assigned mesh axes in this order (cheap parallelism first: batch
# needs no collectives, kv_heads only an o-proj psum, sequence sharding a
# softmax-stat combine).  Position in the tensor no longer decides who wins
# a mesh axis — priority does.
_PRIORITY = ("cache_batch", "batch", "kv_heads", "heads", "experts",
             "vocab", "mlp", "cache_seq", "memory_seq", "act_seq", "embed",
             "state", "lora", "head_dim")


@dataclasses.dataclass
class ShardingCtx:
    mesh: Mesh | None
    rules: dict[str, tuple[str, ...]]


_STACK: list[ShardingCtx] = []


def current_ctx() -> ShardingCtx | None:
    return _STACK[-1] if _STACK else None


@contextlib.contextmanager
def use_sharding(mesh: Mesh | None, rules: dict[str, tuple[str, ...]]):
    _STACK.append(ShardingCtx(mesh, rules))
    try:
        yield _STACK[-1]
    finally:
        _STACK.pop()


# ---------------------------------------------------------------------------
# Resolution
# ---------------------------------------------------------------------------
def _axis_size(mesh: Mesh, axes: tuple[str, ...]) -> int:
    return math.prod(mesh.shape[a] for a in axes)


def resolve_spec(shape: tuple[int, ...], axes: tuple[str | None, ...],
                 rules: dict[str, tuple[str, ...]], mesh: Mesh) -> P:
    used: set[str] = set()
    parts: list[Any] = [None] * len(shape)
    order = sorted(
        range(len(shape)),
        key=lambda i: _PRIORITY.index(axes[i])
        if axes[i] in _PRIORITY else len(_PRIORITY))
    for i in order:
        dim, name = shape[i], axes[i]
        group = tuple(a for a in (rules.get(name) or ())
                      if a in mesh.shape) if name else ()
        for start in range(len(group)):
            cand = group[start:]
            size = _axis_size(mesh, cand)
            if size > 1 and dim % size == 0 \
                    and not any(a in used for a in cand):
                parts[i] = cand[0] if len(cand) == 1 else tuple(cand)
                used.update(cand)
                break
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def shard_act(x: jax.Array, *axes: str | None) -> jax.Array:
    """Sharding constraint by logical axis names; no-op without a context."""
    ctx = current_ctx()
    if ctx is None or ctx.mesh is None:
        return x
    spec = resolve_spec(x.shape, axes, ctx.rules, ctx.mesh)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


# ---------------------------------------------------------------------------
# Pytree shardings
# ---------------------------------------------------------------------------
def param_shardings(skel, mesh: Mesh, rules: dict[str, tuple[str, ...]]):
    """Skeleton of ParamDef -> pytree of NamedSharding."""
    return jax.tree.map(
        lambda d: NamedSharding(mesh, resolve_spec(d.shape, d.axes, rules,
                                                   mesh)),
        skel, is_leaf=lambda x: isinstance(x, ParamDef))


def tree_shardings(shapes_tree, axes_tree, mesh: Mesh,
                   rules: dict[str, tuple[str, ...]]):
    """Zip a ShapeDtypeStruct tree with a logical-axes tree -> shardings."""
    flat_s, treedef = jax.tree.flatten(shapes_tree)
    flat_a = treedef.flatten_up_to(axes_tree)
    out = [NamedSharding(mesh, resolve_spec(s.shape, a, rules, mesh))
           for s, a in zip(flat_s, flat_a)]
    return jax.tree.unflatten(treedef, out)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# Slot (serving batch) sharding — repro.serve
#
# Continuous-batching decode is embarrassingly parallel over slots: every
# slot's token depends only on its own cache row.  The serve step therefore
# runs under a shard_map (shard_map_compat) whose in/out specs shard every
# state leaf on its slot dimension; these helpers build those specs from
# the cache's logical-axes tree, so the serve subsystem never hand-indexes
# leaf ranks.
# ---------------------------------------------------------------------------
def spec_on_dim(ndim: int, dim: int, axes: str | tuple[str, ...]) -> P:
    """PartitionSpec placing `axes` on dimension `dim` of a rank-`ndim`
    tensor, every other dimension unsharded."""
    parts: list[Any] = [None] * ndim
    if not isinstance(axes, str) and len(axes) == 1:
        axes = axes[0]
    parts[dim] = axes
    return P(*parts)


def slot_dim_specs(axes_tree, template, mesh_axes: tuple[str, ...],
                   name: str = "cache_batch"):
    """Spec pytree sharding every leaf's `name` logical dim over
    `mesh_axes`.  `template` fixes leaf ranks; `axes_tree` is the logical
    axes pytree (models.model.cache_axes for a decode cache)."""
    flat_t, treedef = jax.tree.flatten(template)
    flat_a = treedef.flatten_up_to(axes_tree)
    specs = [spec_on_dim(t.ndim, a.index(name), mesh_axes)
             for t, a in zip(flat_t, flat_a)]
    return jax.tree.unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# Expert-parallel shard_map in_specs (see models/moe.py)
# ---------------------------------------------------------------------------
def ep_param_specs(p: dict, fsdp: tuple[str, ...] | None) -> dict:
    """PartitionSpecs for the MoE param dict entering shard_map.

    Experts over `model`; d_model dims stay FSDP-sharded (gathered inside);
    the router is needed in full on every shard (GSPMD all-gathers it).
    """
    f = tuple(fsdp) if fsdp else None
    fs = (f if f else None)
    specs = {
        "router": P(None, None),
        "wi": P("model", fs, None, None),
        "wo": P("model", None, fs),
    }
    if "shared_wi" in p:
        specs["shared_wi"] = P(fs, None, "model")
        specs["shared_wo"] = P("model", fs)
    return specs
