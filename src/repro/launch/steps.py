"""Step-function factories shared by the train driver, the dry-run and the
serving CLI."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.distributed import compress as C
from repro.models.model import ModelBundle
from repro.optim import AdamWConfig, adamw_init, adamw_update


def make_sampling_decode_step(bundle: ModelBundle):
    """-> step(params, tok, cache, temperature, key) -> (tok, cache, key).

    ONE jitted step for the fixed-batch decode loop: the cache is donated
    (in-place KV update), `temperature` is a TRACED scalar and the sampling
    key is carried loop state — greedy (temperature 0) and sampled decoding
    share a single compiled executable instead of building two jitted
    branches and re-threading the key from Python each token (the historic
    launch/serve.py bug).  Continuous-batching serving has its own step
    (`repro.serve.make_serve_step`); this one backs `--policy batch`."""

    @functools.partial(jax.jit, donate_argnums=(2,))
    def step(params, tok, cache, temperature, key):
        logits, cache = bundle.decode_step(
            params, {"token": tok, "pos": cache["pos"], "cache": cache})
        key, sub = jax.random.split(key)
        t = jnp.maximum(jnp.asarray(temperature, jnp.float32), 1e-6)
        sampled = jax.random.categorical(
            sub, logits.astype(jnp.float32) / t, -1)
        greedy = jnp.argmax(logits, -1)
        tok = jnp.where(jnp.asarray(temperature, jnp.float32) > 0.0,
                        sampled, greedy).astype(jnp.int32)
        return tok, cache, key

    return step


def make_train_step(bundle: ModelBundle, opt_cfg: AdamWConfig,
                    grad_compress: bool = False):
    """-> train_step(params, opt_state, batch) -> (params, opt, metrics).

    With grad_compress=True the gradient is cast to bf16 (with f32 error
    feedback carried in opt_state["err"]) BEFORE the data-parallel
    all-reduce — XLA then reduces half the bytes over the pod/data axes.
    """

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: bundle.train_loss(p, batch))(params)
        if grad_compress:
            g16, err = C.compress(grads, opt_state["err"])
            grads = C.decompress(g16)
            opt_state = dict(opt_state, err=err)
        new_params, new_inner, metrics = adamw_update(
            params, grads, opt_state["adam"], opt_cfg)
        metrics["loss"] = loss
        return new_params, dict(opt_state, adam=new_inner), metrics

    return train_step


def init_opt_state(params, grad_compress: bool = False) -> dict:
    st = {"adam": adamw_init(params)}
    if grad_compress:
        st["err"] = C.init_error_state(params)
    return st


def opt_state_shardings(param_sh, grad_compress: bool = False):
    """Moments/err shard like their params; the step counter replicates."""
    mesh = jax.tree.leaves(param_sh)[0].mesh
    from jax.sharding import NamedSharding, PartitionSpec
    rep = NamedSharding(mesh, PartitionSpec())
    st = {"adam": {"mu": param_sh, "nu": param_sh, "step": rep}}
    if grad_compress:
        st["err"] = param_sh
    return st
