"""Shared transformer building blocks (pure functional, scan-friendly).

Every block is a pair of functions:

    <block>_def(cfg)            -> skeleton pytree of ParamDef
    <block>_apply(params, ...)  -> activations

Params are plain pytrees; logical axis names on every ParamDef drive the
distributed sharding rules (distributed/sharding.py).  All blocks support
three execution phases:

    train/prefill : full-sequence forward (B, S, D)
    decode        : single-token forward with a KV cache at position `pos`

Attention flavours: full causal, sliding-window (per-layer window scalar so
gemma-style 5:1 local:global patterns scan), bidirectional (encoders) and
cross-attention (enc-dec).  GQA throughout; qk-norm optional (qwen3).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro import rosa
from repro.models.module import ParamDef

NEG_INF = -2.0e38


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rmsnorm_def(dim: int, axis: str = "embed") -> ParamDef:
    return ParamDef((dim,), (axis,), "ones")


def rmsnorm(scale: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm with a hand-written backward (EXPERIMENTS.md §Perf A5).

    Forward keeps f32 statistics.  The custom VJP keeps every (B, S, D)
    cotangent in the ACTIVATION dtype — autodiff of the naive f32-stats
    formulation drags f32 copies of the residual stream through the whole
    backward scan (measured: +60% memory-roofline term on the 123B cell);
    only the (B, S, 1) reductions run in f32 here, exactly like production
    fused-norm kernels."""
    return _rmsnorm_core(x, scale, eps)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _rmsnorm_core(x, scale, eps):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    r = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * r * scale


def _rmsnorm_fwd2(x, scale, eps):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    r32 = jax.lax.rsqrt(var + eps)
    r = r32.astype(x.dtype)
    return x * r * scale, (x, r, scale)


def _rmsnorm_bwd2(eps, res, g):
    x, r, scale = res
    xh = x * r
    d_scale = jnp.sum((g * xh).astype(jnp.float32),
                      axis=tuple(range(g.ndim - 1))).astype(scale.dtype)
    gsc = g * scale
    m = jnp.mean((gsc * xh).astype(jnp.float32), axis=-1,
                 keepdims=True).astype(x.dtype)
    dx = r * (gsc - xh * m)
    return dx, d_scale


_rmsnorm_core.defvjp(_rmsnorm_fwd2, _rmsnorm_bwd2)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------
def rope(x: jax.Array, positions: jax.Array, theta) -> jax.Array:
    """x: (..., S, H, D) ; positions: (..., S) ; theta: scalar (traced ok)."""
    d = x.shape[-1]
    half = d // 2
    freq = jnp.exp(
        -jnp.log(jnp.asarray(theta, jnp.float32))
        * (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freq      # (..., S, half)
    # trig tables cast to the activation dtype BEFORE the elementwise mix so
    # neither the forward nor the cotangent ever materializes f32 copies of
    # the (B, S, H, D) tensor (EXPERIMENTS.md §Perf A2)
    cos = jnp.cos(ang)[..., None, :].astype(x.dtype)
    sin = jnp.sin(ang)[..., None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                           axis=-1)


# ---------------------------------------------------------------------------
# Attention (GQA, optional qk-norm / sliding window / cross)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qk_norm: bool = False
    rope_theta: float = 1e6
    causal: bool = True          # False -> bidirectional (encoder)
    cross: bool = False          # cross-attention (kv from encoder memory)
    uniform_decode: bool = True  # all sequences decode at the same position
    #   -> cache writes lower to dynamic-update-slice, which GSPMD handles
    #   on a sequence-sharded cache without replication (§Perf B1); set
    #   False for continuous batching with ragged positions (scatter path).

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim


def attn_def(cfg: AttnConfig) -> dict:
    d = cfg.d_model
    p = {
        "wq": ParamDef((d, cfg.n_heads, cfg.head_dim),
                       ("embed", "heads", "head_dim")),
        "wk": ParamDef((d, cfg.n_kv_heads, cfg.head_dim),
                       ("embed", "kv_heads", "head_dim")),
        "wv": ParamDef((d, cfg.n_kv_heads, cfg.head_dim),
                       ("embed", "kv_heads", "head_dim")),
        "wo": ParamDef((cfg.n_heads, cfg.head_dim, d),
                       ("heads", "head_dim", "embed")),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_def(cfg.head_dim, "head_dim")
        p["k_norm"] = rmsnorm_def(cfg.head_dim, "head_dim")
    return p


def cache_write(cache: jax.Array, new: jax.Array, pos: jax.Array,
                uniform: bool) -> jax.Array:
    """Write `new` (B, C, ...) per-sequence tokens into cache (B, S, ...)
    starting at `pos` (B,).  C == 1 is the decode step; C > 1 is a prefill
    chunk (serving)."""
    if uniform:
        # all positions equal: a dynamic-update-slice along S — GSPMD keeps
        # a seq-sharded cache in place (no involuntary replication)
        idx = (jnp.zeros((), jnp.int32), pos[0]) \
            + (jnp.zeros((), jnp.int32),) * (cache.ndim - 2)
        return jax.lax.dynamic_update_slice(cache, new.astype(cache.dtype),
                                            idx)
    b, c = new.shape[:2]
    if c == 1:
        return cache.at[jnp.arange(b), pos].set(new.astype(cache.dtype)[:, 0])
    # ragged chunk write: batched scatter at pos[b] + [0, C); rows whose
    # window crosses S drop the out-of-range tokens (jax scatter semantics)
    rows = jnp.arange(b)[:, None]
    cols = pos[:, None] + jnp.arange(c)[None, :]
    return cache.at[rows, cols].set(new.astype(cache.dtype),
                                    mode="drop")


def _repeat_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(B, S, KV, D) -> (B, S, H, D) by repeating each kv head."""
    n_kv = k.shape[-2]
    if n_kv == n_heads:
        return k
    return jnp.repeat(k, n_heads // n_kv, axis=-2)


def _mask_bias(q_pos: jax.Array, k_pos: jax.Array, causal: bool,
               window, k_len_valid=None) -> jax.Array:
    """Additive mask (..., Sq, Sk). window: scalar; <=0 means unlimited."""
    diff = q_pos[..., :, None] - k_pos[..., None, :]
    ok = jnp.ones(diff.shape, bool)
    if causal:
        ok = ok & (diff >= 0)
    window = jnp.asarray(window)
    ok = ok & ((window <= 0) | (diff < window))
    if k_len_valid is not None:
        # k_len_valid: (B, 1) -> (B, 1, 1) so it broadcasts over (B, Sq, Sk)
        ok = ok & (k_pos[..., None, :] < jnp.asarray(k_len_valid)[..., None])
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def attention_core(q: jax.Array, k: jax.Array, v: jax.Array,
                   bias: jax.Array) -> jax.Array:
    """q: (B, Sq, H, D); k, v: (B, Sk, H, D); bias: (B or 1, Sq, Sk).

    The QK einsum stays in the activation dtype (MXU accumulates in f32
    internally); only the softmax itself runs in f32.  The f32->bf16 cast
    sits directly on the einsum output so the backward pass hands bf16
    cotangents to d_q/d_k — keeping the whole residual-stream backward in
    bf16 (EXPERIMENTS.md §Perf A2)."""
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k)
    scores = scores.astype(jnp.float32) * scale + bias[:, None, :, :]
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def attn_apply(p: dict, cfg: AttnConfig, x: jax.Array,
               positions: jax.Array, *,
               window=0, theta=None,
               memory: jax.Array | None = None,
               memory_pos: jax.Array | None = None) -> jax.Array:
    """Full-sequence attention. x: (B, S, D)."""
    theta = cfg.rope_theta if theta is None else theta
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    src = memory if cfg.cross else x
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"])
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    if not cfg.cross:
        q = rope(q, positions, theta)
        k = rope(k, positions, theta)
        k_pos = positions
    else:
        k_pos = memory_pos
    k = _repeat_kv(k, cfg.n_heads)
    v = _repeat_kv(v, cfg.n_heads)
    bias = _mask_bias(positions, k_pos, cfg.causal and not cfg.cross, window)
    o = attention_core(q, k, v, bias)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def attn_prefill(p: dict, cfg: AttnConfig, x: jax.Array,
                 positions: jax.Array, *, window=0, theta=None):
    """Prefill: like attn_apply but also returns the (k, v) cache."""
    theta = cfg.rope_theta if theta is None else theta
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    q = rope(q, positions, theta)
    k = rope(k, positions, theta)
    kr = _repeat_kv(k, cfg.n_heads)
    vr = _repeat_kv(v, cfg.n_heads)
    bias = _mask_bias(positions, positions, cfg.causal, window)
    o = attention_core(q, kr, vr, bias)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"]), (k, v)


def flash_decode(q: jax.Array, kc: jax.Array, vc: jax.Array,
                 pos: jax.Array, window, n_heads: int) -> jax.Array:
    """Distributed decode attention over a sequence-sharded KV cache.

    GSPMD's default plan ALL-GATHERS the cache per layer (measured 8.6 GB
    per layer on the 500k cell — §Perf B2).  This shard_map computes the
    flash-decoding split instead: each shard takes partial max / sum-exp /
    value-sum over its local KV slice; the cross-shard combine moves only
    (B, H) statistics and the (B, H, D) partial output.

    q: (B, 1, H, D) replicated; kc/vc: (B, S, KV, D) seq-sharded.
    """
    from repro.distributed.sharding import current_ctx, resolve_spec
    ctx = current_ctx()
    kv_axes = ("cache_batch", "cache_seq", "kv_heads", "head_dim")
    if ctx is None or ctx.mesh is None:
        return None
    spec_kv = resolve_spec(kc.shape, kv_axes, ctx.rules, ctx.mesh)
    seq_part = spec_kv[1] if len(spec_kv) > 1 else None
    if seq_part is None:
        return None                       # cache not seq-sharded: gather-free
    seq_axes = seq_part if isinstance(seq_part, tuple) else (seq_part,)
    s_loc_count = math.prod(ctx.mesh.shape[a] for a in seq_axes)
    mesh = ctx.mesh

    def local(qv, k, v, pv):
        s_loc = k.shape[1]
        # global positions of this shard's KV slice
        idx = jnp.zeros((), jnp.int32)
        for a in seq_axes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        k_pos = idx * s_loc + jnp.arange(s_loc)
        k_pos = jnp.broadcast_to(k_pos[None], (k.shape[0], s_loc))
        bias = _mask_bias(pv[:, None], k_pos, True, window,
                          k_len_valid=(pv + 1)[:, None])
        kr = _repeat_kv(k, n_heads)
        vr = _repeat_kv(v, n_heads)
        scale = qv.shape[-1] ** -0.5
        s = jnp.einsum("bqhd,bkhd->bhqk", qv, kr).astype(jnp.float32) \
            * scale + bias[:, None]
        m_l = jnp.max(s, axis=-1)                      # (B, H, 1)
        m = jax.lax.pmax(m_l, seq_axes)
        p_ = jnp.exp(s - m[..., None])
        denom = jax.lax.psum(jnp.sum(p_, -1), seq_axes)
        o = jnp.einsum("bhqk,bkhd->bqhd", p_.astype(qv.dtype), vr)
        o = jax.lax.psum(o, seq_axes)
        return o / denom.transpose(0, 2, 1)[..., None].astype(o.dtype)

    batch_part = spec_kv[0] if len(spec_kv) else None
    q_spec = jax.sharding.PartitionSpec(batch_part)     # match kv's batch
    pos_spec = jax.sharding.PartitionSpec(batch_part)
    from repro.distributed.sharding import shard_map_compat
    return shard_map_compat(local, mesh=mesh,
                            in_specs=(q_spec, spec_kv, spec_kv, pos_spec),
                            out_specs=q_spec)(q, kc, vc, pos)


def attn_decode(p: dict, cfg: AttnConfig, x: jax.Array, cache: tuple,
                pos: jax.Array, *, window=0, theta=None,
                memory: jax.Array | None = None,
                memory_pos: jax.Array | None = None):
    """Cached decode. x: (B, C, D); cache: (k, v) each (B, S, KV, D);
    pos: (B,) first position of the chunk.  C == 1 is the classic one-token
    step; C > 1 is a prefill chunk writing C tokens at pos..pos+C (serving).
    Returns (out, new_cache)."""
    theta = cfg.rope_theta if theta is None else theta
    c = x.shape[1]
    q_pos = pos[:, None] + jnp.arange(c)[None, :]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q)
    if cfg.cross:
        k_full, v_full = cache       # static encoder memory projections
        k_pos = memory_pos[:, :]
        bias = _mask_bias(q_pos, k_pos, False, 0)
        o = attention_core(q, _repeat_kv(k_full, cfg.n_heads),
                           _repeat_kv(v_full, cfg.n_heads), bias)
        return jnp.einsum("bshk,hkd->bsd", o, p["wo"]), cache
    q = rope(q, q_pos, theta)
    k_new = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v_new = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        k_new = rmsnorm(p["k_norm"], k_new)
    k_new = rope(k_new, q_pos, theta)
    kc, vc = cache
    b = x.shape[0]
    kc = cache_write(kc, k_new, pos, cfg.uniform_decode)
    vc = cache_write(vc, v_new, pos, cfg.uniform_decode)
    o = flash_decode(q, kc, vc, pos, window, cfg.n_heads) if c == 1 else None
    if o is None:                      # unsharded cache: plain attention
        s = kc.shape[1]
        k_pos = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        bias = _mask_bias(q_pos, k_pos, True, window,
                          k_len_valid=(pos + c)[:, None])
        o = attention_core(q, _repeat_kv(kc, cfg.n_heads),
                           _repeat_kv(vc, cfg.n_heads), bias)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"]), (kc, vc)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------
def mlp_def(d_model: int, d_ff: int) -> dict:
    return {
        "wi": ParamDef((d_model, 2, d_ff), ("embed", None, "mlp")),  # gate|up
        "wo": ParamDef((d_ff, d_model), ("mlp", "embed")),
    }


def mlp_apply(p: dict, x: jax.Array, engine: "rosa.Engine | None" = None,
              key: jax.Array | None = None, *, name: str = "mlp",
              step: "int | jax.Array" = 0, rosa_cfg=None) -> jax.Array:
    """SwiGLU MLP; with an optical `rosa.Engine` both projections run
    through the paper's optical MAC (OSA bit-serial signed-digit pipeline +
    noisy MRR weight realization — DESIGN.md §3 'execution backends').
    Each projection gets its own deterministic key, folded from the
    engine's base key, its `{name}/wi` / `{name}/wo` layer name, and
    `step`.  Inside a scan-over-layers stack pass the (traced) layer index
    as `step` so layers draw independent noise — the scanned body traces
    once, so the name alone cannot distinguish layers (for the same reason
    an attached EnergyLedger sees the body's two projections once, not L
    times).  `rosa_cfg` is the legacy spelling (uniform config, no plan)."""
    if engine is None and rosa_cfg is not None:
        engine = rosa.Engine.from_config(rosa_cfg)
    if engine is not None and not engine.is_dense:
        if key is not None:
            engine = engine.with_key(key)
        b, s, d = x.shape
        f = p["wi"].shape[-1]
        gu = engine.matmul(x.reshape(-1, d), p["wi"].reshape(d, 2 * f),
                           name=f"{name}/wi", step=step).reshape(b, s, 2, f)
        h = jax.nn.silu(gu[..., 0, :]) * gu[..., 1, :]
        y = engine.matmul(h.reshape(-1, f), p["wo"], name=f"{name}/wo",
                          step=step)
        return y.reshape(b, s, d).astype(x.dtype)
    gu = jnp.einsum("bsd,dcf->bscf", x, p["wi"])
    h = jax.nn.silu(gu[..., 0, :]) * gu[..., 1, :]
    return jnp.einsum("bsf,fd->bsd", h, p["wo"])


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------
def embed_def(vocab: int, d_model: int) -> ParamDef:
    # 0.02 std keeps tied-unembedding logits in a sane range at init
    return ParamDef((vocab, d_model), ("vocab", "embed"), "normal", 0.02)


def embed_apply(table: jax.Array, tokens: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def unembed_def(d_model: int, vocab: int) -> ParamDef:
    return ParamDef((d_model, vocab), ("embed", "vocab"))


def unembed_apply(w: jax.Array, x: jax.Array) -> jax.Array:
    return jnp.einsum("bsd,dv->bsv", x, w)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------
def softmax_xent(logits: jax.Array, labels: jax.Array,
                 mask: jax.Array | None = None) -> jax.Array:
    """Mean next-token cross entropy. logits: (B, S, V); labels: (B, S)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


Pytree = Any
