"""repro.analysis static verifier: PRNG provenance through jaxprs,
donation vs compiled-HLO aliases, recompile hazards, hot-loop purity,
Pallas preflight over zoo shapes, baseline gating, the CLI, and the
`rosa.compile(verify=...)` surface."""

import json
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import analysis as A
from repro import rosa
from repro.analysis import (AnalysisTarget, Severity, VerificationError,
                            load_baseline, run_checks, write_baseline)
from repro.analysis.findings import AnalysisReport, Finding

F32 = jnp.float32


def _sds(*shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def codes(findings):
    return sorted({f.code for f in findings})


# ---------------------------------------------------------------------------
# PRNG discipline
# ---------------------------------------------------------------------------
class TestPRNG:
    def check(self, fn, *args, **kw):
        t = AnalysisTarget("t", fn, tuple(args), **kw)
        return [f for f in run_checks([t], checks=["prng"])]

    def test_reused_key_flagged(self):
        def f(key, x):
            return x + jax.random.normal(key, x.shape) \
                + jax.random.uniform(key, x.shape)
        fs = self.check(f, _sds(2, dtype=jnp.uint32), _sds(4))
        assert codes(fs) == ["PRNG001"]
        assert all(f.severity == Severity.ERROR for f in fs)

    def test_split_keys_clean(self):
        def f(key, x):
            k1, k2 = jax.random.split(key)
            return x + jax.random.normal(k1, x.shape) \
                + jax.random.uniform(k2, x.shape)
        assert self.check(f, _sds(2, dtype=jnp.uint32), _sds(4)) == []

    def test_fold_in_distinct_consts_clean(self):
        def f(key, x):
            a = jax.random.normal(jax.random.fold_in(key, 0), x.shape)
            b = jax.random.normal(jax.random.fold_in(key, 1), x.shape)
            return x + a + b
        assert self.check(f, _sds(2, dtype=jnp.uint32), _sds(4)) == []

    def test_fold_in_same_const_is_reuse(self):
        # two textually-separate folds of the SAME (key, const) pair are
        # one stream: the memoized derivation must see through them
        def f(key, x):
            a = jax.random.normal(jax.random.fold_in(key, 7), x.shape)
            b = jax.random.uniform(jax.random.fold_in(key, 7), x.shape)
            return x + a + b
        fs = self.check(f, _sds(2, dtype=jnp.uint32), _sds(4))
        assert "PRNG001" in codes(fs)

    def test_constant_baked_key_flagged(self):
        baked = jax.random.PRNGKey(0)

        def f(x):
            return x + jax.random.normal(baked, x.shape)
        fs = self.check(f, _sds(4))
        assert "PRNG002" in codes(fs)

    def test_loop_invariant_key_in_scan_flagged(self):
        def f(key, x):
            def body(c, _):
                return c + jax.random.normal(key, c.shape), None
            return jax.lax.scan(body, x, None, length=4)[0]
        fs = self.check(f, _sds(2, dtype=jnp.uint32), _sds(4))
        assert "PRNG004" in codes(fs)

    def test_per_iteration_fold_in_scan_clean(self):
        def f(key, x):
            def body(c, i):
                k = jax.random.fold_in(key, i)
                return c + jax.random.normal(k, c.shape), None
            return jax.lax.scan(body, x, jnp.arange(4))[0]
        assert self.check(f, _sds(2, dtype=jnp.uint32), _sds(4)) == []


# ---------------------------------------------------------------------------
# Donation
# ---------------------------------------------------------------------------
class TestDonation:
    def test_dropped_donation_flagged(self):
        def f(x, scratch):
            return x * 2.0          # scratch never used -> alias dropped
        t = AnalysisTarget("t", f, (_sds(8, 8), _sds(8, 8)),
                           donate_argnums=(1,))
        fs = run_checks([t], checks=["donation"])
        assert codes(fs) == ["DON001"]
        assert fs.findings[0].severity == Severity.ERROR

    def test_honored_donation_clean(self):
        def f(x, state):
            return state + x
        t = AnalysisTarget("t", f, (_sds(8, 8), _sds(8, 8)),
                           donate_argnums=(1,))
        assert list(run_checks([t], checks=["donation"])) == []

    def test_hot_path_without_donation_warns(self):
        def f(state):
            return state + 1.0
        t = AnalysisTarget("t", f, (_sds(8, 8),), hot_path=True)
        fs = run_checks([t], checks=["donation"])
        assert codes(fs) == ["DON002"]
        assert fs.findings[0].severity == Severity.WARNING


# ---------------------------------------------------------------------------
# Purity
# ---------------------------------------------------------------------------
class TestPurity:
    def test_debug_print_in_scan_body_flagged(self):
        def f(x):
            def body(c, _):
                jax.debug.print("c={c}", c=c[0])
                return c * 2.0, None
            return jax.lax.scan(body, x, None, length=3)[0]
        fs = run_checks([AnalysisTarget("t", f, (_sds(4),))],
                        checks=["purity"])
        assert codes(fs) == ["PUR001"]

    def test_callback_in_hot_path_warns(self):
        def f(x):
            jax.debug.print("tick")
            return x * 2.0
        fs = run_checks(
            [AnalysisTarget("t", f, (_sds(4),), hot_path=True)],
            checks=["purity"])
        assert codes(fs) == ["PUR002"]

    def test_pure_fn_clean(self):
        def f(x):
            return jax.lax.scan(lambda c, _: (c * 2.0, None), x, None,
                                length=3)[0]
        assert list(run_checks([AnalysisTarget("t", f, (_sds(4),))],
                               checks=["purity"])) == []


# ---------------------------------------------------------------------------
# Recompile hazards
# ---------------------------------------------------------------------------
class TestRecompile:
    def test_weak_scalar_warns(self):
        def f(x, s):
            return x * s
        closed_args = (_sds(4), 2.5)    # bare float traces weakly typed
        fs = run_checks([AnalysisTarget("t", f, closed_args)],
                        checks=["recompile"])
        assert "REC001" in codes(fs)

    def test_f64_promotion_warns(self):
        def f(x):
            return x.astype(jnp.float64) if jax.config.jax_enable_x64 \
                else np.float64(1.0) + x
        # without x64 enabled nothing promotes; build the hazard directly
        def g(x):
            return jax.lax.convert_element_type(x, jnp.float64)
        with jax.experimental.enable_x64():
            fs = run_checks([AnalysisTarget("t", g, (_sds(4),))],
                            checks=["recompile"])
        assert "REC002" in codes(fs)

    def test_unhashable_static_is_rec003_not_crash(self):
        def f(x, cfg):
            return x * 2.0
        t = AnalysisTarget("t", f, (_sds(4), {"a": 1}), static_argnums=(1,))
        fs = run_checks([t])        # ALL checks: none may CHECKFAIL
        assert codes(fs) == ["REC003"]

    def test_key_typed_args_do_not_crash(self):
        # extended dtypes (key<fry>) must not reach np.dtype()
        def f(key, x):
            return x + jax.random.normal(key, x.shape)
        key = jax.ShapeDtypeStruct((), jax.random.key(0).dtype)
        fs = run_checks([AnalysisTarget("t", f, (key, _sds(4)))],
                        checks=["recompile"])
        assert "CHECKFAIL" not in codes(fs)


# ---------------------------------------------------------------------------
# Pallas preflight
# ---------------------------------------------------------------------------
class TestPallasPreflight:
    def test_pad_waste_warns(self):
        t = AnalysisTarget("t", gemm_shapes=(("tiny", 3, 5, 7),))
        fs = run_checks([t], checks=["pallas"])
        assert "PAL002" in codes(fs)
        assert all(f.severity <= Severity.WARNING for f in fs)

    def test_aligned_shape_clean(self):
        t = AnalysisTarget("t", gemm_shapes=(("ok", 128, 256, 128),))
        assert list(run_checks([t], checks=["pallas"])) == []

    def test_vmem_blowup_errors(self):
        from repro.kernels.osa_matmul.ops import preflight
        rep = preflight(4096, 4096, 4096, bm=1024, bn=1024, bk=1024)
        assert rep["vmem_bytes"] > 16 * 2**20
        assert not rep["issues"]

    def test_bad_block_param_is_contract_issue(self):
        from repro.kernels.osa_matmul.ops import preflight
        rep = preflight(128, 128, 128, bk=100)
        assert any("bk" in s for s in rep["issues"])

    def test_ssd_lane_dims_are_soft(self):
        t = AnalysisTarget("t", ssd_shapes=(("s", 1, 512, 8, 64, 64),))
        fs = run_checks([t], checks=["pallas"])
        lane = [f for f in fs if f.code == "PAL003"]
        assert lane and all(f.severity == Severity.WARNING for f in lane)


# ---------------------------------------------------------------------------
# HLO parser regression (dtype table + alias map)
# ---------------------------------------------------------------------------
class TestHLOParsing:
    def test_narrow_and_f8_dtypes_accounted(self):
        from repro.analysis.hlo import _shape_list_bytes
        assert _shape_list_bytes("s4[16]") == 8
        assert _shape_list_bytes("u4[16]") == 8
        assert _shape_list_bytes("f8e8m0fnu[32]") == 32
        assert _shape_list_bytes("f8e4m3fn[8], f32[2]") == 16

    def test_unknown_dtype_like_raises(self):
        from repro.analysis.hlo import UnknownDtypeError, _shape_list_bytes
        with pytest.raises(UnknownDtypeError):
            _shape_list_bytes("f8e9xyz[8]")

    def test_non_dtype_tokens_skipped(self):
        from repro.analysis.hlo import _shape_list_bytes
        # sharding annotations etc. must not be mistaken for dtypes
        assert _shape_list_bytes("devices=[2,2]") == 0

    def test_legacy_import_path_still_works(self):
        from repro.launch import hlo_analysis
        assert hlo_analysis.DTYPE_BYTES["s4"] == 0.5
        assert hasattr(hlo_analysis, "analyze")

    def test_alias_parsing_roundtrip(self):
        from repro.analysis.hlo import parse_input_output_aliases
        fn = jax.jit(lambda x, y: (x + y, y * 2.0), donate_argnums=(0, 1))
        txt = fn.lower(jnp.ones((4,)), jnp.ones((4,))).compile().as_text()
        aliases = parse_input_output_aliases(txt)
        assert len(aliases) == 2
        assert sorted(p for p, _ in aliases) == [0, 1]


# ---------------------------------------------------------------------------
# Findings / baseline plumbing
# ---------------------------------------------------------------------------
class TestBaseline:
    def _finding(self, code="X001", sev=Severity.WARNING, loc="here"):
        return Finding(check="x", code=code, severity=sev, subject="s",
                       location=loc, message="m")

    def test_fingerprint_ignores_message(self):
        a = self._finding()
        b = Finding(check="x", code="X001", severity=Severity.WARNING,
                    subject="s", location="here", message="other words")
        assert a.fingerprint == b.fingerprint

    def test_report_json_roundtrip(self):
        rep = AnalysisReport((self._finding(), self._finding("X002")))
        back = AnalysisReport.from_json(rep.to_json())
        assert back == rep

    def test_baseline_gates_only_new(self, tmp_path):
        rep = AnalysisReport((self._finding("X001"), self._finding("X002")))
        path = tmp_path / "base.json"
        write_baseline(path, AnalysisReport((self._finding("X001"),)))
        new = rep.new_against(load_baseline(path))
        assert [f.code for f in new] == ["X002"]

    def test_info_never_gates(self, tmp_path):
        rep = AnalysisReport((self._finding(sev=Severity.INFO),))
        assert rep.new_against(set()) == ()

    def test_missing_baseline_is_empty(self, tmp_path):
        assert load_baseline(tmp_path / "nope.json") == set()

    def test_wrong_schema_raises(self, tmp_path):
        p = tmp_path / "bad.json"
        p.write_text(json.dumps({"schema": 99, "findings": {}}))
        with pytest.raises(ValueError):
            load_baseline(p)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------
class TestCLI:
    def test_zoo_scan_baseline_cycle(self, tmp_path, capsys):
        from repro.analysis.cli import main
        base = str(tmp_path / "baseline.json")
        rep_json = str(tmp_path / "report.json")
        argv = ["--no-models", "--no-serve", "--baseline", base]
        # cold: zoo shapes produce findings, none acknowledged -> exit 1
        assert main(argv) == 1
        assert main(argv + ["--write-baseline"]) == 0
        # acknowledged -> exit 0, bench-schema report written
        assert main(argv + ["--json", rep_json]) == 0
        doc = json.loads((tmp_path / "report.json").read_text())
        res = doc["results"][0]
        assert res["name"] == "static_analysis"
        metrics = {m["name"]: m for m in res["metrics"]}
        assert metrics["findings_new"]["value"] == 0
        assert metrics["findings_new"]["gate"] is True
        assert metrics["findings_total"]["value"] > 0

    def test_checks_subset_validated(self):
        with pytest.raises(ValueError):
            run_checks([], checks=["nonexistent"])


# ---------------------------------------------------------------------------
# rosa.compile(verify=...)
# ---------------------------------------------------------------------------
class TestCompileVerify:
    @pytest.fixture()
    def engine(self):
        return rosa.Engine(plan=rosa.ExecutionPlan(default=rosa.RosaConfig()))

    def _bad(self, engine, x, scratch):
        k = engine.key
        a = jax.random.normal(k, x.shape)
        b = jax.random.uniform(k, x.shape)     # reuse
        return x + a + b                       # scratch donated, unused

    def test_error_mode_rejects_reuse_and_dropped_donation(self, engine):
        x = _sds(8, 8)
        with pytest.raises(VerificationError) as ei:
            rosa.compile(self._bad, engine, (x, x), donate_argnums=(1,),
                         cache=False, verify="error")
        got = codes(ei.value.report.findings)
        assert "PRNG001" in got and "DON001" in got

    def test_warn_mode_warns_but_builds(self, engine):
        x = _sds(8, 8)
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            p = rosa.compile(self._bad, engine, (x, x), donate_argnums=(1,),
                             cache=False, verify="warn")
        assert isinstance(p, rosa.Program)
        assert any("PRNG001" in str(x.message) for x in w)

    def test_clean_program_passes_error_mode(self, engine):
        def good(eng, x):
            return eng.matmul(x, x, name="l0")
        p = rosa.compile(good, engine, (_sds(8, 8),), cache=False,
                         verify="error")
        assert isinstance(p, rosa.Program)

    def test_invalid_mode_rejected(self, engine):
        with pytest.raises(ValueError):
            rosa.compile(lambda e, x: x, engine, (_sds(4),), cache=False,
                         verify="loud")

    def test_verify_program_helper(self, engine):
        def good(eng, x):
            return eng.matmul(x, x, name="l0")
        p = rosa.compile(good, engine, (_sds(8, 8),), cache=False)
        rep = A.verify_program(p, (_sds(8, 8),))
        assert rep.errors == ()
