"""Reduced CNN families for the paper's behavioural experiments.

AlexNet / VGG16 / ResNet18 / MobileNetV3 at CIFAR scale, every conv/fc
lowered to im2col + matmul so the contraction routes through a
`rosa.Engine` with a PER-LAYER execution plan — exactly the knob the
paper's hybrid mapping turns.  Widths are reduced (documented in DESIGN.md
§8) so QAT runs in minutes on one CPU core; layer NAMES match
configs/paper_cnns.py so behavioural noise profiles join against the
full-size EDP table rows.

API:
    specs  = LITE_MODELS["alexnet"]
    skel   = cnn_def(specs)
    engine = rosa.Engine.from_config(cfg, layers=[s.name for s in specs])
    logits = cnn_apply(params, specs, images, engine)
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro import rosa
from repro.models.module import ParamDef


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    name: str
    kind: str              # conv | dwconv | fc
    c_in: int
    c_out: int
    k: int = 3
    stride: int = 1
    pool: int = 1          # avg-pool factor applied after activation
    act: bool = True


def _im2col(x: jax.Array, k: int, stride: int) -> jax.Array:
    """x: (B, H, W, C) -> (B, H', W', C*k*k) patches (SAME padding)."""
    b, h, w, c = x.shape
    patches = jax.lax.conv_general_dilated_patches(
        x, (k, k), (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return patches


def cnn_def(specs: list[ConvSpec], n_classes: int = 10) -> dict:
    p: dict = {}
    for s in specs:
        if s.kind == "fc":
            p[s.name] = {"w": ParamDef((s.c_in, s.c_out), (None, None)),
                         "b": ParamDef((s.c_out,), (None,), "zeros")}
        elif s.kind == "dwconv":
            p[s.name] = {"w": ParamDef((s.c_in, s.k * s.k), (None, None)),
                         "b": ParamDef((s.c_in,), (None,), "zeros")}
        else:
            p[s.name] = {"w": ParamDef((s.c_in * s.k * s.k, s.c_out),
                                       (None, None)),
                         "b": ParamDef((s.c_out,), (None,), "zeros")}
    return p


def cnn_apply(params: dict, specs: list[ConvSpec], x: jax.Array,
              engine: rosa.Engine | None = None,
              key: jax.Array | None = None,
              residual_from: dict[str, str] | None = None) -> jax.Array:
    """Forward; x: (B, 32, 32, 3) -> logits (B, n_classes).

    `engine` routes every contraction by layer name (None = all-dense);
    `key` overrides the engine's base PRNG key for this call (per-layer
    noise keys are folded deterministically from it by the engine).
    residual_from: {layer_name: earlier_layer_name} adds skip connections
    (ResNet family); spatial dims must match.
    """
    if engine is None:
        engine = rosa.Engine.dense()
    if key is not None:
        engine = engine.with_key(key)
    saved: dict[str, jax.Array] = {}

    for s in specs:
        p = params[s.name]
        if s.kind == "fc":
            if x.ndim > 2:
                x = jnp.mean(x, axis=(1, 2)) if x.shape[1] > 1 \
                    else x.reshape(x.shape[0], -1)
            y = engine.matmul(x, p["w"], name=s.name) + p["b"]
        elif s.kind == "dwconv":
            patches = _im2col(x, s.k, s.stride)
            b, h, w_, _ = patches.shape
            pr = patches.reshape(b, h, w_, s.c_in, s.k * s.k)
            # per-channel contraction; noise/variation/gate semantics follow
            # the resolved cfg but the contraction is einsum (C tiny
            # independent sub-GEMMs)
            w_eff = engine.effective_weight(p["w"], name=s.name)
            y = jnp.einsum("bhwck,ck->bhwc", pr, w_eff) + p["b"]
        else:
            patches = _im2col(x, s.k, s.stride)
            b, h, w_, kk = patches.shape
            y = engine.matmul(patches.reshape(-1, kk), p["w"], name=s.name)
            y = y.reshape(b, h, w_, s.c_out) + p["b"]
        if residual_from and s.name in residual_from:
            y = y + saved[residual_from[s.name]]
        if s.act:
            y = jax.nn.relu(y)
        if s.pool > 1 and y.ndim == 4:
            b, h, w_, c = y.shape
            y = y.reshape(b, h // s.pool, s.pool, w_ // s.pool, s.pool, c
                          ).mean(axis=(2, 4))
        saved[s.name] = y
        x = y
    return x


# ---------------------------------------------------------------------------
# Reduced model zoo (names match configs/paper_cnns.py rows)
# ---------------------------------------------------------------------------
ALEXNET_LITE = [
    ConvSpec("conv1", "conv", 3, 24, pool=2),
    ConvSpec("conv2", "conv", 24, 48, pool=2),
    ConvSpec("conv3", "conv", 48, 64),
    ConvSpec("conv4", "conv", 64, 64),
    ConvSpec("conv5", "conv", 64, 48, pool=2),
    ConvSpec("fc1", "fc", 48, 128),
    ConvSpec("fc2", "fc", 128, 128),
    ConvSpec("fc3", "fc", 128, 10, act=False),
]

VGG16_LITE = [
    ConvSpec("conv1_1", "conv", 3, 16), ConvSpec("conv1_2", "conv", 16, 16, pool=2),
    ConvSpec("conv2_1", "conv", 16, 32), ConvSpec("conv2_2", "conv", 32, 32, pool=2),
    ConvSpec("conv3_1", "conv", 32, 48), ConvSpec("conv3_2", "conv", 48, 48),
    ConvSpec("conv3_3", "conv", 48, 48, pool=2),
    ConvSpec("conv4_1", "conv", 48, 64), ConvSpec("conv4_2", "conv", 64, 64),
    ConvSpec("conv4_3", "conv", 64, 64, pool=2),
    ConvSpec("conv5_1", "conv", 64, 64), ConvSpec("conv5_2", "conv", 64, 64),
    ConvSpec("conv5_3", "conv", 64, 64, pool=2),
    ConvSpec("fc1", "fc", 64, 96), ConvSpec("fc2", "fc", 96, 96),
    ConvSpec("fc3", "fc", 96, 10, act=False),
]

RESNET18_LITE = [
    ConvSpec("conv1", "conv", 3, 24),
    ConvSpec("l1_b1_c1", "conv", 24, 24), ConvSpec("l1_b1_c2", "conv", 24, 24),
    ConvSpec("l1_b2_c1", "conv", 24, 24), ConvSpec("l1_b2_c2", "conv", 24, 24),
    ConvSpec("l2_b1_c1", "conv", 24, 48, stride=2),
    ConvSpec("l2_b1_c2", "conv", 48, 48),
    ConvSpec("l2_b2_c1", "conv", 48, 48), ConvSpec("l2_b2_c2", "conv", 48, 48),
    ConvSpec("l3_b1_c1", "conv", 48, 64, stride=2),
    ConvSpec("l3_b1_c2", "conv", 64, 64),
    ConvSpec("l3_b2_c1", "conv", 64, 64), ConvSpec("l3_b2_c2", "conv", 64, 64),
    ConvSpec("l4_b1_c1", "conv", 64, 96, stride=2),
    ConvSpec("l4_b1_c2", "conv", 96, 96),
    ConvSpec("l4_b2_c1", "conv", 96, 96), ConvSpec("l4_b2_c2", "conv", 96, 96),
    ConvSpec("fc", "fc", 96, 10, act=False),
]
RESNET18_SKIPS = {"l1_b1_c2": "conv1", "l1_b2_c2": "l1_b1_c2",
                  "l2_b1_c2": None, "l2_b2_c2": "l2_b1_c2",
                  "l3_b2_c2": "l3_b1_c2", "l4_b2_c2": "l4_b1_c2"}
RESNET18_SKIPS = {k: v for k, v in RESNET18_SKIPS.items() if v}

MOBILENET_V3_LITE = [
    ConvSpec("conv_stem", "conv", 3, 16, pool=2),
    # mb1
    ConvSpec("mb1_exp", "conv", 16, 16, k=1),
    ConvSpec("mb1_dw", "dwconv", 16, 16),
    ConvSpec("mb1_prj", "conv", 16, 16, k=1, act=False),
    # mb2
    ConvSpec("mb2_exp", "conv", 16, 36, k=1),
    ConvSpec("mb2_dw", "dwconv", 36, 36, pool=2),
    ConvSpec("mb2_prj", "conv", 36, 24, k=1, act=False),
    # mb4
    ConvSpec("mb4_exp", "conv", 24, 48, k=1),
    ConvSpec("mb4_dw", "dwconv", 48, 48, k=5, pool=2),
    ConvSpec("mb4_prj", "conv", 48, 40, k=1, act=False),
    # mb6
    ConvSpec("mb6_exp", "conv", 40, 60, k=1),
    ConvSpec("mb6_dw", "dwconv", 60, 60, k=5),
    ConvSpec("mb6_prj", "conv", 60, 48, k=1, act=False),
    # head
    ConvSpec("head", "fc", 48, 96),
    ConvSpec("fc", "fc", 96, 10, act=False),
]

LITE_MODELS: dict[str, list[ConvSpec]] = {
    "alexnet": ALEXNET_LITE,
    "vgg16": VGG16_LITE,
    "resnet18": RESNET18_LITE,
    "mobilenet_v3": MOBILENET_V3_LITE,
}
LITE_SKIPS: dict[str, dict] = {"resnet18": RESNET18_SKIPS}
