"""Uniform quantization and signed-digit / PAM plane decomposition.

The paper (Sec. 3.1) quantizes a normalized input x in (-1,1) into N_T
balanced-ternary symbols b_t in {-1,0,1} such that

    x = sum_t 2^(t-N_T) * b_t                       (Eq. 1-2)

We realize the signed-digit stream as sign-magnitude binary: quantize to an
integer q in [-(2^(B-1)-1), 2^(B-1)-1], split |q| into B-1 magnitude bits and
multiply each by sign(q).  That satisfies b_t in {-1,0,1} exactly and is what
the EO modulators transmit, slot t carrying significance 2^(t-N_T).

PAM-k extends each slot to a radix-2^k digit (paper: "supports not only
ternary coding, but also PAM with higher bitwidths"), shrinking the slot
count from B-1 to ceil((B-1)/k) at the cost of 2^k amplitude levels.

All functions are pure jnp, jit/vmap-safe, and exactly invertible —
`compose_planes(decompose_planes(x)) == x` is a tested invariant.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    bits: int = 8          # total bits incl. sign
    symmetric: bool = True

    @property
    def qmax(self) -> int:
        return 2 ** (self.bits - 1) - 1   # 127 for 8-bit

    @property
    def n_planes(self) -> int:
        return self.bits - 1              # magnitude digits (sign rides on each)


Q8 = QuantConfig(bits=8)


def absmax_scale(x: jax.Array, per_vector: bool = False) -> jax.Array:
    """Quantization full-scale: per-tensor absmax, or per trailing-axis
    vector with `per_vector` (each (..., K) row gets its own full-scale).
    The single source of the 1e-8 floor — the digital path (here) and the
    analog realization (rosa.backends._noisy_realize) must keep using the
    SAME scale convention or their blend in _analog_operand diverges."""
    if per_vector and x.ndim >= 2:
        return jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True),
                           1e-8)
    return jnp.maximum(jnp.max(jnp.abs(x)), 1e-8)


def quantize(x: jax.Array, cfg: QuantConfig = Q8, scale: jax.Array | None = None,
             per_vector: bool = False):
    """Symmetric uniform quantization -> (int values, scale).

    scale is absmax unless given: per-tensor by default, per-row with
    `per_vector` (the serving path needs numerics that don't couple batch
    rows through a shared scale).  Returned ints are float-typed
    (TPU-friendly) in [-qmax, qmax].
    """
    if scale is None:
        scale = absmax_scale(x, per_vector)
    q = jnp.clip(jnp.round(x / scale * cfg.qmax), -cfg.qmax, cfg.qmax)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array, cfg: QuantConfig = Q8):
    return q * (scale / cfg.qmax)


def fake_quant(x: jax.Array, cfg: QuantConfig = Q8,
               per_vector: bool = False):
    """Quantize-dequantize with straight-through gradient (QAT primitive)."""
    q, scale = quantize(x, cfg, per_vector=per_vector)
    xq = dequantize(q, scale, cfg)
    return x + jax.lax.stop_gradient(xq - x)


# --------------------------------------------------------------------------
# Signed-digit plane (de)composition
# --------------------------------------------------------------------------
def decompose_planes(q: jax.Array, cfg: QuantConfig = Q8):
    """Integer-valued tensor -> stacked signed bit-planes.

    Args:
      q: integer-valued array (any float/int dtype) in [-qmax, qmax].
    Returns:
      planes: shape (n_planes, *q.shape), values in {-1, 0, +1}; plane t
        carries significance 2^t (t=0 is the LSB, matching Eq. 1's b_{k,0}).
    """
    sign = jnp.sign(q)
    mag = jnp.abs(q).astype(jnp.int32)
    planes = []
    for t in range(cfg.n_planes):
        bit = (mag >> t) & 1
        planes.append(sign * bit.astype(q.dtype))
    return jnp.stack(planes, axis=0)


def plane_weights(cfg: QuantConfig = Q8, dtype=jnp.float32):
    """Significance of each plane: 2^t for t = 0..n_planes-1.

    The paper writes significance as 2^(t-N_T) on normalized x; we fold the
    2^(-N_T) into the dequantization scale so planes stay integer-friendly.
    """
    return (2.0 ** jnp.arange(cfg.n_planes)).astype(dtype)


def compose_planes(planes: jax.Array, cfg: QuantConfig = Q8):
    """Inverse of decompose_planes: sum_t 2^t * plane_t (Eq. 2 inner sum)."""
    w = plane_weights(cfg, planes.dtype).reshape((-1,) + (1,) * (planes.ndim - 1))
    return jnp.sum(planes * w, axis=0)


# --------------------------------------------------------------------------
# PAM-k digit decomposition (radix 2^k)
# --------------------------------------------------------------------------
def decompose_pam(q: jax.Array, pam_bits: int, cfg: QuantConfig = Q8):
    """Signed radix-2^pam_bits digits; slot count = ceil(n_planes/pam_bits).

    digit_t in {-(2^k-1), ..., 2^k-1}; slot t has significance 2^(k*t).
    pam_bits=1 degenerates to decompose_planes.
    """
    radix_bits = pam_bits
    n_slots = -(-cfg.n_planes // radix_bits)
    sign = jnp.sign(q)
    mag = jnp.abs(q).astype(jnp.int32)
    mask = (1 << radix_bits) - 1
    digits = []
    for t in range(n_slots):
        d = (mag >> (radix_bits * t)) & mask
        digits.append(sign * d.astype(q.dtype))
    return jnp.stack(digits, axis=0)


def pam_plane_weights(pam_bits: int, cfg: QuantConfig = Q8, dtype=jnp.float32):
    n_slots = -(-cfg.n_planes // pam_bits)
    return (2.0 ** (pam_bits * jnp.arange(n_slots))).astype(dtype)


def compose_pam(digits: jax.Array, pam_bits: int, cfg: QuantConfig = Q8):
    w = pam_plane_weights(pam_bits, cfg, digits.dtype)
    w = w.reshape((-1,) + (1,) * (digits.ndim - 1))
    return jnp.sum(digits * w, axis=0)
