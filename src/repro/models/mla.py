"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

Queries and keys/values are low-rank compressed:

    c_q  = W_dq  h            (q_lora)             -> q = W_uq norm(c_q)
    c_kv = W_dkv h            (kv_lora)            -> k_nope = W_uk norm(c_kv)
    k_rope = RoPE(W_kr h)     (qk_rope, per-token, shared across heads)
    v    = W_uv norm(c_kv)

Per-head dims: qk = qk_nope + qk_rope for scores, v_head for values.

The decode path caches ONLY (c_kv, k_rope) — kv_lora + qk_rope floats per
token (576 for the paper config vs 2*128*128 = 32768 for vanilla MHA) — and
*absorbs* W_uk / W_uv into the query/output projections so scores are taken
directly against the compressed cache:

    score  = (q_nope W_uk) . c_kv + q_rope . k_rope
    out    = (sum_j p_j c_kv_j) W_uv

This is the paper's inference trick and is what makes deepseek-v2's
decode_32k cell cache-light in the dry-run.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import rmsnorm, rmsnorm_def, rope, _mask_bias
from repro.models.module import ParamDef


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    d_model: int
    n_heads: int
    q_lora: int = 1536
    kv_lora: int = 512
    qk_nope: int = 128
    qk_rope: int = 64
    v_head: int = 128
    rope_theta: float = 1e4
    uniform_decode: bool = True    # see layers.AttnConfig.uniform_decode

    @property
    def cache_width(self) -> int:
        return self.kv_lora + self.qk_rope


def mla_def(cfg: MLAConfig) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    return {
        "w_dq": ParamDef((d, cfg.q_lora), ("embed", "lora")),
        "q_norm": rmsnorm_def(cfg.q_lora, "lora"),
        "w_uq": ParamDef((cfg.q_lora, h, cfg.qk_nope + cfg.qk_rope),
                         ("lora", "heads", "head_dim")),
        "w_dkv": ParamDef((d, cfg.kv_lora), ("embed", "lora")),
        "kv_norm": rmsnorm_def(cfg.kv_lora, "lora"),
        "w_kr": ParamDef((d, cfg.qk_rope), ("embed", None)),
        "w_uk": ParamDef((cfg.kv_lora, h, cfg.qk_nope),
                         ("lora", "heads", "head_dim")),
        "w_uv": ParamDef((cfg.kv_lora, h, cfg.v_head),
                         ("lora", "heads", "head_dim")),
        "wo": ParamDef((h, cfg.v_head, d), ("heads", "head_dim", "embed")),
    }


def _project_q(p, cfg: MLAConfig, x, positions):
    cq = rmsnorm(p["q_norm"], jnp.einsum("bsd,dl->bsl", x, p["w_dq"]))
    q = jnp.einsum("bsl,lhk->bshk", cq, p["w_uq"])
    q_nope, q_rope = q[..., :cfg.qk_nope], q[..., cfg.qk_nope:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _compress_kv(p, cfg: MLAConfig, x, positions):
    c_kv = rmsnorm(p["kv_norm"], jnp.einsum("bsd,dl->bsl", x, p["w_dkv"]))
    k_rope = jnp.einsum("bsd,dr->bsr", x, p["w_kr"])
    k_rope = rope(k_rope[:, :, None, :], positions,
                  cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_rope


def mla_apply(p: dict, cfg: MLAConfig, x: jax.Array,
              positions: jax.Array) -> jax.Array:
    """Full-sequence MLA (train). x: (B, S, D)."""
    y, _ = mla_prefill(p, cfg, x, positions)
    return y


def mla_prefill(p: dict, cfg: MLAConfig, x: jax.Array, positions: jax.Array):
    """Returns (out, cache=(c_kv, k_rope)) — the compressed KV cache."""
    q_nope, q_rope = _project_q(p, cfg, x, positions)
    c_kv, k_rope = _compress_kv(p, cfg, x, positions)
    k_nope = jnp.einsum("bsl,lhk->bshk", c_kv, p["w_uk"])
    v = jnp.einsum("bsl,lhv->bshv", c_kv, p["w_uv"])
    scale = (cfg.qk_nope + cfg.qk_rope) ** -0.5
    scores = (jnp.einsum("bqhn,bkhn->bhqk", q_nope, k_nope)
              + jnp.einsum("bqhr,bkr->bhqk", q_rope, k_rope))
    scores = scores.astype(jnp.float32) * scale
    bias = _mask_bias(positions, positions, True, 0)
    probs = jax.nn.softmax(scores + bias[:, None], axis=-1).astype(x.dtype)
    o = jnp.einsum("bhqk,bkhv->bqhv", probs, v)
    return jnp.einsum("bqhv,hvd->bqd", o, p["wo"]), (c_kv, k_rope)


def mla_decode(p: dict, cfg: MLAConfig, x: jax.Array, cache: tuple,
               pos: jax.Array):
    """Absorbed single-token decode against the compressed cache.

    x: (B, C, D); cache: (c_kv (B, S, kv_lora), k_rope (B, S, qk_rope));
    pos: (B,) first position of the chunk (C == 1: classic decode; C > 1:
    a serving prefill chunk).  Returns (out (B, C, D), new_cache).
    """
    b, c = x.shape[:2]
    q_pos = pos[:, None] + jnp.arange(c)[None, :]
    q_nope, q_rope = _project_q(p, cfg, x, q_pos)
    c_new, r_new = _compress_kv(p, cfg, x, q_pos)
    c_kv, k_rope = cache
    from repro.models.layers import cache_write
    c_kv = cache_write(c_kv, c_new, pos, cfg.uniform_decode)
    k_rope = cache_write(k_rope, r_new, pos, cfg.uniform_decode)

    # absorb W_uk into q: q_c (B, 1, H, kv_lora)
    q_c = jnp.einsum("bqhn,lhn->bqhl", q_nope, p["w_uk"])
    scale = (cfg.qk_nope + cfg.qk_rope) ** -0.5
    scores = (jnp.einsum("bqhl,bkl->bhqk", q_c, c_kv)
              + jnp.einsum("bqhr,bkr->bhqk", q_rope, k_rope))
    scores = scores.astype(jnp.float32) * scale
    s = c_kv.shape[1]
    k_pos = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    bias = _mask_bias(q_pos, k_pos, True, 0,
                      k_len_valid=(pos + c)[:, None])
    probs = jax.nn.softmax(scores + bias[:, None], axis=-1).astype(x.dtype)
    o_c = jnp.einsum("bhqk,bkl->bqhl", probs, c_kv)     # compressed context
    o = jnp.einsum("bqhl,lhv->bqhv", o_c, p["w_uv"])    # absorb W_uv
    return jnp.einsum("bqhv,hvd->bqd", o, p["wo"]), (c_kv, k_rope)
