"""The `fused` raw backend end to end: registry contract, rosa_matmul
dispatch parity vs the composed "ref" chain, gates-as-operands (no
retrace across gate/mgate sweeps, vmap over mapping plans), bit-level
EnergyLedger pricing parity, and the optical serving path routed through
the megakernel (`ServeConfig(rosa_backend="fused")`)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import rosa
from repro.configs import get_smoke
from repro.core import mrr
from repro.core.constants import ROSA_OPTIMAL, ComputeMode, Mapping
from repro.serve import (Request, Scheduler, ServeConfig, run_sequential)

NOISY = rosa.RosaConfig(noise=mrr.PAPER_NOISE, backend="fused")


def _operands(seed: int, m=9, k=130, n=40):
    kx, kw, kn = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(kx, (m, k)), jax.random.normal(kw, (k, n)),
            kn)


def _var(k_dim: int, seed: int = 3) -> mrr.StaticVariation:
    dv = 0.01 * jax.random.normal(jax.random.PRNGKey(seed), (k_dim,))
    return mrr.StaticVariation(dv=dv, ddt=jnp.float32(0.05),
                               dlam=jnp.float32(1e-4))


# ---------------------------------------------------------------------------
# Registry contract
# ---------------------------------------------------------------------------
def test_fused_backend_registered():
    assert "fused" in rosa.backend_names()
    name, fn = rosa.resolve_backend("fused")
    assert name == "fused" and callable(fn)
    from repro.rosa.backends import is_raw_backend
    assert is_raw_backend("fused")
    assert not is_raw_backend("ref")


def test_auto_resolution_platform_pick():
    """"auto" -> the fused megakernel on TPU, the composed ref elsewhere."""
    name, _ = rosa.resolve_backend("auto")
    expected = "fused" if jax.default_backend() == "tpu" else "ref"
    assert name == expected


# ---------------------------------------------------------------------------
# rosa_matmul dispatch parity: backend="fused" == backend="ref"
# ---------------------------------------------------------------------------
def _assert_quantized_parity(y, y_ref, *, qmax: int = 127,
                             tight: float = 2e-4) -> None:
    """Flip-aware quantized-parity discipline (the contract is documented
    on tests/test_kernels.py::assert_quantized_parity): bulk at float
    tightness, nothing beyond the one-requant-LSB bound, and rows touched
    by a requantization boundary flip stay rare."""
    y = np.asarray(y, np.float64).reshape(-1, y.shape[-1])
    r = np.asarray(y_ref, np.float64).reshape(y.shape)
    scale = max(float(np.max(np.abs(r))), 1.0)
    d = np.abs(y - r) / scale
    assert d.max() <= 2.0 / qmax
    assert int((d.max(axis=-1) > tight).sum()) <= max(2, -(-y.shape[0] // 4))


def _assert_dispatch_parity(cfg: rosa.RosaConfig, seed: int, *,
                            key=True, var=True, gate=None, mgate=None):
    x, w, kn = _operands(seed)
    var_ = _var(x.shape[1]) if var else None
    kn_ = kn if key else None
    args = (kn_, var_, gate, mgate)
    y_f = rosa.rosa_matmul(x, w, dataclasses.replace(cfg, backend="fused"),
                           *args)
    y_r = rosa.rosa_matmul(x, w, dataclasses.replace(cfg, backend="ref"),
                           *args)
    _assert_quantized_parity(y_f, y_r)


@pytest.mark.parametrize("seed,cfg_kw,call_kw", [
    (0, {}, {}),                                              # noisy WS
    (1, {"mapping": Mapping.IS, "act_per_vector": True}, {}),
    (2, {}, {"gate": 0.3}),
    (3, {"act_per_vector": True}, {"mgate": 0.5}),
    (4, {"mode": ComputeMode.ANALOG}, {"gate": 0.7}),
    (5, {"noise": mrr.IDEAL}, {"var": False}),                # ideal path
], ids=["ws", "is_apv", "gated", "mgated", "analog", "ideal"])
def test_fused_dispatch_matches_ref(seed, cfg_kw, call_kw):
    _assert_dispatch_parity(dataclasses.replace(NOISY, **cfg_kw), seed,
                            **call_kw)


def test_fused_nonideal_osa_dispatch(key):
    from repro.core import osa
    cfg = dataclasses.replace(
        NOISY, mapping=Mapping.IS, act_per_vector=True,
        osa_cfg=osa.OSAConfig(splitter_imbalance=0.01,
                              odl_loss_db_per_stage=0.05))
    _assert_dispatch_parity(cfg, 6)


def test_fused_batched_leading_dims(key):
    """rosa_matmul flattens leading axes before the kernel and restores
    them after — the (B, T, K) decode call shape."""
    k1, k2, kn = jax.random.split(key, 3)
    x = jax.random.normal(k1, (2, 5, 48))
    w = jax.random.normal(k2, (48, 16))
    y_f = rosa.rosa_matmul(x, w, NOISY, kn)
    y_r = rosa.rosa_matmul(x, w, dataclasses.replace(NOISY, backend="ref"),
                           kn)
    assert y_f.shape == (2, 5, 16)
    _assert_quantized_parity(y_f, y_r)


def test_fused_straight_through_gradients(key):
    """The custom_vjp is backend-agnostic: fused forward, exact dense
    backward (identical cotangents to the ref backend)."""
    x, w, kn = _operands(7, m=6, k=32, n=8)

    def loss(backend):
        cfg = dataclasses.replace(NOISY, backend=backend)
        return lambda x_, w_: jnp.sum(rosa.rosa_matmul(x_, w_, cfg, kn) ** 2)

    gx_f, gw_f = jax.grad(loss("fused"), argnums=(0, 1))(x, w)
    gx_r, gw_r = jax.grad(loss("ref"), argnums=(0, 1))(x, w)
    _assert_quantized_parity(gx_f, gx_r)
    _assert_quantized_parity(gw_f, gw_r)


# ---------------------------------------------------------------------------
# Gates are kernel operands: one trace across sweeps, vmappable plans
# ---------------------------------------------------------------------------
def test_fused_gate_sweep_single_trace(key):
    """PR 7's gated evaluators sweep gate/mgate VALUES through one compiled
    executable — the fused kernel must take them as operands, not consts."""
    x, w, kn = _operands(8, m=8, k=64, n=16)
    traces = []

    @jax.jit
    def f(x_, w_, k_, gate, mgate):
        traces.append(1)          # trace-time side effect: counts retraces
        return rosa.rosa_matmul(x_, w_, NOISY, k_, None, gate, mgate)

    outs = [f(x, w, kn, jnp.float32(g), jnp.float32(mg))
            for g in (0.0, 0.5, 1.0) for mg in (0.0, 1.0)]
    assert len(traces) == 1
    assert all(o.shape == (8, 16) for o in outs)


def test_fused_vmap_over_mapping_gate(key):
    """A whole {layer: IS|WS} plan as a float vector: candidate plans are
    a vmap axis over the mgate operand (robust.sensitivity's search)."""
    x, w, kn = _operands(9, m=4, k=48, n=12)
    mgates = jnp.array([0.0, 0.5, 1.0])
    ys = jax.vmap(lambda mg: rosa.rosa_matmul(x, w, NOISY, kn, None, None,
                                              mg))(mgates)
    assert ys.shape == (3, 4, 12)
    y_ws = rosa.rosa_matmul(x, w, NOISY, kn, None, None, jnp.float32(0.0))
    np.testing.assert_allclose(np.asarray(ys[0]), np.asarray(y_ws),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# EnergyLedger pricing parity
# ---------------------------------------------------------------------------
def test_ledger_prices_fused_identical_to_composed():
    """Fusion is an execution detail: the analytical energy model prices a
    routed GEMM by (shape, mapping, mode), so the fused trace must export
    BIT-identical totals (energy, delay, EDP, every breakdown term) to the
    composed one for the same plan."""
    exports = {}
    for backend in ("fused", "ref"):
        cfg = dataclasses.replace(NOISY, backend=backend)
        ledger = rosa.EnergyLedger()
        eng = rosa.Engine.from_config(cfg, key=jax.random.PRNGKey(0),
                                      ledger=ledger)
        jax.eval_shape(
            lambda p, x_: eng.matmul(x_, p, name="proj"),
            jax.ShapeDtypeStruct((64, 128), jnp.float32),
            jax.ShapeDtypeStruct((8, 64), jnp.float32))
        exports[backend] = ledger.export(ROSA_OPTIMAL)
    f, r = exports["fused"], exports["ref"]
    assert f["totals"] == r["totals"]          # bit-level: no tolerance
    # events identical modulo provenance (backend name, global seq stamp)
    strip = lambda evs: [{k: v for k, v in e.items()
                          if k not in ("backend", "seq")} for e in evs]
    assert strip(f["events"]) == strip(r["events"])


# ---------------------------------------------------------------------------
# Serving: the decode Program routes through the megakernel
# ---------------------------------------------------------------------------
def test_rosa_serving_fused_backend():
    """Optical serving on the fused backend with a pinned fabricated chip:
    the continuous-batching scheduler must stay differentially equal to
    the per-request sequential oracle (same engine), proving the decode
    Program's matmuls route through the megakernel deterministically."""
    smoke_cfg = get_smoke("qwen3-32b")
    scfg = ServeConfig(n_slots=2, max_len=24, prefill_chunk=4, rosa=True,
                       rosa_backend="fused", variation_seed=7)
    sched = Scheduler(smoke_cfg, scfg)
    rng = np.random.default_rng(11)
    reqs = [Request(i, rng.integers(0, smoke_cfg.vocab,
                                    int(rng.integers(3, 8))),
                    int(rng.integers(2, 6)), arrival=i) for i in range(3)]
    rep = sched.run(reqs, policy="continuous")
    ref = run_sequential(smoke_cfg, scfg, sched.params, reqs,
                         engine=sched.engine)
    for r in reqs:
        assert rep.completions[r.rid].tokens == ref[r.rid]["tokens"]
    assert len(sched.engine.ledger.events) > 0
    assert all(ev.backend == "fused" for ev in sched.engine.ledger.events)
