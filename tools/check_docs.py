#!/usr/bin/env python
"""Docs CI: validate internal links and run marked smoke commands.

Two checks over ``docs/*.md`` (plus README.md for links into docs/):

1. **Links** — every relative markdown link ``[..](path#anchor)`` must
   point at an existing file, and when it carries an anchor into a
   markdown file, at an existing heading (GitHub slug rules).  External
   links (``http(s)://``, ``mailto:``) are ignored.

2. **Smoke commands** — every fenced block whose info string is
   ``bash docs-smoke`` is executed with ``bash -e`` from the repo root.
   Documented commands that rot fail CI, not readers.

Usage::

    python tools/check_docs.py            # links + smoke commands
    python tools/check_docs.py --no-run   # links only (fast)
"""

from __future__ import annotations

import argparse
import pathlib
import re
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent

# [text](target) — ignores images' leading "!" by matching the paren pair.
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
_FENCE_RE = re.compile(r"^```([^\n`]*)\n(.*?)^```", re.MULTILINE | re.DOTALL)


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: lowercase, drop punctuation, spaces -> dashes."""
    h = re.sub(r"[`*_]", "", heading.strip()).lower()
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def strip_code(text: str) -> str:
    """Remove fenced code blocks so code samples never count as links."""
    return _FENCE_RE.sub("", text)


def check_links(doc: pathlib.Path) -> list[str]:
    """All broken relative links/anchors in one markdown file."""
    errors = []
    text = doc.read_text()
    for target in _LINK_RE.findall(strip_code(text)):
        if re.match(r"^[a-z][a-z0-9+.-]*:", target):   # http:, mailto:, ...
            continue
        path_part, _, anchor = target.partition("#")
        dest = doc if not path_part else (doc.parent / path_part).resolve()
        if not dest.exists():
            errors.append(f"{doc.relative_to(REPO)}: broken link -> {target}")
            continue
        if anchor and dest.suffix == ".md":
            slugs = {github_slug(h) for h in _HEADING_RE.findall(
                strip_code(dest.read_text()))}
            if anchor not in slugs:
                errors.append(f"{doc.relative_to(REPO)}: missing anchor "
                              f"-> {target}")
    return errors


def smoke_blocks(doc: pathlib.Path) -> list[str]:
    """The ``bash docs-smoke`` fenced blocks of one markdown file."""
    return [body for info, body in _FENCE_RE.findall(doc.read_text())
            if info.strip() == "bash docs-smoke"]


def run_smoke(doc: pathlib.Path) -> list[str]:
    """Execute each marked block; collect failures as error strings."""
    errors = []
    for i, block in enumerate(smoke_blocks(doc)):
        label = f"{doc.relative_to(REPO)} smoke block #{i + 1}"
        print(f"-- running {label}:\n{block.strip()}", flush=True)
        proc = subprocess.run(["bash", "-e", "-c", block], cwd=REPO,
                              capture_output=True, text=True, timeout=900)
        if proc.returncode != 0:
            tail = (proc.stdout + proc.stderr)[-2000:]
            errors.append(f"{label}: exit {proc.returncode}\n{tail}")
        else:
            print(f"-- {label}: ok", flush=True)
    return errors


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--no-run", action="store_true",
                    help="skip executing docs-smoke blocks")
    args = ap.parse_args(argv)

    docs = sorted((REPO / "docs").glob("*.md"))
    if not docs:
        print("no docs/*.md found", file=sys.stderr)
        return 1
    readme = REPO / "README.md"
    errors: list[str] = []
    for doc in [*docs, *([readme] if readme.exists() else [])]:
        errors += check_links(doc)
    n_blocks = sum(len(smoke_blocks(d)) for d in docs)
    if not args.no_run:
        for doc in docs:
            errors += run_smoke(doc)

    if errors:
        print("\n".join(["DOCS CHECK FAILED:", *errors]), file=sys.stderr)
        return 1
    print(f"docs check: {len(docs)} docs, {n_blocks} smoke blocks"
          f"{' (not run)' if args.no_run else ''}, links ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
