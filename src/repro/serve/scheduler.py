"""Continuous-batching scheduler: slot admission, prefill/decode interleave.

One scheduler drives two admission policies over the SAME jitted step:

  continuous  a completed request's slot is refilled on the very next tick
              (eviction + refill ride inside the decode step), so the
              decode batch stays full whenever work is queued;
  oneshot     the static-batching baseline `launch/serve.py` used to be:
              wait until a full batch of prefilled requests is ready,
              admit them together, decode until the LAST one finishes,
              only then form the next batch.

Each tick runs at most one prefill chunk and one decode step, so cost is
countable in deterministic step units — `ServeReport` exposes those
(decode_steps, prefill_chunks, ticks) next to wall-clock times, and the
`serve_smoke` bench gates on the unit-based throughput ratio, which is
reproducible across machines.

The per-request oracle `run_sequential` (same prefill path, batch-1 decode,
same sampling keys) is what the differential suite pins the scheduler
against: greedy tokens AND logits must match bit-exactly, seeded sampling
must draw identical tokens.
"""

from __future__ import annotations

import contextlib
import dataclasses
import heapq
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import build_model
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs
from repro.serve.config import ServeConfig, serving_model_config
from repro.serve.decode import (PrefillTask, init_state, make_admit,
                                make_admit_step, make_chunk_fn, make_evict,
                                make_serve_step, null_admit, sample_token)


@dataclasses.dataclass
class Request:
    """One serving request; `arrival` is in scheduler ticks."""

    rid: int
    prompt: np.ndarray
    max_new_tokens: int
    arrival: int = 0

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")


@dataclasses.dataclass
class Completion:
    rid: int
    prompt_len: int
    arrival: int
    tokens: list = dataclasses.field(default_factory=list)
    logits: list = dataclasses.field(default_factory=list)
    first_token_tick: int = -1
    admit_tick: int = -1
    done_tick: int = -1
    slot: int = -1
    # wall-clock lifecycle stamps (perf_counter seconds relative to the
    # run's t0) — recorded unconditionally; tick counters above remain the
    # deterministic, machine-independent latency unit
    enqueue_wall: float = 0.0
    admit_wall: float = 0.0
    first_token_wall: float = 0.0
    done_wall: float = 0.0

    @property
    def ttft_ticks(self) -> int:
        return self.first_token_tick - self.arrival

    @property
    def latency_ticks(self) -> int:
        return self.done_tick - self.arrival

    @property
    def ttft_s(self) -> float:
        """Wall-clock time to first token (enqueue → prefill finished)."""
        return self.first_token_wall - self.enqueue_wall

    @property
    def latency_s(self) -> float:
        """Wall-clock end-to-end latency (enqueue → last token)."""
        return self.done_wall - self.enqueue_wall


@dataclasses.dataclass(frozen=True)
class EmptyStat:
    """Typed sentinel for a percentile over an EMPTY completion set.

    Short drift scenarios can slice a report down to zero completions
    (e.g. "requests finished before the first probe window"), where
    `np.percentile` would silently return NaN and poison downstream
    arithmetic.  The sentinel is falsy and still floats to NaN, so legacy
    `float(rep.percentile(...))` call sites keep working while callers
    that care can `isinstance`-check instead of testing `math.isnan`.
    """

    q: float
    kind: str

    def __float__(self) -> float:
        return float("nan")

    def __bool__(self) -> bool:
        return False


@dataclasses.dataclass
class ServeReport:
    policy: str
    completions: dict
    ticks: int = 0
    decode_steps: int = 0
    prefill_chunks: int = 0
    wall_s: float = 0.0
    n_slots: int = 1

    @property
    def total_tokens(self) -> int:
        return sum(len(c.tokens) for c in self.completions.values())

    @property
    def step_units(self) -> int:
        """Deterministic cost: every decode step and prefill chunk is one
        unit of accelerator work."""
        return self.decode_steps + self.prefill_chunks

    @property
    def tokens_per_unit(self) -> float:
        """Useful generated tokens per unit of work — the gated,
        machine-independent throughput metric."""
        return self.total_tokens / max(self.step_units, 1)

    @property
    def occupancy(self) -> float:
        """Mean fraction of decode-batch slots doing useful work (each
        request's FIRST token comes from its prefill, not a decode step,
        so it is excluded)."""
        decoded = self.total_tokens - sum(
            1 for c in self.completions.values() if c.tokens)
        return decoded / max(self.decode_steps * self.n_slots, 1)

    @property
    def tokens_per_s(self) -> float:
        return self.total_tokens / max(self.wall_s, 1e-9)

    def latencies(self, kind: str = "latency") -> np.ndarray:
        vals = [getattr(c, f"{kind}_ticks")
                for c in self.completions.values()]
        return np.asarray(sorted(vals), np.float64)

    def percentile(self, q: float, kind: str = "latency"):
        vals = self.latencies(kind)
        if vals.size == 0:
            return EmptyStat(q, kind)
        return float(np.percentile(vals, q))

    def wall_latencies(self, kind: str = "latency") -> np.ndarray:
        """Per-request wall-clock latencies [s]; kind is latency|ttft."""
        vals = [getattr(c, f"{kind}_s") for c in self.completions.values()]
        return np.asarray(sorted(vals), np.float64)

    def wall_percentile_ms(self, q: float, kind: str = "latency"):
        """q-th percentile of the wall-clock latencies, in ms."""
        vals = self.wall_latencies(kind)
        if vals.size == 0:
            return EmptyStat(q, kind)
        return float(np.percentile(vals, q) * 1e3)


class TickHook:
    """Protocol for per-tick scheduler extensions (drift injection and the
    adaptive controller live in `repro.serve.adaptive`).

    `step_args(tick)` returns extra TRACED positional args appended to the
    decode-step call — the installed `Scheduler.step` must accept them
    (the adaptive package installs a drift-aware step that takes the
    residual thermal offset as a traced scalar, so per-tick drift never
    retraces).  `on_tick_end` runs on the host between ticks, after the
    tick's decode completed — the one place a controller may swap the
    serving program/steps without perturbing an in-flight step.  Ticks
    that make no progress (idle-jump to the next arrival) skip both.
    """

    def step_args(self, tick: int) -> tuple:
        return ()

    def on_tick_end(self, sched: "Scheduler", tick: int, state,
                    idle_slots: int) -> None:
        pass


class Scheduler:
    """Builds the jitted serving machinery once; `run` replays a request
    list under a policy.  With `scfg.rosa` the decode step is compiled
    into ONE `rosa.Program` (hybrid plan autotuned on the decode trace,
    disk plan cache, pinned chip, energy ledger) and every jitted step —
    decode, admit, prefill chunk, whole prefill, evict — is built from it,
    so the frozen engine reaches each trace without a global stack."""

    def __init__(self, model_cfg, scfg: ServeConfig, params=None,
                 init_seed: int = 0, mesh=None, engine=None,
                 plan_cache=None):
        self.cfg = serving_model_config(model_cfg, rosa=scfg.rosa)
        self.scfg = scfg
        self.bundle = build_model(self.cfg)
        self.engine = engine
        self.program = None
        if scfg.rosa and engine is None:
            from repro import rosa
            from repro.serve.metrics import build_serving_program
            prog = build_serving_program(self.bundle, scfg,
                                         cache=plan_cache)
            self.program = prog.with_ledger(rosa.EnergyLedger())
            self.engine = self.program.engine
        elif engine is not None:
            self.program = serving_program(self.bundle, scfg, engine)
        with self._engine_ctx():
            self.params = (params if params is not None
                           else self.bundle.init(jax.random.PRNGKey(init_seed)))
        self.step = make_serve_step(self.bundle, scfg, mesh=mesh,
                                    program=self.program)
        self.admit_step = make_admit_step(self.bundle, scfg,
                                          program=self.program)
        self.chunk_fn = make_chunk_fn(self.bundle, program=self.program)
        self.whole_fn = (self.program.bind(self.bundle.prefill)
                         if self.program is not None
                         else jax.jit(self.bundle.prefill))
        self.evict = make_evict(self.bundle, scfg, program=self.program) \
            if scfg.evict_on_done else None
        self.null = null_admit(self.cfg, scfg)
        self.sample1 = jax.jit(sample_token)
        self.base_key = jax.random.PRNGKey(scfg.seed)

    def _engine_ctx(self):
        """Ambient context for the few non-jitted call sites (param init);
        every jitted step already carries the engine via `Program.bind`."""
        if self.engine is None:
            return contextlib.nullcontext()
        from repro import rosa
        return rosa.engine_context(self.engine)

    def _scope(self, tag: str):
        """Ledger attribution scope around a jitted call site: only the
        first (tracing) call records, so scoping every tick is free."""
        return _ledger_scope(self.engine, tag)

    def _check(self, req: Request) -> None:
        """Fail FAST, before any request is served: these bounds mirror
        PrefillTask's (prompt < max_len) exactly, so a bad request can
        never abort the loop mid-stream after others completed."""
        if len(req.prompt) >= self.scfg.max_len:
            raise ValueError(
                f"request {req.rid}: prompt length {len(req.prompt)} >= "
                f"max_len {self.scfg.max_len}: no decode room")
        need = len(req.prompt) + req.max_new_tokens - 1
        if need > self.scfg.max_len:
            raise ValueError(
                f"request {req.rid}: prompt {len(req.prompt)} + "
                f"{req.max_new_tokens} new tokens needs cache {need} > "
                f"max_len {self.scfg.max_len}")

    # -- the serving loop ---------------------------------------------------
    def run(self, requests: list[Request], policy: str = "continuous",
            temperature: float | None = None,
            hook: TickHook | None = None) -> ServeReport:
        """`temperature` overrides scfg.temperature — it is a TRACED scalar,
        so greedy and sampled runs share one compiled step.  `hook` is a
        `TickHook`: extra traced decode-step args + an end-of-tick host
        callback (see the protocol docstring)."""
        if policy not in ("continuous", "oneshot"):
            raise ValueError(policy)
        for r in requests:
            self._check(r)
        scfg = self.scfg
        n_slots = scfg.n_slots
        temp = jnp.float32(scfg.temperature if temperature is None
                           else temperature)

        completions = {r.rid: Completion(r.rid, len(r.prompt), r.arrival)
                       for r in requests}
        pending = deque(sorted(requests, key=lambda r: (r.arrival, r.rid)))
        prefill_q: deque[Request] = deque()
        ready: deque[tuple] = deque()        # (req, cache, first_token)
        inflight: tuple | None = None        # (req, PrefillTask)
        free = list(range(n_slots))
        heapq.heapify(free)
        slot_rid: list[int | None] = [None] * n_slots
        n_done = 0
        state = init_state(self.cfg, scfg)
        rep = ServeReport(policy=policy, completions=completions,
                          n_slots=n_slots)
        tick = 0
        # tracing is ambient and fixed for the run: resolve it once, keep
        # the disabled path at one None check per emission site, and hoist
        # every registry lookup out of the tick loop
        tr = obs.current_tracer()
        reg = obs_metrics.registry()
        c_completed = reg.counter("serve.requests_completed")
        c_evicted = reg.counter("serve.evictions")
        g_depth = reg.gauge("serve.queue_depth")
        g_active = reg.gauge("serve.slots_active")
        last_depth = last_active = -1
        null_span = contextlib.nullcontext()
        # span contexts are stateless between uses — build the per-tick ones
        # once and re-enter them, keeping the hot loop allocation-free
        if tr is not None:
            tick_ctx = tr.span("serve.tick", "serve")
            prefill_ctx = tr.span("serve.prefill_chunk", "serve")
            decode_ctx = tr.span("serve.decode_step", "serve")
        else:
            tick_ctx = prefill_ctx = decode_ctx = null_span
        etrack = None
        if tr is not None and self.engine is not None \
                and self.engine.ledger is not None:
            from repro.obs.energy import EnergyTrack
            etrack = EnergyTrack(self.engine.ledger)
        t0 = time.perf_counter()

        def finish(comp: Completion) -> None:
            comp.done_tick = tick
            comp.done_wall = time.perf_counter() - t0
            c_completed.inc()
            if tr is not None:
                tr.async_end("request", comp.rid, cat="request",
                             tokens=len(comp.tokens))

        with self._engine_ctx():
            while n_done < len(requests):
                with tick_ctx:
                    progressed = False
                    while pending and pending[0].arrival <= tick:
                        r = pending.popleft()
                        completions[r.rid].enqueue_wall = \
                            time.perf_counter() - t0
                        if tr is not None:
                            tr.async_begin("request", r.rid, cat="request",
                                           prompt_len=len(r.prompt))
                        prefill_q.append(r)

                    # -- one prefill chunk per tick -----------------------
                    if inflight is None and prefill_q:
                        req = prefill_q.popleft()
                        inflight = (req, PrefillTask(self.bundle, scfg,
                                                     req.prompt,
                                                     self.chunk_fn,
                                                     self.whole_fn))
                    if inflight is not None:
                        req, task = inflight
                        with prefill_ctx, self._scope("prefill"):
                            task.advance(self.params)
                        if etrack is not None:
                            etrack.tick("prefill")
                        rep.prefill_chunks += 1
                        progressed = True
                        if task.done:
                            comp = completions[req.rid]
                            tok0 = self.sample1(self.base_key, req.rid, 0,
                                                task.logits, temp)
                            comp.tokens.append(int(tok0))
                            comp.first_token_tick = tick
                            comp.first_token_wall = \
                                time.perf_counter() - t0
                            if tr is not None:
                                tr.async_instant("first_token", req.rid,
                                                 cat="request")
                            if scfg.collect_logits:
                                comp.logits.append(np.asarray(task.logits))
                            if req.max_new_tokens == 1:  # done at prefill
                                finish(comp)
                                n_done += 1
                            else:
                                ready.append((req, task.cache, tok0))
                            inflight = None

                    # -- admission ---------------------------------------
                    admit = self.null
                    if policy == "continuous":
                        # refill rides inside the decode step: one per tick
                        if ready and free:
                            slot = heapq.heappop(free)
                            req, cache0, tok0 = ready.popleft()
                            admit = make_admit(cache0, slot, req.rid, tok0,
                                               req.max_new_tokens)
                            slot_rid[slot] = req.rid
                            self._mark_admit(completions[req.rid], slot,
                                             tick, t0, tr)
                    else:
                        # oneshot: once the batch is idle and a full batch
                        # (or everything that's left) is prefilled, admit
                        # it in one burst, then decode until it drains
                        outstanding = (len(pending) + len(prefill_q)
                                       + len(ready)
                                       + (1 if inflight is not None else 0))
                        if (len(free) == n_slots and ready
                                and (len(ready) >= min(n_slots, outstanding)
                                     or (not pending and not prefill_q
                                         and inflight is None))):
                            while ready and free:
                                slot = heapq.heappop(free)
                                req, cache0, tok0 = ready.popleft()
                                state = self.admit_step(
                                    state,
                                    make_admit(cache0, slot, req.rid, tok0,
                                               req.max_new_tokens))
                                slot_rid[slot] = req.rid
                                self._mark_admit(completions[req.rid],
                                                 slot, tick, t0, tr)
                            progressed = True

                    # -- one decode step for the whole batch -------------
                    if any(r is not None for r in slot_rid):
                        extra = hook.step_args(tick) if hook is not None \
                            else ()
                        with decode_ctx, self._scope("decode"):
                            state, out = self.step(self.params, state,
                                                   admit, temp, *extra)
                        if etrack is not None:
                            etrack.tick("decode")
                        rep.decode_steps += 1
                        progressed = True
                        tok = np.asarray(out["token"])
                        emitted = np.asarray(out["emitted"])
                        done = np.asarray(out["done"])
                        logits = (np.asarray(out["logits"])
                                  if scfg.collect_logits else None)
                        for s in range(n_slots):
                            if not emitted[s]:
                                continue
                            comp = completions[slot_rid[s]]
                            comp.tokens.append(int(tok[s]))
                            if logits is not None:
                                comp.logits.append(logits[s])
                            if done[s]:
                                finish(comp)
                                n_done += 1
                                slot_rid[s] = None
                                heapq.heappush(free, s)
                                if self.evict is not None:
                                    c_evicted.inc()
                                    state = self.evict(state, jnp.int32(s))

                    if tr is not None:
                        # counters sample on change only: Perfetto renders
                        # steps, and a flat line is pure per-tick overhead
                        depth = (len(pending) + len(prefill_q) + len(ready)
                                 + (1 if inflight is not None else 0))
                        active = sum(1 for r in slot_rid if r is not None)
                        if depth != last_depth:
                            last_depth = depth
                            tr.counter("serve.queue_depth", depth)
                            g_depth.set(depth)
                        if active != last_active:
                            last_active = active
                            tr.counter("serve.slots_active", active)
                            g_active.set(active)

                    if not progressed:
                        if pending:                 # idle: jump to arrival
                            tick = pending[0].arrival
                            continue
                        raise RuntimeError(
                            "scheduler deadlock")   # pragma: no cover
                    if hook is not None:
                        hook.on_tick_end(self, tick, state, len(free))
                    tick += 1

        rep.ticks = tick
        rep.wall_s = time.perf_counter() - t0
        return rep

    @staticmethod
    def _mark_admit(comp: Completion, slot: int, tick: int, t0: float,
                    tr) -> None:
        """Stamp one request's admission (tick, wall, slot, trace)."""
        comp.admit_tick = tick
        comp.slot = slot
        comp.admit_wall = time.perf_counter() - t0
        if tr is not None:
            tr.async_instant("admit", comp.rid, cat="request", slot=slot)


def serving_program(bundle, scfg: ServeConfig, engine):
    """Freeze an explicitly-supplied engine into a `rosa.Program` (no plan
    autotune — the caller's plan is taken as-is) so the serving machinery
    can build its jitted steps from it."""
    import jax.numpy as jnp

    from repro import rosa
    from repro.serve.metrics import _abstract_decode_batch

    params = bundle.abstract(jnp.float32)
    batch = _abstract_decode_batch(bundle.cfg, scfg)
    # compile with the ledger detached: the runtime serving ledger must
    # carry ONLY the scoped prefill/decode events the scheduler's step
    # traces record, never untagged compile-time duplicates
    prog = rosa.compile(lambda eng, p, b: bundle.decode_step(p, b),
                        engine.with_ledger(None), (params, batch),
                        autotune=None)
    return prog.with_engine(engine)


def _ledger_scope(engine, tag: str):
    if engine is not None and engine.ledger is not None:
        return engine.ledger.scope(tag)
    return contextlib.nullcontext()


# ---------------------------------------------------------------------------
# Per-request sequential oracle (the differential-test reference)
# ---------------------------------------------------------------------------
def run_sequential(model_cfg, scfg: ServeConfig, params,
                   requests: list[Request], engine=None,
                   temperature: float | None = None) -> dict:
    """Decode every request ALONE (batch 1), same prefill path, same
    sampling keys.  Returns {rid: {"tokens": [...], "logits": [...]}}.

    This is the semantic spec of serving: whatever the continuous scheduler
    interleaves, each request's stream must equal this oracle's exactly."""
    cfg = serving_model_config(model_cfg, rosa=scfg.rosa)
    bundle = build_model(cfg)
    ctx = contextlib.nullcontext()
    program = None
    if scfg.rosa and engine is None:
        from repro import rosa
        from repro.serve.metrics import build_serving_program
        # reuse the ONE autotuned Program instead of compiling twice
        program = build_serving_program(bundle, scfg) \
            .with_ledger(rosa.EnergyLedger())
        engine = program.engine
    elif engine is not None:
        program = serving_program(bundle, scfg, engine)
    if engine is not None:
        from repro import rosa
        ctx = rosa.engine_context(engine)
    chunk_fn = make_chunk_fn(bundle, program=program)
    whole_fn = (program.bind(bundle.prefill) if program is not None
                else jax.jit(bundle.prefill))
    decode1_fn = lambda p, t, c: bundle.decode_step(
        p, {"token": t, "pos": c["pos"], "cache": c})
    decode1 = (program.bind(decode1_fn) if program is not None
               else jax.jit(decode1_fn))
    sample1 = jax.jit(sample_token)
    base = jax.random.PRNGKey(scfg.seed)
    temp = jnp.float32(scfg.temperature if temperature is None
                       else temperature)

    out = {}
    with ctx:
        for req in requests:
            task = PrefillTask(bundle, scfg, req.prompt, chunk_fn, whole_fn)
            with _ledger_scope(engine, "prefill"):
                while not task.advance(params):
                    pass
            tok = sample1(base, req.rid, 0, task.logits, temp)
            toks, logs = [int(tok)], [np.asarray(task.logits)]
            cache = task.cache
            for i in range(1, req.max_new_tokens):
                with _ledger_scope(engine, "decode"):
                    logits, cache = decode1(params, tok.reshape(1), cache)
                tok = sample1(base, req.rid, i, logits[0], temp)
                toks.append(int(tok))
                logs.append(np.asarray(logits[0]))
            out[req.rid] = {"tokens": toks, "logits": logs}
    return out
