"""rosa — the unified execution-plan API over the optical backend.

Everything the paper's pipeline needs to execute a network optically enters
through two objects:

  `ExecutionPlan`   frozen, hashable (static-pytree) resolution from layer
                    name to `RosaConfig` — a default config plus per-layer
                    overrides.  The layer-wise hybrid IS/WS mapping
                    (Sec. 3.5) is an override set built by
                    `ExecutionPlan.from_mapping_plan`.

  `Engine`          routes every named matmul: resolves the layer's config
                    from the plan, folds a deterministic per-layer/per-step
                    PRNG key from its base key (`layer_key`), records the
                    GEMM shape on an optional `EnergyLedger`, and dispatches
                    to the registered contraction backend.

Backends (`rosa.backends`) are registered by name — `dense` exact einsum,
`ref` pure-jnp OSA (Eq. 1 oracle), `pallas` TPU kernel — and selected by
`RosaConfig.backend` ("auto" picks per platform).  `register_backend` adds
new ones; later scaling PRs (sharded serving, batching, fused kernels) plug
in here.

`EnergyLedger` prices the *traced* call sequence with the analytical
event-count model (core.energy), so `ledger.edp(...)` is computed from the
same matmuls that produced the numerics — by construction it agrees with
`core.mapping.plan_edp` on the equivalent LayerShape list.

Migration from the pre-Engine API:

    MatmulBackend(kind="rosa", rosa_cfg=cfg, plan=plan).apply(x, w, name=n)
      -> Engine.from_hybrid_plan(cfg, plan).matmul(x, w, name=n)
    RosaConfig(use_kernel=True)  ->  RosaConfig(backend="pallas")
    {layer: RosaConfig} dicts    ->  Engine.from_layer_cfgs(cfgs)
    hand-threaded `key=` args    ->  Engine(..., key=base_key) + name folding
"""

from repro.rosa.backends import (DEFAULT, RosaConfig, backend_names,
                                 make_backend, register_backend,
                                 resolve_backend, rosa_matmul)
from repro.rosa.engine import (Engine, current_engine, layer_key,
                               use_engine)
from repro.rosa.ledger import EnergyLedger, MatmulEvent
from repro.rosa.plan import ExecutionPlan

__all__ = [
    "DEFAULT", "Engine", "EnergyLedger", "ExecutionPlan", "MatmulEvent",
    "RosaConfig", "backend_names", "current_engine", "layer_key",
    "make_backend", "register_backend", "resolve_backend", "rosa_matmul",
    "use_engine",
]
