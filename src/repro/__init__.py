"""repro — ROSA (microring ONN w/ optical shift-and-add) on a JAX substrate.

Layers:
  core/         the paper's contribution (physics, OSA, energy, mapping, DSE)
  rosa/         the execution-plan API: Engine, ExecutionPlan, backend
                registry (dense/ref/pallas), trace-based EnergyLedger
  robust/       vectorized Monte-Carlo device variation: chip ensembles,
                sensitivity profiling, thermal drift + re-trim, reports
  kernels/      Pallas TPU kernels for the compute hot spots (+ jnp oracles)
  models/       pure-JAX model zoo (LM fleet + paper CNN families)
  configs/      assigned architecture configs + paper workload tables
  data/         deterministic synthetic data pipelines
  optim/        optimizers and schedules
  checkpoint/   sharded, atomic, elastic checkpointing
  distributed/  sharding rules, gradient compression, collective helpers
  launch/       production mesh, multi-pod dry-run, train/serve drivers
"""

__version__ = "1.0.0"
