"""Fig. 7 reproduction: OPE array-size DSE across workloads.

Sweeps (R, C) under C<=8, T*R*C<=1024; reports relative EDP (vs the 4x4
compact baseline) per workload + the aggregated metric M, and the paper's
headline deltas: best config vs DEAP-CNNs (R=113,C=9) and vs compact 4x4.
Paper claims: -64% vs DEAP, -26% vs compact; winner (R=8,C=8).
"""

from __future__ import annotations

from repro.configs.paper_cnns import WORKLOADS
from repro.core import dse
from repro.core.constants import COMPACT_4X4


def run(verbose: bool = True, osa: bool = False) -> dict:
    from repro.core import energy as E
    wls = [dse.Workload(n, layers) for n, layers in WORKLOADS.items()]
    pts = dse.sweep(wls, osa=E.OSA_OPTIMAL if osa else E.NO_OSA,
                    batch=128)
    best = pts[0]
    deap = next(p for p in pts if p.ope.rows == 113)
    compact = next(p for p in pts if p.ope == COMPACT_4X4)

    if verbose:
        hdr = f"{'config':16s} {'geomean':>8s} {'worst':>8s} {'M':>8s}  " \
            + " ".join(f"{w.name[:9]:>9s}" for w in wls)
        print(hdr)
        for p in [*pts[:10], deap, compact]:
            row = " ".join(f"{p.rel_edp[w.name]:9.3f}" for w in wls)
            print(f"{p.label:16s} {p.geomean:8.3f} {p.worst:8.3f} "
                  f"{p.metric:8.3f}  {row}")
        print(f"\nbest = {best.label}")
        print(f"aggregated relative EDP: best vs DEAP-CNNs: "
              f"{(1 - best.metric / deap.metric) * 100:.1f}% lower "
              f"(paper: 64%)")
        print(f"aggregated relative EDP: best vs compact 4x4: "
              f"{(1 - best.metric / compact.metric) * 100:.1f}% lower "
              f"(paper: 26%)")
    return {"best": best, "deap": deap, "compact": compact,
            "reduction_vs_deap": 1 - best.metric / deap.metric,
            "reduction_vs_compact": 1 - best.metric / compact.metric}


if __name__ == "__main__":
    run()
