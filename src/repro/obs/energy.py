"""`EnergyLedger` → trace-timeline bridge.

`rosa.EnergyLedger` records matmuls only at JAX *trace* time — a jitted
step that hits the compile cache records nothing — so per-tick energy
cannot be read off the ledger as it grows.  `EnergyTrack` instead prices
each attribution scope's step energy ONCE (lazily, after the first traced
step has populated the ledger for that tag) and then accumulates it
analytically every tick, emitting cumulative counter ("C") events onto the
ambient trace.  The result is an ``energy.<tag>`` counter track per scope
(e.g. ``energy.prefill`` / ``energy.decode``) that Perfetto renders
alongside the latency spans, so energy and latency are inspectable in one
view.

All emission goes through the module-level helpers of `repro.obs.trace`,
so the bridge is a no-op when no tracer is installed.
"""

from __future__ import annotations

from repro.core import energy as E
from repro.core.constants import OPEConfig, ROSA_OPTIMAL
from repro.obs import trace as _trace


class EnergyTrack:
    """Emit per-scope cumulative energy as counter events on the trace.

    One instance watches one ledger.  Call `tick(tag)` once per executed
    step attributed to `tag`; the step energy for a tag is priced from the
    ledger's deduped trace (batch=1 — the traced shapes already carry slot
    concurrency) the first time the ledger holds events for that tag, and
    re-used afterwards.
    """

    def __init__(self, ledger, ope: OPEConfig = ROSA_OPTIMAL,
                 osa: E.OSAEnergyConfig = E.OSA_OPTIMAL):
        self.ledger = ledger
        self.ope = ope
        self.osa = osa
        self._step_j: dict[str, float] = {}     # tag -> priced step energy
        self._cum_j: dict[str, float] = {}      # tag -> cumulative energy

    def _price(self, tag: str) -> float | None:
        j = self._step_j.get(tag)
        if j is None:
            if self.ledger is None or not any(
                    ev.tag == tag for ev in self.ledger.events):
                return None                     # tag not traced yet
            j = self.ledger.breakdown(self.ope, self.osa, batch=1,
                                      tag=tag).energy
            self._step_j[tag] = j
        return j

    def tick(self, tag: str, n: int = 1) -> None:
        """Account `n` executed steps of scope `tag` and emit the counter."""
        if not _trace.enabled():
            return
        j = self._price(tag)
        if j is None:
            return
        cum = self._cum_j.get(tag, 0.0) + j * n
        self._cum_j[tag] = cum
        _trace.counter(f"energy.{tag}", {"J": cum}, cat="energy")

    def total_j(self, tag: str | None = None) -> float:
        """Cumulative accounted energy [J] (all scopes when tag is None)."""
        if tag is not None:
            return self._cum_j.get(tag, 0.0)
        return sum(self._cum_j.values())
