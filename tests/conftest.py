"""Shared fixtures. NOTE: no XLA_FLAGS here on purpose — tests must see
the plain 1-device CPU; only launch/dryrun.py forces 512 devices."""

import jax
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(autouse=True)
def _hermetic_plan_cache(tmp_path_factory, monkeypatch):
    """Point the rosa.compile plan cache at a session-private directory so
    tests never read a stale plan from (or write into) the user's real
    ~/.cache — cache-behaviour tests pass their own `cache=` explicitly."""
    monkeypatch.setenv(
        "ROSA_PLAN_CACHE",
        str(tmp_path_factory.getbasetemp() / "rosa-plan-cache"))


# ---------------------------------------------------------------------------
# Opt-in NaN/Inf guard for the analog numerics path
# ---------------------------------------------------------------------------
def pytest_addoption(parser):
    parser.addoption(
        "--nan-guard", action="store_true", default=False,
        help="run @analog_guard tests under jax_debug_nans/jax_debug_infs "
             "(any NaN/Inf in the analog path raises at the producing op)")


@pytest.fixture(autouse=True)
def _nan_guard(request):
    """For tests marked `analog_guard` under --nan-guard: every op that
    produces a NaN or Inf raises immediately, turning a silent numerics
    regression in the MRR transfer / OSA accumulation path into a
    pinpointed failure.  Off by default — the debug checks force re-traces
    and would slow the whole suite."""
    if request.node.get_closest_marker("analog_guard") is None \
            or not request.config.getoption("--nan-guard"):
        yield
        return
    with jax.debug_nans(True), jax.debug_infs(True):
        yield
