"""Typed findings: what every static check emits.

A `Finding` is one statically-decided fact about one analysis target —
"this jaxpr consumes PRNG key #3 twice", "this donated buffer produced no
input_output_alias".  Findings are value objects with a stable
`fingerprint` (check, code, subject, location) so a committed baseline can
acknowledge known findings without pinning their human-readable messages,
and CI can gate on *new* findings only.

Severity semantics:

  ERROR    — the artifact is wrong (correlated Monte-Carlo noise, a decode
             step silently double-buffering its KV cache); `verify="error"`
             refuses to return the Program.
  WARNING  — probably wrong or fragile (constant-baked seed, >2x padding
             waste); surfaced, baselined, never fatal by default.
  INFO     — noteworthy but expected (a kernel shape that pads); recorded
             in reports, never gates.
"""

from __future__ import annotations

import dataclasses
import enum
import hashlib
import json


class Severity(enum.IntEnum):
    """Ordered so max(severities) is the report's worst finding."""

    INFO = 0
    WARNING = 1
    ERROR = 2

    def __str__(self) -> str:           # "ERROR", not "Severity.ERROR"
        return self.name


@dataclasses.dataclass(frozen=True)
class Finding:
    """One statically-decided fact about one analysis target.

    check:    registry name of the emitting check ("prng", "donation", ...)
    code:     stable machine code within the check ("PRNG001")
    severity: ERROR / WARNING / INFO
    subject:  the analysis target's name ("serve:decode_step", "zoo:...")
    location: where inside the subject (eqn path, parameter index, shape)
    message:  the human-readable explanation (NOT part of the fingerprint)
    """

    check: str
    code: str
    severity: Severity
    subject: str
    location: str
    message: str

    @property
    def fingerprint(self) -> str:
        """Stable identity for baselining: message text excluded so wording
        improvements don't invalidate a committed baseline."""
        raw = json.dumps([self.check, self.code, self.subject, self.location])
        return hashlib.sha256(raw.encode("utf-8")).hexdigest()[:16]

    def to_json(self) -> dict:
        return {"check": self.check, "code": self.code,
                "severity": str(self.severity), "subject": self.subject,
                "location": self.location, "message": self.message,
                "fingerprint": self.fingerprint}

    @classmethod
    def from_json(cls, doc: dict) -> "Finding":
        return cls(check=doc["check"], code=doc["code"],
                   severity=Severity[doc["severity"]],
                   subject=doc["subject"], location=doc["location"],
                   message=doc["message"])

    def __str__(self) -> str:
        return (f"[{self.severity}] {self.code} {self.subject} "
                f"({self.location}): {self.message}")


@dataclasses.dataclass(frozen=True)
class AnalysisReport:
    """All findings of one analysis run, with baseline bookkeeping."""

    findings: tuple[Finding, ...] = ()

    def __len__(self) -> int:
        return len(self.findings)

    def __iter__(self):
        return iter(self.findings)

    def by_severity(self, severity: Severity) -> tuple[Finding, ...]:
        return tuple(f for f in self.findings if f.severity == severity)

    @property
    def errors(self) -> tuple[Finding, ...]:
        return self.by_severity(Severity.ERROR)

    @property
    def warnings(self) -> tuple[Finding, ...]:
        return self.by_severity(Severity.WARNING)

    def fingerprints(self) -> set[str]:
        return {f.fingerprint for f in self.findings}

    def new_against(self, baseline: set[str],
                    min_severity: Severity = Severity.WARNING
                    ) -> tuple[Finding, ...]:
        """Findings at or above `min_severity` absent from the baseline —
        the set a CI gate fails on.  INFO findings never gate by default."""
        return tuple(f for f in self.findings
                     if f.severity >= min_severity
                     and f.fingerprint not in baseline)

    def merged(self, other: "AnalysisReport") -> "AnalysisReport":
        return AnalysisReport(self.findings + other.findings)

    def to_json(self) -> dict:
        return {"findings": [f.to_json() for f in self.findings]}

    @classmethod
    def from_json(cls, doc: dict) -> "AnalysisReport":
        return cls(tuple(Finding.from_json(f) for f in doc["findings"]))

    def summary(self) -> str:
        if not self.findings:
            return "no findings"
        return (f"{len(self.findings)} findings "
                f"({len(self.errors)} error, {len(self.warnings)} warning, "
                f"{len(self.by_severity(Severity.INFO))} info)")


class VerificationError(RuntimeError):
    """`rosa.compile(verify="error")` found ERROR-severity findings.

    Carries the full `AnalysisReport` on `.report` so callers (and tests)
    can inspect exactly which invariants the program violated."""

    def __init__(self, report: AnalysisReport):
        self.report = report
        lines = [str(f) for f in report.errors] or [str(f) for f in report]
        super().__init__(
            "static verification failed: " + report.summary() + "\n  "
            + "\n  ".join(lines))
