"""The rosa package: unified execution-plan API over the optical backend.

Everything the paper's pipeline needs to execute a network optically enters
through three objects:

  `ExecutionPlan`   frozen, hashable (static-pytree) resolution from layer
                    name to `RosaConfig` — a default config plus per-layer
                    overrides.  The layer-wise hybrid IS/WS mapping
                    (Sec. 3.5) is an override set built by
                    `ExecutionPlan.from_mapping_plan`; `to_json`/`from_json`
                    round-trip it losslessly.

  `Engine`          routes every named matmul: resolves the layer's config
                    from the plan, folds a deterministic per-layer/per-step
                    PRNG key from its base key (`layer_key`), records the
                    GEMM shape on an optional `EnergyLedger`, and dispatches
                    to the registered contraction backend.

  `Program`         the compile-once handle (`rosa.compile`): abstractly
                    traces a model once into a `ProgramTrace`, autotunes
                    the hybrid plan against that whole workload
                    (`AutotuneConfig`; searched plans persist in the
                    content-addressed on-disk `PlanCache`, so warm compiles
                    skip the search), and freezes the result into a jitted
                    executable with explicit key/ledger/variation threading
                    — no global engine stack.

Backends (`rosa.backends`) are registered by name — `dense` exact einsum,
`ref` pure-jnp OSA (Eq. 1 oracle), `pallas` TPU kernel — and selected by
`RosaConfig.backend` ("auto" picks per platform).  `register_backend` adds
new ones; later scaling PRs (sharded serving, batching, fused kernels) plug
in here.

`EnergyLedger` prices the *traced* call sequence with the analytical
event-count model (core.energy), so `ledger.edp(...)` is computed from the
same matmuls that produced the numerics — by construction it agrees with
`core.mapping.plan_edp` on the equivalent LayerShape list.

Migration to the Program API (the ambient-engine context managers are
deprecated; `rosa.compile` installs the engine around its own traces):

    with use_engine(engine): y = jit(f)(x)
        -> program = rosa.compile(lambda eng, x: f(x), engine, (x,))
           y = program(x)                          # or program.bind(f)(x)
    current_engine()              -> ambient_engine()   (model code only)
    use_engine(engine)            -> engine_context(engine)  (low-level)
    per-call hybrid plan search   -> rosa.compile(..., autotune=
                                       rosa.AutotuneConfig(...))  [cached]
    hand-threaded `key=` args     -> program(*args, key=base_key)
    MatmulBackend(...).apply(...) -> removed; Engine.matmul / rosa.compile
    RosaConfig(use_kernel=True)   -> RosaConfig(backend="pallas")
"""

from repro.rosa import serialize
from repro.rosa.backends import (DEFAULT, RosaConfig, backend_names,
                                 make_backend, realization_rms_error,
                                 register_backend, resolve_backend,
                                 rosa_matmul)
from repro.rosa.engine import (Engine, ambient_engine, current_engine,
                               engine_context, layer_key, use_engine)
from repro.rosa.ledger import EnergyLedger, MatmulEvent
from repro.rosa.plan import ExecutionPlan
from repro.rosa.program import (EDP_ONLY, AutotuneConfig, DegradationSource,
                                PlanCache, Program, ProgramTrace,
                                TraceEntry, capture_trace, compile,
                                default_cache_dir)

__all__ = [
    "DEFAULT", "EDP_ONLY", "AutotuneConfig", "DegradationSource", "Engine",
    "EnergyLedger", "ExecutionPlan", "MatmulEvent", "PlanCache", "Program",
    "ProgramTrace", "RosaConfig", "TraceEntry", "ambient_engine",
    "backend_names", "capture_trace", "compile", "current_engine",
    "default_cache_dir", "engine_context", "layer_key", "make_backend",
    "realization_rms_error", "register_backend", "resolve_backend",
    "rosa_matmul", "serialize", "use_engine",
]
