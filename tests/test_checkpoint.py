"""Checkpointing: atomicity, keep-K, elastic restore."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt


def _tree(key):
    k1, k2 = jax.random.split(key)
    return {"a": jax.random.normal(k1, (8, 4)),
            "nested": {"b": jax.random.normal(k2, (3,)),
                       "step": jnp.asarray(7)}}


def test_save_restore_roundtrip(tmp_path, key):
    t = _tree(key)
    ckpt.save(str(tmp_path), 5, t)
    out = ckpt.restore(str(tmp_path), 5, jax.tree.map(jnp.zeros_like, t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_step_ignores_tmp_dirs(tmp_path, key):
    t = _tree(key)
    ckpt.save(str(tmp_path), 1, t)
    ckpt.save(str(tmp_path), 2, t)
    os.makedirs(tmp_path / "step_00000099.tmp-garbage")
    assert ckpt.latest_step(str(tmp_path)) == 2


def test_keep_k_gc(tmp_path, key):
    mgr = ckpt.CheckpointManager(str(tmp_path), every=1, keep=2)
    t = _tree(key)
    for s in range(1, 6):
        mgr.maybe_save(s, t)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == ["step_00000004", "step_00000005"]


def test_restore_shape_mismatch_raises(tmp_path, key):
    t = _tree(key)
    ckpt.save(str(tmp_path), 1, t)
    bad = dict(t, a=jnp.zeros((2, 2)))
    with pytest.raises(ValueError, match="shape mismatch"):
        ckpt.restore(str(tmp_path), 1, bad)


def test_elastic_restore_recreates_sharding(tmp_path, key):
    """Arrays are stored topology-free; restore re-places per sharding."""
    t = _tree(key)
    ckpt.save(str(tmp_path), 3, t)
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
    out = ckpt.restore(str(tmp_path), 3, jax.tree.map(jnp.zeros_like, t),
                       shardings=sh)
    assert all(o.sharding == NamedSharding(mesh, P())
               for o in jax.tree.leaves(out))


def test_meta_roundtrip(tmp_path, key):
    ckpt.save(str(tmp_path), 9, _tree(key), meta={"arch": "x", "loss": 1.5})
    m = ckpt.read_meta(str(tmp_path), 9)
    assert m["meta"]["arch"] == "x"
    assert m["step"] == 9
