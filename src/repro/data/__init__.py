from repro.data.tokens import TokenPipeline  # noqa
from repro.data.synth_cifar import synth_cifar  # noqa
