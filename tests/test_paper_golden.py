"""Golden paper-fidelity regression tests (PAPER.md headline numbers).

Two layers of pinning, both with EXPLICIT tolerances:

  1. reproduction pins — the value this repo's energy model computes today,
     held to 1e-6 relative: an energy-model refactor that shifts any of
     these numbers must come with a deliberate golden update, never a
     silent drift;
  2. paper windows — where the reproduction tracks the paper closely
     (OSA 29%, compact-array 26%) the value must stay inside a stated
     window around the PAPER's number; where magnitudes are documented to
     differ (DEAP comparisons on synth workloads — see
     benchmarks/table4_hybrid.py) we pin the paper's claim as a bound the
     reproduction must keep exceeding.

Both the scalar model (core.energy, via fig8/table4 paths) and the
vectorized one (core.energy_vec, via the fig7 DSE sweep and
profile_layers_fast) are exercised, plus an element-level parity check
between the two, so neither implementation can drift from the other.
"""

import numpy as np
import pytest

from repro.configs.paper_cnns import CNN_WORKLOADS
from repro.core import energy as E
from repro.core import mapping as M
from repro.core.constants import (ComputeMode, DEAP_HIGH_CHANNEL, Mapping,
                                  ROSA_OPTIMAL)
from repro.models.cnn import LITE_MODELS

# -- reproduction pins (rel 1e-6) -------------------------------------------
GOLDEN = {
    "fig7_best_label": "R=8,C=8,T=16",      # paper winner: (R=8, C=8)
    "fig7_reduction_vs_deap": 0.33517400209471915,     # paper: 0.64
    "fig7_reduction_vs_compact": 0.22607095668842436,  # paper: 0.26
    "fig8_geomean_reduction_osa": 0.28580986529830166,      # paper: 0.29
    "fig8_geomean_reduction_osa_ode": 0.33332575119641483,  # paper: 0.37
    "table4_avg_hybrid_vs_ws_edp_red": 0.2850777915075481,
    # table4 avg hybrid-vs-DEAP EDP reduction saturates at ~1.0 on the
    # synth workloads (DEAP's high-channel analog arrays price orders of
    # magnitude worse at batch 128) — the paper's 54.7% average is kept as
    # a floor below, not pinned here.
}
REL = 1e-6

# -- paper windows (absolute, explicit) -------------------------------------
PAPER_OSA = 0.29
PAPER_OSA_WINDOW = 0.02          # reproduction tracks closely
PAPER_COMPACT = 0.26
PAPER_COMPACT_WINDOW = 0.05
PAPER_DEAP_FIG7_FLOOR = 0.30     # paper claims 0.64; repo reproduces ~0.335
#   (documented magnitude gap) — must at least stay above this floor
PAPER_TABLE4_DEAP_AVG = 0.547    # repo exceeds; keep exceeding


@pytest.fixture(scope="module")
def fig7():
    from benchmarks import fig7_array_dse
    return fig7_array_dse.run(verbose=False)


@pytest.fixture(scope="module")
def fig8():
    from benchmarks import fig8_osa
    return fig8_osa.run(verbose=False)


def test_fig7_array_dse_golden(fig7):
    assert fig7["best"].label == GOLDEN["fig7_best_label"]
    assert fig7["reduction_vs_deap"] == pytest.approx(
        GOLDEN["fig7_reduction_vs_deap"], rel=REL)
    assert fig7["reduction_vs_compact"] == pytest.approx(
        GOLDEN["fig7_reduction_vs_compact"], rel=REL)


def test_fig7_paper_windows(fig7):
    assert abs(fig7["reduction_vs_compact"] - PAPER_COMPACT) \
        < PAPER_COMPACT_WINDOW
    assert fig7["reduction_vs_deap"] > PAPER_DEAP_FIG7_FLOOR


def test_fig8_osa_golden(fig8):
    assert fig8["geomean_reduction_osa"] == pytest.approx(
        GOLDEN["fig8_geomean_reduction_osa"], rel=REL)
    assert fig8["geomean_reduction_osa_ode"] == pytest.approx(
        GOLDEN["fig8_geomean_reduction_osa_ode"], rel=REL)


def test_fig8_paper_window(fig8):
    """The 29% OSA contribution is the closest-tracked headline number."""
    assert abs(fig8["geomean_reduction_osa"] - PAPER_OSA) < PAPER_OSA_WINDOW
    # ODE sizing must add on top of plain OSA
    assert fig8["geomean_reduction_osa_ode"] > fig8["geomean_reduction_osa"]


def _table4_edp_reductions():
    """EDP-only hybrid-mapping numbers on the table-4 layer subsets
    (profile_layers_fast -> energy_vec; plan_edp -> scalar energy)."""
    ws_red, deap_red = [], []
    for model, layers_full in CNN_WORKLOADS.items():
        lite = {s.name for s in LITE_MODELS[model]}
        mapped = [l for l in layers_full if l.name in lite]
        profs = M.profile_layers_fast(mapped, ROSA_OPTIMAL, batch=128)
        plan = M.hybrid_plan(profs)
        e_h = M.plan_edp(mapped, plan, ROSA_OPTIMAL, batch=128)
        e_ws = M.plan_edp(mapped, {}, ROSA_OPTIMAL, batch=128)
        e_deap = E.network_energy(mapped, DEAP_HIGH_CHANNEL, Mapping.WS,
                                  ComputeMode.ANALOG, E.NO_OSA,
                                  batch=128).edp
        ws_red.append(1 - e_h / e_ws)
        deap_red.append(1 - e_h / e_deap)
    return np.mean(ws_red), np.mean(deap_red)


def test_table4_hybrid_mapping_golden():
    avg_ws, avg_deap = _table4_edp_reductions()
    assert avg_ws == pytest.approx(
        GOLDEN["table4_avg_hybrid_vs_ws_edp_red"], rel=REL)
    # the paper's 54.7%-vs-DEAP average is a floor the reproduction clears
    assert avg_deap > PAPER_TABLE4_DEAP_AVG
    # hybrid never prices worse than pure WS on any network
    assert avg_ws >= 0.0


def test_energy_vec_matches_scalar_on_paper_layers():
    """core.energy_vec and core.energy agree per layer to 1e-9 relative on
    every paper workload row, both mappings — the golden pins above hold
    through EITHER implementation."""
    from jax.experimental import enable_x64

    from repro.core import energy_vec as EV

    for model, layers in CNN_WORKLOADS.items():
        cand = EV.stack_candidates([ROSA_OPTIMAL])
        stacked = EV.stack_layers(layers)
        for mp in (Mapping.IS, Mapping.WS):
            with enable_x64():
                spec = EV.EnergySpec.make(mapping=mp,
                                          mode=ComputeMode.MIXED,
                                          osa=E.OSA_OPTIMAL, batch=128)
                en, lat = EV.grid_energy(cand, stacked, spec)
                vec_edp = np.asarray(en[0] * lat[0])
            for i, layer in enumerate(layers):
                bd = E.layer_energy(layer, ROSA_OPTIMAL, mp,
                                    ComputeMode.MIXED, E.OSA_OPTIMAL,
                                    batch=128)
                assert vec_edp[i] == pytest.approx(bd.edp, rel=1e-9), \
                    (model, layer.name, mp)
