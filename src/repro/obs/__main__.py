"""Module entry point: ``python -m repro.obs summarize trace.json``."""

import sys

from repro.obs.cli import main

sys.exit(main())
