"""End-to-end system behaviour: training convergence, serve loop,
elastic checkpoint-restart across device counts (subprocess)."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_tiny_lm_learns(key):
    """A 2-layer model on deterministic Markov data: loss must drop."""
    import dataclasses
    from repro.configs import get_smoke
    from repro.data import TokenPipeline
    from repro.launch.steps import init_opt_state, make_train_step
    from repro.models.model import build_model
    from repro.optim import AdamWConfig

    cfg = dataclasses.replace(get_smoke("qwen3-32b"), vocab=64)
    bundle = build_model(cfg)
    params = bundle.init(key)
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(bundle, AdamWConfig(lr=3e-3)),
                   donate_argnums=(0, 1))
    pipe = TokenPipeline(vocab=64, seq_len=64, global_batch=8, seed=1)
    losses = []
    for i in range(60):
        params, opt, m = step(params, opt, pipe.batch(i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.5


def test_train_cli_checkpoints_and_resumes(tmp_path):
    """Run the real train driver twice; the resume must continue from the
    saved step and produce a checkpoint directory layout."""
    env = dict(os.environ, PYTHONPATH=SRC)
    base = [sys.executable, "-m", "repro.launch.train", "--arch", "qwen3-32b",
            "--smoke", "--batch", "2", "--seq", "32", "--lr", "1e-3",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "5",
            "--log-every", "100"]
    r1 = subprocess.run(base + ["--steps", "6"], capture_output=True,
                        text=True, env=env, timeout=600)
    assert r1.returncode == 0, r1.stderr[-2000:]
    assert any(d.startswith("step_") for d in os.listdir(tmp_path))
    r2 = subprocess.run(base + ["--steps", "8", "--resume"],
                        capture_output=True, text=True, env=env, timeout=600)
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "resumed from step 5" in r2.stdout


def test_train_cli_elastic_restart_different_device_count(tmp_path):
    """Fault-tolerance: checkpoint under 1 device, restore under 4 devices
    on a (2,2) mesh — the elastic path exercised end-to-end."""
    env1 = dict(os.environ, PYTHONPATH=SRC)
    base = [sys.executable, "-m", "repro.launch.train", "--arch",
            "mistral-large-123b", "--smoke", "--batch", "4", "--seq", "32",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "4",
            "--log-every", "100"]
    r1 = subprocess.run(base + ["--steps", "4"], capture_output=True,
                        text=True, env=env1, timeout=600)
    assert r1.returncode == 0, r1.stderr[-2000:]
    env4 = dict(env1, XLA_FLAGS="--xla_force_host_platform_device_count=4")
    r2 = subprocess.run(base + ["--steps", "6", "--resume",
                                "--data-axis", "2"],
                        capture_output=True, text=True, env=env4,
                        timeout=600)
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "resumed from step 4" in r2.stdout


def test_serve_cli_generates(tmp_path):
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "mamba2-1.3b",
         "--smoke", "--policy", "batch", "--batch", "2",
         "--prompt-len", "16", "--gen", "4"],
        capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "decoded 4 tokens" in r.stdout


def test_serve_cli_continuous_stream(tmp_path):
    """The continuous-batching CLI end-to-end on a small Poisson stream."""
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "qwen3-32b",
         "--smoke", "--requests", "6", "--n-slots", "2", "--max-len", "32",
         "--gen-range", "2", "12", "--temperature", "0.5"],
        capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "tokens_per_unit" in r.stdout
    assert "total_tokens" in r.stdout


def test_grad_compression_error_feedback(key):
    """bf16-compressed grads with error feedback stay unbiased over steps."""
    from repro.distributed import compress as C
    g = {"w": jax.random.normal(key, (256,)) * 1e-3}
    err = C.init_error_state(g)
    acc = jnp.zeros((256,))
    for _ in range(32):
        g16, err = C.compress(g, err)
        acc = acc + C.decompress(g16)["w"]
    # accumulated compressed sum ~ 32 * g (error feedback corrects bias)
    np.testing.assert_allclose(np.asarray(acc / 32), np.asarray(g["w"]),
                               atol=2e-6)
