"""build_model(cfg) — the public model API used by launch/, tests and
benchmarks.

A `ModelBundle` exposes pure functions over plain pytrees:

    bundle.init(key, dtype)              real params
    bundle.abstract(dtype)               ShapeDtypeStruct params (dry-run)
    bundle.train_loss(params, batch)     scalar LM loss
    bundle.prefill(params, batch)        (last logits, cache)
    bundle.decode_step(params, batch)    (logits, new cache)
    bundle.input_specs(shape)            (batch SDS pytree, logical-axes tree)
    bundle.param_axes()                  logical axes of every param

`input_specs` mirrors the assignment's shape grid: ``train_*`` shapes feed
train_loss, ``prefill_*`` feed prefill, ``decode_*`` / ``long_*`` feed
decode_step with a fully-populated KV cache of the given sequence length.
Modality frontends are stubs per the assignment: vision/audio cells receive
precomputed patch/frame embeddings in the batch.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.module import abstract_params, init_params, logical_axes, \
    param_count
from repro.models.transformer import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str                 # train | prefill | decode
    seq_len: int
    global_batch: int


ASSIGNED_SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

# reduced shapes for CPU smoke tests
SMOKE_SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 32, 2),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32, 2),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32, 2),
    "long_500k": ShapeSpec("long_500k", "decode", 64, 1),
}


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Assignment skip rules (documented in DESIGN.md §Arch-applicability)."""
    if shape.name == "long_500k":
        sub_quadratic = (cfg.family in ("ssm", "hybrid")
                         or cfg.window_pattern > 0)
        if not sub_quadratic:
            return False, ("pure full-attention arch: no sub-quadratic path "
                           "for 500k decode (skip per assignment)")
    return True, ""


# ---------------------------------------------------------------------------
# Cache logical axes (mirrors transformer.init_cache structure)
# ---------------------------------------------------------------------------
_KV_AX = ("layers", "cache_batch", "cache_seq", "kv_heads", "head_dim")
_SSM_AX = {
    "conv_x": ("layers", "cache_batch", None, "heads", "head_dim"),
    "conv_b": ("layers", "cache_batch", None, None, "state"),
    "conv_c": ("layers", "cache_batch", None, None, "state"),
    "state": ("layers", "cache_batch", "heads", "state", "head_dim"),
}


def cache_axes(cfg: ModelConfig) -> dict:
    if cfg.family == "hybrid":
        ax = {"groups": {
            "ssm": {k: (None,) + v for k, v in _SSM_AX.items()},
            "shared": (_KV_AX, _KV_AX)}}
        if cfg.n_layers % cfg.shared_every:
            ax["tail"] = dict(_SSM_AX)
    elif cfg.family == "encdec":
        mem_ax = ("layers", "cache_batch", "memory_seq", "kv_heads",
                  "head_dim")
        ax = {"layers": {"self": (_KV_AX, _KV_AX),
                         "cross": (mem_ax, mem_ax)},
              "memory_pos": ("cache_batch", None)}
    elif cfg.family == "ssm":
        ax = {"layers": dict(_SSM_AX)}
    elif cfg.family == "mla_moe":
        mla_ax = ("layers", "cache_batch", "cache_seq", None)
        ax = {"layers": (mla_ax, mla_ax)}
        if cfg.first_dense_ff:
            ax["layer0"] = (mla_ax[1:], mla_ax[1:])
    else:
        ax = {"layers": (_KV_AX, _KV_AX)}
    ax["pos"] = ("cache_batch",)
    return ax


# ---------------------------------------------------------------------------
# Input construction
# ---------------------------------------------------------------------------
def _split_vlm(seq: int) -> tuple[int, int]:
    img = min(1024, max(seq // 4, 1))
    return img, seq - img


def make_inputs(cfg: ModelConfig, shape: ShapeSpec, concrete: bool = False,
                key: jax.Array | None = None):
    """Returns (batch pytree, logical-axes pytree).

    concrete=False -> ShapeDtypeStructs (dry-run); True -> real arrays.
    """
    b, s = shape.global_batch, shape.seq_len
    tok_dt, emb_dt = jnp.int32, jnp.bfloat16

    base_key = key if key is not None else jax.random.PRNGKey(0)
    n_drawn = 0

    def arr(shp, dt, maxval=None):
        if not concrete:
            return jax.ShapeDtypeStruct(shp, dt)
        # fold a per-field counter so no two fields share a stream
        # (tokens == labels correlation broke the loss fixture's entropy)
        nonlocal n_drawn
        k = jax.random.fold_in(base_key, n_drawn)
        n_drawn += 1
        if dt == jnp.int32:
            return jax.random.randint(k, shp, 0, maxval or cfg.vocab,
                                      dtype=dt)
        return jax.random.normal(k, shp, jnp.float32).astype(dt) * 0.02

    if shape.kind == "train":
        s_tok = s
        batch = {}
        axes = {}
        if cfg.frontend == "vision":
            s_img, s_tok = _split_vlm(s)
            batch["patch_embeds"] = arr((b, s_img, cfg.d_model), emb_dt)
            axes["patch_embeds"] = ("batch", None, None)
        if cfg.frontend == "audio":
            batch["src_embeds"] = arr((b, s, cfg.d_model), emb_dt)
            axes["src_embeds"] = ("batch", "act_seq", None)
        batch["tokens"] = arr((b, s_tok), tok_dt)
        batch["labels"] = arr((b, s_tok), tok_dt)   # loss on text positions
        axes["tokens"] = ("batch", "act_seq")
        axes["labels"] = ("batch", "act_seq")
        return batch, axes

    if shape.kind == "prefill":
        batch = {"tokens": arr((b, s), tok_dt)}
        axes = {"tokens": ("batch", "act_seq")}
        if cfg.frontend == "vision":
            s_img, s_tok = _split_vlm(s)
            batch = {"tokens": arr((b, s_tok), tok_dt),
                     "patch_embeds": arr((b, s_img, cfg.d_model), emb_dt)}
            axes = {"tokens": ("batch", "act_seq"),
                    "patch_embeds": ("batch", "act_seq", None)}
        if cfg.frontend == "audio":
            batch["src_embeds"] = arr((b, s, cfg.d_model), emb_dt)
            axes["src_embeds"] = ("batch", "act_seq", None)
        return batch, axes

    # decode: single token against a full cache of length s
    src = s if cfg.is_encdec else 0
    cache_sds = jax.eval_shape(
        functools.partial(T.init_cache, cfg, b, s, src_len=src))
    if concrete:
        cache = T.init_cache(cfg, b, s, src_len=src)
    else:
        cache = cache_sds
    batch = {"token": arr((b,), tok_dt),
             "pos": (jnp.full((b,), max(s - 1, 0), jnp.int32) if concrete
                     else jax.ShapeDtypeStruct((b,), jnp.int32)),
             "cache": cache}
    axes = {"token": ("cache_batch",), "pos": ("cache_batch",),
            "cache": cache_axes(cfg)}
    return batch, axes


# ---------------------------------------------------------------------------
# Bundle
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ModelBundle:
    cfg: ModelConfig
    skeleton: dict

    def init(self, key: jax.Array, dtype=jnp.float32):
        return init_params(self.skeleton, key, dtype)

    def abstract(self, dtype=jnp.bfloat16):
        return abstract_params(self.skeleton, dtype)

    def param_axes(self):
        return logical_axes(self.skeleton)

    @property
    def n_params(self) -> int:
        return param_count(self.skeleton)

    def train_loss(self, params, batch):
        return T.train_loss(params, self.cfg, batch)

    def forward(self, params, batch):
        return T.forward(params, self.cfg, batch)

    def prefill(self, params, batch):
        return T.prefill(params, self.cfg, batch)

    def decode_step(self, params, batch):
        return T.decode_step(params, self.cfg, batch)

    def chunk_step(self, params, batch):
        """Serving prefill chunk: batch = {tokens (B, C), pos, n_valid,
        cache} — see transformer.chunk_step."""
        return T.chunk_step(params, self.cfg, batch)

    def input_specs(self, shape: ShapeSpec, concrete: bool = False,
                    key=None):
        return make_inputs(self.cfg, shape, concrete, key)

    def step_fn(self, shape: ShapeSpec) -> Callable:
        if shape.kind == "train":
            return self.train_loss
        if shape.kind == "prefill":
            return self.prefill
        return self.decode_step


def _flatten_with_axes(cfg: ModelConfig, cache):
    """(flat leaves, flat logical-axes tuples, treedef) for a cache pytree."""
    axes = cache_axes(cfg)
    flat_c, treedef = jax.tree.flatten(cache)
    flat_a = treedef.flatten_up_to(axes)
    return flat_c, flat_a, treedef


def pad_cache(cfg: ModelConfig, cache, extra: int):
    """Grow every cache_seq dimension by `extra` zero slots (decode room)."""
    flat_c, flat_a, treedef = _flatten_with_axes(cfg, cache)
    out = []
    for c, a in zip(flat_c, flat_a):
        if isinstance(a, tuple) and "cache_seq" in a:
            widths = [(0, 0)] * c.ndim
            widths[a.index("cache_seq")] = (0, extra)
            c = jnp.pad(c, widths)
        out.append(c)
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Slot API (continuous-batching serving, repro.serve)
#
# A "slot cache" is an ordinary decode cache built with batch = n_slots:
# every leaf carries a cache_batch dimension indexing the slot.  Requests
# come and go by writing / zeroing ONE row of every leaf — all three ops
# are O(row), jit-traceable with a *traced* slot index, and leave the other
# slots' rows byte-identical (the differential suite in tests/test_serve.py
# pins that invariant).
# ---------------------------------------------------------------------------
def _slot_update(cfg: ModelConfig, cache, rows, slot, valid):
    """Write per-leaf `rows` (size-1 cache_batch dim) into slot `slot`.

    `valid` (bool scalar, traced ok) gates the write per leaf by re-writing
    the CURRENT row when False — a no-op admission costs one O(row)
    gather/scatter instead of an O(cache) select, which matters inside a
    donated decode step."""
    flat_c, flat_a, treedef = _flatten_with_axes(cfg, cache)
    out = []
    for c, a, new in zip(flat_c, flat_a, rows):
        bdim = a.index("cache_batch")
        cur = jax.lax.dynamic_index_in_dim(c, slot, axis=bdim,
                                           keepdims=True)
        row = jnp.where(valid, new.astype(c.dtype), cur)
        out.append(jax.lax.dynamic_update_index_in_dim(c, row, slot,
                                                       axis=bdim))
    return jax.tree.unflatten(treedef, out)


def write_slot(cfg: ModelConfig, cache, req_cache, slot,
               valid: bool | jax.Array = True):
    """Admit one request: copy `req_cache` (a batch-1 cache whose seq dims
    already match the slot cache) into slot `slot`.  `slot` and `valid` may
    be traced — serving folds admission into the jitted decode step."""
    rows, _, _ = _flatten_with_axes(cfg, req_cache)
    return _slot_update(cfg, cache, rows, slot, valid)


def evict_slot(cfg: ModelConfig, cache, slot,
               valid: bool | jax.Array = True):
    """Zero slot `slot` (freed capacity; decode masks it out regardless —
    eviction exists so leaked state can never alias a later admission)."""
    flat_c, flat_a, _ = _flatten_with_axes(cfg, cache)
    rows = []
    for c, a in zip(flat_c, flat_a):
        shape = list(c.shape)
        shape[a.index("cache_batch")] = 1
        rows.append(jnp.zeros(shape, c.dtype))
    return _slot_update(cfg, cache, rows, slot, valid)


def read_slot(cfg: ModelConfig, cache, slot):
    """Extract slot `slot` as a batch-1 cache (debug / tests / migration)."""
    flat_c, flat_a, treedef = _flatten_with_axes(cfg, cache)
    out = [jax.lax.dynamic_index_in_dim(c, slot, axis=a.index("cache_batch"),
                                        keepdims=True)
           for c, a in zip(flat_c, flat_a)]
    return jax.tree.unflatten(treedef, out)


def build_model(cfg: ModelConfig) -> ModelBundle:
    return ModelBundle(cfg=cfg, skeleton=T.model_def(cfg))
