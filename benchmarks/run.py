"""Benchmark aggregator: one entry per paper table/figure.

Prints ``name,seconds,derived`` CSV rows.  The heavyweight behavioural
benchmark (table4) runs in quick mode here; invoke it directly for the
full four-model version used in EXPERIMENTS.md.

    PYTHONPATH=src python -m benchmarks.run [--full]
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    rows = []

    def bench(name, fn):
        t0 = time.time()
        derived = fn()
        dt = time.time() - t0
        rows.append((name, dt, derived))
        print(f"\n>>> {name},{dt:.1f}s,{derived}\n", flush=True)

    from benchmarks import (fig7_array_dse, fig8_osa, fig9_power_breakdown,
                            table1_modes)

    def table1():
        r = table1_modes.run()
        return "%.1fx_ops_mixed_vs_analog" % (r["mixed"]["ops"]
                                              / r["analog"]["ops"])

    bench("table1_modes", table1)

    def fig7():
        r = fig7_array_dse.run()
        return "best=%s;vs_deap=%.1f%%;vs_4x4=%.1f%%" % (
            r["best"].label, r["reduction_vs_deap"] * 100,
            r["reduction_vs_compact"] * 100)

    bench("fig7_array_dse", fig7)

    def fig8():
        r = fig8_osa.run()
        return "osa=%.1f%%;osa_ode=%.1f%%" % (
            r["geomean_reduction_osa"] * 100,
            r["geomean_reduction_osa_ode"] * 100)

    bench("fig8_osa", fig8)
    bench("fig9_power_breakdown",
          lambda: "workloads=%d" % len(fig9_power_breakdown.run()))

    def table4():
        from benchmarks import table4_hybrid
        models = None if args.full else ["alexnet"]
        steps = 400 if args.full else 250
        res = table4_hybrid.run(models=models, steps=steps,
                                n_mc=3 if args.full else 2)
        return "hybrid_vs_ws=%+.1fpp" % (
            sum(r["accs"]["hybrid"] - r["accs"]["ws"]
                for r in res.values()) / len(res))

    bench("table4_hybrid" + ("" if args.full else "_quick"), table4)

    def roofline():
        from benchmarks import roofline as R
        rows_ = [d for r in R.load("results/dryrun", "single")
                 if (d := R.derive(r))]
        if not rows_:
            return "no_dryrun_records"
        dom = {}
        for d in rows_:
            dom[d["dominant"]] = dom.get(d["dominant"], 0) + 1
        return "cells=%d;%s" % (len(rows_), dom)

    bench("roofline_table", roofline)

    print("\n== summary ==")
    for name, dt, derived in rows:
        print(f"{name},{dt:.1f}s,{derived}")


if __name__ == "__main__":
    main()
