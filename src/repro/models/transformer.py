"""Model stacks: decoder-only / MoE / MLA / SSM / hybrid / enc-dec.

All stacks scan over layers (stacked params, one compiled body) with an
optional remat policy — this keeps HLO size and compile time flat in depth,
which matters for the 94-layer dry-run cells.  Heterogeneous-depth patterns
are handled without breaking the scan:

  * gemma3 5:1 local:global — same params every layer; per-layer window and
    rope-theta ride along the scan as (L,) meta arrays.
  * deepseek-v2 layer-0 dense FFN — one unrolled head layer + scanned body.
  * zamba2 — scan over groups of `shared_every` mamba layers, the SHARED
    attention block (one param set, closed over) applied once per group with
    a per-group KV cache; remainder mamba layers unrolled at the tail.

Step functions all take/return plain pytrees so jax.jit can shard them:

    train_loss(params, batch)                -> scalar loss
    prefill(params, batch)                   -> (last_logits, cache)
    decode_step(params, batch-with-cache)    -> (logits, cache)
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import rosa
from repro.distributed.sharding import (current_ctx, ep_param_specs, shard_act)
from repro.models import layers as L
from repro.models import mla as MLA
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.module import ParamDef


# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | mla_moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    vocab: int
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    qk_norm: bool = False
    rope_theta: float = 1e6
    # sliding-window pattern: layers with (i % pattern != pattern-1) are
    # local with `window`; pattern == 0 -> all layers full attention.
    window: int = 0
    window_pattern: int = 0
    rope_theta_local: float = 1e4
    moe: MOE.MoEConfig | None = None
    mla: MLA.MLAConfig | None = None
    first_dense_ff: int = 0
    ssm: SSM.SSMConfig | None = None
    shared_every: int = 0        # zamba2: shared attn after every k ssm layers
    n_enc_layers: int = 0        # encdec: encoder depth (n_layers = decoder)
    frontend: str = "none"       # none | vision | audio
    tie_embeddings: bool = False
    remat: str = "full"          # full | dots | none
    parallelism: str = "tp"      # tp | zero3 (train-time layout; §Perf A6)
    moe_ep: bool = False         # expert-parallel shard_map path
    rosa_mlp: bool = False       # route MLP projections through the ROSA
    #   optical MAC (8-bit OSA bit-serial emulation; Pallas kernel on TPU)
    cache_dtype: Any = jnp.bfloat16
    norm_eps: float = 1e-6
    uniform_decode: bool = True  # False -> continuous-batching serving:
    #   per-sequence ragged positions (scatter cache writes; repro.serve)

    @property
    def attn(self) -> L.AttnConfig:
        return L.AttnConfig(self.d_model, self.n_heads, self.n_kv_heads,
                            self.head_dim, self.qk_norm, self.rope_theta,
                            uniform_decode=self.uniform_decode)

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0


def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)


def stack_defs(skel, n: int):
    """Prepend a layer dimension of size n to every ParamDef in a skeleton."""
    return jax.tree.map(
        lambda d: ParamDef((n,) + d.shape, ("layers",) + d.axes, d.init,
                           d.scale),
        skel, is_leaf=lambda x: isinstance(x, ParamDef))


def layer_at(tree, i):
    """Leaf-wise `tree[i]`: one layer's params/cache out of a stacked tree."""
    return jax.tree.map(lambda a, idx=i: a[idx], tree)


# ---------------------------------------------------------------------------
# Per-layer meta arrays (window / rope theta patterns)
# ---------------------------------------------------------------------------
def layer_meta(cfg: ModelConfig) -> dict:
    li = jnp.arange(cfg.n_layers)
    if cfg.window_pattern > 0:
        is_global = (li % cfg.window_pattern) == (cfg.window_pattern - 1)
        window = jnp.where(is_global, 0, cfg.window)
        theta = jnp.where(is_global, cfg.rope_theta, cfg.rope_theta_local)
    else:
        window = jnp.zeros_like(li)
        theta = jnp.full((cfg.n_layers,), cfg.rope_theta)
    return {"window": window, "theta": theta.astype(jnp.float32),
            "idx": li}


# ---------------------------------------------------------------------------
# FFN dispatch (dense MLP vs MoE, EP-aware)
# ---------------------------------------------------------------------------
def _ffn_def(cfg: ModelConfig) -> dict:
    if cfg.moe is not None:
        return MOE.moe_def(cfg.moe)
    return L.mlp_def(cfg.d_model, cfg.d_ff)


def _ffn_apply(p: dict, cfg: ModelConfig, x: jax.Array,
               step=0) -> jax.Array:
    if cfg.moe is None:
        if cfg.rosa_mlp:
            # step = (traced) layer index: layers in a scanned stack
            # must fold independent noise keys (see mlp_apply).  An
            # installed engine context (rosa.engine_context — a compiled
            # rosa.Program installs its own) wins: serving pins a
            # fabricated chip + hybrid plan + ledger there.
            engine = rosa.ambient_engine()
            if engine is None:
                engine = rosa.Engine.from_config()
            return L.mlp_apply(p, x, engine=engine, step=step)
        return L.mlp_apply(p, x)
    ctx = current_ctx()
    if cfg.moe_ep and ctx is not None and ctx.mesh is not None:
        import math
        from repro.distributed.sharding import resolve_spec
        mesh = ctx.mesh
        x_spec = resolve_spec(x.shape, ("batch", None, None), ctx.rules, mesh)
        fsdp = tuple(a for a in (ctx.rules.get("embed") or ())
                     if a in mesh.shape)
        if fsdp and p["wi"].shape[1] % math.prod(
                mesh.shape[a] for a in fsdp) != 0:
            fsdp = ()
        # ZeRO-3 layout shards tokens over "model" too -> all-to-all EP
        bp = x_spec[0] if len(x_spec) else None
        batch_axes = set(bp if isinstance(bp, tuple) else (bp,))
        a2a = "model" in batch_axes
        fn = functools.partial(
            MOE.moe_ep_local, cfg=cfg.moe, model_axis="model",
            fsdp_axes=fsdp, a2a=a2a)
        specs = ep_param_specs(p, fsdp)
        from repro.distributed.sharding import shard_map_compat
        return shard_map_compat(
            lambda pl_, xl: fn(pl_, x_local=xl),
            mesh=mesh, in_specs=(specs, x_spec),
            out_specs=x_spec)(p, x)
    return MOE.moe_ref(p, cfg.moe, x)


# ---------------------------------------------------------------------------
# Decoder block (attn | mla | ssm) + FFN
# ---------------------------------------------------------------------------
def _block_def(cfg: ModelConfig, cross: bool = False) -> dict:
    d = cfg.d_model
    p = {"ln1": L.rmsnorm_def(d), "ln2": L.rmsnorm_def(d)}
    if cfg.family in ("dense", "moe", "encdec"):
        p["attn"] = L.attn_def(cfg.attn)
        p["ffn"] = _ffn_def(cfg)
    elif cfg.family == "mla_moe":
        p["attn"] = MLA.mla_def(cfg.mla)
        p["ffn"] = _ffn_def(cfg)
    elif cfg.family in ("ssm", "hybrid"):
        p = {"ln1": L.rmsnorm_def(d)}
        p["ssm"] = SSM.ssm_def(cfg.ssm)
    else:
        raise ValueError(cfg.family)
    if cross:
        p["ln_cross"] = L.rmsnorm_def(d)
        p["cross"] = L.attn_def(dataclasses.replace(
            cfg.attn, cross=True, causal=False))
    return p


def _block_fwd(p: dict, cfg: ModelConfig, x, positions, meta,
               memory=None, memory_pos=None):
    """Full-sequence block forward (train path, no cache)."""
    if "ssm" in p:
        return x + SSM.ssm_apply(p["ssm"], cfg.ssm,
                                 L.rmsnorm(p["ln1"], x, cfg.norm_eps))
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.family == "mla_moe":
        a = MLA.mla_apply(p["attn"], cfg.mla, h, positions)
    else:
        a = L.attn_apply(p["attn"], cfg.attn, h, positions,
                         window=meta["window"], theta=meta["theta"])
    x = x + shard_act(a, "batch", None, None)
    if "cross" in p:
        h = L.rmsnorm(p["ln_cross"], x, cfg.norm_eps)
        ccfg = dataclasses.replace(cfg.attn, cross=True, causal=False)
        x = x + L.attn_apply(p["cross"], ccfg, h, positions,
                             memory=memory, memory_pos=memory_pos)
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    return x + shard_act(_ffn_apply(p["ffn"], cfg, h, meta.get("idx", 0)),
                         "batch", None, None)


def _block_prefill(p: dict, cfg: ModelConfig, x, positions, meta):
    if "ssm" in p:
        h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
        # full-sequence ssm + final state capture for the decode cache
        y, cache = _ssm_prefill(p["ssm"], cfg.ssm, h)
        return x + y, cache
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.family == "mla_moe":
        a, cache = MLA.mla_prefill(p["attn"], cfg.mla, h, positions)
    else:
        a, cache = L.attn_prefill(p["attn"], cfg.attn, h, positions,
                                  window=meta["window"], theta=meta["theta"])
        cache = tuple(c.astype(cfg.cache_dtype) for c in cache)
    x = x + a
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    return x + _ffn_apply(p["ffn"], cfg, h, meta.get("idx", 0)), cache


def _block_decode(p: dict, cfg: ModelConfig, x, pos, meta, cache,
                  memory_pos=None):
    if "ssm" in p:
        h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
        y, cache = SSM.ssm_decode(p["ssm"], cfg.ssm, h, cache)
        return x + y, cache
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.family == "mla_moe":
        a, cache = MLA.mla_decode(p["attn"], cfg.mla, h, cache, pos)
    else:
        self_cache = cache["self"] if "cross" in p else cache
        a, self_cache = L.attn_decode(p["attn"], cfg.attn, h, self_cache, pos,
                                      window=meta["window"],
                                      theta=meta["theta"])
        if "cross" in p:
            cache = dict(cache, self=self_cache)
        else:
            cache = self_cache
    x = x + a
    if "cross" in p:
        h = L.rmsnorm(p["ln_cross"], x, cfg.norm_eps)
        ccfg = dataclasses.replace(cfg.attn, cross=True, causal=False)
        a, _ = L.attn_decode(p["cross"], ccfg, h, cache["cross"], pos,
                             memory_pos=memory_pos)
        x = x + a
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    return x + _ffn_apply(p["ffn"], cfg, h, meta.get("idx", 0)), cache


def _ssm_prefill(p: dict, scfg: SSM.SSMConfig, u: jax.Array):
    """Like ssm_apply but also returns the decode cache (conv + state)."""
    h, g = scfg.n_heads, scfg.n_groups
    x_pre = jnp.einsum("bld,dhp->blhp", u, p["w_x"])
    b_pre = jnp.einsum("bld,dgs->blgs", u, p["w_b"])
    c_pre = jnp.einsum("bld,dgs->blgs", u, p["w_c"])
    x = SSM._causal_conv(x_pre, p["conv_x"])
    b = SSM._causal_conv(b_pre, p["conv_b"])
    c = SSM._causal_conv(c_pre, p["conv_c"])
    z = jnp.einsum("bld,dhp->blhp", u, p["w_z"])
    dt, loga = SSM._decay(p, jnp.einsum("bld,dh->blh", u, p["w_dt"]))
    rep = h // g
    bb = jnp.repeat(b, rep, axis=2).astype(jnp.float32)
    cc = jnp.repeat(c, rep, axis=2).astype(jnp.float32)
    y, state = SSM.ssd_chunked(x.astype(jnp.float32) * dt[..., None], loga,
                               bb, cc, scfg.chunk)
    y = y + p["d_skip"][None, None, :, None] * x.astype(jnp.float32)
    y = y.astype(u.dtype) * jax.nn.silu(z)
    y = L.rmsnorm(p["gate_norm"].reshape(-1),
                  y.reshape(*y.shape[:2], -1)).reshape(y.shape)
    out = jnp.einsum("blhp,hpd->bld", y, p["w_out"])
    k = scfg.d_conv - 1
    cache = {"conv_x": x_pre[:, -k:], "conv_b": b_pre[:, -k:],
             "conv_c": c_pre[:, -k:], "state": state}
    return out, cache


# ---------------------------------------------------------------------------
# Whole-model skeletons
# ---------------------------------------------------------------------------
def model_def(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    skel: dict = {"embed": L.embed_def(cfg.vocab, d),
                  "final_norm": L.rmsnorm_def(d)}
    if not cfg.tie_embeddings:
        skel["unembed"] = L.unembed_def(d, cfg.vocab)

    if cfg.family == "hybrid":
        k = cfg.shared_every
        n_groups, rem = divmod(cfg.n_layers, k)
        skel["groups"] = stack_defs(stack_defs(_block_def(cfg), k), n_groups)
        if rem:
            skel["tail"] = stack_defs(_block_def(cfg), rem)
        acfg = cfg.attn
        skel["shared_attn"] = {"ln": L.rmsnorm_def(d),
                               "attn": L.attn_def(acfg),
                               "ln2": L.rmsnorm_def(d),
                               "ffn": L.mlp_def(d, cfg.d_ff)}
    elif cfg.family == "encdec":
        enc_cfg = dataclasses.replace(cfg, family="dense")
        enc_block = {"ln1": L.rmsnorm_def(d), "ln2": L.rmsnorm_def(d),
                     "attn": L.attn_def(dataclasses.replace(
                         enc_cfg.attn, causal=False)),
                     "ffn": L.mlp_def(d, cfg.d_ff)}
        skel["encoder"] = {"layers": stack_defs(enc_block, cfg.n_enc_layers),
                           "norm": L.rmsnorm_def(d)}
        skel["layers"] = stack_defs(
            _block_def(dataclasses.replace(cfg, family="dense"), cross=True),
            cfg.n_layers)
    else:
        n_scanned = cfg.n_layers - (1 if cfg.first_dense_ff else 0)
        if cfg.first_dense_ff:
            dense0 = dataclasses.replace(cfg, moe=None,
                                         d_ff=cfg.first_dense_ff)
            skel["layer0"] = _block_def(dense0)
        skel["layers"] = stack_defs(_block_def(cfg), n_scanned)
    return skel


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------
def _embed_in(params, cfg: ModelConfig, batch: dict):
    """Token (+ modality-frontend) embedding. Returns (x, positions)."""
    tokens = batch["tokens"]
    x = L.embed_apply(params["embed"], tokens)
    if cfg.frontend == "vision":
        # precomputed patch embeddings prepended to the text tokens
        x = jnp.concatenate([batch["patch_embeds"].astype(x.dtype), x], axis=1)
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    return shard_act(x, "batch", None, None), positions


def _scan_fwd(params, cfg: ModelConfig, x, positions, meta,
              memory=None, memory_pos=None):
    def body(carry, xs):
        p_l, m_l = xs
        return _block_fwd(p_l, cfg, carry, positions, m_l,
                          memory, memory_pos), None
    x, _ = jax.lax.scan(_remat(cfg, body), x, (params, meta))
    return x


def forward(params, cfg: ModelConfig, batch: dict) -> jax.Array:
    """Full-sequence forward to final hidden states (B, S, D)."""
    x, positions = _embed_in(params, cfg, batch)
    meta = layer_meta(cfg)
    no_meta = {"window": jnp.zeros((), jnp.int32),
               "theta": jnp.float32(cfg.rope_theta)}

    if cfg.family == "hybrid":
        x = _hybrid_fwd(params, cfg, x, positions)
    elif cfg.family == "encdec":
        mem = _encode(params, cfg, batch)
        mem_pos = jnp.broadcast_to(jnp.arange(mem.shape[1])[None, :],
                                   mem.shape[:2])
        x = _scan_fwd(params["layers"], cfg, x, positions,
                      _stub_meta(cfg, cfg.n_layers), memory=mem,
                      memory_pos=mem_pos)
    else:
        if cfg.first_dense_ff:
            dense0 = dataclasses.replace(cfg, moe=None,
                                         d_ff=cfg.first_dense_ff)
            x = _block_fwd(params["layer0"], dense0, x, positions, no_meta)
            meta = jax.tree.map(lambda a: a[1:], meta)
        x = _scan_fwd(params["layers"], cfg, x, positions, meta)
    return L.rmsnorm(params["final_norm"], x, cfg.norm_eps)


def _stub_meta(cfg: ModelConfig, n: int) -> dict:
    return {"window": jnp.zeros((n,), jnp.int32),
            "theta": jnp.full((n,), cfg.rope_theta, jnp.float32)}


def _encode(params, cfg: ModelConfig, batch: dict) -> jax.Array:
    """Audio/text encoder over precomputed source embeddings."""
    # run the encoder in the parameter dtype regardless of the input's
    mem = batch["src_embeds"].astype(params["encoder"]["norm"].dtype)
    b, s = mem.shape[:2]
    pos = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    enc_cfg = dataclasses.replace(cfg, family="dense")
    acfg = dataclasses.replace(enc_cfg.attn, causal=False)

    def body(carry, p_l):
        h = L.rmsnorm(p_l["ln1"], carry, cfg.norm_eps)
        carry = carry + L.attn_apply(p_l["attn"], acfg, h, pos)
        h = L.rmsnorm(p_l["ln2"], carry, cfg.norm_eps)
        return carry + L.mlp_apply(p_l["ffn"], h), None

    mem, _ = jax.lax.scan(_remat(cfg, body), mem,
                          params["encoder"]["layers"])
    return L.rmsnorm(params["encoder"]["norm"], mem, cfg.norm_eps)


def _hybrid_fwd(params, cfg: ModelConfig, x, positions):
    """zamba2: groups of `shared_every` ssm layers + shared attn block."""
    shared = params["shared_attn"]

    def shared_apply(x):
        h = L.rmsnorm(shared["ln"], x, cfg.norm_eps)
        x = x + L.attn_apply(shared["attn"], cfg.attn, h, positions)
        h = L.rmsnorm(shared["ln2"], x, cfg.norm_eps)
        return x + L.mlp_apply(shared["ffn"], h)

    def group_body(carry, p_g):
        for i in range(cfg.shared_every):
            p_l = layer_at(p_g, i)
            carry = carry + SSM.ssm_apply(
                p_l["ssm"], cfg.ssm, L.rmsnorm(p_l["ln1"], carry,
                                               cfg.norm_eps))
        return shared_apply(carry), None

    x, _ = jax.lax.scan(_remat(cfg, group_body), x, params["groups"])
    if "tail" in params:
        rem = params["tail"]["ln1"].shape[0]
        for i in range(rem):
            p_l = layer_at(params["tail"], i)
            x = x + SSM.ssm_apply(p_l["ssm"], cfg.ssm,
                                  L.rmsnorm(p_l["ln1"], x, cfg.norm_eps))
    return x


def logits_of(params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        out = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        out = L.unembed_apply(params["unembed"], x)
    return shard_act(out, "batch", None, "vocab")


def train_loss(params, cfg: ModelConfig, batch: dict) -> jax.Array:
    x = forward(params, cfg, batch)
    if cfg.frontend == "vision":
        x = x[:, batch["patch_embeds"].shape[1]:]     # loss on text positions
    logits = logits_of(params, cfg, x)
    return L.softmax_xent(logits, batch["labels"], batch.get("mask"))


# ---------------------------------------------------------------------------
# Serving: prefill + single-token decode with caches
# ---------------------------------------------------------------------------
def prefill(params, cfg: ModelConfig, batch: dict):
    """Run the prompt, return (last-token logits (B, V), cache)."""
    x, positions = _embed_in(params, cfg, batch)
    meta = layer_meta(cfg)
    cache: dict = {}

    if cfg.family == "hybrid":
        x, cache = _hybrid_prefill(params, cfg, x, positions)
    elif cfg.family == "encdec":
        mem = _encode(params, cfg, batch)
        mem_pos = jnp.broadcast_to(jnp.arange(mem.shape[1])[None, :],
                                   mem.shape[:2])

        def body(carry, xs):
            p_l, m_l = xs
            h = L.rmsnorm(p_l["ln1"], carry, cfg.norm_eps)
            a, kv = L.attn_prefill(p_l["attn"], cfg.attn, h, positions)
            carry = carry + a
            h = L.rmsnorm(p_l["ln_cross"], carry, cfg.norm_eps)
            ccfg = dataclasses.replace(cfg.attn, cross=True, causal=False)
            # static cross-attention K/V from the encoder memory
            ck = jnp.einsum("bsd,dhk->bshk", mem, p_l["cross"]["wk"])
            cv = jnp.einsum("bsd,dhk->bshk", mem, p_l["cross"]["wv"])
            carry = carry + L.attn_apply(p_l["cross"], ccfg, h, positions,
                                         memory=mem, memory_pos=mem_pos)
            h = L.rmsnorm(p_l["ln2"], carry, cfg.norm_eps)
            carry = carry + L.mlp_apply(p_l["ffn"], h)
            dt = cfg.cache_dtype
            return carry, {"self": tuple(c.astype(dt) for c in kv),
                           "cross": (ck.astype(dt), cv.astype(dt))}

        x, lcache = jax.lax.scan(_remat(cfg, body), x,
                                 (params["layers"], _stub_meta(cfg, cfg.n_layers)))
        cache = {"layers": lcache, "memory_pos": mem_pos}
    else:
        if cfg.first_dense_ff:
            dense0 = dataclasses.replace(cfg, moe=None,
                                         d_ff=cfg.first_dense_ff)
            no_meta = {"window": jnp.zeros((), jnp.int32),
                       "theta": jnp.float32(cfg.rope_theta)}
            x, cache["layer0"] = _block_prefill(params["layer0"], dense0, x,
                                                positions, no_meta)
            meta = jax.tree.map(lambda a: a[1:], meta)

        def body(carry, xs):
            p_l, m_l = xs
            carry, kv = _block_prefill(p_l, cfg, carry, positions, m_l)
            return carry, kv

        x, cache["layers"] = jax.lax.scan(_remat(cfg, body), x,
                                          (params["layers"], meta))

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = logits_of(params, cfg, x[:, -1:])[:, 0]
    cache["pos"] = jnp.full((x.shape[0],), x.shape[1], jnp.int32)
    return logits, cache


def _hybrid_prefill(params, cfg: ModelConfig, x, positions):
    shared = params["shared_attn"]

    def group_body(carry, p_g):
        ssm_caches = []
        for i in range(cfg.shared_every):
            p_l = layer_at(p_g, i)
            y, c = _ssm_prefill(p_l["ssm"], cfg.ssm,
                                L.rmsnorm(p_l["ln1"], carry, cfg.norm_eps))
            carry = carry + y
            ssm_caches.append(c)
        h = L.rmsnorm(shared["ln"], carry, cfg.norm_eps)
        a, kv = L.attn_prefill(shared["attn"], cfg.attn, h, positions)
        carry = carry + a
        h = L.rmsnorm(shared["ln2"], carry, cfg.norm_eps)
        carry = carry + L.mlp_apply(shared["ffn"], h)
        stacked = jax.tree.map(lambda *a: jnp.stack(a), *ssm_caches)
        dt = cfg.cache_dtype
        return carry, {"ssm": stacked,
                       "shared": tuple(c.astype(dt) for c in kv)}

    x, gcache = jax.lax.scan(_remat(cfg, group_body), x, params["groups"])
    cache = {"groups": gcache}
    if "tail" in params:
        tails = []
        rem = params["tail"]["ln1"].shape[0]
        for i in range(rem):
            p_l = layer_at(params["tail"], i)
            y, c = _ssm_prefill(p_l["ssm"], cfg.ssm,
                                L.rmsnorm(p_l["ln1"], x, cfg.norm_eps))
            x = x + y
            tails.append(c)
        cache["tail"] = jax.tree.map(lambda *a: jnp.stack(a), *tails)
    return x, cache


def decode_step(params, cfg: ModelConfig, batch: dict):
    """One token: batch = {token (B,), pos (B,), cache}.

    Returns (logits (B, V), new_cache)."""
    token, pos, cache = batch["token"], batch["pos"], batch["cache"]
    x = L.embed_apply(params["embed"], token[:, None])
    x = shard_act(x, "batch", None, None)
    meta = layer_meta(cfg)

    if cfg.family == "hybrid":
        x, new_cache = _hybrid_decode(params, cfg, x, pos, cache)
    elif cfg.family == "encdec":
        def body(carry, xs):
            p_l, m_l, c_l = xs
            carry, c_l = _block_decode(p_l, cfg, carry, pos, m_l, c_l,
                                       memory_pos=cache["memory_pos"])
            return carry, c_l
        x, lcache = jax.lax.scan(body, x, (params["layers"],
                                           _stub_meta(cfg, cfg.n_layers),
                                           cache["layers"]))
        new_cache = {"layers": lcache, "memory_pos": cache["memory_pos"]}
    else:
        new_cache = {}
        if cfg.first_dense_ff:
            dense0 = dataclasses.replace(cfg, moe=None,
                                         d_ff=cfg.first_dense_ff)
            no_meta = {"window": jnp.zeros((), jnp.int32),
                       "theta": jnp.float32(cfg.rope_theta)}
            x, new_cache["layer0"] = _block_decode(
                params["layer0"], dense0, x, pos, no_meta, cache["layer0"])
            meta = jax.tree.map(lambda a: a[1:], meta)

        def body(carry, xs):
            p_l, m_l, c_l = xs
            carry, c_l = _block_decode(p_l, cfg, carry, pos, m_l, c_l)
            return carry, c_l

        x, new_cache["layers"] = jax.lax.scan(
            body, x, (params["layers"], meta, cache["layers"]))

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = logits_of(params, cfg, x)[:, 0]
    new_cache["pos"] = pos + 1
    if "memory_pos" in cache and "memory_pos" not in new_cache:
        new_cache["memory_pos"] = cache["memory_pos"]
    return logits, new_cache


def chunk_step(params, cfg: ModelConfig, batch: dict):
    """Prefill one chunk of C tokens against a running per-sequence cache.

    batch = {tokens (B, C), pos (B,), n_valid (B,), cache}; positions
    pos..pos+C-1 are written into the cache, `pos` advances by `n_valid`
    (the real token count — the chunk tail may be padding), and the
    returned logits (B, V) are read at local index n_valid-1, i.e. at the
    last REAL token.  Serving uses this to stream long prompts through the
    decode path chunk-by-chunk (repro.serve) so a long prefill never
    stalls running decodes for more than one chunk's latency.

    Supported for attention-cache families (dense/moe/mla_moe/encdec);
    ssm/hybrid prompts must prefill whole (their scan state has no
    positional indexing to chunk against).
    """
    if cfg.family in ("ssm", "hybrid"):
        raise ValueError(f"chunked prefill unsupported for {cfg.family}: "
                         "state-space caches admit no positional chunking")
    tokens, n_valid = batch["tokens"], batch["n_valid"]
    cache = batch["cache"]
    # pos defaults to the cache's own cursor so callers can donate the
    # cache without aliasing its pos buffer into a second operand
    pos = batch.get("pos", cache["pos"])
    x = L.embed_apply(params["embed"], tokens)
    x = shard_act(x, "batch", None, None)
    meta = layer_meta(cfg)

    if cfg.family == "encdec":
        def body(carry, xs):
            p_l, m_l, c_l = xs
            carry, c_l = _block_decode(p_l, cfg, carry, pos, m_l, c_l,
                                       memory_pos=cache["memory_pos"])
            return carry, c_l
        x, lcache = jax.lax.scan(body, x, (params["layers"],
                                           _stub_meta(cfg, cfg.n_layers),
                                           cache["layers"]))
        new_cache = {"layers": lcache, "memory_pos": cache["memory_pos"]}
    else:
        new_cache = {}
        if cfg.first_dense_ff:
            dense0 = dataclasses.replace(cfg, moe=None,
                                         d_ff=cfg.first_dense_ff)
            no_meta = {"window": jnp.zeros((), jnp.int32),
                       "theta": jnp.float32(cfg.rope_theta)}
            x, new_cache["layer0"] = _block_decode(
                params["layer0"], dense0, x, pos, no_meta, cache["layer0"])
            meta = jax.tree.map(lambda a: a[1:], meta)

        def body(carry, xs):
            p_l, m_l, c_l = xs
            carry, c_l = _block_decode(p_l, cfg, carry, pos, m_l, c_l)
            return carry, c_l

        x, new_cache["layers"] = jax.lax.scan(
            body, x, (params["layers"], meta, cache["layers"]))

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    # unembed ONLY the last real token of each row (C-fold cheaper than a
    # full-chunk logits_of, and identical numerics at that position)
    idx = jnp.maximum(n_valid - 1, 0)[:, None, None]
    x_last = jnp.take_along_axis(x, jnp.broadcast_to(
        idx, (x.shape[0], 1, x.shape[2])), axis=1)
    logits = logits_of(params, cfg, x_last)[:, 0]
    new_cache["pos"] = pos + n_valid
    if "memory_pos" in cache and "memory_pos" not in new_cache:
        new_cache["memory_pos"] = cache["memory_pos"]
    return logits, new_cache


def _hybrid_decode(params, cfg: ModelConfig, x, pos, cache):
    shared = params["shared_attn"]

    def group_body(carry, xs):
        p_g, c_g = xs
        ssm_new = []
        for i in range(cfg.shared_every):
            p_l = layer_at(p_g, i)
            c_l = layer_at(c_g["ssm"], i)
            h = L.rmsnorm(p_l["ln1"], carry, cfg.norm_eps)
            y, c_l = SSM.ssm_decode(p_l["ssm"], cfg.ssm, h, c_l)
            carry = carry + y
            ssm_new.append(c_l)
        h = L.rmsnorm(shared["ln"], carry, cfg.norm_eps)
        a, kv = L.attn_decode(shared["attn"], cfg.attn, h, c_g["shared"], pos)
        carry = carry + a
        h = L.rmsnorm(shared["ln2"], carry, cfg.norm_eps)
        carry = carry + L.mlp_apply(shared["ffn"], h)
        return carry, {"ssm": jax.tree.map(lambda *a: jnp.stack(a), *ssm_new),
                       "shared": kv}

    x, gcache = jax.lax.scan(group_body, x, (params["groups"],
                                             cache["groups"]))
    new_cache = {"groups": gcache}
    if "tail" in params:
        rem = params["tail"]["ln1"].shape[0]
        tails = []
        for i in range(rem):
            p_l = layer_at(params["tail"], i)
            c_l = layer_at(cache["tail"], i)
            h = L.rmsnorm(p_l["ln1"], x, cfg.norm_eps)
            y, c_l = SSM.ssm_decode(p_l["ssm"], cfg.ssm, h, c_l)
            x = x + y
            tails.append(c_l)
        new_cache["tail"] = jax.tree.map(lambda *a: jnp.stack(a), *tails)
    return x, new_cache


# ---------------------------------------------------------------------------
# Fresh decode caches (zeros; use jax.eval_shape over this for specs)
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               src_len: int = 0) -> dict:
    dt = cfg.cache_dtype
    kv = lambda n, s: (jnp.zeros((n, batch, s, cfg.n_kv_heads, cfg.head_dim),
                                 dt),
                       jnp.zeros((n, batch, s, cfg.n_kv_heads, cfg.head_dim),
                                 dt))

    def ssm_stack(n):
        c = SSM.ssm_cache_def(cfg.ssm, batch)
        return jax.tree.map(lambda a: jnp.zeros((n,) + a.shape, a.dtype), c)

    if cfg.family == "hybrid":
        k = cfg.shared_every
        n_groups, rem = divmod(cfg.n_layers, k)
        sk, sv = kv(n_groups, max_len)
        cache = {"groups": {
            "ssm": jax.tree.map(
                lambda a: jnp.zeros((n_groups, k) + a.shape[1:], a.dtype),
                ssm_stack(k)),
            "shared": (sk, sv)}}
        if rem:
            cache["tail"] = ssm_stack(rem)
    elif cfg.family == "encdec":
        sk, sv = kv(cfg.n_layers, max_len)
        ck, cv = kv(cfg.n_layers, src_len or max_len)
        cache = {"layers": {"self": (sk, sv), "cross": (ck, cv)},
                 "memory_pos": jnp.broadcast_to(
                     jnp.arange(src_len or max_len)[None, :],
                     (batch, src_len or max_len))}
    elif cfg.family == "ssm":
        cache = {"layers": ssm_stack(cfg.n_layers)}
    elif cfg.family == "mla_moe":
        n = cfg.n_layers - (1 if cfg.first_dense_ff else 0)
        mk = lambda lead: (
            jnp.zeros(lead + (batch, max_len, cfg.mla.kv_lora), dt),
            jnp.zeros(lead + (batch, max_len, cfg.mla.qk_rope), dt))
        cache = {"layers": mk((n,))}
        if cfg.first_dense_ff:
            cache["layer0"] = mk(())
    else:
        n = cfg.n_layers
        cache = {"layers": kv(n, max_len)}
    cache["pos"] = jnp.zeros((batch,), jnp.int32)
    return cache
