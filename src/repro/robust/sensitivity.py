"""Vectorized perturb-one-layer sensitivity profiling (paper Fig. 6).

The serial protocol (`training.cnn_train.layer_noise_profile`) re-jits one
forward per (layer, mapping, MC draw): O(2·L·n_mc) compilations and
evaluations.  Here "which single layer runs the noisy analog path" becomes
a *traced* one-hot gate vector blended inside `rosa.backends`, so ONE
jitted call per mapping evaluates the whole (chips x layers) grid:

    accs[c, l] = accuracy with ONLY layer l analog-noisy on chip c

Degradations are Monte-Carlo averages over the chip ensemble (static
variation + per-shot noise), and feed `mapping.LayerProfile.d_is/d_ws`
directly — the accuracy-aware hybrid search needs no per-model callback
plumbing anymore.  Models without labels (LM stacks in the zoo) profile on
clean-logit agreement instead, through the same code path.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import rosa
from repro.core import energy as E
from repro.core import mapping as M
from repro.core import mrr
from repro.core.constants import Mapping, OPEConfig
from repro.robust import variation as V
from repro.robust.ensemble import (ApplyFn, chunk_eval_set,
                                   chunked_argmax_preds, clean_reference,
                                   cnn_apply_fn, cnn_eval_set,
                                   make_plan_eval)

_D_CLIP = 0.0   # degradations are reported as max(clean - acc, 0), like
#                 the serial profiler


def degradation_matrix(apply_fn: ApplyFn, params, x, y,
                       layer_names: Sequence[str],
                       base_cfg: rosa.RosaConfig,
                       ensemble: V.Chip, key: jax.Array, *,
                       noise: mrr.NoiseModel = mrr.PAPER_NOISE,
                       mappings: Sequence[Mapping] = (Mapping.IS, Mapping.WS),
                       eval_batch: int = 128,
                       layers: Sequence[str] | None = None,
                       evaluator=None) -> dict[str, dict[str, float]]:
    """{layer: {mapping.value: degradation_pp}} over the chip ensemble.

    ONE compiled program covers the whole (mappings x chips x layers) grid:
    both "which single layer runs the analog path" (a one-hot gate vector)
    and "which mapping orientation" (`rosa_matmul`'s ``mgate``) are traced
    arguments of a single gated plan evaluator (`ensemble.make_plan_eval`),
    so every grid cell re-dispatches the same executable — no per-cell (or
    per-mapping) recompilation, and a shared clean-reference forward.
    (The shared clean reference requires the clean forward to be
    mapping-independent, which holds whenever ``act_per_vector`` is off —
    the digital paths of IS and WS are then identical.)

    ``layers`` restricts scoring to a subset of columns (the incremental
    re-score path — see `refresh_degradation_matrix`); the returned dict
    contains only the scored layers.  `y=None` scores clean-logit
    agreement (label-free profiling).  ``evaluator`` accepts a pre-built
    gated evaluator (same layer names, chip count and eval-set shape) so
    callers like `cli.run_smoke` share one compile across the matrix, the
    plan search and the final plan evaluations.
    """
    names = list(layer_names)
    scored = names if layers is None else [n for n in names
                                           if n in set(layers)]
    n_chips = V.ensemble_size(ensemble)
    keys = jax.random.split(key, n_chips)
    if evaluator is None:
        cfg = dataclasses.replace(base_cfg, mapping=Mapping.WS, noise=noise)
        engine = rosa.Engine(rosa.ExecutionPlan.build(cfg, None, names))
        evaluator = make_plan_eval(apply_fn, engine, names,
                                   eval_batch=eval_batch, gated=True)

    eye = np.eye(len(names), dtype=np.float32)
    out: dict[str, dict[str, float]] = {n: {} for n in scored}
    for mp in mappings:
        sel = jnp.full(len(names), 0.0 if mp is Mapping.WS else 1.0,
                       dtype=jnp.float32)
        for n in scored:
            g = jnp.asarray(eye[names.index(n)])
            accs, _, clean_acc = evaluator(params, x, y, ensemble, keys,
                                           sel, g)
            out[n][mp.value] = max(
                float(clean_acc) - float(np.asarray(accs).mean()), _D_CLIP)
    return out


def refresh_degradation_matrix(prev: dict[str, dict[str, float]],
                               changed_layers: Sequence[str],
                               apply_fn: ApplyFn, params, x, y,
                               layer_names: Sequence[str],
                               base_cfg: rosa.RosaConfig,
                               ensemble: V.Chip, key: jax.Array,
                               **kwargs) -> dict[str, dict[str, float]]:
    """Incrementally re-score ONLY `changed_layers`, reusing `prev` rows.

    Because exactly one layer runs the analog path per one-hot evaluation,
    a layer's degradation row is independent of every other layer's
    mapping gate — so after a gate flip (or a new layer appearing in the
    trace) only the affected columns need re-measuring.  The result equals
    a full `degradation_matrix` over the union of layers, bit-for-bit,
    when called with the same ensemble and key (tested).
    """
    fresh = degradation_matrix(apply_fn, params, x, y, layer_names,
                               base_cfg, ensemble, key,
                               layers=changed_layers, **kwargs)
    out = {n: dict(v) for n, v in prev.items()}
    out.update(fresh)
    return out


def plan_search(apply_fn: ApplyFn, params, x, y,
                layer_names: Sequence[str],
                base_cfg: rosa.RosaConfig,
                ensemble: V.Chip, key: jax.Array,
                candidates: np.ndarray, *,
                noise: mrr.NoiseModel = mrr.PAPER_NOISE,
                eval_batch: int = 64, evaluator=None) -> np.ndarray:
    """MC-evaluate a whole batch of hybrid-plan candidates through ONE
    compiled program.

    `candidates` is a (P, L) binary matrix (row p, column l: layer l runs
    IS when 1, WS when 0).  Each layer's WS/IS orientation is superposed
    behind a traced mapping gate (`rosa_matmul`'s `mgate`), so every plan
    row re-dispatches the same executable — P plans x n_chips ensemble
    forwards, identical PRNG draws across plans.  Returns the (P,)
    ensemble-mean accuracies [%]; `y=None` scores clean-logit agreement
    (label-free zoo workloads).  ``evaluator`` accepts a pre-built gated
    plan evaluator to share its compile (see `degradation_matrix`).
    """
    names = list(layer_names)
    n_chips = V.ensemble_size(ensemble)
    keys = jax.random.split(key, n_chips)
    if evaluator is None:
        cfg = dataclasses.replace(base_cfg, mapping=Mapping.WS, noise=noise)
        engine = rosa.Engine(rosa.ExecutionPlan.build(cfg, None, names))
        evaluator = make_plan_eval(apply_fn, engine, names,
                                   eval_batch=eval_batch, gated=True)
    ones = jnp.ones(len(names), dtype=jnp.float32)
    out = []
    for row in np.asarray(candidates, dtype=np.float32):
        accs, _, _ = evaluator(params, x, y, ensemble, keys,
                               jnp.asarray(row), ones)
        out.append(float(np.asarray(accs).mean()))
    return np.asarray(out)


def searched_hybrid_plan(profiles: Sequence[M.LayerProfile],
                         apply_fn: ApplyFn, params, x, y,
                         base_cfg: rosa.RosaConfig,
                         ensemble: V.Chip, key: jax.Array, *,
                         noise: mrr.NoiseModel = mrr.PAPER_NOISE,
                         max_extra_pp: float = 0.5,
                         max_candidates: int = 6,
                         eval_batch: int = 64, evaluator=None
                         ) -> tuple[dict[str, Mapping], dict]:
    """Accuracy-verified hybrid search: profile-guided candidate ordering,
    MC-verified in one vectorized call.

    Single-layer degradations under-estimate full-plan cost (noise
    compounds across layers), so instead of trusting the profile the
    search MC-evaluates nested IS-prefix plans — always including the pure
    WS row — over the chip ensemble and keeps the most IS-aggressive plan
    that attains the best measured accuracy.  By construction the result
    matches or beats pure WS under the search keys (Table-4 direction).
    """
    names = [p.name for p in profiles]
    by_name = {p.name: p for p in profiles}
    # IS-flip attractiveness: robustness gain first, then EDP leverage
    eligible = [p.name for p in profiles
                if p.d_is <= p.d_ws + max_extra_pp]
    order = sorted(eligible,
                   key=lambda n: (by_name[n].d_is - by_name[n].d_ws)
                   + 0.5 * np.log(max(by_name[n].e_is, 1e-30)
                                  / max(by_name[n].e_ws, 1e-30)))
    order = order[:max_candidates]
    cand = np.zeros((len(order) + 1, len(names)), dtype=np.float32)
    for k, layer in enumerate(order):
        cand[k + 1:, names.index(layer)] = 1.0

    accs = plan_search(apply_fn, params, x, y, names, base_cfg, ensemble,
                       key, cand, noise=noise, eval_batch=eval_batch,
                       evaluator=evaluator)
    best = accs.max()
    # most IS-aggressive among the exact-best rows (EDP tie-break)
    p_star = int(max(np.flatnonzero(accs >= best)))
    plan = {layer: Mapping.IS for layer in order[:p_star]}
    info = {"order": order, "accs": accs.tolist(),
            "ws_acc": float(accs[0]), "chosen_acc": float(accs[p_star]),
            "n_is": p_star}
    return plan, info


def accuracy_guarded_plan(profiles: Sequence[M.LayerProfile],
                          max_extra_pp: float = 0.5
                          ) -> dict[str, Mapping]:
    """Accuracy-aware hybrid plan: the balanced-metric argmin
    (`mapping.choose_mapping`), vetoed whenever its degradation exceeds the
    layer's best mapping by more than `max_extra_pp` — then the more robust
    mapping wins.  Under Monte-Carlo degradations with strong static
    variation the raw paper metric can trade tens of pp for EDP (its alpha
    term grows only logarithmically); the guard keeps the Table-4 direction
    (hybrid accuracy >= WS) while still harvesting EDP wherever it is
    accuracy-free.
    """
    plan: dict[str, Mapping] = {}
    for p in profiles:
        m = M.choose_mapping(p)
        if p.d(m) > min(p.d_is, p.d_ws) + max_extra_pp:
            m = Mapping.IS if p.d_is < p.d_ws else Mapping.WS
        plan[p.name] = m
    return plan


def profile_layers_mc(layers: Sequence[E.LayerShape], ope: OPEConfig,
                      degradation: dict[str, dict[str, float]], *,
                      batch: int = 1, **kwargs) -> list[M.LayerProfile]:
    """Join a Monte-Carlo degradation matrix with the vectorized EDP model
    into `mapping.LayerProfile`s — drop-in input for `hybrid_plan`.
    """
    return M.profile_layers_fast(
        layers, ope,
        degradation_fn=M.degradation_fn_from_matrix(degradation),
        batch=batch, **kwargs)


# ---------------------------------------------------------------------------
# CNN front-end
# ---------------------------------------------------------------------------
def cnn_degradation_matrix(params, model: str, *,
                           n_chips: int = 16,
                           key: jax.Array | None = None,
                           noise: mrr.NoiseModel = mrr.PAPER_NOISE,
                           var_model: V.VariationModel = V.PAPER_VARIATION,
                           ensemble: V.Chip | None = None,
                           n_eval: int = 256,
                           eval_batch: int = 128,
                           antithetic: bool = False,
                           layers: Sequence[str] | None = None,
                           evaluator=None) -> dict[str, dict[str, float]]:
    """Degradation matrix of a lite CNN over a chip ensemble.

    The ensemble is freshly sampled (optionally with antithetic mirrored
    pairs) unless one is passed in; ``layers`` restricts the scoring to a
    column subset (incremental re-score); ``evaluator`` shares a pre-built
    gated plan evaluator's compile (`ensemble.make_plan_eval`).
    """
    from repro.models.cnn import LITE_MODELS
    from repro.training.cnn_train import QAT_CFG

    key = key if key is not None else jax.random.PRNGKey(42)
    k_ens, k_mc = jax.random.split(key)
    names = [s.name for s in LITE_MODELS[model]]
    if ensemble is None:
        ensemble = V.sample_ensemble(k_ens, n_chips,
                                     V.cnn_lane_dims(model), var_model,
                                     antithetic=antithetic)
    x, y = cnn_eval_set(n_eval)
    return degradation_matrix(cnn_apply_fn(model), params, x, y, names,
                              QAT_CFG, ensemble, k_mc, noise=noise,
                              eval_batch=eval_batch, layers=layers,
                              evaluator=evaluator)


def params_digest(params) -> str:
    """Deterministic content hash of a parameter pytree.

    Degradation matrices depend on the trained weights, so the weights'
    digest is part of the PlanCache matrix key — retraining invalidates
    cached matrices without any manual versioning.
    """
    import hashlib

    from jax import tree_util

    h = hashlib.sha256()
    leaves = tree_util.tree_flatten_with_path(params)[0]
    for path, leaf in sorted(leaves, key=lambda e: str(e[0])):
        h.update(str(path).encode())
        arr = np.asarray(leaf)
        h.update(str((arr.dtype.str, arr.shape)).encode())
        h.update(arr.tobytes())
    return h.hexdigest()


def cnn_degradation_source(params, model: str, *,
                           n_chips: int = 4,
                           noise: mrr.NoiseModel = mrr.PAPER_NOISE,
                           var_model: V.VariationModel = V.PAPER_VARIATION,
                           n_eval: int = 128, eval_batch: int = 64,
                           antithetic: bool = True,
                           seed: int = 42) -> "rosa.DegradationSource":
    """A cacheable degradation-matrix provider for `rosa.compile`.

    Bundles the measurement callable (the shared-forward
    `cnn_degradation_matrix`, restricted to whichever layers the cache is
    missing) with a JSON-able ``spec`` identifying everything the numbers
    depend on: model, ensemble size/seed, antithetic pairing, eval-set
    size, noise model, variation spec, and a digest of the trained params.
    `rosa.compile(autotune=...)` content-addresses cached matrices by
    (spec, RosaConfig) and calls ``measure`` only for absent layers —
    a warm compile never runs the MC stage at all.
    """
    spec = {"kind": "cnn-mc", "model": model, "n_chips": n_chips,
            "n_eval": n_eval, "eval_batch": eval_batch,
            "antithetic": antithetic, "seed": seed,
            "noise": rosa.serialize.to_jsonable(noise),
            "variation": rosa.serialize.to_jsonable(var_model),
            "params": params_digest(params)}
    key = jax.random.PRNGKey(seed)

    def measure(layer_names: Sequence[str]) -> dict:
        """DegradationSource hook: measure the named layers' rows."""
        return cnn_degradation_matrix(
            params, model, n_chips=n_chips, key=key, noise=noise,
            var_model=var_model, n_eval=n_eval, eval_batch=eval_batch,
            antithetic=antithetic, layers=list(layer_names))

    return rosa.DegradationSource(measure=measure, spec=spec)


def searched_cnn_hybrid_plan(profiles: Sequence[M.LayerProfile], params,
                             model: str, ensemble: V.Chip,
                             key: jax.Array, *,
                             noise: mrr.NoiseModel = mrr.PAPER_NOISE,
                             n_eval: int = 256, eval_batch: int = 64,
                             **kwargs) -> tuple[dict[str, Mapping], dict]:
    """`searched_hybrid_plan` on a lite CNN's synth-CIFAR evaluation set."""
    from repro.training.cnn_train import QAT_CFG

    x, y = cnn_eval_set(n_eval)
    return searched_hybrid_plan(profiles, cnn_apply_fn(model), params, x, y,
                                QAT_CFG, ensemble, key, noise=noise,
                                eval_batch=eval_batch, **kwargs)


def cnn_profiles_mc(params, model: str, ope: OPEConfig, *,
                    batch: int = 128,
                    **kwargs) -> list[M.LayerProfile]:
    """End to end: MC degradation matrix + full-size EDP rows -> profiles
    for the layers that exist in both the lite model and the paper table.
    """
    from repro.configs.paper_cnns import CNN_WORKLOADS

    deg = cnn_degradation_matrix(params, model, **kwargs)
    rows = [l for l in CNN_WORKLOADS[model] if l.name in deg]
    return profile_layers_mc(rows, ope, deg, batch=batch)
