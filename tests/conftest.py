"""Shared fixtures. NOTE: no XLA_FLAGS here on purpose — tests must see
the plain 1-device CPU; only launch/dryrun.py forces 512 devices."""

import jax
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(autouse=True)
def _hermetic_plan_cache(tmp_path_factory, monkeypatch):
    """Point the rosa.compile plan cache at a session-private directory so
    tests never read a stale plan from (or write into) the user's real
    ~/.cache — cache-behaviour tests pass their own `cache=` explicitly."""
    monkeypatch.setenv(
        "ROSA_PLAN_CACHE",
        str(tmp_path_factory.getbasetemp() / "rosa-plan-cache"))
