"""deepseek-v2-236b [arXiv:2405.04434].

60L d_model=5120, 128 heads MLA (kv_lora=512, q_lora=1536, qk_nope=128,
qk_rope=64, v_head=128), MoE 160 routed top-6 + 2 shared, expert d_ff=1536,
layer 0 dense FFN d_ff=12288, vocab=102400.  The decode cache holds only
(c_kv, k_rope) = 576 values/token — the paper's MLA compression.
"""

from repro.models.mla import MLAConfig
from repro.models.moe import MoEConfig
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="mla_moe",
    n_layers=60,
    d_model=5120,
    vocab=102400,
    n_heads=128,
    head_dim=128,          # v_head (for cache bookkeeping)
    n_kv_heads=128,
    rope_theta=1e4,
    mla=MLAConfig(d_model=5120, n_heads=128, q_lora=1536, kv_lora=512,
                  qk_nope=128, qk_rope=64, v_head=128),
    moe=MoEConfig(n_experts=160, top_k=6, d_model=5120, d_ff=1536,
                  n_shared=2, capacity_factor=1.25),
    first_dense_ff=12288,
    moe_ep=True,
)

SMOKE = ModelConfig(
    name="deepseek-v2-smoke",
    family="mla_moe",
    n_layers=3,
    d_model=64,
    vocab=256,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    mla=MLAConfig(d_model=64, n_heads=4, q_lora=32, kv_lora=16,
                  qk_nope=16, qk_rope=8, v_head=16),
    moe=MoEConfig(n_experts=8, top_k=2, d_model=64, d_ff=32, n_shared=1,
                  capacity_factor=2.0),
    first_dense_ff=128,
    moe_ep=False,
)
