"""Synthetic request streams for serving benchmarks and tests.

Arrivals are a Poisson process expressed in scheduler TICKS (exponential
inter-arrival gaps of mean 1/rate), prompt and generation lengths are
uniform over closed ranges — all drawn from one `numpy` Generator seeded
explicitly, so a (seed, rate, ranges) tuple is a fully reproducible
workload: the `serve_smoke` bench gates its throughput numbers on exactly
that determinism.
"""

from __future__ import annotations

import numpy as np

from repro.serve.scheduler import Request


def poisson_requests(n: int, rate: float, *, vocab: int,
                     prompt_len: tuple[int, int] = (4, 16),
                     gen_len: tuple[int, int] = (2, 16),
                     seed: int = 0,
                     start_rid: int = 0) -> list[Request]:
    """`n` requests with Poisson(rate-per-tick) arrivals.

    rate <= 0 means everything arrives at tick 0 (closed-loop load).
    """
    if n < 1:
        raise ValueError("n must be >= 1")
    rng = np.random.default_rng(seed)
    if rate > 0:
        gaps = rng.exponential(1.0 / rate, size=n)
        arrivals = np.floor(np.cumsum(gaps)).astype(np.int64)
    else:
        arrivals = np.zeros(n, np.int64)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(prompt_len[0], prompt_len[1] + 1))
        glen = int(rng.integers(gen_len[0], gen_len[1] + 1))
        prompt = rng.integers(0, vocab, size=plen).astype(np.int32)
        reqs.append(Request(rid=start_rid + i, prompt=prompt,
                            max_new_tokens=glen,
                            arrival=int(arrivals[i])))
    return reqs
