"""The paper's hybrid-mapping pipeline on one CNN, end to end:

QAT-train AlexNet-lite on synth-CIFAR -> profile per-layer IS/WS noise
sensitivity (Fig. 6) -> join with the full-size EDP table -> balanced-
metric plan (Sec. 3.5) -> evaluate accuracy + EDP vs WS/IS/analog.

The resulting plan is then lifted into a compiled `rosa.Program`
(`rosa.compile` freezes the plan and re-prices the captured named-GEMM
trace onto the attached `EnergyLedger`), so the printed behavioural-trace
EDP comes from the very matmuls the plan routed — and the program's
`lower()` artifact shows the JSON plan the on-disk cache would persist.

Run:  PYTHONPATH=src python examples/hybrid_mapping_cnn.py [--steps 250]
"""

import argparse
import dataclasses
import os
import sys

import jax

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from benchmarks.table4_hybrid import run_model
from repro import rosa
from repro.core import mrr
from repro.core.constants import Mapping, ROSA_OPTIMAL
from repro.models.cnn import LITE_MODELS
from repro.training.cnn_train import QAT_CFG


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="alexnet")
    ap.add_argument("--steps", type=int, default=250)
    args = ap.parse_args()
    res = run_model(args.model, steps=args.steps, n_mc=2)
    plan = {k: Mapping(v) for k, v in res["plan"].items()}

    # lift the plan into a compile-once Program: the compile captures the
    # named-GEMM trace and prices it onto the attached ledger
    from repro.training.cnn_train import cnn_program
    specs = LITE_MODELS[args.model]
    engine = rosa.Engine.from_hybrid_plan(
        dataclasses.replace(QAT_CFG, noise=mrr.PAPER_NOISE), plan,
        layers=[s.name for s in specs],
        key=jax.random.PRNGKey(0), ledger=rosa.EnergyLedger())
    program = cnn_program(args.model, engine)

    print("\nper-layer plan (resolved through the Program):")
    for s in specs:
        print(f"  {s.name:10s} -> {program.plan.resolve(s.name).mapping.value}")

    ledger = program.ledger
    print(f"\nlite-model behavioural-trace EDP (batch 8, (8,8) array): "
          f"{ledger.edp(ROSA_OPTIMAL):.4g} J*s over "
          f"{len(program.trace)} traced GEMMs")
    art = program.lower()
    print(f"lowered artifact: {len(art['plan']['overrides'])} plan "
          f"overrides, trace fingerprint "
          f"{program.trace.fingerprint[:12]}...")
