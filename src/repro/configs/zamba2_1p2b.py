"""zamba2-1.2b [arXiv:2411.15242]. Hybrid: 38 Mamba-2 layers (d_model=2048,
d_state=64) with ONE shared attention+MLP block (32H kv=32, d_ff=8192)
applied after every 6 mamba layers (6 applications, per-application KV
cache; weights shared).  vocab=32000, tied embeddings.

long_500k RUNS: mamba state is O(1); the shared-attn caches are the only
sequence-length state."""

from repro.models.ssm import SSMConfig
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    vocab=32000,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    ssm=SSMConfig(d_model=2048, d_state=64, head_dim=64, expand=2,
                  n_groups=1, d_conv=4, chunk=128),
    shared_every=6,
    rope_theta=1e4,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="zamba2-smoke",
    family="hybrid",
    n_layers=5,
    d_model=64,
    vocab=256,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    ssm=SSMConfig(d_model=64, d_state=16, head_dim=16, expand=2,
                  n_groups=1, d_conv=4, chunk=8),
    shared_every=2,
    tie_embeddings=True,
)
