"""Recompile / promotion hazards.

Three classes of silently-expensive mistakes, all visible statically:

  REC001 WARNING  weak-typed traced argument (a bare Python scalar): its
                  value participates in type promotion, and passing it
                  where a static is expected retraces per value
  REC002 WARNING  f64 values appear in the jaxpr while inputs are <= f32:
                  a silent promotion doubles bandwidth (and diverges from
                  the f32 analog-path numerics the paper calibrates)
  REC003 ERROR    an example value at a static_argnums position is
                  unhashable — every call raises (or, for dict-likes that
                  sneak through custom jits, retraces unconditionally)
"""

from __future__ import annotations

import numpy as np

from repro.analysis.findings import Finding, Severity
from repro.analysis.jaxprs import eqn_location, iter_eqns
from repro.analysis.registry import register
from repro.analysis.target import AnalysisTarget


def _np_dtype(dt):
    """np.dtype(dt), or None for JAX extended dtypes (key<fry> etc.) that
    numpy cannot interpret."""
    if dt is None:
        return None
    try:
        return np.dtype(dt)
    except TypeError:
        return None


@register("recompile")
def check_recompile(target: AnalysisTarget) -> list[Finding]:
    if target.fn is None:
        return []
    findings: list[Finding] = []

    for i in target.static_argnums:
        if i >= len(target.example_args):
            continue
        try:
            hash(target.example_args[i])
        except TypeError:
            findings.append(Finding(
                check="recompile", code="REC003", severity=Severity.ERROR,
                subject=target.name, location=f"static arg {i}",
                message=(f"static_argnums position {i} holds an "
                         f"unhashable "
                         f"{type(target.example_args[i]).__name__}: jit "
                         "cannot key its cache on it — freeze it "
                         "(tuple/dataclass(frozen=True)) or make it a "
                         "traced argument")))
    if findings:
        # an unhashable static can't even trace — report it rather than
        # crashing on make_jaxpr below
        return findings

    closed = target.jaxpr()
    for idx, iv in enumerate(closed.jaxpr.invars):
        aval = iv.aval
        if getattr(aval, "weak_type", False) \
                and getattr(aval, "shape", None) == ():
            findings.append(Finding(
                check="recompile", code="REC001",
                severity=Severity.WARNING, subject=target.name,
                location=f"arg {idx} ({aval.str_short()})",
                message=("weak-typed scalar argument: a bare Python "
                         "number reached the trace — it promotes "
                         "surrounding arrays and invites per-value "
                         "retraces; pass jnp.asarray(x, dtype) "
                         "explicitly")))

    max_in_bits = 0
    for iv in closed.jaxpr.invars:
        dt = _np_dtype(getattr(iv.aval, "dtype", None))
        if dt is not None and np.issubdtype(dt, np.floating):
            max_in_bits = max(max_in_bits, dt.itemsize * 8)
    if max_in_bits and max_in_bits <= 32:
        for eqn, path, _ in iter_eqns(closed):
            for ov in eqn.outvars:
                dt = _np_dtype(getattr(getattr(ov, "aval", None),
                                       "dtype", None))
                if dt == np.float64:
                    findings.append(Finding(
                        check="recompile", code="REC002",
                        severity=Severity.WARNING, subject=target.name,
                        location=eqn_location(eqn, path),
                        message=("float64 value produced from <= f32 "
                                 "inputs: silent promotion doubles "
                                 "bandwidth — check for Python-float "
                                 "constants or np.float64 scalars on "
                                 "this path")))
                    break
            else:
                continue
            break
    return findings
