"""Mamba-2 block (state-space duality, arXiv:2405.21060) — train + decode.

Projections are kept as SEPARATE weights (w_x, w_z, w_b, w_c, w_dt) instead
of one packed in_proj so each can carry its own logical sharding axis
(heads -> model TP); the math is identical to the fused projection.

The sequence mix is the SSD recurrence per head h (state S x head dim P):

    H_t = a_t * H_{t-1} + dt_t * B_t x_t^T ,   y_t = C_t H_t + D x_t
    a_t = exp(-exp(A_log) * dt_t),  dt_t = softplus(dt_raw + dt_bias)

computed in chunked matmul form (jnp here — the Pallas kernel in
kernels/ssd_scan implements the same chunking for TPU and is validated
against this code path).  B/C are shared across heads within `n_groups`
groups (Mamba-2's GVA); a causal depthwise conv (width 4) precedes the scan
on x/B/C.  Output gate: RMSNorm(y * silu(z)) -> out projection.

ROSA note (DESIGN.md §Arch-applicability): the five projections are GEMMs
and route through the paper's optical MAC; the SSD scan itself is not a
GEMM the MRR array can hold stationary and stays on the dense path.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import rmsnorm
from repro.models.module import ParamDef


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_model: int
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    n_groups: int = 1
    d_conv: int = 4
    chunk: int = 128

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim


def ssm_def(cfg: SSMConfig) -> dict:
    d, h, p_, g, s = (cfg.d_model, cfg.n_heads, cfg.head_dim,
                      cfg.n_groups, cfg.d_state)
    return {
        "w_x": ParamDef((d, h, p_), ("embed", "heads", "head_dim")),
        "w_z": ParamDef((d, h, p_), ("embed", "heads", "head_dim")),
        "w_b": ParamDef((d, g, s), ("embed", None, "state")),
        "w_c": ParamDef((d, g, s), ("embed", None, "state")),
        "w_dt": ParamDef((d, h), ("embed", "heads")),
        "dt_bias": ParamDef((h,), ("heads",), "zeros"),
        "a_log": ParamDef((h,), ("heads",), "zeros"),
        "d_skip": ParamDef((h,), ("heads",), "ones"),
        "conv_x": ParamDef((cfg.d_conv, h, p_), (None, "heads", "head_dim"),
                           scale=0.5),
        "conv_b": ParamDef((cfg.d_conv, g, s), (None, None, "state"),
                           scale=0.5),
        "conv_c": ParamDef((cfg.d_conv, g, s), (None, None, "state"),
                           scale=0.5),
        "gate_norm": ParamDef((h, p_), ("heads", "head_dim"), "ones"),
        "w_out": ParamDef((h, p_, d), ("heads", "head_dim", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv along axis 1. x: (B, L, ...); w: (K, ...)."""
    k = w.shape[0]
    out = x * w[k - 1]
    for i in range(1, k):
        shifted = jnp.pad(x, [(0, 0), (i, 0)] + [(0, 0)] * (x.ndim - 2)
                          )[:, :x.shape[1]]
        out = out + shifted * w[k - 1 - i]
    return jax.nn.silu(out)


def _decay(p: dict, dt_raw: jax.Array):
    """dt_raw: (..., H) -> (dt, loga) both (..., H)."""
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    loga = -jnp.exp(p["a_log"]) * dt
    return dt, loga


def ssd_chunked(x: jax.Array, loga: jax.Array, b: jax.Array, c: jax.Array,
                chunk: int, state0: jax.Array | None = None):
    """Batched chunked SSD scan (pure jnp; oracle-equivalent to the kernel).

    x: (B, L, H, P) f32 (dt already folded in); loga: (B, L, H);
    b, c: (B, L, H, S) (groups pre-broadcast).  Returns
    (y: (B, L, H, P), state: (B, H, S, P)).  L % chunk == 0.
    """
    bsz, l, h, p_ = x.shape
    s_dim = b.shape[-1]
    pad = (-l) % chunk
    if pad:
        # zero-pad: loga=0 (a=1) keeps the state, b=0 writes nothing
        x, loga, b, c = (jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
                         for a in (x, loga, b, c))
    n = (l + pad) // chunk
    xs = x.reshape(bsz, n, chunk, h, p_)
    ls = loga.reshape(bsz, n, chunk, h)
    bs = b.reshape(bsz, n, chunk, h, s_dim)
    cs = c.reshape(bsz, n, chunk, h, s_dim)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))[None, :, :, None]

    def step(s, inp):
        """One chunk: intra-chunk masked-decay attention + state carry."""
        cq, bq, xq, lq = inp                              # (B, Q, H, ...)
        lcum = jnp.cumsum(lq, axis=1)                     # (B, Q, H)
        ltot = lcum[:, -1]                                # (B, H)
        dmat = jnp.exp(lcum[:, :, None] - lcum[:, None, :])   # (B, Q, Q, H)
        att = jnp.einsum("bihs,bjhs->bijh", cq, bq) * jnp.where(tri, dmat, 0.0)
        y = jnp.einsum("bijh,bjhp->bihp", att, xq)
        y = y + jnp.exp(lcum)[..., None] * jnp.einsum("bqhs,bhsp->bqhp", cq, s)
        carry_w = jnp.exp(ltot[:, None] - lcum)           # (B, Q, H)
        s_new = (jnp.exp(ltot)[:, :, None, None] * s
                 + jnp.einsum("bqhs,bqhp->bhsp", bq * carry_w[..., None], xq))
        return s_new, y

    if state0 is None:
        state0 = jnp.zeros((bsz, h, s_dim, p_), jnp.float32)
    xs_t = tuple(jnp.moveaxis(a, 1, 0) for a in (cs, bs, xs, ls))
    state, ys = jax.lax.scan(step, state0, xs_t)
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, l + pad, h, p_)
    return y[:, :l], state


def ssm_apply(p: dict, cfg: SSMConfig, u: jax.Array) -> jax.Array:
    """Full-sequence Mamba-2 block. u: (B, L, D) -> (B, L, D)."""
    h, g = cfg.n_heads, cfg.n_groups
    x = _causal_conv(jnp.einsum("bld,dhp->blhp", u, p["w_x"]), p["conv_x"])
    b = _causal_conv(jnp.einsum("bld,dgs->blgs", u, p["w_b"]), p["conv_b"])
    c = _causal_conv(jnp.einsum("bld,dgs->blgs", u, p["w_c"]), p["conv_c"])
    z = jnp.einsum("bld,dhp->blhp", u, p["w_z"])
    dt, loga = _decay(p, jnp.einsum("bld,dh->blh", u, p["w_dt"]))

    rep = h // g
    b = jnp.repeat(b, rep, axis=2)
    c = jnp.repeat(c, rep, axis=2)
    x_eff = x.astype(jnp.float32) * dt[..., None]
    y, _ = ssd_chunked(x_eff, loga, b.astype(jnp.float32),
                       c.astype(jnp.float32), cfg.chunk)
    y = y + p["d_skip"][None, None, :, None] * x.astype(jnp.float32)
    y = (y.astype(u.dtype) * jax.nn.silu(z))
    y = rmsnorm(p["gate_norm"].reshape(-1), y.reshape(*y.shape[:2], -1)
                ).reshape(y.shape)
    return jnp.einsum("blhp,hpd->bld", y, p["w_out"])


# ---------------------------------------------------------------------------
# Decode (single token, carried state)
# ---------------------------------------------------------------------------
def ssm_cache_def(cfg: SSMConfig, batch: int, dtype=jnp.float32) -> dict:
    k = cfg.d_conv - 1
    return {
        "conv_x": jnp.zeros((batch, k, cfg.n_heads, cfg.head_dim), dtype),
        "conv_b": jnp.zeros((batch, k, cfg.n_groups, cfg.d_state), dtype),
        "conv_c": jnp.zeros((batch, k, cfg.n_groups, cfg.d_state), dtype),
        "state": jnp.zeros((batch, cfg.n_heads, cfg.d_state, cfg.head_dim),
                           dtype),
    }


def _conv_step(cache: jax.Array, xt: jax.Array, w: jax.Array):
    """cache: (B, K-1, ...) past inputs; xt: (B, ...) new. -> (y, new_cache)."""
    hist = jnp.concatenate([cache, xt[:, None]], axis=1)      # (B, K, ...)
    y = jnp.einsum("bk...,k...->b...", hist, w)
    return jax.nn.silu(y), hist[:, 1:]


def ssm_decode(p: dict, cfg: SSMConfig, u: jax.Array, cache: dict):
    """u: (B, 1, D); cache from ssm_cache_def. Returns (y (B,1,D), cache)."""
    h, g = cfg.n_heads, cfg.n_groups
    ut = u[:, 0]
    x_in = jnp.einsum("bd,dhp->bhp", ut, p["w_x"])
    b_in = jnp.einsum("bd,dgs->bgs", ut, p["w_b"])
    c_in = jnp.einsum("bd,dgs->bgs", ut, p["w_c"])
    z = jnp.einsum("bd,dhp->bhp", ut, p["w_z"])
    dt, loga = _decay(p, jnp.einsum("bd,dh->bh", ut, p["w_dt"]))

    x, cx = _conv_step(cache["conv_x"], x_in, p["conv_x"])
    b, cb = _conv_step(cache["conv_b"], b_in, p["conv_b"])
    c, cc = _conv_step(cache["conv_c"], c_in, p["conv_c"])

    rep = h // g
    b = jnp.repeat(b, rep, axis=1).astype(jnp.float32)        # (B, H, S)
    c = jnp.repeat(c, rep, axis=1).astype(jnp.float32)
    a = jnp.exp(loga)                                         # (B, H)
    s = cache["state"]
    x32 = x.astype(jnp.float32) * dt[..., None]
    s = (a[:, :, None, None] * s
         + jnp.einsum("bhs,bhp->bhsp", b, x32))
    y = jnp.einsum("bhs,bhsp->bhp", c, s)
    y = y + p["d_skip"][None, :, None] * x.astype(jnp.float32)
    y = y.astype(u.dtype) * jax.nn.silu(z)
    y = rmsnorm(p["gate_norm"].reshape(-1), y.reshape(y.shape[0], -1)
                ).reshape(y.shape)
    out = jnp.einsum("bhp,hpd->bd", y, p["w_out"])[:, None]
    return out, {"conv_x": cx, "conv_b": cb, "conv_c": cc, "state": s}
