"""repro.robust — vectorized Monte-Carlo device-variation subsystem.

Splits the paper's noise story into its two physical time scales and makes
both first-class, fully vectorized citizens:

  per-shot noise       `mrr.NoiseModel` — fresh DAC/thermal draw every
                       matmul (Eq. 8), unchanged;
  per-device variation `variation` — static fab mismatch + thermal-
                       crosstalk bias + driver offsets, drawn ONCE per
                       fabricated chip as a `{layer: mrr.StaticVariation}`
                       pytree;
  chip ensembles       `ensemble` — an "N-chip wafer" evaluated in ONE
                       jitted vmapped call: per-chip accuracy, clean-logit
                       agreement, yield statistics;
  sensitivity          `sensitivity` — perturb-one-layer degradation
                       profiling as a traced one-hot gate, (chips x layers)
                       per mapping in one call, feeding
                       `mapping.LayerProfile.d_is/d_ws` directly;
  drift + re-trim      `drift` — thermal drift schedules with periodic
                       re-calibration through `mrr.voltage_of_weight`'s
                       `dt_trim` hook;
  reports              `report` — accuracy-vs-sigma and yield curves in
                       the gateable `repro.bench` schema.

Serving pins one sampled chip with `rosa.Engine.with_variation(chip)` and
reuses it deterministically across decode steps.  CLI:
``python -m repro.robust {ensemble,sensitivity,drift,sweep}``.
"""

from repro.robust.drift import DriftModel, DriftResult, residual_offsets, \
    simulate, simulate_cnn, trim_voltages
from repro.robust.ensemble import (EnsembleResult, clean_reference,
                                   evaluate_cnn_ensemble, evaluate_ensemble,
                                   make_ensemble_eval)
from repro.robust.sensitivity import (accuracy_guarded_plan,
                                      cnn_degradation_matrix,
                                      cnn_profiles_mc, degradation_matrix,
                                      plan_search, profile_layers_mc,
                                      searched_cnn_hybrid_plan,
                                      searched_hybrid_plan)
from repro.robust.variation import (NO_VARIATION, PAPER_VARIATION,
                                    VariationModel, chip_at, cnn_lane_dims,
                                    ensemble_size, sample_chip,
                                    sample_ensemble, scale_ensemble,
                                    shift_thermal)

__all__ = [
    "DriftModel", "DriftResult", "EnsembleResult", "NO_VARIATION",
    "PAPER_VARIATION", "VariationModel", "accuracy_guarded_plan",
    "chip_at", "clean_reference",
    "cnn_degradation_matrix", "cnn_lane_dims", "cnn_profiles_mc",
    "degradation_matrix", "ensemble_size", "evaluate_cnn_ensemble",
    "evaluate_ensemble", "make_ensemble_eval", "plan_search",
    "profile_layers_mc", "residual_offsets", "sample_chip",
    "sample_ensemble", "scale_ensemble", "searched_cnn_hybrid_plan",
    "searched_hybrid_plan", "shift_thermal", "simulate", "simulate_cnn",
    "trim_voltages",
]
