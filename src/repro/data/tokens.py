"""Deterministic synthetic LM token pipeline.

The batch for step N is a pure function of (seed, N) — `batch(step)` —
which is the fault-tolerance property the training loop relies on: after a
checkpoint restore (possibly on a DIFFERENT device count) the pipeline
resumes mid-stream with zero lost or duplicated samples, and a straggler's
shard can be re-issued by any other host.

Tokens follow an order-2 Markov chain over the vocab (so there IS signal to
learn, unlike uniform noise): next = (a * t_{-1} + b * t_{-2} + noise) mod V
with per-sequence drift.  Cheap, stateless, reproducible.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenPipeline:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise_levels: int = 7

    def batch(self, step: int) -> dict:
        """Full global batch for a step (callers slice their DP shard)."""
        coef = np.random.default_rng(self.seed)     # per-RUN constants
        a = int(coef.integers(2, 8))
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        b, s, v = self.global_batch, self.seq_len + 1, self.vocab
        noise = rng.integers(0, self.noise_levels, size=(b, s))
        toks = np.zeros((b, s), np.int64)
        toks[:, 0] = rng.integers(0, v, size=b)
        for t in range(1, s):
            # noisy bigram: the map t_{-1} -> a*t_{-1} is deterministic, the
            # added noise sets the achievable loss floor at ln(noise_levels)
            toks[:, t] = (a * toks[:, t - 1] + noise[:, t]) % v
        toks = toks.astype(np.int32)
        return {"tokens": jnp.asarray(toks[:, :-1]),
                "labels": jnp.asarray(toks[:, 1:])}

    def shard_batch(self, step: int, shard: int, n_shards: int) -> dict:
        """One DP shard's slice — what a host pulls in multi-host training."""
        full = self.batch(step)
        per = self.global_batch // n_shards
        sl = slice(shard * per, (shard + 1) * per)
        return jax.tree.map(lambda x: x[sl], full)
