"""Structured benchmark runner: one entry per paper table/figure.

Every bench returns typed `Metric`s (deterministic analytic numbers gate
the CI regression check; stochastic tiny-step accuracies and wall times are
recorded ungated).  Failures are caught per-bench, recorded as
``status: failed``, and surface as a non-zero exit AFTER the summary — one
broken bench no longer aborts the aggregator.

    PYTHONPATH=src python -m benchmarks.run [--full] [--json] [--only ...]

``--json`` serializes the run as a schema-valid ``BENCH_<n>.json`` at the
repo root (`repro.bench.schema`); gate it against the committed baseline
with ``python -m repro.bench.compare benchmarks/baseline.json BENCH_<n>.json``.
"""

from __future__ import annotations

import argparse
import contextlib
import datetime
import platform
import re
import sys
import time
import traceback
from pathlib import Path

from repro import obs
from repro.bench.schema import (BenchReport, BenchResult, Metric,
                                next_bench_path, save)

REPO_ROOT = Path(__file__).resolve().parent.parent


def _enable_jax_compile_cache() -> None:
    """Persist XLA executables across bench processes.

    The quick benches are compile-dominated on a 1-core CPU runner (the
    shared robust_smoke evaluator alone costs ~16s of XLA time), so repeat
    runs load compiled programs from a disk cache instead.  Opt out with
    ``ROSA_JAX_CACHE=0``; relocate with ``ROSA_JAX_CACHE_DIR``.  Best
    effort: unsupported jax versions just run uncached.
    """
    import os
    if os.environ.get("ROSA_JAX_CACHE", "1") == "0":
        return
    cache_dir = os.environ.get(
        "ROSA_JAX_CACHE_DIR",
        os.path.join(os.path.expanduser("~"), ".cache", "rosa", "jax"))
    try:
        import jax
        jax.config.update("jax_compilation_cache_dir", cache_dir)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.5)
        jax.config.update("jax_persistent_cache_enable_xla_caches", "all")
    except Exception:
        pass


class SkipBench(Exception):
    """Raised by a bench to record ``status: skipped`` (with a reason)."""


# ---------------------------------------------------------------------------
# Benches — each returns list[Metric]
# ---------------------------------------------------------------------------
def bench_table1_modes(quick: bool) -> list[Metric]:
    from benchmarks import table1_modes
    r = table1_modes.run(verbose=False)
    return [
        Metric("ops_mixed_vs_analog", r["mixed"]["ops"] / r["analog"]["ops"],
               unit="x", gate=True, rel_tol=1e-3),
        Metric("mixed_edp", r["mixed"]["edp"], unit="J*s",
               gate=True, rel_tol=1e-3, direction="lower_is_better"),
        Metric("mixed_oadc_energy", r["mixed"]["oadc_energy"], unit="J",
               gate=True, rel_tol=1e-3, direction="lower_is_better"),
    ]


def bench_fig7_array_dse(quick: bool) -> list[Metric]:
    from benchmarks import fig7_array_dse
    r = fig7_array_dse.run(verbose=False)
    return [
        Metric("best_config", r["best"].label, gate=True),
        Metric("reduction_vs_deap", r["reduction_vs_deap"], unit="frac",
               gate=True, rel_tol=0.01, direction="higher_is_better"),
        Metric("reduction_vs_compact", r["reduction_vs_compact"],
               unit="frac", gate=True, rel_tol=0.01,
               direction="higher_is_better"),
    ]


def bench_fig8_osa(quick: bool) -> list[Metric]:
    from benchmarks import fig8_osa
    r = fig8_osa.run(verbose=False)
    return [
        Metric("geomean_reduction_osa", r["geomean_reduction_osa"],
               unit="frac", gate=True, rel_tol=0.01,
               direction="higher_is_better"),
        Metric("geomean_reduction_osa_ode", r["geomean_reduction_osa_ode"],
               unit="frac", gate=True, rel_tol=0.01,
               direction="higher_is_better"),
    ]


def bench_fig9_power_breakdown(quick: bool) -> list[Metric]:
    from benchmarks import fig9_power_breakdown
    r = fig9_power_breakdown.run(verbose=False)
    alex = r["alexnet"]
    adc_red = 1 - alex["osa"]["adc"] / alex["no_osa"]["adc"]
    return [
        Metric("n_workloads", len(r), gate=True, rel_tol=0.0),
        Metric("alexnet_adc_power_reduction", adc_red, unit="frac",
               gate=True, rel_tol=0.01, direction="higher_is_better"),
    ]


def bench_dse_zoo(quick: bool) -> list[Metric]:
    """Grid x model-zoo cross-product through the vmapped DSE engine."""
    from repro.configs import get_workload_zoo
    from repro.core import dse

    wls = get_workload_zoo()
    t0 = time.time()
    pts = dse.sweep(wls, engine="vmap", batch=8)
    dt = time.time() - t0
    return [
        Metric("n_workloads", len(wls), gate=True, rel_tol=0.0),
        Metric("n_layer_rows", sum(len(w.layers) for w in wls),
               gate=True, rel_tol=0.0),
        Metric("n_candidates", len(pts), gate=True, rel_tol=0.0),
        Metric("best_config", pts[0].label, gate=True),
        Metric("best_metric", pts[0].metric, gate=True, rel_tol=0.01,
               direction="lower_is_better"),
        Metric("sweep_wall_s", dt, unit="s"),
    ]


def bench_hybrid_zoo(quick: bool) -> list[Metric]:
    """EDP-only hybrid-mapping search on zoo architectures (accuracy term
    muted — no behavioural twin for the LLM stacks)."""
    from repro.configs import get_workload_zoo
    from repro.core import mapping as M
    from repro.core.constants import Mapping, ROSA_OPTIMAL

    archs = ["qwen3-32b", "mamba2-1.3b"] if quick else \
        ["qwen3-32b", "mamba2-1.3b", "gemma3-12b", "zamba2-1.2b",
         "seamless-m4t-medium"]
    out = []
    for wl in get_workload_zoo(include_paper=False, archs=archs):
        profs = M.profile_layers_fast(wl.layers, ROSA_OPTIMAL, batch=8)
        plan = M.hybrid_plan(profs)
        e_h = M.plan_edp(wl.layers, plan, ROSA_OPTIMAL, batch=8)
        e_ws = M.plan_edp(wl.layers,
                          {p.name: Mapping.WS for p in profs},
                          ROSA_OPTIMAL, batch=8)
        out.append(Metric(f"{wl.name}_hybrid_vs_ws_edp", e_h / e_ws,
                          unit="ratio", gate=True, rel_tol=0.01,
                          direction="lower_is_better"))
    return out


def bench_ledger_trace(quick: bool) -> list[Metric]:
    """Trace-based EDP: the lite CNN re-traced through an Engine with an
    `EnergyLedger` attached (shapes only — deterministic, no training)."""
    import jax
    import jax.numpy as jnp

    from repro import rosa
    from repro.core.constants import ROSA_OPTIMAL
    from repro.models.cnn import LITE_MODELS, LITE_SKIPS, cnn_apply, cnn_def
    from repro.models.module import abstract_params
    from repro.training.cnn_train import QAT_CFG

    specs = LITE_MODELS["alexnet"]
    ledger = rosa.EnergyLedger()
    engine = rosa.Engine.from_config(
        QAT_CFG, layers=[s.name for s in specs],
        key=jax.random.PRNGKey(0), ledger=ledger)
    skel = abstract_params(cnn_def(specs), dtype=jnp.float32)
    jax.eval_shape(
        lambda p, x: cnn_apply(p, specs, x, engine,
                               residual_from=LITE_SKIPS.get("alexnet")),
        skel, jax.ShapeDtypeStruct((8, 32, 32, 3), jnp.float32))
    export = ledger.export(ROSA_OPTIMAL)
    return [
        Metric("n_traced_matmuls", len(export["events"]),
               gate=True, rel_tol=0.0),
        Metric("trace_edp", export["totals"]["edp"], unit="J*s",
               gate=True, rel_tol=1e-3, direction="lower_is_better"),
        Metric("trace_energy", export["totals"]["energy"], unit="J",
               gate=True, rel_tol=1e-3, direction="lower_is_better"),
    ]


def bench_table4_hybrid(quick: bool) -> list[Metric]:
    from benchmarks import table4_hybrid
    models = ["alexnet"] if quick else None
    res = table4_hybrid.run(models=models,
                            steps=60 if quick else 400,
                            n_mc=1 if quick else 3, verbose=False)
    # accuracies are already percentages (evaluate_cnn); tiny-step training
    # numbers are stochastic -> recorded, never gated
    gain = sum(r["accs"]["hybrid"] - r["accs"]["ws"]
               for r in res.values()) / len(res)
    return [
        Metric("hybrid_vs_ws_pp", gain, unit="pp"),
        Metric("n_models", len(res), gate=True, rel_tol=0.0),
    ]


def bench_robust_smoke(quick: bool) -> list[Metric]:
    """repro.robust end-to-end on the variance-reduced estimator
    (`robust.cli.run_smoke`): 16-chip wafer statistics where only
    ``n_probe`` chips get real forwards (antithetic pairing +
    control-variate surrogate), then the shared-forward sensitivity
    profile -> accuracy-aware hybrid plan evaluated against pure WS on the
    same ensemble (paper Table-4 direction: hybrid acc >= WS acc at lower
    EDP).  Every eval-set forward in the pipeline re-dispatches ONE
    compiled gated evaluator, and the degradation matrix persists in the
    content-addressed PlanCache, so warm runs skip the whole MC profiling
    stage.  Fixed seeds: the gated yield/accuracy numbers are
    deterministic on the pinned CI stack."""
    from repro.robust import cli as rcli
    from repro.training.cnn_train import train_cnn

    params, _ = train_cnn("alexnet", steps=40 if quick else 400)
    _, metrics = rcli.run_smoke(
        "alexnet", params=params, n_chips=16 if quick else 64,
        n_probe=2 if quick else 8, n_eval=48 if quick else 256,
        max_candidates=2 if quick else 6)
    return metrics


def bench_compile_cache(quick: bool) -> list[Metric]:
    """rosa.compile cold vs warm: a cold compile must run the plan search
    and a warm compile must load the identical plan from the disk cache
    without searching.  The cache-behaviour bits and the autotuned-plan
    shape are deterministic and gated; wall times are recorded ungated."""
    import tempfile

    import jax
    import jax.numpy as jnp

    from repro import rosa
    from repro.core.constants import Mapping
    from repro.models.cnn import LITE_MODELS, LITE_SKIPS, cnn_apply, cnn_def
    from repro.models.module import abstract_params
    from repro.training.cnn_train import QAT_CFG

    specs = LITE_MODELS["alexnet"]
    skips = LITE_SKIPS.get("alexnet")
    engine = rosa.Engine.from_config(QAT_CFG)

    def apply_fn(eng, params, x):
        return cnn_apply(params, specs, x, eng, residual_from=skips)

    skel = abstract_params(cnn_def(specs), dtype=jnp.float32)
    x = jax.ShapeDtypeStruct((8, 32, 32, 3), jnp.float32)
    tune = rosa.AutotuneConfig(batch=8)
    with tempfile.TemporaryDirectory() as cache_dir:
        t0 = time.time()
        cold = rosa.compile(apply_fn, engine, (skel, x), autotune=tune,
                            cache=cache_dir)
        t_cold = time.time() - t0
        t0 = time.time()
        warm = rosa.compile(apply_fn, engine, (skel, x), autotune=tune,
                            cache=cache_dir)
        t_warm = time.time() - t0
    n_is = sum(1 for m in cold.plan.mapping_plan().values()
               if m is Mapping.IS)
    return [
        Metric("cold_searched", int(cold.searched), gate=True, rel_tol=0.0),
        Metric("warm_cache_hit", int(warm.cache_hit), gate=True,
               rel_tol=0.0),
        Metric("warm_searched", int(warm.searched), gate=True, rel_tol=0.0),
        Metric("plans_equal", int(cold.plan == warm.plan), gate=True,
               rel_tol=0.0),
        Metric("n_trace_layers", len(cold.trace), gate=True, rel_tol=0.0),
        Metric("n_is_layers", n_is, gate=True, rel_tol=0.0),
        Metric("cold_compile_s", t_cold, unit="s"),
        Metric("warm_compile_s", t_warm, unit="s"),
    ]


def bench_serve_smoke(quick: bool) -> list[Metric]:
    """repro.serve end-to-end: a seeded Poisson request stream through the
    continuous-batching scheduler vs the static one-shot baseline on the
    smoke arch.  Gated metrics are deterministic by construction — step
    units and tick latencies depend on request lengths and scheduling, not
    on sampled token values; energy prices the decode trace analytically.
    The headline gate: continuous batching >= 1.5x one-shot tokens/unit."""
    from repro.serve import smoke_report
    return smoke_report(n_requests=24 if quick else 48)


def bench_drift_serve(quick: bool) -> list[Metric]:
    """Closed-loop drift-adaptive serving A/B (repro.serve.adaptive): one
    Poisson stream served twice under the same seeded sine drift schedule
    — uncontrolled vs detect/re-trim/re-plan controller with a forced
    mid-stream Program swap.  Gates: the controller recovers >= 80% of the
    uncontrolled accuracy loss, drops zero requests, keeps every request
    finished before its first action bit-exact with the uncontrolled run,
    and the double-buffered swap costs zero ticks of downtime."""
    from repro.serve.adaptive import drift_serve_metrics
    _, metrics = drift_serve_metrics(quick=quick)
    return metrics


def _replay_cost_s(tracer, repeats: int) -> float:
    """Best-of-N CPU cost of emitting exactly `tracer`'s event mix.

    Replays the recorded phase sequence through the public tracer API
    (reused span contexts, real clock reads — the same call shapes the
    scheduler uses), so the measured loop is cost-equivalent to the
    instrumentation that ran.  A few-ms tight loop min-of-N is stable to
    ~1% even on a contended core, unlike end-to-end A/B at the same
    scale."""
    import time as _time

    phases = [ev[0] if type(ev) is tuple else ev.get("ph", "i")
              for ev in tracer._events]
    best = float("inf")
    for _ in range(repeats):
        t2 = obs.Tracer()
        sp = t2.span("serve.tick", "serve")
        c0 = _time.process_time()
        for ph in phases:
            if ph == "X":
                with sp:
                    pass
            elif ph == "C":
                t2.counter("serve.queue_depth", 3)
            elif ph == "b":
                t2.async_begin("request", 7, cat="request", prompt_len=6)
            elif ph == "e":
                t2.async_end("request", 7, cat="request", tokens=9)
            elif ph == "n":
                t2.async_instant("admit", 7, cat="request", slot=1)
            else:
                t2.instant("x", "serve")
        best = min(best, _time.process_time() - c0)
    return best


def bench_obs_overhead(quick: bool) -> list[Metric]:
    """Tracing must be ~free: the gate rejects instrumentation creep in
    the serving tick loop.

    Direct on-vs-off A/B at the 2% level is UNMEASURABLE on a shared CI
    core — even `process_time` of the same run swings >30% with neighbor
    load — so the gated ratio decomposes the overhead into its stable
    factors: (emission cost of exactly the run's event stream, replayed
    as a tight min-of-N loop) over (best off-run CPU time).  The event
    VOLUME is pinned separately by the exact `trace_events` gate, so
    both instrument creep (more events) and emission-cost creep (slower
    tracer) trip a gate.  The direct A/B CPU/wall numbers are still
    reported, ungated, for humans.  The gated serve metrics must be
    BIT-identical in both modes — observability may never change
    scheduling or sampling."""
    import time as _time

    from repro.configs import get_smoke
    from repro.serve import (Scheduler, ServeConfig, poisson_requests,
                             report_metrics)

    cfg = get_smoke("qwen3-32b")
    scfg = ServeConfig(n_slots=4, max_len=56, prefill_chunk=8, seed=0)
    sched = Scheduler(cfg, scfg, init_seed=0)
    reqs = poisson_requests(96, 1.0, vocab=cfg.vocab, prompt_len=(4, 8),
                            gen_len=(2, 40), seed=0)
    with obs.tracing(None):
        sched.run(reqs)                        # warmup: eat the compiles

    repeats = 3 if quick else 5
    off_cpu, on_cpu, off_walls, on_walls = [], [], [], []
    rep_off = rep_on = tracer = None
    for _ in range(repeats):
        # interleaved off/on pairs: drift in machine load hits both sides
        c0 = _time.process_time()
        with obs.tracing(None):
            rep_off = sched.run(reqs)
        off_cpu.append(_time.process_time() - c0)
        off_walls.append(rep_off.wall_s)
        tracer = obs.Tracer()
        c0 = _time.process_time()
        with obs.tracing(tracer):
            rep_on = sched.run(reqs)
        on_cpu.append(_time.process_time() - c0)
        on_walls.append(rep_on.wall_s)

    emit_s = _replay_cost_s(tracer, repeats=15 if quick else 30)
    ratio = 1.0 + emit_s / max(min(off_cpu), 1e-9)

    def gated(rep):
        return {m.name: m.value for m in report_metrics(rep) if m.gate}

    return [
        Metric("overhead_ratio", ratio, unit="x", gate=True, rel_tol=0.02,
               direction="lower_is_better"),
        Metric("gated_metrics_identical", int(gated(rep_off) == gated(rep_on)),
               gate=True, rel_tol=0.0),
        Metric("trace_events", len(tracer), gate=True, rel_tol=0.0),
        Metric("emit_cost_s", emit_s, unit="s"),
        Metric("cpu_off_s", min(off_cpu), unit="s"),
        Metric("cpu_on_s", min(on_cpu), unit="s"),
        Metric("wall_off_s", min(off_walls), unit="s"),
        Metric("wall_on_s", min(on_walls), unit="s"),
    ]


def bench_kernel_fusion(quick: bool) -> list[Metric]:
    """Fused megakernel vs the composed chain on the smoke-arch decode
    GEMMs (the serving hot path: slot-batch activations against qkv /
    attn-out / mlp weights under paper noise, per-vector scales).

    Gated metrics are deterministic: bit-level EnergyLedger pricing parity
    (fusion is an execution detail — the analytic model must price both
    identically), numeric parity inside the requant flip bound, and the
    traced device-op ratio (one pallas_call + scale pre-pass vs the
    composed quantize -> mrr chain -> per-plane OSA -> dequant graph) —
    the HBM round-trip structure that makes fused <= composed a property
    of the lowering, not of the host.  Wall times per decode step are
    recorded ungated: on the CPU runner pallas executes in interpret mode,
    so timing there would gate the interpreter, not the kernel."""
    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import rosa
    from repro.analysis import jaxprs as J
    from repro.core import mrr
    from repro.core.constants import ROSA_OPTIMAL
    from repro.configs import get_smoke

    cfg_m = get_smoke("qwen3-32b")
    d, ff = cfg_m.d_model, (cfg_m.d_ff or 4 * cfg_m.d_model)
    slots = 4
    gemms = [("qkv", d, 3 * d), ("attn_out", d, d),
             ("mlp_up", d, ff), ("mlp_down", ff, d)]
    base = rosa.RosaConfig(noise=mrr.PAPER_NOISE, act_per_vector=True)
    key = jax.random.PRNGKey(0)
    xs = {k: jax.random.normal(jax.random.fold_in(key, i), (slots, k_dim))
          for i, (k, k_dim, _) in enumerate(gemms)}
    ws = {k: jax.random.normal(jax.random.fold_in(key, 100 + i),
                               (k_dim, n_dim))
          for i, (k, k_dim, n_dim) in enumerate(gemms)}

    def make_step(backend: str):
        cfg = dataclasses.replace(base, backend=backend)

        def step(xs_, ws_, k_):
            return {name: rosa.rosa_matmul(
                xs_[name], ws_[name], cfg, jax.random.fold_in(k_, i))
                for i, (name, _, _) in enumerate(gemms)}
        return jax.jit(step)

    def device_ops(fn) -> int:
        """Top-level device ops of the traced step: recurse through call
        wrappers but count a pallas_call as ONE launch (its body is one
        kernel, not a graph of HBM round-trips)."""
        def count(closed) -> int:
            n = 0
            for eqn in closed.jaxpr.eqns:
                if eqn.primitive.name == "pallas_call":
                    n += 1
                    continue
                subs = list(J.sub_jaxprs(eqn))
                if subs:
                    n += sum(count(s) for _, s in subs)
                else:
                    n += 1
            return n
        return count(jax.make_jaxpr(fn)(xs, ws, key))

    steps = {b: make_step(b) for b in ("fused", "ref")}
    ops = {b: device_ops(steps[b]) for b in steps}

    # numeric parity inside the requant flip bound (the fused kernel's
    # documented contract; tests/test_kernels.py::assert_quantized_parity)
    y = {b: steps[b](xs, ws, key) for b in steps}
    parity_ok = 1
    for name, _, _ in gemms:
        a = np.asarray(y["fused"][name], np.float64)
        r = np.asarray(y["ref"][name], np.float64)
        if np.max(np.abs(a - r)) / max(np.max(np.abs(r)), 1.0) > 2.0 / 127:
            parity_ok = 0

    # bit-level ledger pricing parity on the same traced decode workload
    exports = {}
    for b in steps:
        ledger = rosa.EnergyLedger()
        eng = rosa.Engine.from_config(
            dataclasses.replace(base, backend=b), key=key, ledger=ledger)
        jax.eval_shape(
            lambda w_, x_: [eng.matmul(x_[n_], w_[n_], name=n_)
                            for n_, _, _ in gemms], ws, xs)
        exports[b] = ledger.export(ROSA_OPTIMAL)
    edp_parity = int(exports["fused"]["totals"] == exports["ref"]["totals"])

    def best_step_ms(fn) -> float:
        jax.block_until_ready(fn(xs, ws, key))      # compile
        best = float("inf")
        for _ in range(3 if quick else 10):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(xs, ws, key))
            best = min(best, time.perf_counter() - t0)
        return best * 1e3

    return [
        Metric("ledger_edp_parity", edp_parity, gate=True, rel_tol=0.0),
        Metric("numeric_parity_ok", parity_ok, gate=True, rel_tol=0.0),
        Metric("fused_device_ops", ops["fused"], gate=True, rel_tol=0.0),
        Metric("composed_device_ops", ops["ref"], gate=True, rel_tol=0.0),
        Metric("device_op_ratio", ops["fused"] / ops["ref"], unit="x",
               gate=True, rel_tol=0.01, direction="lower_is_better"),
        Metric("fused_step_ms", best_step_ms(steps["fused"]), unit="ms"),
        Metric("composed_step_ms", best_step_ms(steps["ref"]), unit="ms"),
    ]


def bench_roofline(quick: bool) -> list[Metric]:
    from benchmarks import roofline as R
    rows = [d for r in R.load("results/dryrun", "single")
            if (d := R.derive(r))]
    if not rows:
        raise SkipBench("no dryrun records under results/dryrun")
    dom: dict[str, int] = {}
    for d in rows:
        dom[d["dominant"]] = dom.get(d["dominant"], 0) + 1
    return [Metric("n_cells", len(rows)),
            Metric("dominant_mix", str(sorted(dom.items())))]


BENCHES: dict[str, callable] = {
    "table1_modes": bench_table1_modes,
    "fig7_array_dse": bench_fig7_array_dse,
    "fig8_osa": bench_fig8_osa,
    "fig9_power_breakdown": bench_fig9_power_breakdown,
    "dse_zoo": bench_dse_zoo,
    "hybrid_zoo": bench_hybrid_zoo,
    "ledger_trace": bench_ledger_trace,
    "table4_hybrid": bench_table4_hybrid,
    "robust_smoke": bench_robust_smoke,
    "compile_cache": bench_compile_cache,
    "serve_smoke": bench_serve_smoke,
    "drift_serve": bench_drift_serve,
    "obs_overhead": bench_obs_overhead,
    "kernel_fusion": bench_kernel_fusion,
    "roofline": bench_roofline,
}


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------
_XLA_COUNTERS = ("xla.cache_hits", "xla.cache_misses", "xla.retraces",
                 "xla.backend_compiles")


def _xla_counts() -> dict[str, float]:
    reg = obs.registry()
    return {n: reg.counter(n).value for n in _XLA_COUNTERS}


def run_benches(names: list[str], quick: bool,
                trace_dir: Path | None = None) -> list[BenchResult]:
    results = []
    for name in names:
        tracer = None
        ctx = contextlib.nullcontext()
        if trace_dir is not None:
            tracer = obs.Tracer()
            ctx = obs.tracing(tracer)
        xla0 = _xla_counts()
        t0 = time.time()
        try:
            with ctx:
                metrics = BENCHES[name](quick)
            # cache warmth recorded per entry (ungated): warm = hits > 0
            # and no new backend compiles escaped the persistent cache
            xla1 = _xla_counts()
            metrics = metrics + [
                Metric(f"{k.replace('.', '_')}", xla1[k] - xla0[k])
                for k in _XLA_COUNTERS]
            res = BenchResult(name=name, status="ok",
                              wall_s=time.time() - t0, metrics=metrics)
        except SkipBench as e:
            res = BenchResult(name=name, status="skipped",
                              wall_s=time.time() - t0, error=str(e))
        except Exception:
            res = BenchResult(name=name, status="failed",
                              wall_s=time.time() - t0,
                              error=traceback.format_exc(limit=8))
        if tracer is not None and len(tracer):
            trace_dir.mkdir(parents=True, exist_ok=True)
            tracer.save(trace_dir / f"{name}.trace.json")
        results.append(res)
        tag = {"ok": "", "skipped": " [skipped]",
               "failed": " [FAILED]"}[res.status]
        detail = "; ".join(f"{m.name}={m.value:.4g}"
                           if isinstance(m.value, float) else
                           f"{m.name}={m.value}" for m in res.metrics)
        print(f">>> {name}{tag} ({res.wall_s:.1f}s) {detail}", flush=True)
        if res.status == "failed":
            print(res.error, file=sys.stderr, flush=True)
    return results


def build_report(results: list[BenchResult], quick: bool,
                 seq: int) -> BenchReport:
    import jax
    return BenchReport(
        bench_seq=seq,
        mode="quick" if quick else "full",
        created_utc=datetime.datetime.now(datetime.timezone.utc)
        .strftime("%Y-%m-%dT%H:%M:%SZ"),
        env={"python": platform.python_version(), "jax": jax.__version__,
             "platform": platform.platform()},
        results=results)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--full", action="store_true",
                    help="full-size benches (default: quick mode)")
    ap.add_argument("--quick", action="store_true",
                    help="quick mode (the default; flag kept for CI "
                         "explicitness)")
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_<n>.json at the repo root")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="override the --json output path")
    ap.add_argument("--seq", type=int, default=None,
                    help="explicit <n> for BENCH_<n>.json")
    ap.add_argument("--only", nargs="+", default=None,
                    choices=sorted(BENCHES),
                    help="run only these benches")
    ap.add_argument("--trace-dir", default=None, metavar="DIR",
                    help="write a Perfetto-loadable Chrome trace per "
                         "bench into DIR")
    ap.add_argument("--list", action="store_true", help="list benches")
    args = ap.parse_args(argv)
    if args.list:
        print("\n".join(BENCHES))
        return 0
    if args.full and args.quick:
        ap.error("--quick and --full are mutually exclusive")

    quick = not args.full
    names = args.only if args.only else list(BENCHES)
    _enable_jax_compile_cache()
    obs.install_jax_hooks()      # XLA retrace/cache counters per bench
    results = run_benches(
        names, quick,
        trace_dir=Path(args.trace_dir) if args.trace_dir else None)

    print("\n== summary ==")
    for r in results:
        print(f"{r.name},{r.status},{r.wall_s:.1f}s,"
              + ";".join(f"{m.name}={m.value}" for m in r.metrics))

    if args.json or args.out:
        path = Path(args.out) if args.out \
            else next_bench_path(REPO_ROOT, args.seq)
        # embedded seq must agree with the file written: explicit --seq
        # wins, else the BENCH_<n>.json filename, else the next repo-root
        # trajectory slot (custom --out names like BENCH_ci.json)
        seq = args.seq
        if seq is None:
            m = re.match(r"BENCH_(\d+)\.json$", path.name)
            seq = int(m.group(1)) if m \
                else int(next_bench_path(REPO_ROOT).stem.split("_")[1])
        save(build_report(results, quick, seq), path)
        print(f"\nwrote {path}")

    failed = [r.name for r in results if r.status == "failed"]
    if failed:
        print(f"\nFAILED benches: {', '.join(failed)}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
