"""Render EXPERIMENTS.md tables from the dry-run JSON records (replaces the
<!-- *_TABLE --> placeholders in-place)."""

from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__)))
from benchmarks import roofline as R  # noqa: E402


def dryrun_table(dir_: str) -> str:
    rows = []
    for path in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        if "__opt" in path:
            continue
        with open(path) as f:
            r = json.load(f)
        if r["status"] == "skip":
            continue
        m = r.get("memory", {})
        rows.append((r["arch"], r["shape"], r["mesh"], r["n_devices"],
                     (m.get("argument_size_in_bytes") or 0) / 2**30,
                     (m.get("temp_size_in_bytes") or 0) / 2**30,
                     r["hlo"]["flops"], r["hlo"]["coll_wire_total"],
                     r.get("compile_s", 0)))
    out = ["| arch | shape | mesh | chips | args GiB/dev | temp GiB/dev | "
           "HLO GF/dev | coll GB/dev | compile s |",
           "|---|---|---|---|---|---|---|---|---|"]
    for a, s, me, n, ab, tb, fl, cw, cs in rows:
        out.append(f"| {a} | {s} | {me} | {n} | {ab:.2f} | {tb:.1f} | "
                   f"{fl / 1e9:.0f} | {cw / 1e9:.2f} | {cs:.0f} |")
    skips = []
    for path in sorted(glob.glob(os.path.join(dir_, "*__single.json"))):
        with open(path) as f:
            r = json.load(f)
        if r["status"] == "skip":
            skips.append(f"- {r['arch']} × {r['shape']}: {r['reason']}")
    return "\n".join(out) + "\n\nDocumented skips (×2 meshes):\n" \
        + "\n".join(skips)


def roofline_table(dir_: str) -> str:
    rows = [d for r in R.load(dir_, "single") if (d := R.derive(r))]
    rows.sort(key=lambda d: (d["arch"], d["shape"]))
    out = ["| arch | shape | compute s | memory s | collective s | dominant "
           "| useful | roofline | what would move the dominant term |",
           "|---|---|---|---|---|---|---|---|---|"]
    notes = {
        ("memory", "train"): "ZeRO-3 layout (§Perf A6) / a2a EP (C4)",
        ("memory", "prefill"): "flash attention kernel; bf16 score buffers",
        ("memory", "decode"): "window-sized caches for local layers; "
                              "quantized (int8) KV",
        ("collective", "train"): "ZeRO-3 layout; bf16 collectives",
        ("collective", "decode"): "flash-decode shard_map (§Perf B2)",
        ("collective", "prefill"): "sequence-parallel attention",
        ("compute", "train"): "remat policy (more HBM headroom needed)",
    }
    for d in rows:
        kind = ("train" if d["shape"].startswith("train") else
                "prefill" if d["shape"].startswith("prefill") else "decode")
        note = notes.get((d["dominant"], kind), "")
        out.append(f"| {d['arch']} | {d['shape']} | {d['compute_s']:.3g} | "
                   f"{d['memory_s']:.3g} | {d['collective_s']:.3g} | "
                   f"{d['dominant']} | {d['useful_ratio']:.2f} | "
                   f"{d['roofline_frac']:.3f} | {note} |")
    return "\n".join(out)


def opt_table(base_dir: str, opt_dir: str) -> str:
    out = ["### Optimized (ZeRO-3 + a2a-EP) train cells, whole fleet",
           "",
           "`dryrun --all --shape train_4k --override "
           "'{\"parallelism\": \"zero3\"}'` — the §Perf A6/C4 layout applied "
           "fleet-wide (single-pod mesh):",
           "",
           "| arch | M baseline s | M zero3 s | X baseline s | X zero3 s | "
           "dominant-term gain |",
           "|---|---|---|---|---|---|"]
    for opt in sorted(glob.glob(os.path.join(opt_dir,
                                             "*__train_4k__single__opt.json"))):
        with open(opt) as f:
            o = json.load(f)
        if o.get("status") != "ok":
            continue
        base_path = os.path.join(
            base_dir, os.path.basename(opt).replace("__opt", ""))
        with open(base_path) as f:
            b = json.load(f)
        bm = b["hlo"]["bytes"] / 819e9
        om = o["hlo"]["bytes"] / 819e9
        bx = b["hlo"]["coll_wire_total"] / 50e9
        ox = o["hlo"]["coll_wire_total"] / 50e9
        gain = 1 - max(om, ox) / max(bm, bx)
        out.append(f"| {o['arch']} | {bm:.1f} | {om:.1f} | {bx:.1f} | "
                   f"{ox:.1f} | {gain * 100:+.0f}% |")
    return "\n".join(out)


def main() -> None:
    md = open("EXPERIMENTS.md").read()
    md = md.replace("<!-- DRYRUN_TABLE -->", dryrun_table("results/dryrun"))
    md = md.replace("<!-- ROOFLINE_TABLE -->",
                    roofline_table("results/dryrun"))
    md = md.replace("<!-- ROOFLINE_NOTES -->", "")
    if glob.glob("results/dryrun_opt/*__train_4k__single__opt.json"):
        md = md.replace("<!-- OPT_TABLE -->",
                        opt_table("results/dryrun", "results/dryrun_opt"))
    open("EXPERIMENTS.md", "w").write(md)
    print("EXPERIMENTS.md tables rendered")


if __name__ == "__main__":
    main()
