"""mistral-large-123b [hf:mistralai/Mistral-Large-Instruct-2407].
Dense 88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768."""

from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b",
    family="dense",
    n_layers=88,
    d_model=12288,
    vocab=32768,
    n_heads=96,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="mistral-large-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    vocab=256,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
)
