"""The paper's hybrid-mapping pipeline on one CNN, end to end:

QAT-train AlexNet-lite on synth-CIFAR -> profile per-layer IS/WS noise
sensitivity (Fig. 6) -> join with the full-size EDP table -> balanced-
metric plan (Sec. 3.5) -> evaluate accuracy + EDP vs WS/IS/analog.

The resulting plan is then lifted into an executable `rosa.Engine` and the
lite model is re-traced with an `EnergyLedger` attached, so the printed
behavioural-trace EDP comes from the very matmuls the plan routed.

Run:  PYTHONPATH=src python examples/hybrid_mapping_cnn.py [--steps 250]
"""

import argparse
import dataclasses
import os
import sys

import jax

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from benchmarks.table4_hybrid import run_model
from repro import rosa
from repro.core import mrr
from repro.core.constants import Mapping, ROSA_OPTIMAL
from repro.models.cnn import LITE_MODELS
from repro.training.cnn_train import QAT_CFG


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="alexnet")
    ap.add_argument("--steps", type=int, default=250)
    args = ap.parse_args()
    res = run_model(args.model, steps=args.steps, n_mc=2)
    plan = {k: Mapping(v) for k, v in res["plan"].items()}

    # lift the plan into the execution API and re-trace the lite model
    specs = LITE_MODELS[args.model]
    ledger = rosa.EnergyLedger()
    engine = rosa.Engine.from_hybrid_plan(
        dataclasses.replace(QAT_CFG, noise=mrr.PAPER_NOISE), plan,
        layers=[s.name for s in specs],
        key=jax.random.PRNGKey(0), ledger=ledger)

    print("\nper-layer plan (resolved through the Engine):")
    for s in specs:
        print(f"  {s.name:10s} -> {engine.config(s.name).mapping.value}")

    from repro.models.cnn import LITE_SKIPS, cnn_apply, cnn_def
    from repro.models.module import abstract_params
    import jax.numpy as jnp
    skel = abstract_params(cnn_def(specs), dtype=jnp.float32)
    jax.eval_shape(lambda p, x: cnn_apply(p, specs, x, engine,
                                          residual_from=LITE_SKIPS.get(
                                              args.model)),
                   skel, jax.ShapeDtypeStruct((8, 32, 32, 3), jnp.float32))
    print(f"\nlite-model behavioural-trace EDP (batch 8, (8,8) array): "
          f"{ledger.edp(ROSA_OPTIMAL):.4g} J*s over {len(ledger)} matmuls")
