"""Differential serving-equivalence suite (repro.serve).

The semantic spec of serving: whatever the continuous-batching scheduler
interleaves — staggered arrivals, mid-stream slot eviction + refill, ragged
prompt lengths and budgets — every request's token stream must equal the
per-request sequential oracle's (`run_sequential`) BIT-exactly under greedy
decoding, and exactly under seeded sampling (keys fold (rid, token index),
so the draw is scheduling-invariant by construction).

Also pinned here: the slot cache API invariants (write/evict touch exactly
one row), chunked-prefill == whole-prefill numerics, the slot-sharded
shard_map step, the optical (rosa) serving path with a pinned fabricated
chip, and per-request energy attribution through ledger scopes.
"""

import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import transformer as T
from repro.models.model import (build_model, evict_slot, pad_cache,
                                read_slot, write_slot)
from repro.serve import (Request, Scheduler, ServeConfig, energy_metrics,
                         poisson_requests, run_sequential,
                         serving_model_config)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _requests(cfg, seed=1, n=6, prompt=(3, 10), gen=(2, 8), stagger=True):
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(0, cfg.vocab, int(rng.integers(*prompt))),
                    int(rng.integers(*gen)),
                    arrival=(i if stagger else 0))
            for i in range(n)]


@pytest.fixture(scope="module")
def smoke_cfg():
    return get_smoke("qwen3-32b")


@pytest.fixture(scope="module")
def sched(smoke_cfg):
    """Shared scheduler: 2 slots so 6 requests force eviction + refill."""
    scfg = ServeConfig(n_slots=2, max_len=24, prefill_chunk=4,
                       collect_logits=True)
    return Scheduler(smoke_cfg, scfg)


# ---------------------------------------------------------------------------
# The differential core
# ---------------------------------------------------------------------------
def _assert_streams_equal(rep, ref, logits=True):
    for rid, r in ref.items():
        comp = rep.completions[rid]
        assert comp.tokens == r["tokens"], (
            f"rid {rid}: {comp.tokens} != {r['tokens']}")
        if logits:
            assert len(comp.logits) == len(r["logits"])
            for a, b in zip(comp.logits, r["logits"]):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_greedy_differential_staggered(smoke_cfg, sched):
    """Continuous batching == sequential, bit-exact logits, with staggered
    arrivals and mid-stream eviction/refill (6 requests through 2 slots)."""
    reqs = _requests(smoke_cfg)
    rep = sched.run(reqs, policy="continuous")
    ref = run_sequential(smoke_cfg, sched.scfg, sched.params, reqs)
    _assert_streams_equal(rep, ref)
    # eviction/refill actually happened: more admissions than slots
    slots = [c.slot for c in rep.completions.values() if c.slot >= 0]
    assert len(slots) > sched.scfg.n_slots
    assert len(set(slots)) <= sched.scfg.n_slots


def test_sampled_differential_seeded(smoke_cfg, sched):
    """Seeded sampling: keys fold (rid, token index), so the continuous
    stream equals the sequential one EXACTLY, not just in distribution."""
    reqs = _requests(smoke_cfg, seed=2)
    rep = sched.run(reqs, policy="continuous", temperature=0.8)
    ref = run_sequential(smoke_cfg, sched.scfg, sched.params, reqs,
                         temperature=0.8)
    _assert_streams_equal(rep, ref, logits=False)
    # sampling actually deviates from greedy somewhere
    greedy = run_sequential(smoke_cfg, sched.scfg, sched.params, reqs)
    assert any(greedy[r.rid]["tokens"] != ref[r.rid]["tokens"]
               for r in reqs)


def test_scheduling_invariance(smoke_cfg, sched):
    """A request's stream must not depend on arrival pattern or batch
    composition: all-at-once vs staggered give identical tokens."""
    reqs_a = _requests(smoke_cfg, seed=3, stagger=True)
    reqs_b = [dataclasses.replace(r, arrival=0) for r in reqs_a]
    rep_a = sched.run(reqs_a, policy="continuous")
    rep_b = sched.run(reqs_b, policy="continuous")
    for r in reqs_a:
        assert rep_a.completions[r.rid].tokens == \
            rep_b.completions[r.rid].tokens


def test_oneshot_matches_sequential_and_loses_throughput(smoke_cfg, sched):
    """The static-batching baseline is CORRECT (same streams) but pays for
    stragglers: ragged budgets waste its slots."""
    reqs = _requests(smoke_cfg, seed=4, n=8, gen=(2, 12))
    ones = sched.run(reqs, policy="oneshot")
    ref = run_sequential(smoke_cfg, sched.scfg, sched.params, reqs)
    _assert_streams_equal(ones, ref)
    cont = sched.run(reqs, policy="continuous")
    assert cont.tokens_per_unit > ones.tokens_per_unit


def test_evict_on_done_policy(smoke_cfg):
    """Paranoid eviction (zero freed slots) must not change any stream."""
    scfg = ServeConfig(n_slots=2, max_len=24, prefill_chunk=4,
                       evict_on_done=True)
    sched = Scheduler(smoke_cfg, scfg)
    reqs = _requests(smoke_cfg, seed=5)
    rep = sched.run(reqs, policy="continuous")
    ref = run_sequential(smoke_cfg, scfg, sched.params, reqs)
    for r in reqs:
        assert rep.completions[r.rid].tokens == ref[r.rid]["tokens"]


def test_ssm_family_differential():
    """ssm caches (conv + SSD state) admit no positional chunking: the
    whole-prompt prefill path must still serve bit-exactly."""
    cfg = get_smoke("mamba2-1.3b")
    scfg = ServeConfig(n_slots=2, max_len=24, prefill_chunk=4)
    sched = Scheduler(cfg, scfg)
    reqs = _requests(cfg, seed=6, n=4)
    rep = sched.run(reqs, policy="continuous")
    ref = run_sequential(cfg, scfg, sched.params, reqs)
    for r in reqs:
        assert rep.completions[r.rid].tokens == ref[r.rid]["tokens"]


def test_windowed_family_differential():
    """gemma-style sliding-window layers under ragged slot positions."""
    cfg = get_smoke("gemma3-12b")
    scfg = ServeConfig(n_slots=2, max_len=24, prefill_chunk=4)
    sched = Scheduler(cfg, scfg)
    reqs = _requests(cfg, seed=7, n=4)
    rep = sched.run(reqs, policy="continuous")
    ref = run_sequential(cfg, scfg, sched.params, reqs)
    for r in reqs:
        assert rep.completions[r.rid].tokens == ref[r.rid]["tokens"]


def test_rosa_differential_with_pinned_chip(smoke_cfg):
    """Optical serving: hybrid plan + pinned StaticVariation chip.  Needs
    act_per_vector quantization — a request's numerics must not depend on
    its batch neighbours (per-tensor scales would couple rows)."""
    scfg = ServeConfig(n_slots=2, max_len=24, prefill_chunk=4, rosa=True,
                       variation_seed=7)
    sched = Scheduler(smoke_cfg, scfg)
    reqs = _requests(smoke_cfg, seed=8, n=4)
    rep = sched.run(reqs, policy="continuous")
    ref = run_sequential(smoke_cfg, scfg, sched.params, reqs,
                         engine=sched.engine)
    for r in reqs:
        assert rep.completions[r.rid].tokens == ref[r.rid]["tokens"]
    assert sched.engine.variation is not None
    assert len(sched.engine.ledger.events) > 0


# ---------------------------------------------------------------------------
# Chunked prefill
# ---------------------------------------------------------------------------
def test_chunked_prefill_matches_whole(smoke_cfg):
    """chunk_step streaming == one-shot prefill, bit-exact with an f32
    cache (bf16 caches differ only by the cast of cross-chunk K/V reads)."""
    cfg = dataclasses.replace(serving_model_config(smoke_cfg),
                              cache_dtype=jnp.float32)
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    max_len, C, L = 24, 4, 11
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, L), 0,
                                cfg.vocab, dtype=jnp.int32)
    logits_w, cache_w = jax.jit(bundle.prefill)(
        params, {"tokens": prompt})
    cache_w = pad_cache(cfg, cache_w, max_len - L)

    cache = T.init_cache(cfg, 1, max_len)
    step = jax.jit(bundle.chunk_step)
    off = 0
    while off < L:
        n = min(C, L - off)
        chunk = jnp.pad(prompt[:, off:off + n], ((0, 0), (0, C - n)))
        logits_c, cache = step(params, {"tokens": chunk,
                                        "n_valid": jnp.full((1,), n,
                                                            jnp.int32),
                                        "cache": cache})
        off += n
    assert int(cache["pos"][0]) == L
    np.testing.assert_array_equal(np.asarray(logits_w),
                                  np.asarray(logits_c))
    k_w = np.asarray(cache_w["layers"][0][:, :, :L])
    k_c = np.asarray(cache["layers"][0][:, :, :L])
    np.testing.assert_array_equal(k_w, k_c)


def test_chunk_step_rejects_ssm():
    cfg = get_smoke("mamba2-1.3b")
    bundle = build_model(cfg)
    with pytest.raises(ValueError, match="chunked prefill"):
        bundle.chunk_step(None, {})


# ---------------------------------------------------------------------------
# Slot cache API
# ---------------------------------------------------------------------------
def test_slot_write_evict_roundtrip(smoke_cfg):
    cfg = serving_model_config(smoke_cfg)
    rng = jax.random.PRNGKey(0)
    big = T.init_cache(cfg, 3, 16)
    big = jax.tree.map(
        lambda a: jax.random.normal(rng, a.shape).astype(a.dtype)
        if jnp.issubdtype(a.dtype, jnp.floating) else a, big)
    req = T.init_cache(cfg, 1, 16)
    req = jax.tree.map(
        lambda a: (jax.random.normal(jax.random.PRNGKey(1),
                                     a.shape) + 1).astype(a.dtype)
        if jnp.issubdtype(a.dtype, jnp.floating) else a + 7, req)

    out = jax.jit(lambda b, r, s: write_slot(cfg, b, r, s))(big, req, 1)
    back = read_slot(cfg, out, 1)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(req)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # untouched rows are byte-identical
    for s in (0, 2):
        for a, b in zip(jax.tree.leaves(read_slot(cfg, out, s)),
                        jax.tree.leaves(read_slot(cfg, big, s))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # invalid write is a no-op
    noop = jax.jit(lambda b, r, s: write_slot(cfg, b, r, s, False))(
        big, req, 1)
    for a, b in zip(jax.tree.leaves(noop), jax.tree.leaves(big)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # eviction zeroes exactly one row
    ev = jax.jit(lambda b, s: evict_slot(cfg, b, s))(out, 1)
    assert all(float(jnp.abs(a).sum()) == 0.0
               for a in jax.tree.leaves(read_slot(cfg, ev, 1)))
    for a, b in zip(jax.tree.leaves(read_slot(cfg, ev, 0)),
                    jax.tree.leaves(read_slot(cfg, out, 0))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_request_validation(smoke_cfg, sched):
    with pytest.raises(ValueError, match="max_new_tokens"):
        Request(0, np.zeros(4, np.int32), 0)
    too_long = Request(0, np.zeros(20, np.int32), 10)
    with pytest.raises(ValueError, match="max_len"):
        sched.run([too_long])
    # prompt == max_len must be rejected UPFRONT (same bound as
    # PrefillTask), not crash mid-stream at the prefill stage
    edge = Request(0, np.zeros(sched.scfg.max_len, np.int32), 1)
    with pytest.raises(ValueError, match="no decode room"):
        sched.run([edge])


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_act_per_vector_decouples_rows(backend):
    """EVERY optical backend must honor act_per_vector: a row's result is
    identical whether it shares the batch with an outlier or not (the
    pallas kernel runs in interpret mode on CPU)."""
    from repro import rosa

    cfg = rosa.RosaConfig(backend=backend, act_per_vector=True)
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(k1, (3, 16))
    w = jax.random.normal(k2, (16, 8))
    outlier = jnp.concatenate([x, 100.0 * jnp.ones((1, 16))], 0)
    y_alone = rosa.rosa_matmul(x, w, cfg)
    y_shared = rosa.rosa_matmul(outlier, w, cfg)[:3]
    np.testing.assert_array_equal(np.asarray(y_alone),
                                  np.asarray(y_shared))


def test_loadgen_deterministic(smoke_cfg):
    a = poisson_requests(8, 0.7, vocab=smoke_cfg.vocab, seed=3)
    b = poisson_requests(8, 0.7, vocab=smoke_cfg.vocab, seed=3)
    for x, y in zip(a, b):
        assert x.arrival == y.arrival and x.max_new_tokens == y.max_new_tokens
        np.testing.assert_array_equal(x.prompt, y.prompt)
    assert all(a[i].arrival <= a[i + 1].arrival for i in range(7))
    c = poisson_requests(8, 0.7, vocab=smoke_cfg.vocab, seed=4)
    assert any(not np.array_equal(x.prompt, y.prompt)
               for x, y in zip(a, c))


def test_empty_report_percentiles_are_typed_sentinels():
    """Percentiles over zero completions return the falsy `EmptyStat`
    sentinel (NaN via float()) instead of a silent bare NaN — short drift
    scenarios legitimately slice reports down to empty sets."""
    import math

    from repro.serve import EmptyStat, ServeReport

    rep = ServeReport(policy="continuous", completions={}, n_slots=2)
    for stat in (rep.percentile(99), rep.wall_percentile_ms(50, "ttft")):
        assert isinstance(stat, EmptyStat)
        assert not stat                          # falsy: `or default` works
        assert math.isnan(float(stat))           # legacy float() sites
    assert rep.percentile(99).q == 99
    assert rep.wall_percentile_ms(50, "ttft").kind == "ttft"


def test_report_metrics_surface(smoke_cfg, sched):
    """The bench-schema metric view of a run: gated metrics are the
    deterministic (step-unit / tick) ones; wall-clock never gates."""
    from repro.serve import report_metrics

    reqs = _requests(smoke_cfg, seed=9, n=3)
    rep = sched.run(reqs, policy="continuous")
    ms = {m.name: m for m in report_metrics(rep)}
    assert ms["total_tokens"].value == sum(r.max_new_tokens for r in reqs)
    assert ms["tokens_per_unit"].gate and ms["latency_p99_ticks"].gate
    assert not ms["tokens_per_s"].gate and not ms["wall_s"].gate
    assert 0 < ms["occupancy"].value <= 1.0
    assert rep.percentile(50) <= rep.percentile(99)


# ---------------------------------------------------------------------------
# Energy attribution
# ---------------------------------------------------------------------------
def test_energy_attribution(smoke_cfg):
    scfg = ServeConfig(n_slots=4, max_len=24, prefill_chunk=4)
    ms = {m.name: m for m in energy_metrics(smoke_cfg, scfg)}
    assert ms["energy_per_token_j"].value > 0
    assert ms["energy_per_token_j"].gate
    # the hybrid plan can only improve on pure WS
    assert 0 < ms["decode_edp_hybrid_vs_ws"].value <= 1.0 + 1e-12
    assert ms["energy_per_prefill_chunk_j"].value > 0


def test_ledger_scopes(smoke_cfg):
    """Prefill and decode traces attribute to distinct scopes on ONE
    ledger, so per-request energy = prompt chunks + tokens x decode."""
    from repro.serve.metrics import build_serving_engine, \
        trace_serving_shapes

    scfg = ServeConfig(n_slots=2, max_len=24, prefill_chunk=4)
    bundle = build_model(serving_model_config(smoke_cfg, rosa=True))
    engine = build_serving_engine(bundle, scfg)
    ledger = trace_serving_shapes(bundle, scfg, engine)
    tags = {ev.tag for ev in ledger.events}
    assert tags == {"decode", "prefill"}
    from repro.core.constants import ROSA_OPTIMAL
    e_dec = ledger.breakdown(ROSA_OPTIMAL, batch=1, tag="decode").energy
    e_pre = ledger.breakdown(ROSA_OPTIMAL, batch=1, tag="prefill").energy
    e_all = ledger.breakdown(ROSA_OPTIMAL, batch=1).energy
    assert e_dec > 0 and e_pre > 0 and e_all > 0
    # the trace already carries the slot batch in m: per_token prices it
    # as-is and only DIVIDES by the slot count (no double-batching)
    assert ledger.per_token(ROSA_OPTIMAL, batch=2) == \
        pytest.approx(e_dec / 2)


def test_runtime_ledger_is_tagged(smoke_cfg):
    """The scheduler's own run-time ledger must attribute events to
    prefill/decode scopes — otherwise per_token (tag='decode') prices an
    empty set and reports 0."""
    from repro.core.constants import ROSA_OPTIMAL

    scfg = ServeConfig(n_slots=2, max_len=24, prefill_chunk=4, rosa=True)
    sched = Scheduler(smoke_cfg, scfg)
    reqs = _requests(smoke_cfg, seed=11, n=2)
    sched.run(reqs, policy="continuous")
    tags = {ev.tag for ev in sched.engine.ledger.events}
    assert "decode" in tags and "prefill" in tags
    assert sched.engine.ledger.per_token(ROSA_OPTIMAL,
                                         batch=scfg.n_slots) > 0


def test_encdec_serving_rejected():
    cfg = get_smoke("seamless-m4t-medium")
    with pytest.raises(NotImplementedError, match="encoder-decoder"):
        Scheduler(cfg, ServeConfig(n_slots=2, max_len=24))


# ---------------------------------------------------------------------------
# Slot-axis sharding (shard_map) — needs >1 device, so subprocess
# ---------------------------------------------------------------------------
_SHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import numpy as np, jax
from repro.configs import get_smoke
from repro.serve import ServeConfig, Scheduler, Request, run_sequential

cfg = get_smoke("qwen3-32b")
scfg = ServeConfig(n_slots=4, max_len=24, prefill_chunk=4)
mesh = jax.make_mesh((2,), ("data",))
rng = np.random.default_rng(3)
rs = [Request(i, rng.integers(0, cfg.vocab, int(rng.integers(3, 10))),
              int(rng.integers(2, 8)), arrival=i) for i in range(6)]
sched = Scheduler(cfg, scfg, mesh=mesh)
rep = sched.run(rs, policy="continuous")
ref = run_sequential(cfg, scfg, sched.params, rs)
assert all(rep.completions[r.rid].tokens == ref[r.rid]["tokens"]
           for r in rs), "sharded streams diverged"
print("OK")
"""


def test_sharded_serve_step_matches_oracle():
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", _SHARD_SCRIPT],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, (r.stdout[-1000:], r.stderr[-2000:])
    assert "OK" in r.stdout


# ---------------------------------------------------------------------------
# Donation canaries: the compiled HLO must actually alias the state
# ---------------------------------------------------------------------------
def _abstract_serve_parts(cfg, scfg):
    from repro.serve import decode as D
    bundle = build_model(cfg)
    params = bundle.abstract(jnp.float32)
    state = jax.eval_shape(lambda: D.init_state(cfg, scfg))
    admit = jax.eval_shape(lambda: D.null_admit(cfg, scfg))
    return bundle, params, state, admit


def test_serve_step_donation_canary(smoke_cfg):
    """Pin: every buffer of the donated DecodeState comes back as a real
    `input_output_alias` in the compiled serve step — a regression here
    means the hot loop silently double-buffers the KV cache."""
    from repro.analysis import AnalysisTarget, run_checks
    from repro.serve import decode as D

    scfg = ServeConfig(n_slots=2, max_len=24, prefill_chunk=4)
    bundle, params, state, admit = _abstract_serve_parts(smoke_cfg, scfg)
    temp = jax.ShapeDtypeStruct((), jnp.float32)
    step = D.make_serve_step(bundle, scfg)
    t = AnalysisTarget("canary:serve_step", step,
                       (params, state, admit, temp),
                       donate_argnums=(1,), hot_path=True)
    assert list(run_checks([t], checks=["donation"])) == []


def test_admit_and_evict_donation_canary(smoke_cfg):
    from repro.analysis import AnalysisTarget, run_checks
    from repro.serve import decode as D

    scfg = ServeConfig(n_slots=2, max_len=24, prefill_chunk=4)
    bundle, _, state, admit = _abstract_serve_parts(smoke_cfg, scfg)
    slot = jax.ShapeDtypeStruct((), jnp.int32)
    ts = [AnalysisTarget("canary:admit", D.make_admit_step(bundle, scfg),
                         (state, admit), donate_argnums=(0,),
                         hot_path=True),
          AnalysisTarget("canary:evict", D.make_evict(bundle, scfg),
                         (state, slot), donate_argnums=(0,),
                         hot_path=True)]
    assert list(run_checks(ts, checks=["donation"])) == []


def test_serve_step_alias_map_nonempty(smoke_cfg):
    """Raw-HLO pin (independent of the analysis machinery): the serve
    step's module text carries one alias per DecodeState array leaf."""
    from repro.analysis.hlo import parse_input_output_aliases
    from repro.serve import decode as D

    scfg = ServeConfig(n_slots=2, max_len=24, prefill_chunk=4)
    bundle, params, state, admit = _abstract_serve_parts(smoke_cfg, scfg)
    temp = jax.ShapeDtypeStruct((), jnp.float32)
    step = D.make_serve_step(bundle, scfg)
    txt = step.lower(params, state, admit, temp).compile().as_text()
    n_state_leaves = len(jax.tree.leaves(state))
    assert len(parse_input_output_aliases(txt)) >= n_state_leaves
