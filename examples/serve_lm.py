"""Batched LM serving demo: prefill a prompt batch and decode greedily.

Uses the reduced zamba2 (hybrid SSM + shared-attention) config so the
example exercises the most interesting cache machinery: per-group shared
KV caches + SSD states + conv states.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import subprocess
import sys

if __name__ == "__main__":
    raise SystemExit(subprocess.call(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "zamba2-1.2b",
         "--smoke", "--batch", "4", "--prompt-len", "32", "--gen", "16",
         "--temperature", "0.7"]))
