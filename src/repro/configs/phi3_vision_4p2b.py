"""phi-3-vision-4.2b [hf:microsoft/Phi-3-vision-128k-instruct].

Backbone: 32L d_model=3072 32H (MHA kv=32) d_ff=8192 vocab=32064.
The CLIP frontend is a STUB per the assignment: input_specs provides
precomputed patch embeddings that are prepended to the text tokens."""

from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="dense",
    n_layers=32,
    d_model=3072,
    vocab=32064,
    n_heads=32,
    n_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    rope_theta=1e4,
    frontend="vision",
)

SMOKE = ModelConfig(
    name="phi3v-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    vocab=256,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    frontend="vision",
)
