"""seamless-m4t-medium [arXiv:2308.11596]. Enc-dec 12L+12L d_model=1024
16H d_ff=4096 vocab=256206.  The audio frontend is a STUB per the
assignment: input_specs provides precomputed frame embeddings (B, S, D)."""

from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,
    n_enc_layers=12,
    d_model=1024,
    vocab=256206,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    rope_theta=1e4,
    frontend="audio",
)

SMOKE = ModelConfig(
    name="seamless-smoke",
    family="encdec",
    n_layers=2,
    n_enc_layers=2,
    d_model=64,
    vocab=256,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    frontend="audio",
)
