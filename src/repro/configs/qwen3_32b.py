"""qwen3-32b [hf:Qwen/Qwen3-32B family]. Dense 64L d_model=5120 64H
(GQA kv=8) d_ff=25600 vocab=151936, qk_norm."""

from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    vocab=151936,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=25600,
    qk_norm=True,
    rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="qwen3-32b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    vocab=256,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    qk_norm=True,
)
