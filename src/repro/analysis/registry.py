"""Check registry: name -> check function.

A check is `check(target: AnalysisTarget) -> list[Finding]`.  Each check
decides its own applicability (a target with no callable skips the jaxpr
checks; one with no gemm_shapes skips the Pallas preflight) and returns
[] rather than raising when it has nothing to say.  A check that itself
crashes becomes an ERROR finding with code CHECKFAIL — the verifier must
never mask a target's real findings behind its own stack trace.
"""

from __future__ import annotations

import traceback
from typing import Callable, Iterable, Sequence

from repro.analysis.findings import AnalysisReport, Finding, Severity
from repro.analysis.target import AnalysisTarget

CheckFn = Callable[[AnalysisTarget], "list[Finding]"]

_REGISTRY: dict[str, CheckFn] = {}


def register(name: str) -> Callable[[CheckFn], CheckFn]:
    """Register a check under `name` (its Finding.check namespace)."""

    def deco(fn: CheckFn) -> CheckFn:
        if name in _REGISTRY:
            raise ValueError(f"check {name!r} already registered")
        _REGISTRY[name] = fn
        return fn

    return deco


def all_checks() -> dict[str, CheckFn]:
    from repro.analysis import checks as _checks  # noqa: F401  (registers)
    return dict(_REGISTRY)


def run_checks(targets: Iterable[AnalysisTarget],
               checks: Sequence[str] | None = None) -> AnalysisReport:
    """Run `checks` (default: all registered) over every target."""
    table = all_checks()
    if checks is not None:
        unknown = set(checks) - set(table)
        if unknown:
            raise ValueError(
                f"unknown checks {sorted(unknown)}; "
                f"registered: {sorted(table)}")
        table = {k: table[k] for k in checks}
    findings: list[Finding] = []
    for target in targets:
        for cname, check in table.items():
            try:
                findings.extend(check(target))
            except Exception:
                findings.append(Finding(
                    check=cname, code="CHECKFAIL", severity=Severity.ERROR,
                    subject=target.name, location=cname,
                    message=("check crashed: "
                             + traceback.format_exc(limit=3).strip())))
    return AnalysisReport(tuple(findings))
