"""Versioned ``BENCH_<n>.json`` schema: dataclasses + validation + I/O.

Layout (schema_version = 1):

    {
      "schema_version": 1,
      "bench_seq": 2,                  # the <n> in BENCH_<n>.json
      "created_utc": "2026-07-30T12:00:00Z",
      "mode": "quick" | "full",
      "env": {"python": "...", "jax": "...", "platform": "..."},
      "results": [
        {
          "name": "fig7_array_dse",
          "status": "ok" | "failed" | "skipped",
          "wall_s": 1.23,
          "error": "",                 # traceback tail when status=failed
          "metrics": [
            {"name": "reduction_vs_deap", "value": 0.64, "unit": "frac",
             "gate": true, "rel_tol": 0.05, "direction": "higher_is_better"}
          ]
        }, ...
      ]
    }

Gating semantics live on the metric: only ``gate: true`` metrics are
compared by `repro.bench.compare`; ``direction`` says which way a change
counts as a regression, ``rel_tol`` how much drift is tolerated.  Wall
times and stochastic metrics (tiny-step training accuracies) ship with
``gate: false`` — recorded for trend plots, never gating CI.
"""

from __future__ import annotations

import dataclasses
import json
import re
from pathlib import Path

SCHEMA_VERSION = 1

_STATUSES = ("ok", "failed", "skipped")
_DIRECTIONS = ("both", "higher_is_better", "lower_is_better")
_BENCH_RE = re.compile(r"^BENCH_(\d+)\.json$")


class SchemaError(ValueError):
    """A report violated the BENCH_<n>.json schema."""


@dataclasses.dataclass
class Metric:
    name: str
    value: float | int | str
    unit: str = ""
    gate: bool = False
    rel_tol: float = 0.05
    direction: str = "both"         # both | higher_is_better | lower_is_better


@dataclasses.dataclass
class BenchResult:
    name: str
    status: str = "ok"              # ok | failed | skipped
    wall_s: float = 0.0
    error: str = ""
    metrics: list[Metric] = dataclasses.field(default_factory=list)

    def metric(self, name: str) -> Metric | None:
        for m in self.metrics:
            if m.name == name:
                return m
        return None


@dataclasses.dataclass
class BenchReport:
    bench_seq: int
    mode: str = "quick"
    created_utc: str = ""
    env: dict[str, str] = dataclasses.field(default_factory=dict)
    results: list[BenchResult] = dataclasses.field(default_factory=list)
    schema_version: int = SCHEMA_VERSION

    def result(self, name: str) -> BenchResult | None:
        for r in self.results:
            if r.name == name:
                return r
        return None

    def gated_metrics(self) -> dict[tuple[str, str], Metric]:
        """{(bench, metric): Metric} for every gate=true metric."""
        return {(r.name, m.name): m for r in self.results
                for m in r.metrics if m.gate}

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


# ---------------------------------------------------------------------------
# Validation
# ---------------------------------------------------------------------------
def _expect(cond: bool, msg: str) -> None:
    if not cond:
        raise SchemaError(msg)


def validate(doc: dict | BenchReport) -> None:
    """Raise `SchemaError` unless `doc` is a schema-valid report."""
    if isinstance(doc, BenchReport):
        doc = doc.to_dict()
    _expect(isinstance(doc, dict), "report must be a JSON object")
    _expect(doc.get("schema_version") == SCHEMA_VERSION,
            f"schema_version must be {SCHEMA_VERSION}, "
            f"got {doc.get('schema_version')!r}")
    _expect(isinstance(doc.get("bench_seq"), int) and doc["bench_seq"] >= 0,
            "bench_seq must be a non-negative int")
    _expect(doc.get("mode") in ("quick", "full"),
            f"mode must be quick|full, got {doc.get('mode')!r}")
    _expect(isinstance(doc.get("env"), dict), "env must be an object")
    _expect(isinstance(doc.get("results"), list), "results must be a list")
    seen = set()
    for r in doc["results"]:
        _expect(isinstance(r, dict), "each result must be an object")
        name = r.get("name")
        _expect(isinstance(name, str) and name, "result.name must be set")
        _expect(name not in seen, f"duplicate bench name {name!r}")
        seen.add(name)
        _expect(r.get("status") in _STATUSES,
                f"{name}: status must be one of {_STATUSES}")
        _expect(isinstance(r.get("wall_s"), (int, float))
                and r["wall_s"] >= 0, f"{name}: wall_s must be >= 0")
        _expect(r.get("status") != "failed" or r.get("error"),
                f"{name}: failed result must carry an error")
        _expect(isinstance(r.get("metrics", []), list),
                f"{name}: metrics must be a list")
        mseen = set()
        for m in r.get("metrics", []):
            _expect(isinstance(m, dict), f"{name}: each metric must be "
                                         f"an object")
            mname = m.get("name")
            _expect(isinstance(mname, str) and mname,
                    f"{name}: metric.name must be set")
            _expect(mname not in mseen,
                    f"{name}: duplicate metric {mname!r}")
            mseen.add(mname)
            _expect(isinstance(m.get("value"), (int, float, str)),
                    f"{name}.{mname}: value must be number or string")
            _expect(m.get("direction", "both") in _DIRECTIONS,
                    f"{name}.{mname}: direction must be one of {_DIRECTIONS}")
            rel_tol = m.get("rel_tol", 0.0)
            _expect(isinstance(rel_tol, (int, float)) and rel_tol >= 0,
                    f"{name}.{mname}: rel_tol must be >= 0")
            _expect(not (m.get("gate") and isinstance(m["value"], float)
                         and m["value"] != m["value"]),
                    f"{name}.{mname}: gated metric value is NaN")


# ---------------------------------------------------------------------------
# I/O
# ---------------------------------------------------------------------------
def from_dict(doc: dict) -> BenchReport:
    validate(doc)
    results = [
        BenchResult(
            name=r["name"], status=r["status"], wall_s=float(r["wall_s"]),
            error=r.get("error", ""),
            # rel_tol omitted in hand-edited JSON means EXACT (0.0), the
            # same default validate() checks against — only metrics that
            # declare a tolerance get one
            metrics=[Metric(name=m["name"], value=m["value"],
                            unit=m.get("unit", ""),
                            gate=bool(m.get("gate", False)),
                            rel_tol=float(m.get("rel_tol", 0.0)),
                            direction=m.get("direction", "both"))
                     for m in r.get("metrics", [])])
        for r in doc["results"]
    ]
    return BenchReport(bench_seq=doc["bench_seq"], mode=doc["mode"],
                       created_utc=doc.get("created_utc", ""),
                       env=dict(doc["env"]), results=results)


def load(path: str | Path) -> BenchReport:
    with open(path) as f:
        return from_dict(json.load(f))


def save(report: BenchReport, path: str | Path) -> Path:
    validate(report)
    path = Path(path)
    with open(path, "w") as f:
        json.dump(report.to_dict(), f, indent=1, sort_keys=False)
        f.write("\n")
    return path


def next_bench_path(root: str | Path, seq: int | None = None) -> Path:
    """``BENCH_<n>.json`` under `root`: explicit `seq`, or one past the
    highest existing index (the trajectory starts at BENCH_2 — PR 2 is the
    first to emit reports)."""
    root = Path(root)
    if seq is None:
        existing = [int(m.group(1)) for p in root.glob("BENCH_*.json")
                    if (m := _BENCH_RE.match(p.name))]
        seq = max(existing) + 1 if existing else 2
    return root / f"BENCH_{seq}.json"
