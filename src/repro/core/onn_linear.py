"""`rosa_matmul` — the paper's MAC engine as a drop-in JAX matmul.

Forward semantics (mixed digital-analog mode, Sec. 2-3.1):

  WS mapping: weights are programmed onto TO-tuned analog MRRs through the
    noisy voltage chain (mrr.realize_weights); activations take the exact
    digital EO path (8-bit signed-digit streams) and accumulate via OSA.
  IS mapping: the roles swap — activations are realized on the noisy analog
    MRRs, weights travel the exact digital path.
  ANALOG mode (DEAP baseline): both operands pass the noisy analog chain.

Backward semantics: straight-through — gradients flow as if the matmul were
exact.  This makes every model in the zoo noise-aware-trainable (QAT) with
zero graph surgery, which is how the paper fine-tunes its 8-bit CNNs.

The heavy path (bit-plane decomposition + per-plane MXU matmuls + power-of-
two recombination) is the Pallas kernel in kernels/osa_matmul; this module
chooses between the kernel and the pure-jnp reference depending on platform
and carries the custom_vjp.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import mrr, osa, quant
from repro.core.constants import ComputeMode, Mapping


@dataclasses.dataclass(frozen=True)
class RosaConfig:
    """Per-layer execution config for the optical backend."""

    mapping: Mapping = Mapping.WS
    mode: ComputeMode = ComputeMode.MIXED
    quant_bits: int = 8
    pam_bits: int = 1
    noise: mrr.NoiseModel = mrr.IDEAL
    osa_cfg: osa.OSAConfig = osa.IDEAL_OSA
    mrr_params: mrr.MRRParams = mrr.DEFAULT_PARAMS
    use_kernel: bool = False    # route through the Pallas kernel (TPU path)

    @property
    def qcfg(self) -> quant.QuantConfig:
        return quant.QuantConfig(bits=self.quant_bits)


DEFAULT = RosaConfig()


def _noisy_realize(t: jax.Array, cfg: RosaConfig, key: jax.Array | None):
    """Quantize a tensor to cfg.quant_bits and realize it on analog MRRs.

    Values are normalized per-tensor to the MRR weight range [q_min, q_max],
    programmed through the physical chain with DAC/thermal noise, and
    de-normalized.  This is where WS puts weights and IS puts activations.
    """
    scale = jnp.maximum(jnp.max(jnp.abs(t)), 1e-8)
    q = quant.fake_quant(t / scale, cfg.qcfg)          # 8-bit grid in [-1,1]
    w = mrr.realize_weights(q, key, cfg.mrr_params, cfg.noise)
    return w * scale


def _digital_path(t: jax.Array, cfg: RosaConfig):
    """Exact digital EO encoding: quantization is the only error source."""
    return quant.fake_quant(t, cfg.qcfg)


def _forward(x: jax.Array, w: jax.Array, cfg: RosaConfig,
             key: jax.Array | None) -> jax.Array:
    if cfg.mode is ComputeMode.MIXED:
        if cfg.noise.is_ideal and cfg.osa_cfg.is_ideal and not cfg.use_kernel:
            # exactness-preserving shortcut: ideal OSA over signed-digit
            # planes == fake-quant matmul (tests/test_osa.py asserts this),
            # so QAT training skips the 7-plane decomposition entirely.
            return _digital_path(x, cfg) @ _digital_path(w, cfg)
        if key is not None:
            k_a, k_b = jax.random.split(key)
        else:
            k_a = k_b = None
        if cfg.mapping in (Mapping.WS, Mapping.GEMM):
            w_eff = _noisy_realize(w, cfg, k_a) if not cfg.noise.is_ideal \
                else _digital_path(w, cfg)
            x_eff = _digital_path(x, cfg)
        else:  # IS: inputs on the analog rings, weights exact digital
            w_eff = _digital_path(w, cfg)
            x_eff = _noisy_realize(x, cfg, k_a) if not cfg.noise.is_ideal \
                else _digital_path(x, cfg)
        del k_b
        if cfg.use_kernel:
            from repro.kernels.osa_matmul import ops as osa_ops
            return osa_ops.osa_matmul(x_eff, w_eff, quant_bits=cfg.quant_bits,
                                      pam_bits=cfg.pam_bits)
        return osa.osa_matmul_ref(x_eff, w_eff, cfg.osa_cfg, cfg.qcfg)
    elif cfg.mode is ComputeMode.ANALOG:
        if key is not None:
            k_a, k_b = jax.random.split(key)
        else:
            k_a = k_b = None
        w_eff = _noisy_realize(w, cfg, k_a) if not cfg.noise.is_ideal \
            else _digital_path(w, cfg)
        x_eff = _noisy_realize(x, cfg, k_b) if not cfg.noise.is_ideal \
            else _digital_path(x, cfg)
        return x_eff @ w_eff                      # single-shot analog readout
    elif cfg.mode is ComputeMode.DIGITAL:
        return _digital_path(x, cfg) @ _digital_path(w, cfg)
    raise ValueError(cfg.mode)


@partial(jax.custom_vjp, nondiff_argnums=(2,))
def rosa_matmul(x: jax.Array, w: jax.Array, cfg: RosaConfig = DEFAULT,
                key: jax.Array | None = None) -> jax.Array:
    """Optical matmul  y = x @ w  through the configured ROSA pipeline.

    x: (..., K) activations; w: (K, N) weights; returns (..., N).
    Straight-through gradients w.r.t. both x and w.
    """
    lead = x.shape[:-1]
    y = _forward(x.reshape(-1, x.shape[-1]), w, cfg, key)
    return y.reshape(*lead, w.shape[-1])


def _fwd(x, w, cfg, key):
    return rosa_matmul(x, w, cfg, key), (x, w)


def _bwd(cfg, res, g):
    x, w = res
    lead = g.shape[:-1]
    g2 = g.reshape(-1, g.shape[-1])
    x2 = x.reshape(-1, x.shape[-1])
    dx = (g2 @ w.T).reshape(x.shape)
    dw = x2.T @ g2
    return dx, dw, None


rosa_matmul.defvjp(_fwd, _bwd)


def make_backend(cfg: RosaConfig):
    """Callable matmul backend for models.module.MatmulBackend routing."""
    def matmul(x, w, key=None):
        return rosa_matmul(x, w, cfg, key)
    return matmul
