"""Device constants for the ROSA MRR-ONN model.

Sources: paper Table 2 (microring / thermo-optic model) and Table 3
(per-component static and dynamic energies).  All values are kept in SI with
the unit recorded next to each constant.

A note on internal consistency (documented, not hidden):  Table 2's published
constants (R_h = 50 ohm, R_th = 2 K/mW) reproduce the thermal tuning
efficiency eta_lambdaP ~= 0.238 nm/mW of Eq. (9) exactly, but they *cannot*
simultaneously reproduce Fig. 5(b)'s measured 0.740 nm resonance shift over
the 1 V..3 V drive range (they over-predict it by ~51x, because V^2/R_h over
that range sweeps 160 mW of electrical power while 0.740 nm only requires
~3.1 mW of *heater* power at 0.238 nm/mW).  Physical heaters never couple all
electrical power into the ring; we therefore introduce an explicit heater
coupling efficiency ``HEATER_COUPLING`` calibrated so that the 1->3 V sweep
produces exactly the paper's 0.740 nm shift while eta_lambdaP (per unit of
*coupled* heater power) stays at 0.238 nm/mW.  See DESIGN.md section 8.
"""

from __future__ import annotations

import dataclasses
import enum
import math


# --------------------------------------------------------------------------
# Table 2 — microring and thermo-optic model
# --------------------------------------------------------------------------
LAMBDA_0_NM = 1538.74          # nominal resonance wavelength [nm]
LAMBDA_REF_NM = 1538.26        # probe (reference) wavelength [nm]
ATTENUATION_A = 0.925          # round-trip attenuation factor [-]
N_EFF = 2.4                    # effective refractive index [-]
GAMMA_HWHM_NM = 0.7534         # half-width at half-maximum [nm]
R_HEATER_OHM = 50.0            # heater resistance [ohm]
R_THERMAL_K_PER_MW = 2.0       # thermal resistance [K/mW]
BETA_TO_PER_K = 1.86e-4        # thermo-optic coefficient [1/K]

# Drive-voltage operating range used in Fig. 5(b).
V_MIN = 1.0                    # [V]
V_MAX = 3.0                    # [V]
MAX_SHIFT_NM = 0.740           # Fig. 5(b): max resonance shift over V range [nm]

# Calibrated heater coupling efficiency (see module docstring).  Solved so
# that delta_lambda(V_MAX) - delta_lambda(V_MIN) == MAX_SHIFT_NM given the
# Table 2 constants.  Solved in closed form below.


def _solve_heater_coupling() -> float:
    """kappa s.t. the 1->3 V sweep gives exactly MAX_SHIFT_NM of shift.

    delta_lambda(dT) = lambda0 * beta*dT / (n0 + beta*dT)  with
    dT(V) = kappa * (V^2 / R_h) * 1000 * R_th   [V^2/R_h in W -> mW].

    Since delta_lambda is the composition of two increasing maps, the sweep
    shift is f(kappa*P3) - f(kappa*P1) with P in mW; solve by bisection (the
    equation is scalar and monotone in kappa).
    """
    p1_mw = (V_MIN ** 2 / R_HEATER_OHM) * 1e3
    p3_mw = (V_MAX ** 2 / R_HEATER_OHM) * 1e3

    def shift(kappa: float) -> float:
        def dl(p_mw: float) -> float:
            dt = kappa * p_mw * R_THERMAL_K_PER_MW
            return LAMBDA_0_NM * BETA_TO_PER_K * dt / (N_EFF + BETA_TO_PER_K * dt)
        return dl(p3_mw) - dl(p1_mw)

    lo, hi = 0.0, 1.0
    for _ in range(80):
        mid = 0.5 * (lo + hi)
        if shift(mid) < MAX_SHIFT_NM:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


HEATER_COUPLING = _solve_heater_coupling()   # ~= 0.0194

# Thermal tuning efficiency, Eq. (9): d(lambda)/d(P_heater) [nm/mW].
ETA_LAMBDA_P_NM_PER_MW = LAMBDA_0_NM * BETA_TO_PER_K / N_EFF * R_THERMAL_K_PER_MW
assert abs(ETA_LAMBDA_P_NM_PER_MW - 0.238) < 2e-3, ETA_LAMBDA_P_NM_PER_MW

# --------------------------------------------------------------------------
# Table 3 — per-component static and dynamic energy
# --------------------------------------------------------------------------
LASER_STATIC_W = 1.38e-3            # per wavelength channel [W]
MRR_TO_STATIC_W = 1.58e-3           # avg thermal hold power per weight MRR [W]
#   (paper: resonance shift range = gamma/2 -> 0.5*gamma / eta_lambdaP = 1.58 mW)
assert abs(0.5 * GAMMA_HWHM_NM / ETA_LAMBDA_P_NM_PER_MW - 1.58) < 2e-2
MRR_EO_DYNAMIC_J_PER_BIT = 6.3e-15  # EO modulation energy [J/bit]
DAC_J_PER_BIT = 5.2e-12             # DAC conversion energy [J/bit]
PD_TIA_J_PER_BIT = 440e-15          # photodetector + TIA [J/bit]
SRAM_LEAK_W_PER_BIT = 48.1e-12      # SRAM leakage [W/bit]
SRAM_J_PER_BIT = 50e-15             # SRAM dynamic access [J/bit]
DRAM_J_PER_BIT = 20e-12             # main memory access [J/bit] (LPDDR-class)

# ADC: regression plug-in approach [Andrulis et al. 2024].  We model energy
# per conversion as FOM * 2^bits (Walden figure-of-merit form); 10 fJ/conv-step
# is representative of recent 5 GS/s SAR ADCs surveyed there.
ADC_FOM_J_PER_STEP = 10e-15


def adc_energy_per_conversion(bits: int) -> float:
    """Energy of one ADC conversion at the given resolution [J]."""
    return ADC_FOM_J_PER_STEP * (2 ** bits)


# --------------------------------------------------------------------------
# Timing
# --------------------------------------------------------------------------
F_OPERATING_HZ = 5e9            # paper Sec. 4: operating frequency 5 GHz
T_SLOT_S = 1.0 / F_OPERATING_HZ
T_TO_TUNING_S = 5e-6            # thermo-optic settle (5-10 us; lower bound)
T_EO_TUNING_S = 20e-12          # electro-optic update (20-40 ps; lower bound)
ODL_MAX_DELAY_S = 345e-12       # SCISSOR delay line max tunable delay [17]
ODL_MIN_FREQ_HZ = 2.9e9         # => minimum OSA input signal frequency

# --------------------------------------------------------------------------
# Noise (Sec. 4.2 experiment settings)
# --------------------------------------------------------------------------
SIGMA_DAC_DEFAULT = 0.02        # std of DAC-induced voltage error [V]
SIGMA_TH_DEFAULT = 0.04         # std of thermal crosstalk on dT [K]

# --------------------------------------------------------------------------
# Quantization defaults (Sec. 4: uniform 8-bit on inputs/weights/outputs)
# --------------------------------------------------------------------------
N_BITS_INPUT = 8
N_BITS_WEIGHT = 8
N_BITS_OUTPUT = 8

# --------------------------------------------------------------------------
# Architecture constraints (Sec. 3.5)
# --------------------------------------------------------------------------
MAX_WDM_CHANNELS = 8            # C <= 8
MAX_TOTAL_MRRS = 1024           # T * R * C <= 1024


class ComputeMode(enum.Enum):
    """Table 1 computing modes."""

    ANALOG = "analog"       # DEAP-CNNs: inputs and weights both analog, TO-tuned
    DIGITAL = "digital"     # HolyLight: binary inputs and weights, EO-tuned
    MIXED = "mixed"         # ROSA: analog weights (TO) + digital bit-serial inputs (EO)


class Mapping(enum.Enum):
    """Dataflow mapping of a layer onto the OPE array (Fig. 4)."""

    WS = "weight_stationary"
    IS = "input_stationary"
    GEMM = "gemm"           # transformer GEMM mapping (a WS variant over N_row)


@dataclasses.dataclass(frozen=True)
class OPEConfig:
    """One optical processing element array: R rows x C wavelength columns.

    ``tiles`` = number of such arrays on chip, subject to
    tiles * rows * cols <= MAX_TOTAL_MRRS.
    """

    rows: int
    cols: int
    tiles: int = 0  # 0 -> auto-fill to the MRR budget

    def __post_init__(self):
        if self.tiles == 0:
            object.__setattr__(
                self, "tiles", max(1, MAX_TOTAL_MRRS // (self.rows * self.cols))
            )

    @property
    def total_mrrs(self) -> int:
        return self.tiles * self.rows * self.cols

    def validate(self, enforce_wdm: bool = True) -> None:
        if enforce_wdm and self.cols > MAX_WDM_CHANNELS:
            raise ValueError(f"C={self.cols} exceeds WDM limit {MAX_WDM_CHANNELS}")
        if self.total_mrrs > MAX_TOTAL_MRRS:
            raise ValueError(
                f"T*R*C={self.total_mrrs} exceeds budget {MAX_TOTAL_MRRS}"
            )


# Reference configurations used throughout the paper's experiments.
DEAP_HIGH_CHANNEL = OPEConfig(rows=113, cols=9, tiles=1)    # DEAP-CNNs [9]
DEAP_WIDE_KERNEL = OPEConfig(rows=12, cols=100, tiles=1)    # DEAP-CNNs [9]
COMPACT_4X4 = OPEConfig(rows=4, cols=4)                     # [7, 27, 28]
ROSA_OPTIMAL = OPEConfig(rows=8, cols=8)                    # paper's winner


def ternary_num_slots(n_bits: int) -> int:
    """Number of OSA time slots for an n-bit signed-digit input stream.

    Sign-magnitude signed-digit coding of an n-bit two's-complement value
    needs n-1 magnitude digits (the sign rides on each digit), i.e. 7 slots
    for 8-bit inputs; Eq. (1) indexes slots t = 0..N_T.
    """
    return max(1, n_bits - 1)


ROOFLINE_PEAK_FLOPS = 197e12      # bf16 peak per chip [FLOP/s] (v5e-class)
ROOFLINE_HBM_BW = 819e9           # HBM bandwidth per chip [B/s]
ROOFLINE_ICI_BW = 50e9            # per-link ICI bandwidth [B/s]


def mw(x_w: float) -> float:
    """Watts -> milliwatts (pretty-printing helper)."""
    return x_w * 1e3


def db(x: float) -> float:
    """Linear power ratio -> dB."""
    return 10.0 * math.log10(x)
