"""synth-CIFAR: deterministic 10-class 32x32x3 image dataset.

CIFAR-10/MNIST are not available offline in this container (DESIGN.md §8);
the paper's accuracy experiments run on this generator instead.  Each class
is a mixture of oriented Gabor textures + class-tinted color field; additive
Gaussian pixel noise controls task difficulty.  Linearly separable it is
not: reduced CNNs reach high accuracy only after a few hundred steps, and
noise injected into their weights degrades accuracy layer-dependently —
which is the property the hybrid-mapping experiment needs.
"""

from __future__ import annotations

import numpy as np

_N_CLASSES = 10


def _gabor(size: int, theta: float, freq: float, phase: float) -> np.ndarray:
    ax = np.arange(size) - size / 2
    xx, yy = np.meshgrid(ax, ax)
    xr = xx * np.cos(theta) + yy * np.sin(theta)
    yr = -xx * np.sin(theta) + yy * np.cos(theta)
    return np.exp(-(xr ** 2 + yr ** 2) / (2 * (size / 3) ** 2)) \
        * np.cos(2 * np.pi * freq * xr + phase)


def synth_cifar(n: int, seed: int = 0, noise: float = 1.1,
                size: int = 32):
    """Returns (images (n, size, size, 3) f32 in [-1, 1], labels (n,)).

    Deliberately HARD: neighbouring classes differ by ~9 deg of texture
    orientation with per-sample rotation jitter of ~6 deg, weak color
    tints and strong pixel noise — so clean QAT models land in the
    75-95% band and analog weight noise produces measurable, layer-
    dependent degradation (the regime of the paper's Fig. 6/10)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, _N_CLASSES, size=n)
    imgs = np.zeros((n, size, size, 3), np.float32)
    for i in range(n):
        k = labels[i]
        theta = np.pi / 26.0 * k + rng.normal(0, 0.12)
        freq = 0.085 + 0.006 * (k % 5) + rng.normal(0, 0.005)
        phase = rng.uniform(0, 2 * np.pi)
        tint = np.array([np.sin(2.1 * k), np.cos(1.3 * k),
                         np.sin(0.7 * k + 1)], np.float32) * 0.05
        w = rng.uniform(0.5, 1.0)
        img = w * _gabor(size, theta, freq, phase) \
            + (1 - w) * _gabor(size, theta + 0.4, freq * 1.6,
                               phase + 1.0)
        contrast = rng.uniform(0.5, 1.2)
        imgs[i] = contrast * img[..., None] + tint[None, None, :]
    imgs += rng.normal(0, noise, imgs.shape).astype(np.float32)
    return np.clip(imgs, -1, 1), labels.astype(np.int32)


def train_test_split(n_train: int = 2048, n_test: int = 512, seed: int = 0,
                     noise: float = 0.35):
    xtr, ytr = synth_cifar(n_train, seed=seed, noise=noise)
    xte, yte = synth_cifar(n_test, seed=seed + 1, noise=noise)
    return (xtr, ytr), (xte, yte)
