"""Vectorized Monte-Carlo device-variation subsystem (repro.robust).

Splits the paper's noise story into its two physical time scales and makes
both first-class, fully vectorized citizens:

  per-shot noise       `mrr.NoiseModel` — fresh DAC/thermal draw every
                       matmul (Eq. 8), unchanged;
  per-device variation `variation` — static fab mismatch + thermal-
                       crosstalk bias + driver offsets, drawn ONCE per
                       fabricated chip as a `{layer: mrr.StaticVariation}`
                       pytree;
  chip ensembles       `ensemble` — an "N-chip wafer" evaluated in ONE
                       jitted vmapped call: per-chip accuracy, clean-logit
                       agreement, yield statistics.  The default estimator
                       is variance-reduced: antithetic mirrored chip pairs
                       (`sample_ensemble(antithetic=True)`) plus a
                       control-variate regression on a weight-realization
                       surrogate (`EstimatorConfig`, `estimate_ensemble`),
                       so ~4 probe chips predict 16-chip mean/yield;
                       `FULL_MC` restores brute force;
  sensitivity          `sensitivity` — perturb-one-layer degradation
                       profiling as a traced one-hot gate: ONE compiled
                       call covers (mappings x chips x layers) through the
                       mapping-gate superposition, feeding
                       `mapping.LayerProfile.d_is/d_ws` directly.
                       Matrices are cached in the content-addressed
                       `rosa.PlanCache` per (layer, RosaConfig, measurement
                       spec) via `cnn_degradation_source` — a warm
                       `rosa.compile(...)` skips the MC stage, and
                       `refresh_degradation_matrix` re-scores only changed
                       layers;
  drift + re-trim      `drift` — thermal drift schedules with periodic
                       re-calibration through `mrr.voltage_of_weight`'s
                       `dt_trim` hook;
  reports              `report` — accuracy-vs-sigma and yield curves in
                       the gateable `repro.bench` schema.

Serving pins one sampled chip with `rosa.Engine.with_variation(chip)` and
reuses it deterministically across decode steps.  CLI:
``python -m repro.robust {ensemble,sensitivity,drift,sweep}``.
"""

from repro.robust.drift import DriftModel, DriftResult, residual_offsets, \
    simulate, simulate_cnn, trim_voltages
from repro.robust.ensemble import (FULL_MC, EnsembleResult, EstimatorConfig,
                                   clean_reference, control_variate_accs,
                                   estimate_ensemble, evaluate_cnn_ensemble,
                                   evaluate_ensemble, layer_weights,
                                   make_ensemble_eval, make_plan_eval,
                                   surrogate_features)
from repro.robust.sensitivity import (accuracy_guarded_plan,
                                      cnn_degradation_matrix,
                                      cnn_degradation_source,
                                      cnn_profiles_mc, degradation_matrix,
                                      params_digest, plan_search,
                                      profile_layers_mc,
                                      refresh_degradation_matrix,
                                      searched_cnn_hybrid_plan,
                                      searched_hybrid_plan)
from repro.robust.variation import (NO_VARIATION, PAPER_VARIATION,
                                    VariationModel, chip_at, chip_slice,
                                    cnn_lane_dims, ensemble_size,
                                    sample_chip, sample_ensemble,
                                    scale_ensemble, shift_thermal)

__all__ = [
    "DriftModel", "DriftResult", "EnsembleResult", "EstimatorConfig",
    "FULL_MC", "NO_VARIATION",
    "PAPER_VARIATION", "VariationModel", "accuracy_guarded_plan",
    "chip_at", "chip_slice", "clean_reference",
    "cnn_degradation_matrix", "cnn_degradation_source", "cnn_lane_dims",
    "cnn_profiles_mc", "control_variate_accs",
    "degradation_matrix", "ensemble_size", "estimate_ensemble",
    "evaluate_cnn_ensemble",
    "evaluate_ensemble", "layer_weights", "make_ensemble_eval",
    "make_plan_eval", "params_digest", "plan_search",
    "profile_layers_mc", "refresh_degradation_matrix", "residual_offsets",
    "sample_chip",
    "sample_ensemble", "scale_ensemble", "searched_cnn_hybrid_plan",
    "searched_hybrid_plan", "shift_thermal", "simulate", "simulate_cnn",
    "trim_voltages",
]
