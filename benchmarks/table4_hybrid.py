"""Table 4 / Fig. 10 reproduction: layer-wise hybrid mapping.

Per CNN family (reduced nets on synth-CIFAR; DESIGN.md §8):

  1. QAT-train the 8-bit model (the paper's training protocol),
  2. profile d_l(m): accuracy drop with ONLY layer l noisy-analog under
     mapping m in {IS, WS} (Fig. 6 protocol),
  3. e_l(m) from the full-size layer tables (configs/paper_cnns.py) on the
     optimized (8,8) array with OSA,
  4. per-layer balanced-metric argmin -> hybrid plan (paper Eq.),
  5. evaluate: clean | WS | IS | hybrid | analog(DEAP) accuracies, and
     EDP: WS vs hybrid vs DEAP-CNNs (high-channel, fully-analog).

Paper claims to compare against: hybrid > WS accuracy (avg +8.3pp on
CIFAR-10), hybrid EDP ~10.8% below WS, ~54.7% below DEAP-CNNs, and <=3.3pp
below the clean model.  Magnitudes on synth-CIFAR differ (documented);
orderings and mechanism are the reproduction target.
"""

from __future__ import annotations

import argparse
import dataclasses
import json

import jax

from repro import rosa
from repro.configs.paper_cnns import CNN_WORKLOADS
from repro.core import energy as E
from repro.core import mapping as M
from repro.core import mrr
from repro.core.constants import (ComputeMode, DEAP_HIGH_CHANNEL, Mapping,
                                  ROSA_OPTIMAL)
from repro.models.cnn import LITE_MODELS
from repro.training.cnn_train import (QAT_CFG, cnn_program, evaluate_cnn,
                                      layer_noise_profile, train_cnn)


def _layer_names(model):
    return [s.name for s in LITE_MODELS[model]]


def _acc_with(params, model, mode, mp, noise, n_mc=3, seed=17):
    cfg = dataclasses.replace(QAT_CFG, mode=mode, mapping=mp, noise=noise)
    program = cnn_program(
        model, rosa.Engine.from_config(cfg, layers=_layer_names(model)))
    return evaluate_cnn(params, model, program=program,
                        key=jax.random.PRNGKey(seed), n_mc=n_mc)


def _acc_with_plan(params, model, plan, noise, n_mc=3, seed=17):
    cfg = dataclasses.replace(QAT_CFG, noise=noise)   # default: WS
    program = cnn_program(
        model, rosa.Engine.from_hybrid_plan(cfg, plan,
                                            layers=_layer_names(model)))
    return evaluate_cnn(params, model, program=program,
                        key=jax.random.PRNGKey(seed), n_mc=n_mc)


def run_model(model: str, steps: int = 400, n_mc: int = 3,
              noise: mrr.NoiseModel = mrr.PAPER_NOISE,
              verbose: bool = True) -> dict:
    layers_full = CNN_WORKLOADS[model]
    params, clean = train_cnn(model, steps=steps)
    prof = layer_noise_profile(params, model, noise=noise, n_mc=n_mc)

    # join behavioural profile with full-size EDP rows
    lite_names = {s.name for s in LITE_MODELS[model]}
    profiles = []
    for layer in layers_full:
        if layer.name not in lite_names:
            continue
        d = prof["layers"][layer.name]
        profiles.append(M.LayerProfile(
            layer.name,
            d_is=d[Mapping.IS.value], d_ws=d[Mapping.WS.value],
            e_is=E.layer_energy(layer, ROSA_OPTIMAL, Mapping.IS,
                                batch=128).edp,
            e_ws=E.layer_energy(layer, ROSA_OPTIMAL, Mapping.WS,
                                batch=128).edp))
    plan = M.hybrid_plan(profiles)

    accs = {
        "clean": clean,
        "ws": _acc_with(params, model, ComputeMode.MIXED, Mapping.WS,
                        noise, n_mc),
        "is": _acc_with(params, model, ComputeMode.MIXED, Mapping.IS,
                        noise, n_mc),
        "hybrid": _acc_with_plan(params, model, plan, noise, n_mc),
        "analog": _acc_with(params, model, ComputeMode.ANALOG, Mapping.WS,
                            noise, n_mc),
    }
    mapped_layers = [l for l in layers_full if l.name in lite_names]
    edp = {
        "ws": M.plan_edp(mapped_layers, {}, ROSA_OPTIMAL, batch=128),
        "hybrid": M.plan_edp(mapped_layers, plan, ROSA_OPTIMAL, batch=128),
        "deap": E.network_energy(mapped_layers, DEAP_HIGH_CHANNEL,
                                 Mapping.WS, ComputeMode.ANALOG,
                                 E.NO_OSA, batch=128).edp,
    }
    n_is = sum(1 for v in plan.values() if v is Mapping.IS)
    res = dict(model=model, accs=accs, edp=edp, plan_is_layers=n_is,
               plan={k: v.value for k, v in plan.items()})
    if verbose:
        print(f"\n== {model} ==")
        print("  acc[%]: " + "  ".join(f"{k}={v:.1f}"
                                       for k, v in accs.items()))
        print(f"  plan: {n_is}/{len(plan)} layers IS")
        print(f"  EDP[J*s]: WS={edp['ws']:.4g} hybrid={edp['hybrid']:.4g} "
              f"DEAP={edp['deap']:.4g}")
        print(f"  hybrid vs WS: {(1 - edp['hybrid'] / edp['ws']) * 100:+.1f}%"
              f" EDP, {accs['hybrid'] - accs['ws']:+.1f}pp acc")
        print(f"  hybrid vs DEAP-CNNs EDP: "
              f"{(1 - edp['hybrid'] / edp['deap']) * 100:.1f}% lower")
    return res


def run(models=None, steps: int = 400, n_mc: int = 3,
        sigma_scale: float = 1.0, verbose: bool = True) -> dict:
    models = models or list(CNN_WORKLOADS)
    noise = mrr.NoiseModel(sigma_dac=0.02 * sigma_scale,
                           sigma_th=0.04 * sigma_scale)
    out = {m: run_model(m, steps, n_mc, noise, verbose) for m in models}
    if verbose and len(models) > 1:
        gain = sum(r["accs"]["hybrid"] - r["accs"]["ws"]
                   for r in out.values()) / len(out)
        edp_red = sum(1 - r["edp"]["hybrid"] / r["edp"]["deap"]
                      for r in out.values()) / len(out)
        loss_vs_clean = sum(r["accs"]["clean"] - r["accs"]["hybrid"]
                            for r in out.values()) / len(out)
        print(f"\nAVG hybrid-vs-WS acc: {gain:+.2f}pp (paper: +8.3pp)")
        print(f"AVG hybrid-vs-DEAP EDP: {edp_red * 100:.1f}% lower "
              f"(paper: 54.7%)")
        print(f"AVG acc loss vs clean: {loss_vs_clean:.2f}pp (paper: 3.3pp)")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", nargs="*", default=None)
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--n-mc", type=int, default=3)
    ap.add_argument("--sigma-scale", type=float, default=1.0)
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    res = run(args.models, args.steps, args.n_mc, args.sigma_scale)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(res, f, indent=1, default=str)
