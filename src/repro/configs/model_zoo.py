"""GEMM-lowered workload zoo derived from the `configs/` architectures.

The paper's Fig. 7 DSE aggregates over four CNNs (+GPT-2M/ViT in our
extended table).  The repo, however, already carries ten published LLM/SSM/
enc-dec architectures as `ModelConfig`s — this module lowers each of them
to the `LayerShape` rows the analytical energy model consumes, so the
array-size DSE and the hybrid-mapping search can stress far more diverse
dataflows (GQA attention, MoE expert FFNs, MLA low-rank projections, SSD
projections + depthwise convs, shared-block hybrids, enc-dec cross
attention) than the CNN quartet.

Lowering conventions (one token batch of `seq_len`, decode-free prefill):
  * every dense projection is one GEMM row: M = tokens, K = in, N = out;
  * SwiGLU FFNs emit gate+up fused (N = 2*d_ff) plus the down projection;
  * MoE layers emit the router plus `top_k` activated expert FFN pairs —
    the token batch streams through top_k distinct expert weight sets, so
    weight-programming events scale with activated experts, matching the
    "activated parameters" accounting of the MoE papers;
  * Mamba-2 blocks emit the five projections and the width-4 depthwise
    causal conv (a grouped LayerShape); the SSD scan itself is not a GEMM
    the MRR array can hold stationary and stays electronic (ssm.py);
  * the LM head emits even for tied embeddings (the GEMM still executes);
  * embedding *lookups* are not GEMMs and are skipped.

Only `ModelConfig` metadata is touched — no parameters are materialized, so
building the full zoo is instant.
"""

from __future__ import annotations

from repro.core.energy import LayerShape
from repro.models.transformer import ModelConfig

ZOO_SEQ_LEN = 512      # prefill token batch used for zoo GEMM rows


def _gemm(name: str, m: int, k: int, n: int) -> LayerShape:
    return LayerShape(name, m=m, k=k, n=n, kind="gemm")


def _attn_rows(tag: str, cfg: ModelConfig, seq: int,
               kv_seq: int | None = None) -> list[LayerShape]:
    """QKV / output projections of one (self- or cross-) attention block."""
    hd = cfg.head_dim
    q_out = cfg.n_heads * hd
    kv_out = 2 * cfg.n_kv_heads * hd
    rows = [_gemm(f"{tag}_qkv", seq, cfg.d_model, q_out + kv_out)]
    if kv_seq is not None and kv_seq != seq:
        # cross-attention: queries from the decoder, K/V from the encoder
        rows = [_gemm(f"{tag}_q", seq, cfg.d_model, q_out),
                _gemm(f"{tag}_kv", kv_seq, cfg.d_model, kv_out)]
    rows.append(_gemm(f"{tag}_out", seq, q_out, cfg.d_model))
    return rows


def _mla_rows(tag: str, cfg: ModelConfig, seq: int) -> list[LayerShape]:
    mla = cfg.mla
    h = mla.n_heads
    return [
        _gemm(f"{tag}_dq", seq, mla.d_model, mla.q_lora),
        _gemm(f"{tag}_uq", seq, mla.q_lora, h * (mla.qk_nope + mla.qk_rope)),
        _gemm(f"{tag}_dkv", seq, mla.d_model, mla.kv_lora + mla.qk_rope),
        _gemm(f"{tag}_ukv", seq, mla.kv_lora, h * (mla.qk_nope + mla.v_head)),
        _gemm(f"{tag}_out", seq, h * mla.v_head, mla.d_model),
    ]


def _ffn_rows(tag: str, seq: int, d_model: int, d_ff: int) -> list[LayerShape]:
    return [_gemm(f"{tag}_wi", seq, d_model, 2 * d_ff),
            _gemm(f"{tag}_wo", seq, d_ff, d_model)]


def _moe_rows(tag: str, cfg: ModelConfig, seq: int) -> list[LayerShape]:
    moe = cfg.moe
    rows = [_gemm(f"{tag}_router", seq, moe.d_model, moe.n_experts)]
    for e in range(moe.top_k):
        rows += _ffn_rows(f"{tag}_exp{e}", seq, moe.d_model, moe.d_ff)
    if moe.n_shared:
        rows += _ffn_rows(f"{tag}_shared", seq, moe.d_model,
                          moe.n_shared * moe.d_ff)
    return rows


def _ssm_rows(tag: str, cfg: ModelConfig, seq: int) -> list[LayerShape]:
    ssm = cfg.ssm
    d, di = ssm.d_model, ssm.d_inner
    gs = ssm.n_groups * ssm.d_state
    return [
        _gemm(f"{tag}_x", seq, d, di),
        _gemm(f"{tag}_z", seq, d, di),
        _gemm(f"{tag}_bc", seq, d, 2 * gs),
        _gemm(f"{tag}_dt", seq, d, ssm.n_heads),
        # width-4 depthwise causal conv on x: d_inner independent channels
        LayerShape(f"{tag}_conv", m=seq, k=ssm.d_conv * di, n=di,
                   groups=di, kind="dwconv"),
        _gemm(f"{tag}_out", seq, di, d),
    ]


def layers_from_config(cfg: ModelConfig,
                       seq_len: int = ZOO_SEQ_LEN) -> list[LayerShape]:
    """Lower one `ModelConfig` to its GEMM LayerShape table."""
    seq = seq_len
    rows: list[LayerShape] = []

    if cfg.frontend == "vision":       # CLIP-style 16px patch embed stub
        rows.append(_gemm("vision_patch", 576, 3 * 16 * 16, cfg.d_model))
    elif cfg.frontend == "audio":      # fbank frame embed stub
        rows.append(_gemm("audio_frames", seq, 80 * 2, cfg.d_model))

    if cfg.is_encdec:
        # speech-to-text shape: the encoder sees the full frame sequence,
        # the decoder prefills a shorter text target; cross-attention K/V
        # projects from the encoder length, queries from the decoder's.
        dec_seq = max(1, seq // 2)
        for i in range(cfg.n_enc_layers):
            rows += _attn_rows(f"enc{i}_attn", cfg, seq)
            rows += _ffn_rows(f"enc{i}_ffn", seq, cfg.d_model, cfg.d_ff)
        for i in range(cfg.n_layers):
            rows += _attn_rows(f"dec{i}_attn", cfg, dec_seq)
            rows += _attn_rows(f"dec{i}_xattn", cfg, dec_seq, kv_seq=seq)
            rows += _ffn_rows(f"dec{i}_ffn", dec_seq, cfg.d_model, cfg.d_ff)
    elif cfg.family == "ssm":
        for i in range(cfg.n_layers):
            rows += _ssm_rows(f"l{i}", cfg, seq)
    elif cfg.family == "hybrid":
        n_shared = cfg.n_layers // cfg.shared_every if cfg.shared_every else 0
        for i in range(cfg.n_layers):
            rows += _ssm_rows(f"l{i}", cfg, seq)
        for j in range(n_shared):      # shared attn+MLP block applications
            rows += _attn_rows(f"shared{j}_attn", cfg, seq)
            rows += _ffn_rows(f"shared{j}_ffn", seq, cfg.d_model, cfg.d_ff)
    else:                              # dense | moe | mla_moe decoders
        for i in range(cfg.n_layers):
            if cfg.mla is not None:
                rows += _mla_rows(f"l{i}_attn", cfg, seq)
            else:
                rows += _attn_rows(f"l{i}_attn", cfg, seq)
            if cfg.moe is not None and not (i == 0 and cfg.first_dense_ff):
                rows += _moe_rows(f"l{i}_moe", cfg, seq)
            else:
                d_ff = cfg.first_dense_ff if (i == 0 and cfg.first_dense_ff) \
                    else cfg.d_ff
                rows += _ffn_rows(f"l{i}_ffn", seq, cfg.d_model, d_ff)

    head_seq = max(1, seq // 2) if cfg.is_encdec else seq   # decoder tokens
    rows.append(_gemm("lm_head", head_seq, cfg.d_model, cfg.vocab))
    return rows


def zoo_workloads(seq_len: int = ZOO_SEQ_LEN,
                  include_paper: bool = True,
                  archs: list[str] | None = None) -> "list":
    """`dse.Workload` list: the paper table/figure workloads plus every
    architecture in the config registry, GEMM-lowered at `seq_len`."""
    from repro.configs import ARCHS, get_config
    from repro.core.dse import Workload

    wls = []
    if include_paper:
        from repro.configs.paper_cnns import WORKLOADS
        wls += [Workload(n, layers) for n, layers in WORKLOADS.items()]
    for name in (archs if archs is not None else ARCHS):
        cfg = get_config(name)
        wls.append(Workload(cfg.name, layers_from_config(cfg, seq_len)))
    return wls
