"""`repro.analysis` — static verification of compiled optical programs.

The stack's core invariants are compile-time-decidable but invisible to
output-level tests: per-shot noise keys must be independent (one reused
key correlates the whole Monte-Carlo ensemble), serving-state donations
must really alias (or decode doubles its HBM footprint), Pallas kernels
must tile every zoo shape, hot loops must stay host-callback-free.  This
package decides them by inspecting jaxprs and optimized HLO.

Three surfaces:

  * `rosa.compile(..., verify="error"|"warn"|"off")` runs the pass on the
    compiled Program (`verify_program` is the hook);
  * `python -m repro.analysis` scans the model zoo + serving steps and
    emits bench-schema JSON, exiting non-zero on un-baselined findings;
  * CI runs the CLI against the committed `analysis_baseline.json`.

Check catalog (each module under `checks/` registers itself):

  prng       PRNG001 key reuse / PRNG002 constant-baked key /
             PRNG003 constant seed / PRNG004 unfolded key in a loop
  donation   DON001 dropped donation / DON002 undonated hot-path state
  recompile  REC001 weak scalar / REC002 f64 promotion /
             REC003 unhashable static
  pallas     PAL001 VMEM overflow / PAL002 padding waste /
             PAL003 tile contract violation
  purity     PUR001 callback in loop / PUR002 callback in hot path
"""

from repro.analysis.baseline import load_baseline, write_baseline
from repro.analysis.findings import (AnalysisReport, Finding, Severity,
                                     VerificationError)
from repro.analysis.registry import all_checks, register, run_checks
from repro.analysis.target import AnalysisTarget, program_target

__all__ = [
    "AnalysisReport", "AnalysisTarget", "Finding", "Severity",
    "VerificationError", "all_checks", "load_baseline", "program_target",
    "register", "run_checks", "verify_program", "write_baseline",
]


def verify_program(program, example_args, *, name: str = "program",
                   checks=None) -> AnalysisReport:
    """Run the static checks over a compiled `rosa.Program`.

    Traces the program's jitted entry with an abstract (never constant)
    key and verifies its declared donations against the compiled HLO —
    this is what `rosa.compile(verify=...)` calls."""
    return run_checks([program_target(program, example_args, name=name)],
                      checks=checks)
