"""Pure-jnp oracle for the OSA bit-serial signed-digit matmul kernel.

Semantics (matching core.osa.osa_matmul_ref but taking pre-quantized integer
activations, which is the kernel's contract):

    y[m, n] = sum_t gains[t] * sum_k plane_t(q)[m, k] * w[k, n]

where plane_t(q) = sign(q) * ((|q| >> t) & 1) are the signed digit planes of
the integer activations q (values in [-(2^(B-1)-1), 2^(B-1)-1]) and
gains[t] defaults to the ideal power-of-two ladder 2^t (the optical
shift realized by the splitter/ODL chain).  With ideal gains this equals
q.astype(f32) @ w exactly.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import quant as Q


def osa_matmul_ref(q: jnp.ndarray, w: jnp.ndarray,
                   gains: jnp.ndarray | None = None,
                   quant_bits: int = 8,
                   pam_bits: int = 1) -> jnp.ndarray:
    """q: (M, K) integer-valued; w: (K, N) f32; gains: (T,) or None."""
    cfg = Q.QuantConfig(bits=quant_bits)
    qf = q.astype(jnp.float32)
    if pam_bits == 1:
        planes = Q.decompose_planes(qf, cfg)                 # (T, M, K)
        g = Q.plane_weights(cfg) if gains is None else gains
    else:
        planes = Q.decompose_pam(qf, pam_bits, cfg)
        g = Q.pam_plane_weights(pam_bits, cfg) if gains is None else gains
    per_slot = jnp.einsum("tmk,kn->tmn", planes, w.astype(jnp.float32))
    return jnp.einsum("t,tmn->mn", g.astype(jnp.float32), per_slot)
