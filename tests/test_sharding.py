"""Logical-axis resolution: divisibility fallback, no-reuse, priority."""

import types

from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import SERVE_RULES, TRAIN_RULES, resolve_spec


class FakeMesh(types.SimpleNamespace):
    pass


MESH = FakeMesh(shape={"pod": 2, "data": 16, "model": 16})
MESH1 = FakeMesh(shape={"data": 16, "model": 16})


def test_train_weight_fsdp_plus_tp():
    spec = resolve_spec((4096, 64, 128), ("embed", "heads", "head_dim"),
                        TRAIN_RULES, MESH)
    assert spec == P(("pod", "data"), "model")


def test_divisibility_fallback_drops_axis():
    # vocab 256206 not divisible by model=16 -> replicated
    spec = resolve_spec((1024, 256206), ("embed", "vocab"), TRAIN_RULES,
                        MESH1)
    assert spec == P(("data",)) or spec == P("data")


def test_batch_suffix_fallback():
    # batch 8 can't take (pod,data)=32 nor (data,)=16 -> replicated
    spec = resolve_spec((8, 128, 512), ("batch", None, None), TRAIN_RULES,
                        MESH)
    assert spec == P()
    # batch 16 falls back to the ("data",) suffix
    spec = resolve_spec((16, 128, 512), ("batch", None, None), TRAIN_RULES,
                        MESH)
    assert spec == P("data")


def test_no_axis_reuse_within_tensor():
    # both cache_batch and cache_seq want (pod,data): only one gets it
    spec = resolve_spec((64, 32768, 8, 128),
                        ("cache_batch", "cache_seq", "kv_heads", "head_dim"),
                        SERVE_RULES, MESH)
    used = [a for part in spec if part
            for a in (part if isinstance(part, tuple) else (part,))]
    assert len(used) == len(set(used))


def test_priority_kv_heads_beats_cache_seq():
    # kv divisible: kv_heads takes model, seq gets nothing on 1-pod mesh
    spec = resolve_spec((128, 32768, 16, 64),
                        ("cache_batch", "cache_seq", "kv_heads", "head_dim"),
                        SERVE_RULES, MESH1)
    assert spec[2] == "model"
    # kv NOT divisible (8 over 16): cache_seq picks up model instead
    spec = resolve_spec((128, 32768, 8, 64),
                        ("cache_batch", "cache_seq", "kv_heads", "head_dim"),
                        SERVE_RULES, MESH1)
    assert spec[1] == "model" and (len(spec) < 3 or spec[2] is None)


def test_long_context_batch1_shards_seq_everywhere():
    spec = resolve_spec((1, 524288, 8, 256),
                        ("cache_batch", "cache_seq", "kv_heads", "head_dim"),
                        SERVE_RULES, MESH)
    assert spec[1] == ("pod", "data", "model")
