"""Event-count energy/EDP model (paper Sec. 3.4, Table 1/3)."""

import pytest

from repro.core import constants as C
from repro.core import energy as E
from repro.core.constants import ComputeMode, Mapping, OPEConfig

LAYER = E.LayerShape("conv", m=1024, k=576, n=64)
OPE = C.ROSA_OPTIMAL


def test_table1_ops_ordering():
    """Mixed mode OPS beats analog (t_TO bottleneck) and digital (1 bit)."""
    ope = OPEConfig(rows=8, cols=8, tiles=1)
    assert E.ops_mixed(ope) > E.ops_analog(ope)
    assert E.ops_mixed(ope) > E.ops_digital(ope) / 8 * 7  # ~N_w x digital


def test_osa_reduces_adc_events():
    no = E.layer_energy(LAYER, OPE, osa=E.NO_OSA)
    yes = E.layer_energy(LAYER, OPE, osa=E.OSA_OPTIMAL)
    assert yes.events["adc_conversions"] * 6.9 < no.events["adc_conversions"]
    assert yes.adc < no.adc
    assert yes.pd_tia < no.pd_tia


def test_osa_lowers_edp():
    no = E.layer_energy(LAYER, OPE, osa=E.NO_OSA)
    dflt = E.layer_energy(LAYER, OPE, osa=E.OSA_DEFAULT)
    opt = E.layer_energy(LAYER, OPE, osa=E.OSA_OPTIMAL)
    assert opt.edp < dflt.edp < no.edp


def test_analog_mode_slower_than_mixed():
    """DEAP analog reprograms thermo-optically per vector: huge latency."""
    an = E.layer_energy(LAYER, OPE, mode=ComputeMode.ANALOG)
    mx = E.layer_energy(LAYER, OPE, mode=ComputeMode.MIXED)
    assert an.latency > 100 * mx.latency


def test_mapping_changes_event_structure():
    ws = E.layer_energy(LAYER, OPE, Mapping.WS)
    is_ = E.layer_energy(LAYER, OPE, Mapping.IS)
    assert ws.events["n_tiles"] != is_.events["n_tiles"]
    assert ws.energy > 0 and is_.energy > 0


def test_energy_components_all_positive():
    bd = E.layer_energy(LAYER, OPE)
    for k, v in bd.as_dict().items():
        assert v >= 0, k


def test_network_energy_adds_up():
    layers = [LAYER, E.LayerShape("fc", m=1, k=4096, n=10, kind="fc")]
    total = E.network_energy(layers, OPE)
    parts = [E.layer_energy(l, OPE) for l in layers]
    assert total.energy == pytest.approx(sum(p.energy for p in parts))
    assert total.latency == pytest.approx(sum(p.latency for p in parts))


def test_depthwise_groups_submatrix():
    dw = E.LayerShape("dw", m=256, k=64 * 9, n=64, groups=64, kind="dwconv")
    g, m, k, n = dw.sub_gemm()
    assert (g, m, k, n) == (64, 256, 9, 1)
    assert E.layer_energy(dw, OPE).energy > 0


def test_adc_energy_scales_exponentially():
    assert C.adc_energy_per_conversion(8) == 16 * C.adc_energy_per_conversion(4)
