"""AdamW with f32 moments over arbitrary param pytrees.

Production layout: params may be bf16 on device; moments are always f32 and
shard exactly like their parameters (the train driver passes the same
NamedShardings for both).  Optional gradient compression (bf16 on the DP
all-reduce with f32 error feedback) lives in distributed/compress.py and
wraps the gradient before this update.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float | Callable[[jax.Array], jax.Array] = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adamw_update(params, grads, state, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = cfg.lr(step) if callable(cfg.lr) else cfg.lr
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if cfg.grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = global_norm(grads)

    b1, b2 = cfg.b1, cfg.b2
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["mu"], grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g,
                      state["nu"], grads)
    mu_hat = jax.tree.map(lambda m: m / (1 - b1 ** step), mu)
    nu_hat = jax.tree.map(lambda v: v / (1 - b2 ** step), nu)

    def upd(p, m, v):
        delta = m / (jnp.sqrt(v) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu_hat, nu_hat)
    return new_params, {"mu": mu, "nu": nu, "step": step}, \
        {"grad_norm": gnorm, "lr": jnp.asarray(lr)}
