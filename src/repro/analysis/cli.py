"""`python -m repro.analysis` — scan the zoo + serving steps, gate on new.

Default target set:

  * every model-zoo workload's GEMM table through the Pallas preflight
    (shape math only — the full zoo costs nothing);
  * the smoke arch's decode step as a jaxpr target;
  * the smoke serving stack: decode/admit/evict/prefill-chunk steps built
    from ONE autotuned `rosa.Program` (declared donations verified against
    compiled HLO; hot-path purity enforced), plus the Program itself.

Output: findings to stdout, a bench-schema JSON report (--json), and an
exit code that is non-zero iff WARNING+ findings exist that the committed
baseline (--baseline) does not acknowledge.  --write-baseline regenerates
the baseline from the current findings.
"""

from __future__ import annotations

import argparse
import sys
import time

from repro.analysis.baseline import load_baseline, write_baseline
from repro.analysis.findings import AnalysisReport, Severity
from repro.analysis.registry import run_checks
from repro.analysis.target import AnalysisTarget, program_target

DEFAULT_ARCH = "qwen3-32b"


# ---------------------------------------------------------------------------
# Target construction
# ---------------------------------------------------------------------------
def zoo_shape_targets() -> list[AnalysisTarget]:
    """One shapes-only target per zoo workload (plus ssd workloads for the
    ssm-family archs) — feeds the Pallas preflight."""
    from repro.configs import ARCHS, get_config
    from repro.configs.model_zoo import ZOO_SEQ_LEN, zoo_workloads

    targets = []
    for w in zoo_workloads():
        gemms = tuple((ls.name, ls.m, ls.k, ls.n)
                      for ls in w.layers if ls.kind == "gemm")
        targets.append(AnalysisTarget(name=f"zoo:{w.name}",
                                      gemm_shapes=gemms))
    ssd = []
    for arch in ARCHS:
        cfg = get_config(arch)
        ssm = getattr(cfg, "ssm", None)
        if ssm is None:
            continue
        ssd.append((cfg.name, 1, ZOO_SEQ_LEN, ssm.n_heads,
                    ssm.d_inner // ssm.n_heads, ssm.d_state))
    if ssd:
        targets.append(AnalysisTarget(name="zoo:ssd_scan",
                                      ssd_shapes=tuple(ssd)))
    return targets


def model_targets(arch: str) -> list[AnalysisTarget]:
    """The smoke model's decode step as a plain jaxpr target."""
    import jax.numpy as jnp

    from repro.configs import get_smoke
    from repro.models.model import build_model
    from repro.serve.config import ServeConfig
    from repro.serve.metrics import _abstract_decode_batch

    cfg = get_smoke(arch)
    bundle = build_model(cfg)
    scfg = ServeConfig(n_slots=4, max_len=56, prefill_chunk=8)
    return [AnalysisTarget(
        name=f"model:{arch}:decode_step", fn=bundle.decode_step,
        example_args=(bundle.abstract(jnp.float32),
                      _abstract_decode_batch(cfg, scfg)))]


def serving_targets(arch: str) -> list[AnalysisTarget]:
    """The full smoke serving stack from one autotuned Program."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_smoke
    from repro.models import transformer as T
    from repro.models.model import build_model
    from repro.serve import decode as D
    from repro.serve.config import ServeConfig, serving_model_config
    from repro.serve.metrics import (_abstract_decode_batch,
                                     build_serving_program)

    cfg = get_smoke(arch)
    bundle = build_model(serving_model_config(cfg, rosa=True))
    scfg = ServeConfig(n_slots=4, max_len=56, prefill_chunk=8)
    program = build_serving_program(bundle, scfg)

    params = bundle.abstract(jnp.float32)
    state = jax.eval_shape(lambda: D.init_state(bundle.cfg, scfg))
    admit = jax.eval_shape(lambda: D.null_admit(bundle.cfg, scfg))
    temp = jax.ShapeDtypeStruct((), jnp.float32)
    slot = jax.ShapeDtypeStruct((), jnp.int32)
    cache1 = jax.eval_shape(
        lambda: T.init_cache(bundle.cfg, 1, scfg.max_len))
    tokens = jax.ShapeDtypeStruct((1, scfg.prefill_chunk), jnp.int32)
    n_valid = jax.ShapeDtypeStruct((1,), jnp.int32)

    pre = f"serve:{arch}:"
    targets = [
        AnalysisTarget(
            name=pre + "decode_step",
            fn=D.make_serve_step(bundle, scfg, program=program),
            example_args=(params, state, admit, temp),
            donate_argnums=(1,), hot_path=True),
        AnalysisTarget(
            name=pre + "admit_step",
            fn=D.make_admit_step(bundle, scfg, program=program),
            example_args=(state, admit),
            donate_argnums=(0,), hot_path=True),
        AnalysisTarget(
            name=pre + "evict",
            fn=D.make_evict(bundle, scfg, program=program),
            example_args=(state, slot),
            donate_argnums=(0,), hot_path=True),
        program_target(
            program, (params, _abstract_decode_batch(bundle.cfg, scfg)),
            name=pre + "program"),
    ]
    # the adaptive controller's drift step: the decode step with one extra
    # traced residual scalar — must stay as pure/donating as the base step
    from repro.serve.adaptive import make_drift_step
    targets.append(AnalysisTarget(
        name=pre + "drift_step",
        fn=make_drift_step(bundle, scfg, program),
        example_args=(params, state, admit, temp,
                      jax.ShapeDtypeStruct((), jnp.float32)),
        donate_argnums=(1,), hot_path=True))
    if bundle.cfg.family not in ("ssm", "hybrid"):
        targets.append(AnalysisTarget(
            name=pre + "chunk_fn",
            fn=D.make_chunk_fn(bundle, program=program),
            example_args=(params, tokens, n_valid, cache1),
            donate_argnums=(3,), hot_path=True))
    return targets


def build_targets(arch: str = DEFAULT_ARCH, *, zoo: bool = True,
                  models: bool = True, serve: bool = True
                  ) -> list[AnalysisTarget]:
    targets: list[AnalysisTarget] = []
    if zoo:
        targets += zoo_shape_targets()
    if models:
        targets += model_targets(arch)
    if serve:
        targets += serving_targets(arch)
    return targets


# ---------------------------------------------------------------------------
# Bench-schema report
# ---------------------------------------------------------------------------
def bench_report(report: AnalysisReport, new_count: int, wall_s: float):
    from repro.bench.schema import BenchReport, BenchResult, Metric

    per_check: dict[str, int] = {}
    for f in report.findings:
        per_check[f.check] = per_check.get(f.check, 0) + 1
    metrics = [
        Metric("findings_new", new_count, gate=True, rel_tol=0.0,
               direction="lower_is_better"),
        Metric("findings_error", len(report.errors)),
        Metric("findings_warning", len(report.warnings)),
        Metric("findings_total", len(report)),
    ]
    metrics += [Metric(f"findings_{check}", n)
                for check, n in sorted(per_check.items())]
    return BenchReport(
        bench_seq=0, mode="quick",
        created_utc=time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        env={}, results=[BenchResult(name="static_analysis",
                                     wall_s=round(wall_s, 3),
                                     metrics=metrics)])


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------
def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--arch", default=DEFAULT_ARCH,
                    help="smoke arch for the model/serving targets")
    ap.add_argument("--baseline", default="analysis_baseline.json",
                    help="committed findings baseline (missing = empty)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="regenerate the baseline from current findings "
                         "and exit 0")
    ap.add_argument("--json", default=None,
                    help="write a bench-schema JSON report here")
    ap.add_argument("--checks", default=None,
                    help="comma-separated subset of checks to run")
    ap.add_argument("--no-zoo", action="store_true")
    ap.add_argument("--no-models", action="store_true")
    ap.add_argument("--no-serve", action="store_true")
    args = ap.parse_args(argv)

    t0 = time.monotonic()
    targets = build_targets(args.arch, zoo=not args.no_zoo,
                            models=not args.no_models,
                            serve=not args.no_serve)
    checks = args.checks.split(",") if args.checks else None
    report = run_checks(targets, checks=checks)
    wall = time.monotonic() - t0

    if args.write_baseline:
        path = write_baseline(args.baseline, report)
        print(f"wrote {len(load_baseline(path))} acknowledged findings "
              f"to {path}")
        return 0

    baseline = load_baseline(args.baseline)
    new = report.new_against(baseline, Severity.WARNING)

    for f in sorted(report.findings,
                    key=lambda f: (-f.severity, f.subject, f.code)):
        mark = "NEW " if f in new else ""
        print(f"{mark}{f}")
    print(f"-- {len(targets)} targets, {report.summary()}, "
          f"{len(new)} new vs baseline ({wall:.1f}s)")

    if args.json:
        from repro.bench.schema import save
        save(bench_report(report, len(new), wall), args.json)
        print(f"wrote {args.json}")

    if new:
        print(f"FAIL: {len(new)} finding(s) not in {args.baseline} — fix "
              "them, or acknowledge deliberately with --write-baseline",
              file=sys.stderr)
        return 1
    return 0
