# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.


def on_tpu() -> bool:
    """Whether the default jax backend is a real TPU (Pallas compiles
    natively); every kernel wrapper keys interpret-mode fallback off this
    ONE helper so a future backend rename is a one-line fix."""
    import jax
    return jax.default_backend() == "tpu"


def tpu_compiler_params(**kwargs):
    """Pallas TPU CompilerParams across the jax rename (TPUCompilerParams
    in older releases).  Raises a descriptive error if neither exists."""
    import jax.experimental.pallas.tpu as pltpu
    cls = getattr(pltpu, "CompilerParams",
                  getattr(pltpu, "TPUCompilerParams", None))
    if cls is None:
        raise ImportError(
            "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
            "TPUCompilerParams; unsupported jax version")
    return cls(**kwargs)
