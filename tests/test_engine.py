"""rosa.Engine execution-plan API: plan resolution, backend parity,
per-layer key folding, and trace-ledger EDP vs the analytical model."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import rosa
from repro.core import energy as E
from repro.core import mapping as M
from repro.core import mrr
from repro.core.constants import Mapping, ROSA_OPTIMAL

NOISY = rosa.RosaConfig(noise=mrr.PAPER_NOISE)


# ---------------------------------------------------------------------------
# ExecutionPlan resolution
# ---------------------------------------------------------------------------
def test_plan_override_beats_default():
    ws = rosa.RosaConfig(mapping=Mapping.WS)
    is_ = rosa.RosaConfig(mapping=Mapping.IS)
    plan = rosa.ExecutionPlan.build(ws, {"a": is_}, layers=("a", "b"))
    assert plan.resolve("a").mapping is Mapping.IS
    assert plan.resolve("b").mapping is Mapping.WS


def test_plan_rejects_unknown_override_names():
    with pytest.raises(ValueError, match="unknown layers"):
        rosa.ExecutionPlan.build(rosa.DEFAULT, {"nope": rosa.DEFAULT},
                                 layers=("a", "b"))


def test_plan_rejects_undeclared_lookup():
    plan = rosa.ExecutionPlan.build(rosa.DEFAULT, layers=("a",))
    with pytest.raises(KeyError):
        plan.resolve("zzz")
    # without a declared layer set, any name resolves to the default
    open_plan = rosa.ExecutionPlan.build(rosa.DEFAULT)
    assert open_plan.resolve("zzz") is rosa.DEFAULT


def test_plan_from_mapping_plan_and_projection():
    plan = rosa.ExecutionPlan.from_mapping_plan(
        NOISY, {"a": Mapping.IS}, layers=("a", "b"))
    assert plan.resolve("a").mapping is Mapping.IS
    assert plan.resolve("a").noise == mrr.PAPER_NOISE   # other fields kept
    assert plan.resolve("b").mapping is Mapping.WS
    assert plan.mapping_plan() == {"a": Mapping.IS}


def test_mapping_execution_plan_bridge():
    """core.mapping.execution_plan lifts profiled layers into an
    ExecutionPlan whose per-layer mapping is the balanced-metric argmin."""
    profiles = [
        M.LayerProfile("cheap_is", d_is=0.01, d_ws=0.01, e_is=1.0, e_ws=5.0),
        M.LayerProfile("cheap_ws", d_is=0.01, d_ws=0.01, e_is=5.0, e_ws=1.0),
        M.LayerProfile("contested", d_is=9.0, d_ws=0.1, e_is=1.0, e_ws=5.0),
    ]
    plan = M.execution_plan(profiles, NOISY)
    assert isinstance(plan, rosa.ExecutionPlan)
    for p in profiles:
        assert plan.resolve(p.name).mapping is M.choose_mapping(p)
        assert plan.resolve(p.name).noise == NOISY.noise     # cfg inherited
    assert plan.resolve("cheap_is").mapping is Mapping.IS    # EDP argmin
    assert plan.resolve("cheap_ws").mapping is Mapping.WS
    with pytest.raises(KeyError):
        plan.resolve("unprofiled")       # layer set comes from the profiles


def test_plan_is_static_pytree():
    plan = rosa.ExecutionPlan.build(rosa.DEFAULT, layers=("a",))
    assert jax.tree.leaves(plan) == []          # no array leaves: jit-static
    assert hash(plan) == hash(rosa.ExecutionPlan.build(rosa.DEFAULT,
                                                       layers=("a",)))


# ---------------------------------------------------------------------------
# Backend registry parity
# ---------------------------------------------------------------------------
def test_backend_registry_names():
    assert {"dense", "ref", "pallas"} <= set(rosa.backend_names())
    with pytest.raises(ValueError, match="unknown backend"):
        rosa.resolve_backend("not-a-backend")


def test_backend_parity_ideal_noise(key):
    """dense == ref == pallas under ideal noise (8-bit exactness).

    Explicit "ref"/"pallas" requests run their registered pipelines even at
    ideal settings (only "auto"/"dense" take the exactness shortcut), so
    this genuinely exercises all three contraction paths."""
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (24, 48))
    w = jax.random.normal(k2, (48, 16))
    ys = {b: rosa.rosa_matmul(x, w,
                              dataclasses.replace(rosa.DEFAULT, backend=b))
          for b in ("dense", "ref", "pallas")}
    np.testing.assert_allclose(np.asarray(ys["dense"]), np.asarray(ys["ref"]),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(ys["dense"]),
                               np.asarray(ys["pallas"]),
                               rtol=1e-4, atol=1e-4)


def test_backend_parity_noisy_operands(key):
    """With noise the exactness shortcut is bypassed, so this parity check
    exercises the actual registered contraction fns on identical
    noise-placed operands (same key -> same weight realization)."""
    k1, k2, kn = jax.random.split(key, 3)
    x = jax.random.normal(k1, (24, 48))
    w = jax.random.normal(k2, (48, 16))
    ys = {b: rosa.rosa_matmul(x, w, dataclasses.replace(NOISY, backend=b), kn)
          for b in ("dense", "ref", "pallas")}
    # sanity: the noisy path really differs from the clean shortcut
    assert float(jnp.max(jnp.abs(ys["dense"]
                                 - rosa.rosa_matmul(x, w)))) > 1e-5
    np.testing.assert_allclose(np.asarray(ys["dense"]), np.asarray(ys["ref"]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ys["dense"]),
                               np.asarray(ys["pallas"]),
                               rtol=1e-4, atol=1e-4)


def test_no_boolean_kernel_toggle():
    assert not hasattr(rosa.DEFAULT, "use_kernel")
    assert rosa.DEFAULT.backend == "auto"


# ---------------------------------------------------------------------------
# Deterministic per-layer key folding
# ---------------------------------------------------------------------------
def test_key_folding_determinism(key):
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (8, 32))
    w = jax.random.normal(k2, (32, 8))
    eng = rosa.Engine.from_config(NOISY, key=jax.random.PRNGKey(0))
    y_a1 = eng.matmul(x, w, name="a")
    y_a2 = eng.matmul(x, w, name="a")
    np.testing.assert_array_equal(np.asarray(y_a1), np.asarray(y_a2))
    # different layer name, different step, different base key -> new draws
    assert float(jnp.max(jnp.abs(y_a1 - eng.matmul(x, w, name="b")))) > 1e-6
    assert float(jnp.max(jnp.abs(
        y_a1 - eng.matmul(x, w, name="a", step=1)))) > 1e-6
    eng2 = eng.with_key(jax.random.PRNGKey(1))
    assert float(jnp.max(jnp.abs(y_a1 - eng2.matmul(x, w, name="a")))) > 1e-6
    # the folded key is exactly layer_key(base, name, step)
    y_direct = rosa.rosa_matmul(
        x, w, NOISY, rosa.layer_key(jax.random.PRNGKey(0), "a", 0))
    np.testing.assert_array_equal(np.asarray(y_a1), np.asarray(y_direct))


def test_engine_matmul_inside_jit(key):
    eng = rosa.Engine.from_config(NOISY, key=jax.random.PRNGKey(0))
    x = jax.random.normal(key, (4, 16))
    w = jnp.ones((16, 4))
    f = jax.jit(lambda x_, w_: eng.matmul(x_, w_, name="l"))
    np.testing.assert_allclose(np.asarray(f(x, w)),
                               np.asarray(eng.matmul(x, w, name="l")),
                               rtol=1e-6, atol=1e-6)


def test_dense_layers_bypass_optical(key):
    eng = rosa.Engine.from_layer_cfgs({"opt": rosa.DEFAULT},
                                      layers=("opt", "plain"))
    x = jax.random.normal(key, (4, 8))
    w = jnp.eye(8)
    np.testing.assert_array_equal(
        np.asarray(eng.matmul(x, w, name="plain")), np.asarray(x))
    assert float(jnp.max(jnp.abs(
        eng.matmul(x, w, name="opt") - x))) > 1e-6   # quantized path


# ---------------------------------------------------------------------------
# Ledger EDP == analytical plan EDP on a known 3-layer network
# ---------------------------------------------------------------------------
def test_ledger_edp_matches_plan_edp(key):
    layers = [E.LayerShape("a", m=16, k=32, n=8, kind="gemm"),
              E.LayerShape("b", m=16, k=8, n=24, kind="gemm"),
              E.LayerShape("c", m=16, k=24, n=10, kind="gemm")]
    plan = {"a": Mapping.IS, "c": Mapping.IS}          # "b" defaults to WS

    ledger = rosa.EnergyLedger()
    eng = rosa.Engine.from_hybrid_plan(
        NOISY, plan, layers=[l.name for l in layers],
        key=jax.random.PRNGKey(0), ledger=ledger)
    x = jax.random.normal(key, (16, 32))
    for l in layers:
        w = jnp.ones((l.k, l.n)) * 0.1
        x = eng.matmul(x, w, name=l.name)
    assert x.shape == (16, 10)

    edp_trace = ledger.edp(ROSA_OPTIMAL)
    edp_analytical = M.plan_edp(layers, plan, ROSA_OPTIMAL, batch=1)
    assert edp_trace == pytest.approx(edp_analytical, rel=1e-12)
    assert ledger.mapping_plan() == {"a": Mapping.IS, "b": Mapping.WS,
                                     "c": Mapping.IS}


def test_ledger_dedupes_retraced_layers(key):
    ledger = rosa.EnergyLedger()
    eng = rosa.Engine.from_config(rosa.DEFAULT, ledger=ledger)
    x = jax.random.normal(key, (4, 8))
    w = jnp.ones((8, 8))
    for _ in range(3):                      # MC loop re-routes the same layer
        eng.matmul(x, w, name="l")
    assert len(ledger.events) == 3
    assert len(ledger.unique_events()) == 1
    assert ledger.edp(ROSA_OPTIMAL) == pytest.approx(
        E.layer_energy(E.LayerShape("l", 4, 8, 8, kind="gemm"),
                       ROSA_OPTIMAL).edp, rel=1e-12)


def test_ledger_records_at_trace_time(key):
    """eval_shape populates the ledger without running any math."""
    ledger = rosa.EnergyLedger()
    eng = rosa.Engine.from_config(rosa.DEFAULT, ledger=ledger)
    jax.eval_shape(lambda x_, w_: eng.matmul(x_, w_, name="l"),
                   jax.ShapeDtypeStruct((6, 12), jnp.float32),
                   jax.ShapeDtypeStruct((12, 3), jnp.float32))
    assert [e.name for e in ledger.events] == ["l"]
    assert (ledger.events[0].m, ledger.events[0].k,
            ledger.events[0].n) == (6, 12, 3)


# ---------------------------------------------------------------------------
# Legacy shims are retired with a pointer to the Program migration note
# ---------------------------------------------------------------------------
def test_legacy_shims_removed():
    # importlib/getattr spellings keep this file clean under the ruff
    # TID251 banned-api rule that forbids importing the retired shims
    import importlib
    with pytest.raises(ImportError, match="rosa"):
        importlib.import_module("repro.core.onn_linear")
    module = importlib.import_module("repro.models.module")
    with pytest.raises(ImportError, match="rosa.compile"):
        _ = module.MatmulBackend
    with pytest.raises(ImportError, match="rosa"):
        _ = module.DENSE
