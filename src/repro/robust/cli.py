"""Experiment runners behind ``python -m repro.robust`` (and the
`robust_smoke` bench): quick-train a lite CNN, then run the requested
robustness study.  Every runner returns ``(summary_dict, [Metric])`` so
the CLI can print and/or serialize through the `repro.bench` schema and
the bench harness can gate the same numbers in CI.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro import rosa
from repro.bench.schema import Metric
from repro.core import mapping as M
from repro.core import mrr
from repro.core.constants import Mapping, ROSA_OPTIMAL
from repro.obs import trace as obs
from repro.robust import drift as D
from repro.robust import ensemble as ENS
from repro.robust import report as R
from repro.robust import sensitivity as S
from repro.robust import variation as V


def _trained(model: str, steps: int, seed: int = 0):
    from repro.training.cnn_train import train_cnn
    with obs.span("robust.train", cat="robust", model=model, steps=steps):
        return train_cnn(model, steps=steps, seed=seed)


def _noisy_cfg(sigma_scale: float = 1.0) -> rosa.RosaConfig:
    from repro.training.cnn_train import QAT_CFG
    noise = mrr.NoiseModel(sigma_dac=mrr.PAPER_NOISE.sigma_dac * sigma_scale,
                           sigma_th=mrr.PAPER_NOISE.sigma_th * sigma_scale)
    return dataclasses.replace(QAT_CFG, noise=noise)


def _names(model: str) -> list[str]:
    from repro.models.cnn import LITE_MODELS
    return [s.name for s in LITE_MODELS[model]]


def run_ensemble(model: str = "alexnet", *, steps: int = 150,
                 n_chips: int = 64, n_eval: int = 512,
                 sigma_scale: float = 1.0, seed: int = 0,
                 n_probe: int = 4, antithetic: bool = True,
                 params=None) -> tuple[dict, list[Metric]]:
    """N-chip wafer statistics of the QAT model under WS mapping.

    Default path: variance-reduced — the wafer is drawn with antithetic
    mirrored pairs and only ``n_probe`` chips get real eval-set forwards,
    the rest are predicted by the control-variate surrogate
    (`ensemble.estimate_ensemble`).  ``n_probe=0`` (CLI ``--exact``) runs
    brute-force MC over every chip.
    """
    if params is None:
        params, _ = _trained(model, steps, seed)
    key = jax.random.PRNGKey(seed + 1000)
    k_ens, k_mc = jax.random.split(key)
    ens = V.sample_ensemble(k_ens, n_chips, V.cnn_lane_dims(model),
                            V.PAPER_VARIATION.scaled(sigma_scale),
                            antithetic=antithetic)
    engine = rosa.Engine.from_config(_noisy_cfg(sigma_scale),
                                     layers=_names(model))
    est = ENS.EstimatorConfig(n_probe=n_probe, antithetic=antithetic) \
        if n_probe else None
    res = ENS.evaluate_cnn_ensemble(params, model, engine, ens, k_mc,
                                    n_eval=n_eval, estimator=est)
    summary = {"model": model, **res.summary(),
               "yield_curve": res.yield_curve((1.0, 2.0, 5.0))}
    # ensemble_metrics already carries yield_2pp; add the curve endpoints
    metrics = R.ensemble_metrics(res, gate=True) \
        + R.yield_curve_metrics(res, drops_pp=(1.0, 5.0))
    return summary, metrics


def run_sensitivity(model: str = "alexnet", *, steps: int = 150,
                    n_chips: int = 16, n_eval: int = 256,
                    sigma_scale: float = 1.0, seed: int = 0,
                    antithetic: bool = True,
                    params=None) -> tuple[dict, list[Metric]]:
    """Vectorized perturb-one-layer profile -> accuracy-aware hybrid plan.

    The searched plan is evaluated against pure WS on the SAME chip
    ensemble (Table-4 direction: hybrid accuracy >= WS accuracy, lower
    EDP).  The degradation matrix runs the shared-forward path — one
    compiled program covers both mappings and every one-hot layer — over
    an antithetic ensemble (default), and the final hybrid/WS evaluations
    share ONE compiled evaluator via traced mapping gates
    (`ensemble.make_plan_eval`).
    """
    import numpy as np

    if params is None:
        params, _ = _trained(model, steps, seed)
    key = jax.random.PRNGKey(seed + 2000)
    k_ens, k_prof, k_mc = jax.random.split(key, 3)
    names = _names(model)
    ens = V.sample_ensemble(k_ens, n_chips, V.cnn_lane_dims(model),
                            V.PAPER_VARIATION.scaled(sigma_scale),
                            antithetic=antithetic)
    cfg = _noisy_cfg(sigma_scale)

    deg = S.cnn_degradation_matrix(params, model, key=k_prof, ensemble=ens,
                                   noise=cfg.noise, n_eval=n_eval)
    from repro.configs.paper_cnns import CNN_WORKLOADS
    rows = [l for l in CNN_WORKLOADS[model] if l.name in deg]
    profiles = S.profile_layers_mc(rows, ROSA_OPTIMAL, deg, batch=128)
    plan, search = S.searched_cnn_hybrid_plan(profiles, params, model, ens,
                                              k_mc, noise=cfg.noise,
                                              n_eval=n_eval)

    e_ws = rosa.Engine.from_config(cfg, layers=names)
    x, yl = ENS.cnn_eval_set(n_eval)
    keys = jax.random.split(k_mc, n_chips)
    evaluator = ENS.make_plan_eval(ENS.cnn_apply_fn(model), e_ws, names,
                                   eval_batch=128)

    def eval_sel(sel) -> ENS.EnsembleResult:
        """Evaluate one mapping-gate vector through the shared evaluator."""
        accs, agree, clean = evaluator(params, x, yl, ens, keys,
                                       jnp.asarray(sel, dtype=jnp.float32))
        return ENS.EnsembleResult(accs=np.asarray(accs),
                                  agreement=np.asarray(agree),
                                  clean_acc=float(clean))

    sel_h = [1.0 if plan.get(n) is Mapping.IS else 0.0 for n in names]
    res_h = eval_sel(sel_h)
    res_ws = eval_sel([0.0] * len(names))
    gain = res_h.mean_acc - res_ws.mean_acc
    if gain < 0.0 and plan:
        # the search verified under superposed-mapping keys; if the final
        # independent evaluation disagrees (rare, small-|gain| MC edge),
        # fall back to pure WS — "matches" is guaranteed by construction
        plan, res_h, gain = {}, res_ws, 0.0
    edp_ratio = (M.plan_edp(rows, plan, ROSA_OPTIMAL, batch=128)
                 / M.plan_edp(rows, {}, ROSA_OPTIMAL, batch=128))
    n_is = sum(1 for v in plan.values() if v is Mapping.IS)

    summary = {"model": model, "plan": {k: v.value for k, v in plan.items()},
               "plan_is_layers": n_is, "clean_acc": res_h.clean_acc,
               "hybrid_mean_acc": res_h.mean_acc,
               "ws_mean_acc": res_ws.mean_acc,
               "hybrid_minus_ws_pp": gain,
               "hybrid_vs_ws_edp": edp_ratio,
               "search": search,
               "degradation": deg}
    metrics = [
        Metric("n_chips", n_chips, gate=True, rel_tol=0.0),
        # rel_tol 0.1: XLA CPU reduction-order drift moves trained-CNN
        # accuracies by up to ~2pp per machine generation (PR 6 observed
        # 3.6pp on a 65% baseline = 5.5%, breaching the old 5% gate);
        # 10% ≈ 6pp headroom covers it with margin while still catching
        # real regressions (the hybrid-vs-WS direction gate below is the
        # tight contract)
        Metric("hybrid_mean_acc", res_h.mean_acc, unit="%", gate=True,
               rel_tol=0.1, direction="higher_is_better"),
        # the Table-4 direction claim: gated so hybrid may never fall
        # below WS (rel_tol 1.0 tolerates drift down to ~0 gain)
        Metric("hybrid_minus_ws_pp", gain, unit="pp", gate=True,
               rel_tol=1.0, direction="higher_is_better"),
        # ungated: WHICH prefix the verified search keeps can flip on
        # sub-pp numeric differences across CPU generations, and every
        # prefix is accuracy-safe — the EDP ratio is a recorded outcome,
        # not a contract
        Metric("hybrid_vs_ws_edp", edp_ratio, unit="ratio",
               direction="lower_is_better"),
        Metric("hybrid_yield_2pp", res_h.yield_frac(2.0), unit="frac",
               gate=True, rel_tol=0.5, direction="higher_is_better"),
    ]
    return summary, metrics


def run_smoke(model: str = "alexnet", *, steps: int = 40,
              n_chips: int = 16, n_probe: int = 2, n_eval: int = 64,
              max_candidates: int = 3, seed: int = 0,
              params=None, cache: "rosa.PlanCache | None" = None
              ) -> tuple[dict, list[Metric]]:
    """The whole robustness pipeline through ONE compiled evaluator.

    Budget-mode composition of `run_ensemble` + `run_sensitivity` for the
    `robust_smoke` bench: ensemble probe forwards, every degradation-matrix
    cell, every plan-search candidate and both final plan evaluations
    re-dispatch a single gated plan evaluator (`ensemble.make_plan_eval`
    with traced one-hot analog gates AND traced mapping gates), so the
    pipeline pays exactly one XLA compilation.  Wafer statistics use the
    variance-reduced estimator: ``n_chips`` antithetic chips, ``n_probe``
    real forwards, control-variate surrogate for the rest.  The degradation
    matrix is stored in the content-addressed `rosa.PlanCache` — a warm
    run skips the whole MC profiling stage.
    """
    import numpy as np

    if params is None:
        params, _ = _trained(model, steps, seed)
    key = jax.random.PRNGKey(seed + 5000)
    k_ens, k_prof, k_mc = jax.random.split(key, 3)
    names = _names(model)
    cfg = _noisy_cfg(1.0)
    cfg_ws = dataclasses.replace(cfg, mapping=Mapping.WS)
    engine = rosa.Engine(rosa.ExecutionPlan.build(cfg_ws, None, names))
    apply_fn = ENS.cnn_apply_fn(model)
    x, yl = ENS.cnn_eval_set(n_eval)
    evaluator = ENS.make_plan_eval(apply_fn, engine, names,
                                   eval_batch=n_eval, gated=True)
    ones = jnp.ones(len(names), dtype=jnp.float32)
    zeros = jnp.zeros(len(names), dtype=jnp.float32)

    # --- ensemble: n_probe real forwards + control-variate prediction ---
    with obs.span("robust.ensemble_probe", cat="robust", n_probe=n_probe):
        ens = V.sample_ensemble(k_ens, n_chips, V.cnn_lane_dims(model),
                                V.PAPER_VARIATION, antithetic=True)
        probes = V.chip_slice(ens, n_probe)
        keys_mc = jax.random.split(k_mc, n_chips)[:n_probe]
        p_accs, p_agree, clean_acc = evaluator(params, x, yl, probes,
                                               keys_mc, zeros, ones)
        feats = ENS.surrogate_features(ENS.layer_weights(params, names),
                                       ens, engine)
        res_ens = ENS.EnsembleResult(
            accs=ENS.control_variate_accs(np.asarray(p_accs), feats,
                                          n_probe),
            agreement=np.asarray(p_agree), clean_acc=float(clean_acc),
            n_probe=n_probe, method="control-variate")

    # --- degradation matrix: PlanCache-backed, shared-compile cells ---
    cache = cache if cache is not None else rosa.PlanCache()
    spec = {"kind": "cnn-smoke", "model": model, "n_probe": n_probe,
            "n_eval": n_eval, "antithetic": True, "seed": seed,
            "noise": rosa.serialize.to_jsonable(cfg.noise),
            "variation": rosa.serialize.to_jsonable(V.PAPER_VARIATION),
            "params": S.params_digest(params)}
    mkey = cache.matrix_key(cfg_ws, spec)
    deg = cache.load_matrix(mkey)
    matrix_cached = deg is not None and all(n in deg for n in names)
    if not matrix_cached:
        from repro.training.cnn_train import QAT_CFG
        with obs.span("robust.degradation_matrix", cat="robust",
                      layers=len(names)):
            deg = S.degradation_matrix(apply_fn, params, x, yl, names,
                                       QAT_CFG, probes, k_prof,
                                       evaluator=evaluator)
        cache.store_matrix(mkey, deg)

    # --- plan search + final evaluations, same executable ---
    from repro.configs.paper_cnns import CNN_WORKLOADS
    rows = [l for l in CNN_WORKLOADS[model] if l.name in deg]
    with obs.span("robust.plan_search", cat="robust", layers=len(rows)):
        profiles = S.profile_layers_mc(rows, ROSA_OPTIMAL, deg, batch=128)
        plan, search = S.searched_hybrid_plan(
            profiles, apply_fn, params, x, yl, cfg_ws, probes, k_mc,
            max_candidates=max_candidates, evaluator=evaluator)

    keys_f = jax.random.split(k_mc, n_probe)

    def eval_sel(sel) -> ENS.EnsembleResult:
        """Evaluate one mapping-gate vector through the shared evaluator."""
        accs, agree, clean = evaluator(params, x, yl, probes, keys_f,
                                       jnp.asarray(sel, dtype=jnp.float32),
                                       ones)
        return ENS.EnsembleResult(accs=np.asarray(accs),
                                  agreement=np.asarray(agree),
                                  clean_acc=float(clean))

    with obs.span("robust.final_eval", cat="robust"):
        sel_h = [1.0 if plan.get(n) is Mapping.IS else 0.0 for n in names]
        res_h = eval_sel(sel_h)
        res_ws = eval_sel([0.0] * len(names))
    gain = res_h.mean_acc - res_ws.mean_acc
    if gain < 0.0 and plan:
        # the search verified under the same evaluator and keys; a
        # negative final gain can only come from MC noise on a sub-pp
        # margin — fall back to pure WS ("matches" by construction)
        plan, res_h, gain = {}, res_ws, 0.0
    edp_ratio = (M.plan_edp(rows, plan, ROSA_OPTIMAL, batch=128)
                 / M.plan_edp(rows, {}, ROSA_OPTIMAL, batch=128))

    summary = {"model": model, **{f"ens_{k}": v
                                  for k, v in res_ens.summary().items()},
               "plan": {k: v.value for k, v in plan.items()},
               "hybrid_mean_acc": res_h.mean_acc,
               "ws_mean_acc": res_ws.mean_acc,
               "hybrid_minus_ws_pp": gain,
               "hybrid_vs_ws_edp": edp_ratio,
               "matrix_cached": matrix_cached,
               "search": search, "degradation": deg}
    metrics = (
        [dataclasses.replace(m, name=f"ens_{m.name}")
         for m in R.ensemble_metrics(res_ens, gate=True)
         + R.yield_curve_metrics(res_ens, drops_pp=(1.0, 5.0))]
        + [
            Metric("sens_n_chips", n_probe, gate=True, rel_tol=0.0),
            # rel_tol 0.1 / 1.0 / 0.5: same XLA reduction-order headroom
            # rationale as run_sensitivity (see comment there)
            Metric("sens_hybrid_mean_acc", res_h.mean_acc, unit="%",
                   gate=True, rel_tol=0.1, direction="higher_is_better"),
            Metric("sens_hybrid_minus_ws_pp", gain, unit="pp", gate=True,
                   rel_tol=1.0, direction="higher_is_better"),
            Metric("sens_hybrid_vs_ws_edp", edp_ratio, unit="ratio",
                   direction="lower_is_better"),
            Metric("sens_hybrid_yield_2pp", res_h.yield_frac(2.0),
                   unit="frac", gate=True, rel_tol=0.5,
                   direction="higher_is_better"),
        ])
    return summary, metrics


def run_drift(model: str = "alexnet", *, steps: int = 150,
              n_chips: int = 16, n_eval: int = 256, seed: int = 0,
              kind: str = "sine", amp_k: float = 0.25,
              period_s: float = 3600.0, t_end_s: float = 3600.0,
              n_t: int = 9, retrim_every: float | None = 900.0,
              params=None) -> tuple[dict, list[Metric]]:
    """Accuracy-over-time under thermal drift, with and without periodic
    re-trim (re-invoking the `voltage_of_weight` calibration).
    """
    import numpy as np
    if params is None:
        params, _ = _trained(model, steps, seed)
    key = jax.random.PRNGKey(seed + 3000)
    k_ens, k_mc = jax.random.split(key)
    ens = V.sample_ensemble(k_ens, n_chips, V.cnn_lane_dims(model))
    engine = rosa.Engine.from_config(_noisy_cfg(), layers=_names(model))
    dm = D.DriftModel(kind=kind, amp_k=amp_k, period_s=period_s)
    t_grid = np.linspace(0.0, t_end_s, n_t)
    # ONE compiled evaluator serves both simulations (and every time step)
    evaluator = ENS.make_ensemble_eval(ENS.cnn_apply_fn(model), engine,
                                       eval_batch=128)
    trimmed = D.simulate_cnn(params, model, engine, ens, k_mc, dm, t_grid,
                             retrim_every, n_eval=n_eval,
                             evaluator=evaluator)
    free = D.simulate_cnn(params, model, engine, ens, k_mc, dm, t_grid,
                          None, n_eval=n_eval, evaluator=evaluator)
    summary = {"model": model, "times_s": t_grid.tolist(),
               "retrim": trimmed.summary(), "no_retrim": free.summary(),
               "retrim_mean_acc": trimmed.mean_acc.tolist(),
               "no_retrim_mean_acc": free.mean_acc.tolist()}
    metrics = [
        Metric("worst_acc_retrim", trimmed.worst_mean_acc(), unit="%",
               gate=True, rel_tol=0.05, direction="higher_is_better"),
        Metric("worst_acc_no_retrim", free.worst_mean_acc(), unit="%"),
        Metric("retrim_gain_pp",
               trimmed.worst_mean_acc() - free.worst_mean_acc(), unit="pp",
               direction="higher_is_better"),
        Metric("min_yield_2pp_retrim", float(trimmed.yield_2pp.min()),
               unit="frac", direction="higher_is_better"),
    ]
    return summary, metrics


def run_sweep(model: str = "alexnet", *, steps: int = 150,
              n_chips: int = 32, n_eval: int = 256, seed: int = 0,
              scales: tuple = (0.0, 0.5, 1.0, 1.5, 2.0),
              params=None) -> tuple[dict, list[Metric]]:
    """Accuracy-vs-sigma / yield-vs-sigma curves (per-shot AND static
    sigmas scaled together).
    """
    if params is None:
        params, _ = _trained(model, steps, seed)
    key = jax.random.PRNGKey(seed + 4000)
    k_ens, k_mc = jax.random.split(key)
    names = _names(model)
    base_ens = V.sample_ensemble(k_ens, n_chips, V.cnn_lane_dims(model))

    def eval_at(s: float) -> ENS.EnsembleResult:
        """Ensemble statistics at noise scale `s`."""
        engine = rosa.Engine.from_config(_noisy_cfg(s), layers=names)
        return ENS.evaluate_cnn_ensemble(
            params, model, engine, V.scale_ensemble(base_ens, s), k_mc,
            n_eval=n_eval)

    rows = R.sigma_sweep(eval_at, scales)
    summary = {"model": model, "rows": rows}
    return summary, R.sweep_metrics(rows)


RUNNERS = {"ensemble": run_ensemble, "sensitivity": run_sensitivity,
           "smoke": run_smoke, "drift": run_drift, "sweep": run_sweep}
