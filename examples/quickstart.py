"""Quickstart: the paper's core pieces in ~60 lines.

  1. the MRR voltage->weight physics chain (Fig. 5),
  2. an OSA bit-serial optical matmul == its exact digital reference,
  3. the rosa.Engine: hybrid WS/IS execution plan, per-layer keys, and
     trace-based energy accounting from the same routed matmuls,
  4. rosa.compile: the compile-once Program — trace the workload, autotune
     the hybrid plan against it, cache the searched plan on disk (the
     second compile is a warm cache hit that skips the search),
  5. the energy model: one conv layer with and without OSA,
  6. the array-size DSE winner.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro import rosa
from repro.core import dse, energy, mrr, osa
from repro.core.constants import Mapping, ROSA_OPTIMAL
from repro.configs.paper_cnns import WORKLOADS

key = jax.random.PRNGKey(0)

# 1. physics: program weights through the V -> dT -> d_lambda -> T -> w chain
targets = jnp.linspace(-1, 1, 5)
volts = mrr.voltage_of_weight(targets)
realized = mrr.realize_weights(targets)
noisy = mrr.realize_weights(targets, key, noise=mrr.PAPER_NOISE)
print("targets :", targets)
print("volts   :", jnp.round(volts, 3))
print("ideal   :", jnp.round(realized, 4))
print("noisy   :", jnp.round(noisy, 4))

# 2. OSA optical matmul == fake-quant reference (Eq. 1 == Eq. 2)
x = jax.random.normal(key, (4, 32))
w = jax.random.normal(jax.random.PRNGKey(1), (32, 8))
y_osa = osa.osa_matmul_ref(x, w)
from repro.core.quant import fake_quant
print("\nOSA == 8-bit reference:",
      bool(jnp.allclose(y_osa, fake_quant(x) @ w, atol=1e-4)))

# 3. the Engine: one object owns the per-layer execution plan (hybrid WS/IS
#    mapping), deterministic per-layer PRNG keys folded from a single base
#    key, and an EnergyLedger that prices the routed matmuls themselves.
ledger = rosa.EnergyLedger()
engine = rosa.Engine.from_hybrid_plan(
    rosa.RosaConfig(noise=mrr.PAPER_NOISE),      # default: WS everywhere
    {"proj_is": Mapping.IS},                     # hybrid-plan override
    key=key, ledger=ledger)
print()
for name in ("proj_ws", "proj_is"):
    y = engine.matmul(x, w, name=name)           # key folded from `name`
    err = jnp.mean(jnp.abs(y - x @ w))
    mp = engine.config(name).mapping
    print(f"layer={name}  mapping={mp.value:17s} mean |err| = {float(err):.4f}")
traced_plan = {k: v.value for k, v in ledger.mapping_plan().items()}
print(f"traced EDP of those two matmuls on the (8,8) array: "
      f"{ledger.edp(ROSA_OPTIMAL):.3e} J*s "
      f"({len(ledger)} events, plan={traced_plan})")

# 4. compile-once Program: one abstract trace captures the whole workload,
#    the layer-wise hybrid plan is autotuned on it, and the searched plan
#    persists in a content-addressed disk cache
import tempfile

w2 = jax.random.normal(jax.random.PRNGKey(2), (8, 16))

def toy_net(eng, x, w, w2):
    h = eng.matmul(x, w, name="proj_in")
    return eng.matmul(h, w2, name="proj_out")

with tempfile.TemporaryDirectory() as cache_dir:
    base = rosa.Engine.from_config(rosa.RosaConfig(noise=mrr.PAPER_NOISE))
    tune = dict(autotune=rosa.AutotuneConfig(batch=4), cache=cache_dir)
    cold = rosa.compile(toy_net, base, (x, w, w2), **tune)
    warm = rosa.compile(toy_net, base, (x, w, w2), **tune)
    y = cold(x, w, w2, key=key)
    print(f"\ncompile: cold searched={cold.searched}, "
          f"warm cache_hit={warm.cache_hit} (plans equal: "
          f"{cold.plan == warm.plan})")
    print("autotuned plan:",
          {k: v.value for k, v in cold.plan.mapping_plan().items()})

# 5. energy: OSA cuts the ADC events per output from 7 to 1
layer = energy.LayerShape("conv3", m=64, k=1728, n=384)
no = energy.layer_energy(layer, ROSA_OPTIMAL, osa=energy.NO_OSA, batch=128)
ya = energy.layer_energy(layer, ROSA_OPTIMAL, osa=energy.OSA_OPTIMAL,
                         batch=128)
print(f"\nconv3 EDP: no-OSA {no.edp:.3e}  with-OSA {ya.edp:.3e} "
      f"({(1 - ya.edp / no.edp) * 100:.0f}% lower)")

# 6. the DSE winner across all six workloads
wls = [dse.Workload(n, ls) for n, ls in WORKLOADS.items()]
best = dse.best(wls, batch=128)
print(f"DSE winner: {best.label} (paper: R=8,C=8)")
