"""Serving configuration: slots, chunking, sampling, optical engine.

`ServeConfig` is the one knob-bundle the whole subsystem reads; it is
frozen/hashable so jitted step factories can close over it safely.
`serving_model_config` derives the serving variant of a `ModelConfig`:
continuous batching decodes at RAGGED per-slot positions, so every
attention cache write must take the scatter path (`uniform_decode=False`)
— including MLA's compressed cache.
"""

from __future__ import annotations

import dataclasses

from repro.models.transformer import ModelConfig


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Continuous-batching serving knobs.

    n_slots        concurrent sequences in the decode batch (slot count)
    max_len        per-slot cache capacity: prompt + generated tokens
    prefill_chunk  tokens per prefill chunk — bounds how long one queued
                   prompt can stall the running decode batch
    temperature    sampling temperature (0 = greedy).  Traced at call time:
                   changing it never recompiles the step.
    seed           base PRNG seed; per-token sampling keys fold
                   (request id, token index) so a request's sample stream
                   is invariant to HOW it was scheduled
    collect_logits serving step also returns per-slot logits (tests)
    evict_on_done  zero a slot's cache rows when its request completes
                   (admission overwrites anyway; this guarantees freed
                   state never outlives its request)
    rosa           route MLP projections through the optical engine: the
                   decode step is compiled into one `rosa.Program` (plan
                   autotuned on the decode trace, disk plan cache) and
                   every jitted step is built from it; optional pinned chip
    rosa_backend   contraction backend name for the optical path
    variation_seed pin ONE sampled fabricated chip (repro.robust
                   StaticVariation) for every decode; None = ideal device
    """

    n_slots: int = 4
    max_len: int = 64
    prefill_chunk: int = 8
    temperature: float = 0.0
    seed: int = 0
    collect_logits: bool = False
    evict_on_done: bool = False
    rosa: bool = False
    rosa_backend: str = "ref"
    variation_seed: int | None = None

    def __post_init__(self):
        if self.n_slots < 1:
            raise ValueError("n_slots must be >= 1")
        if self.prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        if self.max_len < self.prefill_chunk:
            raise ValueError("max_len must be >= prefill_chunk")


def serving_model_config(cfg: ModelConfig, rosa: bool = False) -> ModelConfig:
    """The serving variant of a model config: ragged (scatter) cache writes
    everywhere, and optionally the optical MLP path enabled.

    Encoder-decoder families are rejected: the serving prefill has no
    encoder pass, so their requests would silently cross-attend to an
    all-zero memory."""
    if cfg.is_encdec:
        raise NotImplementedError(
            f"{cfg.name}: encoder-decoder serving is not supported — the "
            "request path has no encoder invocation (prompts are token "
            "ids, not source embeddings)")
    kw: dict = {"uniform_decode": False}
    if cfg.mla is not None:
        kw["mla"] = dataclasses.replace(cfg.mla, uniform_decode=False)
    if rosa:
        kw["rosa_mlp"] = True
    return dataclasses.replace(cfg, **kw)
