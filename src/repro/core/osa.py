"""Optical Shift-and-Add (OSA) module semantics — paper Sec. 3.1, Fig. 3(c).

The OSA module performs, purely in the optical domain,

    y = sum_k sum_t 2^(t-N_T) * w_k * b_{k,t}        (Eq. 1)
      = sum_k w_k * x_k                              (Eq. 2)

where the *shift* (power-of-two scaling of bit slot t) is a chain of 1:1
light splitters and the temporal alignment of slots is done by optical delay
lines (ODLs); the *add* is photodetection + TIA, which natively integrates
aligned optical power.

The payoff is architectural, not mathematical: without OSA the photocurrent
must be digitized once per bit slot (N_T ADC conversions per output); with
OSA the slots accumulate optically and the ADC fires once per output.  The
energy model (energy.py) counts exactly that.

This module provides:
  * `osa_mac` / `osa_matmul_ref`: bit-exact reference semantics (the oracle
    for the Pallas kernel in kernels/osa_matmul).
  * non-ideality knobs: splitter imbalance (the divide-by-2 ratio is not
    exactly 1/2), per-slot ODL delay mis-alignment modeled as a multiplicative
    slot-gain error, and ODL insertion loss per stage.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import quant as Q


@dataclasses.dataclass(frozen=True)
class OSAConfig:
    """Physical configuration of one OSA chain."""

    n_slots: int = 7               # N_T (+1 slots indexed 0..N_T in Eq. 1)
    pam_bits: int = 1              # 1 = balanced ternary; k>1 = PAM-2^k digits
    splitter_imbalance: float = 0.0   # eps: splits are (0.5+eps, 0.5-eps)
    odl_loss_db_per_stage: float = 0.0  # insertion loss per shift stage [dB]
    slot_jitter_sigma: float = 0.0      # std of per-slot gain error from delay
    #   mis-alignment (paper: mitigated by active phase-modulator calibration)

    @property
    def is_ideal(self) -> bool:
        return (self.splitter_imbalance == 0.0
                and self.odl_loss_db_per_stage == 0.0
                and self.slot_jitter_sigma == 0.0)


IDEAL_OSA = OSAConfig()


def slot_gains(cfg: OSAConfig, key: jax.Array | None = None,
               dtype=jnp.float32) -> jax.Array:
    """Effective gain of each bit slot after the splitter/ODL chain.

    Ideal slot t (t=0 LSB) passes through k*(n_slots-1-t) divide-by-two
    stages (k = pam_bits, 1 for ternary), so its gain is 2^(k*t) in integer
    significance units (matching quant.plane_weights / pam_plane_weights);
    splitter imbalance / loss / jitter fold multiplicatively on top.
    """
    t = jnp.arange(cfg.n_slots)
    gains = (2.0 ** (cfg.pam_bits * t)).astype(dtype)
    if cfg.splitter_imbalance != 0.0:
        # slot t passes through k*(n_slots-1-t) splitter stages; each stage
        # routes the 'shifted' arm a fraction (0.5+eps) instead of 0.5.
        stages = (cfg.pam_bits * (cfg.n_slots - 1 - t)).astype(dtype)
        per_stage = (0.5 + cfg.splitter_imbalance) / 0.5
        gains = gains * per_stage ** stages
    if cfg.odl_loss_db_per_stage != 0.0:
        stages = (cfg.pam_bits * (cfg.n_slots - 1 - t)).astype(dtype)
        loss = 10.0 ** (-cfg.odl_loss_db_per_stage * stages / 10.0)
        gains = gains * loss
    if cfg.slot_jitter_sigma != 0.0:
        if key is None:
            raise ValueError("slot jitter requires a PRNG key")
        gains = gains * (1.0 + cfg.slot_jitter_sigma
                         * jax.random.normal(key, (cfg.n_slots,), dtype))
    return gains


def osa_mac(x_digits: jax.Array, w: jax.Array, cfg: OSAConfig = IDEAL_OSA,
            key: jax.Array | None = None) -> jax.Array:
    """One OSA accumulate: digits (n_slots, K) x weights (K,) -> scalar.

    Bit-exact reference of Eq. (1): per-slot products are scaled by the slot
    gain (the optical shift) and *then* summed across both slots and
    wavelengths by a single photodetection event.
    """
    g = slot_gains(cfg, key, x_digits.dtype)
    per_slot = x_digits @ w                      # (n_slots,) optical power/slot
    return jnp.sum(g * per_slot)


def osa_matmul_ref(x: jax.Array, w: jax.Array, cfg: OSAConfig = IDEAL_OSA,
                   quant: Q.QuantConfig = Q.Q8,
                   key: jax.Array | None = None,
                   per_vector: bool = False) -> jax.Array:
    """Full OSA matmul reference: float x (M,K) @ w (K,N) via the optical path.

    Pipeline (exactly what the hardware does):
      1. quantize x to `quant.bits` ints (the DAC feeding the EO modulators),
      2. signed-digit/PAM decompose into time slots,
      3. per-slot 'matmul' = the wavelength-parallel MRR weighting,
      4. OSA shift-and-add across slots (slot gains = powers of two),
      5. rescale by the quantization scale (done electronically after ADC).

    With an ideal OSAConfig this equals fake-quant(x) @ w to float precision.
    This function is the oracle for kernels/osa_matmul.
    """
    q, scale = Q.quantize(x, quant, per_vector=per_vector)
    if cfg.pam_bits == 1:
        digits = Q.decompose_planes(q, quant)          # (T, M, K)
    else:
        digits = Q.decompose_pam(q, cfg.pam_bits, quant)
    g = slot_gains(dataclasses.replace(cfg, n_slots=digits.shape[0],
                                       pam_bits=cfg.pam_bits), key, w.dtype)
    per_slot = jnp.einsum("tmk,kn->tmn", digits.astype(w.dtype), w)
    y = jnp.einsum("t,tmn->mn", g, per_slot)
    return y * (scale / quant.qmax)


def required_slot_count(quant: Q.QuantConfig, pam_bits: int = 1) -> int:
    """Slots per input value: B-1 for ternary, ceil((B-1)/k) for PAM-k."""
    return -(-quant.n_planes // pam_bits)


def osa_latency_slots(n_values: int, quant: Q.QuantConfig = Q.Q8,
                      pam_bits: int = 1) -> int:
    """Bit-slot count to stream n_values inputs through one OSA chain."""
    return n_values * required_slot_count(quant, pam_bits)
