"""QAT training + noisy evaluation for the reduced CNN families.

Matches the paper's Sec. 4 protocol: train with uniform 8-bit quantization
of inputs/weights (straight-through), then evaluate under DAC + thermal
noise with a chosen per-layer IS/WS mapping.  All on synth-CIFAR
(DESIGN.md §8 — CIFAR-10 itself is not available offline).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mrr
from repro.core.constants import ComputeMode, Mapping
from repro.core.onn_linear import RosaConfig
from repro.data.synth_cifar import train_test_split
from repro.models.cnn import LITE_MODELS, LITE_SKIPS, cnn_apply, cnn_def
from repro.models.layers import softmax_xent
from repro.models.module import init_params

QAT_CFG = RosaConfig(mode=ComputeMode.MIXED, noise=mrr.IDEAL)


def _loss(params, specs, skips, x, y, layer_cfgs, key=None):
    logits = cnn_apply(params, specs, x, layer_cfgs, key,
                       residual_from=skips)
    labels = jax.nn.one_hot(y, logits.shape[-1])
    return -jnp.mean(jnp.sum(labels * jax.nn.log_softmax(logits), -1))


def train_cnn(model: str = "alexnet", steps: int = 400, batch: int = 64,
              lr: float = 3e-3, seed: int = 0, qat: bool = True,
              n_train: int = 4096, verbose: bool = False):
    """Returns (params, clean_test_accuracy)."""
    specs = LITE_MODELS[model]
    skips = LITE_SKIPS.get(model)
    (xtr, ytr), (xte, yte) = train_test_split(n_train=n_train, seed=seed)
    key = jax.random.PRNGKey(seed)
    params = init_params(cnn_def(specs), key)
    cfgs = {s.name: QAT_CFG for s in specs} if qat else {}

    # Adam
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)

    @jax.jit
    def step(params, m, v, i, x, y):
        loss, g = jax.value_and_grad(_loss)(params, specs, skips, x, y, cfgs)
        m = jax.tree.map(lambda a, b: 0.9 * a + 0.1 * b, m, g)
        v = jax.tree.map(lambda a, b: 0.99 * a + 0.01 * b * b, v, g)
        t = i + 1
        params = jax.tree.map(
            lambda p, mm, vv: p - lr * (mm / (1 - 0.9 ** t))
            / (jnp.sqrt(vv / (1 - 0.99 ** t)) + 1e-8), params, m, v)
        return params, m, v, loss

    rng = np.random.default_rng(seed)
    for i in range(steps):
        idx = rng.integers(0, len(xtr), batch)
        params, m, v, loss = step(params, m, v, i,
                                  jnp.asarray(xtr[idx]), jnp.asarray(ytr[idx]))
        if verbose and i % 100 == 0:
            print(f"  step {i} loss {float(loss):.3f}")

    acc = evaluate_cnn(params, model, cfgs)
    return params, acc


@functools.lru_cache(maxsize=4)
def _test_set(seed: int = 0):
    (_, _), (xte, yte) = train_test_split(seed=seed)
    return jnp.asarray(xte), jnp.asarray(yte)


def evaluate_cnn(params, model: str, layer_cfgs: dict | None = None,
                 key: jax.Array | None = None, n_mc: int = 1,
                 seed: int = 0) -> float:
    """Test accuracy (%); with a noisy cfg and n_mc>1, MC-average."""
    specs = LITE_MODELS[model]
    skips = LITE_SKIPS.get(model)
    xte, yte = _test_set(seed)

    @jax.jit
    def acc_of(params, k):
        logits = cnn_apply(params, specs, xte, layer_cfgs, k,
                           residual_from=skips)
        return jnp.mean(jnp.argmax(logits, -1) == yte)

    if key is None and n_mc == 1:
        return float(acc_of(params, None)) * 100.0
    keys = jax.random.split(key if key is not None
                            else jax.random.PRNGKey(7), n_mc)
    return float(jnp.mean(jnp.stack([acc_of(params, k)
                                     for k in keys]))) * 100.0


def layer_noise_profile(params, model: str, *,
                        noise: mrr.NoiseModel = mrr.PAPER_NOISE,
                        n_mc: int = 3, seed: int = 0) -> dict:
    """d_l(m): accuracy drop (pp) when ONLY layer l is noisy-analog under
    mapping m, all other layers exact 8-bit (paper Fig. 6 protocol)."""
    specs = LITE_MODELS[model]
    base_cfgs = {s.name: QAT_CFG for s in specs}
    clean = evaluate_cnn(params, model, base_cfgs)
    out: dict[str, dict[str, float]] = {}
    key = jax.random.PRNGKey(seed + 100)
    for s in specs:
        out[s.name] = {}
        for mp in (Mapping.IS, Mapping.WS):
            cfgs = dict(base_cfgs)
            cfgs[s.name] = dataclasses.replace(
                QAT_CFG, mapping=mp, noise=noise)
            acc = evaluate_cnn(params, model, cfgs, key=key, n_mc=n_mc)
            out[s.name][mp.value] = max(clean - acc, 0.0)
    return {"clean": clean, "layers": out}
