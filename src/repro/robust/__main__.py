"""CLI: vectorized Monte-Carlo robustness studies on the lite CNNs.

    PYTHONPATH=src python -m repro.robust ensemble    --model alexnet --n-chips 64
    PYTHONPATH=src python -m repro.robust sensitivity --model alexnet
    PYTHONPATH=src python -m repro.robust smoke       --steps 40 --n-probe 2
    PYTHONPATH=src python -m repro.robust drift       --retrim-every 900
    PYTHONPATH=src python -m repro.robust sweep       --scales 0 0.5 1 2

``--json PATH`` writes the run as a schema-valid report
(`repro.bench.schema`) gateable with ``repro.bench.compare``.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.bench.schema import BenchResult
from repro.robust import cli


def main(argv: list[str] | None = None) -> int:
    """Parse args, run the chosen study, print/save the report."""
    ap = argparse.ArgumentParser(prog="repro.robust",
                                 description=__doc__.split("\n")[0])
    ap.add_argument("cmd", choices=sorted(cli.RUNNERS),
                    help="which robustness study to run")
    ap.add_argument("--model", default="alexnet")
    ap.add_argument("--steps", type=int, default=150,
                    help="QAT training steps before the study")
    ap.add_argument("--n-chips", type=int, default=None,
                    help="ensemble size (default: per-study)")
    ap.add_argument("--n-eval", type=int, default=None,
                    help="evaluation images (default: per-study)")
    ap.add_argument("--sigma-scale", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--n-probe", type=int, default=4,
                    help="[ensemble] chips given real forwards; the rest "
                         "are predicted by the control-variate surrogate")
    ap.add_argument("--exact", action="store_true",
                    help="[ensemble/sensitivity] brute-force MC: no "
                         "antithetic pairing, every chip evaluated")
    ap.add_argument("--scales", type=float, nargs="+", default=None,
                    help="[sweep] sigma scales")
    ap.add_argument("--retrim-every", type=float, default=900.0,
                    help="[drift] re-trim period [s]; <0 disables")
    ap.add_argument("--drift-kind", default="sine",
                    choices=("sine", "linear", "walk"))
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write a schema-valid robustness report")
    args = ap.parse_args(argv)

    kw: dict = {"steps": args.steps, "seed": args.seed}
    if args.n_chips is not None:
        kw["n_chips"] = args.n_chips
    if args.n_eval is not None:
        kw["n_eval"] = args.n_eval
    if args.cmd in ("ensemble", "sensitivity"):
        kw["sigma_scale"] = args.sigma_scale
        kw["antithetic"] = not args.exact
    if args.cmd == "ensemble":
        kw["n_probe"] = 0 if args.exact else args.n_probe
    if args.cmd == "smoke":
        kw["n_probe"] = args.n_probe
    if args.cmd == "sweep" and args.scales is not None:
        kw["scales"] = tuple(args.scales)
    if args.cmd == "drift":
        kw["kind"] = args.drift_kind
        kw["retrim_every"] = None if args.retrim_every < 0 \
            else args.retrim_every

    summary, metrics = cli.RUNNERS[args.cmd](args.model, **kw)

    print(f"== robust.{args.cmd} [{args.model}] ==")
    for m in metrics:
        val = f"{m.value:.4g}" if isinstance(m.value, float) else m.value
        print(f"  {m.name:28s} {val}{' ' + m.unit if m.unit else ''}"
              f"{'  [gated]' if m.gate else ''}")
    print(json.dumps({k: v for k, v in summary.items()
                      if k not in ("degradation", "rows")},
                     indent=1, default=str))

    if args.json:
        from repro.robust.report import save_report
        path = save_report(
            [BenchResult(name=f"robust_{args.cmd}", metrics=metrics)],
            args.json)
        print(f"wrote {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
