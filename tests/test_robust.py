"""repro.robust — chip ensembles, sensitivity gates, drift, reports."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import rosa
from repro.core import mrr
from repro.core.constants import Mapping
from repro.robust import drift as D
from repro.robust import ensemble as ENS
from repro.robust import sensitivity as S
from repro.robust import variation as V

NOISY_CFG = rosa.RosaConfig(noise=mrr.PAPER_NOISE)
DIMS = {"a": 6, "b": 4}


def _toy_apply(params, x, engine):
    """Two-layer MLP routed through the engine (names 'a', 'b')."""
    h = jax.nn.relu(engine.matmul(x, params["a"], name="a"))
    return engine.matmul(h, params["b"], name="b")


def _toy_params(key):
    k1, k2 = jax.random.split(key)
    return {"a": jax.random.normal(k1, (6, 8)) * 0.4,
            "b": jax.random.normal(k2, (8, 3)) * 0.4}


def _toy_dims():
    return {"a": 6, "b": 8}


# ---------------------------------------------------------------------------
# variation sampling
# ---------------------------------------------------------------------------
def test_sampling_deterministic_and_name_stable(key):
    c1 = V.sample_chip(key, DIMS)
    c2 = V.sample_chip(key, DIMS)
    for n in DIMS:
        for f in ("dv", "ddt", "dlam"):
            np.testing.assert_array_equal(getattr(c1[n], f),
                                          getattr(c2[n], f))
    # dropping a layer must not perturb the other layer's draw
    c3 = V.sample_chip(key, {"a": 6})
    np.testing.assert_array_equal(c1["a"].dv, c3["a"].dv)
    assert c1["a"].dv.shape == (6,)
    assert not np.allclose(np.asarray(c1["a"].dv[:4]),
                           np.asarray(c1["b"].dv))


def test_ensemble_axis_and_chip_at(key):
    ens = V.sample_ensemble(key, 5, DIMS)
    assert V.ensemble_size(ens) == 5
    assert ens["a"].dv.shape == (5, 6)
    chip2 = V.chip_at(ens, 2)
    np.testing.assert_array_equal(chip2["a"].ddt, ens["a"].ddt[2])
    # chips are distinct draws
    assert not np.allclose(np.asarray(ens["a"].dv[0]),
                           np.asarray(ens["a"].dv[1]))


def test_scale_and_thermal_shift(key):
    ens = V.sample_ensemble(key, 3, DIMS)
    z = V.scale_ensemble(ens, 0.0)
    assert float(jnp.abs(z["a"].dv).max()) == 0.0
    sh = V.shift_thermal(ens, 0.5)
    np.testing.assert_allclose(np.asarray(sh["b"].ddt),
                               np.asarray(ens["b"].ddt) + 0.5, rtol=1e-6)
    np.testing.assert_array_equal(sh["b"].dv, ens["b"].dv)


def test_static_variation_perturbs_realization(key):
    w = jnp.linspace(-0.8, 0.8, 16)
    var = V.sample_layer(key, V.PAPER_VARIATION, 16)
    w_var = mrr.realize_weights(w, None, var=var)
    w_zero = mrr.realize_weights(w, None, var=mrr.StaticVariation.zero())
    w_plain = mrr.realize_weights(w)
    np.testing.assert_allclose(np.asarray(w_zero), np.asarray(w_plain),
                               atol=1e-6)
    assert float(jnp.max(jnp.abs(w_var - w_plain))) > 1e-4


# ---------------------------------------------------------------------------
# engine hooks: pinning, gates, mapping gates
# ---------------------------------------------------------------------------
def test_engine_pins_chip_deterministically(key):
    params = _toy_params(key)
    x = jax.random.normal(jax.random.fold_in(key, 9), (5, 6))
    ens = V.sample_ensemble(key, 3, _toy_dims())
    engine = rosa.Engine.from_config(rosa.RosaConfig(), layers=["a", "b"])
    e0 = engine.with_variation(V.chip_at(ens, 0))
    y0a = _toy_apply(params, x, e0)
    y0b = _toy_apply(params, x, e0)           # same chip -> same forward
    np.testing.assert_array_equal(np.asarray(y0a), np.asarray(y0b))
    # decode-step stability: step only folds the per-shot key, and with
    # ideal per-shot noise the pinned chip output is step-invariant
    ya = e0.matmul(x, params["a"], name="a", step=0)
    yb = e0.matmul(x, params["a"], name="a", step=7)
    np.testing.assert_array_equal(np.asarray(ya), np.asarray(yb))
    y1 = _toy_apply(params, x, engine.with_variation(V.chip_at(ens, 1)))
    assert float(jnp.max(jnp.abs(y0a - y1))) > 1e-6


def test_gate_blend_matches_explicit_noisy_plan(key):
    """gate=1 on exactly one layer == an explicit one-layer-noisy plan."""
    params = _toy_params(key)
    x = jax.random.normal(jax.random.fold_in(key, 3), (4, 6))
    base = rosa.RosaConfig()         # ideal
    noisy = NOISY_CFG
    names = ["a", "b"]
    gated_engine = rosa.Engine(
        rosa.ExecutionPlan.build(noisy, None, names),
        key=key).with_gates({"a": jnp.float32(1.0), "b": jnp.float32(0.0)})
    explicit_engine = rosa.Engine(
        rosa.ExecutionPlan.build(base, {"a": noisy}, names), key=key)
    y_gate = _toy_apply(params, x, gated_engine)
    y_explicit = _toy_apply(params, x, explicit_engine)
    np.testing.assert_allclose(np.asarray(y_gate), np.asarray(y_explicit),
                               atol=1e-5)


def test_mapping_gate_matches_static_mapping(key):
    """mgate in {0,1} reproduces the static WS / IS configs exactly
    (deterministic case: ideal per-shot noise + pinned variation)."""
    params = _toy_params(key)
    x = jax.random.normal(jax.random.fold_in(key, 4), (4, 6))
    chip = V.sample_chip(key, _toy_dims())
    names = ["a", "b"]
    for g, mapping in ((0.0, Mapping.WS), (1.0, Mapping.IS)):
        cfg = rosa.RosaConfig(mapping=Mapping.WS)
        e_gate = rosa.Engine(rosa.ExecutionPlan.build(cfg, None, names)) \
            .with_variation(chip) \
            .with_mapping_gates({n: jnp.float32(g) for n in names})
        e_static = rosa.Engine(rosa.ExecutionPlan.build(
            dataclasses.replace(cfg, mapping=mapping), None, names)) \
            .with_variation(chip)
        np.testing.assert_allclose(
            np.asarray(_toy_apply(params, x, e_gate)),
            np.asarray(_toy_apply(params, x, e_static)), atol=1e-5)


# ---------------------------------------------------------------------------
# ensemble evaluation: ONE jitted vmapped call
# ---------------------------------------------------------------------------
def test_ensemble_eval_toy_one_trace(key):
    params = _toy_params(key)
    x = jax.random.normal(jax.random.fold_in(key, 5), (32, 6))
    y = jax.random.randint(jax.random.fold_in(key, 6), (32,), 0, 3)
    ens = V.sample_ensemble(key, 10, _toy_dims())
    engine = rosa.Engine.from_config(NOISY_CFG, layers=["a", "b"])
    traces = []

    def counted(params, xc, e):
        traces.append(1)
        return _toy_apply(params, xc, e)

    res = ENS.evaluate_ensemble(counted, params, x, y, engine, ens, key,
                                eval_batch=16)
    # one clean trace + ONE vmapped chip trace — not one per chip
    assert len(traces) == 2
    assert res.accs.shape == (10,)
    assert 0.0 <= res.yield_frac(2.0) <= 1.0
    assert res.summary()["n_chips"] == 10


def test_ensemble_eval_label_free_agreement(key):
    params = _toy_params(key)
    x = jax.random.normal(jax.random.fold_in(key, 7), (24, 6))
    ens = V.sample_ensemble(key, 4, _toy_dims())
    engine = rosa.Engine.from_config(NOISY_CFG, layers=["a", "b"])
    res = ENS.evaluate_ensemble(_toy_apply, params, x, None, engine, ens,
                                key, eval_batch=12)
    # label-free: accuracy IS agreement with the clean model
    np.testing.assert_allclose(res.accs, 100.0 * res.agreement, atol=1e-5)
    assert res.clean_acc == pytest.approx(100.0)


def test_paper_cnn_64_chips_one_vmapped_call(key):
    """Acceptance: the paper CNN over >= 64 variation instances in ONE
    jitted vmapped call (untrained params — the mechanism is the test)."""
    from repro.models.cnn import LITE_MODELS, cnn_def
    from repro.models.module import init_params

    model = "alexnet"
    params = init_params(cnn_def(LITE_MODELS[model]), key)
    names = [s.name for s in LITE_MODELS[model]]
    ens = V.sample_ensemble(key, 64, V.cnn_lane_dims(model))
    engine = rosa.Engine.from_config(NOISY_CFG, layers=names)
    x, y = ENS.cnn_eval_set(64)
    traces = []
    base_fn = ENS.cnn_apply_fn(model)

    def counted(params, xc, e):
        traces.append(1)
        return base_fn(params, xc, e)

    res = ENS.evaluate_ensemble(counted, params, x, y, engine, ens, key,
                                eval_batch=32)
    assert len(traces) == 2            # clean + one vmapped 64-chip trace
    assert res.accs.shape == (64,)
    assert np.all(np.isfinite(res.accs))


# ---------------------------------------------------------------------------
# sensitivity: degradation matrix + verified plan search
# ---------------------------------------------------------------------------
def test_degradation_matrix_toy(key):
    params = _toy_params(key)
    x = jax.random.normal(jax.random.fold_in(key, 8), (32, 6))
    y = jax.random.randint(jax.random.fold_in(key, 9), (32,), 0, 3)
    ens = V.sample_ensemble(key, 4, _toy_dims())
    deg = S.degradation_matrix(_toy_apply, params, x, y, ["a", "b"],
                               rosa.RosaConfig(), ens, key,
                               eval_batch=16)
    assert set(deg) == {"a", "b"}
    for n in deg:
        assert set(deg[n]) == {Mapping.IS.value, Mapping.WS.value}
        for v in deg[n].values():
            assert v >= 0.0 and np.isfinite(v)


def test_plan_search_row0_is_pure_ws(key):
    params = _toy_params(key)
    x = jax.random.normal(jax.random.fold_in(key, 11), (32, 6))
    y = jax.random.randint(jax.random.fold_in(key, 12), (32,), 0, 3)
    ens = V.sample_ensemble(key, 4, _toy_dims())
    cand = np.array([[0, 0], [1, 0], [1, 1]], dtype=np.float32)
    accs = S.plan_search(_toy_apply, params, x, y, ["a", "b"],
                         rosa.RosaConfig(), ens, key, cand, eval_batch=16)
    assert accs.shape == (3,)
    assert np.all(np.isfinite(accs))


def test_searched_plan_matches_or_beats_ws(key):
    """The verified search always returns a plan whose in-search accuracy
    >= the pure-WS row (WS is candidate row 0 by construction)."""
    params = _toy_params(key)
    x = jax.random.normal(jax.random.fold_in(key, 13), (48, 6))
    y = jax.random.randint(jax.random.fold_in(key, 14), (48,), 0, 3)
    ens = V.sample_ensemble(key, 4, _toy_dims())
    from repro.core.mapping import LayerProfile
    # layer 'a': IS attractive (robust + cheaper); 'b': clearly WS
    profiles = [LayerProfile("a", d_is=0.0, d_ws=0.5, e_is=1e-6, e_ws=1e-4),
                LayerProfile("b", d_is=9.0, d_ws=0.1, e_is=1e-4, e_ws=1e-6)]
    plan, info = S.searched_hybrid_plan(profiles, _toy_apply, params, x, y,
                                        rosa.RosaConfig(), ens, key,
                                        eval_batch=16)
    assert info["chosen_acc"] >= info["ws_acc"]
    # 'b' is ineligible (d_is >> d_ws + margin) so it can never flip
    assert plan.get("b") is not Mapping.IS
    assert set(info) >= {"order", "accs", "n_is"}


def test_accuracy_guarded_plan_vetoes_costly_is():
    from repro.core.mapping import LayerProfile, choose_mapping
    # EDP ratio so extreme the paper metric picks IS despite 12 pp cost
    lured = LayerProfile("lured", d_is=12.0, d_ws=0.2, e_is=1e-8, e_ws=1e-2)
    assert choose_mapping(lured) is Mapping.IS          # the raw metric bites
    safe = LayerProfile("safe", d_is=0.1, d_ws=0.3, e_is=1e-6, e_ws=1e-5)
    plan = S.accuracy_guarded_plan([lured, safe], max_extra_pp=0.5)
    assert plan["lured"] is Mapping.WS                  # vetoed
    assert plan["safe"] is Mapping.IS                   # kept (more robust)


def test_profile_layers_mc_joins_edp(key):
    from repro.core import energy as E
    from repro.core.constants import ROSA_OPTIMAL
    layers = [E.LayerShape("a", m=64, k=6, n=8),
              E.LayerShape("b", m=64, k=8, n=3)]
    deg = {"a": {Mapping.IS.value: 1.0, Mapping.WS.value: 0.2},
           "b": {Mapping.IS.value: 0.0, Mapping.WS.value: 0.3}}
    profs = S.profile_layers_mc(layers, ROSA_OPTIMAL, deg, batch=4)
    assert [p.name for p in profs] == ["a", "b"]
    assert profs[0].d_is == 1.0 and profs[1].d_ws == 0.3
    assert profs[0].e_is > 0.0 and profs[0].e_ws > 0.0


# ---------------------------------------------------------------------------
# drift + re-trim
# ---------------------------------------------------------------------------
def test_drift_schedules():
    t = np.linspace(0.0, 3600.0, 13)
    sine = D.DriftModel(kind="sine", amp_k=0.4).offsets(t)
    assert abs(float(sine[0])) < 1e-9 and np.max(np.abs(sine)) <= 0.4 + 1e-9
    lin = D.DriftModel(kind="linear", amp_k=0.4).offsets(t)
    np.testing.assert_allclose(lin[-1], 0.4, rtol=1e-6)
    walk = D.DriftModel(kind="walk", amp_k=0.4).offsets(
        t, jax.random.PRNGKey(0))
    assert walk[0] == 0.0 and np.all(np.isfinite(walk))
    with pytest.raises(ValueError):
        D.DriftModel(kind="walk").offsets(t)          # needs a key
    with pytest.raises(ValueError):
        D.DriftModel(kind="nope").offsets(t)


def test_residual_offsets_retrim():
    t = np.array([0.0, 400.0, 900.0, 1300.0, 1800.0])
    offs = D.DriftModel(kind="linear", amp_k=1.0, period_s=1800.0).offsets(t)
    resid = D.residual_offsets(offs, t, retrim_every=900.0)
    # trim instants are exactly compensated; between trims the residual is
    # drift since the last trim
    np.testing.assert_allclose(resid[[0, 2, 4]], 0.0, atol=1e-12)
    np.testing.assert_allclose(resid[1], offs[1], atol=1e-12)
    np.testing.assert_allclose(resid[3], offs[3] - offs[2], atol=1e-12)
    # no retrim: one calibration at t=0 only
    np.testing.assert_allclose(D.residual_offsets(offs, t, None),
                               offs - offs[0], atol=1e-12)
    # a trim falling BETWEEN grid samples still takes effect (interpolated
    # trim-time offset, not snapped back to the previous sample)
    t2 = np.array([0.0, 1000.0])
    offs2 = D.DriftModel(kind="linear", amp_k=1.0,
                         period_s=1000.0).offsets(t2)
    resid2 = D.residual_offsets(offs2, t2, retrim_every=900.0)
    np.testing.assert_allclose(resid2[1], 0.1, atol=1e-12)  # d(1000)-d(900)


def test_trim_voltages_compensate_known_offset():
    """Re-invoked calibration nulls a known thermal bias (away from the
    V_min saturation region); uncompensated programming does not."""
    w = jnp.linspace(-0.9, 0.5, 29)
    ddt = jnp.float32(0.3)
    bias = mrr.StaticVariation(jnp.zeros(()), ddt, jnp.zeros(()))
    w_trim = mrr.weight_of_voltage(D.trim_voltages(w, ddt), var=bias)
    err_trim = float(jnp.max(jnp.abs(w_trim - w)))
    v_raw = jnp.clip(mrr.voltage_of_weight(w), 1.0, 3.0)
    err_raw = float(jnp.max(jnp.abs(mrr.weight_of_voltage(v_raw, var=bias)
                                    - w)))
    assert err_trim < 1e-3
    assert err_trim < err_raw / 10.0


def test_drift_simulation_toy(key):
    params = _toy_params(key)
    x = jax.random.normal(jax.random.fold_in(key, 15), (24, 6))
    y = jax.random.randint(jax.random.fold_in(key, 16), (24,), 0, 3)
    ens = V.sample_ensemble(key, 3, _toy_dims())
    engine = rosa.Engine.from_config(NOISY_CFG, layers=["a", "b"])
    t = np.linspace(0.0, 1800.0, 3)
    dm = D.DriftModel(kind="linear", amp_k=1.0, period_s=1800.0)
    res = D.simulate(_toy_apply, params, x, y, engine, ens, key, dm, t,
                     retrim_every=900.0, eval_batch=12)
    assert res.mean_acc.shape == (3,) and np.all(np.isfinite(res.mean_acc))
    assert set(res.summary()) >= {"worst_mean_acc", "min_yield_2pp"}
    # residual at every sampled instant is a trim instant here -> zero
    np.testing.assert_allclose(res.residual_k, 0.0, atol=1e-12)


# ---------------------------------------------------------------------------
# ensemble-axis QAT + reports
# ---------------------------------------------------------------------------
def test_train_cnn_over_ensemble_axis(key):
    from repro.training.cnn_train import train_cnn
    ens = V.sample_ensemble(key, 2, V.cnn_lane_dims("alexnet"))
    params, acc = train_cnn("alexnet", steps=2, batch=8, n_train=64,
                            ensemble=ens)
    assert np.isfinite(acc)
    assert all(np.all(np.isfinite(np.asarray(l)))
               for l in jax.tree.leaves(params))


def test_report_schema_roundtrip(tmp_path):
    from repro.bench.schema import BenchResult, load
    from repro.robust import report as R
    res = ENS.EnsembleResult(accs=np.array([70.0, 68.0, 40.0]),
                             agreement=np.array([0.9, 0.8, 0.4]),
                             clean_acc=71.0)
    metrics = R.ensemble_metrics(res, gate=True) \
        + R.yield_curve_metrics(res, drops_pp=(1.0, 5.0))
    names = [m.name for m in metrics]
    assert len(names) == len(set(names))          # schema rejects dupes
    path = R.save_report([BenchResult(name="robust_test", metrics=metrics)],
                         tmp_path / "ROBUST.json", seq=3)
    rep = load(path)
    assert rep.result("robust_test").metric("yield_2pp").value \
        == pytest.approx(1.0 / 3.0)
    assert rep.result("robust_test").metric("mean_acc").direction \
        == "higher_is_better"


# ---------------------------------------------------------------------------
# variance-reduced estimation: antithetic pairs + control-variate surrogate
# ---------------------------------------------------------------------------
def test_antithetic_sampling_mirrors_pairs(key):
    ens = V.sample_ensemble(key, 6, _toy_dims(), antithetic=True)
    for n in _toy_dims():
        for f in ("dv", "ddt", "dlam"):
            a = np.asarray(getattr(ens[n], f))
            np.testing.assert_array_equal(a[1::2], -a[0::2])
    # pairs are distinct draws, and the mean of each pair is exactly zero
    assert not np.allclose(np.asarray(ens["a"].dv[0]),
                           np.asarray(ens["a"].dv[2]))
    with pytest.raises(ValueError):
        V.sample_ensemble(key, 5, _toy_dims(), antithetic=True)


def test_chip_slice_prefix(key):
    ens = V.sample_ensemble(key, 8, _toy_dims(), antithetic=True)
    sl = V.chip_slice(ens, 2)
    assert V.ensemble_size(sl) == 2
    np.testing.assert_array_equal(np.asarray(sl["a"].dv),
                                  np.asarray(ens["a"].dv[:2]))


def test_control_variate_accs_recovers_linear_relation():
    feats = np.array([0.1, 0.2, 0.3, 0.4, 0.5, 0.6])
    true = 90.0 - 20.0 * feats
    pred = ENS.control_variate_accs(true[:3], feats, 3)
    # an exactly linear probe relation extrapolates exactly
    np.testing.assert_allclose(pred, true, atol=1e-8)
    # degenerate (zero-variance) feature falls back to the probe mean
    flat = ENS.control_variate_accs(np.array([60.0, 70.0]),
                                    np.zeros(4), 2)
    np.testing.assert_allclose(flat[2:], 65.0)
    np.testing.assert_allclose(flat[:2], [60.0, 70.0])


def test_estimator_probe_prefix_is_measured(key):
    """Probe chips keep their real measured accuracies bit-for-bit."""
    params = _toy_params(key)
    x = jax.random.normal(jax.random.fold_in(key, 21), (32, 6))
    y = jax.random.randint(jax.random.fold_in(key, 22), (32,), 0, 3)
    ens = V.sample_ensemble(key, 8, _toy_dims(), antithetic=True)
    engine = rosa.Engine.from_config(NOISY_CFG, layers=["a", "b"])
    full = ENS.evaluate_ensemble(_toy_apply, params, x, y, engine, ens,
                                 key, eval_batch=16)
    est = ENS.estimate_ensemble(
        _toy_apply, params, x, y, engine, ens, key,
        estimator=ENS.EstimatorConfig(n_probe=4), eval_batch=16)
    assert est.method == "control-variate" and est.n_probe == 4
    np.testing.assert_array_equal(est.accs[:4], full.accs[:4])


def test_estimator_within_tolerance_of_brute_force(key):
    """Acceptance: ~4 evaluated chips predict the 16-chip wafer mean
    within a pinned tolerance of the brute-force estimate."""
    params = _toy_params(key)
    x = jax.random.normal(jax.random.fold_in(key, 23), (48, 6))
    y = jax.random.randint(jax.random.fold_in(key, 24), (48,), 0, 3)
    ens = V.sample_ensemble(key, 16, _toy_dims(), antithetic=True)
    engine = rosa.Engine.from_config(NOISY_CFG, layers=["a", "b"])
    brute = ENS.evaluate_ensemble(_toy_apply, params, x, y, engine, ens,
                                  key, eval_batch=16)
    est = ENS.estimate_ensemble(
        _toy_apply, params, x, y, engine, ens, key,
        estimator=ENS.EstimatorConfig(n_probe=4), eval_batch=16)
    assert est.n_chips == brute.n_chips == 16
    assert abs(est.mean_acc - brute.mean_acc) <= 5.0
    assert abs(est.yield_frac(2.0) - brute.yield_frac(2.0)) <= 0.5


def test_full_mc_estimator_is_bitexact_fallback(key):
    params = _toy_params(key)
    x = jax.random.normal(jax.random.fold_in(key, 25), (32, 6))
    y = jax.random.randint(jax.random.fold_in(key, 26), (32,), 0, 3)
    ens = V.sample_ensemble(key, 4, _toy_dims())
    engine = rosa.Engine.from_config(NOISY_CFG, layers=["a", "b"])
    exact = ENS.evaluate_ensemble(_toy_apply, params, x, y, engine, ens,
                                  key, eval_batch=16)
    fb = ENS.estimate_ensemble(_toy_apply, params, x, y, engine, ens, key,
                               estimator=ENS.FULL_MC, eval_batch=16)
    np.testing.assert_array_equal(fb.accs, exact.accs)
    assert fb.method == "mc" and fb.n_probe == 0


def test_surrogate_features_no_forwards(key):
    """The surrogate costs zero eval-set forwards and reacts to variation
    strength monotonically enough to regress on."""
    params = _toy_params(key)
    ens = V.sample_ensemble(key, 4, _toy_dims())
    engine = rosa.Engine.from_config(NOISY_CFG, layers=["a", "b"])
    f1 = ENS.surrogate_features(ENS.layer_weights(params, ["a", "b"]),
                                ens, engine)
    assert f1.shape == (4,) and np.all(np.isfinite(f1)) and np.all(f1 >= 0)
    f2 = ENS.surrogate_features(ENS.layer_weights(params, ["a", "b"]),
                                V.scale_ensemble(ens, 3.0), engine)
    assert f2.mean() > f1.mean()


# ---------------------------------------------------------------------------
# incremental degradation re-score + shared-compile evaluator
# ---------------------------------------------------------------------------
def test_incremental_matrix_equals_full(key):
    """refresh over changed layers == full matrix, bit-for-bit (row
    independence of the one-hot protocol)."""
    params = _toy_params(key)
    x = jax.random.normal(jax.random.fold_in(key, 27), (32, 6))
    y = jax.random.randint(jax.random.fold_in(key, 28), (32,), 0, 3)
    ens = V.sample_ensemble(key, 2, _toy_dims(), antithetic=True)
    full = S.degradation_matrix(_toy_apply, params, x, y, ["a", "b"],
                                rosa.RosaConfig(), ens, key, eval_batch=16)
    only_a = S.degradation_matrix(_toy_apply, params, x, y, ["a", "b"],
                                  rosa.RosaConfig(), ens, key,
                                  eval_batch=16, layers=["a"])
    assert set(only_a) == {"a"}
    merged = S.refresh_degradation_matrix(
        only_a, ["b"], _toy_apply, params, x, y, ["a", "b"],
        rosa.RosaConfig(), ens, key, eval_batch=16)
    assert merged == full


def test_degradation_matrix_shared_evaluator(key):
    """A pre-built gated evaluator reproduces the built-in path exactly
    and is traced exactly once for the whole (mappings x layers) grid."""
    params = _toy_params(key)
    x = jax.random.normal(jax.random.fold_in(key, 29), (32, 6))
    y = jax.random.randint(jax.random.fold_in(key, 30), (32,), 0, 3)
    ens = V.sample_ensemble(key, 2, _toy_dims())
    cfg = dataclasses.replace(rosa.RosaConfig(), mapping=Mapping.WS,
                              noise=mrr.PAPER_NOISE)
    engine = rosa.Engine(rosa.ExecutionPlan.build(cfg, None, ["a", "b"]))
    traces = []

    def counted(params, xc, e):
        traces.append(1)
        return _toy_apply(params, xc, e)

    ev = ENS.make_plan_eval(counted, engine, ["a", "b"], eval_batch=16,
                            gated=True)
    deg = S.degradation_matrix(counted, params, x, y, ["a", "b"],
                               rosa.RosaConfig(), ens, key, eval_batch=16,
                               evaluator=ev)
    # clean trace + one vmapped chip trace — 8 grid cells, ONE compile
    assert len(traces) == 2
    ref = S.degradation_matrix(_toy_apply, params, x, y, ["a", "b"],
                               rosa.RosaConfig(), ens, key, eval_batch=16)
    assert deg == ref
    # the same executable also serves full-plan evaluation (g all-ones)
    keys = jax.random.split(key, 2)
    accs, agree, clean = ev(params, x, y, ens, keys,
                            jnp.zeros(2), jnp.ones(2))
    assert np.asarray(accs).shape == (2,)
    assert len(traces) == 2                       # still no retrace
