"""`AnalysisTarget` — one unit of code the static checks inspect.

A target bundles a callable with the abstract arguments to trace it on,
plus the *declared* intent the checks verify against reality:

  donate_argnums — positions the author claims are donated (the donation
                   check compares them with the compiled HLO's
                   input_output_alias map);
  hot_path       — this function runs per serving tick / per token, so
                   callbacks and undonated state are findings, not style;
  gemm_shapes    — (name, m, k, n) workload shapes for the Pallas
                   preflight (a target may carry only shapes, no fn).

Tracing is lazy and cached: `jaxpr()` costs one abstract trace,
`compiled_text()` one XLA compile — only the checks that need them pay.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import numpy as np

# np dtype name -> HLO element type text, for comparing pytree leaves
# against shapes parsed out of HLO.
_HLO_DTYPE = {
    "bool": "pred", "int4": "s4", "uint4": "u4",
    "int8": "s8", "uint8": "u8", "int16": "s16", "uint16": "u16",
    "int32": "s32", "uint32": "u32", "int64": "s64", "uint64": "u64",
    "float16": "f16", "bfloat16": "bf16", "float32": "f32",
    "float64": "f64", "complex64": "c64", "complex128": "c128",
    "float8_e4m3fn": "f8e4m3fn", "float8_e5m2": "f8e5m2",
    "float8_e8m0fnu": "f8e8m0fnu",
}


def hlo_shape_of(leaf) -> str:
    """'f32[4,8]'-style text for an array / ShapeDtypeStruct leaf."""
    dt = np.dtype(leaf.dtype).name
    dims = ",".join(str(d) for d in leaf.shape)
    return f"{_HLO_DTYPE.get(dt, dt)}[{dims}]"


@dataclasses.dataclass
class AnalysisTarget:
    name: str
    fn: Callable | None = None
    example_args: tuple = ()
    donate_argnums: tuple[int, ...] = ()
    static_argnums: tuple[int, ...] = ()
    hot_path: bool = False
    gemm_shapes: tuple[tuple[str, int, int, int], ...] = ()
    # (name, B, L, H, P, S) workloads for the ssd_scan preflight
    ssd_shapes: tuple[tuple[str, int, int, int, int, int], ...] = ()

    _jaxpr: Any = dataclasses.field(default=None, repr=False)
    _compiled: str | None = dataclasses.field(default=None, repr=False)

    def jaxpr(self):
        """The closed jaxpr of fn(*example_args) (cached; abstract — no
        FLOPs run)."""
        if self._jaxpr is None:
            if self.fn is None:
                raise ValueError(f"target {self.name!r} has no callable")
            self._jaxpr = jax.make_jaxpr(
                self.fn, static_argnums=self.static_argnums)(
                    *self.example_args)
        return self._jaxpr

    def try_jaxpr(self):
        """`jaxpr()`, or None when the target cannot trace at all (e.g.
        an unhashable static arg — the recompile check owns reporting
        that; the other jaxpr checks silently skip)."""
        try:
            return self.jaxpr()
        except (TypeError, ValueError):
            return None

    def compiled_text(self) -> str:
        """Optimized HLO of the jitted fn with the declared donations
        (cached; one real XLA compile).  Pre-jitted fns lower directly —
        their own donate/static settings are what gets compiled."""
        if self._compiled is None:
            if self.fn is None:
                raise ValueError(f"target {self.name!r} has no callable")
            fn = self.fn
            if not hasattr(fn, "lower"):
                fn = jax.jit(fn, donate_argnums=self.donate_argnums,
                             static_argnums=self.static_argnums)
            self._compiled = fn.lower(
                *self.example_args).compile().as_text()
        return self._compiled

    def donated_leaf_shapes(self) -> list[str]:
        """HLO shape text of every array leaf under the declared donated
        argument positions — the buffers that MUST come back aliased."""
        leaves: list[str] = []
        for i in self.donate_argnums:
            if i >= len(self.example_args):
                continue
            for leaf in jax.tree_util.tree_leaves(self.example_args[i]):
                if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
                    leaves.append(hlo_shape_of(leaf))
        return leaves


def consts_of(closed) -> list[tuple[Any, Any]]:
    """(constvar, const_value) pairs of a ClosedJaxpr."""
    return list(zip(closed.jaxpr.constvars, closed.consts))


def program_target(program, example_args: Sequence[Any], *,
                   name: str = "program") -> AnalysisTarget:
    """Build the verification target for a `rosa.Program`.

    The program's jitted entry is `run(key, variation, *args)`; an abstract
    uint32[2] key (never a baked constant) exercises the noisy-realization
    path, and the declared donations are the program's `donate_argnums`
    shifted past the two prepended slots — exactly what `Program.__init__`
    hands `jax.jit`."""
    key_spec = jax.ShapeDtypeStruct((2,), np.uint32)
    return AnalysisTarget(
        name=name,
        fn=program._call,
        example_args=(key_spec, None, *tuple(example_args)),
        donate_argnums=tuple(i + 2 for i in program._donate))
