"""HLO analyzer: trip-count-aware flops/bytes/collectives."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import analyze


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_flops_match_cost_analysis_no_loops(key):
    def f(x, w):
        return jnp.sum(jnp.tanh(x @ w) @ w.T)
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    comp = _compile(f, x, w)
    rep = analyze(comp.as_text())
    ca = comp.cost_analysis()
    if isinstance(ca, list):      # pre-0.5 jax returns [per-device dict]
        ca = ca[0]
    assert rep.flops == pytest.approx(ca["flops"], rel=0.05)


def test_scan_trip_count_multiplies():
    def model(params, x, n):
        def body(c, p):
            return jnp.tanh(c @ p), None
        y, _ = jax.lax.scan(body, x, params)
        return jnp.sum(y)

    flops = {}
    for n in (2, 8):
        p = jax.ShapeDtypeStruct((n, 64, 64), jnp.float32)
        x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
        comp = jax.jit(model, static_argnums=2).lower(p, x, n).compile()
        rep = analyze(comp.as_text())
        flops[n] = rep.flops
        assert n in rep.loop_counts.values()
    assert flops[8] == pytest.approx(4 * flops[2], rel=0.2)


def test_collectives_detected_in_psum():
    mesh = jax.make_mesh((1,), ("d",))
    from jax.sharding import PartitionSpec as P

    def f(x):
        return jax.lax.psum(x * 2.0, "d")

    from repro.distributed.sharding import shard_map_compat
    g = jax.jit(shard_map_compat(f, mesh=mesh, in_specs=P("d"),
                                 out_specs=P()))
    comp = g.lower(jax.ShapeDtypeStruct((16,), jnp.float32)).compile()
    rep = analyze(comp.as_text())
    # single-device psum may be optimised away; just assert no crash and
    # non-negative accounting
    assert rep.flops >= 0 and rep.bytes > 0


def test_bytes_positive_and_scaled(key):
    def f(x):
        return jnp.sum(x * 2.0 + 1.0)
    x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    rep = analyze(_compile(f, x).as_text())
    # at least one full read of x
    assert rep.bytes >= 4 * 1024 * 1024
