"""Fig. 8 reproduction: EDP reduction from optical shift-and-add.

Three bars per workload on the optimized (8,8) array, mixed mode:
  baseline      — no OSA: the ADC fires once per bit slot,
  + OSA         — default (unoptimized) ODE chain length,
  + ODE sizing  — chain sized to the full slot count (1 conversion/output).
Paper claims: OSA -29% EDP, OSA+ODE sizing -37% vs the no-OSA baseline.
"""

from __future__ import annotations

from repro.configs.paper_cnns import WORKLOADS
from repro.core import energy as E
from repro.core.constants import ROSA_OPTIMAL

# batched inference (paper Sec. 4 operating point): amortizes the 5 us
# thermo-optic weight programming across the batch's input streams
BATCH = 128


def run(verbose: bool = True) -> dict:
    out = {}
    geo = {"no_osa": 1.0, "osa": 1.0, "osa_ode": 1.0}
    names = list(WORKLOADS)
    for name in names:
        layers = WORKLOADS[name]
        base = E.network_energy(layers, ROSA_OPTIMAL, osa=E.NO_OSA,
                                batch=BATCH).edp
        osa = E.network_energy(layers, ROSA_OPTIMAL, osa=E.OSA_DEFAULT,
                               batch=BATCH).edp
        opt = E.network_energy(layers, ROSA_OPTIMAL, osa=E.OSA_OPTIMAL,
                               batch=BATCH).edp
        out[name] = dict(no_osa=base, osa=osa, osa_ode=opt,
                         red_osa=1 - osa / base, red_ode=1 - opt / base)
        geo["osa"] *= (osa / base) ** (1 / len(names))
        geo["osa_ode"] *= (opt / base) ** (1 / len(names))
    if verbose:
        print(f"{'workload':14s} {'EDP no-OSA':>12s} {'+OSA':>12s} "
              f"{'+ODE sizing':>12s} {'dOSA':>7s} {'dODE':>7s}")
        for n, r in out.items():
            print(f"{n:14s} {r['no_osa']:12.4e} {r['osa']:12.4e} "
                  f"{r['osa_ode']:12.4e} {r['red_osa'] * 100:6.1f}% "
                  f"{r['red_ode'] * 100:6.1f}%")
        print(f"\ngeomean EDP reduction: OSA {100 * (1 - geo['osa']):.1f}% "
              f"(paper: 29%), OSA+ODE {100 * (1 - geo['osa_ode']):.1f}% "
              f"(paper: 37%)")
    out["geomean_reduction_osa"] = 1 - geo["osa"]
    out["geomean_reduction_osa_ode"] = 1 - geo["osa_ode"]
    return out


if __name__ == "__main__":
    run()
