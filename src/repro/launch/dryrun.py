import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"  # noqa: E402  (MUST precede any jax import)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the production mesh (16,16)=256 chips or (2,16,16)=512 chips,
  2. resolves parameter / optimizer / batch / cache shardings from the
     logical rules (train vs serve),
  3. jits the right step (train_step / prefill / decode_step),
     .lower()s it with ShapeDtypeStruct inputs (no allocation), .compile()s,
  4. records memory_analysis(), cost_analysis() and the trip-count-aware
     HLO analysis (launch/hlo_analysis.py) to a JSON file per cell.

Failures here (sharding mismatch, OOM at compile, unsupported collective)
are bugs in the system — the roofline reporter refuses to run on a cell
without a green dry-run record.

Usage:
  python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k --mesh multi
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs import ARCH_IDS, get_config  # noqa: E402
from repro.distributed.sharding import (SERVE_RULES, TRAIN_RULES,  # noqa: E402
                                        ZERO3_TRAIN_RULES, param_shardings,
                                        tree_shardings, use_sharding)
from repro.launch import hlo_analysis  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import (init_opt_state, make_train_step,  # noqa: E402
                                opt_state_shardings)
from repro.models.model import (ASSIGNED_SHAPES, applicable,  # noqa: E402
                                build_model)
from repro.optim import AdamWConfig  # noqa: E402


def _mem_dict(ma) -> dict:
    if ma is None:
        return {}
    fields = ("generated_code_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "alias_size_in_bytes",
              "temp_size_in_bytes")
    return {f: getattr(ma, f, None) for f in fields}


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             backend: str = "dense", overrides: dict | None = None,
             save_hlo: str | None = None) -> dict:
    """Lower+compile one cell; returns the JSON-able record."""
    cfg = get_config(arch)
    compress = False
    if overrides:
        overrides = dict(overrides)
        compress = overrides.pop("grad_compress", False)
        cap = overrides.pop("capacity_factor", None)
        cfg = dataclasses.replace(cfg, **overrides)
        if cap is not None and cfg.moe is not None:
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=cap))
    shape = ASSIGNED_SHAPES[shape_name]
    ok, why = applicable(cfg, shape)
    rec = {"arch": cfg.name, "shape": shape_name, "mesh": mesh_kind,
           "backend": backend, "status": "skip", "reason": why}
    if not ok:
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    if shape.kind != "train":
        rules = SERVE_RULES
    elif cfg.parallelism == "zero3":
        rules = ZERO3_TRAIN_RULES
    else:
        rules = TRAIN_RULES
    bundle = build_model(cfg)
    t0 = time.time()

    with use_sharding(mesh, rules):
        params_abs = bundle.abstract()
        p_sh = param_shardings(bundle.skeleton, mesh, rules)
        batch_abs, batch_axes = bundle.input_specs(shape)
        b_sh = tree_shardings(batch_abs, batch_axes, mesh, rules)

        if shape.kind == "train":
            opt_abs = jax.eval_shape(
                lambda p: init_opt_state(p, compress), params_abs)
            o_sh = opt_state_shardings(p_sh, compress)
            step = make_train_step(bundle, AdamWConfig(),
                                   grad_compress=compress)
            jitted = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_abs, opt_abs, batch_abs)
        elif shape.kind == "prefill":
            jitted = jax.jit(bundle.prefill, in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(params_abs, batch_abs)
        else:
            jitted = jax.jit(bundle.decode_step, in_shardings=(p_sh, b_sh),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_abs, batch_abs)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    rep = hlo_analysis.analyze(hlo)
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)

    rec.update(
        status="ok",
        n_devices=mesh.devices.size,
        n_params=bundle.n_params,
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        memory=_mem_dict(ma),
        xla_cost={"flops_single_visit": ca.get("flops"),
                  "bytes_single_visit": ca.get("bytes accessed")},
        hlo=rep.as_dict(),
    )
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(ASSIGNED_SHAPES) + [None])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--backend", default="dense")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--override", default=None,
                    help="JSON dict of ModelConfig field overrides")
    ap.add_argument("--save-hlo", default=None)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = list(ARCH_IDS) if args.all or not args.arch else [args.arch]
    shapes = list(ASSIGNED_SHAPES) if args.all or not args.shape \
        else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    overrides = json.loads(args.override) if args.override else None

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                tag = f"{arch}__{shape}__{mesh_kind}"
                if overrides:
                    tag += "__opt"
                try:
                    rec = run_cell(arch, shape, mesh_kind, args.backend,
                                   overrides, save_hlo=args.save_hlo)
                except Exception as e:  # noqa: BLE001 — record and continue
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                           "status": "fail", "error": f"{type(e).__name__}: {e}"}
                    n_fail += 1
                path = os.path.join(args.out, tag + ".json")
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                mem = rec.get("memory", {}).get("argument_size_in_bytes")
                print(f"[{rec['status']:4s}] {tag} "
                      f"args/dev={mem if mem else '-'} "
                      f"flops/dev={rec.get('hlo', {}).get('flops', '-'):} "
                      f"({rec.get('reason', '')})", flush=True)
    if n_fail:
        raise SystemExit(f"{n_fail} cells failed")


if __name__ == "__main__":
    main()
