"""Pallas TPU kernel: noisy MRR voltage->weight realization (Eqs. 3-8).

Elementwise physical chain, fused into one VPU pass over VMEM blocks:

    w_target --inverse--> V --(+sigma_dac*eps)--> dT --(+sigma_th*eps)-->
    d_lambda --> Lorentzian T_drop --> T_diff --> realized w

Noise draws arrive as operands (generated with jax.random outside) so the
kernel is deterministic and bit-comparable with ref.py on CPU.  On real TPU
hardware the draws can instead be generated in-kernel with
pltpu.prng_seed/prng_random_bits to save the two HBM streams; that variant
is gated behind `use_tpu_prng` (not available in CPU interpret mode, which
is why correctness validation uses the operand path).

The weight tensor is processed in (block_rows, 128)-aligned VMEM tiles; the
chain is ~20 transcendental-free VPU ops per element (sqrt, divisions), so
the kernel is memory-bound and the tiling exists purely to stream HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels import tpu_compiler_params

from repro.core import mrr


def _chain(wt, e_dac, e_th, sigma_dac, sigma_th, p: mrr.MRRParams,
           t_hi: float, t_lo: float):
    """The full forward+inverse chain on VMEM-resident values."""
    # ---- inverse: target weight -> programming voltage ----
    wq = jnp.clip(wt, p.q_min, p.q_max)
    td = t_lo + (wq - p.q_min) / p.q_rng * (t_hi - t_lo)
    tdrop = 0.5 * (td + 1.0)
    det = p.gamma * jnp.sqrt(jnp.maximum(1.0 / tdrop - 1.0, 0.0))
    lam = p.lambda_ref + det
    dl = lam - p.lambda_0
    u = dl / p.lambda_0
    dt = p.n_eff * u / (p.beta * (1.0 - u))
    p_mw = dt / p.r_thermal
    v2 = p_mw / (p.kappa * 1e3) * p.r_heater
    v = jnp.sqrt(jnp.maximum(v2, 0.0))
    v = jnp.clip(v, p.v_min, p.v_max)
    # ---- forward with noise: V' -> dT' -> d_lambda -> T_diff -> w ----
    v = v + sigma_dac * e_dac
    dtn = (p.kappa * (v * v / p.r_heater) * 1e3) * p.r_thermal + sigma_th * e_th
    bdt = p.beta * dtn
    lam2 = p.lambda_0 + p.lambda_0 * bdt / (p.n_eff + bdt)
    detu = lam2 - p.lambda_ref
    g2 = p.gamma * p.gamma
    td2 = 2.0 * g2 / (detu * detu + g2) - 1.0
    return p.q_min + p.q_rng * (td2 - t_lo) / (t_hi - t_lo)


def _kernel(w_ref, edac_ref, eth_ref, o_ref, *, sigma_dac, sigma_th, p,
            t_hi, t_lo):
    o_ref[...] = _chain(w_ref[...], edac_ref[...], eth_ref[...],
                        sigma_dac, sigma_th, p, t_hi, t_lo)


@functools.partial(jax.jit, static_argnames=("sigma_dac", "sigma_th", "p",
                                             "block_rows", "interpret"))
def mrr_transfer_pallas(w_target: jax.Array, eps_dac: jax.Array,
                        eps_th: jax.Array, *, sigma_dac: float = 0.02,
                        sigma_th: float = 0.04,
                        p: mrr.MRRParams = mrr.DEFAULT_PARAMS,
                        block_rows: int = 8,
                        interpret: bool = False) -> jax.Array:
    # block_rows default MUST stay equal to ops.preflight's — the analysis
    # sweep prices the launched configuration, and the wrapper's noise-draw
    # padding (rows_pad) depends on it.  tests/test_kernels.py pins all
    # three defaults (kernel == wrapper == preflight) together.
    """2-D entry: (R, 128*k) tensors, R % block_rows == 0 (ops.py pads)."""
    rows, cols = w_target.shape
    assert rows % block_rows == 0, (rows, block_rows)
    t_hi, t_lo = mrr.transmission_endpoints_py(p)
    kernel = functools.partial(_kernel, sigma_dac=sigma_dac,
                               sigma_th=sigma_th, p=p, t_hi=t_hi, t_lo=t_lo)
    grid = (rows // block_rows,)
    spec = pl.BlockSpec((block_rows, cols), lambda i: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rows, cols), w_target.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(w_target, eps_dac, eps_th)
