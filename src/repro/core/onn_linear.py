"""Removed module (kept only as a pointer for stale imports)."""

raise ImportError(
    "repro.core.onn_linear was removed: rosa_matmul/RosaConfig live in "
    "repro.rosa, and per-layer routing is the compile-once Program API — "
    "see the rosa.compile migration table in src/repro/rosa/__init__.py")
