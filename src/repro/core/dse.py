"""OPE array-size design-space exploration (paper Sec. 3.5, Fig. 7).

Sweeps (R, C) under the physical constraints C <= MAX_WDM_CHANNELS and
T*R*C <= MAX_TOTAL_MRRS (T auto-filled to the budget), evaluates the EDP of
every workload network, and aggregates with

    G     = (prod_n EDP_n)^(1/N)            # balanced geometric mean
    W_max = max_n EDP_n                      # worst case
    M     = (1-lambda) * G + lambda * W_max  # robust efficiency metric

EDPs are expressed *relative to a reference config per workload* before
aggregation (the paper reports "relative EDP" vs. the compact 4x4 array) so
no single heavy network dominates the geomean.

Two evaluation engines produce identical `DSEPoint`s:

  * ``engine="vmap"`` (default) — candidates and layers are stacked into
    arrays (`core.energy_vec`) and the analytic EDP model is vmapped over
    the full candidate-grid x workload cross-product in ONE jitted float64
    call.  This is what makes model-zoo-scale sweeps (tens of candidates x
    thousands of GEMM rows) interactive.
  * ``engine="scalar"`` — the original nested-loop pure-Python path, kept
    as the parity reference; `tests/test_bench.py` pins the two to 1e-6
    relative on the default grid.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.core import energy as E
from repro.core import energy_vec as EV
from repro.core.constants import (COMPACT_4X4, DEAP_HIGH_CHANNEL, ComputeMode,
                                  Mapping, MAX_TOTAL_MRRS, MAX_WDM_CHANNELS,
                                  OPEConfig)


@dataclasses.dataclass
class Workload:
    name: str
    layers: list[E.LayerShape]


@dataclasses.dataclass
class DSEPoint:
    ope: OPEConfig
    edp_per_workload: dict[str, float]
    rel_edp: dict[str, float]
    geomean: float
    worst: float
    metric: float

    @property
    def label(self) -> str:
        return f"R={self.ope.rows},C={self.ope.cols},T={self.ope.tiles}"


def default_candidates(include_baselines: bool = True) -> list[OPEConfig]:
    """The sweep grid: all power-of-two-ish (R, C) within constraints."""
    rs = [1, 2, 4, 8, 16, 32, 64, 128]
    cs = [1, 2, 4, 8]
    cands = []
    for r in rs:
        for c in cs:
            if r * c <= MAX_TOTAL_MRRS and c <= MAX_WDM_CHANNELS:
                cands.append(OPEConfig(rows=r, cols=c))
    if include_baselines:
        cands.append(DEAP_HIGH_CHANNEL)      # violates C<=8; kept for comparison
    return cands


def evaluate(ope: OPEConfig,
             workloads: Sequence[Workload],
             reference: OPEConfig = COMPACT_4X4,
             lam: float = 0.3,
             mapping: Mapping = Mapping.WS,
             mode: ComputeMode = ComputeMode.MIXED,
             osa: E.OSAEnergyConfig = E.NO_OSA,
             batch: int = 1) -> DSEPoint:
    """Scalar reference: EDP of every workload on `ope`, aggregated."""
    edp, rel = {}, {}
    for wl in workloads:
        e = E.network_energy(wl.layers, ope, mapping, mode, osa, batch=batch).edp
        e_ref = E.network_energy(wl.layers, reference, mapping, mode, osa,
                                 batch=batch).edp
        edp[wl.name] = e
        rel[wl.name] = e / e_ref
    g = math.exp(sum(math.log(v) for v in rel.values()) / len(rel))
    w = max(rel.values())
    return DSEPoint(ope=ope, edp_per_workload=edp, rel_edp=rel,
                    geomean=g, worst=w, metric=(1 - lam) * g + lam * w)


# ---------------------------------------------------------------------------
# Vectorized engine
# ---------------------------------------------------------------------------
@jax.jit
def _grid_eval(cand: dict, layers: dict, onehot: jax.Array,
               spec: EV.EnergySpec, lam: jax.Array):
    """One fused evaluation of the whole grid.

    cand holds P+1 configs (the last row is the reference); onehot is the
    (L, W) layer->workload incidence matrix.  Returns per-candidate (P,W)
    absolute and relative EDP plus the (P,) aggregates.
    """
    energy, latency = EV.grid_energy(cand, layers, spec)      # (P+1, L)
    e_net = energy @ onehot                                   # (P+1, W)
    t_net = latency @ onehot
    edp = e_net * t_net
    rel = edp[:-1] / edp[-1:]                                 # vs reference
    geo = jnp.exp(jnp.mean(jnp.log(rel), axis=1))
    worst = jnp.max(rel, axis=1)
    metric = (1.0 - lam) * geo + lam * worst
    return edp[:-1], rel, geo, worst, metric


def evaluate_grid(workloads: Sequence[Workload],
                  candidates: Sequence[OPEConfig],
                  reference: OPEConfig = COMPACT_4X4,
                  lam: float = 0.3,
                  mapping: Mapping = Mapping.WS,
                  mode: ComputeMode = ComputeMode.MIXED,
                  osa: E.OSAEnergyConfig = E.NO_OSA,
                  batch: int = 1) -> list[DSEPoint]:
    """Vectorized DSE: all candidates x all workloads in one jitted call.

    Returns DSEPoints in candidate order (unsorted) so callers can line the
    results up against `candidates`.
    """
    names = [w.name for w in workloads]
    shapes: list[E.LayerShape] = []
    wl_id: list[int] = []
    for wi, wl in enumerate(workloads):
        shapes.extend(wl.layers)
        wl_id.extend([wi] * len(wl.layers))
    if not shapes:
        raise ValueError("no workload layers to evaluate")

    cand_arrays = EV.stack_candidates(list(candidates) + [reference])
    layer_arrays = EV.stack_layers(shapes)
    onehot = np.zeros((len(shapes), len(names)))
    onehot[np.arange(len(shapes)), np.array(wl_id)] = 1.0
    spec = EV.EnergySpec.make(mapping=mapping, mode=mode, osa=osa, batch=batch)

    with enable_x64():
        edp, rel, geo, worst, metric = _grid_eval(
            cand_arrays, layer_arrays, jnp.asarray(onehot, jnp.float64),
            spec, jnp.asarray(lam, jnp.float64))
        edp, rel, geo, worst, metric = map(np.asarray,
                                           (edp, rel, geo, worst, metric))

    return [
        DSEPoint(
            ope=ope,
            edp_per_workload={n: float(edp[i, j]) for j, n in enumerate(names)},
            rel_edp={n: float(rel[i, j]) for j, n in enumerate(names)},
            geomean=float(geo[i]), worst=float(worst[i]),
            metric=float(metric[i]))
        for i, ope in enumerate(candidates)
    ]


def sweep(workloads: Sequence[Workload],
          candidates: Sequence[OPEConfig] | None = None,
          lam: float = 0.3,
          engine: str = "vmap",
          **kw) -> list[DSEPoint]:
    """Full DSE; returns points sorted by the robust metric M (best first).

    ``engine="vmap"`` evaluates the whole grid in one jitted call;
    ``engine="scalar"`` is the pure-Python reference path.
    """
    candidates = candidates or default_candidates()
    if engine == "vmap":
        pts = evaluate_grid(workloads, candidates, lam=lam, **kw)
    elif engine == "scalar":
        pts = [evaluate(ope, workloads, lam=lam, **kw) for ope in candidates]
    else:
        raise ValueError(f"unknown DSE engine {engine!r}")
    pts.sort(key=lambda p: p.metric)
    return pts


def best(workloads: Sequence[Workload], **kw) -> DSEPoint:
    return sweep(workloads, **kw)[0]
