"""Thermal drift schedules and periodic re-trim (in-situ recalibration).

Deployed chips drift: ambient temperature and heater aging shift every
ring's operating point over minutes-to-hours (the photonic-accelerator
recalibration literature treats this as a first-class effect).  We model
drift as a global thermal offset d(t) [K] added to each chip's static
`ddt` field, and re-trim as the controller re-invoking the programming
calibration (`mrr.voltage_of_weight` with its `dt_trim` hook) against the
offset *measured at trim time* — so between trims the residual error is
d(t) - d(t_trim), and a trim instant is exactly compensated.

`simulate` reuses ONE jitted ensemble evaluator across the whole time
grid: each step only shifts the ensemble's ddt leaves (same shapes, no
retrace).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mrr
from repro.robust import variation as V
from repro.robust.ensemble import (ApplyFn, EnsembleResult,
                                   cnn_apply_fn, cnn_eval_set,
                                   make_ensemble_eval)


@dataclasses.dataclass(frozen=True)
class DriftModel:
    """Deterministic-in-key thermal drift schedule d(t) [K]."""

    kind: str = "sine"          # sine | linear | walk
    amp_k: float = 0.25         # peak offset [K]
    period_s: float = 3600.0    # sine period / linear ramp horizon [s]

    def offsets(self, t_grid: np.ndarray,
                key: jax.Array | None = None) -> np.ndarray:
        """Offsets d(t) sampled on the grid; `walk` needs a key (Gaussian steps
        scaled so the horizon-end std is ~amp_k).
        """
        t = np.asarray(t_grid, dtype=np.float64)
        if self.kind == "sine":
            return self.amp_k * np.sin(2.0 * np.pi * t / self.period_s)
        if self.kind == "linear":
            return self.amp_k * t / self.period_s
        if self.kind == "walk":
            if key is None:
                raise ValueError("random-walk drift requires a PRNG key")
            steps = np.array(jax.random.normal(key, (len(t),)))
            steps[0] = 0.0
            walk = np.cumsum(steps)
            return self.amp_k * walk / max(np.sqrt(len(t) - 1), 1.0)
        raise ValueError(f"unknown drift kind {self.kind!r}")

    def offsets_at(self, t, key: jax.Array | None = None,
                   t_grid=None) -> jax.Array:
        """Jit-compatible single-timestep d(t): a traceable scalar (or
        batch) instead of the materialized numpy grid of `offsets`.

        `sine` and `linear` are closed-form.  `walk` is path-dependent, so
        it additionally needs the `key` and the (static-shape) `t_grid`
        the walk is defined on: the step table is rebuilt with jnp ops
        bit-compatible with `offsets` and linearly interpolated at `t`
        (exact on grid points).  The in-loop serving controller queries
        this once per tick; `tests/test_adaptive.py` pins parity with the
        grid path for all three kinds.
        """
        t = jnp.asarray(t)
        if self.kind == "sine":
            return self.amp_k * jnp.sin(2.0 * jnp.pi * t / self.period_s)
        if self.kind == "linear":
            return self.amp_k * t / self.period_s
        if self.kind == "walk":
            if key is None:
                raise ValueError("random-walk drift requires a PRNG key")
            if t_grid is None:
                raise ValueError(
                    "random-walk drift is path-dependent: offsets_at needs "
                    "the t_grid the walk is defined on")
            grid = jnp.asarray(t_grid, dtype=jnp.float32)
            n = int(grid.shape[0])
            steps = jax.random.normal(key, (n,)).at[0].set(0.0)
            table = self.amp_k * jnp.cumsum(steps) / max(np.sqrt(n - 1), 1.0)
            return jnp.interp(t, grid, table)
        raise ValueError(f"unknown drift kind {self.kind!r}")


def trim_voltages(w_target, dt_known, p: mrr.MRRParams = mrr.DEFAULT_PARAMS):
    """Re-invoke the programming calibration against a measured thermal
    offset: voltages such that, WITH the offset present, the realized
    weights hit their targets exactly (clipping aside).
    """
    return jnp.clip(mrr.voltage_of_weight(w_target, p, dt_trim=dt_known),
                    p.v_min, p.v_max)


def residual_offsets(offsets: np.ndarray, t_grid: np.ndarray,
                     retrim_every: float | None) -> np.ndarray:
    """Effective offset after periodic re-trim: d(t) - d(last trim <= t).

    The offset measured at trim time is linearly interpolated on the
    sampled schedule (exact whenever trims land on grid points) — snapping
    to the previous grid sample would silently ignore trims falling
    between samples.  `retrim_every=None` disables re-trim (residual = raw
    drift; a single calibration at t=0 is always assumed).
    """
    t = np.asarray(t_grid, dtype=np.float64)
    if retrim_every is None:
        return offsets - offsets[0]
    t_trims = (t // retrim_every) * retrim_every
    return offsets - np.interp(t_trims, t, offsets)


@dataclasses.dataclass
class DriftResult:
    """Time series of ensemble accuracy under a drift schedule."""
    times: np.ndarray               # (T,) [s]
    residual_k: np.ndarray          # (T,) effective thermal offset [K]
    mean_acc: np.ndarray            # (T,) ensemble-mean accuracy [%]
    min_acc: np.ndarray             # (T,)
    yield_2pp: np.ndarray           # (T,) yield at 2 pp drop
    clean_acc: float

    def worst_mean_acc(self) -> float:
        """Lowest ensemble-mean accuracy over the time grid."""
        return float(self.mean_acc.min())

    def summary(self) -> dict:
        """One-level dict of the headline drift statistics."""
        return {"clean_acc": self.clean_acc,
                "worst_mean_acc": self.worst_mean_acc(),
                "final_mean_acc": float(self.mean_acc[-1]),
                "min_yield_2pp": float(self.yield_2pp.min())}


def simulate(apply_fn: ApplyFn, params, x, y, engine, ensemble: V.Chip,
             key: jax.Array, drift: DriftModel, t_grid,
             retrim_every: float | None = None, *,
             eval_batch: int = 128,
             yield_drop_pp: float = 2.0,
             evaluator=None) -> DriftResult:
    """Accuracy-over-time of a chip ensemble under a drift schedule,
    with optional periodic re-trim.  One compiled evaluator serves every
    time step (only the ddt leaves change); pass `evaluator` (a
    `make_ensemble_eval` result for the same apply_fn/engine/eval_batch)
    to reuse the compilation across several simulations — e.g. the
    with/without-re-trim pair.
    """
    t = np.asarray(t_grid, dtype=np.float64)
    key, k_walk = jax.random.split(key)
    offs = drift.offsets(t, k_walk)
    resid = residual_offsets(offs, t, retrim_every)

    n = V.ensemble_size(ensemble)
    run = evaluator if evaluator is not None \
        else make_ensemble_eval(apply_fn, engine, eval_batch=eval_batch)
    mean_acc, min_acc, yld = [], [], []
    clean = 0.0
    for i in range(len(t)):
        ens_t = V.shift_thermal(ensemble, resid[i])
        keys = jax.random.split(jax.random.fold_in(key, i), n)
        accs, agreement, clean_acc = run(params, x, y, ens_t, keys)
        res = EnsembleResult(np.asarray(accs), np.asarray(agreement),
                             float(clean_acc))
        clean = res.clean_acc
        mean_acc.append(res.mean_acc)
        min_acc.append(res.min_acc)
        yld.append(res.yield_frac(yield_drop_pp))
    return DriftResult(times=t, residual_k=resid,
                       mean_acc=np.asarray(mean_acc),
                       min_acc=np.asarray(min_acc),
                       yield_2pp=np.asarray(yld), clean_acc=clean)


def simulate_cnn(params, model: str, engine, ensemble: V.Chip,
                 key: jax.Array, drift: DriftModel, t_grid,
                 retrim_every: float | None = None, *,
                 n_eval: int = 256, eval_batch: int = 128,
                 evaluator=None) -> DriftResult:
    """CNN front-end of `simulate` on the synth-CIFAR eval set."""
    x, y = cnn_eval_set(n_eval)
    return simulate(cnn_apply_fn(model), params, x, y, engine, ensemble,
                    key, drift, t_grid, retrim_every,
                    eval_batch=eval_batch, evaluator=evaluator)
