"""Pure-jnp oracle for the Mamba-2 SSD chunked scan kernel.

State-space duality (SSD) recurrence, per (batch, head):

    S_t = a_t * S_{t-1} + b_t x_t^T          S in R^{d_state x d_head}
    y_t = c_t @ S_t                          y in R^{d_head}

with a_t = exp(A * dt_t) in (0, 1] the scalar per-step decay, b_t, c_t in
R^{d_state}, x_t in R^{d_head}.  This sequential lax.scan is the ground
truth; the kernel computes the chunked matmul form (intra-chunk masked
attention + inter-chunk state carry) which is algebraically identical.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(x: jax.Array, a: jax.Array, b: jax.Array,
                 c: jax.Array, s0: jax.Array | None = None):
    """Sequential oracle.

    x: (L, P) inputs;  a: (L,) decays in (0,1];  b, c: (L, S) in/out
    projections; s0: (S, P) initial state.  Returns (y: (L, P), s_f: (S, P)).
    """
    l, p = x.shape
    s_dim = b.shape[-1]
    if s0 is None:
        s0 = jnp.zeros((s_dim, p), x.dtype)

    def step(s, inp):
        xt, at, bt, ct = inp
        s = at * s + bt[:, None] * xt[None, :]
        y = ct @ s
        return s, y

    s_f, y = jax.lax.scan(step, s0, (x, a, b, c))
    return y, s_f


def ssd_scan_chunked_ref(x, a, b, c, chunk: int, s0=None):
    """Chunked matmul formulation (what the kernel implements), pure jnp.

    Within a chunk of length Q (log-decay prefix sums l_i = sum_{j<=i} log a_j):
      intra:  Y[i] += sum_{j<=i} (c_i . b_j) * exp(l_i - l_j) * x_j
      inter:  Y[i] += exp(l_i) * c_i @ S_in
      carry:  S_out = exp(l_Q) * S_in + sum_j exp(l_Q - l_j) * b_j x_j^T
    """
    l, p = x.shape
    s_dim = b.shape[-1]
    assert l % chunk == 0
    n_chunks = l // chunk
    if s0 is None:
        s0 = jnp.zeros((s_dim, p), jnp.float32)

    xs = x.reshape(n_chunks, chunk, p).astype(jnp.float32)
    as_ = a.reshape(n_chunks, chunk).astype(jnp.float32)
    bs = b.reshape(n_chunks, chunk, s_dim).astype(jnp.float32)
    cs = c.reshape(n_chunks, chunk, s_dim).astype(jnp.float32)

    def chunk_step(s, inp):
        xq, aq, bq, cq = inp
        loga = jnp.log(aq)
        lcum = jnp.cumsum(loga)                        # l_i (inclusive)
        ltot = lcum[-1]
        # intra-chunk masked kernel: decay(i, j) = exp(l_i - l_j) for j <= i
        dmat = jnp.exp(lcum[:, None] - lcum[None, :])
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        att = (cq @ bq.T) * jnp.where(mask, dmat, 0.0)
        y = att @ xq
        # inter-chunk contribution from the incoming state
        y = y + jnp.exp(lcum)[:, None] * (cq @ s)
        # state carry
        w = jnp.exp(ltot - lcum)                       # per-step carry weight
        s_new = jnp.exp(ltot) * s + (bq * w[:, None]).T @ xq
        return s_new, y

    s_f, ys = jax.lax.scan(chunk_step, s0, (xs, as_, bs, cs))
    return ys.reshape(l, p), s_f
