"""Minimal functional module system: param skeletons with logical axes.

No flax — params are plain pytrees.  A model first builds a *skeleton*
(nested dict of ParamDef), from which we derive, with one tree_map each:

  * init_params(skel, key)        -> pytree of jnp arrays (real init)
  * abstract_params(skel)         -> pytree of ShapeDtypeStruct (dry-run)
  * logical_axes(skel)            -> pytree of axis-name tuples

Logical axis names are resolved to mesh axes by distributed/sharding.py
(MaxText-style rules table), so model code never mentions mesh axes.

The paper's technique enters the model zoo through `repro.rosa`: linear
layers route their contractions through a `rosa.Engine` (or, compile-once,
a `rosa.Program` built by `rosa.compile`).  The old `MatmulBackend` shim
was removed after its last importers migrated.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Param skeletons
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]          # logical axis per dim
    init: str = "normal"                  # normal | zeros | ones | scaled
    scale: float | None = None            # stddev override

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def init_params(skel, key: jax.Array, dtype=jnp.float32):
    leaves, treedef = jax.tree.flatten(skel, is_leaf=_is_def)
    keys = jax.random.split(key, len(leaves))

    def mk(d: ParamDef, k):
        if d.init == "zeros":
            return jnp.zeros(d.shape, dtype)
        if d.init == "ones":
            return jnp.ones(d.shape, dtype)
        std = d.scale if d.scale is not None else 1.0 / np.sqrt(
            max(1, d.shape[0] if len(d.shape) > 1 else d.shape[-1]))
        return (jax.random.normal(k, d.shape, jnp.float32) * std).astype(dtype)

    return jax.tree.unflatten(treedef, [mk(d, k) for d, k in zip(leaves, keys)])


def abstract_params(skel, dtype=jnp.bfloat16):
    return jax.tree.map(lambda d: jax.ShapeDtypeStruct(d.shape, dtype), skel,
                        is_leaf=_is_def)


def logical_axes(skel):
    return jax.tree.map(lambda d: d.axes, skel, is_leaf=_is_def)


def param_count(skel) -> int:
    return sum(int(np.prod(d.shape))
               for d in jax.tree.leaves(skel, is_leaf=_is_def))


def __getattr__(name: str):
    if name in ("MatmulBackend", "DENSE"):
        raise ImportError(
            f"repro.models.module.{name} was removed: use rosa.Engine / "
            "rosa.compile — see the migration table in "
            "src/repro/rosa/__init__.py")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


# ---------------------------------------------------------------------------
# Small shared helpers
# ---------------------------------------------------------------------------


def linear_def(d_in: int, d_out: int, axes=("embed", "mlp"),
               scale: float | None = None) -> ParamDef:
    return ParamDef((d_in, d_out), axes, "normal", scale)


def merge(*trees) -> dict:
    out: dict = {}
    for t in trees:
        out.update(t)
    return out


Pytree = Any
Forward = Callable[..., Any]
