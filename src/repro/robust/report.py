"""Robustness reports in the `repro.bench` schema.

Accuracy-vs-sigma sweeps and yield curves are serialized as schema-valid
``BENCH_<n>.json`` documents (one `BenchResult` per experiment, typed
`Metric`s inside), so the same `repro.bench.compare` gate that guards the
perf benches can gate robustness regressions — direction semantics:
yield and accuracy metrics are ``higher_is_better``, degradations
``lower_is_better``.
"""

from __future__ import annotations

import datetime
import platform
from pathlib import Path
from typing import Callable, Sequence

from repro.bench.schema import BenchReport, BenchResult, Metric, save
from repro.robust.ensemble import EnsembleResult


def ensemble_metrics(res: EnsembleResult, *, prefix: str = "",
                     yield_drop_pp: float = 2.0,
                     gate: bool = False,
                     acc_rel_tol: float = 0.1,
                     yield_rel_tol: float = 0.5) -> list[Metric]:
    # yields are quantized to 1/n_chips: the tolerance must absorb a
    # couple of chips flipping across CPU generations (XLA numerics).
    # acc_rel_tol 0.1 (was 0.05): XLA CPU reduction-order drift moves
    # trained-CNN accuracies by up to ~2pp per machine generation — the
    # drift is born in the conv/GEMM training reductions, not in the
    # accuracy means (those are exact counts), so no fixed-order sum on
    # our side can remove it; the widened tolerance is the documented fix
    # (see docs/robustness.md "Bench gating").
    """Typed metrics of one ensemble evaluation (gated on request)."""
    p = f"{prefix}_" if prefix else ""
    return [
        Metric(f"{p}n_chips", res.n_chips, gate=gate, rel_tol=0.0),
        Metric(f"{p}clean_acc", res.clean_acc, unit="%"),
        Metric(f"{p}mean_acc", res.mean_acc, unit="%", gate=gate,
               rel_tol=acc_rel_tol, direction="higher_is_better"),
        Metric(f"{p}min_acc", res.min_acc, unit="%"),
        Metric(f"{p}mean_drop_pp", res.mean_drop_pp, unit="pp"),
        Metric(f"{p}yield_{yield_drop_pp:g}pp", res.yield_frac(yield_drop_pp),
               unit="frac", gate=gate, rel_tol=yield_rel_tol,
               direction="higher_is_better"),
    ]


def yield_curve_metrics(res: EnsembleResult,
                        drops_pp: Sequence[float] = (1.0, 2.0, 5.0),
                        prefix: str = "") -> list[Metric]:
    """Ungated yield metrics over a drop-threshold grid."""
    p = f"{prefix}_" if prefix else ""
    return [Metric(f"{p}yield_{d:g}pp", y, unit="frac",
                   direction="higher_is_better")
            for d, y in res.yield_curve(drops_pp)]


def sigma_sweep(eval_at: Callable[[float], EnsembleResult],
                scales: Sequence[float], *,
                yield_drop_pp: float = 2.0) -> list[dict]:
    """Accuracy/yield vs. noise-scale rows: `eval_at(s)` must evaluate the
    ensemble with per-shot sigmas AND static-variation sigmas scaled by
    `s` (0 = ideal chip).
    """
    rows = []
    for s in scales:
        res = eval_at(float(s))
        rows.append({"scale": float(s), **res.summary(),
                     "yield": res.yield_frac(yield_drop_pp)})
    return rows


def sweep_metrics(rows: Sequence[dict]) -> list[Metric]:
    """Gated accuracy/yield metrics of a sigma sweep."""
    out = []
    for r in rows:
        tag = f"s{r['scale']:g}".replace(".", "p")
        out.append(Metric(f"acc_{tag}", r["mean_acc"], unit="%",
                          direction="higher_is_better"))
        out.append(Metric(f"yield_{tag}", r["yield"], unit="frac",
                          direction="higher_is_better"))
    return out


def build_report(results: Sequence[BenchResult], *, seq: int = 0,
                 mode: str = "quick") -> BenchReport:
    """Wrap results in a schema-valid BenchReport (env stamped)."""
    import jax
    return BenchReport(
        bench_seq=seq, mode=mode,
        created_utc=datetime.datetime.now(datetime.timezone.utc)
        .strftime("%Y-%m-%dT%H:%M:%SZ"),
        env={"python": platform.python_version(), "jax": jax.__version__,
             "platform": platform.platform()},
        results=list(results))


def save_report(results: Sequence[BenchResult], path: str | Path, *,
                seq: int = 0, mode: str = "quick") -> Path:
    """Validate + write a robustness report (schema round-trip safe)."""
    return save(build_report(results, seq=seq, mode=mode), Path(path))
