"""Production mesh factory.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run must set
XLA_FLAGS before the first jax call, and tests must see 1 device.

Single pod:  (16, 16)      axes ("data", "model")    = 256 chips
Multi-pod :  (2, 16, 16)   axes ("pod", "data", "model") = 512 chips;
             the "pod" axis is the DCN-like cross-pod boundary — gradients
             reduce over it, weights FSDP over (pod, data).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Tiny mesh over however many (CPU) devices the test process has."""
    return jax.make_mesh((data, model), ("data", "model"))
