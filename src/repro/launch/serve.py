"""Batched serving driver: prefill a batch of prompts, decode N tokens.

Demonstrates the serving path end-to-end on real devices (CPU here):
prefill -> padded KV cache -> jitted decode loop with donated cache.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-12b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke
from repro.models.model import build_model, pad_cache


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-12b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    bundle = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = bundle.init(key)
    print(f"arch={cfg.name} params={bundle.n_params:,}")

    b, s = args.batch, args.prompt_len
    prompt = jax.random.randint(key, (b, s), 0, cfg.vocab, dtype=jnp.int32)
    batch = {"tokens": prompt}
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jnp.zeros((b, 16, cfg.d_model), jnp.bfloat16)
    if cfg.frontend == "audio":
        batch["src_embeds"] = jax.random.normal(
            key, (b, s, cfg.d_model), jnp.float32).astype(jnp.bfloat16)

    t0 = time.time()
    logits, cache = jax.jit(bundle.prefill)(params, batch)
    cache = pad_cache(cfg, cache, args.gen + 1)
    print(f"prefill {b}x{s}: {time.time() - t0:.2f}s")

    # donate ONLY the cache operand: its buffers are dead after each step
    # (the returned cache replaces them), so XLA can update the KV state in
    # place instead of copying it every token.  token stays un-donated (it
    # is rebuilt from the logits), and pos rides inside the donated cache.
    @functools.partial(jax.jit, donate_argnums=(2,))
    def decode(params, tok, cache):
        return bundle.decode_step(params, {"token": tok, "pos": cache["pos"],
                                           "cache": cache})

    tok = jnp.argmax(logits, -1)
    out = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = decode(params, tok, cache)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits / args.temperature, -1)
        else:
            tok = jnp.argmax(logits, -1)
        out.append(tok)
    dt = time.time() - t0
    toks = jnp.stack(out, 1)
    print(f"decoded {args.gen} tokens x {b} seqs in {dt:.2f}s "
          f"({b * args.gen / max(dt, 1e-9):.1f} tok/s)")
    print("sample token ids:", toks[0, :12].tolist())


if __name__ == "__main__":
    main()
